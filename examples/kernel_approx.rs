//! Kernel approximation (the §6.2 workload): approximate an RBF kernel
//! with Nyström, fast SPSD (Wang et al. 2016b), **faster SPSD**
//! (Algorithm 2, the paper's method) and the optimal core, reporting
//! error ratios and — the paper's key axis — the number of kernel
//! entries each method has to compute.
//!
//! ```bash
//! cargo run --release --example kernel_approx
//! ```

use fastgmr::compute::CpuBackend;
use fastgmr::coordinator::TiledKernelOracle;
use fastgmr::data::{calibrate_sigma, rbf_kernel, synth_clustered};
use fastgmr::rng::rng;
use fastgmr::spsd::{
    error_ratio, fast_spsd_core, faster_spsd_core, nystrom_core, optimal_core, CountingOracle,
    DenseKernelOracle, KernelOracle,
};

fn main() {
    let mut r = rng(0);
    let (n, d, k) = (1500, 64, 15);

    println!("building {n}-point dataset and calibrating sigma to eta=0.9 at k={k}…");
    let x = synth_clustered(n, d, 10, 0.4, &mut r);
    let sigma = calibrate_sigma(&x, k, 0.9, &mut r);
    println!("sigma = {sigma:.4}");

    // Full kernel for error evaluation only — the approximation methods
    // observe K strictly through counting oracles.
    let kfull = rbf_kernel(&x, sigma);
    let dense_oracle = DenseKernelOracle { k: &kfull };

    let c_dim = 2 * k;
    let idx = r.sample_without_replacement(n, c_dim);
    let c = dense_oracle.columns(&idx);
    println!("\nC = {c_dim} uniformly sampled kernel columns (n·c = {} entries)\n", n * c_dim);

    // Optimal core (observes everything).
    let x_opt = optimal_core(&dense_oracle, &c);
    println!("optimal      : err {:.4}  entries {} (all of K)", error_ratio(&kfull, &c, &x_opt), n * n);

    // Nyström (observes only C).
    let x_nys = nystrom_core(&c, &idx);
    println!("nystrom      : err {:.4}  entries {}", error_ratio(&kfull, &c, &x_nys), n * c_dim);

    // Fast SPSD (Wang et al.) and faster SPSD (ours) at the same s = 10c.
    let s = 10 * c_dim;
    let counting = CountingOracle::new(&dense_oracle);
    let x_wang = fast_spsd_core(&counting, &c, s, &mut r);
    println!(
        "fast  (wang) : err {:.4}  extra entries {} (s = {s})",
        error_ratio(&kfull, &c, &x_wang),
        counting.observed()
    );

    let counting2 = CountingOracle::new(&dense_oracle);
    let x_ours = faster_spsd_core(&counting2, &c, s, &mut r);
    println!(
        "faster (ours): err {:.4}  extra entries {} (s = {s})",
        error_ratio(&kfull, &c, &x_ours),
        counting2.observed()
    );

    // Production path: the same Algorithm 2 through the coordinator's
    // tiled oracle, where every entry is computed by the compute backend
    // (the PJRT rbf_block artifact when available; CPU here).
    println!("\n— production path: TiledKernelOracle over the compute backend —");
    let backend = CpuBackend;
    let tiled = TiledKernelOracle::new(&x, sigma, &backend, 256);
    let x_tiled = faster_spsd_core(&tiled, &c, s, &mut r);
    println!(
        "faster(tiled): err {:.4}  entries requested {}  backend tiles {}",
        error_ratio(&kfull, &c, &x_tiled),
        tiled.entries_requested(),
        tiled.tiles_executed()
    );

    println!("\nTheorem 3: ours observes nc + s² = {} ≪ n² = {}.", n * c_dim + s * s, n * n);
}
