//! Single-pass SVD (the §6.3 workload): stream a matrix once through
//! Fast SP-SVD (Algorithm 3) and the Practical SP-SVD baseline
//! (Algorithm 4, Tropp et al. 2017), comparing error ratios at equal
//! sketch budgets; then an input-sparsity run over a sparse stream.
//!
//! ```bash
//! cargo run --release --example streaming_svd
//! ```

use fastgmr::data::{synth_dense, synth_sparse, SpectrumKind};
use fastgmr::gmr::Input;
use fastgmr::rng::rng;
use fastgmr::sketch::SketchKind;
use fastgmr::svdstream::source::{CsrColumnStream, DenseColumnStream};
use fastgmr::svdstream::{
    ak_error, fast_sp_svd, practical_sp_svd, reconstruction_error_input, FastSpSvdConfig,
    PracticalSpSvdConfig,
};

fn main() {
    let mut r = rng(0);
    let k = 10;

    // Dense stream.
    let (m, n) = (3000, 2500);
    println!("dense {m}x{n}, target rank k={k}");
    let a = synth_dense(m, n, 50, SpectrumKind::Exponential { base: 0.85 }, 0.02, &mut r);
    let ak = ak_error(Input::Dense(&a), k, 6, &mut r);
    println!("‖A − A_k‖_F = {ak:.4}\n  budget    fast(ours)   practical");
    for mult in [2usize, 4, 8] {
        let cfg_f = FastSpSvdConfig::paper(k, mult, SketchKind::Gaussian);
        let mut s1 = DenseColumnStream::new(&a, 256);
        let res_f = fast_sp_svd(&mut s1, &cfg_f, &mut r);
        let e_f = reconstruction_error_input(Input::Dense(&a), &res_f) / ak - 1.0;

        let cfg_p = PracticalSpSvdConfig::from_budget(k, 2 * mult * k, SketchKind::Gaussian);
        let mut s2 = DenseColumnStream::new(&a, 256);
        let res_p = practical_sp_svd(&mut s2, &cfg_p, &mut r);
        let e_p = reconstruction_error_input(Input::Dense(&a), &res_p) / ak - 1.0;
        println!("  (c+r)/k={:>2}  {e_f:>8.4}    {e_p:>8.4}", 2 * mult);
    }

    // Sparse stream (input-sparsity path: CountSketch core sketches).
    let (m, n) = (8000, 12000);
    println!("\nsparse {m}x{n} (0.2% density), single pass with CountSketch");
    let sp = synth_sparse(m, n, 0.002, 30, &mut r);
    println!("nnz = {}", sp.nnz());
    let ak = ak_error(Input::Sparse(&sp), k, 6, &mut r);
    let cfg = FastSpSvdConfig::paper(k, 4, SketchKind::Count);
    let start = std::time::Instant::now();
    let mut stream = CsrColumnStream::new(&sp, 512);
    let res = fast_sp_svd(&mut stream, &cfg, &mut r);
    let secs = start.elapsed().as_secs_f64();
    let e = reconstruction_error_input(Input::Sparse(&sp), &res) / ak - 1.0;
    println!(
        "fast SP-SVD: error ratio {e:.4} in {secs:.2}s ({} blocks, single pass, {:.1} Mnnz/s)",
        res.blocks,
        sp.nnz() as f64 / secs / 1e6
    );
    println!("\nmemory note: accumulators are O((m+n)·k/ε); the matrix is only ever resident as blocks.");
}
