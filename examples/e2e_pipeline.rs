//! End-to-end driver: the full three-layer system on a real workload.
//!
//! Streams a 2048×4096 synthetic dataset (a "real-sim"-profile matrix)
//! through the single-pass SVD pipeline with the **PJRT backend on the
//! hot path** — every block update runs the AOT-compiled JAX/Pallas
//! `stream_update` artifact, and the core solve runs the `gmr_solve`
//! artifact (Cholesky inside the HLO). The CPU backend runs the same
//! stream as a cross-check; the paper's headline metric (error ratio vs
//! ‖A − A_k‖_F) and throughput are reported for both.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```
//!
//! Recorded in EXPERIMENTS.md §End-to-end.

use fastgmr::compute::{Backend, CpuBackend, PjrtBackend};
use fastgmr::data::{synth_dense, SpectrumKind};
use fastgmr::gmr::Input;
use fastgmr::linalg::{matmul, pinv_apply_left, pinv_apply_right, qr_thin, svd_jacobi, Mat};
use fastgmr::rng::rng;
use fastgmr::runtime::Engine;
use fastgmr::svdstream::{ak_error, SpSvdResult};
use std::sync::Arc;
use std::time::Instant;

// Shapes match the `stream_2048x512x64x64x192x192` and
// `gmr_solve_192x64x192x64` artifacts.
const M: usize = 2048;
const N: usize = 4096;
const L: usize = 512;
const C: usize = 64;
const R: usize = 64;
const SC: usize = 192;
const SR: usize = 192;
const K: usize = 10;

fn main() -> anyhow::Result<()> {
    let mut r = rng(0);
    println!("building {M}x{N} workload (decaying spectrum + noise)…");
    let a = synth_dense(M, N, 80, SpectrumKind::Exponential { base: 0.92 }, 0.02, &mut r);
    let ak = ak_error(Input::Dense(&a), K, 6, &mut r);
    println!("‖A − A_k‖_F = {ak:.4} at k = {K}");

    // Dense sketch operators sized for the artifacts (hardware adaptation:
    // the TPU-facing path materializes sketches densely per tile and uses
    // the MXU; see DESIGN.md §Hardware-Adaptation).
    let scale = |s: usize| 1.0 / (s as f64).sqrt();
    let mut omega_t = Mat::randn(N, C, &mut r); // Ω̃ (n×c)
    omega_t.scale(scale(C));
    let mut psi = Mat::randn(R, M, &mut r); // Ψ̃ (r×m)
    psi.scale(scale(R));
    let mut sc = Mat::randn(SC, M, &mut r); // S_C
    sc.scale(scale(SC));
    let mut sr = Mat::randn(SR, N, &mut r); // S_R
    sr.scale(scale(SR));

    let cpu = CpuBackend;
    let engine = match Engine::new("artifacts") {
        Ok(e) => Some(Arc::new(e)),
        Err(e) => {
            println!("(PJRT path unavailable: {e})");
            None
        }
    };

    let (res_cpu, t_cpu) = run_pipeline(&cpu, &a, &omega_t, &psi, &sc, &sr, None)?;
    report("cpu ", &a, &res_cpu, ak, t_cpu);

    if let Some(engine) = engine {
        let pjrt = PjrtBackend::new(engine.clone());
        let gmr_graph = engine.load("gmr_solve_192x64x192x64").ok();
        let (res_pjrt, t_pjrt) =
            run_pipeline(&pjrt, &a, &omega_t, &psi, &sc, &sr, gmr_graph.as_deref())?;
        report("pjrt", &a, &res_pjrt, ak, t_pjrt);

        // Cross-check: both backends computed the same algorithm.
        let du = fastgmr::linalg::fro_norm_diff(&res_cpu.u, &res_pjrt.u) / res_cpu.u.fro_norm();
        println!("\nbackend agreement: ‖U_cpu − U_pjrt‖/‖U‖ = {du:.2e} (f32 artifact boundary)");
    }
    Ok(())
}

/// The streaming pipeline over a compute backend: Algorithm 3 with dense
/// sketch tiles, block by block, single pass.
fn run_pipeline(
    backend: &dyn Backend,
    a: &Mat,
    omega_t: &Mat,
    psi: &Mat,
    sc: &Mat,
    sr: &Mat,
    gmr_graph: Option<&fastgmr::runtime::LoadedGraph>,
) -> anyhow::Result<(SpSvdResult, f64)> {
    let start = Instant::now();
    let mut c_acc = Mat::zeros(M, C);
    let mut r_acc = Mat::zeros(R, N);
    let mut m_acc = Mat::zeros(SC, SR);
    let mut blocks = 0;
    for c0 in (0..N).step_by(L) {
        let c1 = (c0 + L).min(N);
        let a_l = a.slice(0, M, c0, c1);
        let om_slice = omega_t.slice(c0, c1, 0, C);
        let sr_slice = sr.slice(0, SR, c0, c1);
        let (c_d, r_b, m_d) = backend
            .stream_update(&a_l, &om_slice, psi, sc, &sr_slice)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        c_acc += &c_d;
        r_acc.set_block(0, c0, &r_b);
        m_acc += &m_d;
        blocks += 1;
    }

    // Finalize: orthonormal bases + Fast-GMR core solve + small SVD.
    let u_c = qr_thin(&c_acc).q; // m x C
    let v_r = qr_thin(&r_acc.transpose()).q; // n x R
    let sc_uc = matmul(sc, &u_c); // SC x C
    let vr_sr = matmul(&v_r.transpose(), &sr.transpose()); // R x SR
    let n_core = match gmr_graph {
        // The AOT gmr_solve artifact (Cholesky inside HLO).
        Some(g) => {
            let out = g.run(&[&sc_uc, &m_acc, &vr_sr]).map_err(|e| anyhow::anyhow!("{e}"))?;
            out.into_iter().next().unwrap()
        }
        None => {
            let left = pinv_apply_left(&sc_uc, &m_acc);
            pinv_apply_right(&left, &vr_sr)
        }
    };
    let svd = svd_jacobi(&n_core);
    let u = matmul(&u_c, &svd.u);
    let v = matmul(&v_r, &svd.v);
    let secs = start.elapsed().as_secs_f64();
    Ok((SpSvdResult { u, sigma: svd.s, v, blocks }, secs))
}

fn report(tag: &str, a: &Mat, res: &SpSvdResult, ak: f64, secs: f64) {
    let err = fastgmr::svdstream::reconstruction_error_input(Input::Dense(a), res);
    println!(
        "[{tag}] blocks={} time={secs:.2}s  throughput={:.0} cols/s ({:.1} MB/s)  error ratio={:+.4}",
        res.blocks,
        N as f64 / secs,
        (M * N * 8) as f64 / secs / 1e6,
        err / ak - 1.0
    );
}
