//! Quickstart: solve one generalized matrix regression problem three ways
//! (exact, Fast GMR with Gaussian sketches, Fast GMR with CountSketch)
//! and print the error ratios and timings.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fastgmr::data::{synth_dense, SpectrumKind};
use fastgmr::gmr::{compute_rho, relative_regret, solve_exact, solve_fast, FastGmrConfig, Input};
use fastgmr::linalg::{matmul, Mat};
use fastgmr::rng::rng;
use std::time::Instant;

fn main() {
    let mut r = rng(0);

    // A 2000x1500 matrix with a decaying spectrum plus noise —
    // the regime the paper targets (Section 6.1).
    let (m, n) = (2000, 1500);
    println!("building {m}x{n} test matrix…");
    let a = synth_dense(m, n, 60, SpectrumKind::Exponential { base: 0.9 }, 0.02, &mut r);

    // C = A·G_C and R = G_R·A with c = r = 20, exactly as in §6.1.
    let (c_dim, r_dim) = (20, 20);
    let g_c = Mat::randn(n, c_dim, &mut r);
    let c = matmul(&a, &g_c);
    let g_r = Mat::randn(r_dim, m, &mut r);
    let rr = matmul(&g_r, &a);

    // The spectral ratio rho decides the sketch-size regime (Remark 2).
    let rho = compute_rho(Input::Dense(&a), &c, &rr);
    println!("rho = {:.3}  (1/rho² ≤ √ε ⇒ sketch sizes scale as ε^-1/2)", rho.rho());

    // Exact GMR: X* = C† A R†.
    let t0 = Instant::now();
    let exact = solve_exact(Input::Dense(&a), &c, &rr);
    let t_exact = t0.elapsed().as_secs_f64();
    println!("exact GMR:            {t_exact:.3}s");

    // Fast GMR (Algorithm 1), sketch sizes s = a·c for a = 8.
    for (label, cfg) in [
        ("fast GMR (gaussian)", FastGmrConfig::gaussian(160, 160)),
        ("fast GMR (count)   ", FastGmrConfig::count(160, 160)),
        ("fast GMR (leverage)", FastGmrConfig::leverage(160, 160)),
    ] {
        let t0 = Instant::now();
        let sol = solve_fast(Input::Dense(&a), &c, &rr, &cfg, &mut r);
        let t_fast = t0.elapsed().as_secs_f64();
        let regret = relative_regret(Input::Dense(&a), &c, &rr, &sol.x, &exact.x);
        println!(
            "{label}: {t_fast:.3}s  ({:.1}x speedup)  error ratio {regret:.4}",
            t_exact / t_fast
        );
    }

    println!("\n(1+ε)-guarantee check: all error ratios above should be well under 0.1 at a = 8.");
}
