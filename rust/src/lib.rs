//! # fastgmr — Fast Generalized Matrix Regression
//!
//! A from-scratch reproduction of *"Fast Generalized Matrix Regression
//! with Applications in Machine Learning"* (Ye, Wang, Zhang, Zhang, 2019)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 1/2 (build time)** — Pallas kernels and JAX compute graphs in
//!   `python/compile/`, AOT-lowered to HLO text artifacts.
//! * **Layer 3 (this crate)** — streaming coordinator, sketching library,
//!   the paper's algorithms (Fast GMR, faster-SPSD, fast single-pass SVD)
//!   plus every baseline, a PJRT runtime that executes the artifacts, and
//!   the benchmark harness that regenerates every table and figure of the
//!   paper's evaluation.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

// Numeric-kernel code: index-based loops mirror the math and keep the
// autovectorizer happy; silence the style lints that fight that.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments, clippy::type_complexity)]

pub mod bench;
pub mod cli;
pub mod compute;
pub mod config;
pub mod coordinator;
pub mod cur;
pub mod data;
pub mod error;
pub mod faults;
pub mod gmr;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod parallel;
pub mod plan;
pub mod rng;
pub mod runtime;
pub mod sketch;
pub mod sparse;
pub mod spsd;
pub mod svdstream;
pub mod testing;

pub use error::{FgError, Result};

/// Common imports for examples and downstream users.
pub mod prelude {
    pub use crate::error::{FgError, Result};
    pub use crate::linalg::Mat;
    pub use crate::parallel::{set_threads, Pool};
    pub use crate::plan::EpsilonPlan;
    pub use crate::rng::Pcg64;
    pub use crate::sketch::{Sketch, SketchKind};
    pub use crate::sparse::Csr;
}
