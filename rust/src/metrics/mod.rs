//! Lightweight metrics: counters, gauges, wall-clock timers and
//! histograms, shared across coordinator threads.
//!
//! Consumers: the job [`crate::coordinator::Router`] (per-kind
//! submitted/completed counts and latency histograms, including the
//! `cur_stream` kind), the serving layer (the `serve.*` counters,
//! gauges, and end-to-end latency histograms — naming convention in the
//! README §Serving), and the streaming pipelines (batch timings, block
//! and column counts, reservoir occupancy gauges). `report()` renders
//! the snapshot the `pipeline`/`serve` CLI subcommands print, with
//! p50/p95/p99 per histogram.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// A process-wide metrics registry. Cheap to clone handles out of; all
/// counters are atomics and histograms sit behind a mutex (cold path).
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, std::sync::Arc<AtomicU64>>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch-or-create a counter handle.
    pub fn counter(&self, name: &str) -> std::sync::Arc<AtomicU64> {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string()).or_default().clone()
    }

    /// Add to a counter by name (convenience; takes the map lock).
    pub fn add(&self, name: &str, delta: u64) {
        self.counter(name).fetch_add(delta, Ordering::Relaxed);
    }

    /// Set a gauge-style counter to an absolute value (last write wins)
    /// — for point-in-time facts like reservoir occupancy, as opposed to
    /// the monotone [`Metrics::add`] counters.
    pub fn set(&self, name: &str, value: u64) {
        self.counter(name).store(value, Ordering::Relaxed);
    }

    /// Record a duration (seconds) into a histogram.
    pub fn observe(&self, name: &str, seconds: f64) {
        let mut map = self.histograms.lock().unwrap();
        map.entry(name.to_string()).or_default().record(seconds);
    }

    /// Time a closure into a histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.observe(name, start.elapsed().as_secs_f64());
        out
    }

    /// Render a human-readable snapshot.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name}: {}\n", c.load(Ordering::Relaxed)));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "{name}: n={} mean={:.6}s p50={:.6}s p95={:.6}s p99={:.6}s max={:.6}s\n",
                h.count,
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.95),
                h.quantile(0.99),
                h.max
            ));
        }
        out
    }

    /// Read a counter's current value.
    pub fn get(&self, name: &str) -> u64 {
        self.counter(name).load(Ordering::Relaxed)
    }

    /// Read a histogram quantile by name. Both a missing histogram and
    /// an empty one report `0.0` — the "no samples yet" convention (see
    /// [`Histogram::quantile`]) — so idle serve loops can feed p99
    /// gauges from this without ever reading a garbage boundary value.
    pub fn quantile(&self, name: &str, q: f64) -> f64 {
        self.histograms.lock().unwrap().get(name).map_or(0.0, |h| h.quantile(q))
    }

    /// Remove and return a histogram (empty if it was never recorded),
    /// so a bench can read one phase's percentiles — cold vs warm cache,
    /// say — without the next phase's samples mixing in.
    pub fn take_histogram(&self, name: &str) -> Histogram {
        self.histograms.lock().unwrap().remove(name).unwrap_or_default()
    }
}

/// Fixed-size log-bucketed histogram of seconds.
pub struct Histogram {
    /// Buckets: [1ns, ~1000s) in half-decade steps.
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self { buckets: vec![0; 48], count: 0, sum: 0.0, max: 0.0 }
    }
}

impl Histogram {
    fn bucket_index(seconds: f64) -> usize {
        // bucket i covers [1e-9 * sqrt(10)^i, ...): i = 2*log10(s/1e-9)
        if seconds <= 1e-9 {
            return 0;
        }
        let i = (2.0 * (seconds / 1e-9).log10()).floor() as isize;
        i.clamp(0, 47) as usize
    }

    pub fn record(&mut self, seconds: f64) {
        self.buckets[Self::bucket_index(seconds)] += 1;
        self.count += 1;
        self.sum += seconds;
        if seconds > self.max {
            self.max = seconds;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Largest recorded sample (0.0 when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate quantile from the log-bucket boundaries.
    ///
    /// Convention: an **empty histogram returns `0.0` for every `q`** —
    /// never a bucket boundary or stale `max` — so percentile gauges
    /// computed on idle serve loops read as "no samples", not garbage
    /// (pinned by `empty_histogram_quantile_is_zero`). On a non-empty
    /// histogram `q ≤ 0` clamps to the smallest observed bucket and
    /// `q ≥ 1` to the largest.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return 1e-9 * 10f64.powf(i as f64 / 2.0);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add("blocks", 3);
        m.add("blocks", 4);
        assert_eq!(m.get("blocks"), 7);
        assert_eq!(m.get("other"), 0);
    }

    #[test]
    fn gauge_set_overwrites() {
        let m = Metrics::new();
        m.add("g", 5);
        m.set("g", 3);
        assert_eq!(m.get("g"), 3);
        m.set("g", 9);
        assert_eq!(m.get("g"), 9);
    }

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count, 100);
        assert!((h.mean() - 0.0505).abs() < 1e-6);
        let p50 = h.quantile(0.5);
        assert!(p50 > 0.005 && p50 < 0.2, "p50 {p50}");
        assert!((h.max - 0.1).abs() < 1e-9);
    }

    #[test]
    fn timing_records() {
        let m = Metrics::new();
        let v = m.time("op", || 42);
        assert_eq!(v, 42);
        assert!(m.report().contains("op:"));
        assert!(m.report().contains("p95="), "report must surface p95 alongside p50/p99");
    }

    /// The serving loop reads p99 gauges even when nothing has been
    /// recorded yet — empty and missing histograms must report 0.0,
    /// never a bucket boundary or a stale max.
    #[test]
    fn empty_histogram_quantile_is_zero() {
        let h = Histogram::default();
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 0.0, "empty histogram q={q}");
        }
        let m = Metrics::new();
        assert_eq!(m.quantile("never.recorded", 0.99), 0.0);
        // Non-empty: q <= 0 clamps to the smallest observed bucket
        // instead of reporting the 1 ns floor for a 10 ms sample.
        let mut h = Histogram::default();
        h.record(0.01);
        assert_eq!(h.quantile(0.0), h.quantile(1.0));
        assert!(h.quantile(0.0) > 1e-9);
    }

    #[test]
    fn take_histogram_separates_phases() {
        let m = Metrics::new();
        m.observe("lat", 0.5);
        let cold = m.take_histogram("lat");
        assert_eq!(cold.count(), 1);
        m.observe("lat", 0.001);
        let warm = m.take_histogram("lat");
        assert_eq!(warm.count(), 1);
        assert!(warm.quantile(0.5) < cold.quantile(0.5));
        assert_eq!(m.take_histogram("lat").count(), 0);
    }

    #[test]
    fn threads_share_counters() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = vec![];
        for _ in 0..4 {
            let mm = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    mm.add("x", 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.get("x"), 4000);
    }
}
