//! Lightweight metrics: counters, gauges, wall-clock timers and
//! histograms, shared across coordinator threads.
//!
//! Consumers: the job [`crate::coordinator::Router`] (per-kind
//! submitted/completed counts and latency histograms, including the
//! `cur_stream` kind), the serving layer (the `serve.*` counters,
//! gauges, and end-to-end latency histograms — naming convention in the
//! README §Serving), and the streaming pipelines (batch timings, block
//! and column counts, reservoir occupancy gauges). `report()` renders
//! the snapshot the `pipeline`/`serve` CLI subcommands print, with
//! p50/p95/p99 per histogram.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// A process-wide metrics registry. Cheap to clone handles out of; all
/// counters are atomics and histograms sit behind a mutex (cold path).
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, std::sync::Arc<AtomicU64>>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch-or-create a counter handle.
    pub fn counter(&self, name: &str) -> std::sync::Arc<AtomicU64> {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string()).or_default().clone()
    }

    /// Add to a counter by name (convenience; takes the map lock).
    pub fn add(&self, name: &str, delta: u64) {
        self.counter(name).fetch_add(delta, Ordering::Relaxed);
    }

    /// Set a gauge-style counter to an absolute value (last write wins)
    /// — for point-in-time facts like reservoir occupancy, as opposed to
    /// the monotone [`Metrics::add`] counters.
    pub fn set(&self, name: &str, value: u64) {
        self.counter(name).store(value, Ordering::Relaxed);
    }

    /// Record a duration (seconds) into a histogram.
    pub fn observe(&self, name: &str, seconds: f64) {
        let mut map = self.histograms.lock().unwrap();
        map.entry(name.to_string()).or_default().record(seconds);
    }

    /// Time a closure into a histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.observe(name, start.elapsed().as_secs_f64());
        out
    }

    /// Render a human-readable snapshot, grouping monotone counters,
    /// point-in-time gauges (detected by the [`is_gauge`] naming
    /// convention), and histograms under separate headings.
    pub fn report(&self) -> String {
        let counters = self.counters.lock().unwrap();
        let monotone: Vec<_> = counters.iter().filter(|(n, _)| !is_gauge(n)).collect();
        let gauges: Vec<_> = counters.iter().filter(|(n, _)| is_gauge(n)).collect();
        let mut out = String::new();
        if !monotone.is_empty() {
            out.push_str("counters:\n");
            for (name, c) in &monotone {
                out.push_str(&format!("  {name}: {}\n", c.load(Ordering::Relaxed)));
            }
        }
        if !gauges.is_empty() {
            out.push_str("gauges:\n");
            for (name, c) in &gauges {
                out.push_str(&format!("  {name}: {}\n", c.load(Ordering::Relaxed)));
            }
        }
        drop(counters);
        let histograms = self.histograms.lock().unwrap();
        if !histograms.is_empty() {
            out.push_str("histograms:\n");
            for (name, h) in histograms.iter() {
                out.push_str(&format!(
                    "  {name}: n={} mean={:.6}s p50={:.6}s p95={:.6}s p99={:.6}s max={:.6}s\n",
                    h.count,
                    h.mean(),
                    h.quantile(0.5),
                    h.quantile(0.95),
                    h.quantile(0.99),
                    h.max
                ));
            }
        }
        out
    }

    /// Prometheus text exposition of the full registry: counters and
    /// gauges as scalar samples, histograms as cumulative
    /// `_bucket{le="..."}` series with `_sum` and `_count` — the
    /// standard scrape format, written by `--metrics-out`.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            let kind = if is_gauge(name) { "gauge" } else { "counter" };
            let pname = prom_name(name);
            out.push_str(&format!("# TYPE {pname} {kind}\n"));
            out.push_str(&format!("{pname} {}\n", c.load(Ordering::Relaxed)));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            let pname = prom_name(name);
            out.push_str(&format!("# TYPE {pname} histogram\n"));
            let mut cumulative = 0u64;
            for (i, &b) in h.bucket_counts().iter().enumerate() {
                if b == 0 {
                    continue;
                }
                cumulative += b;
                let (_, hi) = Histogram::bucket_bounds(i);
                out.push_str(&format!("{pname}_bucket{{le=\"{hi:.3e}\"}} {cumulative}\n"));
            }
            out.push_str(&format!("{pname}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
            out.push_str(&format!("{pname}_sum {:.9}\n", h.sum()));
            out.push_str(&format!("{pname}_count {}\n", h.count()));
        }
        out
    }

    /// Read a counter's current value.
    pub fn get(&self, name: &str) -> u64 {
        self.counter(name).load(Ordering::Relaxed)
    }

    /// Read a histogram quantile by name. Both a missing histogram and
    /// an empty one report `0.0` — the "no samples yet" convention (see
    /// [`Histogram::quantile`]) — so idle serve loops can feed p99
    /// gauges from this without ever reading a garbage boundary value.
    pub fn quantile(&self, name: &str, q: f64) -> f64 {
        self.histograms.lock().unwrap().get(name).map_or(0.0, |h| h.quantile(q))
    }

    /// Remove and return a histogram (empty if it was never recorded),
    /// so a bench can read one phase's percentiles — cold vs warm cache,
    /// say — without the next phase's samples mixing in.
    pub fn take_histogram(&self, name: &str) -> Histogram {
        self.histograms.lock().unwrap().remove(name).unwrap_or_default()
    }
}

/// Registry naming convention: a counter is a **gauge** (point-in-time,
/// set with [`Metrics::set`]) when its last dot-segment is, or ends in
/// `_` + one of, the gauge suffixes — `depth`, `peak`, `bytes`,
/// `entries`, `candidates` (`serve.queue.depth`,
/// `pipeline.max_queue_depth`, `serve.cache.bytes`, ...). Everything
/// else is a monotone counter. `report()` and `prometheus()` group and
/// type by this predicate.
pub fn is_gauge(name: &str) -> bool {
    const SUFFIXES: [&str; 5] = ["depth", "peak", "bytes", "entries", "candidates"];
    let last = name.rsplit('.').next().unwrap_or(name);
    SUFFIXES.iter().any(|s| {
        last == *s
            || (last.ends_with(s) && last.as_bytes().get(last.len() - s.len() - 1) == Some(&b'_'))
    })
}

/// Sanitize a dotted metric name into the Prometheus charset
/// (`[a-zA-Z0-9_]`).
fn prom_name(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect()
}

/// Fixed-size log-bucketed histogram of seconds.
pub struct Histogram {
    /// Buckets: [1ns, ...) in half-decade steps.
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self { buckets: vec![0; Self::NUM_BUCKETS], count: 0, sum: 0.0, max: 0.0 }
    }
}

impl Histogram {
    /// Number of half-decade buckets, covering 1 ns up through ~10^14 s.
    pub const NUM_BUCKETS: usize = 48;

    fn bucket_index(seconds: f64) -> usize {
        // bucket i covers [1e-9 * sqrt(10)^i, ...): i = 2*log10(s/1e-9)
        if seconds <= 1e-9 {
            return 0;
        }
        let i = (2.0 * (seconds / 1e-9).log10()).floor() as isize;
        i.clamp(0, Self::NUM_BUCKETS as isize - 1) as usize
    }

    /// The `[lower, upper)` bounds of bucket `i` in seconds. Bucket 0
    /// additionally absorbs everything below 1 ns, so its lower bound
    /// is reported as `0.0`; the top bucket absorbs everything above
    /// its lower bound.
    pub fn bucket_bounds(i: usize) -> (f64, f64) {
        assert!(i < Self::NUM_BUCKETS, "bucket {i} out of range");
        let lo = if i == 0 { 0.0 } else { 1e-9 * 10f64.powf(i as f64 / 2.0) };
        let hi = 1e-9 * 10f64.powf((i + 1) as f64 / 2.0);
        (lo, hi)
    }

    /// Per-bucket sample counts (length [`Histogram::NUM_BUCKETS`]).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    /// Sum of all recorded samples, seconds.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn record(&mut self, seconds: f64) {
        self.buckets[Self::bucket_index(seconds)] += 1;
        self.count += 1;
        self.sum += seconds;
        if seconds > self.max {
            self.max = seconds;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Largest recorded sample (0.0 when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate quantile from the log-bucket boundaries.
    ///
    /// Convention: an **empty histogram returns `0.0` for every `q`** —
    /// never a bucket boundary or stale `max` — so percentile gauges
    /// computed on idle serve loops read as "no samples", not garbage
    /// (pinned by `empty_histogram_quantile_is_zero`). On a non-empty
    /// histogram `q ≤ 0` clamps to the smallest observed bucket and
    /// `q ≥ 1` to the largest.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return 1e-9 * 10f64.powf(i as f64 / 2.0);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add("blocks", 3);
        m.add("blocks", 4);
        assert_eq!(m.get("blocks"), 7);
        assert_eq!(m.get("other"), 0);
    }

    #[test]
    fn gauge_set_overwrites() {
        let m = Metrics::new();
        m.add("g", 5);
        m.set("g", 3);
        assert_eq!(m.get("g"), 3);
        m.set("g", 9);
        assert_eq!(m.get("g"), 9);
    }

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count, 100);
        assert!((h.mean() - 0.0505).abs() < 1e-6);
        let p50 = h.quantile(0.5);
        assert!(p50 > 0.005 && p50 < 0.2, "p50 {p50}");
        assert!((h.max - 0.1).abs() < 1e-9);
    }

    #[test]
    fn timing_records() {
        let m = Metrics::new();
        let v = m.time("op", || 42);
        assert_eq!(v, 42);
        assert!(m.report().contains("op:"));
        assert!(m.report().contains("p95="), "report must surface p95 alongside p50/p99");
    }

    /// The serving loop reads p99 gauges even when nothing has been
    /// recorded yet — empty and missing histograms must report 0.0,
    /// never a bucket boundary or a stale max.
    #[test]
    fn empty_histogram_quantile_is_zero() {
        let h = Histogram::default();
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 0.0, "empty histogram q={q}");
        }
        let m = Metrics::new();
        assert_eq!(m.quantile("never.recorded", 0.99), 0.0);
        // Non-empty: q <= 0 clamps to the smallest observed bucket
        // instead of reporting the 1 ns floor for a 10 ms sample.
        let mut h = Histogram::default();
        h.record(0.01);
        assert_eq!(h.quantile(0.0), h.quantile(1.0));
        assert!(h.quantile(0.0) > 1e-9);
    }

    #[test]
    fn take_histogram_separates_phases() {
        let m = Metrics::new();
        m.observe("lat", 0.5);
        let cold = m.take_histogram("lat");
        assert_eq!(cold.count(), 1);
        m.observe("lat", 0.001);
        let warm = m.take_histogram("lat");
        assert_eq!(warm.count(), 1);
        assert!(warm.quantile(0.5) < cold.quantile(0.5));
        assert_eq!(m.take_histogram("lat").count(), 0);
    }

    /// The contention-shaped handle test: the serve loop must bump
    /// cached `Arc<AtomicU64>` handles, never re-take the registry map
    /// lock per increment — the handle and the registry slot are the
    /// same atomic, so everything stays visible through `get`.
    #[test]
    fn counter_handles_bypass_the_registry_lock() {
        let m = std::sync::Arc::new(Metrics::new());
        let h = m.counter("hot");
        assert!(
            std::sync::Arc::ptr_eq(&h, &m.counter("hot")),
            "counter() must hand out the registry's own atomic"
        );
        let mut threads = vec![];
        for _ in 0..4 {
            let h = h.clone();
            threads.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    h.fetch_add(1, Ordering::Relaxed);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(m.get("hot"), 40_000);
    }

    /// Pins the half-decade bucket geometry the Prometheus exposition
    /// publishes: bucket i covers [1e-9·10^(i/2), 1e-9·10^((i+1)/2)),
    /// with bucket 0 absorbing the sub-nanosecond tail.
    #[test]
    fn histogram_bucket_geometry_is_half_decade() {
        // Mid-bucket samples (away from boundaries, where log10
        // rounding is exact): 2.0 s -> bucket 18, 2e-3 s -> bucket 12.
        let mut h = Histogram::default();
        h.record(2.0);
        h.record(2e-3);
        assert_eq!(h.bucket_counts().len(), Histogram::NUM_BUCKETS);
        assert_eq!(h.bucket_counts()[18], 1);
        assert_eq!(h.bucket_counts()[12], 1);
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), 2);
        assert!((h.sum() - 2.002).abs() < 1e-12);
        // Bounds: bucket 0 starts at 0.0; consecutive buckets tile the
        // axis; each spans a factor of sqrt(10).
        assert_eq!(Histogram::bucket_bounds(0).0, 0.0);
        for i in 0..Histogram::NUM_BUCKETS - 1 {
            let (_, hi) = Histogram::bucket_bounds(i);
            let (lo_next, hi_next) = Histogram::bucket_bounds(i + 1);
            assert_eq!(hi, lo_next, "buckets {i}/{} must tile", i + 1);
            assert!((hi_next / lo_next - 10f64.sqrt()).abs() < 1e-9);
        }
        // A sample lands inside its bucket's bounds.
        let (lo, hi) = Histogram::bucket_bounds(18);
        assert!(lo <= 2.0 && 2.0 < hi, "2.0 s outside bucket 18 [{lo}, {hi})");
    }

    #[test]
    fn report_groups_gauges_separately_from_counters() {
        assert!(is_gauge("serve.queue.depth"));
        assert!(is_gauge("serve.queue.peak"));
        assert!(is_gauge("serve.cache.bytes"));
        assert!(is_gauge("serve.cache.entries"));
        assert!(is_gauge("pipeline.max_queue_depth"));
        assert!(is_gauge("pipeline.cur_reservoir_candidates"));
        assert!(!is_gauge("serve.cache.hits"));
        assert!(!is_gauge("router.cur.completed"));
        assert!(!is_gauge("pipeline.blocks"));

        let m = Metrics::new();
        m.add("router.cur.completed", 2);
        m.set("serve.queue.depth", 5);
        m.observe("serve.latency", 0.01);
        let r = m.report();
        let counters_at = r.find("counters:").expect("counters heading");
        let gauges_at = r.find("gauges:").expect("gauges heading");
        let hists_at = r.find("histograms:").expect("histograms heading");
        assert!(counters_at < gauges_at && gauges_at < hists_at);
        // Each name sits in its own section.
        assert!(r[counters_at..gauges_at].contains("router.cur.completed: 2"));
        assert!(r[gauges_at..hists_at].contains("serve.queue.depth: 5"));
        assert!(r[hists_at..].contains("serve.latency: n=1"));
    }

    #[test]
    fn prometheus_exposition_has_typed_series_and_cumulative_buckets() {
        let m = Metrics::new();
        m.add("serve.cache.hits", 3);
        m.set("serve.queue.depth", 2);
        m.observe("serve.latency", 2e-3);
        m.observe("serve.latency", 2e-3);
        m.observe("serve.latency", 2.0);
        let p = m.prometheus();
        assert!(p.contains("# TYPE serve_cache_hits counter\nserve_cache_hits 3\n"));
        assert!(p.contains("# TYPE serve_queue_depth gauge\nserve_queue_depth 2\n"));
        assert!(p.contains("# TYPE serve_latency histogram\n"));
        // Cumulative buckets: 2 samples by the end of bucket 12, 3 by
        // bucket 18, 3 at +Inf; le boundaries are the upper bounds.
        let hi12 = Histogram::bucket_bounds(12).1;
        let hi18 = Histogram::bucket_bounds(18).1;
        let le12 = format!("serve_latency_bucket{{le=\"{hi12:.3e}\"}} 2");
        let le18 = format!("serve_latency_bucket{{le=\"{hi18:.3e}\"}} 3");
        assert!(p.contains(&le12), "missing {le12} in:\n{p}");
        assert!(p.contains(&le18), "missing {le18} in:\n{p}");
        assert!(p.contains("serve_latency_bucket{le=\"+Inf\"} 3\n"));
        assert!(p.contains("serve_latency_count 3\n"));
        assert!(p.contains("serve_latency_sum 2.004"));
    }

    #[test]
    fn threads_share_counters() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = vec![];
        for _ in 0..4 {
            let mm = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    mm.add("x", 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.get("x"), 4000);
    }
}
