//! SPSD (kernel) matrix approximation — Section 4 of the paper.
//!
//! All methods approximate a kernel matrix `K ∈ R^{n×n}` as
//! `K ≈ C X Cᵀ` with `C` a set of sampled columns; they differ only in
//! how the core matrix `X` is computed and, critically, in **how many
//! entries of K must be observed** (Table 4):
//!
//! | method | core matrix | entries observed |
//! |---|---|---|
//! | Nyström ([`nystrom_core`]) | `W†` (intersection) | `nc` |
//! | fast SPSD ([`fast_spsd_core`], Wang et al. 2016b, Eqn. 4.1) | `(SC)†(SKSᵀ)(CᵀSᵀ)†`, one sketch | `nc + s²` with `s = O(c√(n/ε))` |
//! | **faster SPSD** ([`faster_spsd`], Algorithm 2) | two independent leverage samplings + PSD projection | `nc + c²·max{ε⁻¹, ε⁻²ρ⁻⁴}` |
//! | optimal ([`optimal_core`]) | `C† K C†ᵀ` (prototype) | `n²` |
//!
//! Methods access K only through a [`KernelOracle`], so the
//! entries-observed accounting is enforced by construction — the oracle
//! counts every entry it computes, which the Table 4 bench reports.

mod faster;
mod fast_spsd;
mod nystrom;
mod oracle;

pub use fast_spsd::fast_spsd_core;
pub use faster::{
    faster_spsd, faster_spsd_core, faster_spsd_core_planned, faster_spsd_planned,
    FasterSpsdConfig, SpsdApproximation,
};
pub use nystrom::{nystrom_core, optimal_core, reconstruct};
pub use oracle::{CountingOracle, DenseKernelOracle, KernelOracle, RbfOracle};

use crate::linalg::{matmul, matmul_a_bt, Mat};

/// `‖K − C X Cᵀ‖_F / ‖K‖_F` — the error ratio of §6.2, computed blockwise
/// against a dense K.
pub fn error_ratio(k: &Mat, c: &Mat, x: &Mat) -> f64 {
    let cx = matmul(c, x); // n x c
    let mut acc = 0.0f64;
    const B: usize = 512;
    let n = k.rows();
    for i0 in (0..n).step_by(B) {
        let i1 = (i0 + B).min(n);
        let cx_blk = cx.slice(i0, i1, 0, cx.cols());
        let approx = matmul_a_bt(&cx_blk, c); // block of C X Cᵀ
        let k_blk = k.slice(i0, i1, 0, n);
        let d = crate::linalg::fro_norm_diff(&k_blk, &approx);
        acc += d * d;
    }
    acc.sqrt() / k.fro_norm()
}

#[cfg(test)]
mod tests;
