//! **Faster SPSD** — Algorithm 2, the paper's contribution applied to
//! kernel approximation.
//!
//! 1. sample `c` columns of K uniformly → `C` (nc entries observed);
//! 2. compute leverage scores of `C`;
//! 3. draw two *independent* leverage-score samplings `S_1, S_2` of size
//!    `s` and observe only the `s×s` intersection block `S_1 K S_2ᵀ`;
//! 4. `X̂ = (S_1 C)† (S_1 K S_2ᵀ) (Cᵀ S_2ᵀ)†` (Fast GMR, Eqn. 4.2);
//! 5. project onto the PSD cone: `X̃_+ = Π_{H+}(X̂)` (eigendecomposition
//!    of a c×c matrix — Remark 3: only O(c³)).
//!
//! Theorem 3: `(1+ε)` relative error vs. the optimal core with
//! `s = O(max{c/√ε, c/(ερ²)} + c log c)`, observing
//! `N = nc + c²·max{ε⁻¹, ε⁻²ρ⁻⁴}` kernel entries.

use super::KernelOracle;
use crate::gmr::solve_core;
use crate::linalg::{project_psd, Mat};
use crate::rng::Pcg64;
use crate::sketch::row_leverage_scores;

/// Configuration for Algorithm 2.
#[derive(Clone, Debug)]
pub struct FasterSpsdConfig {
    /// Number of kernel columns to sample for C.
    pub c: usize,
    /// Sketch size s for the two leverage samplings.
    pub s: usize,
}

/// Output of Algorithm 2.
pub struct SpsdApproximation {
    /// Sampled column indices.
    pub idx: Vec<usize>,
    /// The sampled columns C (n×c).
    pub c: Mat,
    /// The PSD-projected core X̃_+ (c×c).
    pub x: Mat,
}

/// Algorithm 2, given a column matrix C already sampled (steps 3–7).
pub fn faster_spsd_core<O: KernelOracle + ?Sized>(
    oracle: &O,
    c: &Mat,
    s: usize,
    rng: &mut Pcg64,
) -> Mat {
    let n = oracle.n();
    assert_eq!(c.rows(), n, "C must have n rows");
    let mut sketch_span = crate::obs::span("spsd.sketch", crate::obs::cat::SKETCH);
    sketch_span.meta("s", s);
    // Step 3: leverage scores of C.
    let scores = row_leverage_scores(c);
    let total: f64 = scores.iter().sum();
    let probs: Vec<f64> = scores.iter().map(|&w| (w + 1e-12) / (total + 1e-12 * n as f64)).collect();

    // Step 4: two independent samplings.
    let idx1 = rng.sample_weighted_many(&probs, s);
    let scale1: Vec<f64> = idx1.iter().map(|&i| 1.0 / ((s as f64) * probs[i]).sqrt()).collect();
    let idx2 = rng.sample_weighted_many(&probs, s);
    let scale2: Vec<f64> = idx2.iter().map(|&i| 1.0 / ((s as f64) * probs[i]).sqrt()).collect();

    // S_1 C and Cᵀ S_2ᵀ from the already-observed C.
    let mut s1c = c.select_rows(&idx1);
    for (t, &sv) in scale1.iter().enumerate() {
        for v in s1c.row_mut(t) {
            *v *= sv;
        }
    }
    let mut s2c = c.select_rows(&idx2);
    for (t, &sv) in scale2.iter().enumerate() {
        for v in s2c.row_mut(t) {
            *v *= sv;
        }
    }
    // Only these s×s kernel entries are observed beyond C itself.
    let mut s1ks2 = oracle.block(&idx1, &idx2);
    for i in 0..s {
        for j in 0..s {
            s1ks2[(i, j)] *= scale1[i] * scale2[j];
        }
    }

    drop(sketch_span);

    // Step 5: Fast GMR core; steps 6–7: PSD projection.
    let x_raw = solve_core(&s1c, &s1ks2, &s2c.transpose());
    let _sp = crate::obs::span("spsd.psd_project", crate::obs::cat::FACTORIZE);
    project_psd(&x_raw)
}

/// Full Algorithm 2 (steps 1–7): uniform column sampling included.
pub fn faster_spsd<O: KernelOracle + ?Sized>(
    oracle: &O,
    cfg: &FasterSpsdConfig,
    rng: &mut Pcg64,
) -> SpsdApproximation {
    let n = oracle.n();
    // Step 2: sample c distinct columns uniformly and observe them.
    let (idx, c) = {
        let mut sp = crate::obs::span("spsd.sample_columns", crate::obs::cat::GATHER);
        sp.meta("c", cfg.c);
        let idx = rng.sample_without_replacement(n, cfg.c);
        let c = oracle.columns(&idx);
        (idx, c)
    };
    let x = faster_spsd_core(oracle, &c, cfg.s, rng);
    SpsdApproximation { idx, c, x }
}
