//! **Faster SPSD** — Algorithm 2, the paper's contribution applied to
//! kernel approximation.
//!
//! 1. sample `c` columns of K uniformly → `C` (nc entries observed);
//! 2. compute leverage scores of `C`;
//! 3. draw two *independent* leverage-score samplings `S_1, S_2` of size
//!    `s` and observe only the `s×s` intersection block `S_1 K S_2ᵀ`;
//! 4. `X̂ = (S_1 C)† (S_1 K S_2ᵀ) (Cᵀ S_2ᵀ)†` (Fast GMR, Eqn. 4.2);
//! 5. project onto the PSD cone: `X̃_+ = Π_{H+}(X̂)` (eigendecomposition
//!    of a c×c matrix — Remark 3: only O(c³)).
//!
//! Theorem 3: `(1+ε)` relative error vs. the optimal core with
//! `s = O(max{c/√ε, c/(ερ²)} + c log c)`, observing
//! `N = nc + c²·max{ε⁻¹, ε⁻²ρ⁻⁴}` kernel entries.

use super::KernelOracle;
use crate::gmr::solve_core;
use crate::linalg::{fro_norm_diff, matmul, project_psd, Mat};
use crate::plan::{EpsilonPlan, PlanOutcome};
use crate::rng::{rng, Pcg64};
use crate::sketch::row_leverage_scores;

/// Configuration for Algorithm 2.
#[derive(Clone, Debug)]
pub struct FasterSpsdConfig {
    /// Number of kernel columns to sample for C.
    pub c: usize,
    /// Sketch size s for the two leverage samplings.
    pub s: usize,
}

/// Output of Algorithm 2.
pub struct SpsdApproximation {
    /// Sampled column indices.
    pub idx: Vec<usize>,
    /// The sampled columns C (n×c).
    pub c: Mat,
    /// The PSD-projected core X̃_+ (c×c).
    pub x: Mat,
}

/// Algorithm 2, given a column matrix C already sampled (steps 3–7).
pub fn faster_spsd_core<O: KernelOracle + ?Sized>(
    oracle: &O,
    c: &Mat,
    s: usize,
    rng: &mut Pcg64,
) -> Mat {
    let n = oracle.n();
    assert_eq!(c.rows(), n, "C must have n rows");
    let mut sketch_span = crate::obs::span("spsd.sketch", crate::obs::cat::SKETCH);
    sketch_span.meta("s", s);
    // Step 3: leverage scores of C.
    let scores = row_leverage_scores(c);
    let total: f64 = scores.iter().sum();
    let probs: Vec<f64> = scores.iter().map(|&w| (w + 1e-12) / (total + 1e-12 * n as f64)).collect();

    // Step 4: two independent samplings.
    let idx1 = rng.sample_weighted_many(&probs, s);
    let scale1: Vec<f64> = idx1.iter().map(|&i| 1.0 / ((s as f64) * probs[i]).sqrt()).collect();
    let idx2 = rng.sample_weighted_many(&probs, s);
    let scale2: Vec<f64> = idx2.iter().map(|&i| 1.0 / ((s as f64) * probs[i]).sqrt()).collect();

    // S_1 C and Cᵀ S_2ᵀ from the already-observed C.
    let mut s1c = c.select_rows(&idx1);
    for (t, &sv) in scale1.iter().enumerate() {
        for v in s1c.row_mut(t) {
            *v *= sv;
        }
    }
    let mut s2c = c.select_rows(&idx2);
    for (t, &sv) in scale2.iter().enumerate() {
        for v in s2c.row_mut(t) {
            *v *= sv;
        }
    }
    // Only these s×s kernel entries are observed beyond C itself.
    let mut s1ks2 = oracle.block(&idx1, &idx2);
    for i in 0..s {
        for j in 0..s {
            s1ks2[(i, j)] *= scale1[i] * scale2[j];
        }
    }

    drop(sketch_span);

    // Step 5: Fast GMR core; steps 6–7: PSD projection.
    let x_raw = solve_core(&s1c, &s1ks2, &s2c.transpose());
    let _sp = crate::obs::span("spsd.psd_project", crate::obs::cat::FACTORIZE);
    project_psd(&x_raw)
}

/// ε-planned Algorithm 2 core: escalates the sketch size `s` until a
/// fixed validation block certifies `(1+ε)` relative error against the
/// optimal core *on that block*.
///
/// Reuse across escalations happens at the **kernel-observation**
/// level, the expensive resource in the oracle model: the index lists
/// grow prefix-stably (each attempt replays `sample_weighted_many`
/// from the same seed — its draws are sequential, so a longer sample
/// extends the shorter one bitwise), and only the two new strips of
/// `S₁ K S₂ᵀ` are queried from the oracle; previously observed entries
/// are kept (rescaling by the new `1/√(s·pᵢ)` factors is free — scale
/// is separable from observation).
///
/// The validation block `K[V, V]` (|V| = `plan.check_size`, saturating
/// at `n`, drawn once uniformly) is the a-posteriori check — its
/// entries are additional observations, the price of certification. At
/// |V| = n the check is exact.
pub fn faster_spsd_core_planned<O: KernelOracle + ?Sized>(
    oracle: &O,
    c: &Mat,
    plan: &EpsilonPlan,
) -> (Mat, PlanOutcome) {
    let n = oracle.n();
    assert_eq!(c.rows(), n, "C must have n rows");
    let w = c.cols().max(1);

    // Fixed validation set + its optimum (drawn once, shared by every
    // attempt so escalation decisions are monotone).
    let v = plan.check_size(w).min(n);
    let vidx = rng(plan.seed ^ 0x59d0_000f).sample_without_replacement(n, v);
    let kv = oracle.block(&vidx, &vidx);
    let cv = c.select_rows(&vidx);
    let cvt = cv.transpose();
    let x_opt = solve_core(&cv, &kv, &cvt);
    let opt = fro_norm_diff(&kv, &matmul(&matmul(&cv, &x_opt), &cvt));
    let floor = 1e-9 * (1.0 + kv.fro_norm());

    let scores = row_leverage_scores(c);
    let total: f64 = scores.iter().sum();
    let probs: Vec<f64> = scores.iter().map(|&s| (s + 1e-12) / (total + 1e-12 * n as f64)).collect();

    let sched = plan.schedule(w, n);
    // Separate seeded streams per side keep each index list
    // prefix-stable under growth (the shared-rng draw order of the
    // unplanned path would interleave them).
    let seed1 = plan.seed ^ 0x59d0_0001;
    let seed2 = plan.seed ^ 0x59d0_0002;

    let mut idx1: Vec<usize> = Vec::new();
    let mut idx2: Vec<usize> = Vec::new();
    let mut kb = Mat::zeros(0, 0); // unscaled S₁KS₂ᵀ entries observed so far

    let mut result: Option<(Mat, PlanOutcome)> = None;
    for (attempt, &s) in sched.iter().enumerate() {
        let mut sp = crate::obs::span("plan.attempt", crate::obs::cat::DISPATCH);
        sp.meta("attempt", attempt + 1);
        sp.meta("s_c", s);
        sp.meta("s_r", s);

        let p = idx1.len();
        idx1 = rng(seed1).sample_weighted_many(&probs, s);
        idx2 = rng(seed2).sample_weighted_many(&probs, s);
        // Observe only the marginal strips of the intersection block.
        if p == 0 {
            kb = oracle.block(&idx1, &idx2);
        } else {
            let rows = oracle.block(&idx1[p..], &idx2[..p]);
            let cols = oracle.block(&idx1, &idx2[p..]);
            let mut grown = Mat::zeros(s, s);
            grown.set_block(0, 0, &kb);
            grown.set_block(p, 0, &rows);
            grown.set_block(0, p, &cols);
            kb = grown;
        }

        // Scale factors depend on the current s — reapplied per
        // attempt, never re-observed.
        let scale1: Vec<f64> =
            idx1.iter().map(|&i| 1.0 / ((s as f64) * probs[i]).sqrt()).collect();
        let scale2: Vec<f64> =
            idx2.iter().map(|&i| 1.0 / ((s as f64) * probs[i]).sqrt()).collect();
        let mut s1c = c.select_rows(&idx1);
        for (t, &sv) in scale1.iter().enumerate() {
            for val in s1c.row_mut(t) {
                *val *= sv;
            }
        }
        let mut s2c = c.select_rows(&idx2);
        for (t, &sv) in scale2.iter().enumerate() {
            for val in s2c.row_mut(t) {
                *val *= sv;
            }
        }
        let mut s1ks2 = kb.clone();
        for i in 0..s {
            for j in 0..s {
                s1ks2[(i, j)] *= scale1[i] * scale2[j];
            }
        }

        let x_raw = solve_core(&s1c, &s1ks2, &s2c.transpose());
        let x = {
            let _psp = crate::obs::span("spsd.psd_project", crate::obs::cat::FACTORIZE);
            project_psd(&x_raw)
        };
        let achieved = fro_norm_diff(&kv, &matmul(&matmul(&cv, &x), &cvt));
        let attained = achieved <= (1.0 + plan.epsilon) * opt + floor;
        sp.meta("achieved", achieved);
        sp.meta("attained", if attained { "yes" } else { "no" });
        drop(sp);

        if attained || attempt + 1 == sched.len() {
            let outcome = PlanOutcome {
                epsilon: plan.epsilon,
                attempts: attempt + 1,
                s_c: s,
                s_r: s,
                achieved,
                optimum: opt,
                attained,
            };
            result = Some((x, outcome));
            break;
        }
    }
    result.expect("planner runs at least one attempt")
}

/// ε-planned full Algorithm 2: uniform column sampling (identical rng
/// consumption to [`faster_spsd`]), then the planned core. `cfg.s` is
/// ignored — the plan sizes the sketch.
pub fn faster_spsd_planned<O: KernelOracle + ?Sized>(
    oracle: &O,
    cfg: &FasterSpsdConfig,
    plan: &EpsilonPlan,
    rng: &mut Pcg64,
) -> (SpsdApproximation, PlanOutcome) {
    let n = oracle.n();
    let (idx, c) = {
        let mut sp = crate::obs::span("spsd.sample_columns", crate::obs::cat::GATHER);
        sp.meta("c", cfg.c);
        let idx = rng.sample_without_replacement(n, cfg.c);
        let c = oracle.columns(&idx);
        (idx, c)
    };
    let (x, outcome) = faster_spsd_core_planned(oracle, &c, plan);
    (SpsdApproximation { idx, c, x }, outcome)
}

/// Full Algorithm 2 (steps 1–7): uniform column sampling included.
pub fn faster_spsd<O: KernelOracle + ?Sized>(
    oracle: &O,
    cfg: &FasterSpsdConfig,
    rng: &mut Pcg64,
) -> SpsdApproximation {
    let n = oracle.n();
    // Step 2: sample c distinct columns uniformly and observe them.
    let (idx, c) = {
        let mut sp = crate::obs::span("spsd.sample_columns", crate::obs::cat::GATHER);
        sp.meta("c", cfg.c);
        let idx = rng.sample_without_replacement(n, cfg.c);
        let c = oracle.columns(&idx);
        (idx, c)
    };
    let x = faster_spsd_core(oracle, &c, cfg.s, rng);
    SpsdApproximation { idx, c, x }
}
