//! Nyström (Williams–Seeger 2001) and the optimal/prototype core
//! (Wang et al. 2016a) — the two classical baselines of §6.2.

use super::KernelOracle;
use crate::linalg::{matmul, pinv, pinv_apply_left, Mat};

/// Conventional Nyström core: `X = W†` where `W = K[idx, idx]` is the
/// intersection matrix of the sampled columns. Observes only the `nc`
/// entries of `C` (W is a sub-block of C).
pub fn nystrom_core(c: &Mat, idx: &[usize]) -> Mat {
    // W = C[idx, :] (rows of C at the sampled positions).
    let w = c.select_rows(idx);
    pinv(&w)
}

/// Optimal (modified-Nyström / prototype) core:
/// `X = C† K (C†)ᵀ = argmin_X ‖K − C X Cᵀ‖_F`. Observes all n² entries.
pub fn optimal_core<O: KernelOracle + ?Sized>(oracle: &O, c: &Mat) -> Mat {
    let n = oracle.n();
    let all: Vec<usize> = (0..n).collect();
    let k = oracle.block(&all, &all);
    // C†K then (C†K)C†ᵀ = pinv_apply on both sides.
    let ck = pinv_apply_left(c, &k); // c x n
    pinv_apply_left(c, &ck.transpose()).transpose()
}

/// `C X Cᵀ` reconstruction helper (examples).
pub fn reconstruct(c: &Mat, x: &Mat) -> Mat {
    matmul(&matmul(c, x), &c.transpose())
}
