//! Fast SPSD approximation of Wang et al. (2016b) — Eqn. (4.1):
//! `X̂ = (S C)† (S K Sᵀ) (Cᵀ Sᵀ)†` with a **single** sketching matrix S
//! (leverage-score sampling w.r.t. C), which keeps X̂ symmetric but,
//! per Section 4.2 of our paper, needs `s = O(c√(n/ε))` — i.e.
//! `O(nc²/ε)` observed entries — to reach (1+ε). This is the baseline
//! Table 7 evaluates.

use super::KernelOracle;
use crate::gmr::solve_core;
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::sketch::row_leverage_scores;

/// Compute the fast-SPSD core with sketch size `s`; returns the c×c core.
///
/// The sampling sketch is realized explicitly as (indices, scales) so the
/// oracle is only asked for the `s×s` intersection block.
pub fn fast_spsd_core<O: KernelOracle + ?Sized>(
    oracle: &O,
    c: &Mat,
    s: usize,
    rng: &mut Pcg64,
) -> Mat {
    let n = oracle.n();
    assert_eq!(c.rows(), n);
    let scores = row_leverage_scores(c);
    let total: f64 = scores.iter().sum();
    let probs: Vec<f64> = scores.iter().map(|&w| (w + 1e-12) / (total + 1e-12 * n as f64)).collect();
    let idx = rng.sample_weighted_many(&probs, s);
    let scale: Vec<f64> = idx.iter().map(|&i| 1.0 / ((s as f64) * probs[i]).sqrt()).collect();

    // S C: sampled+scaled rows of C.
    let mut sc = c.select_rows(&idx);
    for (t, &sc_v) in scale.iter().enumerate() {
        for v in sc.row_mut(t) {
            *v *= sc_v;
        }
    }
    // S K Sᵀ: the sampled intersection block, scaled on both sides.
    let mut sks = oracle.block(&idx, &idx);
    for i in 0..s {
        for j in 0..s {
            sks[(i, j)] *= scale[i] * scale[j];
        }
    }
    // X̂ = (SC)† (SKSᵀ) (Cᵀ Sᵀ)† — with one S this is symmetric in
    // exact arithmetic; reuse the shared sketched-solve core.
    let ct_st = sc.transpose(); // (S C)ᵀ = Cᵀ Sᵀ
    solve_core(&sc, &sks, &ct_st)
}
