//! Tests for the SPSD approximation methods.

use super::*;
use crate::linalg::{eigh, matmul_a_bt, Mat};
use crate::rng::rng;

/// Build a small RBF kernel problem with a fast-decaying spectrum.
fn kernel_problem(n: usize, d: usize, sigma: f64, seed: u64) -> (Mat, Mat) {
    let mut r = rng(seed);
    // Clustered points → near-low-rank kernel (like the paper's η ≥ 0.6).
    let centers = Mat::randn(5, d, &mut r);
    let mut x = Mat::zeros(n, d);
    for i in 0..n {
        let c = i % 5;
        for j in 0..d {
            x[(i, j)] = centers[(c, j)] + 0.3 * r.next_normal();
        }
    }
    let oracle = RbfOracle::new(&x, sigma);
    let all: Vec<usize> = (0..n).collect();
    let k = oracle.block(&all, &all);
    (x, k)
}

#[test]
fn rbf_oracle_matches_direct() {
    let mut r = rng(1);
    let x = Mat::randn(20, 4, &mut r);
    let oracle = RbfOracle::new(&x, 0.7);
    let rows = [0usize, 5, 19];
    let cols = [2usize, 5, 7, 11];
    let blk = oracle.block(&rows, &cols);
    for (oi, &i) in rows.iter().enumerate() {
        for (oj, &j) in cols.iter().enumerate() {
            let mut d2 = 0.0;
            for t in 0..4 {
                let d = x[(i, t)] - x[(j, t)];
                d2 += d * d;
            }
            let want = (-0.7 * d2).exp();
            assert!((blk[(oi, oj)] - want).abs() < 1e-12);
        }
    }
    // Diagonal entries are 1.
    let diag = oracle.block(&[3], &[3]);
    assert!((diag[(0, 0)] - 1.0).abs() < 1e-12);
}

#[test]
fn counting_oracle_counts() {
    let mut r = rng(2);
    let x = Mat::randn(30, 3, &mut r);
    let inner = RbfOracle::new(&x, 0.5);
    let counting = CountingOracle::new(&inner);
    let _ = counting.block(&[0, 1, 2], &[4, 5]);
    assert_eq!(counting.observed(), 6);
    let _ = counting.columns(&[7]);
    assert_eq!(counting.observed(), 6 + 30);
}

#[test]
fn optimal_core_beats_nystrom() {
    let (_x, k) = kernel_problem(120, 6, 0.4, 3);
    let oracle = DenseKernelOracle { k: &k };
    let mut r = rng(4);
    let idx = r.sample_without_replacement(120, 15);
    let c = oracle.columns(&idx);

    let x_nys = nystrom_core(&c, &idx);
    let x_opt = optimal_core(&oracle, &c);
    let e_nys = error_ratio(&k, &c, &x_nys);
    let e_opt = error_ratio(&k, &c, &x_opt);
    assert!(e_opt <= e_nys + 1e-12, "optimal {e_opt} vs nystrom {e_nys}");
    assert!(e_opt < 0.5, "optimal error too large: {e_opt}");
}

#[test]
fn faster_spsd_approaches_optimal_as_s_grows() {
    let (_x, k) = kernel_problem(200, 6, 0.4, 5);
    let oracle = DenseKernelOracle { k: &k };
    let mut r = rng(6);
    let c_dim = 20;
    let idx = r.sample_without_replacement(200, c_dim);
    let c = oracle.columns(&idx);
    let x_opt = optimal_core(&oracle, &c);
    let e_opt = error_ratio(&k, &c, &x_opt);

    let mut prev = f64::INFINITY;
    for &s in &[40usize, 100, 190] {
        let mut acc = 0.0;
        let trials = 3;
        for t in 0..trials {
            let mut rr = rng(100 + s as u64 + t);
            let x = faster_spsd_core(&oracle, &c, s, &mut rr);
            acc += error_ratio(&k, &c, &x);
        }
        let e = acc / trials as f64;
        assert!(e < prev * 1.3 + 1e-12, "error not shrinking: {e} after {prev}");
        prev = e;
    }
    // At s close to n the faster-SPSD error approaches the optimal.
    assert!(prev <= e_opt * 1.5 + 0.05, "final {prev} vs optimal {e_opt}");
}

#[test]
fn faster_spsd_core_is_psd() {
    let (_x, k) = kernel_problem(80, 5, 0.5, 7);
    let oracle = DenseKernelOracle { k: &k };
    let mut r = rng(8);
    let sol = faster_spsd(&oracle, &FasterSpsdConfig { c: 10, s: 40 }, &mut r);
    assert_eq!(sol.c.shape(), (80, 10));
    assert_eq!(sol.x.shape(), (10, 10));
    let e = eigh(&sol.x);
    assert!(e.values.iter().all(|&w| w >= -1e-9), "core not PSD: {:?}", e.values);
}

#[test]
fn entries_observed_matches_theorem3() {
    let (_x, k) = kernel_problem(150, 5, 0.5, 9);
    let oracle = DenseKernelOracle { k: &k };
    let counting = CountingOracle::new(&oracle);
    let mut r = rng(10);
    let (c_dim, s) = (12, 50);
    let _ = faster_spsd(&counting, &FasterSpsdConfig { c: c_dim, s }, &mut r);
    // N = n*c + s*s exactly: C columns + the sampled intersection block.
    assert_eq!(counting.observed(), (150 * c_dim + s * s) as u64);
}

#[test]
fn fast_spsd_single_sketch_baseline_runs() {
    let (_x, k) = kernel_problem(100, 5, 0.5, 11);
    let oracle = DenseKernelOracle { k: &k };
    let mut r = rng(12);
    let idx = r.sample_without_replacement(100, 10);
    let c = oracle.columns(&idx);
    let x = fast_spsd_core(&oracle, &c, 60, &mut r);
    assert_eq!(x.shape(), (10, 10));
    let e = error_ratio(&k, &c, &x);
    assert!(e.is_finite() && e < 2.0, "fast-SPSD error {e}");
}

/// §6.2's headline comparison, in miniature: with s = 10c the faster-SPSD
/// error should be close to optimal and beat Nyström.
#[test]
fn headline_comparison_shape() {
    let (_x, k) = kernel_problem(300, 6, 0.4, 13);
    let oracle = DenseKernelOracle { k: &k };
    let mut r = rng(14);
    let c_dim = 20;
    let idx = r.sample_without_replacement(300, c_dim);
    let c = oracle.columns(&idx);

    let e_opt = error_ratio(&k, &c, &optimal_core(&oracle, &c));
    let e_nys = error_ratio(&k, &c, &nystrom_core(&c, &idx));
    let mut acc = 0.0;
    let trials = 3;
    for t in 0..trials {
        let mut rr = rng(200 + t);
        acc += error_ratio(&k, &c, &faster_spsd_core(&oracle, &c, 10 * c_dim, &mut rr));
    }
    let e_faster = acc / trials as f64;
    assert!(
        e_faster < e_nys,
        "faster-SPSD ({e_faster}) should beat Nyström ({e_nys}); optimal {e_opt}"
    );
    assert!(e_faster < e_opt * 1.25 + 0.02, "faster-SPSD {e_faster} far from optimal {e_opt}");
}

/// ISSUE 9 acceptance: the ε-planned faster-SPSD core reaches `(1+ε)`
/// of the *unconstrained* optimal core's residual for its own sampled
/// columns in ≥90% of fixed-seed trials. At n = 110 the plan's
/// validation set saturates to the whole kernel, so the planner's
/// certificate is exact and must agree with the independent
/// recomputation below.
#[test]
fn planner_acceptance_spsd() {
    let eps = 0.5;
    crate::testing::assert_attains_epsilon("spsd planned", eps, 10, 9, |seed| {
        let (_x, k) = kernel_problem(110, 5, 0.4, seed);
        let oracle = DenseKernelOracle { k: &k };
        let plan = crate::plan::EpsilonPlan::new(eps).with_seed(seed);
        let mut r = rng(seed ^ 0x2);
        let (sol, out) =
            faster_spsd_planned(&oracle, &FasterSpsdConfig { c: 10, s: 0 }, &plan, &mut r);
        let achieved = crate::linalg::fro_norm_diff(&k, &reconstruct(&sol.c, &sol.x));
        let optimum =
            crate::linalg::fro_norm_diff(&k, &reconstruct(&sol.c, &optimal_core(&oracle, &sol.c)));
        (achieved, optimum, out.attained)
    });
}

#[test]
fn reconstruct_shape() {
    let mut r = rng(15);
    let c = Mat::randn(30, 4, &mut r);
    let b = Mat::randn(4, 4, &mut r);
    let x = matmul_a_bt(&b, &b);
    let k_hat = reconstruct(&c, &x);
    assert_eq!(k_hat.shape(), (30, 30));
    // C X Cᵀ is symmetric PSD.
    let e = eigh(&k_hat);
    assert!(e.values.iter().all(|&w| w >= -1e-8));
}
