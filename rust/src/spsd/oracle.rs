//! Kernel-entry oracles.
//!
//! Algorithm 2's efficiency claim is about the *number of kernel entries
//! observed* (Theorem 3: `N = nc + c²·max{ε⁻¹, ε⁻²ρ⁻⁴}`). To make that
//! claim measurable, every SPSD method reads K exclusively through a
//! [`KernelOracle`]; [`CountingOracle`] wraps any oracle and counts the
//! entries actually computed.

use crate::linalg::Mat;
use std::cell::Cell;

/// Source of kernel-matrix entries.
pub trait KernelOracle {
    /// Kernel size n (K is n×n).
    fn n(&self) -> usize;

    /// Compute the block `K[rows, cols]`.
    fn block(&self, rows: &[usize], cols: &[usize]) -> Mat;

    /// Compute full columns `K[:, cols]` (the `C` matrix).
    fn columns(&self, cols: &[usize]) -> Mat {
        let all: Vec<usize> = (0..self.n()).collect();
        self.block(&all, cols)
    }
}

/// Oracle over a materialized dense kernel (tests and small benches).
pub struct DenseKernelOracle<'a> {
    pub k: &'a Mat,
}

impl<'a> KernelOracle for DenseKernelOracle<'a> {
    fn n(&self) -> usize {
        self.k.rows()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Mat {
        let mut out = Mat::zeros(rows.len(), cols.len());
        for (oi, &i) in rows.iter().enumerate() {
            let src = self.k.row(i);
            let dst = out.row_mut(oi);
            for (oj, &j) in cols.iter().enumerate() {
                dst[oj] = src[j];
            }
        }
        out
    }
}

/// RBF kernel oracle computing entries on demand from the data matrix
/// (n points × d features): `K_ij = exp(−σ ‖x_i − x_j‖²)`, the kernel of
/// §6.2. Entries are *computed*, not looked up — this is the realistic
/// regime where observing fewer entries saves real work.
pub struct RbfOracle<'a> {
    /// Data points as rows (n×d).
    pub x: &'a Mat,
    /// Scaling parameter σ.
    pub sigma: f64,
    /// Precomputed squared row norms.
    norms: Vec<f64>,
}

impl<'a> RbfOracle<'a> {
    pub fn new(x: &'a Mat, sigma: f64) -> Self {
        let norms = x.row_norms_sq();
        Self { x, sigma, norms }
    }
}

impl<'a> KernelOracle for RbfOracle<'a> {
    fn n(&self) -> usize {
        self.x.rows()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Mat {
        // K[I,J] = exp(-σ (‖xi‖² + ‖xj‖² − 2 xi·xj)) — gather the two row
        // sets and do a small matmul for the cross terms (exactly the
        // structure the L1 `rbf_block` Pallas kernel implements on-device).
        let xi = self.x.select_rows(rows);
        let xj = self.x.select_rows(cols);
        let cross = crate::linalg::matmul_a_bt(&xi, &xj);
        let mut out = Mat::zeros(rows.len(), cols.len());
        for (oi, &i) in rows.iter().enumerate() {
            let crow = cross.row(oi);
            let orow = out.row_mut(oi);
            for (oj, &j) in cols.iter().enumerate() {
                let d2 = (self.norms[i] + self.norms[j] - 2.0 * crow[oj]).max(0.0);
                orow[oj] = (-self.sigma * d2).exp();
            }
        }
        out
    }
}

/// Wrapper that counts the number of kernel entries computed.
pub struct CountingOracle<'a, O: KernelOracle + ?Sized> {
    pub inner: &'a O,
    count: Cell<u64>,
}

impl<'a, O: KernelOracle + ?Sized> CountingOracle<'a, O> {
    pub fn new(inner: &'a O) -> Self {
        Self { inner, count: Cell::new(0) }
    }

    /// Entries observed so far.
    pub fn observed(&self) -> u64 {
        self.count.get()
    }
}

impl<'a, O: KernelOracle + ?Sized> KernelOracle for CountingOracle<'a, O> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Mat {
        self.count.set(self.count.get() + (rows.len() * cols.len()) as u64);
        self.inner.block(rows, cols)
    }
}
