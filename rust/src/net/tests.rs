//! Wire front-end tests: grammar round-trips, loopback bitwise
//! equality, rejection paths, shedding, graceful drain, and seeded
//! net-chaos with provably zero hard failures.

use super::client::Client;
use super::server::{NetConfig, Server};
use super::wire::{self, LineReader, WireLimits};
use crate::coordinator::{job_key, ApproxJob, JobResult, MatrixPayload, Router, ServeConfig};
use crate::cur::{CoreMethod, CurConfig, SelectionStrategy, StreamingCurConfig};
use crate::error::FgError;
use crate::faults::{site, FaultPlan, RetryPolicy};
use crate::gmr::FastGmrConfig;
use crate::linalg::Mat;
use crate::rng::rng;
use crate::sketch::SketchKind;
use crate::sparse::Csr;
use crate::svdstream::FastSpSvdConfig;
use std::io::Write as _;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn test_matrix(m: usize, n: usize, seed: u64) -> Mat {
    let mut r = rng(seed);
    let spectrum = crate::data::SpectrumKind::Exponential { base: 0.8 };
    crate::data::synth_dense(m, n, 8, spectrum, 0.05, &mut r)
}

fn quick_cur_job(seed: u64) -> ApproxJob {
    ApproxJob::Cur {
        a: MatrixPayload::Dense(test_matrix(40, 30, seed)),
        cfg: CurConfig::fast(5, 5, 2),
        seed,
    }
}

/// A job spanning every grammar feature, per kind.
fn grammar_jobs() -> Vec<ApproxJob> {
    let dense = test_matrix(30, 24, 3);
    let sparse = Csr::from_dense(&test_matrix(26, 22, 4), 0.4);
    let c = test_matrix(30, 6, 5);
    let r = test_matrix(6, 24, 6);
    vec![
        ApproxJob::Gmr {
            a: MatrixPayload::Sparse(sparse.clone()),
            c: test_matrix(26, 5, 7),
            r: test_matrix(5, 22, 8),
            cfg: FastGmrConfig::count(12, 12),
            seed: 11,
        },
        ApproxJob::GmrExact { a: MatrixPayload::Dense(dense.clone()), c, r },
        ApproxJob::SpsdKernel { x: test_matrix(28, 4, 9), sigma: 0.75, c: 6, s: 18, seed: 12 },
        ApproxJob::StreamSvd {
            a: MatrixPayload::Dense(dense.clone()),
            cfg: FastSpSvdConfig::paper(3, 2, SketchKind::Osnap),
            block: 8,
            seed: 13,
        },
        ApproxJob::Cur {
            a: MatrixPayload::Dense(dense.clone()),
            cfg: CurConfig {
                c: 5,
                r: 5,
                selection: SelectionStrategy::SketchedLeverage {
                    kind: SketchKind::Count,
                    size: 14,
                },
                core: CoreMethod::StabilizedQr,
                sketch: SketchKind::Gaussian,
                s_c: 10,
                s_r: 10,
            },
            seed: 14,
        },
        ApproxJob::Cur {
            a: MatrixPayload::Sparse(sparse),
            cfg: CurConfig {
                c: 4,
                r: 4,
                selection: SelectionStrategy::SubspaceLeverage { k: 3 },
                core: CoreMethod::Exact,
                sketch: SketchKind::Count,
                s_c: 0,
                s_r: 0,
            },
            seed: 15,
        },
        ApproxJob::Cur {
            a: MatrixPayload::Dense(dense.clone()),
            cfg: CurConfig {
                c: 4,
                r: 4,
                selection: SelectionStrategy::Uniform,
                core: CoreMethod::FastGmr,
                sketch: SketchKind::Srht,
                s_c: 9,
                s_r: 9,
            },
            seed: 16,
        },
        ApproxJob::StreamingCur {
            a: MatrixPayload::Dense(dense),
            cfg: StreamingCurConfig {
                c: 4,
                r: 4,
                k: 3,
                kind: SketchKind::Srht,
                s_c: 16,
                s_r: 8,
                oversample: 3,
            },
            block: 8,
            seed: 17,
        },
    ]
}

fn decode_frame(frame: &str) -> ApproxJob {
    let limits = WireLimits::default();
    let mut reader = LineReader::new(frame.as_bytes(), RetryPolicy::none());
    let header = reader.read_line(limits.max_line_bytes).unwrap().unwrap();
    wire::decode_job(&header, &mut reader, &limits).unwrap()
}

/// Grammar round-trip: every job kind — including sparse payloads and
/// every selection/core/sketch token family — must decode to a job the
/// cache fingerprints identically (the key digests payload bits and
/// every config knob, so key equality is bitwise job equality).
#[test]
fn wire_grammar_round_trips_every_job_kind() {
    for job in grammar_jobs() {
        let decoded = decode_frame(&wire::encode_job(&job));
        assert_eq!(job.kind(), decoded.kind());
        assert_eq!(job.dims(), decoded.dims());
        assert_eq!(job_key(&job), job_key(&decoded), "key drift for kind {}", job.kind());
    }
}

/// Result frames round-trip bitwise, including the SPSD trailing word
/// and the degraded marker.
#[test]
fn wire_result_frames_round_trip_bitwise() {
    let results = vec![
        JobResult::Spsd {
            idx: vec![3, 1, 4],
            c: test_matrix(6, 3, 21),
            x: test_matrix(3, 3, 22),
            entries_observed: 1234,
        },
        JobResult::Degraded {
            est_rel_residual: 0.125,
            inner: Box::new(JobResult::Gmr { x: test_matrix(4, 5, 23) }),
        },
    ];
    for r in results {
        let frame = wire::encode_result(&r, 0xabcd);
        let mut reader = LineReader::new(frame.as_bytes(), RetryPolicy::none());
        let (back, trace) = wire::decode_response(&mut reader, &WireLimits::default()).unwrap();
        assert_eq!(trace, 0xabcd);
        assert_eq!(back.kind(), r.kind());
        assert_eq!(back.is_degraded(), r.is_degraded());
        assert_eq!(back.output_shapes(), r.output_shapes());
        assert_eq!(back.to_words(), r.to_words());
    }
}

/// A corrupted payload word must fail the checksum, not decode quietly.
#[test]
fn wire_checksum_rejects_flipped_bits() {
    let frame = wire::encode_result(&JobResult::Gmr { x: test_matrix(3, 3, 24) }, 1);
    let mut tampered: Vec<String> = frame.lines().map(str::to_string).collect();
    let last = tampered.last_mut().unwrap();
    // Flip one hex digit of the first payload word.
    let flipped = if last.as_bytes()[0] == b'0' { "1" } else { "0" };
    last.replace_range(0..1, flipped);
    let text = tampered.join("\n") + "\n";
    let mut reader = LineReader::new(text.as_bytes(), RetryPolicy::none());
    let err = wire::decode_response(&mut reader, &WireLimits::default()).unwrap_err();
    assert!(matches!(err, FgError::Protocol(m) if m.contains("checksum")));
}

fn tight_cfg() -> NetConfig {
    NetConfig {
        read_timeout: Some(Duration::from_secs(2)),
        write_timeout: Some(Duration::from_secs(2)),
        ..NetConfig::default()
    }
}

/// Loopback round-trip of a mixed job stream: every result that comes
/// back over the socket must be bitwise identical to the same job
/// executed by an identically-configured in-process router.
#[test]
fn loopback_round_trip_is_bitwise_identical_to_in_process() {
    let wire_router = Arc::new(Router::with_config(&ServeConfig::service(2)));
    let inproc = Router::with_config(&ServeConfig::service(2));
    let server = Server::bind("127.0.0.1:0", Arc::clone(&wire_router), tight_cfg()).unwrap();
    let mut client = Client::connect(server.addr(), &tight_cfg()).unwrap();

    let jobs: Vec<(ApproxJob, ApproxJob)> =
        grammar_jobs().into_iter().zip(grammar_jobs()).collect();
    for (over_wire, in_process) in jobs {
        let kind = over_wire.kind();
        let (wire_res, trace) = client.submit(&over_wire).unwrap();
        assert!(trace > 0);
        let local = inproc.submit(in_process).unwrap().wait().unwrap();
        assert_eq!(wire_res.kind(), local.kind(), "kind mismatch for {kind}");
        assert_eq!(
            wire_res.output_shapes(),
            local.output_shapes(),
            "shape mismatch for {kind}"
        );
        assert_eq!(wire_res.to_words(), local.to_words(), "bitwise mismatch for {kind}");
    }
    client.quit().unwrap();
    server.drain();
}

/// An over-cap payload is rejected with a typed protocol error before
/// the server buffers it, and the listener keeps serving new clients.
#[test]
fn oversized_request_rejected_and_server_stays_healthy() {
    let router = Arc::new(Router::new(1));
    let mut cfg = tight_cfg();
    cfg.limits.max_payload_words = 64;
    let server = Server::bind("127.0.0.1:0", router, cfg.clone()).unwrap();

    let mut client = Client::connect(server.addr(), &cfg).unwrap();
    let err = client.submit(&quick_cur_job(1)).unwrap_err();
    assert!(matches!(&err, FgError::Protocol(m) if m.contains("cap")), "got {err}");

    // The offending connection is closed; a fresh one works.
    let mut fresh = Client::connect(server.addr(), &cfg).unwrap();
    fresh.ping().unwrap();
    assert!(fresh.ready().unwrap());
    server.drain();
}

/// Disconnecting mid-frame must register as a protocol error server
/// side (typed, counted) without disturbing later connections.
#[test]
fn mid_frame_disconnect_is_rejected_and_survivable() {
    let router = Arc::new(Router::new(1));
    let cfg = tight_cfg();
    let server = Server::bind("127.0.0.1:0", Arc::clone(&router), cfg.clone()).unwrap();

    {
        let mut raw = std::net::TcpStream::connect(server.addr()).unwrap();
        raw.write_all(b"HELLO v1\n").unwrap();
        let mut reader = LineReader::new(raw.try_clone().unwrap(), RetryPolicy::none());
        assert_eq!(reader.read_line(256).unwrap().unwrap(), wire::GREETING);
        // A JOB header, a MAT header, a words header — then vanish
        // mid-payload.
        raw.write_all(b"JOB gmr_exact\nMAT dense 4 4\nwords 16 0123456789abcdef\nffff")
            .unwrap();
    } // dropped: RST/EOF mid-line

    let deadline = Instant::now() + Duration::from_secs(10);
    while router.metrics.get("net.protocol_errors") == 0 {
        assert!(Instant::now() < deadline, "protocol error never counted");
        std::thread::sleep(Duration::from_millis(2));
    }

    let mut fresh = Client::connect(server.addr(), &cfg).unwrap();
    let (res, _) = fresh.submit(&quick_cur_job(2)).unwrap();
    assert_eq!(res.kind(), "cur");
    server.drain();
}

/// At the connection cap, excess connects are shed with an explicit
/// `BUSY` (mapped to [`FgError::Overloaded`] client-side), not queued
/// or silently dropped.
#[test]
fn connection_cap_sheds_with_busy() {
    let router = Arc::new(Router::new(1));
    let cfg = NetConfig { max_conns: 1, ..tight_cfg() };
    let server = Server::bind("127.0.0.1:0", Arc::clone(&router), cfg.clone()).unwrap();

    let held = Client::connect(server.addr(), &cfg).unwrap();
    let err = Client::connect(server.addr(), &cfg).unwrap_err();
    assert!(matches!(&err, FgError::Overloaded { .. }), "got {err}");
    assert!(router.metrics.get("net.busy") >= 1);
    drop(held);
    server.drain();
}

/// Graceful drain: the in-flight request completes with a full
/// response, post-drain connects are refused at the OS level, and the
/// persisted cache warm-starts a fresh router to a bitwise-equal hit.
#[test]
fn graceful_drain_finishes_in_flight_persists_and_refuses_after() {
    let dir = std::env::temp_dir().join(format!("fgmr_net_drain_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cache_path = dir.join("inventory.txt");

    let cfg = ServeConfig {
        cache_bytes: 8 << 20,
        cache_path: Some(cache_path.clone()),
        ..ServeConfig::service(2)
    };
    let router = Arc::new(Router::with_config(&cfg));
    let net = tight_cfg();
    let server = Server::bind("127.0.0.1:0", Arc::clone(&router), net.clone()).unwrap();
    let addr = server.addr();

    let worker = {
        let net = net.clone();
        std::thread::spawn(move || {
            let mut client = Client::connect(addr, &net).unwrap();
            client.submit(&quick_cur_job(3)).unwrap()
        })
    };
    // Wait until the request is in flight, then drain under it.
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.metrics.get("net.requests") == 0 {
        assert!(Instant::now() < deadline, "request never arrived");
        std::thread::sleep(Duration::from_millis(1));
    }
    server.drain();

    let (result, _) = worker.join().expect("in-flight request must complete through a drain");
    assert_eq!(result.kind(), "cur");
    assert!(
        std::net::TcpStream::connect(addr).is_err(),
        "post-drain connect must be refused"
    );
    assert!(cache_path.exists(), "drain must persist the cache inventory");

    // Warm start: the same job served from the persisted artifact,
    // bitwise equal to the wire result.
    let warm = Router::with_config(&cfg);
    let hit = warm.submit(quick_cur_job(3)).unwrap().wait().unwrap();
    assert_eq!(warm.metrics.get("serve.cache.hits"), 1);
    assert_eq!(hit.to_words(), result.to_words(), "warm-start result drifted");
    drop(warm);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The chaos seed's worst consecutive-injection run bounds the retry
/// budget: enumerate the pure decision schedule and check the server's
/// 16-attempt policy clears it with room, making
/// [`net_chaos_read_faults_cause_zero_hard_failures`] deterministic
/// rather than lucky.
#[test]
fn chaos_seed_worst_run_is_within_retry_budget() {
    let plan = FaultPlan::new(0x5EED_4E74)
        .with_site(site::NET_READ, 0.5, u64::MAX)
        .with_site(site::NET_WRITE, 0.25, u64::MAX);
    for (s, budget) in [(site::NET_READ, 16u32), (site::NET_WRITE, 16)] {
        let mut worst = 0u32;
        let mut run = 0u32;
        for occ in 0..20_000u64 {
            if plan.decide(s, occ) {
                run += 1;
                worst = worst.max(run);
            } else {
                run = 0;
            }
        }
        assert!(
            worst + 1 < budget,
            "{s}: worst run {worst} leaves no retry headroom under {budget} attempts"
        );
    }
}

/// Net-level chaos: 50% seeded `net.read` faults (plus write/accept
/// faults) on the server threads. Every request must still succeed —
/// zero hard failures — with bitwise-correct results, because injected
/// faults trip before any byte moves and the retry budget exceeds the
/// seed's worst run.
#[test]
fn net_chaos_read_faults_cause_zero_hard_failures() {
    let plan = Arc::new(
        FaultPlan::new(0x5EED_4E74)
            .with_site(site::NET_READ, 0.5, u64::MAX)
            .with_site(site::NET_WRITE, 0.25, u64::MAX)
            .with_site(site::NET_ACCEPT, 0.25, u64::MAX),
    );
    let cfg = NetConfig {
        faults: Some(Arc::clone(&plan)),
        retry: RetryPolicy {
            max_attempts: 16,
            base_backoff: Duration::from_micros(50),
            cap: Duration::from_millis(1),
        },
        ..tight_cfg()
    };
    let router = Arc::new(Router::with_config(&ServeConfig::service(2)));
    let baseline = Router::with_config(&ServeConfig::service(2));
    let server = Server::bind("127.0.0.1:0", Arc::clone(&router), cfg.clone()).unwrap();
    let mut client = Client::connect_retry(server.addr(), &cfg, 8).unwrap();

    for i in 0..12u64 {
        let (res, _) = client.submit(&quick_cur_job(100 + i)).unwrap();
        let reference = baseline.submit(quick_cur_job(100 + i)).unwrap().wait().unwrap();
        assert_eq!(res.to_words(), reference.to_words(), "chaos corrupted job {i}");
    }
    assert!(plan.injected() > 0, "chaos run injected nothing — seed or sites broken");
    client.quit().unwrap();
    server.drain();
}

/// Probe lines and the HTTP scrape endpoints answer correctly on both
/// dialects of the same port.
#[test]
fn probes_and_http_scrape_work() {
    let router = Arc::new(Router::new(1));
    let cfg = tight_cfg();
    let server = Server::bind("127.0.0.1:0", Arc::clone(&router), cfg.clone()).unwrap();

    let mut client = Client::connect(server.addr(), &cfg).unwrap();
    client.ping().unwrap();
    assert_eq!(client.health().unwrap(), "OK healthy");
    assert!(client.ready().unwrap());
    let body = client.metrics().unwrap();
    assert!(body.contains("net_accepted"), "prometheus body missing net counters:\n{body}");
    client.quit().unwrap();

    // HTTP dialect: a plain GET with headers, no HELLO.
    let mut raw = std::net::TcpStream::connect(server.addr()).unwrap();
    raw.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\nAccept: */*\r\n\r\n").unwrap();
    let mut response = String::new();
    std::io::Read::read_to_string(&mut raw, &mut response).unwrap();
    assert!(response.starts_with("HTTP/1.0 200 OK"), "bad scrape response:\n{response}");
    assert!(response.contains("net_accepted"));

    let mut raw = std::net::TcpStream::connect(server.addr()).unwrap();
    raw.write_all(b"GET /ready HTTP/1.1\r\n\r\n").unwrap();
    let mut response = String::new();
    std::io::Read::read_to_string(&mut raw, &mut response).unwrap();
    assert!(response.contains("200 OK") && response.contains("OK ready"));
    server.drain();
}

/// A first line that is neither `HELLO v1` nor HTTP is rejected with a
/// typed protocol error, never served.
#[test]
fn bad_opener_is_rejected() {
    let router = Arc::new(Router::new(1));
    let server = Server::bind("127.0.0.1:0", router, tight_cfg()).unwrap();
    let mut raw = std::net::TcpStream::connect(server.addr()).unwrap();
    raw.write_all(b"FROB x\n").unwrap();
    let mut reader = LineReader::new(raw, RetryPolicy::none());
    let line = reader.read_line(4096).unwrap().unwrap();
    assert!(line.starts_with("ERR protocol"), "got `{line}`");
    server.drain();
}
