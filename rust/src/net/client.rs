//! Loopback/remote wire client: a thin, synchronous speaker of the v1
//! line grammar.
//!
//! The client is the round-trip witness for the whole front-end: a job
//! submitted through [`Client::submit`] must come back **bitwise
//! identical** (same [`JobResult::to_words`] encoding) to the same job
//! submitted in-process through `Router::submit` — under fault-free
//! runs *and* under seeded net-chaos, where injected socket faults are
//! retried transparently on both ends.
//!
//! [`JobResult::to_words`]: crate::coordinator::JobResult::to_words

use super::server::NetConfig;
use super::wire::{self, LineReader, GREETING};
use crate::coordinator::{ApproxJob, JobResult};
use crate::error::{FgError, Result};
use std::net::{TcpStream, ToSocketAddrs};

/// A connected wire-protocol client (one persistent connection).
pub struct Client {
    reader: LineReader<TcpStream>,
    writer: TcpStream,
    cfg: NetConfig,
}

impl Client {
    /// Connect and validate the greeting. A `BUSY` greeting maps to
    /// [`FgError::Overloaded`] (shed — try again later), `DRAINING` to
    /// [`FgError::Coordinator`] (going away), anything else to
    /// [`FgError::Protocol`].
    pub fn connect(addr: impl ToSocketAddrs, cfg: &NetConfig) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(cfg.read_timeout)?;
        stream.set_write_timeout(cfg.write_timeout)?;
        let mut writer = stream.try_clone()?;
        let mut reader = LineReader::new(stream, cfg.retry.clone());
        wire::write_retried(&mut writer, b"HELLO v1\n", &cfg.retry)?;
        let greeting = reader
            .read_line(cfg.limits.max_line_bytes)?
            .ok_or_else(|| FgError::Coordinator("server closed before greeting".into()))?;
        match greeting.as_str() {
            GREETING => Ok(Client { reader, writer, cfg: cfg.clone() }),
            "BUSY" => Err(FgError::Overloaded { depth: 0 }),
            "DRAINING" => Err(FgError::Coordinator("server draining".into())),
            other => Err(FgError::Protocol(format!("unexpected greeting `{other}`"))),
        }
    }

    /// [`Client::connect`] with up to `attempts` tries, backing off per
    /// the config's retry policy on shed (`BUSY`) or transport errors —
    /// the client-side answer to accept-shedding backpressure and
    /// injected `net.accept` faults.
    pub fn connect_retry(
        addr: impl ToSocketAddrs + Copy,
        cfg: &NetConfig,
        attempts: u32,
    ) -> Result<Client> {
        let mut last = FgError::Coordinator("no connect attempts made".into());
        for attempt in 1..=attempts.max(1) {
            match Client::connect(addr, cfg) {
                Ok(c) => return Ok(c),
                Err(e @ (FgError::Overloaded { .. } | FgError::Io(_))) => {
                    last = e;
                    std::thread::sleep(cfg.retry.backoff(attempt));
                }
                Err(e) => return Err(e),
            }
        }
        Err(last)
    }

    /// Submit one job and wait for its result. Returns the decoded
    /// result plus the server-assigned request trace id (the same id
    /// tagged on the job's `router.dispatch` span server-side).
    pub fn submit(&mut self, job: &ApproxJob) -> Result<(JobResult, u64)> {
        let frame = wire::encode_job(job);
        wire::write_retried(&mut self.writer, frame.as_bytes(), &self.cfg.retry)?;
        wire::decode_response(&mut self.reader, &self.cfg.limits)
    }

    /// Liveness probe: `PING` → `PONG`.
    pub fn ping(&mut self) -> Result<()> {
        match self.roundtrip("PING\n")?.as_str() {
            "PONG" => Ok(()),
            other => Err(FgError::Protocol(format!("expected PONG, got `{other}`"))),
        }
    }

    /// Health probe: returns the server's `HEALTH` status line.
    pub fn health(&mut self) -> Result<String> {
        self.roundtrip("HEALTH\n")
    }

    /// Readiness probe: `true` until the server starts draining.
    pub fn ready(&mut self) -> Result<bool> {
        Ok(self.roundtrip("READY\n")?.starts_with("OK"))
    }

    /// Fetch the server's Prometheus metrics exposition.
    pub fn metrics(&mut self) -> Result<String> {
        let head = self.roundtrip("METRICS\n")?;
        let n: usize = head
            .strip_prefix("METRICS ")
            .and_then(|t| t.parse().ok())
            .ok_or_else(|| FgError::Protocol(format!("bad METRICS header `{head}`")))?;
        let body = self.reader.read_exact_bytes(n)?;
        String::from_utf8(body).map_err(|_| FgError::Protocol("non-UTF-8 metrics body".into()))
    }

    /// Close the connection cleanly (`QUIT` → `BYE`).
    pub fn quit(mut self) -> Result<()> {
        let _ = self.roundtrip("QUIT\n")?;
        Ok(())
    }

    fn roundtrip(&mut self, line: &str) -> Result<String> {
        wire::write_retried(&mut self.writer, line.as_bytes(), &self.cfg.retry)?;
        self.reader
            .read_line(self.cfg.limits.max_line_bytes)?
            .ok_or_else(|| FgError::Coordinator("server closed connection".into()))
    }
}
