//! The hardened TCP front-end: accept loop, per-connection handlers,
//! and graceful drain.
//!
//! One thread accepts; each accepted connection gets its own handler
//! thread with the router's fault plan and trace collector installed
//! (so `net.*` chaos sites and `net.request` spans behave exactly like
//! their executor-side counterparts). Robustness posture:
//!
//! * **Deadlines** — every connection gets `SO_RCVTIMEO`/`SO_SNDTIMEO`
//!   from [`NetConfig`]; a peer that stalls mid-frame (slow loris) times
//!   the read out and the connection is closed, never parking a handler
//!   thread forever.
//! * **Backpressure** — at most [`NetConfig::max_conns`] concurrent
//!   connections; excess accepts (and injected [`site::NET_ACCEPT`]
//!   faults) are shed with an explicit `BUSY` greeting so clients
//!   distinguish "try later" from "gone".
//! * **Typed rejection** — malformed or over-limit requests get an
//!   `ERR protocol ...` line and a close; the handler never panics on
//!   wire input.
//! * **Graceful drain** — [`Server::drain`] stops accepting (new
//!   connects are refused at the OS level once the listener drops),
//!   lets in-flight requests finish, joins every handler, then drains
//!   the router — which persists the artifact cache and flushes trace/
//!   metrics exports *before* returning.

use super::wire::{self, LineReader, WireLimits, GREETING};
use crate::coordinator::Router;
use crate::error::{FgError, Result};
use crate::faults::{self, site, FaultPlan, RetryPolicy};
use crate::obs;
use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Wire front-end configuration for [`Server::bind`].
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Concurrent-connection limit; accepts beyond it are shed with
    /// `BUSY`. `0` means unlimited.
    pub max_conns: usize,
    /// Per-connection socket read deadline (slow-loris protection and
    /// idle-connection reaping); `None` = block forever.
    pub read_timeout: Option<Duration>,
    /// Per-connection socket write deadline; `None` = block forever.
    pub write_timeout: Option<Duration>,
    /// Frame size caps (header lines and payload words).
    pub limits: WireLimits,
    /// Retry policy for *injected* transient socket faults
    /// (`net.read`/`net.write`); sized above the fault plan's worst
    /// consecutive-injection run, it makes chaos runs provably
    /// hard-failure-free.
    pub retry: RetryPolicy,
    /// Fault plan installed on the accept and handler threads; `None`
    /// disables net-level chaos.
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for NetConfig {
    /// 64 connections, 5 s deadlines, default frame caps, default retry.
    fn default() -> Self {
        Self {
            max_conns: 64,
            read_timeout: Some(Duration::from_secs(5)),
            write_timeout: Some(Duration::from_secs(5)),
            limits: WireLimits::default(),
            retry: RetryPolicy::default(),
            faults: None,
        }
    }
}

/// Pre-fetched counter handles — the per-request path never touches the
/// metrics registry lock (the router's `ServeCounters` pattern).
struct NetCounters {
    accepted: Arc<AtomicU64>,
    busy: Arc<AtomicU64>,
    requests: Arc<AtomicU64>,
    ok: Arc<AtomicU64>,
    err: Arc<AtomicU64>,
    protocol_errors: Arc<AtomicU64>,
    disconnects: Arc<AtomicU64>,
}

impl NetCounters {
    fn new(router: &Router) -> Self {
        let m = &router.metrics;
        Self {
            accepted: m.counter("net.accepted"),
            busy: m.counter("net.busy"),
            requests: m.counter("net.requests"),
            ok: m.counter("net.ok"),
            err: m.counter("net.err"),
            protocol_errors: m.counter("net.protocol_errors"),
            disconnects: m.counter("net.disconnects"),
        }
    }
}

struct ServerState {
    router: Arc<Router>,
    cfg: NetConfig,
    draining: AtomicBool,
    active: AtomicUsize,
    next_trace: AtomicU64,
    nc: NetCounters,
}

/// The wire front-end: a bound listener plus its accept thread. Submits
/// decoded jobs through the shared [`Router`] with per-request trace
/// ids, and owns the drain sequencing.
pub struct Server {
    addr: SocketAddr,
    state: Arc<ServerState>,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    drained: bool,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting. The router is shared — in-process submitters
    /// keep working alongside the wire front-end.
    pub fn bind(addr: &str, router: Arc<Router>, cfg: NetConfig) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let nc = NetCounters::new(&router);
        let state = Arc::new(ServerState {
            router,
            cfg,
            draining: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            next_trace: AtomicU64::new(0),
            nc,
        });
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let state = Arc::clone(&state);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("fastgmr-accept".into())
                .spawn(move || accept_loop(listener, &state, &conns))
                .map_err(FgError::Io)?
        };
        Ok(Server { addr: local, state, accept: Some(accept), conns, drained: false })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Whether the server has begun draining.
    pub fn draining(&self) -> bool {
        self.state.draining.load(Ordering::SeqCst)
    }

    fn do_drain(&mut self) {
        if self.drained {
            return;
        }
        self.drained = true;
        self.state.draining.store(true, Ordering::SeqCst);
        // Poke the (blocking) accept call so it observes the flag; the
        // listener drops with the accept thread, so post-drain connects
        // are refused by the OS, not silently queued.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handlers: Vec<_> = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handlers {
            let _ = h.join();
        }
        // Last: the router finishes queued work, persists the artifact
        // cache, and flushes trace/metrics exports before this returns.
        self.state.router.drain();
    }

    /// Graceful shutdown: stop accepting, finish in-flight requests,
    /// join every handler thread, then drain the router (cache persist
    /// + observability export flush). Idle keep-alive connections are
    /// closed at their next read deadline, so the drain completes
    /// within roughly one [`NetConfig::read_timeout`].
    pub fn drain(mut self) {
        self.do_drain();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.do_drain();
    }
}

fn accept_loop(
    listener: TcpListener,
    state: &Arc<ServerState>,
    conns: &Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
) {
    faults::install(state.cfg.faults.clone());
    loop {
        let Ok((stream, _)) = listener.accept() else {
            if state.draining.load(Ordering::SeqCst) {
                return;
            }
            continue;
        };
        if state.draining.load(Ordering::SeqCst) {
            // Includes the drain's own wake-up poke. Tell a real client
            // why before closing (best effort — it may be the poke).
            let mut s = stream;
            let _ = s.write_all(b"DRAINING\n");
            return;
        }
        let at_cap =
            state.cfg.max_conns > 0 && state.active.load(Ordering::SeqCst) >= state.cfg.max_conns;
        if at_cap || faults::trip_ambient(site::NET_ACCEPT) {
            state.nc.busy.fetch_add(1, Ordering::Relaxed);
            let mut s = stream;
            let _ = s.write_all(b"BUSY\n");
            continue; // dropped: shed, not served
        }
        state.nc.accepted.fetch_add(1, Ordering::Relaxed);
        state.active.fetch_add(1, Ordering::SeqCst);
        let st = Arc::clone(state);
        let handle = std::thread::Builder::new()
            .name("fastgmr-conn".into())
            .spawn(move || {
                handle_conn(&st, stream);
                st.active.fetch_sub(1, Ordering::SeqCst);
            });
        let mut guard = conns.lock().unwrap();
        match handle {
            Ok(h) => guard.push(h),
            Err(_) => {
                state.active.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
        }
        // Reap finished handlers so a long-lived server doesn't
        // accumulate join handles without bound.
        let (done, live): (Vec<_>, Vec<_>) = guard.drain(..).partition(|h| h.is_finished());
        *guard = live;
        drop(guard);
        for h in done {
            let _ = h.join();
        }
    }
}

/// What a request handler decided about the connection's future.
enum Flow {
    /// Keep serving requests on this connection.
    Continue,
    /// Close cleanly (QUIT, HTTP response sent, drain, EOF, deadline).
    Close,
}

fn handle_conn(state: &ServerState, stream: TcpStream) {
    faults::install(state.cfg.faults.clone());
    obs::install(state.router.trace_collector());
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(state.cfg.read_timeout);
    let _ = stream.set_write_timeout(state.cfg.write_timeout);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = LineReader::new(read_half, state.cfg.retry.clone());
    let mut writer = stream;
    let retry = state.cfg.retry.clone();

    // The client speaks first, and its first line picks the dialect:
    // `HELLO v1` opens a line-protocol session (answered with the
    // greeting), an HTTP request line gets a clean scrape response with
    // no greeting in front of it. Anything else is a typed rejection.
    match reader.read_line(state.cfg.limits.max_line_bytes) {
        Ok(Some(first)) if first.starts_with("GET ") => {
            let _ = handle_http(state, &first, &mut reader, &mut writer);
            return;
        }
        Ok(Some(first)) if first == "HELLO v1" => {
            if wire::write_retried(&mut writer, format!("{GREETING}\n").as_bytes(), &retry)
                .is_err()
            {
                state.nc.disconnects.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        Ok(Some(first)) => {
            state.nc.protocol_errors.fetch_add(1, Ordering::Relaxed);
            let e = FgError::Protocol(format!("expected HELLO v1 or an HTTP GET, got `{first}`"));
            let _ = wire::write_retried(&mut writer, wire::encode_err(&e).as_bytes(), &retry);
            return;
        }
        Ok(None) => return,
        Err(_) => {
            state.nc.disconnects.fetch_add(1, Ordering::Relaxed);
            return;
        }
    }
    loop {
        let line = match reader.read_line(state.cfg.limits.max_line_bytes) {
            Ok(Some(line)) => line,
            Ok(None) => return, // clean close at a request boundary
            Err(e) => {
                // Read deadline, mid-line disconnect, oversized header:
                // best-effort typed rejection, then close.
                if matches!(e, FgError::Protocol(_)) {
                    state.nc.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    let reject = wire::encode_err(&e);
                    let _ = wire::write_retried(&mut writer, reject.as_bytes(), &retry);
                } else {
                    state.nc.disconnects.fetch_add(1, Ordering::Relaxed);
                }
                return;
            }
        };
        let verb = line.split_whitespace().next().unwrap_or("");
        let flow = match verb {
            "" => Ok(Flow::Continue),
            "JOB" => handle_job(state, &line, &mut reader, &mut writer),
            "PING" => wire::write_retried(&mut writer, b"PONG\n", &retry).map(|()| Flow::Continue),
            "HEALTH" => {
                wire::write_retried(&mut writer, b"OK healthy\n", &retry).map(|()| Flow::Continue)
            }
            "READY" => {
                let body: &[u8] = if state.draining.load(Ordering::SeqCst) {
                    b"ERR coordinator draining\n"
                } else {
                    b"OK ready\n"
                };
                wire::write_retried(&mut writer, body, &retry).map(|()| Flow::Continue)
            }
            "METRICS" => {
                let body = state.router.metrics.prometheus();
                let head = format!("METRICS {}\n", body.len());
                wire::write_retried(&mut writer, head.as_bytes(), &retry)
                    .and_then(|()| wire::write_retried(&mut writer, body.as_bytes(), &retry))
                    .map(|()| Flow::Continue)
            }
            "QUIT" => {
                let _ = wire::write_retried(&mut writer, b"BYE\n", &retry);
                Ok(Flow::Close)
            }
            "GET" => handle_http(state, &line, &mut reader, &mut writer),
            _ => {
                let e = FgError::Protocol(format!("unknown request `{verb}`"));
                state.nc.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let _ = wire::write_retried(&mut writer, wire::encode_err(&e).as_bytes(), &retry);
                Ok(Flow::Close)
            }
        };
        match flow {
            Ok(Flow::Continue) => {
                if state.draining.load(Ordering::SeqCst) {
                    return; // in-flight request finished; drain wins now
                }
            }
            Ok(Flow::Close) => return,
            Err(_) => {
                state.nc.disconnects.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
    }
}

/// One `JOB` request: decode frames, submit through the router with a
/// fresh trace id, wait, and stream the result (or typed error) back.
/// `Err` means the *socket* failed; request-level failures are `Ok`
/// responses carrying `ERR` frames.
fn handle_job(
    state: &ServerState,
    header: &str,
    reader: &mut LineReader<TcpStream>,
    writer: &mut TcpStream,
) -> Result<Flow> {
    let trace_id = state.next_trace.fetch_add(1, Ordering::Relaxed) + 1;
    let started = Instant::now();
    state.nc.requests.fetch_add(1, Ordering::Relaxed);
    let mut span = obs::span("net.request", obs::cat::NET);
    if span.active() {
        span.meta("trace_id", trace_id);
    }
    let retry = state.cfg.retry.clone();
    let job = match wire::decode_job(header, reader, &state.cfg.limits) {
        Ok(job) => job,
        Err(e) => {
            // The stream may be mid-frame — unknowable state, so reject
            // and close rather than resynchronize heuristically.
            state.nc.protocol_errors.fetch_add(1, Ordering::Relaxed);
            state.nc.err.fetch_add(1, Ordering::Relaxed);
            let _ = wire::write_retried(writer, wire::encode_err(&e).as_bytes(), &retry);
            return Ok(Flow::Close);
        }
    };
    if span.active() {
        span.meta("kind", job.kind());
    }
    let outcome = state
        .router
        .submit_traced(job, state.router.default_deadline(), Some(trace_id))
        .and_then(|h| h.wait());
    let frame = match &outcome {
        Ok(result) => {
            state.nc.ok.fetch_add(1, Ordering::Relaxed);
            wire::encode_result(result, trace_id)
        }
        Err(e) => {
            state.nc.err.fetch_add(1, Ordering::Relaxed);
            wire::encode_err(e)
        }
    };
    wire::write_retried(writer, frame.as_bytes(), &retry)?;
    state.router.metrics.observe("net.request.latency", started.elapsed().as_secs_f64());
    Ok(Flow::Continue)
}

/// Minimal HTTP/1.0 responder for scrape probes: `GET /metrics`,
/// `GET /health`, `GET /ready`. Reads (and discards) request headers up
/// to the blank line, answers with `Connection: close`, and closes.
fn handle_http(
    state: &ServerState,
    request_line: &str,
    reader: &mut LineReader<TcpStream>,
    writer: &mut TcpStream,
) -> Result<Flow> {
    let path = request_line.split_whitespace().nth(1).unwrap_or("/");
    // Drain request headers; a peer streaming unbounded headers hits
    // the per-line cap or the read deadline, both of which close.
    for _ in 0..128 {
        match reader.read_line(state.cfg.limits.max_line_bytes)? {
            Some(l) if l.is_empty() => break,
            Some(_) => continue,
            None => break,
        }
    }
    let draining = state.draining.load(Ordering::SeqCst);
    let (status, body) = match path {
        "/metrics" => ("200 OK", state.router.metrics.prometheus()),
        "/health" => ("200 OK", "OK healthy\n".to_string()),
        "/ready" if !draining => ("200 OK", "OK ready\n".to_string()),
        "/ready" => ("503 Service Unavailable", "DRAINING\n".to_string()),
        _ => ("404 Not Found", "not found\n".to_string()),
    };
    let response = format!(
        "HTTP/1.0 {status}\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    wire::write_retried(writer, response.as_bytes(), &state.cfg.retry)?;
    Ok(Flow::Close)
}
