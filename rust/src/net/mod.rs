//! Hardened wire front-end: line-protocol serving over TCP.
//!
//! The serving daemon grows past one process here: a std-only
//! (`std::net`) TCP listener speaks a line-based grammar ([`wire`]),
//! decodes requests into the existing [`ApproxJob`] grammar, submits
//! them through the shared [`Router`] with per-request trace ids, and
//! streams [`JobResult`] payloads back as checksummed word frames — the
//! exact `to_words`/`from_words` + FNV-64 encoding the persisted
//! artifact cache already trusts, so wire results are bitwise identical
//! to in-process ones.
//!
//! * [`wire`] — grammar v1: frames, caps, checksums, typed
//!   [`FgError::Protocol`] rejection, fault-injected retried I/O.
//! * [`Server`] — accept loop with connection-limit shedding (`BUSY`),
//!   socket deadlines, `/metrics`–`/health`–`/ready` scrape endpoints,
//!   and graceful drain (finish in-flight, persist cache, flush
//!   exports, join).
//! * [`Client`] — the loopback round-trip witness used by tests,
//!   `bench fig_serve`, and the CLI demo stream.
//!
//! Chaos sites `net.accept` / `net.read` / `net.write` plug into the
//! seeded [`FaultPlan`](crate::faults::FaultPlan) machinery; with a
//! retry budget above the plan's worst consecutive-injection run, a
//! chaos run is provably free of hard failures (tested, and guarded in
//! CI via `BENCH_net.json`).
//!
//! [`ApproxJob`]: crate::coordinator::ApproxJob
//! [`JobResult`]: crate::coordinator::JobResult
//! [`Router`]: crate::coordinator::Router
//! [`FgError::Protocol`]: crate::error::FgError::Protocol

pub mod client;
pub mod server;
pub mod wire;

pub use client::Client;
pub use server::{NetConfig, Server};
pub use wire::{LineReader, WireLimits};

#[cfg(test)]
mod tests;
