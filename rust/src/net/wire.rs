//! Wire grammar v1: the line protocol spoken between [`super::Server`]
//! and [`super::Client`].
//!
//! Every frame is a `\n`-terminated ASCII line; matrix/result payloads
//! ride as one line of space-separated 16-hex-digit words guarded by a
//! word-folded FNV-1a checksum (the artifact cache's
//! [`Fnv64`] — the same digest that already guards the persisted
//! inventory). Floats travel as IEEE-754 bit patterns, so a decoded job
//! is *bitwise* the job that was encoded and the serving results are
//! bitwise identical to an in-process [`Router::submit`]
//! (`Router`: crate::coordinator::Router).
//!
//! ```text
//! JOB cur c=4 r=4 sel=leverage core=fast-gmr sketch=gaussian s_c=12 s_r=12 seed=7
//! MAT dense 24 18
//! words 432 <fnv64>
//! <432 hex words>
//! ```
//!
//! responded to with
//!
//! ```text
//! OK cur trace=0000000000000001 shapes=4x1,4x1,24x4,4x4,4x18
//! words 448 <fnv64>
//! <448 hex words>
//! ```
//!
//! or `ERR <code> <message>`. See README §Serving for the full grammar
//! table. Malformed input is always a typed [`FgError::Protocol`] —
//! never a panic, never a partial decode: counts are bounded *before*
//! any allocation sized by them, CSR structure is validated before
//! [`Csr::from_raw`] (whose assertions are for trusted callers), and a
//! checksum mismatch rejects the frame.
//!
//! Reads and writes honor the deterministic chaos sites
//! [`site::NET_READ`] / [`site::NET_WRITE`]: a [`LineReader`] trips the
//! plan **before** touching the socket, so an injected fault is
//! retried in place (per [`RetryPolicy`]) without consuming bytes —
//! replay-safe by construction, exactly like the stream-read fault
//! contract in [`crate::faults`].

use crate::coordinator::cache::Fnv64;
use crate::coordinator::{ApproxJob, JobResult, MatrixPayload};
use crate::cur::{CoreMethod, CurConfig, SelectionStrategy, StreamingCurConfig};
use crate::error::{FgError, Result};
use crate::faults::{self, site, RetryPolicy};
use crate::gmr::FastGmrConfig;
use crate::linalg::Mat;
use crate::sketch::SketchKind;
use crate::sparse::Csr;
use crate::svdstream::FastSpSvdConfig;
use std::io::{Read, Write};

/// Protocol identifier sent in reply to a client's `HELLO v1` opener
/// (the accept path may answer `BUSY` or `DRAINING` instead).
pub const GREETING: &str = "FASTGMR v1";

/// Size caps enforced while decoding frames. Both caps reject with
/// [`FgError::Protocol`] *before* any cap-sized allocation happens, so
/// a hostile peer cannot balloon server memory with a forged header.
#[derive(Clone, Copy, Debug)]
pub struct WireLimits {
    /// Longest accepted header/control line, in bytes.
    pub max_line_bytes: usize,
    /// Largest accepted payload, in 64-bit words (dense: `rows·cols`;
    /// CSR: `rows+1 + 2·nnz`).
    pub max_payload_words: usize,
}

impl Default for WireLimits {
    /// 4 KiB header lines, 4 Mi payload words (32 MiB of matrix).
    fn default() -> Self {
        Self { max_line_bytes: 4096, max_payload_words: 4 << 20 }
    }
}

/// Word-folded FNV-1a digest of a payload word slice — the checksum
/// carried on every `words` line.
pub fn checksum(words: &[u64]) -> u64 {
    let mut h = Fnv64::new();
    for &w in words {
        h.write_u64(w);
    }
    h.finish()
}

fn protocol(msg: impl Into<String>) -> FgError {
    FgError::Protocol(msg.into())
}

/// Buffered, cap-enforcing line reader with deterministic fault
/// injection and in-place retry.
///
/// Each buffer fill trips [`site::NET_READ`] **before** the socket is
/// touched; injected faults surface as `ErrorKind::Interrupted` and are
/// retried per the policy without consuming any bytes, so a retried
/// read observes exactly the bytes the failed attempt would have. Line
/// caps, mid-line EOF, and non-UTF-8 input are typed
/// [`FgError::Protocol`] rejections.
pub struct LineReader<R: Read> {
    inner: R,
    buf: Vec<u8>,
    retry: RetryPolicy,
}

impl<R: Read> LineReader<R> {
    pub fn new(inner: R, retry: RetryPolicy) -> Self {
        Self { inner, buf: Vec::new(), retry }
    }

    /// One buffer fill with fault injection + retry. Returns the byte
    /// count appended (0 = EOF).
    fn fill(&mut self) -> Result<usize> {
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            let res = if faults::trip_ambient(site::NET_READ) {
                Err(std::io::Error::new(std::io::ErrorKind::Interrupted, "injected net.read fault"))
            } else {
                let mut chunk = [0u8; 65536];
                self.inner.read(&mut chunk).map(|n| {
                    self.buf.extend_from_slice(&chunk[..n]);
                    n
                })
            };
            match res {
                Ok(n) => return Ok(n),
                // Interrupted (real or injected) is the one transient
                // read error: nothing was consumed, replay is safe.
                // Timeouts are *not* retried here — they are the
                // connection deadline doing its job.
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                    if attempt >= self.retry.max_attempts {
                        return Err(FgError::Io(e));
                    }
                    std::thread::sleep(self.retry.backoff(attempt));
                }
                Err(e) => return Err(FgError::Io(e)),
            }
        }
    }

    /// Read one `\n`-terminated line of at most `cap` bytes (terminator
    /// excluded). `Ok(None)` is a clean EOF at a line boundary; EOF
    /// mid-line is a [`FgError::Protocol`] truncation.
    pub fn read_line(&mut self, cap: usize) -> Result<Option<String>> {
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                if pos > cap {
                    return Err(protocol(format!("line exceeds {cap} byte cap")));
                }
                let rest = self.buf.split_off(pos + 1);
                let mut line = std::mem::replace(&mut self.buf, rest);
                line.pop(); // '\n'
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                let s = String::from_utf8(line).map_err(|_| protocol("non-UTF-8 line"))?;
                return Ok(Some(s));
            }
            if self.buf.len() > cap {
                return Err(protocol(format!("line exceeds {cap} byte cap")));
            }
            if self.fill()? == 0 {
                if self.buf.is_empty() {
                    return Ok(None);
                }
                return Err(protocol("connection closed mid-line"));
            }
        }
    }

    /// Read exactly `n` raw bytes (used for the `METRICS <n>` body).
    pub fn read_exact_bytes(&mut self, n: usize) -> Result<Vec<u8>> {
        while self.buf.len() < n {
            if self.fill()? == 0 {
                return Err(protocol("connection closed mid-body"));
            }
        }
        let rest = self.buf.split_off(n);
        Ok(std::mem::replace(&mut self.buf, rest))
    }
}

/// Write `buf` with deterministic fault injection and in-place retry:
/// [`site::NET_WRITE`] trips **before** the first byte leaves, so an
/// injected fault replays the whole buffer (nothing was sent) and a
/// response frame is never interleaved with a retry of itself.
pub fn write_retried<W: Write>(w: &mut W, buf: &[u8], retry: &RetryPolicy) -> Result<()> {
    let mut attempt = 0u32;
    loop {
        attempt += 1;
        if faults::trip_ambient(site::NET_WRITE) {
            if attempt >= retry.max_attempts {
                return Err(FgError::Io(std::io::Error::new(
                    std::io::ErrorKind::Interrupted,
                    "injected net.write fault",
                )));
            }
            std::thread::sleep(retry.backoff(attempt));
            continue;
        }
        // `write_all` retries real `Interrupted` internally; any other
        // error (incl. the write deadline) fails the connection.
        w.write_all(buf)?;
        w.flush()?;
        return Ok(());
    }
}

// ---------------------------------------------------------------------
// Word-line payloads
// ---------------------------------------------------------------------

/// Render a `words <n> <fnv64>` guard line plus the payload line.
///
/// The payload line is built with a nibble-table encoder instead of a
/// per-word `format!`: a dense bench payload is ~10^5 words, and this
/// is the hot half of the socket latency the CI guard compares against
/// in-process serving.
fn push_words(out: &mut String, words: &[u64]) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "words {} {:016x}", words.len(), checksum(words));
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut bytes = Vec::with_capacity(words.len() * 17 + 1);
    for (i, &w) in words.iter().enumerate() {
        if i > 0 {
            bytes.push(b' ');
        }
        let mut chunk = [0u8; 16];
        for (k, c) in chunk.iter_mut().enumerate() {
            *c = HEX[((w >> (60 - 4 * k)) & 0xf) as usize];
        }
        bytes.extend_from_slice(&chunk);
    }
    bytes.push(b'\n');
    out.push_str(std::str::from_utf8(&bytes).expect("hex payload is pure ASCII"));
}

/// Read a `words` guard line plus payload line, enforcing the declared
/// count against `expect` and the checksum against the decoded words.
fn read_words<R: Read>(
    r: &mut LineReader<R>,
    limits: &WireLimits,
    expect: usize,
) -> Result<Vec<u64>> {
    let line = r
        .read_line(limits.max_line_bytes)?
        .ok_or_else(|| protocol("connection closed before words header"))?;
    let mut parts = line.split_whitespace();
    if parts.next() != Some("words") {
        return Err(protocol("expected `words <n> <fnv64>` header"));
    }
    let n: usize =
        parts.next().and_then(|t| t.parse().ok()).ok_or_else(|| protocol("bad words count"))?;
    let declared = parts
        .next()
        .and_then(|t| u64::from_str_radix(t, 16).ok())
        .ok_or_else(|| protocol("bad words checksum"))?;
    if n != expect {
        return Err(protocol(format!("words count {n} disagrees with frame header ({expect})")));
    }
    // 16 hex digits + separator per word, plus slack for the newline.
    let cap = n.saturating_mul(17) + 64;
    let payload = r
        .read_line(cap)?
        .ok_or_else(|| protocol("connection closed before payload line"))?;
    let mut words = Vec::with_capacity(n);
    for tok in payload.split_ascii_whitespace() {
        if words.len() == n {
            return Err(protocol("payload has more words than declared"));
        }
        words.push(
            u64::from_str_radix(tok, 16).map_err(|_| protocol("non-hex payload word"))?,
        );
    }
    if words.len() != n {
        return Err(protocol(format!("payload has {} words, declared {n}", words.len())));
    }
    if checksum(&words) != declared {
        return Err(protocol("payload checksum mismatch"));
    }
    Ok(words)
}

// ---------------------------------------------------------------------
// Matrix frames
// ---------------------------------------------------------------------

fn push_dense(out: &mut String, m: &Mat) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "MAT dense {} {}", m.rows(), m.cols());
    let words: Vec<u64> = m.data().iter().map(|v| v.to_bits()).collect();
    push_words(out, &words);
}

fn push_payload(out: &mut String, p: &MatrixPayload) {
    use std::fmt::Write as _;
    match p {
        MatrixPayload::Dense(m) => push_dense(out, m),
        MatrixPayload::Sparse(a) => {
            let _ = writeln!(out, "MAT csr {} {} {}", a.rows(), a.cols(), a.nnz());
            let mut words = Vec::with_capacity(a.rows() + 1 + 2 * a.nnz());
            // indptr (rows+1), then indices (nnz), then value bits (nnz).
            let mut running = 0u64;
            words.push(0);
            for i in 0..a.rows() {
                running += a.row(i).0.len() as u64;
                words.push(running);
            }
            for i in 0..a.rows() {
                words.extend(a.row(i).0.iter().map(|&j| j as u64));
            }
            for i in 0..a.rows() {
                words.extend(a.row(i).1.iter().map(|&v| v.to_bits()));
            }
            push_words(out, &words);
        }
    }
}

fn read_mat_header<R: Read>(r: &mut LineReader<R>, limits: &WireLimits) -> Result<String> {
    r.read_line(limits.max_line_bytes)?
        .ok_or_else(|| protocol("connection closed before MAT header"))
}

/// Decode one matrix frame (dense or CSR) with full structural
/// validation — the CSR path re-checks everything
/// [`Csr::from_raw`] asserts, as a typed rejection instead of a panic.
fn read_payload<R: Read>(r: &mut LineReader<R>, limits: &WireLimits) -> Result<MatrixPayload> {
    let header = read_mat_header(r, limits)?;
    let mut parts = header.split_whitespace();
    if parts.next() != Some("MAT") {
        return Err(protocol("expected MAT frame"));
    }
    let form = parts.next().ok_or_else(|| protocol("MAT frame missing form"))?;
    let dim = |p: Option<&str>| -> Result<usize> {
        p.and_then(|t| t.parse().ok()).ok_or_else(|| protocol("bad MAT dimension"))
    };
    match form {
        "dense" => {
            let rows = dim(parts.next())?;
            let cols = dim(parts.next())?;
            let n = rows
                .checked_mul(cols)
                .filter(|&n| n <= limits.max_payload_words)
                .ok_or_else(|| {
                    protocol(format!(
                        "dense payload {rows}x{cols} exceeds {} word cap",
                        limits.max_payload_words
                    ))
                })?;
            let words = read_words(r, limits, n)?;
            let data: Vec<f64> = words.iter().map(|&w| f64::from_bits(w)).collect();
            Ok(MatrixPayload::Dense(Mat::from_vec(rows, cols, data)))
        }
        "csr" => {
            let rows = dim(parts.next())?;
            let cols = dim(parts.next())?;
            let nnz = dim(parts.next())?;
            let n = nnz
                .checked_mul(2)
                .and_then(|t| t.checked_add(rows))
                .and_then(|t| t.checked_add(1))
                .filter(|&n| n <= limits.max_payload_words)
                .ok_or_else(|| {
                    protocol(format!(
                        "csr payload ({rows} rows, {nnz} nnz) exceeds {} word cap",
                        limits.max_payload_words
                    ))
                })?;
            let words = read_words(r, limits, n)?;
            let indptr: Vec<usize> = words[..rows + 1].iter().map(|&w| w as usize).collect();
            if indptr[0] != 0 || indptr[rows] != nnz || indptr.windows(2).any(|w| w[0] > w[1]) {
                return Err(protocol("csr indptr is not a monotone 0..nnz partition"));
            }
            let indices: Vec<usize> =
                words[rows + 1..rows + 1 + nnz].iter().map(|&w| w as usize).collect();
            if indices.iter().any(|&j| j >= cols) {
                return Err(protocol("csr column index out of bounds"));
            }
            let values: Vec<f64> =
                words[rows + 1 + nnz..].iter().map(|&w| f64::from_bits(w)).collect();
            Ok(MatrixPayload::Sparse(Csr::from_raw(rows, cols, indptr, indices, values)))
        }
        other => Err(protocol(format!("unknown MAT form `{other}`"))),
    }
}

/// Like [`read_payload`] but for frames where the grammar requires a
/// dense matrix (the supplied `C`/`R` factors of GMR jobs).
fn read_dense<R: Read>(r: &mut LineReader<R>, limits: &WireLimits) -> Result<Mat> {
    match read_payload(r, limits)? {
        MatrixPayload::Dense(m) => Ok(m),
        MatrixPayload::Sparse(_) => Err(protocol("this frame requires a dense matrix")),
    }
}

// ---------------------------------------------------------------------
// Job frames
// ---------------------------------------------------------------------

fn sel_token(s: &SelectionStrategy) -> String {
    match s {
        SelectionStrategy::Uniform => "uniform".into(),
        SelectionStrategy::Leverage => "leverage".into(),
        SelectionStrategy::SubspaceLeverage { k } => format!("subspace:{k}"),
        SelectionStrategy::SketchedLeverage { kind, size } => {
            format!("sketched:{}:{}", kind.name(), size)
        }
    }
}

fn parse_sel(tok: &str) -> Result<SelectionStrategy> {
    let mut parts = tok.split(':');
    let head = parts.next().unwrap_or("");
    let sel = match head {
        "uniform" => SelectionStrategy::Uniform,
        "leverage" => SelectionStrategy::Leverage,
        "subspace" => {
            let k = parts
                .next()
                .and_then(|t| t.parse().ok())
                .ok_or_else(|| protocol("subspace selection needs `subspace:<k>`"))?;
            SelectionStrategy::SubspaceLeverage { k }
        }
        "sketched" => {
            let kind = parts
                .next()
                .and_then(|t| SketchKind::parse(t).ok())
                .ok_or_else(|| protocol("sketched selection needs `sketched:<kind>:<size>`"))?;
            let size = parts
                .next()
                .and_then(|t| t.parse().ok())
                .ok_or_else(|| protocol("sketched selection needs `sketched:<kind>:<size>`"))?;
            SelectionStrategy::SketchedLeverage { kind, size }
        }
        other => return Err(protocol(format!("unknown selection `{other}`"))),
    };
    if parts.next().is_some() {
        return Err(protocol(format!("trailing tokens in selection `{tok}`")));
    }
    Ok(sel)
}

/// `key=value` fields of a `JOB` header line.
struct Fields<'a> {
    pairs: Vec<(&'a str, &'a str)>,
}

impl<'a> Fields<'a> {
    fn parse(tokens: impl Iterator<Item = &'a str>) -> Result<Self> {
        let mut pairs = Vec::new();
        for tok in tokens {
            let (k, v) =
                tok.split_once('=').ok_or_else(|| protocol(format!("bad field `{tok}`")))?;
            pairs.push((k, v));
        }
        Ok(Self { pairs })
    }

    fn raw(&self, key: &str) -> Result<&'a str> {
        self.pairs
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, v)| *v)
            .ok_or_else(|| protocol(format!("missing field `{key}`")))
    }

    fn num<T: std::str::FromStr>(&self, key: &str) -> Result<T> {
        self.raw(key)?.parse().map_err(|_| protocol(format!("bad numeric field `{key}`")))
    }

    fn f64_bits(&self, key: &str) -> Result<f64> {
        u64::from_str_radix(self.raw(key)?, 16)
            .map(f64::from_bits)
            .map_err(|_| protocol(format!("field `{key}` must be 16 hex digits (f64 bits)")))
    }

    fn sketch(&self, key: &str) -> Result<SketchKind> {
        SketchKind::parse(self.raw(key)?).map_err(|e| protocol(e.to_string()))
    }
}

/// Encode a job as its full wire frame set (header + matrix frames).
pub fn encode_job(job: &ApproxJob) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    match job {
        ApproxJob::Gmr { a, c, r, cfg, seed } => {
            let _ = writeln!(
                out,
                "JOB gmr kind_c={} kind_r={} s_c={} s_r={} seed={}",
                cfg.kind_c.name(),
                cfg.kind_r.name(),
                cfg.s_c,
                cfg.s_r,
                seed
            );
            push_payload(&mut out, a);
            push_dense(&mut out, c);
            push_dense(&mut out, r);
        }
        ApproxJob::GmrExact { a, c, r } => {
            out.push_str("JOB gmr_exact\n");
            push_payload(&mut out, a);
            push_dense(&mut out, c);
            push_dense(&mut out, r);
        }
        ApproxJob::SpsdKernel { x, sigma, c, s, seed } => {
            let _ = writeln!(
                out,
                "JOB spsd sigma={:016x} c={c} s={s} seed={seed}",
                sigma.to_bits()
            );
            push_dense(&mut out, x);
        }
        ApproxJob::StreamSvd { a, cfg, block, seed } => {
            let _ = writeln!(
                out,
                "JOB svd k={} c={} r={} s_c={} s_r={} osnap_mult={} core={} block={block} seed={seed}",
                cfg.k, cfg.c, cfg.r, cfg.s_c, cfg.s_r, cfg.osnap_mult, cfg.core_kind.name()
            );
            push_payload(&mut out, a);
        }
        ApproxJob::Cur { a, cfg, seed } => {
            let _ = writeln!(
                out,
                "JOB cur c={} r={} sel={} core={} sketch={} s_c={} s_r={} seed={seed}",
                cfg.c,
                cfg.r,
                sel_token(&cfg.selection),
                cfg.core.name(),
                cfg.sketch.name(),
                cfg.s_c,
                cfg.s_r
            );
            push_payload(&mut out, a);
        }
        ApproxJob::StreamingCur { a, cfg, block, seed } => {
            let _ = writeln!(
                out,
                "JOB cur_stream c={} r={} k={} sketch={} s_c={} s_r={} oversample={} block={block} seed={seed}",
                cfg.c, cfg.r, cfg.k, cfg.kind.name(), cfg.s_c, cfg.s_r, cfg.oversample
            );
            push_payload(&mut out, a);
        }
    }
    out
}

/// Decode the frames following an already-read `JOB ...` header line.
pub fn decode_job<R: Read>(
    header: &str,
    r: &mut LineReader<R>,
    limits: &WireLimits,
) -> Result<ApproxJob> {
    let mut toks = header.split_whitespace();
    if toks.next() != Some("JOB") {
        return Err(protocol("expected JOB header"));
    }
    let kind = toks.next().ok_or_else(|| protocol("JOB header missing kind"))?;
    let f = Fields::parse(toks)?;
    match kind {
        "gmr" => {
            let cfg = FastGmrConfig {
                kind_c: f.sketch("kind_c")?,
                kind_r: f.sketch("kind_r")?,
                s_c: f.num("s_c")?,
                s_r: f.num("s_r")?,
            };
            let seed = f.num("seed")?;
            let a = read_payload(r, limits)?;
            let c = read_dense(r, limits)?;
            let rr = read_dense(r, limits)?;
            Ok(ApproxJob::Gmr { a, c, r: rr, cfg, seed })
        }
        "gmr_exact" => {
            let a = read_payload(r, limits)?;
            let c = read_dense(r, limits)?;
            let rr = read_dense(r, limits)?;
            Ok(ApproxJob::GmrExact { a, c, r: rr })
        }
        "spsd" => {
            let sigma = f.f64_bits("sigma")?;
            let c = f.num("c")?;
            let s = f.num("s")?;
            let seed = f.num("seed")?;
            let x = read_dense(r, limits)?;
            Ok(ApproxJob::SpsdKernel { x, sigma, c, s, seed })
        }
        "svd" => {
            let cfg = FastSpSvdConfig {
                k: f.num("k")?,
                c: f.num("c")?,
                r: f.num("r")?,
                s_c: f.num("s_c")?,
                s_r: f.num("s_r")?,
                osnap_mult: f.num("osnap_mult")?,
                core_kind: f.sketch("core")?,
            };
            let block = f.num("block")?;
            let seed = f.num("seed")?;
            let a = read_payload(r, limits)?;
            Ok(ApproxJob::StreamSvd { a, cfg, block, seed })
        }
        "cur" => {
            let cfg = CurConfig {
                c: f.num("c")?,
                r: f.num("r")?,
                selection: parse_sel(f.raw("sel")?)?,
                core: CoreMethod::parse(f.raw("core")?)
                    .ok_or_else(|| protocol("unknown core method"))?,
                sketch: f.sketch("sketch")?,
                s_c: f.num("s_c")?,
                s_r: f.num("s_r")?,
            };
            let seed = f.num("seed")?;
            let a = read_payload(r, limits)?;
            Ok(ApproxJob::Cur { a, cfg, seed })
        }
        "cur_stream" => {
            let cfg = StreamingCurConfig {
                c: f.num("c")?,
                r: f.num("r")?,
                k: f.num("k")?,
                kind: f.sketch("sketch")?,
                s_c: f.num("s_c")?,
                s_r: f.num("s_r")?,
                oversample: f.num("oversample")?,
            };
            let block = f.num("block")?;
            let seed = f.num("seed")?;
            let a = read_payload(r, limits)?;
            Ok(ApproxJob::StreamingCur { a, cfg, block, seed })
        }
        other => Err(protocol(format!("unknown job kind `{other}`"))),
    }
}

// ---------------------------------------------------------------------
// Result frames
// ---------------------------------------------------------------------

/// Encode a completed result: `OK` header (kind, request trace id,
/// per-factor shapes, degraded marker) plus the word payload.
pub fn encode_result(result: &JobResult, trace_id: u64) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let shapes = result.output_shapes();
    let _ = write!(out, "OK {} trace={trace_id:016x} shapes=", result.kind());
    for (i, (r, c)) in shapes.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{r}x{c}");
    }
    if let JobResult::Degraded { est_rel_residual, .. } = result {
        let _ = write!(out, " degraded={:016x}", est_rel_residual.to_bits());
    }
    out.push('\n');
    push_words(&mut out, &result.to_words());
    out
}

/// Encode a failure as a one-line `ERR <code> <message>` frame.
pub fn encode_err(e: &FgError) -> String {
    let code = match e {
        FgError::Protocol(_) => "protocol",
        FgError::Overloaded { .. } => "overloaded",
        FgError::DeadlineExceeded { .. } => "deadline",
        FgError::CircuitOpen { .. } => "circuit_open",
        FgError::Coordinator(_) => "coordinator",
        FgError::Config(_) => "config",
        FgError::Data(_) => "data",
        FgError::ShapeMismatch { .. } => "shape",
        FgError::Io(_) => "io",
        _ => "runtime",
    };
    // The message must stay one line — the grammar is line-framed.
    let msg = e.to_string().replace('\n', " ");
    format!("ERR {code} {msg}\n")
}

/// Decode the response to a job frame: `Ok((result, trace_id))` on an
/// `OK` header, the transported error on an `ERR` header.
pub fn decode_response<R: Read>(
    r: &mut LineReader<R>,
    limits: &WireLimits,
) -> Result<(JobResult, u64)> {
    let header = r
        .read_line(limits.max_line_bytes)?
        .ok_or_else(|| protocol("connection closed before response"))?;
    let mut toks = header.split_whitespace();
    match toks.next() {
        Some("OK") => {}
        Some("ERR") => {
            let code = toks.next().unwrap_or("runtime");
            let msg: String = toks.collect::<Vec<_>>().join(" ");
            return Err(match code {
                "protocol" => FgError::Protocol(msg),
                "overloaded" => FgError::Overloaded { depth: 0 },
                "deadline" => FgError::DeadlineExceeded { waited_ms: 0 },
                "circuit_open" => FgError::CircuitOpen { kind: msg },
                "coordinator" => FgError::Coordinator(msg),
                "config" => FgError::Config(msg),
                "data" => FgError::Data(msg),
                _ => FgError::Runtime(msg),
            });
        }
        _ => return Err(protocol("expected OK or ERR response")),
    }
    let kind = toks.next().ok_or_else(|| protocol("OK response missing kind"))?.to_string();
    let mut trace_id = 0u64;
    let mut shapes: Vec<(usize, usize)> = Vec::new();
    let mut degraded: Option<f64> = None;
    for tok in toks {
        let (k, v) = tok.split_once('=').ok_or_else(|| protocol("bad OK field"))?;
        match k {
            "trace" => {
                trace_id =
                    u64::from_str_radix(v, 16).map_err(|_| protocol("bad trace id"))?;
            }
            "shapes" => {
                for s in v.split(',') {
                    let (rr, cc) =
                        s.split_once('x').ok_or_else(|| protocol("bad shape token"))?;
                    let rr: usize =
                        rr.parse().map_err(|_| protocol("bad shape rows"))?;
                    let cc: usize =
                        cc.parse().map_err(|_| protocol("bad shape cols"))?;
                    shapes.push((rr, cc));
                }
            }
            "degraded" => {
                degraded = Some(
                    u64::from_str_radix(v, 16)
                        .map(f64::from_bits)
                        .map_err(|_| protocol("bad degraded residual"))?,
                );
            }
            other => return Err(protocol(format!("unknown OK field `{other}`"))),
        }
    }
    let mut total: usize = 0;
    for (rr, cc) in &shapes {
        let n = rr.checked_mul(*cc).ok_or_else(|| protocol("shape overflow"))?;
        total = total.checked_add(n).ok_or_else(|| protocol("shape overflow"))?;
    }
    if kind == "spsd" {
        total += 1; // trailing entries_observed word
    }
    if total > limits.max_payload_words {
        return Err(protocol(format!(
            "result payload {total} exceeds {} word cap",
            limits.max_payload_words
        )));
    }
    let words = read_words(r, limits, total)?;
    let inner = JobResult::from_words(&kind, &shapes, &words)
        .ok_or_else(|| protocol("result words disagree with kind/shapes"))?;
    let result = match degraded {
        Some(est_rel_residual) => JobResult::Degraded { est_rel_residual, inner: Box::new(inner) },
        None => inner,
    };
    Ok((result, trace_id))
}
