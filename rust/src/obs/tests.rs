//! Tracing tests: disabled-path zero-allocation, Chrome-trace validity
//! (balanced B/E, monotone per-thread timestamps), and span nesting
//! pinned against the known phase structure of a CUR job.

use super::*;
use crate::testing::alloc_count::allocs_now;

#[test]
fn disabled_span_path_allocates_nothing() {
    install(None);
    // Warm the thread-local slot so lazy TLS setup is not charged to
    // the measured region.
    {
        let _warm = span("warm", cat::DISPATCH);
    }
    let before = allocs_now();
    for _ in 0..1000 {
        let mut sp = span("gmr.core.solve", cat::SOLVE);
        sp.meta("rows", 128usize);
        assert!(!sp.active());
    }
    let after = allocs_now();
    assert_eq!(after - before, 0, "disabled span path must not allocate");
}

#[test]
fn fresh_collector_exports_are_empty() {
    let tc = TraceCollector::new();
    assert!(tc.is_empty());
    assert_eq!(tc.to_chrome_json(), "{\"traceEvents\":[]}\n");
    assert_eq!(tc.to_jsonl(), "");
    assert!(tc.root_structures().is_empty());
    assert!(tc.seconds_by_category().is_empty());
}

#[test]
fn spans_nest_and_render_structure() {
    let tc = Arc::new(TraceCollector::new());
    install(Some(tc.clone()));
    {
        let _job = span("job", cat::DISPATCH);
        {
            let _a = span("a", cat::SKETCH);
            let _b = span("b", cat::SOLVE);
        }
        let _c = span("c", cat::GATHER);
    }
    install(None);
    // b opened inside a's lifetime, so it nests under a; c is a's
    // sibling under the root.
    assert_eq!(tc.root_structures(), vec!["job{a{b},c}".to_string()]);
    let spans = tc.spans();
    assert_eq!(spans.len(), 4);
    let job = spans.iter().find(|s| s.name == "job").unwrap();
    let a = spans.iter().find(|s| s.name == "a").unwrap();
    let b = spans.iter().find(|s| s.name == "b").unwrap();
    assert_eq!(job.parent, 0);
    assert_eq!(a.parent, job.id);
    assert_eq!(b.parent, a.id);
    // All on one installed thread.
    assert!(spans.iter().all(|s| s.tid == spans[0].tid));
    // Containment: children close no later than their parent closes.
    assert!(a.start_ns >= job.start_ns && a.end_ns <= job.end_ns);
    assert!(b.start_ns >= a.start_ns && b.end_ns <= a.end_ns);
}

/// The end-to-end tentpole check: a CUR job through the router yields
/// exactly the paper's phase tree — selection (with leverage-score
/// factorizations), then the Fast GMR core (sketch draw, sketch apply,
/// core solve) — nested under the dispatch root.
#[test]
fn router_traces_cur_job_phases() {
    use crate::coordinator::router::{Router, ServeConfig};
    use crate::coordinator::{ApproxJob, MatrixPayload};
    use crate::linalg::Mat;
    use crate::rng::rng;

    let trace = Arc::new(TraceCollector::new());
    let router = Router::with_config(&ServeConfig {
        workers: 1,
        trace: Some(trace.clone()),
        ..ServeConfig::service(1)
    });
    let mut r = rng(7);
    let a = Mat::randn(60, 40, &mut r);
    let job = ApproxJob::Cur {
        a: MatrixPayload::Dense(a),
        cfg: crate::cur::CurConfig::fast(6, 6, 3),
        seed: 3,
    };
    router.submit(job).unwrap().wait().unwrap();
    router.shutdown();

    let want = "router.dispatch{cur.select.columns{leverage.scores},\
                cur.select.rows{leverage.scores},\
                cur.core{gmr.sketch.draw,gmr.sketch.apply,gmr.core.solve}}"
        .replace(" ", "");
    assert_eq!(trace.root_structures(), vec![want]);

    // The root span carries the job's identity metadata.
    let spans = trace.spans();
    let root = spans.iter().find(|s| s.name == "router.dispatch").unwrap();
    let get = |key: &str| root.meta.iter().find(|(k, _)| *k == key).map(|(_, v)| *v);
    assert_eq!(get("kind"), Some(MetaValue::Label("cur")));
    assert_eq!(get("rows"), Some(MetaValue::Int(60)));
    assert_eq!(get("cols"), Some(MetaValue::Int(40)));
    // The sketch-apply span carries a flop estimate, so GFLOP/s derives.
    let apply = spans.iter().find(|s| s.name == "gmr.sketch.apply").unwrap();
    assert!(apply.meta.iter().any(|(k, _)| *k == "flops"));
}

/// Minimal field extractors for self-parsing the hand-rolled exports.
fn field_str<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":\"");
    let start = line.find(&pat)? + pat.len();
    let end = line[start..].find('"')?;
    Some(&line[start..start + end])
}

fn field_num(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find([',', '}']).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

#[test]
fn chrome_trace_is_balanced_with_monotone_timestamps_per_thread() {
    let tc = Arc::new(TraceCollector::new());
    install(Some(tc.clone()));
    {
        let _job = span("job", cat::DISPATCH);
        let _inner = span("job.solve", cat::SOLVE);
    }
    // A second traced thread interleaves with the first in the sink.
    let tc2 = tc.clone();
    std::thread::spawn(move || {
        install(Some(tc2));
        let _other = span("other", cat::STREAM);
    })
    .join()
    .unwrap();
    install(None);

    let json = tc.to_chrome_json();
    assert!(json.starts_with("{\"traceEvents\":["));
    assert!(json.trim_end().ends_with("]}"));
    let lines: Vec<&str> = json.lines().filter(|l| l.contains("\"ph\"")).collect();
    assert_eq!(lines.len(), 6, "3 spans -> 3 B + 3 E events");
    // Per-thread: phases balance as a stack and timestamps never go
    // backwards — exactly what chrome://tracing requires to load.
    let mut stacks: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    let mut last_ts: BTreeMap<u64, f64> = BTreeMap::new();
    for line in &lines {
        let name = field_str(line, "name").unwrap().to_string();
        let ph = field_str(line, "ph").unwrap();
        let tid = field_num(line, "tid").unwrap() as u64;
        let ts = field_num(line, "ts").unwrap();
        assert_eq!(field_num(line, "pid"), Some(1.0));
        let prev = last_ts.entry(tid).or_insert(0.0);
        assert!(ts >= *prev, "timestamps must be monotone per thread: {line}");
        *prev = ts;
        let stack = stacks.entry(tid).or_default();
        match ph {
            "B" => stack.push(name),
            "E" => assert_eq!(stack.pop().as_deref(), Some(name.as_str()), "unbalanced: {line}"),
            other => panic!("unexpected phase {other}"),
        }
    }
    for (tid, stack) in &stacks {
        assert!(stack.is_empty(), "tid {tid} left unbalanced B events: {stack:?}");
    }
}

#[test]
fn jsonl_export_carries_meta_and_derived_gflops() {
    let tc = Arc::new(TraceCollector::new());
    install(Some(tc.clone()));
    {
        let mut sp = span("gmr.sketch.apply", cat::SKETCH);
        sp.meta("flops", 2.0e6);
        sp.meta("m", 100usize);
        sp.meta("method", "gaussian");
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    install(None);
    let jsonl = tc.to_jsonl();
    let line = jsonl.lines().next().unwrap();
    assert_eq!(field_str(line, "name"), Some("gmr.sketch.apply"));
    assert_eq!(field_str(line, "cat"), Some("sketch"));
    assert_eq!(field_str(line, "method"), Some("gaussian"));
    assert_eq!(field_num(line, "m"), Some(100.0));
    assert_eq!(field_num(line, "parent"), Some(0.0));
    let dur = field_num(line, "dur_us").unwrap();
    assert!(dur >= 2000.0, "2 ms sleep must show in dur_us: {dur}");
    let gflops = field_num(line, "gflops").unwrap();
    let expect = 2.0e6 / (dur * 1e-6) / 1e9;
    assert!((gflops - expect).abs() / expect < 1e-3, "gflops {gflops} vs {expect}");
    // Self-time attribution sums to the span's own duration.
    let by_cat = tc.seconds_by_category();
    assert!((by_cat["sketch"] - dur * 1e-6).abs() < 1e-9);
}
