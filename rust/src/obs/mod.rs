//! Span-based tracing for the serving daemon and the approximation
//! pipelines.
//!
//! The paper's Fast GMR algorithms decompose into distinct stages —
//! sketch draw, sketch apply, core solve — and the serving layer adds
//! its own (dispatch, cache, fan-out). This module makes those stage
//! boundaries first-class: a [`TraceCollector`] records job-scoped span
//! trees with per-span metadata (shapes, sketch sizes, flop estimates),
//! exportable as Chrome trace-event JSON (`chrome://tracing`, Perfetto)
//! or line-oriented JSONL.
//!
//! Design constraints, in order:
//!
//! 1. **Zero-cost when disabled.** Instrumented code calls [`span`]
//!    unconditionally; with no collector installed on the thread it does
//!    one thread-local borrow, allocates nothing, and returns an inert
//!    guard. The disabled path is pinned by an allocation-counting test.
//! 2. **Deterministic structure.** Span *trees* (names + nesting) must
//!    be identical at any `threads` knob setting, so tracing folds into
//!    the global determinism test. Spans are therefore only opened on
//!    sequential driver/executor threads — never inside pool workers —
//!    and never keyed on anything timing-dependent.
//! 3. **No dependencies.** Like the rest of the crate, the exporters
//!    hand-roll their JSON.
//!
//! # Usage
//!
//! ```
//! use fastgmr::obs::{self, TraceCollector};
//! use std::sync::Arc;
//!
//! let trace = Arc::new(TraceCollector::new());
//! obs::install(Some(trace.clone()));
//! {
//!     let mut root = obs::span("job", obs::cat::DISPATCH);
//!     root.meta("rows", 128usize);
//!     let _child = obs::span("job.phase", obs::cat::SOLVE);
//! }
//! obs::install(None);
//! assert_eq!(trace.root_structures(), vec!["job{job.phase}".to_string()]);
//! let json = trace.to_chrome_json();
//! assert!(json.contains("\"ph\":\"B\""));
//! ```

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

#[cfg(test)]
mod tests;

/// Span categories — coarse phase classes used for per-category time
/// attribution (`fig_serve` phase shares) and Chrome trace colouring.
pub mod cat {
    /// Router dispatch / job-scoped root spans.
    pub const DISPATCH: &str = "dispatch";
    /// Sketch draw + sketch apply (the paper's compression stage).
    pub const SKETCH: &str = "sketch";
    /// Dense factorizations: QR, SVD, eigendecomposition, PSD project.
    pub const FACTORIZE: &str = "factorize";
    /// Core solves: pseudoinverse applies producing the small core.
    pub const SOLVE: &str = "solve";
    /// Row/column selection and gathers.
    pub const GATHER: &str = "gather";
    /// Streaming block ingestion.
    pub const STREAM: &str = "stream";
    /// Artifact-cache persistence and warm start.
    pub const CACHE: &str = "cache";
    /// Wire front-end request handling.
    pub const NET: &str = "net";
}

/// A metadata value attached to a span. Only cheap, statically-named
/// payloads — no owned strings on the span path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MetaValue {
    /// Integer payload (shapes, sketch sizes, counts).
    Int(u64),
    /// Float payload (flop estimates).
    Float(f64),
    /// Static label (job kind, core method).
    Label(&'static str),
}

impl From<u64> for MetaValue {
    fn from(v: u64) -> Self {
        MetaValue::Int(v)
    }
}

impl From<usize> for MetaValue {
    fn from(v: usize) -> Self {
        MetaValue::Int(v as u64)
    }
}

impl From<f64> for MetaValue {
    fn from(v: f64) -> Self {
        MetaValue::Float(v)
    }
}

impl From<&'static str> for MetaValue {
    fn from(v: &'static str) -> Self {
        MetaValue::Label(v)
    }
}

/// One completed span: a named interval with parent/child nesting,
/// recording thread id and metadata.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Collector-unique id (> 0).
    pub id: u64,
    /// Parent span id, or 0 for a root.
    pub parent: u64,
    /// Static span name, dot-separated by convention
    /// (`gmr.sketch.apply`).
    pub name: &'static str,
    /// Category from [`cat`].
    pub cat: &'static str,
    /// Collector-scoped thread id (dense, starting at 0).
    pub tid: u32,
    /// Start offset from the collector epoch, nanoseconds.
    pub start_ns: u64,
    /// End offset from the collector epoch, nanoseconds.
    pub end_ns: u64,
    /// Metadata key/value pairs in attachment order.
    pub meta: Vec<(&'static str, MetaValue)>,
}

impl SpanRecord {
    /// Span duration in seconds.
    pub fn seconds(&self) -> f64 {
        self.end_ns.saturating_sub(self.start_ns) as f64 / 1e9
    }

    /// Derived GFLOP/s when the span carries a `flops` estimate and a
    /// positive duration.
    pub fn gflops(&self) -> Option<f64> {
        let secs = self.seconds();
        if secs <= 0.0 {
            return None;
        }
        self.meta.iter().find(|(k, _)| *k == "flops").map(|(_, v)| {
            let flops = match v {
                MetaValue::Int(x) => *x as f64,
                MetaValue::Float(x) => *x,
                MetaValue::Label(_) => 0.0,
            };
            flops / secs / 1e9
        })
    }
}

/// Thread-safe span sink. One collector per traced workload; threads
/// participate by [`install`]ing an `Arc` handle, and completed spans
/// are appended under a single mutex at span *close* (one lock per
/// span, nothing on open).
pub struct TraceCollector {
    epoch: Instant,
    next_id: AtomicU64,
    next_tid: AtomicU32,
    spans: Mutex<Vec<SpanRecord>>,
}

impl std::fmt::Debug for TraceCollector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceCollector").field("spans", &self.len()).finish()
    }
}

impl Default for TraceCollector {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceCollector {
    /// New empty collector; its epoch (timestamp zero) is now.
    pub fn new() -> Self {
        TraceCollector {
            epoch: Instant::now(),
            next_id: AtomicU64::new(1),
            next_tid: AtomicU32::new(0),
            spans: Mutex::new(Vec::new()),
        }
    }

    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn alloc_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    fn alloc_tid(&self) -> u32 {
        self.next_tid.fetch_add(1, Ordering::Relaxed)
    }

    fn record(&self, span: SpanRecord) {
        self.spans.lock().unwrap().push(span);
    }

    /// Snapshot of all completed spans (unordered — threads race to the
    /// sink; exporters sort).
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.spans.lock().unwrap().clone()
    }

    /// Number of completed spans.
    pub fn len(&self) -> usize {
        self.spans.lock().unwrap().len()
    }

    /// True when no span has completed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Chrome trace-event JSON (`{"traceEvents": [...]}` with duration
    /// `B`/`E` pairs, timestamps in microseconds). Events are emitted by
    /// depth-first walk over the span forest, so B/E events are balanced
    /// per thread by construction and loadable in `chrome://tracing` or
    /// Perfetto.
    pub fn to_chrome_json(&self) -> String {
        let spans = self.spans();
        let order = sorted_forest(&spans);
        let mut events = Vec::new();
        for root in &order.roots {
            emit_chrome(&spans, &order.children, *root, &mut events);
        }
        if events.is_empty() {
            return "{\"traceEvents\":[]}\n".to_string();
        }
        format!("{{\"traceEvents\":[\n{}\n]}}\n", events.join(",\n"))
    }

    /// Line-oriented JSONL export: one span per line, sorted by start
    /// time, with derived `gflops` when the span carries a flop
    /// estimate. Friendlier to `grep`/`jq` pipelines than the Chrome
    /// format.
    pub fn to_jsonl(&self) -> String {
        let mut spans = self.spans();
        spans.sort_by_key(|s| (s.start_ns, s.id));
        let mut out = String::new();
        for s in &spans {
            out.push_str(&format!(
                "{{\"id\":{},\"parent\":{},\"name\":\"{}\",\"cat\":\"{}\",\"tid\":{},\
                 \"ts_us\":{:.3},\"dur_us\":{:.3}",
                s.id,
                s.parent,
                s.name,
                s.cat,
                s.tid,
                s.start_ns as f64 / 1e3,
                s.end_ns.saturating_sub(s.start_ns) as f64 / 1e3
            ));
            if let Some(g) = s.gflops() {
                out.push_str(&format!(",\"gflops\":{}", format_f64(g)));
            }
            for (k, v) in &s.meta {
                out.push_str(&format!(",\"{}\":{}", k, json_value(*v)));
            }
            out.push_str("}\n");
        }
        out
    }

    /// Canonical structure strings for every root span: `name{c1,c2}`
    /// with children in start order, rendered recursively and sorted.
    /// Timing-free, so equal across thread counts — the determinism
    /// test compares these.
    pub fn root_structures(&self) -> Vec<String> {
        let spans = self.spans();
        let order = sorted_forest(&spans);
        let mut out: Vec<String> =
            order.roots.iter().map(|r| render_structure(&spans, &order.children, *r)).collect();
        out.sort();
        out
    }

    /// Self-time (own duration minus direct children) summed per
    /// category, in seconds. The basis for `fig_serve`'s per-phase
    /// attribution shares.
    pub fn seconds_by_category(&self) -> BTreeMap<&'static str, f64> {
        let spans = self.spans();
        let order = sorted_forest(&spans);
        let mut by_cat: BTreeMap<&'static str, f64> = BTreeMap::new();
        for (i, s) in spans.iter().enumerate() {
            let child_ns: u64 = order
                .children
                .get(&s.id)
                .map(|c| {
                    c.iter().map(|&j| spans[j].end_ns.saturating_sub(spans[j].start_ns)).sum()
                })
                .unwrap_or(0);
            let own = spans[i].end_ns.saturating_sub(spans[i].start_ns).saturating_sub(child_ns);
            *by_cat.entry(s.cat).or_insert(0.0) += own as f64 / 1e9;
        }
        by_cat
    }
}

/// Deterministically ordered view of the span forest: root indices
/// sorted by (tid, start, id) and a children map sorted by (start, id).
struct Forest {
    roots: Vec<usize>,
    children: BTreeMap<u64, Vec<usize>>,
}

fn sorted_forest(spans: &[SpanRecord]) -> Forest {
    let mut roots = Vec::new();
    let mut children: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for (i, s) in spans.iter().enumerate() {
        if s.parent == 0 {
            roots.push(i);
        } else {
            children.entry(s.parent).or_default().push(i);
        }
    }
    roots.sort_by_key(|&i| (spans[i].tid, spans[i].start_ns, spans[i].id));
    for c in children.values_mut() {
        c.sort_by_key(|&i| (spans[i].start_ns, spans[i].id));
    }
    Forest { roots, children }
}

fn emit_chrome(
    spans: &[SpanRecord],
    children: &BTreeMap<u64, Vec<usize>>,
    i: usize,
    events: &mut Vec<String>,
) {
    let s = &spans[i];
    events.push(format!(
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"B\",\"ts\":{:.3},\"pid\":1,\"tid\":{}}}",
        s.name,
        s.cat,
        s.start_ns as f64 / 1e3,
        s.tid
    ));
    if let Some(kids) = children.get(&s.id) {
        for &k in kids {
            emit_chrome(spans, children, k, events);
        }
    }
    let mut end = format!(
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"E\",\"ts\":{:.3},\"pid\":1,\"tid\":{}",
        s.name,
        s.cat,
        s.end_ns as f64 / 1e3,
        s.tid
    );
    if !s.meta.is_empty() {
        end.push_str(",\"args\":{");
        for (j, (k, v)) in s.meta.iter().enumerate() {
            if j > 0 {
                end.push(',');
            }
            end.push_str(&format!("\"{}\":{}", k, json_value(*v)));
        }
        end.push('}');
    }
    end.push('}');
    events.push(end);
}

fn render_structure(
    spans: &[SpanRecord],
    children: &BTreeMap<u64, Vec<usize>>,
    i: usize,
) -> String {
    let s = &spans[i];
    match children.get(&s.id) {
        None => s.name.to_string(),
        Some(kids) => {
            let inner: Vec<String> =
                kids.iter().map(|&k| render_structure(spans, children, k)).collect();
            format!("{}{{{}}}", s.name, inner.join(","))
        }
    }
}

fn json_value(v: MetaValue) -> String {
    match v {
        MetaValue::Int(x) => x.to_string(),
        MetaValue::Float(x) => format_f64(x),
        MetaValue::Label(x) => format!("\"{x}\""),
    }
}

fn format_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "null".to_string()
    }
}

// ---- thread-local span context --------------------------------------

struct ThreadCtx {
    collector: Arc<TraceCollector>,
    tid: u32,
    stack: Vec<u64>,
}

thread_local! {
    static CTX: RefCell<Option<ThreadCtx>> = const { RefCell::new(None) };
}

/// Install (or clear, with `None`) the trace collector for the current
/// thread. Router executors install the shared collector at spawn;
/// CLI drivers install it around the traced region. Installing does
/// not affect other threads, and spans opened on a thread without a
/// collector are silently inert.
pub fn install(collector: Option<Arc<TraceCollector>>) {
    CTX.with(|ctx| {
        *ctx.borrow_mut() = collector.map(|c| {
            let tid = c.alloc_tid();
            ThreadCtx { collector: c, tid, stack: Vec::new() }
        });
    });
}

/// True when a collector is installed on this thread.
pub fn enabled() -> bool {
    CTX.with(|ctx| ctx.borrow().is_some())
}

/// Open a span. With no collector installed this is one thread-local
/// borrow and returns an inert guard — no allocation, no clock read.
/// The span closes (and is recorded) when the guard drops, so bind it
/// to a named variable (`let _sp = ...`), never `_`.
pub fn span(name: &'static str, category: &'static str) -> SpanGuard {
    CTX.with(|ctx| {
        let mut ctx = ctx.borrow_mut();
        let Some(tc) = ctx.as_mut() else {
            return SpanGuard { open: None };
        };
        let id = tc.collector.alloc_id();
        let parent = tc.stack.last().copied().unwrap_or(0);
        tc.stack.push(id);
        let start_ns = tc.collector.now_ns();
        SpanGuard {
            open: Some(OpenSpan {
                collector: tc.collector.clone(),
                id,
                parent,
                name,
                cat: category,
                tid: tc.tid,
                start_ns,
                meta: Vec::new(),
            }),
        }
    })
}

struct OpenSpan {
    collector: Arc<TraceCollector>,
    id: u64,
    parent: u64,
    name: &'static str,
    cat: &'static str,
    tid: u32,
    start_ns: u64,
    meta: Vec<(&'static str, MetaValue)>,
}

/// RAII guard for an open span; records the span on drop. Inert (all
/// methods no-ops) when tracing is disabled.
pub struct SpanGuard {
    open: Option<OpenSpan>,
}

impl SpanGuard {
    /// Attach a metadata key/value pair. No-op when inert — guard meta
    /// computations behind [`SpanGuard::active`] if they are not free.
    pub fn meta(&mut self, key: &'static str, value: impl Into<MetaValue>) {
        if let Some(open) = self.open.as_mut() {
            open.meta.push((key, value.into()));
        }
    }

    /// True when this guard belongs to an installed collector.
    pub fn active(&self) -> bool {
        self.open.is_some()
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(open) = self.open.take() else { return };
        let end_ns = open.collector.now_ns();
        CTX.with(|ctx| {
            if let Some(tc) = ctx.borrow_mut().as_mut() {
                // Pop through any spans abandoned by panic unwinds so
                // the stack stays consistent with recorded nesting.
                while let Some(top) = tc.stack.pop() {
                    if top == open.id {
                        break;
                    }
                }
            }
        });
        open.collector.record(SpanRecord {
            id: open.id,
            parent: open.parent,
            name: open.name,
            cat: open.cat,
            tid: open.tid,
            start_ns: open.start_ns,
            end_ns,
            meta: open.meta,
        });
    }
}
