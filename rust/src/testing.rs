//! Tiny property-testing harness (no proptest in the offline vendor set).
//!
//! Provides seeded random-case generation with failure reporting that
//! includes the case seed, so any failing property is reproducible by
//! construction.

use crate::linalg::Mat;
use crate::rng::{rng, Pcg64};

/// Default dimension cap for "small" property matrices.
pub const MAT_DIM_SMALL: usize = 24;

/// Allocation counter shared by every zero-overhead test in the crate
/// (`obs` disabled spans, `faults` disabled trips). Rust allows exactly
/// one `#[global_allocator]` per binary, so it lives here rather than in
/// any single module's tests.
#[cfg(test)]
pub mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    /// Counting wrapper around the system allocator. The count is
    /// per-thread so parallel test threads don't pollute each other;
    /// `try_with` keeps allocation during thread teardown safe.
    struct CountingAlloc;

    thread_local! {
        static ALLOCS: Cell<u64> = const { Cell::new(0) };
    }

    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
            System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
            System.realloc(ptr, layout, new_size)
        }
    }

    #[global_allocator]
    static GLOBAL: CountingAlloc = CountingAlloc;

    /// Allocations observed on the current thread so far.
    pub fn allocs_now() -> u64 {
        ALLOCS.with(|c| c.get())
    }
}

/// Assert two matrices are elementwise close (absolute + relative blend).
#[track_caller]
pub fn assert_close(got: &Mat, want: &Mat, tol: f64, context: &str) {
    assert_eq!(got.shape(), want.shape(), "{context}: shape mismatch");
    let scale = want.max_abs().max(1.0);
    for i in 0..got.rows() {
        for j in 0..got.cols() {
            let d = (got[(i, j)] - want[(i, j)]).abs();
            assert!(
                d <= tol * scale,
                "{context}: mismatch at ({i},{j}): got {} want {} (|d|={d}, tol*scale={})",
                got[(i, j)],
                want[(i, j)],
                tol * scale
            );
        }
    }
}

/// Assert two scalars are close.
#[track_caller]
pub fn assert_scalar_close(got: f64, want: f64, tol: f64, context: &str) {
    let d = (got - want).abs();
    let scale = want.abs().max(1.0);
    assert!(d <= tol * scale, "{context}: got {got} want {want} (|d|={d})");
}

/// Statistical acceptance harness for the ε-planner: run `trials`
/// fixed-seed instances of a planned job and require that at least
/// `min_pass` of them (≥90% in the crate's acceptance tests) both
/// *certify* attainment and *actually* achieve
/// `achieved ≤ (1+eps)·optimum` against an exactly-computed optimum.
///
/// The closure receives the per-trial seed and returns
/// `(achieved, optimum, attained)` — the true residual of the planned
/// solution, the true optimal residual for the same factors, and the
/// planner's own certificate. Failing seeds are listed in the panic
/// message so any regression is reproducible by construction.
#[track_caller]
pub fn assert_attains_epsilon(
    job: &str,
    eps: f64,
    trials: usize,
    min_pass: usize,
    mut trial: impl FnMut(u64) -> (f64, f64, bool),
) {
    assert!(min_pass <= trials, "{job}: min_pass {min_pass} > trials {trials}");
    let mut failed: Vec<String> = Vec::new();
    for t in 0..trials {
        let seed = 0xacce_0000 + t as u64;
        let (achieved, optimum, attained) = trial(seed);
        let within = achieved <= (1.0 + eps) * optimum + 1e-9 * (1.0 + optimum);
        if !(attained && within) {
            failed.push(format!(
                "seed {seed:#x}: achieved {achieved:.6} vs (1+{eps})·{optimum:.6}, certified={attained}"
            ));
        }
    }
    let passed = trials - failed.len();
    assert!(
        passed >= min_pass,
        "{job}: ε={eps} attained in {passed}/{trials} trials (need {min_pass}):\n  {}",
        failed.join("\n  ")
    );
}

/// Run `cases` random property checks over a random matrix with dims in
/// `1..=max_dim`. The closure receives the matrix and a per-case rng.
/// Panics (from the closure's asserts) are annotated with the case seed.
pub fn prop_mats(cases: usize, max_dim: usize, mut check: impl FnMut(&Mat, &mut Pcg64)) {
    for case in 0..cases {
        let seed = 0x5eed_0000 + case as u64;
        let mut r = rng(seed);
        let m = 1 + r.next_range(max_dim);
        let n = 1 + r.next_range(max_dim);
        let a = Mat::randn(m, n, &mut r);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(&a, &mut r);
        }));
        if let Err(e) = result {
            eprintln!("property failed on case {case} (seed {seed:#x}, shape {m}x{n})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Run `cases` checks over (m, n, k)-indexed closures with a seeded rng
/// and custom generation. Generic scaffold for non-matrix properties.
pub fn prop_cases(cases: usize, mut check: impl FnMut(u64, &mut Pcg64)) {
    for case in 0..cases {
        let seed = 0xabcd_0000 + case as u64;
        let mut r = rng(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(case as u64, &mut r);
        }));
        if let Err(e) = result {
            eprintln!("property failed on case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}
