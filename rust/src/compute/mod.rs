//! Compute backends.
//!
//! Two interchangeable implementations of the fixed-shape hot-path
//! operations sit behind [`Backend`]:
//!
//! * [`CpuBackend`] — pure Rust (the linalg substrate); arbitrary shapes,
//!   input-sparsity-aware upstream.
//! * [`PjrtBackend`] — executes the AOT JAX/Pallas artifacts through the
//!   PJRT runtime; fixed tile shapes with zero-padding at the edges
//!   (padding is exact for these linear/elementwise ops).
//!
//! The coordinator picks a backend at startup; examples/benches compare
//! the two for both numerics (they must agree) and throughput.

mod cpu;
mod pjrt;

pub use cpu::CpuBackend;
pub use pjrt::PjrtBackend;

use crate::error::Result;
use crate::linalg::Mat;
use crate::parallel::Pool;

/// Fixed-shape hot-path operations.
///
/// Not `Send`/`Sync`: the PJRT client is single-threaded by construction
/// (the `xla` crate wraps an `Rc` handle), so each coordinator thread
/// owns its backend instance; the CPU backend is trivially cloneable.
pub trait Backend {
    /// Human-readable name for logs/metrics.
    fn name(&self) -> &'static str;

    /// Worker pool the backend's sharded hot loops run on. The default
    /// is the calling thread's effective budget: the process-wide
    /// `threads` knob capped by any per-executor budget installed with
    /// [`crate::parallel::set_thread_budget`] (the router does this for
    /// its executor threads, so `N_workers × threads` never
    /// oversubscribes the machine).
    fn pool(&self) -> Pool {
        Pool::current()
    }

    /// Dense product `S · A` (the sketch-apply hot spot).
    fn sketch_apply(&self, s: &Mat, a: &Mat) -> Result<Mat>;

    /// RBF kernel block: `K[I,J] = exp(−σ‖x_i − x_j‖²)` from row blocks
    /// `xi` (bi×d) and `xj` (bj×d).
    fn rbf_block(&self, xi: &Mat, xj: &Mat, sigma: f64) -> Result<Mat>;

    /// Two-sided sketch of a column block: `(S_C · A_L) · S_Rᵀ`.
    fn twoside_sketch(&self, sc: &Mat, a_l: &Mat, sr: &Mat) -> Result<Mat>;

    /// Streaming SP-SVD block update (Algorithm 3 steps 6–8), returning
    /// (C_delta, R_block, M_delta) for the coordinator to accumulate:
    /// C_delta = A_L·Ωᵀ, R_block = Ψ·A_L, M_delta = (S_C A_L) S_Rᵀ.
    fn stream_update(&self, a_l: &Mat, omega_t: &Mat, psi: &Mat, sc: &Mat, sr: &Mat)
        -> Result<(Mat, Mat, Mat)>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_a_bt, Mat};
    use crate::rng::rng;
    use crate::testing::assert_close;

    #[test]
    fn cpu_backend_matches_reference() {
        let be = CpuBackend;
        let mut r = rng(1);
        let s = Mat::randn(8, 20, &mut r);
        let a = Mat::randn(20, 12, &mut r);
        let got = be.sketch_apply(&s, &a).unwrap();
        assert_close(&got, &matmul(&s, &a), 1e-12, "sketch_apply");

        let xi = Mat::randn(6, 4, &mut r);
        let xj = Mat::randn(5, 4, &mut r);
        let k = be.rbf_block(&xi, &xj, 0.3).unwrap();
        for i in 0..6 {
            for j in 0..5 {
                let mut d2 = 0.0;
                for t in 0..4 {
                    let d = xi[(i, t)] - xj[(j, t)];
                    d2 += d * d;
                }
                assert!((k[(i, j)] - (-0.3 * d2).exp()).abs() < 1e-12);
            }
        }

        let sc = Mat::randn(7, 20, &mut r);
        let sr = Mat::randn(9, 12, &mut r);
        let al = Mat::randn(20, 12, &mut r);
        let two = be.twoside_sketch(&sc, &al, &sr).unwrap();
        let want = matmul_a_bt(&matmul(&sc, &al), &sr);
        assert_close(&two, &want, 1e-12, "twoside");

        let om_t = Mat::randn(12, 5, &mut r); // Ωᵀ slice: L x c
        let psi = Mat::randn(4, 20, &mut r);
        let (c_d, r_b, m_d) = be.stream_update(&al, &om_t, &psi, &sc, &sr).unwrap();
        assert_close(&c_d, &matmul(&al, &om_t), 1e-12, "stream C");
        assert_close(&r_b, &matmul(&psi, &al), 1e-12, "stream R");
        assert_close(&m_d, &want, 1e-12, "stream M");
    }
}
