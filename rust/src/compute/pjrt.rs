//! PJRT compute backend — runs the AOT JAX/Pallas artifacts.
//!
//! Artifacts have fixed shapes (PJRT requires static shapes); this
//! backend pads inputs with zeros up to the artifact tile and trims the
//! outputs. Padding is exact for every op here: they are linear in A (or,
//! for `rbf_block`, the padded rows are simply discarded).
//!
//! Artifact naming convention (see `python/compile/aot.py`):
//! `sketch_SxMxN`, `rbf_BIxBJxD`, `twoside_SCxMxLxSR`,
//! `stream_MxLxCxRxSCxSR` — the manifest carries the shapes, so this
//! backend just looks for a tile big enough and pads.

use super::Backend;
use crate::error::{FgError, Result};
use crate::linalg::Mat;
use crate::runtime::Engine;
use std::sync::Arc;

/// Backend that dispatches to AOT artifacts through the PJRT engine.
pub struct PjrtBackend {
    engine: Arc<Engine>,
}

impl PjrtBackend {
    pub fn new(engine: Arc<Engine>) -> Self {
        Self { engine }
    }

    /// Find an artifact whose name starts with `prefix` and whose input
    /// shapes (given by the first input) can contain (r, c).
    fn find_tile(&self, prefix: &str, need: &[(usize, usize)]) -> Result<String> {
        let mut best: Option<(String, usize)> = None;
        'outer: for name in self.engine.manifest().names() {
            if !name.starts_with(prefix) {
                continue;
            }
            let entry = self.engine.manifest().get(name)?;
            if entry.input_shapes.len() != need.len() {
                continue;
            }
            let mut area = 0usize;
            for (&(ar, ac), &(nr, nc)) in entry.input_shapes.iter().zip(need) {
                if ar < nr || ac < nc {
                    continue 'outer;
                }
                area += ar * ac;
            }
            if best.as_ref().map(|(_, a)| area < *a).unwrap_or(true) {
                best = Some((name.to_string(), area));
            }
        }
        best.map(|(n, _)| n).ok_or_else(|| FgError::ArtifactMissing {
            name: format!("{prefix}* covering {need:?}"),
            dir: self.engine.manifest().dir.display().to_string(),
        })
    }

    fn pad_to(mat: &Mat, r: usize, c: usize) -> Mat {
        if mat.shape() == (r, c) {
            return mat.clone();
        }
        let mut out = Mat::zeros(r, c);
        out.set_block(0, 0, mat);
        out
    }

    fn run_padded(&self, name: &str, inputs: &[&Mat], trim: &[(usize, usize)]) -> Result<Vec<Mat>> {
        let graph = self.engine.load(name)?;
        let padded: Vec<Mat> = inputs
            .iter()
            .zip(&graph.entry.input_shapes)
            .map(|(m, &(r, c))| Self::pad_to(m, r, c))
            .collect();
        let refs: Vec<&Mat> = padded.iter().collect();
        let outs = graph.run(&refs)?;
        Ok(outs
            .into_iter()
            .zip(trim)
            .map(|(o, &(r, c))| if o.shape() == (r, c) { o } else { o.slice(0, r, 0, c) })
            .collect())
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn sketch_apply(&self, s: &Mat, a: &Mat) -> Result<Mat> {
        let name = self.find_tile("sketch", &[s.shape(), a.shape()])?;
        let mut out = self.run_padded(&name, &[s, a], &[(s.rows(), a.cols())])?;
        Ok(out.remove(0))
    }

    fn rbf_block(&self, xi: &Mat, xj: &Mat, sigma: f64) -> Result<Mat> {
        let name = self.find_tile("rbf", &[xi.shape(), xj.shape(), (1, 1)])?;
        let sig = Mat::from_vec(1, 1, vec![sigma]);
        let mut out = self.run_padded(&name, &[xi, xj, &sig], &[(xi.rows(), xj.rows())])?;
        Ok(out.remove(0))
    }

    fn twoside_sketch(&self, sc: &Mat, a_l: &Mat, sr: &Mat) -> Result<Mat> {
        let name = self.find_tile("twoside", &[sc.shape(), a_l.shape(), sr.shape()])?;
        let mut out = self.run_padded(&name, &[sc, a_l, sr], &[(sc.rows(), sr.rows())])?;
        Ok(out.remove(0))
    }

    fn stream_update(
        &self,
        a_l: &Mat,
        omega_t: &Mat,
        psi: &Mat,
        sc: &Mat,
        sr: &Mat,
    ) -> Result<(Mat, Mat, Mat)> {
        let name = self.find_tile(
            "stream",
            &[a_l.shape(), omega_t.shape(), psi.shape(), sc.shape(), sr.shape()],
        )?;
        let trims = [
            (a_l.rows(), omega_t.cols()),
            (psi.rows(), a_l.cols()),
            (sc.rows(), sr.rows()),
        ];
        let mut out = self.run_padded(&name, &[a_l, omega_t, psi, sc, sr], &trims)?;
        let m_delta = out.remove(2);
        let r_block = out.remove(1);
        let c_delta = out.remove(0);
        Ok((c_delta, r_block, m_delta))
    }
}
