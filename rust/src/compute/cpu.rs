//! Pure-Rust compute backend (reference + fallback).
//!
//! Hot-path products go through `linalg`'s size-gated parallel dispatch
//! (row-panel sharding on the `crate::parallel` pool above
//! `PAR_FLOP_MIN`, serial below — so tiny remainder tiles never pay
//! thread-spawn overhead), and the RBF exponential pass shards over
//! output rows with the same work gate. Row-panel sharding is bitwise
//! equal to the serial kernels for any thread count, and `threads = 1`
//! reproduces the original single-threaded results bitwise.

use super::Backend;
use crate::error::Result;
use crate::linalg::{matmul, matmul_a_bt, Mat};
use crate::parallel::Pool;

/// Backend backed by the crate's own linalg substrate.
pub struct CpuBackend;

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn sketch_apply(&self, s: &Mat, a: &Mat) -> Result<Mat> {
        Ok(matmul(s, a))
    }

    fn rbf_block(&self, xi: &Mat, xj: &Mat, sigma: f64) -> Result<Mat> {
        let ni = xi.row_norms_sq();
        let nj = xj.row_norms_sq();
        let cross = matmul_a_bt(xi, xj);
        let (rows, cols) = (xi.rows(), xj.rows());
        let mut out = Mat::zeros(rows, cols);
        let exp_pool = if rows * cols >= crate::parallel::PAR_MIN_WORK {
            self.pool()
        } else {
            Pool::new(1)
        };
        exp_pool.run_row_panels(rows, cols, out.data_mut(), |r0, r1, panel| {
            for i in r0..r1 {
                let crow = cross.row(i);
                let orow = &mut panel[(i - r0) * cols..(i - r0 + 1) * cols];
                for j in 0..cols {
                    let d2 = (ni[i] + nj[j] - 2.0 * crow[j]).max(0.0);
                    orow[j] = (-sigma * d2).exp();
                }
            }
        });
        Ok(out)
    }

    fn twoside_sketch(&self, sc: &Mat, a_l: &Mat, sr: &Mat) -> Result<Mat> {
        Ok(matmul_a_bt(&matmul(sc, a_l), sr))
    }

    fn stream_update(
        &self,
        a_l: &Mat,
        omega_t: &Mat,
        psi: &Mat,
        sc: &Mat,
        sr: &Mat,
    ) -> Result<(Mat, Mat, Mat)> {
        let c_delta = matmul(a_l, omega_t);
        let r_block = matmul(psi, a_l);
        let m_delta = matmul_a_bt(&matmul(sc, a_l), sr);
        Ok((c_delta, r_block, m_delta))
    }
}
