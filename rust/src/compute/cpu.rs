//! Pure-Rust compute backend (reference + fallback).

use super::Backend;
use crate::error::Result;
use crate::linalg::{matmul, matmul_a_bt, Mat};

/// Backend backed by the crate's own linalg substrate.
pub struct CpuBackend;

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn sketch_apply(&self, s: &Mat, a: &Mat) -> Result<Mat> {
        Ok(matmul(s, a))
    }

    fn rbf_block(&self, xi: &Mat, xj: &Mat, sigma: f64) -> Result<Mat> {
        let ni = xi.row_norms_sq();
        let nj = xj.row_norms_sq();
        let cross = matmul_a_bt(xi, xj);
        let mut out = Mat::zeros(xi.rows(), xj.rows());
        for i in 0..xi.rows() {
            let crow = cross.row(i);
            let orow = out.row_mut(i);
            for j in 0..xj.rows() {
                let d2 = (ni[i] + nj[j] - 2.0 * crow[j]).max(0.0);
                orow[j] = (-sigma * d2).exp();
            }
        }
        Ok(out)
    }

    fn twoside_sketch(&self, sc: &Mat, a_l: &Mat, sr: &Mat) -> Result<Mat> {
        Ok(matmul_a_bt(&matmul(sc, a_l), sr))
    }

    fn stream_update(
        &self,
        a_l: &Mat,
        omega_t: &Mat,
        psi: &Mat,
        sc: &Mat,
        sr: &Mat,
    ) -> Result<(Mat, Mat, Mat)> {
        let c_delta = matmul(a_l, omega_t);
        let r_block = matmul(psi, a_l);
        let m_delta = matmul_a_bt(&matmul(sc, a_l), sr);
        Ok((c_delta, r_block, m_delta))
    }
}
