//! ε-targeted accuracy planning — invert the paper's sketch-size bounds.
//!
//! Every solver in this crate takes raw sketch sizes; the paper's
//! guarantee runs the other way: given a target relative error `ε`,
//! Theorem 1 (with the sharper constants of Ye–Ye–Zhang,
//! arXiv:1609.02258) prescribes sketch sizes of order `O(ε^{-1/2})`
//! times the factor width. [`EpsilonPlan`] packages that inversion:
//!
//! 1. **Seed** — [`EpsilonPlan::initial_size`] picks the first sketch
//!    size `⌈w·(1 + 2/√ε)⌉` for a width-`w` factor (clamped to the
//!    matrix dimension).
//! 2. **Check** — after each solve the attainment test compares the
//!    sketched residual `‖S₁(A − C X̃ R)S₂‖_F` against the sketched
//!    *optimum* on the same count-sketch pair (size
//!    [`EpsilonPlan::check_size`], the `O(ε^{-2})` a-posteriori
//!    estimator of `gmr::estimate_residual`, after Tropp et al.
//!    arXiv:1609.00048). Both norms live on one fixed sketch, so their
//!    ratio concentrates far better than either norm alone.
//! 3. **Escalate** — on a miss the sizes double
//!    ([`EpsilonPlan::schedule`]) and the sketches are *extended*, not
//!    redrawn: [`crate::sketch::Sketch::draw_extension`] replays the
//!    same seeded stream, so the previous sketch is a bitwise prefix of
//!    the larger one and every cached product (`S_C A`, `S_C C`,
//!    `R S_Rᵀ`, `Ã`) grows by appending rows/columns instead of being
//!    recomputed. A schedule entry that reaches the full dimension
//!    degenerates to [`crate::sketch::Sketch::identity`], which makes
//!    the final attempt exact and guarantees termination.
//!
//! The planner never discards completed work and never loops past
//! [`EpsilonPlan::max_attempts`]. Outcomes are reported in
//! [`PlanOutcome`] (and as `plan.attempt` spans when tracing is
//! installed), including the *estimated* ε actually reached — callers
//! that stop early (e.g. a degraded serving tier) report that estimate
//! instead of silently violating the target.

use crate::gmr::{self, FastGmrSolution, Input};
use crate::linalg::{fro_norm_diff, matmul, Mat};
use crate::obs::{self, cat};
use crate::rng::{rng, Pcg64};
use crate::sketch::{row_leverage_scores, Sketch, SketchKind};

/// An ε target plus the escalation policy used to reach it.
///
/// ```
/// use fastgmr::gmr::Input;
/// use fastgmr::linalg::Mat;
/// use fastgmr::plan::{solve_gmr_planned, EpsilonPlan};
/// use fastgmr::rng::rng;
/// use fastgmr::sketch::SketchKind;
///
/// let mut r = rng(7);
/// let a = Mat::randn(60, 40, &mut r);
/// let cols: Vec<usize> = (0..10).collect();
/// let c = a.select_cols(&cols);
/// let rmat = a.select_rows(&cols);
/// let plan = EpsilonPlan::new(0.5);
/// // Sizes come from the ε → O(ε^{-1/2}) inversion, not the caller.
/// assert!(plan.initial_size(10, 60) > 10);
/// let (sol, out) =
///     solve_gmr_planned(Input::Dense(&a), &c, &rmat, SketchKind::Gaussian, SketchKind::Gaussian, &plan);
/// assert_eq!(sol.x.shape(), (10, 10));
/// assert!(out.attempts >= 1 && out.attempts <= 4);
/// ```
#[derive(Clone, Debug)]
pub struct EpsilonPlan {
    /// Target relative error: the planner aims for
    /// `‖A − C X̃ R‖_F ≤ (1+ε)·‖A − C X* R‖_F`.
    pub epsilon: f64,
    /// Escalation budget (≥ 1); the last attempt's result is returned
    /// even when the target was not certified.
    pub max_attempts: usize,
    /// Seed for the planner's own randomness (sketch draws and the
    /// attainment check); two runs with the same plan are bitwise
    /// identical.
    pub seed: u64,
}

impl EpsilonPlan {
    /// A plan targeting `epsilon` with the default escalation budget
    /// (4 attempts) and a fixed default seed.
    pub fn new(epsilon: f64) -> Self {
        assert!(
            epsilon.is_finite() && epsilon > 0.0,
            "EpsilonPlan: epsilon must be a positive finite number, got {epsilon}"
        );
        EpsilonPlan { epsilon, max_attempts: 4, seed: 0x00e5_7a26 }
    }

    /// Same plan, different seed (jobs should pass their own seed so
    /// repeated submissions stay reproducible *per job*).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Same plan, different escalation budget (must be ≥ 1).
    pub fn with_max_attempts(mut self, max_attempts: usize) -> Self {
        assert!(max_attempts >= 1, "EpsilonPlan: max_attempts must be ≥ 1");
        self.max_attempts = max_attempts;
        self
    }

    /// First-attempt sketch size for a width-`width` factor along a
    /// dimension of size `dim`: `⌈width·(1 + 2/√ε)⌉`, clamped to
    /// `[width, dim]`. The `2/√ε` factor is the paper's `O(ε^{-1/2})`
    /// oversampling with the 1609.02258 constants rounded up to the
    /// next integer multiple.
    pub fn initial_size(&self, width: usize, dim: usize) -> usize {
        let w = width.max(1);
        let s = (w as f64 * (1.0 + 2.0 / self.epsilon.sqrt())).ceil() as usize;
        s.clamp(w, dim.max(1))
    }

    /// The geometric escalation schedule: `s₀, 2s₀, 4s₀, …` capped at
    /// `dim` and truncated to [`EpsilonPlan::max_attempts`] entries.
    /// Once an entry reaches `dim` the schedule stops — that attempt
    /// runs with the identity sketch and is exact.
    pub fn schedule(&self, width: usize, dim: usize) -> Vec<usize> {
        let dim = dim.max(1);
        let mut sizes = Vec::with_capacity(self.max_attempts);
        let mut s = self.initial_size(width, dim);
        for _ in 0..self.max_attempts {
            sizes.push(s);
            if s >= dim {
                break;
            }
            s = (s * 2).min(dim);
        }
        sizes
    }

    /// Count-sketch size for the a-posteriori attainment check:
    /// `max(⌈32/ε²⌉, 4·width)`. The `O(ε^{-2})` term is the §6.1
    /// estimator rate; the `4·width` floor keeps the sketched optimum
    /// (a rank-`width` solve on the check sketch) from overfitting.
    /// Sides saturate at the matrix dimension inside the estimator
    /// (degenerating to an exact check — see
    /// `gmr::estimate_residual`), so small problems are always checked
    /// exactly.
    pub fn check_size(&self, width: usize) -> usize {
        let rate = (32.0 / (self.epsilon * self.epsilon)).ceil() as usize;
        rate.max(4 * width.max(1))
    }
}

/// What the planner actually did and reached.
#[derive(Clone, Debug)]
pub struct PlanOutcome {
    /// The ε the plan targeted.
    pub epsilon: f64,
    /// Attempts executed (1 = no escalation).
    pub attempts: usize,
    /// Final left / right sketch sizes.
    pub s_c: usize,
    /// Final right sketch size.
    pub s_r: usize,
    /// Check-sketch residual of the returned solution.
    pub achieved: f64,
    /// Check-sketch residual of the optimum on the same sketch.
    pub optimum: f64,
    /// Whether `achieved ≤ (1+ε)·optimum` was certified.
    pub attained: bool,
}

impl PlanOutcome {
    /// The relative error the check actually certified:
    /// `achieved/optimum − 1` (0 when the residual is at the noise
    /// floor). A degraded or budget-capped run reports this instead of
    /// claiming the target ε.
    pub fn estimated_epsilon(&self) -> f64 {
        if self.optimum > 0.0 && self.achieved.is_finite() {
            (self.achieved / self.optimum - 1.0).max(0.0)
        } else {
            0.0
        }
    }
}

// ---- attainment check ------------------------------------------------

/// The fixed a-posteriori check sketch: `S₁ A S₂ᵀ` drawn once per
/// planned job, mirroring `gmr::estimate_residual` bitwise (same
/// count-sketch family, draw order, and dimension saturation).
///
/// Comparing a candidate's sketched residual against the sketched
/// *optimum* computed on the same pair cancels most of the estimator's
/// variance: both norms distort the same residual directions, so the
/// ratio concentrates at rate `O(√ε/√s)` rather than `O(1/√s)`.
pub struct CheckOracle {
    s1: Sketch,
    s2: Sketch,
    sa: Mat,
    floor: f64,
}

impl CheckOracle {
    /// Draw the check pair (size `s`, saturating at `A`'s dimensions)
    /// and sketch `A` once.
    pub fn new(a: Input<'_>, s: usize, seed: u64) -> Self {
        let mut r = rng(seed);
        let (s1, s2) = gmr::residual_sketch_pair(a.rows(), a.cols(), s, &mut r);
        let sa = s2.apply_right(&a.sketch_left(&s1));
        // Absolute floor so exactly-representable inputs (residual 0)
        // terminate instead of chasing 0 ≤ (1+ε)·0.
        let floor = 1e-9 * (1.0 + sa.fro_norm());
        CheckOracle { s1, s2, sa, floor }
    }

    /// The check pair alone (for streaming drivers that must accumulate
    /// `S₁A` during their single pass) — bitwise the pair
    /// [`CheckOracle::new`] would draw.
    pub fn sketch_pair(rows: usize, cols: usize, s: usize, seed: u64) -> (Sketch, Sketch) {
        let mut r = rng(seed);
        gmr::residual_sketch_pair(rows, cols, s, &mut r)
    }

    /// Assemble from a pair drawn with [`CheckOracle::sketch_pair`] and
    /// the already-sketched `S₁AS₂ᵀ` (streaming drivers apply `S₂` to
    /// their accumulated `S₁A`).
    pub fn from_sketched(s1: Sketch, s2: Sketch, sa: Mat) -> Self {
        let floor = 1e-9 * (1.0 + sa.fro_norm());
        CheckOracle { s1, s2, sa, floor }
    }

    /// Bind the check to a fixed factor pair `(C, R)`: sketches the
    /// factors and solves for the check-sketch optimum once; candidate
    /// cores are then scored with two small products each.
    pub fn for_factors(&self, c: &Mat, r: &Mat) -> FactorCheck<'_> {
        let s1c = self.s1.apply_left(c);
        let rs2 = self.s2.apply_right(r);
        let x_opt = gmr::solve_core(&s1c, &self.sa, &rs2);
        let opt = fro_norm_diff(&self.sa, &matmul(&matmul(&s1c, &x_opt), &rs2));
        FactorCheck { s1c, rs2, sa: &self.sa, opt, floor: self.floor }
    }
}

/// A [`CheckOracle`] specialized to one factor pair; scores candidate
/// core matrices against the check-sketch optimum.
pub struct FactorCheck<'a> {
    s1c: Mat,
    rs2: Mat,
    sa: &'a Mat,
    opt: f64,
    floor: f64,
}

impl FactorCheck<'_> {
    /// Check-sketch residual `‖S₁AS₂ᵀ − (S₁C) X (RS₂ᵀ)‖_F` of a
    /// candidate core (bitwise equal to `gmr::estimate_residual` on the
    /// same seed and size).
    pub fn residual_of(&self, x: &Mat) -> f64 {
        fro_norm_diff(self.sa, &matmul(&matmul(&self.s1c, x), &self.rs2))
    }

    /// The check-sketch optimum residual for these factors.
    pub fn optimum(&self) -> f64 {
        self.opt
    }

    /// Attainment: `achieved ≤ (1+ε)·optimum + floor`.
    pub fn attained(&self, epsilon: f64, achieved: f64) -> bool {
        achieved <= (1.0 + epsilon) * self.opt + self.floor
    }
}

// ---- prefix-growing sketch state ------------------------------------

/// What [`SideState::grow`] did this attempt.
#[derive(Clone, Copy)]
enum Grown {
    /// Nothing changed (target already reached, or already identity).
    Unchanged,
    /// `blocks[i..]` are newly drawn; caches append their applications.
    NewFrom(usize),
    /// The side saturated at its dimension: caches must be rebuilt from
    /// the un-sketched operands (which is exact, so this is final).
    Identity,
}

/// One side's escalating sketch. Drawing continues a single seeded rng
/// across escalations, which reproduces exactly the block stream of
/// [`Sketch::draw_extension`] — the attempt-`k` sketch is a bitwise
/// prefix of the attempt-`k+1` sketch.
struct SideState {
    kind: SketchKind,
    dim: usize,
    scores: Option<Vec<f64>>,
    rng: Pcg64,
    size: usize,
    blocks: Vec<Sketch>,
    identity: bool,
}

impl SideState {
    fn new(kind: SketchKind, dim: usize, scores: Option<Vec<f64>>, rng: Pcg64) -> Self {
        SideState { kind, dim, scores, rng, size: 0, blocks: Vec::new(), identity: false }
    }

    fn grow(&mut self, target: usize) -> Grown {
        if self.identity {
            return Grown::Unchanged;
        }
        if target >= self.dim {
            self.identity = true;
            self.size = self.dim;
            self.blocks.clear();
            return Grown::Identity;
        }
        if self.size >= target {
            return Grown::Unchanged;
        }
        let first_new = self.blocks.len();
        if self.size == 0 {
            self.blocks.push(Sketch::draw(
                self.kind,
                target,
                self.dim,
                self.scores.as_deref(),
                &mut self.rng,
            ));
            self.size = target;
        } else {
            while self.size < target {
                let b = self.size.min(target - self.size);
                self.blocks.push(Sketch::draw(
                    self.kind,
                    b,
                    self.dim,
                    self.scores.as_deref(),
                    &mut self.rng,
                ));
                self.size += b;
            }
        }
        Grown::NewFrom(first_new)
    }
}

fn vcat_into(acc: &mut Option<Mat>, part: Mat) {
    *acc = Some(match acc.take() {
        None => part,
        Some(m) => m.vcat(&part),
    });
}

fn hcat_into(acc: &mut Option<Mat>, part: Mat) {
    *acc = Some(match acc.take() {
        None => part,
        Some(m) => m.hcat(&part),
    });
}

/// `A · [S₀ᵀ | S₁ᵀ | …]` for a list of right-sketch blocks.
fn apply_blocks_right(a: &Mat, blocks: &[Sketch]) -> Mat {
    let mut out: Option<Mat> = None;
    for blk in blocks {
        hcat_into(&mut out, blk.apply_right(a));
    }
    out.expect("apply_blocks_right: no blocks")
}

// ---- the planned GMR solve -------------------------------------------

/// ε-planned Fast GMR: solve `min_X ‖A − C X R‖_F` to a target
/// relative error, escalating sketch sizes geometrically until the
/// a-posteriori check certifies attainment (or the budget runs out —
/// inspect [`PlanOutcome::attained`]).
///
/// All sketch products are cached and *extended* across attempts
/// (`S_C A`, `S_C C`, `R S_Rᵀ`, and `Ã` grow by appended rows/columns),
/// so an escalation costs only the marginal rows it adds. Determinism
/// is governed entirely by `plan.seed` — the same plan on the same
/// input is bitwise reproducible regardless of thread count.
///
/// Each attempt is recorded as a `plan.attempt` span (category
/// `dispatch`) with `attempt`, `s_c`, `s_r`, and `achieved` metadata.
pub fn solve_gmr_planned(
    a: Input<'_>,
    c: &Mat,
    r: &Mat,
    kind_c: SketchKind,
    kind_r: SketchKind,
    plan: &EpsilonPlan,
) -> (FastGmrSolution, PlanOutcome) {
    let (m, n) = (a.rows(), a.cols());
    let (wc, wr) = (c.cols(), r.rows());
    assert_eq!(c.rows(), m, "solve_gmr_planned: C must have A's row count");
    assert_eq!(r.cols(), n, "solve_gmr_planned: R must have A's column count");

    let check = CheckOracle::new(a, plan.check_size(wc.max(wr)), plan.seed ^ 0x00e5_c4ec);
    let fc = check.for_factors(c, r);

    let sched_c = plan.schedule(wc, m);
    let sched_r = plan.schedule(wr, n);
    let attempts = sched_c.len().max(sched_r.len());

    // Leverage scores are a property of the factors, not the sketch
    // size — compute once, reuse across every escalation.
    let scores_c = (kind_c == SketchKind::Leverage).then(|| row_leverage_scores(c));
    let scores_r = (kind_r == SketchKind::Leverage).then(|| row_leverage_scores(&r.transpose()));
    let mut side_c = SideState::new(kind_c, m, scores_c, rng(plan.seed ^ 0x00e5_00c0));
    let mut side_r = SideState::new(kind_r, n, scores_r, rng(plan.seed ^ 0x00e5_00f0));

    // Growing caches. `a_tilde` is kept consistent with (sc_a, r-blocks)
    // by appending the marginal rows/columns each escalation.
    let mut sc_a: Option<Mat> = None; // S_C A      (s_c × n)
    let mut sc_c: Option<Mat> = None; // S_C C      (s_c × wc)
    let mut r_sr: Option<Mat> = None; // R S_Rᵀ     (wr × s_r)
    let mut a_tilde: Option<Mat> = None; // S_C A S_Rᵀ (s_c × s_r)

    let mut result: Option<(FastGmrSolution, PlanOutcome)> = None;
    for attempt in 0..attempts {
        let t_c = sched_c[attempt.min(sched_c.len() - 1)];
        let t_r = sched_r[attempt.min(sched_r.len() - 1)];
        let mut sp = obs::span("plan.attempt", cat::DISPATCH);
        sp.meta("attempt", attempt + 1);
        sp.meta("s_c", t_c);
        sp.meta("s_r", t_r);

        let old_rows = sc_a.as_ref().map_or(0, Mat::rows);
        let old_rblocks = side_r.blocks.len();
        let step_c = side_c.grow(t_c);
        let step_r = side_r.grow(t_r);

        match step_c {
            Grown::Unchanged => {}
            Grown::NewFrom(i) => {
                for blk in &side_c.blocks[i..] {
                    vcat_into(&mut sc_a, a.sketch_left(blk));
                    vcat_into(&mut sc_c, blk.apply_left(c));
                }
            }
            Grown::Identity => {
                sc_a = Some(a.sketch_left(&Sketch::identity(m)));
                sc_c = Some(c.clone());
                a_tilde = None; // stale: rebuilt below
            }
        }
        match step_r {
            Grown::Unchanged => {}
            Grown::NewFrom(i) => {
                for blk in &side_r.blocks[i..] {
                    hcat_into(&mut r_sr, blk.apply_right(r));
                }
            }
            Grown::Identity => {
                r_sr = Some(r.clone());
                a_tilde = None;
            }
        }

        let sca = sc_a.as_ref().expect("sc_a initialized on first attempt");
        if side_r.identity {
            // S_R = I ⇒ Ã = S_C A. Rebuilt whenever either side moved.
            let fresh = match &a_tilde {
                Some(t) => t.rows() != sca.rows(),
                None => true,
            };
            if fresh {
                a_tilde = Some(sca.clone());
            }
        } else {
            a_tilde = Some(match a_tilde.take() {
                // No valid cache (first attempt, or S_C just saturated
                // and invalidated it): build against all current blocks.
                None => apply_blocks_right(sca, &side_r.blocks),
                Some(mut t) => {
                    // New S_C rows against the blocks R already had.
                    if sca.rows() > old_rows && old_rblocks > 0 {
                        let new_rows = sca.slice(old_rows, sca.rows(), 0, sca.cols());
                        t = t.vcat(&apply_blocks_right(&new_rows, &side_r.blocks[..old_rblocks]));
                    }
                    // New R blocks against the full (grown) S_C A.
                    if side_r.blocks.len() > old_rblocks {
                        t = t.hcat(&apply_blocks_right(sca, &side_r.blocks[old_rblocks..]));
                    }
                    t
                }
            });
        }

        let scc = sc_c.as_ref().expect("sc_c initialized");
        let rsr = r_sr.as_ref().expect("r_sr initialized");
        let atl = a_tilde.as_ref().expect("a_tilde initialized");
        let x = gmr::solve_core(scc, atl, rsr);
        let achieved = fc.residual_of(&x);
        let attained = fc.attained(plan.epsilon, achieved);
        sp.meta("achieved", achieved);
        sp.meta("attained", if attained { "yes" } else { "no" });
        drop(sp);

        let last = attempt + 1 == attempts;
        if attained || last {
            let outcome = PlanOutcome {
                epsilon: plan.epsilon,
                attempts: attempt + 1,
                s_c: side_c.size,
                s_r: side_r.size,
                achieved,
                optimum: fc.optimum(),
                attained,
            };
            let sol = FastGmrSolution {
                x,
                sc_c: sc_c.take().expect("sc_c"),
                r_sr: r_sr.take().expect("r_sr"),
                a_tilde: a_tilde.take().expect("a_tilde"),
            };
            result = Some((sol, outcome));
            break;
        }
    }
    result.expect("planner runs at least one attempt")
}

#[cfg(test)]
mod tests;
