use super::*;
use crate::gmr::{estimate_residual, residual, solve_exact, solve_fast_with};

fn assert_mats_bitwise(a: &Mat, b: &Mat, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape mismatch");
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            assert!(
                a[(i, j)] == b[(i, j)],
                "{what}: bitwise mismatch at ({i},{j}): {} vs {}",
                a[(i, j)],
                b[(i, j)]
            );
        }
    }
}

/// Low-rank-plus-noise test input with width-`w` factors drawn from A's
/// actual columns/rows (the CUR setting the planner serves).
fn problem(m: usize, n: usize, w: usize, seed: u64) -> (Mat, Mat, Mat) {
    let mut r = rng(seed);
    let u = Mat::randn(m, w, &mut r);
    let v = Mat::randn(w, n, &mut r);
    let mut a = matmul(&u, &v);
    let noise = Mat::randn(m, n, &mut r);
    for i in 0..m {
        for j in 0..n {
            a[(i, j)] += 0.05 * noise[(i, j)];
        }
    }
    let idx: Vec<usize> = (0..w).collect();
    let c = a.select_cols(&idx);
    let rm = a.select_rows(&idx);
    (a, c, rm)
}

#[test]
fn initial_size_inverts_the_epsilon_bound() {
    // ε = 0.25 ⇒ 1 + 2/√ε = 5, so a width-10 factor seeds at 50.
    let plan = EpsilonPlan::new(0.25);
    assert_eq!(plan.initial_size(10, 1000), 50);
    // Tighter ε ⇒ strictly larger seed size (the O(ε^{-1/2}) law).
    let loose = EpsilonPlan::new(0.5).initial_size(10, 1000);
    let tight = EpsilonPlan::new(0.05).initial_size(10, 1000);
    assert!(tight > loose, "tighter ε must oversample more: {tight} vs {loose}");
    // Clamped into [width, dim].
    assert_eq!(plan.initial_size(10, 30), 30);
    assert_eq!(EpsilonPlan::new(1e9).initial_size(10, 1000), 10);
}

#[test]
fn schedule_doubles_caps_and_truncates() {
    let plan = EpsilonPlan::new(0.25);
    // 50, 100, 200, 400 — geometric, max_attempts entries.
    assert_eq!(plan.schedule(10, 10_000), vec![50, 100, 200, 400]);
    // Capped at dim, and stops once an entry reaches it (that attempt is
    // exact — no point planning past it).
    assert_eq!(plan.schedule(10, 150), vec![50, 100, 150]);
    assert_eq!(plan.schedule(10, 40), vec![40]);
    // Budget of one: a single attempt at the seeded size.
    assert_eq!(plan.with_max_attempts(1).schedule(10, 10_000), vec![50]);
}

#[test]
fn check_size_takes_the_estimator_rate_or_the_width_floor() {
    // ⌈32/ε²⌉ dominates for small widths...
    assert_eq!(EpsilonPlan::new(0.5).check_size(4), 128);
    // ...and the 4·width floor for wide factors.
    assert_eq!(EpsilonPlan::new(0.5).check_size(100), 400);
}

#[test]
#[should_panic(expected = "epsilon must be a positive finite number")]
fn rejects_nonpositive_epsilon() {
    let _ = EpsilonPlan::new(0.0);
}

/// The attainment check must be *the* a-posteriori estimator of §6.1,
/// bitwise: same seed and size ⇒ same sketched residual as
/// [`gmr::estimate_residual`].
#[test]
fn check_oracle_mirrors_estimate_residual_bitwise() {
    let (a, c, rm) = problem(45, 37, 6, 3);
    let x = solve_exact(Input::Dense(&a), &c, &rm).x;
    for s in [12, 30, 64] {
        let oracle = CheckOracle::new(Input::Dense(&a), s, 0xC4EC);
        let fc = oracle.for_factors(&c, &rm);
        let direct = estimate_residual(Input::Dense(&a), &c, &x, &rm, s, &mut rng(0xC4EC));
        let via_oracle = fc.residual_of(&x);
        assert!(
            via_oracle == direct,
            "s={s}: CheckOracle {via_oracle} != estimate_residual {direct}"
        );
    }
}

/// At check sizes ≥ both dimensions the sketch pair degenerates to the
/// identity and the check scores the *exact* residual.
#[test]
fn saturated_check_is_exact() {
    let (a, c, rm) = problem(24, 18, 4, 5);
    let x = solve_exact(Input::Dense(&a), &c, &rm).x;
    let oracle = CheckOracle::new(Input::Dense(&a), 64, 0x5A7);
    let fc = oracle.for_factors(&c, &rm);
    let exact = residual(Input::Dense(&a), &c, &x, &rm);
    let sketched = fc.residual_of(&x);
    assert!(
        (sketched - exact).abs() <= 1e-10 * (1.0 + exact),
        "saturated check must equal the exact residual: {sketched} vs {exact}"
    );
}

/// End-to-end: the planner certifies its target, and because the check
/// saturates at this scale the certificate is about the *true* relative
/// error, verified here against the exact optimum.
#[test]
fn planned_solve_attains_its_target() {
    let (a, c, rm) = problem(60, 40, 6, 7);
    let plan = EpsilonPlan::new(0.5);
    let (sol, out) =
        solve_gmr_planned(Input::Dense(&a), &c, &rm, SketchKind::Gaussian, SketchKind::Gaussian, &plan);
    assert!(out.attained, "planner must certify ε=0.5 within budget: {out:?}");
    assert!(out.attempts >= 1 && out.attempts <= plan.max_attempts);
    let achieved = residual(Input::Dense(&a), &c, &sol.x, &rm);
    let opt = residual(Input::Dense(&a), &c, &solve_exact(Input::Dense(&a), &c, &rm).x, &rm);
    assert!(
        achieved <= (1.0 + plan.epsilon) * opt + 1e-9 * (1.0 + opt),
        "certified solution violates the target: {achieved} vs (1+ε)·{opt}"
    );
}

/// A schedule entry that reaches the dimension runs with the identity
/// sketch: one attempt, exact result, always attained.
#[test]
fn identity_cap_makes_the_final_attempt_exact() {
    let (a, c, rm) = problem(20, 16, 5, 9);
    // ε small enough that the seeded size exceeds both dimensions.
    let plan = EpsilonPlan::new(0.005);
    let (sol, out) =
        solve_gmr_planned(Input::Dense(&a), &c, &rm, SketchKind::Gaussian, SketchKind::Gaussian, &plan);
    assert_eq!((out.attempts, out.s_c, out.s_r), (1, 20, 16), "{out:?}");
    assert!(out.attained, "the exact attempt always attains: {out:?}");
    let x_exact = solve_exact(Input::Dense(&a), &c, &rm).x;
    let d = fro_norm_diff(&sol.x, &x_exact);
    assert!(d <= 1e-8 * (1.0 + x_exact.fro_norm()), "identity attempt must be exact, diff {d}");
}

/// The planner's escalating side state replays the exact block stream of
/// [`Sketch::draw_extension`]: growing 12 → 24 in two steps consumes the
/// same rng draws as one extension call, so the applied products match
/// bitwise and the attempt-k sketch is a true prefix of attempt-k+1.
#[test]
fn side_state_growth_matches_draw_extension_bitwise() {
    let mut r = rng(31);
    let a = Mat::randn(50, 34, &mut r);
    for kind in [SketchKind::Gaussian, SketchKind::Count, SketchKind::Srht, SketchKind::Uniform] {
        let mut side = SideState::new(kind, 50, None, rng(0xABCD));
        assert!(matches!(side.grow(12), Grown::NewFrom(0)));
        let mut sc_a: Option<Mat> = None;
        for blk in &side.blocks {
            vcat_into(&mut sc_a, blk.apply_left(&a));
        }
        let first = sc_a.clone().unwrap();
        assert!(matches!(side.grow(24), Grown::NewFrom(1)));
        for blk in &side.blocks[1..] {
            vcat_into(&mut sc_a, blk.apply_left(&a));
        }
        let grown = sc_a.unwrap();

        let ext = Sketch::draw_extension(kind, 12, 24, 50, None, &mut rng(0xABCD));
        let full = ext.apply_left(&a);
        assert_mats_bitwise(&grown, &full, &format!("{kind:?} two-step growth vs extension"));
        // Prefix property: the first 12 rows are the 12-row sketch.
        let prefix = full.slice(0, 12, 0, full.cols());
        assert_mats_bitwise(&first, &prefix, &format!("{kind:?} prefix"));
    }
}

/// Whatever sizes the planner ends at (identity aside), its solution is
/// bitwise the plain [`solve_fast_with`] run on extension-drawn sketches
/// of those sizes — escalation reuses work but never changes the answer.
#[test]
fn planned_solution_matches_unplanned_at_final_sizes() {
    let (a, c, rm) = problem(80, 70, 6, 13);
    let plan = EpsilonPlan::new(0.5);
    let (sol, out) =
        solve_gmr_planned(Input::Dense(&a), &c, &rm, SketchKind::Gaussian, SketchKind::Gaussian, &plan);
    assert!(out.s_c < 80 && out.s_r < 70, "test needs non-saturated sizes, got {out:?}");
    let s0_c = plan.schedule(c.cols(), 80)[0];
    let s0_r = plan.schedule(rm.rows(), 70)[0];
    let s_c =
        Sketch::draw_extension(SketchKind::Gaussian, s0_c, out.s_c, 80, None, &mut rng(plan.seed ^ 0x00e5_00c0));
    let s_r =
        Sketch::draw_extension(SketchKind::Gaussian, s0_r, out.s_r, 70, None, &mut rng(plan.seed ^ 0x00e5_00f0));
    let direct = solve_fast_with(Input::Dense(&a), &c, &rm, &s_c, &s_r);
    assert_mats_bitwise(&sol.x, &direct.x, "planned core vs direct solve at final sizes");
    assert_mats_bitwise(&sol.a_tilde, &direct.a_tilde, "planned Ã vs direct");
}

#[test]
fn estimated_epsilon_reports_the_certified_gap() {
    let base = PlanOutcome {
        epsilon: 0.1,
        attempts: 2,
        s_c: 10,
        s_r: 10,
        achieved: 1.2,
        optimum: 1.0,
        attained: false,
    };
    assert!((base.estimated_epsilon() - 0.2).abs() < 1e-12);
    // Better than optimal on the sketch (fp luck) clamps to 0, as does a
    // zero optimum (the floor regime).
    let lucky = PlanOutcome { achieved: 0.99, ..base.clone() };
    assert_eq!(lucky.estimated_epsilon(), 0.0);
    let floor = PlanOutcome { optimum: 0.0, ..base };
    assert_eq!(floor.estimated_epsilon(), 0.0);
}
