//! Column-block streams — the single-pass data model of Section 5.
//!
//! A [`ColumnStream`] yields consecutive column blocks `A_L` of a matrix
//! exactly once. Implementations exist for in-memory dense and CSR
//! matrices (benches/tests) and the same trait is what the coordinator's
//! reader thread drives in production.

use crate::error::Result;
use crate::linalg::Mat;
use crate::sparse::Csr;

/// One block of consecutive columns.
pub struct ColumnBlock {
    /// First column index of this block in A.
    pub col_start: usize,
    /// The dense m × L block.
    pub data: Mat,
}

/// A single-pass source of column blocks.
pub trait ColumnStream {
    /// Total rows m.
    fn rows(&self) -> usize;
    /// Total columns n.
    fn cols(&self) -> usize;
    /// Next block, `Ok(None)` when the matrix has been fully read, or
    /// `Err` when the read failed — transient errors (see
    /// [`FgError::is_transient`](crate::error::FgError::is_transient))
    /// may be retried in place: an erroring implementation must not
    /// have advanced past the block the failed call would have yielded.
    fn next_block(&mut self) -> Result<Option<ColumnBlock>>;
    /// Reset to the beginning (allowed only in tests/benches — a true
    /// stream cannot be replayed; the algorithms never call this).
    fn reset(&mut self);
}

/// Stream over an in-memory dense matrix.
pub struct DenseColumnStream<'a> {
    a: &'a Mat,
    block: usize,
    pos: usize,
}

impl<'a> DenseColumnStream<'a> {
    pub fn new(a: &'a Mat, block: usize) -> Self {
        assert!(block > 0);
        Self { a, block, pos: 0 }
    }
}

impl<'a> ColumnStream for DenseColumnStream<'a> {
    fn rows(&self) -> usize {
        self.a.rows()
    }

    fn cols(&self) -> usize {
        self.a.cols()
    }

    fn next_block(&mut self) -> Result<Option<ColumnBlock>> {
        if self.pos >= self.a.cols() {
            return Ok(None);
        }
        let c0 = self.pos;
        let c1 = (c0 + self.block).min(self.a.cols());
        self.pos = c1;
        Ok(Some(ColumnBlock { col_start: c0, data: self.a.slice(0, self.a.rows(), c0, c1) }))
    }

    fn reset(&mut self) {
        self.pos = 0;
    }
}

/// Wrapper enforcing the single-pass contract: counts the blocks handed
/// out and **panics on `reset()`** — wrap a source in tests (or
/// paranoid callers) to prove an algorithm truly reads the stream once.
/// [`crate::cur::streaming`] and the SVD pipeline are both validated
/// through it.
pub struct OnePassStream<S: ColumnStream> {
    inner: S,
    blocks: usize,
}

impl<S: ColumnStream> OnePassStream<S> {
    pub fn new(inner: S) -> Self {
        Self { inner, blocks: 0 }
    }

    /// Blocks handed out so far.
    pub fn blocks(&self) -> usize {
        self.blocks
    }
}

impl<S: ColumnStream> ColumnStream for OnePassStream<S> {
    fn rows(&self) -> usize {
        self.inner.rows()
    }

    fn cols(&self) -> usize {
        self.inner.cols()
    }

    fn next_block(&mut self) -> Result<Option<ColumnBlock>> {
        let block = self.inner.next_block()?;
        if block.is_some() {
            self.blocks += 1;
        }
        Ok(block)
    }

    fn reset(&mut self) {
        panic!("OnePassStream: reset() called — the consumer must be single-pass");
    }
}

/// Stream over an in-memory CSR matrix (densifies each block; the blocks
/// are thin so this is the natural layout for the downstream sketches).
pub struct CsrColumnStream<'a> {
    a: &'a Csr,
    block: usize,
    pos: usize,
}

impl<'a> CsrColumnStream<'a> {
    pub fn new(a: &'a Csr, block: usize) -> Self {
        assert!(block > 0);
        Self { a, block, pos: 0 }
    }
}

impl<'a> ColumnStream for CsrColumnStream<'a> {
    fn rows(&self) -> usize {
        self.a.rows()
    }

    fn cols(&self) -> usize {
        self.a.cols()
    }

    fn next_block(&mut self) -> Result<Option<ColumnBlock>> {
        if self.pos >= self.a.cols() {
            return Ok(None);
        }
        let c0 = self.pos;
        let c1 = (c0 + self.block).min(self.a.cols());
        self.pos = c1;
        Ok(Some(ColumnBlock { col_start: c0, data: self.a.slice_cols(c0, c1).to_dense() }))
    }

    fn reset(&mut self) {
        self.pos = 0;
    }
}
