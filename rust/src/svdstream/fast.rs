//! Fast SP-SVD — Algorithm 3 of the paper.

use super::source::ColumnStream;
use crate::error::Result;
use crate::linalg::{matmul, pinv_apply_left, pinv_apply_right, qr_thin, svd_jacobi, Mat, Svd};
use crate::rng::Pcg64;
use crate::sketch::{Sketch, SketchKind};

/// Sketch sizes for Algorithm 3. The paper's step 2 sets
/// `r0, c0 = O((k/ε)^{1+γ})`, `r, c = O(k/ε)` and
/// `s_c, s_r = O(max{k/ε^{3/2}, k/(ε²ρ²)} + (k/ε)^{1+γ})`; the §6.3
/// experiments use `c = r` (one tuning knob — a practical advantage over
/// Algorithm 4) and `s_c = 3c·√a`.
#[derive(Clone, Debug)]
pub struct FastSpSvdConfig {
    /// Target rank k (metadata; the factors have rank ≥ k).
    pub k: usize,
    /// Range-sketch size c (columns of C = A Ω̃).
    pub c: usize,
    /// Range-sketch size r (rows of R = Ψ̃ A).
    pub r: usize,
    /// Core-solve sketch size s_c.
    pub s_c: usize,
    /// Core-solve sketch size s_r.
    pub s_r: usize,
    /// Intermediate OSNAP dimension multiplier for Ω/Ψ (c0 = mult·c).
    pub osnap_mult: usize,
    /// Family for the core sketches S_C/S_R (OSNAP in the paper;
    /// Gaussian for dense data, CountSketch for sparse in §6.3).
    pub core_kind: SketchKind,
}

impl FastSpSvdConfig {
    /// §6.3 parameterization: `c = r = mult·k`, `s_c = s_r = 3c·√a`
    /// where `a = mult` plays the x-axis role of Figure 3.
    pub fn paper(k: usize, mult: usize, core_kind: SketchKind) -> Self {
        let c = mult * k;
        let s = (3.0 * c as f64 * (mult as f64).sqrt()).ceil() as usize;
        Self { k, c, r: c, s_c: s, s_r: s, osnap_mult: 4, core_kind }
    }
}

/// Output factors: `A ≈ U diag(σ) Vᵀ` with rank = min(c, r) ≥ k.
pub struct SpSvdResult {
    pub u: Mat,
    pub sigma: Vec<f64>,
    pub v: Mat,
    /// Number of column blocks consumed (diagnostics).
    pub blocks: usize,
}

/// The realized sketches of Algorithm 3 (drawn before the pass; the
/// coordinator shares this struct so the concurrent pipeline and this
/// reference implementation are bit-identical given the same rng seed).
pub struct FastSpSvdSketches {
    /// Ψ̃ = G_R Ψ — r×m (left range sketch).
    pub psi: Sketch,
    /// Ω̃ᵀ = G_C Ω — c×n (right range sketch, stored as the c×n map so
    /// `C = A Ω̃` is `apply_right` over column coordinates).
    pub omega: Sketch,
    /// S_C — s_c×m.
    pub s_c: Sketch,
    /// S_R — s_r×n.
    pub s_r: Sketch,
}

impl FastSpSvdSketches {
    /// Draw all four sketches. Ψ̃ and Ω̃ are OSNAP∘Gaussian compositions
    /// exactly as in Algorithm 3 step 3 (OSNAP with O(1) nonzeros per
    /// column to an intermediate `mult`-inflated dimension, then a dense
    /// Gaussian down to r / c).
    pub fn draw(cfg: &FastSpSvdConfig, m: usize, n: usize, rng: &mut Pcg64) -> Self {
        let r0 = (cfg.osnap_mult * cfg.r).min(m);
        let c0 = (cfg.osnap_mult * cfg.c).min(n);
        let psi = {
            let osnap = Sketch::draw(SketchKind::Osnap, r0, m, None, rng);
            let g = Sketch::draw(SketchKind::Gaussian, cfg.r, r0, None, rng);
            crate::sketch::compose_sketches(osnap, g)
        };
        let omega = {
            let osnap = Sketch::draw(SketchKind::Osnap, c0, n, None, rng);
            let g = Sketch::draw(SketchKind::Gaussian, cfg.c, c0, None, rng);
            crate::sketch::compose_sketches(osnap, g)
        };
        let s_c = Sketch::draw(cfg.core_kind, cfg.s_c, m, None, rng);
        let s_r = Sketch::draw(cfg.core_kind, cfg.s_r, n, None, rng);
        Self { psi, omega, s_c, s_r }
    }
}

/// Algorithm 3 — Fast Single-Pass SVD.
///
/// Consumes the stream exactly once. Memory: `O((m+n)(c+r) + s_c s_r)` —
/// the accumulators only; blocks are dropped after processing.
pub fn fast_sp_svd(
    stream: &mut dyn ColumnStream,
    cfg: &FastSpSvdConfig,
    rng: &mut Pcg64,
) -> Result<SpSvdResult> {
    let (m, n) = (stream.rows(), stream.cols());
    let sketches = {
        let mut sp = crate::obs::span("svd.sketch.draw", crate::obs::cat::SKETCH);
        sp.meta("c", cfg.c);
        sp.meta("s_c", cfg.s_c);
        FastSpSvdSketches::draw(cfg, m, n, rng)
    };
    fast_sp_svd_with(stream, cfg, &sketches)
}

/// Algorithm 3 with pre-drawn sketches (shared with the coordinator).
pub fn fast_sp_svd_with(
    stream: &mut dyn ColumnStream,
    cfg: &FastSpSvdConfig,
    sk: &FastSpSvdSketches,
) -> Result<SpSvdResult> {
    let (m, n) = (stream.rows(), stream.cols());
    // Accumulators (steps 4–9).
    let mut c_acc = Mat::zeros(m, cfg.c); // C = A Ω̃
    let mut r_acc = Mat::zeros(cfg.r, n); // R = Ψ̃ A
    let mut m_acc = Mat::zeros(cfg.s_c, cfg.s_r); // M = S_C A S_Rᵀ
    let mut blocks = 0usize;

    while let Some(block) = stream.next_block()? {
        let a_l = &block.data;
        let (c0, c1) = (block.col_start, block.col_start + a_l.cols());
        let mut sp = crate::obs::span("svd.block", crate::obs::cat::STREAM);
        sp.meta("cols", a_l.cols());
        accumulate_block(a_l, c0, c1, sk, &mut c_acc, &mut r_acc, &mut m_acc);
        drop(sp);
        blocks += 1;
    }

    let (u, sigma, v) = finalize(cfg, sk, &c_acc, &r_acc, &m_acc);
    Ok(SpSvdResult { u, sigma, v, blocks })
}

/// One streaming update (steps 6–8). Factored out so the coordinator's
/// worker threads and the PJRT `stream_update` artifact path share the
/// exact same semantics. Sketch applies shard on the process-wide pool.
pub fn accumulate_block(
    a_l: &Mat,
    c0: usize,
    c1: usize,
    sk: &FastSpSvdSketches,
    c_acc: &mut Mat,
    r_acc: &mut Mat,
    m_acc: &mut Mat,
) {
    accumulate_block_with(a_l, c0, c1, sk, &crate::parallel::Pool::current(), c_acc, r_acc, m_acc);
}

/// [`accumulate_block`] with an explicit pool for the sketch applies —
/// the coordinator pipeline passes a 1-thread pool from its slot workers
/// so parallelism shards at exactly one layer (no oversubscription).
pub fn accumulate_block_with(
    a_l: &Mat,
    c0: usize,
    c1: usize,
    sk: &FastSpSvdSketches,
    pool: &crate::parallel::Pool,
    c_acc: &mut Mat,
    r_acc: &mut Mat,
    m_acc: &mut Mat,
) {
    // R[:, c0..c1] = Ψ̃ A_L
    let r_blk = sk.psi.apply_left_with(a_l, pool); // r x L
    r_acc.set_block(0, c0, &r_blk);
    // C += A_L · Ω̃[c0..c1, :]  (Ω̃ = omegaᵀ, so this is apply_right with
    // the sliced coordinates).
    let om_slice = sk.omega.slice_input(c0, c1); // c x L map
    let c_blk = om_slice.apply_right_with(a_l, pool); // m x c
    *c_acc += &c_blk;
    // M += (S_C A_L) (S_R[:, c0..c1])ᵀ
    let sc_al = sk.s_c.apply_left_with(a_l, pool); // s_c x L
    let sr_slice = sk.s_r.slice_input(c0, c1); // s_r x L
    let m_blk = sr_slice.apply_right_with(&sc_al, pool); // s_c x s_r
    *m_acc += &m_blk;
}

/// ε-planned Algorithm 3. The stream is single-pass, so the caller
/// provides a factory; each escalation attempt re-streams the data.
///
/// What escalation does and does not redo: the range sketches Ψ̃/Ω̃ and
/// their accumulators `C = AΩ̃`, `R = Ψ̃A` — and therefore the
/// orthonormal bases `U_C`, `V_R` and the a-posteriori check products —
/// are computed on the **first pass only** and reused verbatim (they do
/// not depend on the core sketch sizes). Only the core product
/// `M = S_C A S_Rᵀ` is re-accumulated per attempt, with `S_C`/`S_R`
/// grown as bitwise prefix extensions ([`Sketch::draw_extension`]); a
/// schedule entry at the full dimension degenerates to the identity,
/// making the final core solve exact for the fixed bases. The certified
/// ε is therefore relative to the best core for `U_C`/`V_R` — the
/// factor-range error is governed by `cfg.c`/`cfg.r`, which the plan
/// does not change.
pub fn fast_sp_svd_planned<'a, F>(
    mut open_stream: F,
    cfg: &FastSpSvdConfig,
    plan: &crate::plan::EpsilonPlan,
) -> Result<(SpSvdResult, crate::plan::PlanOutcome)>
where
    F: FnMut() -> Result<Box<dyn ColumnStream + 'a>>,
{
    use crate::plan::CheckOracle;
    use crate::rng::rng;

    let mut next_stream = Some(open_stream()?);
    let (m, n) = {
        let s = next_stream.as_ref().expect("stream");
        (s.rows(), s.cols())
    };
    // Range sketches: drawn once from the plan seed, never escalated.
    let mut range_rng = rng(plan.seed ^ 0x55d0_0a0e);
    let r0 = (cfg.osnap_mult * cfg.r).min(m);
    let c0 = (cfg.osnap_mult * cfg.c).min(n);
    let psi = {
        let osnap = Sketch::draw(SketchKind::Osnap, r0, m, None, &mut range_rng);
        let g = Sketch::draw(SketchKind::Gaussian, cfg.r, r0, None, &mut range_rng);
        crate::sketch::compose_sketches(osnap, g)
    };
    let omega = {
        let osnap = Sketch::draw(SketchKind::Osnap, c0, n, None, &mut range_rng);
        let g = Sketch::draw(SketchKind::Gaussian, cfg.c, c0, None, &mut range_rng);
        crate::sketch::compose_sketches(osnap, g)
    };

    let sched_c = plan.schedule(cfg.c.max(1), m);
    let sched_r = plan.schedule(cfg.r.max(1), n);
    let attempts = sched_c.len().max(sched_r.len());
    let (chk1, chk2) =
        CheckOracle::sketch_pair(m, n, plan.check_size(cfg.c.max(cfg.r)), plan.seed ^ 0x55d0_c4ec);

    // First-pass products, reused by every later attempt.
    let mut bases: Option<(Mat, Mat)> = None; // (U_C m×c, V_Rᵀ r×n)
    let mut oracle: Option<CheckOracle> = None;
    let mut blocks = 0usize;

    let mut result = None;
    for attempt in 0..attempts {
        let t_c = sched_c[attempt.min(sched_c.len() - 1)];
        let t_r = sched_r[attempt.min(sched_r.len() - 1)];
        let mut sp = crate::obs::span("plan.attempt", crate::obs::cat::DISPATCH);
        sp.meta("attempt", attempt + 1);
        sp.meta("s_c", t_c);
        sp.meta("s_r", t_r);

        let s_c = if t_c >= m {
            Sketch::identity(m)
        } else {
            Sketch::draw_extension(
                cfg.core_kind,
                sched_c[0],
                t_c,
                m,
                None,
                &mut rng(plan.seed ^ 0x55d0_00c0),
            )
        };
        let s_r = if t_r >= n {
            Sketch::identity(n)
        } else {
            Sketch::draw_extension(
                cfg.core_kind,
                sched_r[0],
                t_r,
                n,
                None,
                &mut rng(plan.seed ^ 0x55d0_00f0),
            )
        };

        let mut stream = match next_stream.take() {
            Some(s) => s,
            None => open_stream()?,
        };
        assert_eq!(
            (stream.rows(), stream.cols()),
            (m, n),
            "fast_sp_svd_planned: reopened stream changed shape"
        );
        let pool = crate::parallel::Pool::current();
        let first_pass = bases.is_none();
        let mut m_acc = Mat::zeros(s_c.out_dim(), s_r.out_dim());
        let mut c_acc = first_pass.then(|| Mat::zeros(m, cfg.c));
        let mut r_acc = first_pass.then(|| Mat::zeros(cfg.r, n));
        let mut y1 = first_pass.then(|| Mat::zeros(chk1.out_dim(), n));
        while let Some(block) = stream.next_block()? {
            let a_l = &block.data;
            let (b0, b1) = (block.col_start, block.col_start + a_l.cols());
            let sc_al = s_c.apply_left_with(a_l, &pool);
            m_acc += &s_r.slice_input(b0, b1).apply_right_with(&sc_al, &pool);
            if first_pass {
                let r_blk = psi.apply_left_with(a_l, &pool);
                r_acc.as_mut().expect("first pass").set_block(0, b0, &r_blk);
                *c_acc.as_mut().expect("first pass") +=
                    &omega.slice_input(b0, b1).apply_right_with(a_l, &pool);
                y1.as_mut().expect("first pass").set_block(
                    0,
                    b0,
                    &chk1.apply_left_with(a_l, &pool),
                );
                blocks += 1;
            }
        }
        if first_pass {
            let _qsp = crate::obs::span("svd.finalize.qr", crate::obs::cat::FACTORIZE);
            let u_c = qr_thin(&c_acc.take().expect("first pass")).q;
            let v_r = qr_thin(&r_acc.take().expect("first pass").transpose()).q;
            bases = Some((u_c, v_r.transpose()));
            let sa = chk2.apply_right(&y1.take().expect("first pass"));
            oracle = Some(CheckOracle::from_sketched(chk1.clone(), chk2.clone(), sa));
        }
        let (u_c, v_rt) = bases.as_ref().expect("bases built on first pass");
        let n_core = {
            let _csp = crate::obs::span("svd.finalize.core", crate::obs::cat::SOLVE);
            let sc_uc = s_c.apply_left(u_c);
            let vr_sr = s_r.apply_right(v_rt);
            let left = pinv_apply_left(&sc_uc, &m_acc);
            pinv_apply_right(&left, &vr_sr)
        };
        let fc = oracle.as_ref().expect("oracle built on first pass").for_factors(u_c, v_rt);
        let achieved = fc.residual_of(&n_core);
        let attained = fc.attained(plan.epsilon, achieved);
        sp.meta("achieved", achieved);
        sp.meta("attained", if attained { "yes" } else { "no" });
        drop(sp);

        if attained || attempt + 1 == attempts {
            let _ssp = crate::obs::span("svd.finalize.svd", crate::obs::cat::FACTORIZE);
            let Svd { u: u_n, s: sigma, v: v_n } = svd_jacobi(&n_core);
            let u = matmul(u_c, &u_n);
            let v = matmul(&v_rt.transpose(), &v_n);
            let outcome = crate::plan::PlanOutcome {
                epsilon: plan.epsilon,
                attempts: attempt + 1,
                s_c: s_c.out_dim(),
                s_r: s_r.out_dim(),
                achieved,
                optimum: fc.optimum(),
                attained,
            };
            result = Some((SpSvdResult { u, sigma, v, blocks }, outcome));
            break;
        }
    }
    Ok(result.expect("planner runs at least one attempt"))
}

/// Steps 10–13: orthonormal bases, Fast-GMR core solve, small SVD. The
/// two tall QRs are the blocked compact-WY kernel and the core SVD is
/// the round-robin parallel Jacobi, so finalize shards over the pool
/// end-to-end.
pub fn finalize(
    cfg: &FastSpSvdConfig,
    sk: &FastSpSvdSketches,
    c_acc: &Mat,
    r_acc: &Mat,
    m_acc: &Mat,
) -> (Mat, Vec<f64>, Mat) {
    let _ = cfg;
    let (u_c, v_r) = {
        let _sp = crate::obs::span("svd.finalize.qr", crate::obs::cat::FACTORIZE);
        let u_c = qr_thin(c_acc).q; // m x c
        let v_r = qr_thin(&r_acc.transpose()).q; // n x r
        (u_c, v_r)
    };
    // N = (S_C U_C)† M (V_Rᵀ S_Rᵀ)†
    let n_core = {
        let _sp = crate::obs::span("svd.finalize.core", crate::obs::cat::SOLVE);
        let sc_uc = sk.s_c.apply_left(&u_c); // s_c x c
        let vr_sr = sk.s_r.apply_right(&v_r.transpose()); // r x s_r  (V_Rᵀ S_Rᵀ)
        let left = pinv_apply_left(&sc_uc, m_acc); // c x s_r
        pinv_apply_right(&left, &vr_sr) // c x r
    };
    let _sp = crate::obs::span("svd.finalize.svd", crate::obs::cat::FACTORIZE);
    let Svd { u: u_n, s: sigma, v: v_n } = svd_jacobi(&n_core);
    let u = matmul(&u_c, &u_n);
    let v = matmul(&v_r, &v_n);
    (u, sigma, v)
}
