//! Tests for the single-pass SVD algorithms.

use super::*;
use crate::linalg::{matmul, matmul_at_b, qr_thin, svd_randomized, Mat};
use crate::rng::rng;
use crate::sketch::SketchKind;
use crate::sparse::Csr;
use crate::svdstream::fast::{fast_sp_svd_with, FastSpSvdSketches};
use crate::testing::assert_close;

/// Matrix with exponentially decaying spectrum (rank structure at k).
fn decaying_matrix(m: usize, n: usize, seed: u64) -> Mat {
    let mut r = rng(seed);
    let p = m.min(n);
    let u = qr_thin(&Mat::randn(m, p, &mut r)).q;
    let v = qr_thin(&Mat::randn(n, p, &mut r)).q;
    let mut us = u;
    for j in 0..p {
        let s = 10.0 * (0.7f64).powi(j as i32) + 1e-3;
        for i in 0..m {
            us[(i, j)] *= s;
        }
    }
    crate::linalg::matmul_a_bt(&us, &v)
}

fn ak_error(a: &Mat, k: usize, seed: u64) -> f64 {
    let mut r = rng(seed);
    let svd = svd_randomized(a, k, 10, 6, &mut r);
    let top_sq: f64 = svd.s.iter().map(|s| s * s).sum();
    (a.fro_norm_sq() - top_sq).max(0.0).sqrt()
}

#[test]
fn column_streams_cover_matrix_once() {
    let mut r = rng(1);
    let a = Mat::randn(13, 29, &mut r);
    let mut stream = DenseColumnStream::new(&a, 7);
    let mut rebuilt = Mat::zeros(13, 29);
    let mut count = 0;
    while let Some(b) = stream.next_block().unwrap() {
        rebuilt.set_block(0, b.col_start, &b.data);
        count += 1;
    }
    assert_eq!(count, 5); // ceil(29/7)
    assert_close(&rebuilt, &a, 1e-15, "dense stream coverage");
    assert!(stream.next_block().unwrap().is_none());

    let a_sp = Csr::from_dense(&a, 0.0);
    let mut stream2 = CsrColumnStream::new(&a_sp, 10);
    let mut rebuilt2 = Mat::zeros(13, 29);
    while let Some(b) = stream2.next_block().unwrap() {
        rebuilt2.set_block(0, b.col_start, &b.data);
    }
    assert_close(&rebuilt2, &a, 1e-15, "csr stream coverage");
}

#[test]
fn fast_sp_svd_achieves_small_error() {
    let a = decaying_matrix(120, 90, 2);
    let k = 5;
    let ak = ak_error(&a, k, 3);
    let mut r = rng(4);
    let cfg = FastSpSvdConfig::paper(k, 6, SketchKind::Gaussian);
    let mut stream = DenseColumnStream::new(&a, 16);
    let res = fast_sp_svd(&mut stream, &cfg, &mut r).unwrap();
    assert_eq!(res.u.rows(), 120);
    assert_eq!(res.v.rows(), 90);
    assert_eq!(res.blocks, (90 + 15) / 16);
    let ratio = error_ratio(&a, &res, ak);
    // rank > k factors can beat ‖A−A_k‖, so ratio may be negative;
    // anything below 0.5 is a success at this sketch size.
    assert!(ratio < 0.5, "fast SP-SVD error ratio {ratio}");
}

#[test]
fn fast_sp_svd_block_size_invariance() {
    // Single-pass accumulation must not depend on the block partition.
    let a = decaying_matrix(60, 50, 5);
    let cfg = FastSpSvdConfig::paper(4, 4, SketchKind::Gaussian);
    let mut r1 = rng(77);
    let sketches = FastSpSvdSketches::draw(&cfg, 60, 50, &mut r1);
    let mut s_small = DenseColumnStream::new(&a, 3);
    let res_small = fast_sp_svd_with(&mut s_small, &cfg, &sketches).unwrap();
    let mut s_big = DenseColumnStream::new(&a, 50);
    let res_big = fast_sp_svd_with(&mut s_big, &cfg, &sketches).unwrap();
    assert_close(&res_small.u, &res_big.u, 1e-8, "U invariant to blocking");
    assert_close(&res_small.v, &res_big.v, 1e-8, "V invariant to blocking");
    for (a_, b_) in res_small.sigma.iter().zip(&res_big.sigma) {
        assert!((a_ - b_).abs() < 1e-8);
    }
}

#[test]
fn fast_sp_svd_improves_with_budget() {
    let a = decaying_matrix(150, 120, 6);
    let k = 5;
    let ak = ak_error(&a, k, 7);
    let mut prev = f64::INFINITY;
    for &mult in &[2usize, 4, 8] {
        let mut acc = 0.0;
        let trials = 3;
        for t in 0..trials {
            let mut r = rng(500 + mult as u64 * 10 + t);
            let cfg = FastSpSvdConfig::paper(k, mult, SketchKind::Gaussian);
            let mut stream = DenseColumnStream::new(&a, 32);
            let res = fast_sp_svd(&mut stream, &cfg, &mut r).unwrap();
            acc += error_ratio(&a, &res, ak);
        }
        let ratio = acc / trials as f64;
        assert!(ratio < prev + 0.05, "not improving: {ratio} after {prev}");
        prev = ratio;
    }
    assert!(prev < 0.1, "final error ratio {prev}");
}

#[test]
fn practical_sp_svd_runs_and_fast_beats_it_at_small_budget() {
    let a = decaying_matrix(150, 120, 8);
    let k = 5;
    let ak = ak_error(&a, k, 9);
    // Budget (c + r) = 6k for both methods — the small-budget regime where
    // Figure 3 shows the largest gap.
    let budget = 6 * k;
    let trials = 5;
    let mut fast_acc = 0.0;
    let mut prac_acc = 0.0;
    for t in 0..trials {
        let mut r = rng(900 + t);
        let cfg_f = FastSpSvdConfig { k, c: budget / 2, r: budget / 2, s_c: 3 * budget, s_r: 3 * budget, osnap_mult: 4, core_kind: SketchKind::Gaussian };
        let mut stream = DenseColumnStream::new(&a, 32);
        fast_acc += error_ratio(&a, &fast_sp_svd(&mut stream, &cfg_f, &mut r).unwrap(), ak);

        let cfg_p = PracticalSpSvdConfig::from_budget(k, budget, SketchKind::Gaussian);
        let mut stream2 = DenseColumnStream::new(&a, 32);
        prac_acc += error_ratio(&a, &practical_sp_svd(&mut stream2, &cfg_p, &mut r).unwrap(), ak);
    }
    let (fast_e, prac_e) = (fast_acc / trials as f64, prac_acc / trials as f64);
    assert!(
        fast_e < prac_e,
        "Fast SP-SVD ({fast_e}) should beat Practical SP-SVD ({prac_e}) at small budget"
    );
}

#[test]
fn factors_are_orthonormal() {
    let a = decaying_matrix(80, 70, 10);
    let mut r = rng(11);
    let cfg = FastSpSvdConfig::paper(4, 4, SketchKind::Gaussian);
    let mut stream = DenseColumnStream::new(&a, 16);
    let res = fast_sp_svd(&mut stream, &cfg, &mut r).unwrap();
    let utu = matmul_at_b(&res.u, &res.u);
    assert_close(&utu, &Mat::eye(res.u.cols()), 1e-8, "UᵀU = I");
    let vtv = matmul_at_b(&res.v, &res.v);
    assert_close(&vtv, &Mat::eye(res.v.cols()), 1e-8, "VᵀV = I");
    // Sigma descending and nonnegative.
    for w in res.sigma.windows(2) {
        assert!(w[0] >= w[1] - 1e-12);
    }
    assert!(res.sigma.iter().all(|&s| s >= 0.0));
}

#[test]
fn sparse_stream_matches_dense_stream() {
    let mut r = rng(12);
    let mut trips = Vec::new();
    for i in 0..100 {
        for j in 0..80 {
            if r.next_f64() < 0.06 {
                trips.push(crate::sparse::Triplet { row: i, col: j, val: r.next_normal() });
            }
        }
    }
    let a_sp = Csr::from_triplets(100, 80, trips);
    let a_d = a_sp.to_dense();
    let cfg = FastSpSvdConfig::paper(4, 4, SketchKind::Count);
    let mut rr = rng(13);
    let sketches = FastSpSvdSketches::draw(&cfg, 100, 80, &mut rr);
    let mut s1 = CsrColumnStream::new(&a_sp, 16);
    let res1 = fast_sp_svd_with(&mut s1, &cfg, &sketches).unwrap();
    let mut s2 = DenseColumnStream::new(&a_d, 16);
    let res2 = fast_sp_svd_with(&mut s2, &cfg, &sketches).unwrap();
    assert_close(&res1.u, &res2.u, 1e-9, "sparse vs dense stream");
    let _ = matmul; // silence unused when optimized out
}

#[test]
fn reconstruction_error_matches_direct() {
    let a = decaying_matrix(40, 30, 14);
    let mut r = rng(15);
    let cfg = FastSpSvdConfig::paper(3, 4, SketchKind::Gaussian);
    let mut stream = DenseColumnStream::new(&a, 8);
    let res = fast_sp_svd(&mut stream, &cfg, &mut r).unwrap();
    let blockwise = reconstruction_error(&a, &res);
    // Direct dense computation.
    let mut us = res.u.clone();
    for j in 0..res.sigma.len() {
        for i in 0..us.rows() {
            us[(i, j)] *= res.sigma[j];
        }
    }
    let approx = crate::linalg::matmul_a_bt(&us, &res.v);
    let direct = crate::linalg::fro_norm_diff(&a, &approx);
    assert!((blockwise - direct).abs() < 1e-10);
}

#[test]
fn ak_error_matches_direct() {
    let a = decaying_matrix(60, 45, 20);
    let k = 4;
    let mut r = rng(21);
    let got = crate::svdstream::ak_error(crate::gmr::Input::Dense(&a), k, 8, &mut r);
    // Direct: full Jacobi SVD tail mass.
    let svd = crate::linalg::svd_jacobi(&a);
    let tail: f64 = svd.s.iter().skip(k).map(|s| s * s).sum();
    let want = tail.sqrt();
    assert!((got - want).abs() / want < 1e-6, "ak_error {got} vs {want}");
    // Sparse path agrees.
    let sp = Csr::from_dense(&a, 0.0);
    let got_sp = crate::svdstream::ak_error(crate::gmr::Input::Sparse(&sp), k, 8, &mut r);
    assert!((got_sp - want).abs() / want < 1e-6);
}

#[test]
fn reconstruction_error_input_matches_dense_path() {
    let a = decaying_matrix(50, 40, 22);
    let mut r = rng(23);
    let cfg = FastSpSvdConfig::paper(3, 4, SketchKind::Gaussian);
    let mut stream = DenseColumnStream::new(&a, 8);
    let res = fast_sp_svd(&mut stream, &cfg, &mut r).unwrap();
    let direct = reconstruction_error(&a, &res);
    let via_input = reconstruction_error_input(crate::gmr::Input::Dense(&a), &res);
    assert!((direct - via_input).abs() < 1e-8, "{direct} vs {via_input}");
    let sp = Csr::from_dense(&a, 0.0);
    let via_sparse = reconstruction_error_input(crate::gmr::Input::Sparse(&sp), &res);
    assert!((direct - via_sparse).abs() < 1e-8);
}
