//! Single-pass (streaming) SVD — Section 5 of the paper.
//!
//! * [`fast_sp_svd`] — Algorithm 3 (**Fast SP-SVD**, the paper's method):
//!   range sketches `C = A Ω̃`, `R = Ψ̃ A` with OSNAP∘Gaussian maps, plus a
//!   third sketch pair for the Fast-GMR core solve
//!   `N = (S_C U_C)† M (V_Rᵀ S_Rᵀ)†` with `M = S_C A S_Rᵀ` accumulated in
//!   the same single pass.
//! * [`practical_sp_svd`] — Algorithm 4 (Tropp et al. 2017 baseline):
//!   `N' = (Ψ̃ U_C)† R V_R`.
//!
//! Both consume the matrix through a [`ColumnStream`] — column blocks
//! arrive once and are dropped, exactly the streaming model of §5. The
//! concurrent production version of this loop lives in
//! [`crate::coordinator::pipeline`]; this module is the reference
//! (single-threaded) implementation the coordinator is tested against.

pub mod fast;
pub mod practical;
pub mod source;

pub use fast::{
    fast_sp_svd, fast_sp_svd_planned, fast_sp_svd_with, FastSpSvdConfig, FastSpSvdSketches,
    SpSvdResult,
};
pub use practical::{practical_sp_svd, PracticalSpSvdConfig};
pub use source::{ColumnStream, CsrColumnStream, DenseColumnStream, OnePassStream};

use crate::linalg::Mat;

/// §6.3 error ratio: `‖A − U Σ Vᵀ‖_F / ‖A − A_k‖_F − 1` (can be negative:
/// the factors have rank > k).
pub fn error_ratio(a: &Mat, res: &SpSvdResult, ak_err: f64) -> f64 {
    let approx_err = reconstruction_error(a, res);
    approx_err / ak_err - 1.0
}

/// `‖A − A_k‖_F` for dense or sparse A via randomized subspace iteration:
/// `‖A − A_k‖² = ‖A‖² − Σ_{i≤k} σ_i²`.
pub fn ak_error(a: crate::gmr::Input<'_>, k: usize, n_iter: usize, rng: &mut crate::rng::Pcg64) -> f64 {
    let (m, n) = (a.rows(), a.cols());
    let l = (k + 8).min(m.min(n));
    let omega = Mat::randn(n, l, rng);
    let mut q = crate::linalg::qr_thin(&a.a_b(&omega)).q;
    for _ in 0..n_iter {
        let z = a.at_b(&q);
        let qz = crate::linalg::qr_thin(&z).q;
        q = crate::linalg::qr_thin(&a.a_b(&qz)).q;
    }
    let b = a.at_b(&q).transpose(); // l x n
    let svd = crate::linalg::svd_jacobi(&b);
    let top: f64 = svd.s.iter().take(k).map(|s| s * s).sum();
    let total = a.fro_norm();
    (total * total - top).max(0.0).sqrt()
}

/// `‖A − U Σ Vᵀ‖_F` for dense or sparse A via the Gram expansion
/// (never materializes the m×n approximation):
/// `‖A − UΣVᵀ‖² = ‖A‖² − 2·tr(ΣᵀUᵀAV) + tr((UᵀU)Σ(VᵀV)Σ)`.
pub fn reconstruction_error_input(a: crate::gmr::Input<'_>, res: &SpSvdResult) -> f64 {
    let k = res.sigma.len();
    // Uᵀ A (k×n) computed as (Aᵀ U)ᵀ — one pass over A.
    let at_u = a.at_b(&res.u); // n x k
    let utav = crate::linalg::matmul_at_b(&at_u, &res.v); // k x k  (UᵀAV)ᵀ… careful
    // at_u = AᵀU; (AᵀU)ᵀ V has shape k×k and equals Uᵀ A V.
    let mut cross = 0.0;
    for i in 0..k {
        cross += res.sigma[i] * utav[(i, i)];
    }
    let gu = crate::linalg::matmul_at_b(&res.u, &res.u); // k x k
    let gv = crate::linalg::matmul_at_b(&res.v, &res.v);
    // tr(Gu Σ Gv Σ) = Σ_ij Gu[i,j] σ_j Gv[j,i] σ_i
    let mut norm_sq = 0.0;
    for i in 0..k {
        for j in 0..k {
            norm_sq += gu[(i, j)] * res.sigma[j] * gv[(j, i)] * res.sigma[i];
        }
    }
    let af = a.fro_norm();
    (af * af - 2.0 * cross + norm_sq).max(0.0).sqrt()
}

/// `‖A − U Σ Vᵀ‖_F`, blockwise.
pub fn reconstruction_error(a: &Mat, res: &SpSvdResult) -> f64 {
    let mut us = res.u.clone();
    for j in 0..res.sigma.len() {
        for i in 0..us.rows() {
            us[(i, j)] *= res.sigma[j];
        }
    }
    let mut acc = 0.0;
    const B: usize = 512;
    for i0 in (0..a.rows()).step_by(B) {
        let i1 = (i0 + B).min(a.rows());
        let us_blk = us.slice(i0, i1, 0, us.cols());
        let approx = crate::linalg::matmul_a_bt(&us_blk, &res.v);
        let a_blk = a.slice(i0, i1, 0, a.cols());
        let d = crate::linalg::fro_norm_diff(&a_blk, &approx);
        acc += d * d;
    }
    acc.sqrt()
}

#[cfg(test)]
mod tests;
