//! Practical SP-SVD — Algorithm 4 (Tropp et al. 2017 / Clarkson–Woodruff
//! 2013), the baseline Fast SP-SVD is compared against in §6.3.
//!
//! Same streaming range sketches `C = A Ω̃`, `R = Ψ̃ A`, but the core is
//! `N' = (Ψ̃ U_C)† R V_R` — no third sketch pair, which forces the
//! r-side sketch to be much larger than the c-side (`r = O(k/ε²)` vs
//! `c = O(k/ε)`) or `N'` becomes ill-conditioned (Section 5.3).

use super::fast::SpSvdResult;
use super::source::ColumnStream;
use crate::error::Result;
use crate::linalg::{matmul, pinv_apply_left, qr_thin, svd_jacobi, Mat, Svd};
use crate::rng::Pcg64;
use crate::sketch::{Sketch, SketchKind};

/// Configuration for Algorithm 4.
#[derive(Clone, Debug)]
pub struct PracticalSpSvdConfig {
    /// Target rank (metadata).
    pub k: usize,
    /// Column-sketch size c (Ω̃ ∈ R^{n×c}).
    pub c: usize,
    /// Row-sketch size r (Ψ̃ ∈ R^{r×m}); Tropp et al. recommend r ≈ 2c+1.
    pub r: usize,
    /// Sketch family (Gaussian for dense, CountSketch for sparse — §6.3).
    pub kind: SketchKind,
}

impl PracticalSpSvdConfig {
    /// The §6.3 comparison point: split a total budget `c + r` with the
    /// baseline's recommended r ≈ 2c ratio.
    pub fn from_budget(k: usize, total: usize, kind: SketchKind) -> Self {
        let c = (total / 3).max(k + 1);
        let r = (total - c).max(c + 1);
        Self { k, c, r, kind }
    }
}

/// Algorithm 4 — Practical Single-Pass SVD (baseline).
pub fn practical_sp_svd(
    stream: &mut dyn ColumnStream,
    cfg: &PracticalSpSvdConfig,
    rng: &mut Pcg64,
) -> Result<SpSvdResult> {
    let (m, n) = (stream.rows(), stream.cols());
    let psi = Sketch::draw(cfg.kind, cfg.r, m, None, rng); // Ψ̃: r×m
    let omega = Sketch::draw(cfg.kind, cfg.c, n, None, rng); // Ω̃ᵀ: c×n

    let mut c_acc = Mat::zeros(m, cfg.c);
    let mut r_acc = Mat::zeros(cfg.r, n);
    let mut blocks = 0usize;

    // Steps 4–7: one pass.
    while let Some(block) = stream.next_block()? {
        let a_l = &block.data;
        let (c0, c1) = (block.col_start, block.col_start + a_l.cols());
        let r_blk = psi.apply_left(a_l); // r x L
        r_acc.set_block(0, c0, &r_blk);
        let om_slice = omega.slice_input(c0, c1);
        let c_blk = om_slice.apply_right(a_l); // m x c
        c_acc += &c_blk;
        blocks += 1;
    }

    // Steps 8–11.
    let u_c = qr_thin(&c_acc).q; // m x c
    let v_r = qr_thin(&r_acc.transpose()).q; // n x r'
    let psi_uc = psi.apply_left(&u_c); // r x c
    let r_vr = matmul(&r_acc, &v_r); // r x r'
    let n_core = pinv_apply_left(&psi_uc, &r_vr); // c x r'
    let Svd { u: u_n, s: sigma, v: v_n } = svd_jacobi(&n_core);
    let u = matmul(&u_c, &u_n);
    let v = matmul(&v_r, &v_n);
    Ok(SpSvdResult { u, sigma, v, blocks })
}
