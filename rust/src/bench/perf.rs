//! §Perf microbenchmarks: per-layer hot-path throughput, the backend
//! comparison (CPU vs PJRT artifacts), the coordinator overhead, and the
//! headline exact-vs-fast GMR wall-clock ratio.

use super::harness::{BenchCtx, Profile};
use crate::compute::{Backend, CpuBackend, PjrtBackend};
use crate::coordinator::{PipelineConfig, StreamPipeline};
use crate::gmr::{solve_exact, solve_fast, FastGmrConfig, Input};
use crate::linalg::{matmul, matmul_a_bt, matmul_at_b, Mat};
use crate::rng::rng;
use crate::sketch::{Sketch, SketchKind};
use crate::svdstream::fast::{fast_sp_svd_with, FastSpSvdSketches};
use crate::svdstream::source::DenseColumnStream;
use crate::svdstream::FastSpSvdConfig;
use std::sync::Arc;

pub fn run(ctx: &mut BenchCtx) {
    matmul_roofline(ctx);
    sketch_throughput(ctx);
    headline_speedup(ctx);
    pipeline_overhead(ctx);
    backend_compare(ctx);
}

/// L3 hot path #1: the blocked matmul vs its theoretical single-core
/// roofline.
fn matmul_roofline(ctx: &mut BenchCtx) {
    ctx.line("\n-- matmul (f64, single core) --");
    let dims: &[usize] = match ctx.profile {
        Profile::Quick => &[256, 512, 1024],
        Profile::Full => &[256, 512, 1024, 2048],
    };
    let mut r = rng(1);
    for &d in dims {
        let a = Mat::randn(d, d, &mut r);
        let b = Mat::randn(d, d, &mut r);
        let reps = if d <= 512 { 5 } else { 3 };
        let t = ctx.time_n(&format!("matmul {d}x{d}x{d}"), reps, || {
            std::hint::black_box(matmul(&a, &b));
        });
        let gflops = 2.0 * (d as f64).powi(3) / t / 1e9;
        ctx.line(&format!("    => {gflops:.2} GFLOP/s"));
        let t2 = ctx.time_n(&format!("matmul_at_b {d}"), reps, || {
            std::hint::black_box(matmul_at_b(&a, &b));
        });
        ctx.line(&format!("    => {:.2} GFLOP/s", 2.0 * (d as f64).powi(3) / t2 / 1e9));
        let t3 = ctx.time_n(&format!("matmul_a_bt {d}"), reps, || {
            std::hint::black_box(matmul_a_bt(&a, &b));
        });
        ctx.line(&format!("    => {:.2} GFLOP/s", 2.0 * (d as f64).powi(3) / t3 / 1e9));
    }
}

/// L3 hot path #2: sketch application throughput per family.
fn sketch_throughput(ctx: &mut BenchCtx) {
    ctx.line("\n-- sketch apply (dense input) --");
    let (m, n) = match ctx.profile {
        Profile::Quick => (4096, 512),
        Profile::Full => (16384, 1024),
    };
    let s = 256;
    let mut r = rng(2);
    let a = Mat::randn(m, n, &mut r);
    let bytes = (m * n * 8) as f64;
    for kind in [SketchKind::Count, SketchKind::Osnap, SketchKind::Uniform, SketchKind::Srht, SketchKind::Gaussian] {
        let sk = Sketch::draw(kind, s, m, None, &mut r);
        let t = ctx.time_n(&format!("{} {m}x{n} -> {s}", kind.name()), 3, || {
            std::hint::black_box(sk.apply_left(&a));
        });
        ctx.line(&format!("    => {:.2} GB/s input scan", bytes / t / 1e9));
    }

    ctx.line("\n-- sketch apply (sparse input, O(nnz) path) --");
    let sp = crate::data::synth_sparse(m, 4 * n, 0.002, 20, &mut r);
    let nnz = sp.nnz();
    for kind in [SketchKind::Count, SketchKind::Osnap] {
        let sk = Sketch::draw(kind, s, m, None, &mut r);
        let t = ctx.time_n(&format!("{} csr nnz={nnz}", kind.name()), 3, || {
            std::hint::black_box(sk.apply_left_csr(&sp));
        });
        ctx.line(&format!("    => {:.1} Mnnz/s", nnz as f64 / t / 1e6));
    }
}

/// The headline claim: Fast GMR beats exact GMR wall-clock at equal-ish
/// quality once the matrix is large.
fn headline_speedup(ctx: &mut BenchCtx) {
    ctx.line("\n-- exact vs fast GMR wall clock --");
    let (m, n) = match ctx.profile {
        Profile::Quick => (2000, 1600),
        Profile::Full => (6000, 5000),
    };
    let mut r = rng(3);
    let a = crate::data::synth_dense(m, n, 60, crate::data::SpectrumKind::Exponential { base: 0.92 }, 0.02, &mut r);
    let g_c = Mat::randn(n, 20, &mut r);
    let c = matmul(&a, &g_c);
    let g_r = Mat::randn(20, m, &mut r);
    let rr = matmul(&g_r, &a);
    let (exact, t_exact) = ctx.time("exact", || solve_exact(Input::Dense(&a), &c, &rr));
    let cfg = FastGmrConfig::count(160, 160);
    let mut rt = rng(4);
    let (sol, t_fast) = ctx.time("fast (count, a=8)", || solve_fast(Input::Dense(&a), &c, &rr, &cfg, &mut rt));
    let regret = crate::gmr::relative_regret(Input::Dense(&a), &c, &rr, &sol.x, &exact.x);
    ctx.line(&format!(
        "  speedup {:.1}x at error ratio {:.4} ({m}x{n}, c=r=20)",
        t_exact / t_fast,
        regret
    ));
}

/// Coordinator overhead: concurrent pipeline vs the direct single-thread
/// loop on the same workload (target: <5% overhead at 1 worker).
fn pipeline_overhead(ctx: &mut BenchCtx) {
    ctx.line("\n-- pipeline overhead --");
    let (m, n) = match ctx.profile {
        Profile::Quick => (1024, 2048),
        Profile::Full => (2048, 8192),
    };
    let mut r = rng(5);
    let a = crate::data::synth_dense(m, n, 30, crate::data::SpectrumKind::Exponential { base: 0.9 }, 0.02, &mut r);
    let cfg = FastSpSvdConfig::paper(10, 4, SketchKind::Gaussian);
    let sketches = FastSpSvdSketches::draw(&cfg, m, n, &mut r);

    let t_direct = ctx.time_n("direct loop", 3, || {
        let mut s = DenseColumnStream::new(&a, 256);
        std::hint::black_box(fast_sp_svd_with(&mut s, &cfg, &sketches).unwrap());
    });
    let pipeline = StreamPipeline::new(PipelineConfig {
        workers: 1,
        queue_depth: 4,
        ..PipelineConfig::default()
    });
    let t_pipe = ctx.time_n("pipeline (1 worker)", 3, || {
        let mut s = DenseColumnStream::new(&a, 256);
        std::hint::black_box(pipeline.run(&mut s, &cfg, &sketches).unwrap());
    });
    ctx.line(&format!("  overhead: {:+.1}%", (t_pipe / t_direct - 1.0) * 100.0));
    ctx.line(&format!("  throughput: {:.1} cols/s, {:.2} MB/s", n as f64 / t_pipe, (m * n * 8) as f64 / t_pipe / 1e6));
}

/// CPU backend vs PJRT artifacts on the fixed-tile hot ops.
fn backend_compare(ctx: &mut BenchCtx) {
    ctx.line("\n-- compute backends (CPU rust vs PJRT artifacts) --");
    let Ok(engine) = crate::runtime::Engine::new("artifacts") else {
        ctx.line("  artifacts/ not built — skipping (run `make artifacts`)");
        return;
    };
    let engine = Arc::new(engine);
    let pjrt = PjrtBackend::new(engine);
    let cpu = CpuBackend;
    let mut r = rng(6);

    // sketch_apply at the exact artifact tile (no padding overhead).
    let s = Mat::randn(256, 2048, &mut r);
    let a = Mat::randn(2048, 512, &mut r);
    let flops = 2.0 * 256.0 * 2048.0 * 512.0;
    let t_cpu = ctx.time_n("cpu sketch 256x2048x512", 5, || {
        std::hint::black_box(cpu.sketch_apply(&s, &a).unwrap());
    });
    let t_pjrt = ctx.time_n("pjrt sketch 256x2048x512", 5, || {
        std::hint::black_box(pjrt.sketch_apply(&s, &a).unwrap());
    });
    ctx.line(&format!(
        "    cpu {:.2} GF/s, pjrt {:.2} GF/s ({:.2}x)",
        flops / t_cpu / 1e9,
        flops / t_pjrt / 1e9,
        t_cpu / t_pjrt
    ));

    // rbf tile.
    let xi = Mat::randn(256, 128, &mut r);
    let xj = Mat::randn(256, 128, &mut r);
    let t_cpu = ctx.time_n("cpu rbf 256x256x128", 5, || {
        std::hint::black_box(cpu.rbf_block(&xi, &xj, 0.3).unwrap());
    });
    let t_pjrt = ctx.time_n("pjrt rbf 256x256x128", 5, || {
        std::hint::black_box(pjrt.rbf_block(&xi, &xj, 0.3).unwrap());
    });
    ctx.line(&format!("    rbf speed ratio cpu/pjrt: {:.2}x", t_cpu / t_pjrt));

    // stream_update at the artifact tile.
    let a_l = Mat::randn(2048, 512, &mut r);
    let om = Mat::randn(512, 64, &mut r);
    let psi = Mat::randn(64, 2048, &mut r);
    let sc = Mat::randn(192, 2048, &mut r);
    let sr = Mat::randn(192, 512, &mut r);
    let t_cpu = ctx.time_n("cpu stream_update", 3, || {
        std::hint::black_box(cpu.stream_update(&a_l, &om, &psi, &sc, &sr).unwrap());
    });
    let t_pjrt = ctx.time_n("pjrt stream_update", 3, || {
        std::hint::black_box(pjrt.stream_update(&a_l, &om, &psi, &sc, &sr).unwrap());
    });
    ctx.line(&format!("    stream_update speed ratio cpu/pjrt: {:.2}x", t_cpu / t_pjrt));
}
