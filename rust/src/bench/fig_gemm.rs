//! Packed-GEMM figure — single-core GFLOP/s of the BLIS-style packed
//! kernels in `linalg::matmul` against the frozen pre-pack seed kernels,
//! across square, tall-skinny, and sketch-shaped products.
//!
//! The seed kernels (i-k-j with 4-row A-blocking, the `Aᵀ·B` scatter,
//! the `A·Bᵀ` 4-dot kernel — the PR-1 generation that measured
//! ~8.7–10.9 GFLOP/s f64) are kept **here, frozen, bench-only** as the
//! comparison baseline; every production caller goes through the packed
//! drivers. Both sides are timed through the serial *panel* entry points
//! so the numbers are genuinely single-core regardless of the process
//! `threads` knob.
//!
//! Emits `results/BENCH_gemm.json` (uploaded as a CI artifact) and
//! `PERF`-prefixed stdout lines the CI bench step greps into the log;
//! the bench-smoke job additionally fails if the packed kernel is slower
//! than the seed at the 512³ point (the ratio guard). Acceptance bar for
//! the PR-5 pass: **≥ 2× the seed GFLOP/s on the 512–1024 squares**.
//! The optimization log lives in EXPERIMENTS.md §Perf.

use super::harness::{secs, BenchCtx, Profile};
use crate::linalg::Mat;
use crate::rng::rng;

/// One measured row for the JSON artifact.
struct Row {
    kernel: &'static str,
    shape: &'static str,
    m: usize,
    k: usize,
    n: usize,
    seed_s: f64,
    new_s: f64,
}

impl Row {
    fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }
    fn speedup(&self) -> f64 {
        self.seed_s / self.new_s
    }
    fn gflops(&self) -> f64 {
        self.flops() / self.new_s / 1e9
    }
    fn seed_gflops(&self) -> f64 {
        self.flops() / self.seed_s / 1e9
    }
}

/// Repetitions scaled so the cheap shapes average over noise without the
/// big ones dominating wall clock.
fn reps(m: usize, k: usize, n: usize) -> usize {
    match m * k * n {
        v if v <= 1 << 28 => 5,
        v if v <= 1 << 31 => 3,
        _ => 1,
    }
}

pub fn run(ctx: &mut BenchCtx) {
    let squares: &[usize] = match ctx.profile {
        Profile::Quick => &[256, 512, 1024],
        Profile::Full => &[256, 512, 1024, 2048],
    };
    let mut rows: Vec<Row> = Vec::new();
    ctx.line("single-core panel kernels (threads knob bypassed on both sides)");

    ctx.line("\n-- gemm: packed MRxNR microkernel vs seed 4-row i-k-j --");
    for &d in squares {
        rows.push(time_gemm(ctx, "square", d, d, d));
    }
    // Tall-skinny (thin-QR trailing-update shape) and sketch-shaped
    // (S_C·C: small s times a long inner dimension) products.
    rows.push(time_gemm(ctx, "tall-skinny", 4096, 512, 128));
    rows.push(time_gemm(ctx, "sketch", 256, 4096, 512));

    ctx.line("\n-- matmul_at_b: packed transpose-pack vs seed scatter --");
    rows.push(time_at_b(ctx, 4096, 512, 256));

    ctx.line("\n-- matmul_a_bt: packed transpose-pack vs seed 4-dot --");
    rows.push(time_a_bt(ctx, 4096, 512, 256));

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kernel.to_string(),
                r.shape.to_string(),
                format!("{}x{}x{}", r.m, r.k, r.n),
                secs(r.seed_s),
                secs(r.new_s),
                format!("{:.2}", r.speedup()),
                format!("{:.2}", r.seed_gflops()),
                format!("{:.2}", r.gflops()),
            ]
        })
        .collect();
    ctx.line("");
    ctx.table(
        &["kernel", "shape", "m x k x n", "t_seed", "t_new", "speedup", "seed_GF/s", "GF/s"],
        &table,
    );
    for r in &rows {
        ctx.line(&format!(
            "PERF gemm {} {} {}x{}x{}: seed {:.2} -> {:.2} GF/s ({:.2}x)",
            r.kernel,
            r.shape,
            r.m,
            r.k,
            r.n,
            r.seed_gflops(),
            r.gflops(),
            r.speedup()
        ));
    }
    write_json(&rows);
    ctx.line("\nshape check: packed >= 2x seed GF/s on the 512/1024 squares (acceptance bar);");
    ctx.line("CI ratio guard fails the bench-smoke job if speedup < 1.0 at 512^3.");
}

fn time_gemm(ctx: &mut BenchCtx, shape: &'static str, m: usize, k: usize, n: usize) -> Row {
    let mut r = rng(0x21);
    let a = Mat::randn(m, k, &mut r);
    let b = Mat::randn(k, n, &mut r);
    let mut c = Mat::zeros(m, n);
    let reps = reps(m, k, n);
    let seed_s = ctx.time_n(&format!("seed gemm {shape} {m}x{k}x{n}"), reps, || {
        c.data_mut().fill(0.0);
        seed_matmul_acc_panel(a.data(), b.data(), c.data_mut(), m, k, n);
        std::hint::black_box(c.data());
    });
    let new_s = ctx.time_n(&format!("packed gemm {shape} {m}x{k}x{n}"), reps, || {
        c.data_mut().fill(0.0);
        crate::linalg::matmul_acc_panel(a.data(), b.data(), c.data_mut(), m, k, n);
        std::hint::black_box(c.data());
    });
    Row { kernel: "gemm", shape, m, k, n, seed_s, new_s }
}

fn time_at_b(ctx: &mut BenchCtx, k: usize, m: usize, n: usize) -> Row {
    let mut r = rng(0x22);
    let a = Mat::randn(k, m, &mut r);
    let b = Mat::randn(k, n, &mut r);
    let mut c = Mat::zeros(m, n);
    let reps = reps(m, k, n);
    let seed_s = ctx.time_n(&format!("seed at_b {m}x{k}x{n}"), reps, || {
        c.data_mut().fill(0.0);
        seed_matmul_at_b_panel(&a, &b, 0, m, c.data_mut());
        std::hint::black_box(c.data());
    });
    let new_s = ctx.time_n(&format!("packed at_b {m}x{k}x{n}"), reps, || {
        c.data_mut().fill(0.0);
        crate::linalg::matmul_at_b_panel(&a, &b, 0, m, c.data_mut());
        std::hint::black_box(c.data());
    });
    Row { kernel: "at_b", shape: "sketch", m, k, n, seed_s, new_s }
}

fn time_a_bt(ctx: &mut BenchCtx, m: usize, k: usize, n: usize) -> Row {
    let mut r = rng(0x23);
    let a = Mat::randn(m, k, &mut r);
    let b = Mat::randn(n, k, &mut r);
    let mut c = Mat::zeros(m, n);
    let reps = reps(m, k, n);
    let seed_s = ctx.time_n(&format!("seed a_bt {m}x{k}x{n}"), reps, || {
        c.data_mut().fill(0.0);
        seed_matmul_a_bt_panel(&a, &b, 0, m, c.data_mut());
        std::hint::black_box(c.data());
    });
    let new_s = ctx.time_n(&format!("packed a_bt {m}x{k}x{n}"), reps, || {
        c.data_mut().fill(0.0);
        crate::linalg::matmul_a_bt_panel(&a, &b, 0, m, c.data_mut());
        std::hint::black_box(c.data());
    });
    Row { kernel: "a_bt", shape: "sketch", m, k, n, seed_s, new_s }
}

/// Hand-rolled JSON artifact (no serde in the offline vendor set).
fn write_json(rows: &[Row]) {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"fig_gemm\",\n");
    out.push_str(&format!("  \"threads\": {},\n", crate::parallel::threads()));
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"shape\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, \
             \"seed_seconds\": {:.6}, \"seconds\": {:.6}, \"seed_gflops\": {:.3}, \
             \"gflops\": {:.3}, \"speedup\": {:.3}}}{comma}\n",
            r.kernel,
            r.shape,
            r.m,
            r.k,
            r.n,
            r.seed_s,
            r.new_s,
            r.seed_gflops(),
            r.gflops(),
            r.speedup()
        ));
    }
    out.push_str("  ]\n}\n");
    let path = "results/BENCH_gemm.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

// ---------------------------------------------------------------------------
// Frozen seed kernels (baseline for the speedup columns). These are the
// pre-PR-5 implementations, kept verbatim and bench-local: production
// code must never call them.
// ---------------------------------------------------------------------------

/// Seed cache block sizes.
const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 512;

/// Seed serial kernel: unpacked i-k-j with 4-row A-blocking, `C += A·B`.
fn seed_matmul_acc_panel(ad: &[f64], bd: &[f64], cd: &mut [f64], m: usize, k: usize, n: usize) {
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                let mut i = ic;
                while i + 4 <= ic + mb {
                    let (a0, a1, a2, a3) = (
                        &ad[i * k + pc..i * k + pc + kb],
                        &ad[(i + 1) * k + pc..(i + 1) * k + pc + kb],
                        &ad[(i + 2) * k + pc..(i + 2) * k + pc + kb],
                        &ad[(i + 3) * k + pc..(i + 3) * k + pc + kb],
                    );
                    for p in 0..kb {
                        let (v0, v1, v2, v3) = (a0[p], a1[p], a2[p], a3[p]);
                        if v0 == 0.0 && v1 == 0.0 && v2 == 0.0 && v3 == 0.0 {
                            continue;
                        }
                        let brow = &bd[(pc + p) * n + jc..(pc + p) * n + jc + nb];
                        let (c01, c23) = cd[i * n..].split_at_mut(2 * n);
                        let (c0, c1) = c01.split_at_mut(n);
                        let (c2, c3) = c23.split_at_mut(n);
                        let c0 = &mut c0[jc..jc + nb];
                        let c1 = &mut c1[jc..jc + nb];
                        let c2 = &mut c2[jc..jc + nb];
                        let c3 = &mut c3[jc..jc + nb];
                        for t in 0..nb {
                            let bv = brow[t];
                            c0[t] += v0 * bv;
                            c1[t] += v1 * bv;
                            c2[t] += v2 * bv;
                            c3[t] += v3 * bv;
                        }
                    }
                    i += 4;
                }
                for i in i..ic + mb {
                    let arow = &ad[i * k + pc..i * k + pc + kb];
                    let crow = &mut cd[i * n + jc..i * n + jc + nb];
                    for (p, &aval) in arow.iter().enumerate() {
                        if aval == 0.0 {
                            continue;
                        }
                        let brow = &bd[(pc + p) * n + jc..(pc + p) * n + jc + nb];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += aval * bv;
                        }
                    }
                }
            }
        }
    }
}

/// Seed `Aᵀ·B` scatter kernel over the output-row panel `c0..c1`.
fn seed_matmul_at_b_panel(a: &Mat, b: &Mat, c0: usize, c1: usize, cd: &mut [f64]) {
    let (m, k, n) = (a.cols(), a.rows(), b.cols());
    debug_assert_eq!(cd.len(), (c1 - c0) * n);
    let (ad, bd) = (a.data(), b.data());
    for p in 0..k {
        let arow = &ad[p * m + c0..p * m + c1];
        let brow = &bd[p * n..(p + 1) * n];
        for (i, &aval) in arow.iter().enumerate() {
            if aval == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aval * bv;
            }
        }
    }
}

/// Seed `A·Bᵀ` kernel: four B-row dot products per A row.
fn seed_matmul_a_bt_panel(a: &Mat, b: &Mat, r0: usize, r1: usize, cd: &mut [f64]) {
    let n = b.rows();
    debug_assert_eq!(cd.len(), (r1 - r0) * n);
    for i in r0..r1 {
        let arow = a.row(i);
        let crow = &mut cd[(i - r0) * n..(i - r0 + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let (b0, b1, b2, b3) = (b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3));
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
            for t in 0..arow.len() {
                let x = arow[t];
                s0 += x * b0[t];
                s1 += x * b1[t];
                s2 += x * b2[t];
                s3 += x * b3[t];
            }
            crow[j] = s0;
            crow[j + 1] = s1;
            crow[j + 2] = s2;
            crow[j + 3] = s3;
            j += 4;
        }
        for j in j..n {
            let brow = b.row(j);
            let mut acc = 0.0;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            crow[j] = acc;
        }
    }
}
