//! Figure 2 + Table 7 — kernel (SPSD) approximation comparison.
//!
//! Paper setup (§6.2): RBF kernels of six datasets with σ calibrated to
//! Table 6's η at k = 15; C = 2k uniformly sampled columns; s = a·c.
//! Methods: Nyström, fast SPSD (Wang et al. 2016b, Table 7), faster SPSD
//! (Algorithm 2, ours), optimal core. Error ratio = ‖K − CXCᵀ‖_F/‖K‖_F.
//!
//! Expected shape: faster-SPSD ≈ optimal by s = 10c; Nyström plateaus
//! above both; fast-SPSD is much worse than Nyström at small s/c
//! (Table 7's message).

use super::harness::{f4, BenchCtx, Profile};
use crate::data::{kernel_registry, rbf_kernel};
use crate::linalg::Mat;
use crate::rng::rng;
use crate::spsd::{
    error_ratio, fast_spsd_core, faster_spsd_core, nystrom_core, optimal_core, DenseKernelOracle,
};

const K: usize = 15;

struct Problem {
    name: &'static str,
    k: Mat,
    c: Mat,
    idx: Vec<usize>,
    sigma: f64,
}

fn problems(ctx: &mut BenchCtx) -> Vec<Problem> {
    let mut out = Vec::new();
    for spec in kernel_registry() {
        let mut r = rng(0xF16_2 + spec.name.len() as u64);
        let (n, d) = match ctx.profile {
            Profile::Full => spec.run_shape,
            Profile::Quick => (spec.run_shape.0.min(1000), spec.run_shape.1.min(200)),
        };
        let shrunk = crate::data::KernelSpec { run_shape: (n, d), ..spec };
        let (x, sigma) = shrunk.load(&mut r);
        let k = rbf_kernel(&x, sigma);
        let c_dim = 2 * K;
        let idx = r.sample_without_replacement(n, c_dim);
        let oracle = DenseKernelOracle { k: &k };
        let c = crate::spsd::KernelOracle::columns(&oracle, &idx);
        ctx.line(&format!("[{}] n={} d={} sigma={:.4}", spec.name, n, d, sigma));
        out.push(Problem { name: spec.name, k, c, idx, sigma });
    }
    out
}

pub fn run(ctx: &mut BenchCtx) {
    let trials = 2;
    let a_values: &[usize] = &[4, 6, 8, 10, 12, 16];
    let probs = problems(ctx);
    for p in &probs {
        let oracle = DenseKernelOracle { k: &p.k };
        let e_opt = error_ratio(&p.k, &p.c, &optimal_core(&oracle, &p.c));
        let e_nys = error_ratio(&p.k, &p.c, &nystrom_core(&p.c, &p.idx));
        ctx.line(&format!("\n[{}] optimal={} nystrom={} (sigma={:.4})", p.name, f4(e_opt), f4(e_nys), p.sigma));
        let mut rows = Vec::new();
        for &a in a_values {
            let s = (a * p.c.cols()).min(p.k.rows());
            let mut acc = 0.0;
            for t in 0..trials {
                let mut rt = rng(2000 + a as u64 * 13 + t);
                let x = faster_spsd_core(&oracle, &p.c, s, &mut rt);
                acc += error_ratio(&p.k, &p.c, &x);
            }
            let e_faster = acc / trials as f64;
            rows.push(vec![
                a.to_string(),
                f4(e_faster),
                f4(e_nys),
                f4(e_opt),
                f4(e_faster - e_opt),
            ]);
        }
        ctx.table(&["a=s/c", "faster(ours)", "nystrom", "optimal", "gap_to_opt"], &rows);
    }
    ctx.line("\nshape check: faster-SPSD approaches the optimal ratio as a grows (≈ by a=10) while Nyström stays flat above it.");
}

/// Table 7: the fast-SPSD baseline (Wang et al. 2016b) error ratios at
/// a = s/c ∈ {8, 10, 12, 14, 16} — the regime where the single-sketch
/// construction is far from both Nyström and optimal.
pub fn run_table7(ctx: &mut BenchCtx) {
    let a_values = [8usize, 10, 12, 14, 16];
    let probs = problems(ctx);
    let mut rows = Vec::new();
    for &a in &a_values {
        let mut row = vec![format!("a = {a}")];
        for p in &probs {
            let oracle = DenseKernelOracle { k: &p.k };
            let s = (a * p.c.cols()).min(p.k.rows());
            let mut rt = rng(3000 + a as u64);
            let x = fast_spsd_core(&oracle, &p.c, s, &mut rt);
            row.push(f4(error_ratio(&p.k, &p.c, &x)));
        }
        rows.push(row);
    }
    let mut header = vec!["a = s/c"];
    let names: Vec<&str> = probs.iter().map(|p| p.name).collect();
    header.extend(names.iter());
    ctx.table(&header, &rows);
    ctx.line("\nshape check: values are well above the Nyström ratios of fig2 at the same a (fast-SPSD needs s = O(c sqrt(n/eps)) — Section 4.2).");
}
