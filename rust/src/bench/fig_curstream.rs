//! Streaming-CUR figure — error ratio and throughput of the single-pass
//! [`crate::cur::streaming`] driver vs the in-memory subspace-leverage
//! CUR, across the Fast-GMR sketch-size multiplier.
//!
//! The in-memory path scores/selects once (the rank-k subspace scores
//! cost a thin factorization of `A`) and re-solves the core per `mult`,
//! exactly like `fig_cur`; the streaming path re-runs end-to-end per
//! `mult` since its scoring rides the per-run sketch accumulators.
//!
//! Expected shape: both paths sit within a small constant of
//! `‖A − A_k‖_F` once `mult ≥ 4`; the streaming path pays a modest error
//! premium for its sketch-resolved rows (shrinking with `mult`, since
//! `s_c = 2·mult·c` controls the one-pass reconstruction variance) while
//! reading `A` exactly once — the OnePassStream wrapper panics if it
//! does not.
//!
//! Emits `results/BENCH_curstream.json` (CI artifact next to
//! `BENCH_linalg.json`) and `PERF`-prefixed stdout lines the CI bench
//! step greps into the log. EXPERIMENTS.md §CUR-streaming tracks the
//! numbers.

use super::harness::{f4, secs, BenchCtx, Profile};
use crate::coordinator::{PipelineConfig, StreamPipeline};
use crate::cur::{self, SelectionStrategy, StreamingCurConfig, StreamingCurSketches};
use crate::data::{synth_dense, SpectrumKind};
use crate::gmr::Input;
use crate::rng::rng;
use crate::sketch::SketchKind;
use crate::svdstream::{DenseColumnStream, OnePassStream};

/// One measured row for the JSON artifact.
struct Row {
    mult: usize,
    mem_ratio: f64,
    stream_ratio: f64,
    mem_s: f64,
    stream_s: f64,
    cols_per_s: f64,
}

pub fn run(ctx: &mut BenchCtx) {
    let (m, n, k, block) = match ctx.profile {
        Profile::Quick => (700, 900, 8, 128),
        Profile::Full => (1600, 2400, 16, 512),
    };
    let sel = 3 * k;
    let mut r = rng(0xC05);
    let a = synth_dense(m, n, k, SpectrumKind::Exponential { base: 0.8 }, 0.02, &mut r);
    let input = Input::Dense(&a);
    let mut rak = rng(1);
    let ak = crate::svdstream::ak_error(input, k, 6, &mut rak);
    ctx.line(&format!(
        "A: {m}x{n} rank-{k}+noise, c = r = {sel}, block = {block}, ‖A − A_k‖_F = {ak:.5}"
    ));

    // In-memory rank-k subspace-leverage selection, once (scores cost a
    // thin factorization of A; the mult sweep only re-solves the core).
    let strategy = SelectionStrategy::SubspaceLeverage { k };
    let mut rs = rng(7);
    let t0 = std::time::Instant::now();
    let (_, cmat) = cur::select_columns(input, &strategy, sel, &mut rs);
    let (_, rmat) = cur::select_rows(input, &strategy, sel, &mut rs);
    let t_select = t0.elapsed().as_secs_f64();
    ctx.line(&format!("in-memory subspace-leverage selection: {}", secs(t_select)));

    let mut rows = Vec::new();
    for mult in [2usize, 4, 6, 8] {
        // In-memory Fast-GMR core at this sketch size.
        let mut rm = rng(100 + mult as u64);
        let t0 = std::time::Instant::now();
        let u = cur::core_fast(
            input,
            &cmat,
            &rmat,
            SketchKind::Gaussian,
            mult * sel,
            mult * sel,
            &mut rm,
        );
        let mem_s = t_select + t0.elapsed().as_secs_f64();
        let mem_ratio = crate::gmr::residual(input, &cmat, &u, &rmat) / ak;

        // Streaming: one pass through the concurrent pipeline.
        let stream_cfg = StreamingCurConfig::fast(sel, sel, k, mult);
        let mut rstream = rng(200 + mult as u64);
        let sketches = StreamingCurSketches::draw(&stream_cfg, m, n, &mut rstream);
        let pipeline = StreamPipeline::new(PipelineConfig::default());
        let mut stream = OnePassStream::new(DenseColumnStream::new(&a, block));
        let t0 = std::time::Instant::now();
        let res = pipeline
            .run_cur(&mut stream, &stream_cfg, &sketches, &mut rstream)
            .expect("streaming CUR pipeline failed");
        let stream_s = t0.elapsed().as_secs_f64();
        assert_eq!(res.blocks, stream.blocks(), "pipeline must consume every block exactly once");
        let stream_ratio = res.cur.residual(input) / ak;
        rows.push(Row {
            mult,
            mem_ratio,
            stream_ratio,
            mem_s,
            stream_s,
            cols_per_s: n as f64 / stream_s,
        });
    }

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.mult.to_string(),
                f4(r.mem_ratio),
                f4(r.stream_ratio),
                secs(r.mem_s),
                secs(r.stream_s),
                format!("{:.0}", r.cols_per_s),
            ]
        })
        .collect();
    ctx.line("");
    ctx.table(&["mult", "mem_ratio", "stream_ratio", "t_mem", "t_stream", "cols/s"], &table);
    for r in &rows {
        ctx.line(&format!(
            "PERF curstream mult={}: in-mem {} (ratio {}) -> stream {} (ratio {}, {:.0} cols/s)",
            r.mult,
            secs(r.mem_s),
            f4(r.mem_ratio),
            secs(r.stream_s),
            f4(r.stream_ratio),
            r.cols_per_s
        ));
    }
    write_json(&rows);
    ctx.line("\nshape check: stream_ratio within ~2x of mem_ratio at mult >= 4, one pass enforced.");
}

/// Hand-rolled JSON artifact (no serde in the offline vendor set).
fn write_json(rows: &[Row]) {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"fig_curstream\",\n");
    out.push_str(&format!("  \"threads\": {},\n", crate::parallel::threads()));
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"mult\": {}, \"mem_ratio\": {:.6}, \"stream_ratio\": {:.6}, \"mem_seconds\": {:.6}, \"stream_seconds\": {:.6}, \"cols_per_second\": {:.1}}}{comma}\n",
            r.mult, r.mem_ratio, r.stream_ratio, r.mem_s, r.stream_s, r.cols_per_s
        ));
    }
    out.push_str("  ]\n}\n");
    let path = "results/BENCH_curstream.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
