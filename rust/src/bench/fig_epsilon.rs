//! ε-planner figure — accuracy attainment, escalation count, and
//! planning overhead of [`crate::plan::solve_gmr_planned`] across an ε
//! sweep, against an *oracle-sized* baseline (plain `solve_fast` told
//! the planner's final sketch sizes up front).
//!
//! At bench scale the planner's check sketch saturates to the identity
//! (see `EpsilonPlan::check_size`), so attainment is certified against
//! the *exact* sketched-solve residual — which is what makes the CI
//! guard on this figure deterministic: every swept point must reach
//! `‖A − C X̃ R‖_F ≤ (1+ε)·‖A − C X* R‖_F` with mean attempts ≤ 3, all
//! from fixed seeds.
//!
//! Emits `results/BENCH_epsilon.json` (CI artifact) and `PERF`-prefixed
//! stdout lines. EXPERIMENTS.md §Epsilon records the design log.

use super::harness::{f4, secs, BenchCtx, Profile};
use crate::data::{synth_dense, SpectrumKind};
use crate::gmr::{residual, solve_exact, solve_fast, FastGmrConfig, Input};
use crate::plan::EpsilonPlan;
use crate::rng::rng;
use crate::sketch::SketchKind;
use std::sync::atomic::{AtomicU64, Ordering};

/// `--epsilon` override from the CLI: restrict the sweep to one point.
/// Stored as bits (0 = unset; 0.0 is not a legal ε, so no ambiguity).
static CLI_EPS_BITS: AtomicU64 = AtomicU64::new(0);

/// Restrict the sweep to a single caller-chosen ε (the CLI's
/// `bench fig_epsilon --epsilon E`).
pub fn set_cli_epsilon(eps: f64) {
    CLI_EPS_BITS.store(eps.to_bits(), Ordering::Relaxed);
}

fn cli_epsilon() -> Option<f64> {
    match CLI_EPS_BITS.load(Ordering::Relaxed) {
        0 => None,
        bits => Some(f64::from_bits(bits)),
    }
}

/// One measured sweep point for the JSON artifact.
struct Row {
    epsilon: f64,
    attempts: usize,
    s_c: usize,
    s_r: usize,
    /// Exact `‖A − C X̃ R‖_F / ‖A − C X* R‖_F` (target: ≤ 1+ε).
    ratio: f64,
    target_met: bool,
    planned_s: f64,
    oracle_s: f64,
}

pub fn run(ctx: &mut BenchCtx) {
    let (m, n, k) = match ctx.profile {
        Profile::Quick => (300, 240, 8),
        Profile::Full => (1200, 900, 12),
    };
    let w = 3 * k;
    let mut r = rng(0xE5);
    let a = synth_dense(m, n, k, SpectrumKind::Exponential { base: 0.85 }, 0.02, &mut r);
    let input = Input::Dense(&a);
    let idx: Vec<usize> = (0..w).collect();
    let c = a.select_cols(&idx);
    let rm = a.select_rows(&idx);
    let opt = residual(input, &c, &solve_exact(input, &c, &rm).x, &rm);
    ctx.line(&format!(
        "A: {m}x{n} rank-{k}+noise, factors width {w}, exact optimum ‖A − C X* R‖_F = {opt:.5}"
    ));

    let sweep = match cli_epsilon() {
        Some(eps) => vec![eps],
        None => vec![0.5, 0.25, 0.1, 0.05],
    };
    let mut rows = Vec::new();
    for &eps in &sweep {
        let plan = EpsilonPlan::new(eps);
        let t0 = std::time::Instant::now();
        let (sol, out) =
            crate::plan::solve_gmr_planned(input, &c, &rm, SketchKind::Gaussian, SketchKind::Gaussian, &plan);
        let planned_s = t0.elapsed().as_secs_f64();
        let ratio = residual(input, &c, &sol.x, &rm) / opt;
        // Oracle baseline: the same solve handed the planner's final
        // sizes directly — what planning costs over clairvoyance.
        let cfg = FastGmrConfig::uniform_kind(SketchKind::Gaussian, out.s_c.max(w), out.s_r.max(w));
        let mut ro = rng(plan.seed);
        let t0 = std::time::Instant::now();
        let base = solve_fast(input, &c, &rm, &cfg, &mut ro);
        let oracle_s = t0.elapsed().as_secs_f64();
        let _ = base.x;
        rows.push(Row {
            epsilon: eps,
            attempts: out.attempts,
            s_c: out.s_c,
            s_r: out.s_r,
            ratio,
            target_met: out.attained && ratio <= 1.0 + eps + 1e-6,
            planned_s,
            oracle_s,
        });
    }

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.epsilon),
                r.attempts.to_string(),
                format!("{}x{}", r.s_c, r.s_r),
                f4(r.ratio),
                if r.target_met { "yes" } else { "NO" }.to_string(),
                secs(r.planned_s),
                secs(r.oracle_s),
            ]
        })
        .collect();
    ctx.line("");
    ctx.table(&["epsilon", "attempts", "s_c x s_r", "ratio", "met", "t_planned", "t_oracle"], &table);
    for r in &rows {
        ctx.line(&format!(
            "PERF epsilon eps={}: attempts {} (s_c={} s_r={}), ratio {} <= {:.4} [{}], planned {} vs oracle {}",
            r.epsilon,
            r.attempts,
            r.s_c,
            r.s_r,
            f4(r.ratio),
            1.0 + r.epsilon,
            if r.target_met { "met" } else { "MISSED" },
            secs(r.planned_s),
            secs(r.oracle_s)
        ));
    }
    write_json(&rows);
    let mean_attempts =
        rows.iter().map(|r| r.attempts as f64).sum::<f64>() / rows.len().max(1) as f64;
    ctx.line(&format!(
        "\nshape check: every point within (1+ε) of the exact optimum, mean attempts {mean_attempts:.2} (CI guard: <= 3)."
    ));
}

/// Hand-rolled JSON artifact (no serde in the offline vendor set).
fn write_json(rows: &[Row]) {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"fig_epsilon\",\n");
    out.push_str(&format!("  \"threads\": {},\n", crate::parallel::threads()));
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"epsilon\": {}, \"attempts\": {}, \"s_c\": {}, \"s_r\": {}, \"rel_ratio\": {:.6}, \"target_met\": {}, \"planned_seconds\": {:.6}, \"oracle_seconds\": {:.6}}}{comma}\n",
            r.epsilon, r.attempts, r.s_c, r.s_r, r.ratio, r.target_met, r.planned_s, r.oracle_s
        ));
    }
    out.push_str("  ]\n}\n");
    let path = "results/BENCH_epsilon.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
