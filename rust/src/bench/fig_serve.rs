//! Serving figure — cold vs warm latency and throughput of the
//! [`crate::coordinator::Router`] serving layer on a mixed job stream.
//!
//! A client replays the same request set twice against one daemon. The
//! **cold** phase computes every approximation (every cache key is new);
//! the **warm** phase resubmits the identical requests, so every one is
//! answered from the fingerprint-keyed artifact cache — the paper's
//! one-sketch-many-queries amortization measured across requests instead
//! of inside one algorithm. Expected shape: warm p50 sits orders of
//! magnitude under cold p50 (a fingerprint pass plus a clone vs a
//! factorization), and warm hits equal the request count.
//!
//! A third **traced** phase replays the cold workload against a fresh
//! daemon with a [`crate::obs::TraceCollector`] installed, measuring the
//! overhead of span tracing on a fully-cold request stream and deriving
//! the per-phase attribution (sketch/solve/gather/... self-time shares)
//! from the recorded spans. The Chrome trace and the Prometheus metrics
//! exposition are written as CI artifacts (`results/TRACE_serve.json`,
//! `results/METRICS_serve.prom`).
//!
//! Two **robustness** phases close the run: `fault_off` replays the cold
//! workload with the full fault-tolerance stack (retry policy, degraded
//! admission, circuit breakers) configured but no fault plan — measuring
//! that the plumbing is ~free — and `chaos` replays it under a
//! fixed-seed [`crate::faults::FaultPlan`] (transient stream reads, one
//! executor panic per kind, admission pressure). Every chaos job must
//! complete via retry or a verified degraded tier.
//!
//! Three **wire** phases measure the hardened TCP front-end
//! ([`crate::net`]): `inproc` is a sequential in-process baseline that
//! also records every result's `to_words` encoding; `socket` replays the
//! identical stream through a loopback [`crate::net::Client`] — every
//! response must decode **bitwise identical** to the baseline — then
//! drains gracefully (post-drain connects refused, cache persisted and
//! warm-started bitwise by a fresh router); `socket_chaos` repeats the
//! replay under seeded `net.read`/`net.write`/`net.accept` faults, where
//! the in-place socket retries must heal every injection (zero hard
//! failures, still bitwise).
//!
//! Emits `results/BENCH_serve.json`, `results/BENCH_chaos.json`, and
//! `results/BENCH_net.json` (CI artifacts) and `PERF`-prefixed stdout
//! lines; the CI bench step fails if the warm phase records no cache
//! hits, its p50 is not under the cold p50, the traced p50 regresses
//! more than 10% over the cold p50, any chaos job hard-fails, the
//! fault-off p50 regresses more than 5% over cold, the socket p50
//! exceeds 1.5x the in-process p50, or the net-chaos replay records any
//! hard failure or bitwise mismatch. EXPERIMENTS.md §Serving,
//! §Robustness, and §Networking track the numbers.

use super::harness::{f4, secs, BenchCtx, Profile};
use crate::coordinator::{ApproxJob, MatrixPayload, Router, ServeConfig};
use crate::cur::CurConfig;
use crate::data::{synth_dense, SpectrumKind};
use crate::linalg::Mat;
use crate::metrics::Histogram;
use crate::net::{Client, NetConfig, Server};
use crate::obs::TraceCollector;
use crate::rng::rng;
use crate::sketch::SketchKind;
use crate::svdstream::FastSpSvdConfig;
use std::sync::Arc;

/// One measured phase for the JSON artifact.
struct Phase {
    name: &'static str,
    seconds: f64,
    jobs_per_s: f64,
    p50: f64,
    p95: f64,
    p99: f64,
    cache_hits: u64,
}

/// A [`Phase`] from client-side per-request latencies (the wire phases
/// measure at the submitter, so the socket and in-process numbers are
/// apples-to-apples).
fn client_phase(
    name: &'static str,
    jobs: usize,
    seconds: f64,
    hist: &Histogram,
    hits: u64,
) -> Phase {
    Phase {
        name,
        seconds,
        jobs_per_s: jobs as f64 / seconds,
        p50: hist.quantile(0.5),
        p95: hist.quantile(0.95),
        p99: hist.quantile(0.99),
        cache_hits: hits,
    }
}

/// Wire front-end outcomes for `results/BENCH_net.json` (CI net guard).
struct NetStats {
    bitwise_mismatches: u64,
    chaos_hard_failures: u64,
    chaos_injected: u64,
    busy_sheds: u64,
    drain_refused_clean: bool,
    drain_warm_hits: u64,
    drain_warm_bitwise_ok: bool,
}

pub fn run(ctx: &mut BenchCtx) {
    let (m, n, jobs, ndata) = match ctx.profile {
        Profile::Quick => (320, 260, 24, 4),
        Profile::Full => (840, 700, 96, 6),
    };
    let mut r = rng(0x5E4E);
    let datasets: Vec<Mat> = (0..ndata)
        .map(|_| synth_dense(m, n, 12, SpectrumKind::Exponential { base: 0.85 }, 0.02, &mut r))
        .collect();
    let points: Vec<Mat> = (0..ndata).map(|_| Mat::randn(m, 8, &mut r)).collect();
    // One job per (kind, dataset, seed) triple — all keys distinct, so
    // the cold phase computes everything and the warm replay hits
    // everything.
    let job = |j: usize| -> ApproxJob {
        let d = j % ndata;
        let seed = j as u64;
        match j % 3 {
            0 => ApproxJob::Cur {
                a: MatrixPayload::Dense(datasets[d].clone()),
                cfg: CurConfig::fast(12, 12, 3),
                seed,
            },
            1 => ApproxJob::SpsdKernel { x: points[d].clone(), sigma: 0.5, c: 12, s: 60, seed },
            _ => ApproxJob::StreamSvd {
                a: MatrixPayload::Dense(datasets[d].clone()),
                cfg: FastSpSvdConfig::paper(6, 4, SketchKind::Gaussian),
                block: 64,
                seed,
            },
        }
    };

    let router = Router::with_config(&ServeConfig {
        workers: 2,
        cache_bytes: 256 << 20,
        ..ServeConfig::service(2)
    });
    ctx.line(&format!(
        "serve: {jobs} mixed CUR/SPSD/SVD jobs over {ndata} datasets ({m}x{n}), workers=2, \
         cache=256 MB, threads={}",
        crate::parallel::threads()
    ));

    let mut phases = Vec::new();
    let mut hits_before = 0;
    for name in ["cold", "warm"] {
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..jobs)
            .map(|j| router.submit(job(j)).expect("unbounded queue must not shed"))
            .collect();
        for h in handles {
            h.wait().expect("serve bench job failed");
        }
        let seconds = t0.elapsed().as_secs_f64();
        // Draining the histogram isolates this phase's percentiles.
        let hist = router.metrics.take_histogram("serve.latency");
        let hits = router.metrics.get("serve.cache.hits") - hits_before;
        hits_before += hits;
        assert_eq!(hist.count(), jobs as u64, "every job must record one serve latency");
        phases.push(Phase {
            name,
            seconds,
            jobs_per_s: jobs as f64 / seconds,
            p50: hist.quantile(0.5),
            p95: hist.quantile(0.95),
            p99: hist.quantile(0.99),
            cache_hits: hits,
        });
    }
    router.shutdown();

    // Traced phase: a fresh daemon (empty cache, so every request is
    // cold again) with a span collector installed — traced p50 vs cold
    // p50 is the tracing overhead, guarded at ≤ 10% in CI.
    let trace = Arc::new(TraceCollector::new());
    let traced_router = Router::with_config(&ServeConfig {
        workers: 2,
        cache_bytes: 256 << 20,
        trace: Some(trace.clone()),
        ..ServeConfig::service(2)
    });
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..jobs)
        .map(|j| traced_router.submit(job(j)).expect("unbounded queue must not shed"))
        .collect();
    for h in handles {
        h.wait().expect("serve bench job failed");
    }
    let seconds = t0.elapsed().as_secs_f64();
    let hist = traced_router.metrics.take_histogram("serve.latency");
    assert_eq!(hist.count(), jobs as u64, "every traced job must record one serve latency");
    phases.push(Phase {
        name: "traced",
        seconds,
        jobs_per_s: jobs as f64 / seconds,
        p50: hist.quantile(0.5),
        p95: hist.quantile(0.95),
        p99: hist.quantile(0.99),
        cache_hits: traced_router.metrics.get("serve.cache.hits"),
    });
    let prom = traced_router.metrics.prometheus();
    // Join the executors before exporting so every span tree is closed.
    traced_router.shutdown();

    // Robustness phases (chaos engineering): the same cold workload
    // against (a) a daemon with the full fault-tolerance stack
    // configured but no fault plan installed — the plumbing must cost
    // ~nothing — and (b) a chaos daemon replaying a **fixed** fault
    // seed: transient stream-read faults healed by in-place retry, one
    // injected executor panic per kind healed by job-level retry, and
    // admission pressure that re-plans the first requests at a degraded
    // tier. Every chaos job must complete (zero hard failures); CI
    // fails the smoke run otherwise, or if the fault-off p50 regresses
    // more than 5% over the plain cold phase.
    //
    // The seed is chosen so the stream-read schedule has no run of ≥ 4
    // consecutive trips in its first 4000 occurrences: a 5-attempt
    // retry therefore heals every injected read fault no matter how
    // the executors interleave on the shared occurrence counter.
    const FAULT_SEED: u64 = 0x5EED_C405;
    let retry = crate::faults::RetryPolicy {
        max_attempts: 5,
        base_backoff: std::time::Duration::from_millis(1),
        cap: std::time::Duration::from_millis(20),
    };
    let chaos_plan = || {
        Arc::new(
            crate::faults::FaultPlan::new(FAULT_SEED)
                .with_site(crate::faults::site::STREAM_READ, 0.1, 12)
                .with_site(crate::faults::site::executor("cur"), 1.0, 1)
                .with_site(crate::faults::site::executor("spsd"), 1.0, 1)
                .with_site(crate::faults::site::executor("svd"), 1.0, 1)
                .with_site(crate::faults::site::QUEUE_ADMISSION, 1.0, 3),
        )
    };
    let mut chaos_stats = (0u64, 0u64, 0u64, 0u64); // hard, degraded, retries, injected
    for (name, plan) in [("fault_off", None), ("chaos", Some(chaos_plan()))] {
        let router = Router::with_config(&ServeConfig {
            workers: 2,
            cache_bytes: 256 << 20,
            retry,
            degrade: true,
            breaker_threshold: 5,
            faults: plan,
            ..ServeConfig::service(2)
        });
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..jobs)
            .map(|j| router.submit(job(j)).expect("degrading admission must not shed"))
            .collect();
        let mut hard_failures = 0u64;
        let mut degraded_seen = 0u64;
        for h in handles {
            match h.wait() {
                Ok(res) if res.is_degraded() => degraded_seen += 1,
                Ok(_) => {}
                Err(_) => hard_failures += 1,
            }
        }
        let seconds = t0.elapsed().as_secs_f64();
        let hist = router.metrics.take_histogram("serve.latency");
        assert_eq!(hist.count(), jobs as u64, "every {name} job must record one serve latency");
        if name == "chaos" {
            chaos_stats = (
                hard_failures,
                degraded_seen,
                router.metrics.get("serve.retries"),
                router.metrics.get("faults.injected"),
            );
            assert!(router.metrics.get("faults.injected") > 0, "the chaos plan must inject");
        } else {
            assert_eq!(hard_failures, 0, "the fault-off phase must not fail any job");
        }
        phases.push(Phase {
            name,
            seconds,
            jobs_per_s: jobs as f64 / seconds,
            p50: hist.quantile(0.5),
            p95: hist.quantile(0.95),
            p99: hist.quantile(0.99),
            cache_hits: router.metrics.get("serve.cache.hits"),
        });
        router.shutdown();
    }
    let (hard_failures, degraded, chaos_retries, injected) = chaos_stats;
    assert_eq!(hard_failures, 0, "chaos replay must complete every job via retry/degradation");

    // ---- Wire front-end phases (hardened TCP serving) -----------------
    // A dedicated sequential baseline keeps the comparison fair (the
    // loopback client is sequential too) and records the bitwise
    // `to_words` reference every socket response is checked against. The
    // job mix carries more compute per payload byte than the cold
    // workload so the CI-guarded socket/in-process p50 ratio measures
    // wire overhead against real work, not a codec microbenchmark.
    const NET_SEED: u64 = 0x5EED_4E74;
    let net_job = |j: usize| -> ApproxJob {
        let d = j % ndata;
        let seed = 0x4E54 + j as u64;
        match j % 3 {
            0 => ApproxJob::Cur {
                a: MatrixPayload::Dense(datasets[d].clone()),
                cfg: CurConfig::fast(24, 24, 4),
                seed,
            },
            1 => ApproxJob::SpsdKernel { x: points[d].clone(), sigma: 0.5, c: 24, s: 120, seed },
            _ => ApproxJob::StreamSvd {
                a: MatrixPayload::Dense(datasets[d].clone()),
                cfg: FastSpSvdConfig::paper(8, 4, SketchKind::Gaussian),
                block: 64,
                seed,
            },
        }
    };
    let fresh = |cache_path: Option<std::path::PathBuf>| {
        Router::with_config(&ServeConfig {
            workers: 2,
            cache_bytes: 256 << 20,
            cache_path,
            ..ServeConfig::service(2)
        })
    };
    let _ = std::fs::create_dir_all("results");

    let mut baseline: Vec<Vec<u64>> = Vec::with_capacity(jobs);
    let mut hist = Histogram::default();
    let router = fresh(None);
    let t0 = std::time::Instant::now();
    for j in 0..jobs {
        let q0 = std::time::Instant::now();
        let res = router
            .submit(net_job(j))
            .expect("unbounded queue must not shed")
            .wait()
            .expect("net baseline job failed");
        hist.record(q0.elapsed().as_secs_f64());
        baseline.push(res.to_words());
    }
    let seconds = t0.elapsed().as_secs_f64();
    let hits = router.metrics.get("serve.cache.hits");
    phases.push(client_phase("inproc", jobs, seconds, &hist, hits));
    router.shutdown();

    // Fault-off socket replay, then a graceful drain: post-drain
    // connects must be refused and the persisted cache must warm-start
    // a fresh router to an all-hit, bitwise-identical replay.
    let cache_file = std::path::PathBuf::from("results/BENCH_net_cache.txt");
    let _ = std::fs::remove_file(&cache_file);
    let ncfg = NetConfig::default();
    let router = Arc::new(fresh(Some(cache_file.clone())));
    let server =
        Server::bind("127.0.0.1:0", Arc::clone(&router), ncfg.clone()).expect("bind loopback");
    let addr = server.addr();
    let mut client = Client::connect(addr, &ncfg).expect("loopback connect");
    let mut bitwise_mismatches = 0u64;
    let mut hist = Histogram::default();
    let t0 = std::time::Instant::now();
    for (j, words) in baseline.iter().enumerate() {
        let q0 = std::time::Instant::now();
        let (res, _trace) = client.submit(&net_job(j)).expect("socket job failed");
        hist.record(q0.elapsed().as_secs_f64());
        if &res.to_words() != words {
            bitwise_mismatches += 1;
        }
    }
    let seconds = t0.elapsed().as_secs_f64();
    let hits = router.metrics.get("serve.cache.hits");
    phases.push(client_phase("socket", jobs, seconds, &hist, hits));
    client.quit().expect("clean QUIT");
    server.drain();
    let drain_refused_clean = Client::connect(addr, &ncfg).is_err();

    let router = fresh(Some(cache_file.clone()));
    let mut warm_ok = true;
    for (j, words) in baseline.iter().enumerate() {
        let res = router
            .submit(net_job(j))
            .expect("unbounded queue must not shed")
            .wait()
            .expect("warm-start job failed");
        warm_ok &= &res.to_words() == words;
    }
    let drain_warm_hits = router.metrics.get("serve.cache.hits");
    let drain_warm_bitwise_ok = warm_ok && drain_warm_hits == jobs as u64;
    router.shutdown();
    let _ = std::fs::remove_file(&cache_file);

    // Net-chaos replay: every read/write/accept can trip, the in-place
    // socket retries must heal every injection, and every response must
    // still be bitwise identical. Retry budget 16 clears the seed's
    // worst consecutive-injection run (12, self-checked in net::tests).
    let plan = Arc::new(
        crate::faults::FaultPlan::new(NET_SEED)
            .with_site(crate::faults::site::NET_READ, 0.5, u64::MAX)
            .with_site(crate::faults::site::NET_WRITE, 0.25, u64::MAX)
            .with_site(crate::faults::site::NET_ACCEPT, 0.25, u64::MAX),
    );
    let ncfg = NetConfig {
        retry: crate::faults::RetryPolicy {
            max_attempts: 16,
            base_backoff: std::time::Duration::from_micros(200),
            cap: std::time::Duration::from_millis(2),
        },
        faults: Some(Arc::clone(&plan)),
        ..NetConfig::default()
    };
    let router = Arc::new(fresh(None));
    let server = Server::bind("127.0.0.1:0", Arc::clone(&router), ncfg.clone())
        .expect("bind chaos loopback");
    let mut client = Client::connect_retry(server.addr(), &ncfg, 8).expect("chaos connect");
    let mut chaos_hard_failures = 0u64;
    let mut hist = Histogram::default();
    let t0 = std::time::Instant::now();
    for (j, words) in baseline.iter().enumerate() {
        let q0 = std::time::Instant::now();
        match client.submit(&net_job(j)) {
            Ok((res, _)) if &res.to_words() == words => {}
            Ok(_) => bitwise_mismatches += 1,
            Err(_) => chaos_hard_failures += 1,
        }
        hist.record(q0.elapsed().as_secs_f64());
    }
    let seconds = t0.elapsed().as_secs_f64();
    let busy_sheds = router.metrics.get("net.busy");
    phases.push(client_phase(
        "socket_chaos",
        jobs,
        seconds,
        &hist,
        router.metrics.get("serve.cache.hits"),
    ));
    drop(client);
    server.drain();
    let chaos_injected = plan.injected();
    assert!(chaos_injected > 0, "the net chaos plan must inject");
    assert_eq!(chaos_hard_failures, 0, "net chaos must heal every request via socket retries");
    assert_eq!(bitwise_mismatches, 0, "socket results must be bitwise identical to in-process");
    assert!(drain_refused_clean, "post-drain connects must be refused");
    assert!(drain_warm_bitwise_ok, "the drained cache must warm-start bitwise");
    let net = NetStats {
        bitwise_mismatches,
        chaos_hard_failures,
        chaos_injected,
        busy_sheds,
        drain_refused_clean,
        drain_warm_hits,
        drain_warm_bitwise_ok,
    };

    let by_cat = trace.seconds_by_category();
    let total_self: f64 = by_cat.values().sum();
    let attribution: Vec<(String, f64)> = by_cat
        .iter()
        .map(|(cat, s)| (cat.to_string(), if total_self > 0.0 { s / total_self } else { 0.0 }))
        .collect();

    let warm = &phases[1];
    assert_eq!(warm.cache_hits, jobs as u64, "warm replay must hit on every request");

    let table: Vec<Vec<String>> = phases
        .iter()
        .map(|p| {
            vec![
                p.name.to_string(),
                secs(p.seconds),
                format!("{:.1}", p.jobs_per_s),
                secs(p.p50),
                secs(p.p95),
                secs(p.p99),
                p.cache_hits.to_string(),
            ]
        })
        .collect();
    ctx.line("");
    ctx.table(&["phase", "wall", "jobs/s", "p50", "p95", "p99", "hits"], &table);
    for p in &phases {
        ctx.line(&format!(
            "PERF serve {}: {jobs} jobs in {} ({:.1} jobs/s), p50 {} p95 {} p99 {}, hits {}",
            p.name,
            secs(p.seconds),
            p.jobs_per_s,
            secs(p.p50),
            secs(p.p95),
            secs(p.p99),
            p.cache_hits
        ));
    }
    let speedup = phases[0].p50 / warm.p50.max(1e-9);
    ctx.line(&format!("PERF serve warm/cold p50 speedup: {}x", f4(speedup)));
    let overhead = phases[2].p50 / phases[0].p50.max(1e-9);
    ctx.line(&format!("PERF serve traced/cold p50 ratio: {}", f4(overhead)));
    let fault_off_ratio = phases[3].p50 / phases[0].p50.max(1e-9);
    ctx.line(&format!("PERF serve fault_off/cold p50 ratio: {}", f4(fault_off_ratio)));
    ctx.line(&format!(
        "PERF serve chaos: {hard_failures} hard failures, {degraded} degraded, \
         {chaos_retries} retries, {injected} injected (seed {FAULT_SEED:#x})"
    ));
    let by_name = |name: &str| phases.iter().find(|p| p.name == name).expect("phase recorded");
    let (inproc, socket, socket_chaos) =
        (by_name("inproc"), by_name("socket"), by_name("socket_chaos"));
    ctx.line(&format!(
        "PERF serve socket/inproc p50 ratio: {} (CI guard <= 1.5)",
        f4(socket.p50 / inproc.p50.max(1e-9))
    ));
    ctx.line(&format!(
        "PERF serve net chaos: {} hard failures, {} bitwise mismatches, {} injected, \
         {} busy sheds, chaos/inproc p50 ratio {} (seed {NET_SEED:#x})",
        net.chaos_hard_failures,
        net.bitwise_mismatches,
        net.chaos_injected,
        net.busy_sheds,
        f4(socket_chaos.p50 / inproc.p50.max(1e-9))
    ));
    ctx.line(&format!(
        "PERF serve net drain: refused_clean={}, warm hits {}/{jobs}, warm bitwise ok={}",
        net.drain_refused_clean, net.drain_warm_hits, net.drain_warm_bitwise_ok
    ));
    let shares: Vec<String> =
        attribution.iter().map(|(cat, f)| format!("{cat} {:.1}%", 100.0 * f)).collect();
    ctx.line(&format!(
        "PERF serve traced attribution ({} spans, self-time): {}",
        trace.len(),
        shares.join(", ")
    ));
    write_json(jobs, &phases, &attribution);
    write_chaos_json(jobs, FAULT_SEED, &phases, hard_failures, degraded, chaos_retries, injected);
    write_net_json(jobs, NET_SEED, &phases, &net);
    write_artifact("results/TRACE_serve.json", &trace.to_chrome_json());
    write_artifact("results/METRICS_serve.prom", &prom);
    ctx.line("\nshape check: warm hits == jobs, warm p50 far below cold p50, chaos completes \
              every job (enforced in CI).");
}

/// Hand-rolled JSON artifact (no serde in the offline vendor set).
fn write_json(jobs: usize, phases: &[Phase], attribution: &[(String, f64)]) {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"fig_serve\",\n");
    out.push_str(&format!("  \"threads\": {},\n", crate::parallel::threads()));
    out.push_str(&format!("  \"jobs\": {jobs},\n"));
    out.push_str("  \"phases\": [\n");
    for (i, p) in phases.iter().enumerate() {
        let comma = if i + 1 < phases.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"phase\": \"{}\", \"seconds\": {:.6}, \"jobs_per_second\": {:.1}, \
             \"p50\": {:.9}, \"p95\": {:.9}, \"p99\": {:.9}, \"cache_hits\": {}}}{comma}\n",
            p.name, p.seconds, p.jobs_per_s, p.p50, p.p95, p.p99, p.cache_hits
        ));
    }
    out.push_str("  ],\n");
    // Self-time share of each span category in the traced phase — the
    // per-phase attribution the serving figure tracks over time.
    out.push_str("  \"traced_attribution\": {\n");
    for (i, (cat, f)) in attribution.iter().enumerate() {
        let comma = if i + 1 < attribution.len() { "," } else { "" };
        out.push_str(&format!("    \"{cat}\": {f:.6}{comma}\n"));
    }
    out.push_str("  }\n}\n");
    let path = "results/BENCH_serve.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// Chaos artifact for the CI robustness guard: zero hard failures and a
/// fault-off p50 within 5% of the plain cold p50 are enforced against
/// this file by the bench-smoke workflow.
#[allow(clippy::too_many_arguments)]
fn write_chaos_json(
    jobs: usize,
    fault_seed: u64,
    phases: &[Phase],
    hard_failures: u64,
    degraded: u64,
    retries: u64,
    injected: u64,
) {
    let p = |name: &str| phases.iter().find(|p| p.name == name).expect("phase recorded");
    let (cold, fault_off, chaos) = (p("cold"), p("fault_off"), p("chaos"));
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"fig_serve_chaos\",\n");
    out.push_str(&format!("  \"threads\": {},\n", crate::parallel::threads()));
    out.push_str(&format!("  \"jobs\": {jobs},\n"));
    out.push_str(&format!("  \"fault_seed\": {fault_seed},\n"));
    out.push_str(&format!("  \"hard_failures\": {hard_failures},\n"));
    out.push_str(&format!("  \"degraded\": {degraded},\n"));
    out.push_str(&format!("  \"retries\": {retries},\n"));
    out.push_str(&format!("  \"injected\": {injected},\n"));
    out.push_str(&format!("  \"cold_p50\": {:.9},\n", cold.p50));
    out.push_str(&format!("  \"fault_off_p50\": {:.9},\n", fault_off.p50));
    out.push_str(&format!("  \"chaos_p50\": {:.9}\n", chaos.p50));
    out.push_str("}\n");
    let path = "results/BENCH_chaos.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// Wire front-end artifact for the CI net guard: the socket p50 must
/// stay within 1.5x the sequential in-process p50, the chaos replay
/// must record zero hard failures and zero bitwise mismatches (with a
/// non-zero injection count proving the plan fired), and the graceful
/// drain must refuse late connects and warm-start bitwise.
fn write_net_json(jobs: usize, fault_seed: u64, phases: &[Phase], net: &NetStats) {
    let p = |name: &str| phases.iter().find(|p| p.name == name).expect("phase recorded");
    let (inproc, socket, chaos) = (p("inproc"), p("socket"), p("socket_chaos"));
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"fig_serve_net\",\n");
    out.push_str(&format!("  \"threads\": {},\n", crate::parallel::threads()));
    out.push_str(&format!("  \"jobs\": {jobs},\n"));
    out.push_str(&format!("  \"fault_seed\": {fault_seed},\n"));
    out.push_str(&format!("  \"inproc_p50\": {:.9},\n", inproc.p50));
    out.push_str(&format!("  \"socket_p50\": {:.9},\n", socket.p50));
    out.push_str(&format!("  \"socket_chaos_p50\": {:.9},\n", chaos.p50));
    out.push_str(&format!("  \"bitwise_mismatches\": {},\n", net.bitwise_mismatches));
    out.push_str(&format!("  \"chaos_hard_failures\": {},\n", net.chaos_hard_failures));
    out.push_str(&format!("  \"chaos_injected\": {},\n", net.chaos_injected));
    out.push_str(&format!("  \"busy_sheds\": {},\n", net.busy_sheds));
    out.push_str(&format!("  \"drain_refused_clean\": {},\n", net.drain_refused_clean));
    out.push_str(&format!("  \"drain_warm_hits\": {},\n", net.drain_warm_hits));
    out.push_str(&format!("  \"drain_warm_bitwise_ok\": {}\n", net.drain_warm_bitwise_ok));
    out.push_str("}\n");
    let path = "results/BENCH_net.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// Write an exported observability artifact next to the bench JSON.
fn write_artifact(path: &str, data: &str) {
    match std::fs::write(path, data) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
