//! CUR figure — `‖A − C U R‖_F / ‖A − A_k‖_F` and core-solve wall time
//! vs the Fast-GMR sketch-size multiplier, against the exact-core
//! `C† A R†` baseline, for each selection strategy.
//!
//! Expected shape: the exact core sits near ratio ≈ 1 (the selection
//! oversamples the rank), the fast core's excess over it shrinks like
//! 1/mult² (Theorem 1, same shape as fig1), and the fast solve time is
//! roughly flat in `mult` while the exact core pays a full pass over A.

use super::harness::{f4, secs, BenchCtx, Profile};
use crate::cur::{self, SelectionStrategy};
use crate::data::{synth_dense, SpectrumKind};
use crate::gmr::Input;
use crate::rng::rng;
use crate::sketch::SketchKind;

pub fn run(ctx: &mut BenchCtx) {
    let (m, n, k) = match ctx.profile {
        Profile::Quick => (700, 500, 8),
        Profile::Full => (2400, 1800, 16),
    };
    let sel = 3 * k;
    let mut r = rng(0xC04);
    let a = synth_dense(m, n, k, SpectrumKind::Exponential { base: 0.8 }, 0.02, &mut r);
    let input = Input::Dense(&a);
    let mut rak = rng(1);
    let ak = crate::svdstream::ak_error(input, k, 6, &mut rak);
    ctx.line(&format!("A: {m}x{n} rank-{k}+noise, c = r = {sel}, ‖A − A_k‖_F = {ak:.5}"));

    let strategies = [
        SelectionStrategy::Uniform,
        SelectionStrategy::Leverage,
        SelectionStrategy::SketchedLeverage { kind: SketchKind::Gaussian, size: 4 * k },
    ];
    for strategy in strategies {
        let mut rs = rng(7);
        let t0 = std::time::Instant::now();
        let (_, c) = cur::select_columns(input, &strategy, sel, &mut rs);
        let (_, rmat) = cur::select_rows(input, &strategy, sel, &mut rs);
        let t_select = t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let u_exact = cur::core_exact(input, &c, &rmat);
        let t_exact = t0.elapsed().as_secs_f64();
        let res_exact = crate::gmr::residual(input, &c, &u_exact, &rmat);
        ctx.line(&format!(
            "\n[{}] select {}, exact core {} (ratio {})",
            strategy.name(),
            secs(t_select),
            secs(t_exact),
            f4(res_exact / ak)
        ));

        let mut rows = Vec::new();
        for mult in [2usize, 4, 6, 8] {
            let mut rf = rng(100 + mult as u64);
            let t0 = std::time::Instant::now();
            let u = cur::core_fast(
                input,
                &c,
                &rmat,
                SketchKind::Gaussian,
                mult * sel,
                mult * sel,
                &mut rf,
            );
            let t_fast = t0.elapsed().as_secs_f64();
            let res = crate::gmr::residual(input, &c, &u, &rmat);
            rows.push(vec![
                mult.to_string(),
                f4(res / ak),
                f4(res / res_exact - 1.0),
                secs(t_fast),
                secs(t_exact),
            ]);
        }
        ctx.table(&["mult", "ratio", "excess_vs_exact", "t_fast", "t_exact"], &rows);
    }
    ctx.line("\nshape check: excess_vs_exact ≈ 1/mult² (Theorem 1), t_fast ≪ t_exact at scale.");
}
