//! Factorization-kernel figure — GFLOP/s and speedup-vs-seed for the
//! blocked compact-WY [`qr_thin`], the round-robin parallel
//! [`svd_jacobi`] and [`eigh`], across tall and square shapes.
//!
//! The seed kernels (column-at-a-time Householder QR, cyclic
//! strided-access Jacobi SVD/eigh) are kept **here, frozen, bench-only**
//! as the comparison baseline — no production caller reaches them; every
//! caller goes through `crate::linalg`. Expected shape: blocked QR ≥
//! 2.5x the seed on the tall 4096×512 input at default threads (the
//! trailing updates ride the blocked parallel matmul), and the Jacobi
//! kernels gain from contiguous column/row rotations plus round
//! sharding.
//!
//! Emits `results/BENCH_linalg.json` (uploaded as a CI artifact next to
//! `bench_smoke.json`) and `PERF`-prefixed stdout lines the CI bench
//! step greps into the log, so seed-vs-current regressions are visible
//! per-PR. The §Perf log in EXPERIMENTS.md tracks these numbers.

use super::harness::{secs, BenchCtx, Profile};
use crate::linalg::{eigh, qr_thin, svd_jacobi, Mat};
use crate::rng::rng;

/// One measured row for the JSON artifact.
struct Row {
    kernel: &'static str,
    m: usize,
    n: usize,
    seed_s: f64,
    new_s: f64,
    flops: f64,
}

impl Row {
    fn speedup(&self) -> f64 {
        self.seed_s / self.new_s
    }
    fn gflops(&self) -> f64 {
        self.flops / self.new_s / 1e9
    }
    fn seed_gflops(&self) -> f64 {
        self.flops / self.seed_s / 1e9
    }
}

/// Nominal QR flop count (factor + thin-Q formation), `k = min(m, n)`:
/// `4mnk − (4/3)k³`. Nominal — used consistently for seed and current,
/// so the speedup column is an exact time ratio.
fn qr_flops(m: usize, n: usize) -> f64 {
    let k = m.min(n) as f64;
    4.0 * m as f64 * n as f64 * k - 4.0 / 3.0 * k * k * k
}

/// Nominal one-sided Jacobi flop model: 8 sweeps × n(n−1)/2 pairs ×
/// 6(2m + n) flops per pair (Gram dots + U and V rotations). A
/// throughput *index* (actual sweep counts vary), identical for both
/// implementations.
fn svd_flops(m: usize, n: usize) -> f64 {
    8.0 * (n * (n - 1) / 2) as f64 * 6.0 * (2 * m + n) as f64
}

/// Nominal two-sided Jacobi flop model: 8 sweeps × n(n−1)/2 pairs ×
/// 12n flops per pair (row + column + V rotations).
fn eigh_flops(n: usize) -> f64 {
    8.0 * (n * (n - 1) / 2) as f64 * 12.0 * n as f64
}

pub fn run(ctx: &mut BenchCtx) {
    let (qr_shapes, svd_shapes, eig_sizes): (&[(usize, usize)], &[(usize, usize)], &[usize]) =
        match ctx.profile {
            Profile::Quick => (&[(4096, 512), (1024, 1024)], &[(512, 128), (256, 256)], &[256]),
            Profile::Full => (
                &[(4096, 512), (8192, 1024), (2048, 2048), (1024, 4096)],
                &[(1024, 256), (512, 512)],
                &[256, 512],
            ),
        };
    ctx.line(&format!("threads = {}", crate::parallel::threads()));
    let mut rows: Vec<Row> = Vec::new();

    ctx.line("\n-- qr_thin: blocked compact-WY vs seed column-at-a-time --");
    for &(m, n) in qr_shapes {
        let mut r = rng(0x11);
        let a = Mat::randn(m, n, &mut r);
        let seed_s = ctx.time_n(&format!("seed qr {m}x{n}"), 1, || {
            std::hint::black_box(seed_qr_thin(&a));
        });
        let new_s = ctx.time_n(&format!("blocked qr {m}x{n}"), 3, || {
            std::hint::black_box(qr_thin(&a));
        });
        rows.push(Row { kernel: "qr_thin", m, n, seed_s, new_s, flops: qr_flops(m, n) });
    }

    ctx.line("\n-- svd_jacobi: round-robin parallel vs seed cyclic --");
    for &(m, n) in svd_shapes {
        let mut r = rng(0x12);
        let a = Mat::randn(m, n, &mut r);
        let seed_s = ctx.time_n(&format!("seed svd {m}x{n}"), 1, || {
            std::hint::black_box(seed_svd_jacobi(&a));
        });
        let new_s = ctx.time_n(&format!("parallel svd {m}x{n}"), 3, || {
            std::hint::black_box(svd_jacobi(&a));
        });
        rows.push(Row { kernel: "svd_jacobi", m, n, seed_s, new_s, flops: svd_flops(m, n) });
    }

    ctx.line("\n-- eigh: round-robin parallel vs seed cyclic --");
    for &n in eig_sizes {
        let mut r = rng(0x13);
        let b = Mat::randn(n, n, &mut r);
        let a = &b + &b.transpose();
        let seed_s = ctx.time_n(&format!("seed eigh {n}"), 1, || {
            std::hint::black_box(seed_eigh(&a));
        });
        let new_s = ctx.time_n(&format!("parallel eigh {n}"), 3, || {
            std::hint::black_box(eigh(&a));
        });
        rows.push(Row { kernel: "eigh", m: n, n, seed_s, new_s, flops: eigh_flops(n) });
    }

    // Table + grep-able PERF lines (the CI bench-smoke step surfaces
    // these in the workflow log).
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kernel.to_string(),
                format!("{}x{}", r.m, r.n),
                secs(r.seed_s),
                secs(r.new_s),
                format!("{:.2}", r.speedup()),
                format!("{:.2}", r.seed_gflops()),
                format!("{:.2}", r.gflops()),
            ]
        })
        .collect();
    ctx.line("");
    ctx.table(&["kernel", "shape", "t_seed", "t_new", "speedup", "seed_GF/s", "GF/s"], &table);
    for r in &rows {
        ctx.line(&format!(
            "PERF {} {}x{}: seed {} -> {} ({:.2}x, {:.2} GF/s)",
            r.kernel,
            r.m,
            r.n,
            secs(r.seed_s),
            secs(r.new_s),
            r.speedup(),
            r.gflops()
        ));
    }
    write_json(&rows);
    ctx.line("\nshape check: qr_thin 4096x512 speedup >= 2.5x at default threads (acceptance bar).");
}

/// Hand-rolled JSON artifact (no serde in the offline vendor set).
fn write_json(rows: &[Row]) {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"fig_linalg\",\n");
    out.push_str(&format!("  \"threads\": {},\n", crate::parallel::threads()));
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"m\": {}, \"n\": {}, \"seed_seconds\": {:.6}, \"seconds\": {:.6}, \"seed_gflops\": {:.3}, \"gflops\": {:.3}, \"speedup\": {:.3}}}{comma}\n",
            r.kernel, r.m, r.n, r.seed_s, r.new_s, r.seed_gflops(), r.gflops(), r.speedup()
        ));
    }
    out.push_str("  ]\n}\n");
    let path = "results/BENCH_linalg.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

// ---------------------------------------------------------------------------
// Frozen seed kernels (baseline for the speedup columns). These are the
// pre-PR-3 implementations, kept verbatim and bench-local: production
// code must never call them.
// ---------------------------------------------------------------------------

/// Seed `qr_thin`: column-at-a-time Householder with strided
/// `r_work[(i, col)]` access.
fn seed_qr_thin(a: &Mat) -> (Mat, Mat) {
    let (m, n) = a.shape();
    let k = m.min(n);
    let mut r_work = a.clone();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);
    let mut betas = Vec::with_capacity(k);

    for j in 0..k {
        let mut v: Vec<f64> = (j..m).map(|i| r_work[(i, j)]).collect();
        let alpha = {
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if v[0] >= 0.0 {
                -norm
            } else {
                norm
            }
        };
        if alpha == 0.0 {
            vs.push(v);
            betas.push(0.0);
            continue;
        }
        v[0] -= alpha;
        let vnorm_sq: f64 = v.iter().map(|x| x * x).sum();
        let beta = if vnorm_sq == 0.0 { 0.0 } else { 2.0 / vnorm_sq };
        for col in j..n {
            let mut dot = 0.0;
            for (t, i) in (j..m).enumerate() {
                dot += v[t] * r_work[(i, col)];
            }
            let s = beta * dot;
            if s != 0.0 {
                for (t, i) in (j..m).enumerate() {
                    r_work[(i, col)] -= s * v[t];
                }
            }
        }
        vs.push(v);
        betas.push(beta);
    }

    let mut r = Mat::zeros(k, n);
    for i in 0..k {
        for j in i..n {
            r[(i, j)] = r_work[(i, j)];
        }
    }
    let mut q = Mat::zeros(m, k);
    for i in 0..k {
        q[(i, i)] = 1.0;
    }
    for j in (0..k).rev() {
        let (v, beta) = (&vs[j], betas[j]);
        if beta == 0.0 {
            continue;
        }
        for col in 0..k {
            let mut dot = 0.0;
            for (t, i) in (j..m).enumerate() {
                dot += v[t] * q[(i, col)];
            }
            let s = beta * dot;
            if s != 0.0 {
                for (t, i) in (j..m).enumerate() {
                    q[(i, col)] -= s * v[t];
                }
            }
        }
    }
    (q, r)
}

/// Seed `svd_jacobi`: cyclic one-sided Jacobi with strided column walks.
fn seed_svd_jacobi(a: &Mat) -> (Mat, Vec<f64>, Mat) {
    let (m, n) = a.shape();
    if m < n {
        let (u, s, v) = seed_svd_jacobi(&a.transpose());
        return (v, s, u);
    }
    let mut u = a.clone();
    let mut v = Mat::eye(n);
    let tol = 1e-15;
    let max_sweeps = 64;

    for _sweep in 0..max_sweeps {
        let mut rotated = false;
        for p in 0..n {
            for q in (p + 1)..n {
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= tol * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                rotated = true;
                let theta = (aqq - app) / (2.0 * apq);
                let t = {
                    let sgn = if theta >= 0.0 { 1.0 } else { -1.0 };
                    sgn / (theta.abs() + (theta * theta + 1.0).sqrt())
                };
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    u[(i, p)] = c * up - s * uq;
                    u[(i, q)] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if !rotated {
            break;
        }
    }

    let mut sv: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm: f64 = (0..m).map(|i| u[(i, j)] * u[(i, j)]).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    sv.sort_by(|a, b| b.0.total_cmp(&a.0));

    let mut u_out = Mat::zeros(m, n);
    let mut v_out = Mat::zeros(n, n);
    let mut s_out = Vec::with_capacity(n);
    for (oj, &(norm, j)) in sv.iter().enumerate() {
        s_out.push(norm);
        if norm > 0.0 {
            for i in 0..m {
                u_out[(i, oj)] = u[(i, j)] / norm;
            }
        }
        for i in 0..n {
            v_out[(i, oj)] = v[(i, j)];
        }
    }
    (u_out, s_out, v_out)
}

/// Seed `eigh`: cyclic two-sided Jacobi with per-pair row+column
/// rotations over strided indices.
fn seed_eigh(a: &Mat) -> (Vec<f64>, Mat) {
    let n = a.rows();
    let mut m = a.clone();
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    let tol = 1e-14 * m.fro_norm().max(1e-300);

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= tol * 1e-2 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = {
                    let s = if theta >= 0.0 { 1.0 } else { -1.0 };
                    s / (theta.abs() + (theta * theta + 1.0).sqrt())
                };
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&a, &b| diag[b].total_cmp(&diag[a]));
    let values: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let vectors = v.select_cols(&order);
    (values, vectors)
}
