//! Figure 1 — Fast GMR error ratio vs sketch-size multiplier `a`.
//!
//! Paper setup (§6.1): C = A·G_C, R = G_R·A with c = r = 20; sketches are
//! Gaussian for dense datasets (a = 2..12) and CountSketch for sparse
//! ones (a = 3..13); error ratio = ‖A − CX̃R‖/‖A − CC†AR†R‖ − 1.
//! Expected shape: error ratio ≈ linear in 1/a², reaching ≲0.05 by a=10.

use super::harness::{f4, BenchCtx, Profile};
use crate::data::{matrix_registry, Dataset};
use crate::gmr::{relative_regret, solve_exact, solve_fast, FastGmrConfig, Input};
use crate::linalg::Mat;
use crate::rng::rng;

const C_DIM: usize = 20;
const R_DIM: usize = 20;

pub fn run(ctx: &mut BenchCtx) {
    let trials = match ctx.profile {
        Profile::Quick => 2,
        Profile::Full => 3,
    };
    for spec in matrix_registry() {
        let mut r = rng(0xF16_1 + spec.name.len() as u64);
        // Quick profile shrinks every dataset ~4x per side (sparse keeps
        // its density so the CountSketch O(nnz) path is still exercised).
        let (m, n) = match ctx.profile {
            Profile::Full => spec.run_shape,
            Profile::Quick => (spec.run_shape.0.min(1600), spec.run_shape.1.min(1400)),
        };
        let shrunk = crate::data::DatasetSpec { run_shape: (m, n), ..spec };
        let data = shrunk.load(&mut r);
        let sparse = shrunk.density.is_some();
        ctx.line(&format!(
            "\n[{}] {}x{} ({}) — {} sketch",
            shrunk.name,
            m,
            n,
            if sparse { "sparse" } else { "dense" },
            if sparse { "count" } else { "gaussian" }
        ));

        let input = match &data {
            Dataset::Dense(a) => Input::Dense(a),
            Dataset::Sparse(a) => Input::Sparse(a),
        };

        // C = A G_C, R = G_R A (Gaussian factors, as in the paper).
        let g_c = Mat::randn(n, C_DIM, &mut r);
        let c = input.a_b(&g_c);
        let g_r = Mat::randn(R_DIM, m, &mut r);
        let rr = input.at_b(&g_r.transpose()).transpose();

        let (exact, _t_exact) = ctx.time("exact GMR", || solve_exact(input, &c, &rr));
        let rho = crate::gmr::compute_rho(input, &c, &rr);
        ctx.line(&format!("  rho = {:.3}", rho.rho()));

        let a_values: &[usize] = if sparse { &[3, 5, 7, 9, 11, 13] } else { &[2, 4, 6, 8, 10, 12] };
        let mut rows = Vec::new();
        for &a in a_values {
            let mut acc = 0.0;
            let mut t_total = 0.0;
            for t in 0..trials {
                let mut rt = rng(1000 + a as u64 * 31 + t as u64);
                let cfg = if sparse {
                    FastGmrConfig::count(a * C_DIM, a * R_DIM)
                } else {
                    FastGmrConfig::gaussian(a * C_DIM, a * R_DIM)
                };
                let start = std::time::Instant::now();
                let sol = solve_fast(input, &c, &rr, &cfg, &mut rt);
                t_total += start.elapsed().as_secs_f64();
                acc += relative_regret(input, &c, &rr, &sol.x, &exact.x);
            }
            let ratio = acc / trials as f64;
            rows.push(vec![
                a.to_string(),
                f4(ratio),
                f4(1.0 / (a * a) as f64),
                f4(ratio * (a * a) as f64),
                format!("{:.3}s", t_total / trials as f64),
            ]);
        }
        ctx.table(&["a", "error_ratio", "1/a^2", "ratio*a^2", "t_fast"], &rows);
    }
    ctx.line("\nshape check: ratio*a^2 ≈ constant ⇒ error ratio is linear in 1/a² (Theorem 1's ε^{-1/2} sketch-size bound).");
}
