//! Benchmark harness — regenerates every table and figure of the paper's
//! evaluation (Section 6) plus the §Perf microbenchmarks.
//!
//! Run through `cargo bench` (custom harness):
//!
//! ```text
//! cargo bench                      # everything, quick profile
//! cargo bench -- fig1              # one target
//! cargo bench -- fig2 --full       # paper-scale sizes
//! cargo bench -- list              # show targets
//! ```
//!
//! Output goes to stdout and `results/<target>.txt`. The paper mapping
//! for each target is documented in DESIGN.md §5; the expected *shapes*
//! (who wins, by what factor, where crossovers fall) are asserted in the
//! end-to-end tests and recorded in EXPERIMENTS.md.

pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig_cur;
pub mod fig_curstream;
pub mod fig_epsilon;
pub mod fig_gemm;
pub mod fig_linalg;
pub mod fig_serve;
pub mod harness;
pub mod perf;
pub mod tables;

pub use harness::{BenchCtx, Profile};

/// All bench targets in run order.
pub fn targets() -> Vec<(&'static str, fn(&mut BenchCtx))> {
    vec![
        ("table1", tables::table1 as fn(&mut BenchCtx)),
        ("table2", tables::table2),
        ("table3", tables::table3),
        ("table4", tables::table4),
        ("table5", tables::table5),
        ("table6", tables::table6),
        ("fig1", fig1::run),
        ("fig2", fig2::run),
        ("table7", fig2::run_table7),
        ("fig3", fig3::run),
        ("fig_cur", fig_cur::run),
        ("fig_curstream", fig_curstream::run),
        ("fig_epsilon", fig_epsilon::run),
        ("fig_gemm", fig_gemm::run),
        ("fig_linalg", fig_linalg::run),
        ("fig_serve", fig_serve::run),
        ("perf", perf::run),
    ]
}

/// Targets run by `--smoke` when none are named explicitly: one table,
/// the figures that track per-PR perf (fig_cur for the CUR workload,
/// fig_curstream for streaming-vs-in-memory CUR, fig_epsilon for the
/// ε-planner's attainment/escalation guard, fig_gemm for the packed
/// GEMM vs its frozen seed kernels, fig_linalg for the factorization
/// kernels vs theirs, fig_serve for warm-cache serving latency), and the
/// microbenchmarks — enough to catch a perf regression without
/// paper-scale runtimes.
const SMOKE_TARGETS: [&str; 9] = [
    "table1",
    "fig1",
    "fig_cur",
    "fig_curstream",
    "fig_epsilon",
    "fig_gemm",
    "fig_linalg",
    "fig_serve",
    "perf",
];

/// Entry point used by `rust/benches/bench_main.rs`.
///
/// `--full` runs paper-scale sizes; `--smoke` runs the reduced CI subset
/// at the quick profile and writes per-target wall times to
/// `results/bench_smoke.json` (uploaded as a CI artifact so perf
/// regressions are visible per-PR).
pub fn bench_main(args: &[String]) {
    let smoke = args.iter().any(|a| a == "--smoke");
    let profile = if args.iter().any(|a| a == "--full") { Profile::Full } else { Profile::Quick };
    let wanted: Vec<&String> = args.iter().filter(|a| !a.starts_with("--")).collect();
    if wanted.iter().any(|a| a.as_str() == "list") {
        for (name, _) in targets() {
            println!("{name}");
        }
        return;
    }
    std::fs::create_dir_all("results").ok();
    let mut timings: Vec<(&'static str, f64)> = Vec::new();
    for (name, f) in targets() {
        let selected = if !wanted.is_empty() {
            wanted.iter().any(|w| w.as_str() == name)
        } else if smoke {
            SMOKE_TARGETS.contains(&name)
        } else {
            true
        };
        if !selected {
            continue;
        }
        let mut ctx = BenchCtx::new(name, profile);
        let start = std::time::Instant::now();
        f(&mut ctx);
        let elapsed = start.elapsed();
        ctx.finish(elapsed);
        timings.push((name, elapsed.as_secs_f64()));
    }
    if smoke {
        write_smoke_json(&timings);
    }
}

/// Serialize smoke timings as JSON by hand (no serde in the offline
/// vendor set).
fn write_smoke_json(timings: &[(&str, f64)]) {
    let mut out = String::from("{\n");
    out.push_str("  \"mode\": \"smoke\",\n");
    out.push_str(&format!("  \"threads\": {},\n", crate::parallel::threads()));
    out.push_str("  \"targets\": [\n");
    for (i, (name, secs)) in timings.iter().enumerate() {
        let comma = if i + 1 < timings.len() { "," } else { "" };
        out.push_str(&format!("    {{\"name\": \"{name}\", \"seconds\": {secs:.6}}}{comma}\n"));
    }
    out.push_str("  ]\n}\n");
    let path = "results/bench_smoke.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
