//! Benchmark harness — regenerates every table and figure of the paper's
//! evaluation (Section 6) plus the §Perf microbenchmarks.
//!
//! Run through `cargo bench` (custom harness):
//!
//! ```text
//! cargo bench                      # everything, quick profile
//! cargo bench -- fig1              # one target
//! cargo bench -- fig2 --full       # paper-scale sizes
//! cargo bench -- list              # show targets
//! ```
//!
//! Output goes to stdout and `results/<target>.txt`. The paper mapping
//! for each target is documented in DESIGN.md §5; the expected *shapes*
//! (who wins, by what factor, where crossovers fall) are asserted in the
//! end-to-end tests and recorded in EXPERIMENTS.md.

pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod harness;
pub mod perf;
pub mod tables;

pub use harness::{BenchCtx, Profile};

/// All bench targets in run order.
pub fn targets() -> Vec<(&'static str, fn(&mut BenchCtx))> {
    vec![
        ("table1", tables::table1 as fn(&mut BenchCtx)),
        ("table2", tables::table2),
        ("table3", tables::table3),
        ("table4", tables::table4),
        ("table5", tables::table5),
        ("table6", tables::table6),
        ("fig1", fig1::run),
        ("fig2", fig2::run),
        ("table7", fig2::run_table7),
        ("fig3", fig3::run),
        ("perf", perf::run),
    ]
}

/// Entry point used by `rust/benches/bench_main.rs`.
pub fn bench_main(args: &[String]) {
    let profile = if args.iter().any(|a| a == "--full") { Profile::Full } else { Profile::Quick };
    let wanted: Vec<&String> = args.iter().filter(|a| !a.starts_with("--")).collect();
    if wanted.iter().any(|a| a.as_str() == "list") {
        for (name, _) in targets() {
            println!("{name}");
        }
        return;
    }
    std::fs::create_dir_all("results").ok();
    for (name, f) in targets() {
        if !wanted.is_empty() && !wanted.iter().any(|w| w.as_str() == name) {
            continue;
        }
        let mut ctx = BenchCtx::new(name, profile);
        let start = std::time::Instant::now();
        f(&mut ctx);
        ctx.finish(start.elapsed());
    }
}
