//! Figure 3 — single-pass SVD comparison: Fast SP-SVD (Algorithm 3) vs
//! Practical SP-SVD (Algorithm 4, Tropp et al. 2017).
//!
//! Paper setup (§6.3): k = 10; x-axis is (c+r)/k; Fast SP-SVD uses c = r
//! and s_c = 3c·√a; Practical SP-SVD splits the same (c+r) budget with
//! its recommended r ≈ 2c ratio. Gaussian sketches for dense datasets,
//! CountSketch for sparse. Error ratio = ‖A − UΣVᵀ‖/‖A − A_k‖ − 1
//! (can be negative: factor rank > k).
//!
//! Expected shape: Fast SP-SVD below Practical SP-SVD everywhere, with
//! the largest gap at small budgets.

use super::harness::{f4, BenchCtx, Profile};
use crate::data::{matrix_registry, Dataset};
use crate::gmr::Input;
use crate::rng::rng;
use crate::sketch::SketchKind;
use crate::svdstream::source::{ColumnStream, CsrColumnStream, DenseColumnStream};
use crate::svdstream::{
    ak_error, fast_sp_svd, practical_sp_svd, reconstruction_error_input, FastSpSvdConfig,
    PracticalSpSvdConfig,
};

const K: usize = 10;

pub fn run(ctx: &mut BenchCtx) {
    let trials = 2;
    let mults: &[usize] = &[2, 3, 4, 6, 8];
    for spec in matrix_registry() {
        let mut r = rng(0xF16_3 + spec.name.len() as u64);
        let (m, n) = match ctx.profile {
            Profile::Full => spec.run_shape,
            Profile::Quick => (spec.run_shape.0.min(1500), spec.run_shape.1.min(1200)),
        };
        let shrunk = crate::data::DatasetSpec { run_shape: (m, n), ..spec };
        let data = shrunk.load(&mut r);
        let sparse = shrunk.density.is_some();
        let kind = if sparse { SketchKind::Count } else { SketchKind::Gaussian };
        let input = match &data {
            Dataset::Dense(a) => Input::Dense(a),
            Dataset::Sparse(a) => Input::Sparse(a),
        };
        let (ak, _) = ctx.time("‖A − A_k‖", || ak_error(input, K, 6, &mut r));
        ctx.line(&format!(
            "\n[{}] {}x{} ({}) — ak_err={:.4}",
            shrunk.name,
            m,
            n,
            if sparse { "sparse/count" } else { "dense/gaussian" },
            ak
        ));

        let block = 256;
        let mut rows = Vec::new();
        for &mult in mults {
            let budget = 2 * mult * K; // c + r
            let mut fast_acc = 0.0;
            let mut prac_acc = 0.0;
            let mut t_fast = 0.0;
            let mut t_prac = 0.0;
            for t in 0..trials {
                let mut rt = rng(4000 + mult as u64 * 101 + t as u64);

                let cfg_f = FastSpSvdConfig::paper(K, mult, kind);
                let start = std::time::Instant::now();
                let res_f = run_stream(&data, block, |s| fast_sp_svd(s, &cfg_f, &mut rt));
                t_fast += start.elapsed().as_secs_f64();
                fast_acc += reconstruction_error_input(input, &res_f) / ak - 1.0;

                let cfg_p = PracticalSpSvdConfig::from_budget(K, budget, kind);
                let start = std::time::Instant::now();
                let res_p = run_stream(&data, block, |s| practical_sp_svd(s, &cfg_p, &mut rt));
                t_prac += start.elapsed().as_secs_f64();
                prac_acc += reconstruction_error_input(input, &res_p) / ak - 1.0;
            }
            rows.push(vec![
                format!("{}", 2 * mult),
                f4(fast_acc / trials as f64),
                f4(prac_acc / trials as f64),
                format!("{:.2}s", t_fast / trials as f64),
                format!("{:.2}s", t_prac / trials as f64),
            ]);
        }
        ctx.table(&["(c+r)/k", "fast(ours)", "practical", "t_fast", "t_prac"], &rows);
    }
    ctx.line("\nshape check: fast(ours) < practical at every budget; the gap shrinks as the budget grows.");
}

fn run_stream<F>(data: &Dataset, block: usize, f: F) -> crate::svdstream::SpSvdResult
where
    F: FnOnce(&mut dyn ColumnStream) -> crate::error::Result<crate::svdstream::SpSvdResult>,
{
    let res = match data {
        Dataset::Dense(a) => {
            let mut s = DenseColumnStream::new(a, block);
            f(&mut s)
        }
        Dataset::Sparse(a) => {
            let mut s = CsrColumnStream::new(a, block);
            f(&mut s)
        }
    };
    res.expect("in-memory bench stream cannot fail")
}
