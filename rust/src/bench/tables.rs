//! Tables 1–6: the paper's analytical tables, reproduced as empirical
//! measurements (sketch-property sweeps, sketch-size/time trade-offs,
//! entries-observed accounting, dataset registries).

use super::harness::{f4, secs, BenchCtx, Profile};
use crate::data::{kernel_registry, matrix_registry, rbf_kernel, Dataset};
use crate::gmr::{relative_regret, solve_exact, solve_fast, FastGmrConfig, Input, SymGmrConfig};
use crate::linalg::{eigh, matmul, matmul_at_b, qr_thin, Mat};
use crate::rng::rng;
use crate::sketch::{Sketch, SketchKind};
use crate::spsd::{error_ratio, faster_spsd, CountingOracle, DenseKernelOracle, FasterSpsdConfig};

/// Table 1 — the two sketching properties of Lemma 1, measured:
/// property 1 (subspace embedding distortion η) and property 2
/// (matrix-multiplication error ε·√s, which should be ~constant in s).
pub fn table1(ctx: &mut BenchCtx) {
    let m = match ctx.profile {
        Profile::Quick => 512,
        Profile::Full => 2048,
    };
    let k = 10;
    let mut r = rng(0x7AB1);
    let u = qr_thin(&Mat::randn(m, k, &mut r)).q;
    let scores = u.row_norms_sq();
    let b1 = Mat::randn(m, 8, &mut r);
    let b2 = Mat::randn(m, 6, &mut r);
    let exact = matmul_at_b(&b2, &b1);
    let denom = b1.fro_norm() * b2.fro_norm();

    let mut rows = Vec::new();
    for kind in SketchKind::all() {
        let mut row = vec![kind.name().to_string()];
        for &s in &[4 * k, 16 * k, 32 * k] {
            // Property 1: worst singular-value distortion of S·U.
            let mut eta_max: f64 = 0.0;
            let mut amm: f64 = 0.0;
            let trials = 8;
            for t in 0..trials {
                let mut rt = rng(100 + s as u64 * 7 + t);
                let sk = Sketch::draw(kind, s, m, Some(&scores), &mut rt);
                let su = sk.apply_left(&u);
                let e = eigh(&matmul_at_b(&su, &su));
                eta_max = eta_max.max((e.values[0] - 1.0).abs()).max((1.0 - e.values[k - 1]).abs());
                // Property 2: ‖BᵀSᵀSA − BᵀA‖ / (‖A‖‖B‖), scaled by √s.
                let sa = sk.apply_left(&b1);
                let sb = sk.apply_left(&b2);
                let approx = matmul_at_b(&sb, &sa);
                amm += crate::linalg::fro_norm_diff(&approx, &exact) / denom;
            }
            row.push(f4(eta_max));
            row.push(f4(amm / trials as f64 * (s as f64).sqrt()));
        }
        rows.push(row);
    }
    ctx.line(&format!("m={m}, k={k}; columns per s: (eta_max, eps*sqrt(s))"));
    ctx.table(
        &["sketch", "η@4k", "ε√s@4k", "η@16k", "ε√s@16k", "η@32k", "ε√s@32k"],
        &rows,
    );
    ctx.line("\nshape check: η shrinks with s; ε·√s ≈ constant per family (property 2's 1/√s rate).");
}

/// Table 2 — Fast GMR per sketching family: sketch time T_sketch, solve
/// time, and achieved error ratio at the theory-suggested sizes.
pub fn table2(ctx: &mut BenchCtx) {
    let (m, n) = match ctx.profile {
        Profile::Quick => (1500, 1200),
        Profile::Full => (6000, 5000),
    };
    let (c_dim, r_dim) = (20, 20);
    let mut r = rng(0x7AB2);
    let a = crate::data::synth_dense(m, n, 60, crate::data::SpectrumKind::Exponential { base: 0.92 }, 0.02, &mut r);
    let g_c = Mat::randn(n, c_dim, &mut r);
    let c = matmul(&a, &g_c);
    let g_r = Mat::randn(r_dim, m, &mut r);
    let rr = matmul(&g_r, &a);
    let exact = solve_exact(Input::Dense(&a), &c, &rr);
    let rho = crate::gmr::compute_rho(Input::Dense(&a), &c, &rr);
    ctx.line(&format!("A {m}x{n}, c=r=20, rho={:.3}", rho.rho()));

    let s = 8 * c_dim;
    let mut rows = Vec::new();
    for kind in SketchKind::all() {
        let mut rt = rng(0xBEEF + kind.name().len() as u64);
        let cfg = FastGmrConfig::uniform_kind(kind, s, s);
        let start = std::time::Instant::now();
        let sol = solve_fast(Input::Dense(&a), &c, &rr, &cfg, &mut rt);
        let t_total = start.elapsed().as_secs_f64();
        let regret = relative_regret(Input::Dense(&a), &c, &rr, &sol.x, &exact.x);
        rows.push(vec![
            kind.name().to_string(),
            format!("{s}"),
            secs(t_total),
            f4(regret),
            theory_size(kind),
        ]);
    }
    ctx.table(&["sketch", "s_c=s_r", "t_fastGMR", "error_ratio", "theory s (Table 2)"], &rows);
    let (_, t_exact) = ctx.time("exact GMR", || solve_exact(Input::Dense(&a), &c, &rr));
    ctx.line(&format!("exact GMR time: {} — speedup factors above are t_exact/t_fast", secs(t_exact)));
}

fn theory_size(kind: SketchKind) -> String {
    match kind {
        SketchKind::Gaussian => "max{c/√ε, c/(ερ²)}".into(),
        SketchKind::Leverage | SketchKind::Srht => "max{c/√ε, c/(ερ²)} + c·log c".into(),
        SketchKind::Count => "max{c/√ε, c/(ερ²)} + c²".into(),
        SketchKind::Osnap | SketchKind::OsnapGaussian => "max{c/√ε, c/(ερ²)} + c^{1+γ}".into(),
        SketchKind::Uniform => "(coherence-dependent)".into(),
    }
}

/// Table 3 — the symmetric (C = Rᵀ) case: per family, error ratio of the
/// symmetric Fast GMR (Theorem 2) on an RBF kernel.
pub fn table3(ctx: &mut BenchCtx) {
    let n = match ctx.profile {
        Profile::Quick => 800,
        Profile::Full => 2000,
    };
    let mut r = rng(0x7AB3);
    let x = crate::data::synth_clustered(n, 20, 10, 0.4, &mut r);
    let sigma = crate::data::calibrate_sigma(&x, 15, 0.85, &mut r);
    let k = rbf_kernel(&x, sigma);
    let c_dim = 30;
    let idx = r.sample_without_replacement(n, c_dim);
    let c = k.select_cols(&idx);
    let rho_sym = crate::gmr::compute_rho_symmetric(Input::Dense(&k), &c);
    ctx.line(&format!("K {n}x{n} (RBF, sigma={sigma:.4}), c={c_dim}, rho_sym={rho_sym:.3}"));

    let opt = solve_exact(Input::Dense(&k), &c, &c.transpose());
    let e_opt = crate::gmr::residual(Input::Dense(&k), &c, &opt.x, &c.transpose()) / k.fro_norm();
    let mut rows = vec![vec!["optimal".to_string(), "-".into(), "-".into(), f4(e_opt)]];
    for kind in [SketchKind::Leverage, SketchKind::Gaussian, SketchKind::Srht, SketchKind::Count, SketchKind::Osnap] {
        let mut rt = rng(0xCAFE + kind.name().len() as u64);
        let s = 8 * c_dim;
        let cfg = SymGmrConfig { kind, s };
        let start = std::time::Instant::now();
        let xsym = crate::gmr::solve_fast_symmetric(Input::Dense(&k), &c, &cfg, &mut rt);
        let t = start.elapsed().as_secs_f64();
        let e = crate::gmr::residual(Input::Dense(&k), &c, &xsym, &c.transpose()) / k.fro_norm();
        rows.push(vec![kind.name().to_string(), format!("{s}"), secs(t), f4(e)]);
    }
    ctx.table(&["sketch", "s", "time", "‖K−CXCᵀ‖/‖K‖"], &rows);
}

/// Table 4 — entries of K observed: fast SPSD (Wang 2016b) vs Algorithm 2
/// at matching target ε, measured with the counting oracle.
pub fn table4(ctx: &mut BenchCtx) {
    let n = match ctx.profile {
        Profile::Quick => 1200,
        Profile::Full => 4000,
    };
    let mut r = rng(0x7AB4);
    let x = crate::data::synth_clustered(n, 16, 10, 0.4, &mut r);
    let sigma = crate::data::calibrate_sigma(&x, 15, 0.9, &mut r);
    let k = rbf_kernel(&x, sigma);
    let oracle = DenseKernelOracle { k: &k };
    let c_dim = 30;
    ctx.line(&format!("K {n}x{n}, c={c_dim}; entries observed to reach each target s"));

    let mut rows = Vec::new();
    for &eps in &[0.5f64, 0.25, 0.1, 0.05] {
        // Our Algorithm 2: s = c/sqrt(eps) (+ c log c), entries = nc + s².
        let s_ours = ((c_dim as f64) / eps.sqrt() + (c_dim as f64) * (c_dim as f64).ln() / 4.0)
            .ceil() as usize;
        let s_ours = s_ours.min(n);
        let counting = CountingOracle::new(&oracle);
        let mut rt = rng(500 + (eps * 1000.0) as u64);
        let sol = faster_spsd(&counting, &FasterSpsdConfig { c: c_dim, s: s_ours }, &mut rt);
        let obs_ours = counting.observed();
        let e_ours = error_ratio(&k, &sol.c, &sol.x);

        // Wang et al. 2016b: s = c·sqrt(n/eps) (capped at n), single sketch.
        let s_wang = (((c_dim as f64) * (n as f64 / eps).sqrt()).ceil() as usize).min(n);
        let counting2 = CountingOracle::new(&oracle);
        let idx = rt.sample_without_replacement(n, c_dim);
        let c_mat = crate::spsd::KernelOracle::columns(&counting2, &idx);
        let x_wang = crate::spsd::fast_spsd_core(&counting2, &c_mat, s_wang, &mut rt);
        let obs_wang = counting2.observed();
        let e_wang = error_ratio(&k, &c_mat, &x_wang);

        rows.push(vec![
            format!("{eps}"),
            format!("{s_ours}"),
            format!("{obs_ours}"),
            f4(e_ours),
            format!("{s_wang}"),
            format!("{obs_wang}"),
            f4(e_wang),
        ]);
    }
    ctx.table(
        &["ε", "s(ours)", "entries(ours)", "err(ours)", "s(wang)", "entries(wang)", "err(wang)"],
        &rows,
    );
    ctx.line(&format!("\nfull kernel would be n² = {} entries; shape check: ours observes ~nc + c²/ε ≪ wang's nc + c²n/ε.", n * n));
}

/// Table 5 — the GMR/SVD dataset registry with measured properties.
pub fn table5(ctx: &mut BenchCtx) {
    let mut rows = Vec::new();
    for spec in matrix_registry() {
        let mut r = rng(0x7AB5);
        let (m, n) = match ctx.profile {
            Profile::Full => spec.run_shape,
            Profile::Quick => (spec.run_shape.0.min(1200), spec.run_shape.1.min(1000)),
        };
        let shrunk = crate::data::DatasetSpec { run_shape: (m, n), ..spec };
        let data = shrunk.load(&mut r);
        let (density, fro) = match &data {
            Dataset::Dense(a) => (1.0, a.fro_norm()),
            Dataset::Sparse(a) => (a.density(), a.fro_norm()),
        };
        rows.push(vec![
            shrunk.name.to_string(),
            format!("{}x{}", shrunk.paper_shape.0, shrunk.paper_shape.1),
            format!("{}x{}", m, n),
            if shrunk.density.is_some() { format!("{:.3}%", density * 100.0) } else { "dense".into() },
            format!("{fro:.1}"),
        ]);
    }
    ctx.table(&["dataset", "paper shape", "run shape", "sparsity", "‖A‖_F"], &rows);
}

/// Table 6 — kernel datasets: calibrated σ and achieved η vs the paper.
pub fn table6(ctx: &mut BenchCtx) {
    let mut rows = Vec::new();
    for spec in kernel_registry() {
        let mut r = rng(0x7AB6);
        let (n, d) = match ctx.profile {
            Profile::Full => spec.run_shape,
            Profile::Quick => (spec.run_shape.0.min(800), spec.run_shape.1.min(150)),
        };
        let shrunk = crate::data::KernelSpec { run_shape: (n, d), ..spec };
        let (x, sigma) = shrunk.load(&mut r);
        let eta = crate::data::eta_for_sigma(&x, sigma, 15, &mut r);
        rows.push(vec![
            shrunk.name.to_string(),
            format!("{}x{}", shrunk.paper_shape.0, shrunk.paper_shape.1),
            format!("{n}x{d}"),
            format!("{sigma:.4}"),
            f4(shrunk.eta),
            f4(eta),
        ]);
    }
    ctx.table(&["dataset", "paper shape", "run shape", "σ (calibrated)", "η (paper)", "η (achieved)"], &rows);
}
