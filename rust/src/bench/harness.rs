//! Bench harness utilities: profiles, timers, table rendering, result
//! persistence.

use std::fmt::Write as _;
use std::time::Instant;

/// Workload scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// Shrunk sizes that finish in seconds (CI / iteration).
    Quick,
    /// Paper-scale sizes (minutes on the 1-core container).
    Full,
}

/// Context passed to every bench target: collects output lines and writes
/// them to `results/<name>.txt` at the end.
pub struct BenchCtx {
    pub name: &'static str,
    pub profile: Profile,
    out: String,
}

impl BenchCtx {
    pub fn new(name: &'static str, profile: Profile) -> Self {
        let mut ctx = Self { name, profile, out: String::new() };
        ctx.line(&format!("=== {} ({:?} profile) ===", name, profile));
        ctx
    }

    /// Emit a line to stdout and the result buffer.
    pub fn line(&mut self, s: &str) {
        println!("{s}");
        self.out.push_str(s);
        self.out.push('\n');
    }

    /// Emit a formatted table: header + rows of equal arity.
    pub fn table(&mut self, header: &[&str], rows: &[Vec<String>]) {
        let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
        for row in rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut line = String::new();
        for (h, w) in header.iter().zip(&widths) {
            let _ = write!(line, "{h:>w$}  ", w = w);
        }
        self.line(line.trim_end());
        for row in rows {
            let mut line = String::new();
            for (cell, w) in row.iter().zip(&widths) {
                let _ = write!(line, "{cell:>w$}  ", w = w);
            }
            self.line(line.trim_end());
        }
    }

    /// Time a closure (single shot — workloads here are seconds-scale).
    pub fn time<T>(&mut self, label: &str, f: impl FnOnce() -> T) -> (T, f64) {
        let start = Instant::now();
        let out = f();
        let secs = start.elapsed().as_secs_f64();
        self.line(&format!("  [{label}: {secs:.3}s]"));
        (out, secs)
    }

    /// Median-of-n timing for microbenchmarks.
    pub fn time_n(&mut self, label: &str, n: usize, mut f: impl FnMut()) -> f64 {
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            let start = Instant::now();
            f();
            samples.push(start.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.total_cmp(b)); // NaN-safe, never panics
        let med = samples[n / 2];
        self.line(&format!("  {label}: median {:.6}s over {n} runs", med));
        med
    }

    /// Flush results to disk.
    pub fn finish(mut self, total: std::time::Duration) {
        self.line(&format!("=== {} done in {:.1}s ===\n", self.name, total.as_secs_f64()));
        let path = format!("results/{}.txt", self.name);
        if let Err(e) = std::fs::write(&path, &self.out) {
            eprintln!("could not write {path}: {e}");
        }
    }
}

/// Format helper: fixed 4-decimal float.
pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

/// Format helper: engineering seconds.
pub fn secs(x: f64) -> String {
    if x < 1e-3 {
        format!("{:.1}µs", x * 1e6)
    } else if x < 1.0 {
        format!("{:.2}ms", x * 1e3)
    } else {
        format!("{x:.2}s")
    }
}
