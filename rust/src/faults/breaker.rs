//! Per-kind circuit breaker: fail fast after repeated executor panics.
//!
//! Classic three-state machine. **Closed** admits everything and counts
//! consecutive failures; `threshold` consecutive failures trip it
//! **Open**, which rejects immediately (no executor time burned on a
//! kind that reliably panics). After `cooldown` the next admit goes
//! through as a **Half-open** probe: success closes the breaker,
//! failure re-opens it for another cooldown.

use std::sync::Mutex;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum State {
    Closed { failures: u32 },
    Open,
    HalfOpen,
}

pub struct CircuitBreaker {
    threshold: u32,
    cooldown: Duration,
    state: Mutex<(State, Instant)>,
}

impl CircuitBreaker {
    /// `threshold` consecutive failures open the breaker; it stays open
    /// for `cooldown` before allowing a half-open probe.
    pub fn new(threshold: u32, cooldown: Duration) -> Self {
        Self {
            threshold: threshold.max(1),
            cooldown,
            state: Mutex::new((State::Closed { failures: 0 }, Instant::now())),
        }
    }

    /// May a request proceed? Open breakers transition to half-open
    /// (admitting exactly one probe) once the cooldown has elapsed.
    pub fn admit(&self) -> bool {
        let mut guard = self.state.lock().unwrap();
        match guard.0 {
            State::Closed { .. } => true,
            State::HalfOpen => true,
            State::Open => {
                if guard.1.elapsed() >= self.cooldown {
                    guard.0 = State::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record a success: any state closes.
    pub fn on_success(&self) {
        let mut guard = self.state.lock().unwrap();
        guard.0 = State::Closed { failures: 0 };
    }

    /// Record a failure (an executor panic). Returns `true` when this
    /// failure transitions the breaker to open.
    pub fn on_failure(&self) -> bool {
        let mut guard = self.state.lock().unwrap();
        match guard.0 {
            State::Closed { failures } => {
                let failures = failures + 1;
                if failures >= self.threshold {
                    *guard = (State::Open, Instant::now());
                    true
                } else {
                    guard.0 = State::Closed { failures };
                    false
                }
            }
            // A failed half-open probe re-opens for another cooldown.
            State::HalfOpen => {
                *guard = (State::Open, Instant::now());
                true
            }
            State::Open => false,
        }
    }

    /// Current state name, for tests and reporting.
    pub fn state_name(&self) -> &'static str {
        match self.state.lock().unwrap().0 {
            State::Closed { .. } => "closed",
            State::Open => "open",
            State::HalfOpen => "half-open",
        }
    }
}
