//! Capped-exponential-backoff retry for transient failures.

use crate::error::Result;
use crate::svdstream::source::{ColumnBlock, ColumnStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// How many times to attempt an operation and how long to wait between
/// attempts: attempt `k` (1-based) sleeps `min(base_backoff · 2^(k-1),
/// cap)` before retrying. Only errors classified transient by
/// [`FgError::is_transient`](crate::error::FgError::is_transient) are
/// retried; permanent errors propagate on the first attempt.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts including the first (1 = no retries).
    pub max_attempts: u32,
    /// Sleep before the first retry.
    pub base_backoff: Duration,
    /// Upper bound on any single backoff sleep.
    pub cap: Duration,
}

impl RetryPolicy {
    /// No retries: fail on the first error.
    pub fn none() -> Self {
        Self { max_attempts: 1, base_backoff: Duration::ZERO, cap: Duration::ZERO }
    }

    /// Backoff before retry number `retry` (1-based): capped doubling.
    pub fn backoff(&self, retry: u32) -> Duration {
        let exp = retry.saturating_sub(1).min(20);
        let d = self.base_backoff.saturating_mul(1u32 << exp);
        d.min(self.cap)
    }
}

impl Default for RetryPolicy {
    /// 3 attempts, 1 ms → 50 ms capped doubling — small enough that a
    /// persistent failure still surfaces promptly.
    fn default() -> Self {
        Self {
            max_attempts: 3,
            base_backoff: Duration::from_millis(1),
            cap: Duration::from_millis(50),
        }
    }
}

/// Stream wrapper that retries transient `next_block` errors in place.
///
/// Because the failing layer (e.g. [`FaultyStream`](super::FaultyStream))
/// errors *before* advancing its source, each retry re-reads the same
/// block: downstream reservoir/sketch state never observes a duplicate
/// or a gap, preserving the single-pass contract.
pub struct RetryStream<S: ColumnStream> {
    inner: S,
    policy: RetryPolicy,
    /// Optional shared retry counter (the router points this at its
    /// `serve.retries` metric handle).
    retries: Option<Arc<AtomicU64>>,
}

impl<S: ColumnStream> RetryStream<S> {
    pub fn new(inner: S, policy: RetryPolicy) -> Self {
        Self { inner, policy, retries: None }
    }

    /// Count retries into a shared counter.
    pub fn with_counter(mut self, counter: Arc<AtomicU64>) -> Self {
        self.retries = Some(counter);
        self
    }
}

impl<S: ColumnStream> ColumnStream for RetryStream<S> {
    fn rows(&self) -> usize {
        self.inner.rows()
    }

    fn cols(&self) -> usize {
        self.inner.cols()
    }

    fn next_block(&mut self) -> Result<Option<ColumnBlock>> {
        let mut attempt = 1u32;
        loop {
            match self.inner.next_block() {
                Ok(b) => return Ok(b),
                Err(e) if e.is_transient() && attempt < self.policy.max_attempts => {
                    if let Some(c) = &self.retries {
                        c.fetch_add(1, Ordering::Relaxed);
                    }
                    std::thread::sleep(self.policy.backoff(attempt));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    fn reset(&mut self) {
        self.inner.reset();
    }
}
