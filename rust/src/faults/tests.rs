//! Fault-injection tests: schedule determinism, cap enforcement,
//! breaker transitions, retry backoff, and the zero-allocation
//! disabled path (counted by the same global allocator the `obs`
//! tests use).

use super::*;
use crate::linalg::Mat;
use crate::rng::rng;
use crate::testing::alloc_count::allocs_now;
use std::time::Duration;

#[test]
fn same_seed_yields_identical_injection_sequence() {
    let a = FaultPlan::new(0xc4a0).with_site(site::STREAM_READ, 0.3, u64::MAX);
    let b = FaultPlan::new(0xc4a0).with_site(site::STREAM_READ, 0.3, u64::MAX);
    let c = FaultPlan::new(0xc4a1).with_site(site::STREAM_READ, 0.3, u64::MAX);
    let seq_a: Vec<bool> = (0..2000).map(|n| a.decide(site::STREAM_READ, n)).collect();
    let seq_b: Vec<bool> = (0..2000).map(|n| b.decide(site::STREAM_READ, n)).collect();
    let seq_c: Vec<bool> = (0..2000).map(|n| c.decide(site::STREAM_READ, n)).collect();
    assert_eq!(seq_a, seq_b, "same seed must give the identical schedule");
    assert_ne!(seq_a, seq_c, "a different seed must perturb the schedule");
    // The empirical rate tracks the configured one.
    let hits = seq_a.iter().filter(|&&h| h).count() as f64 / 2000.0;
    assert!((hits - 0.3).abs() < 0.05, "empirical rate {hits} far from 0.3");
    // Sites are decorrelated: an unknown site never injects.
    assert!(!a.decide("no.such.site", 0));
}

#[test]
fn trip_counts_occurrences_and_matches_pure_decide() {
    let plan = FaultPlan::new(77).with_site(site::STREAM_READ, 0.4, u64::MAX);
    let tripped: Vec<bool> = (0..500).map(|_| plan.trip(site::STREAM_READ)).collect();
    let decided: Vec<bool> = (0..500).map(|n| plan.decide(site::STREAM_READ, n)).collect();
    assert_eq!(tripped, decided, "stateful trip must replay the pure schedule");
    assert_eq!(plan.occurrences(site::STREAM_READ), 500);
    assert_eq!(plan.injected(), tripped.iter().filter(|&&h| h).count() as u64);
}

#[test]
fn trip_honors_injection_cap() {
    // rate 1.0, max 1 — the "one executor panic per kind" shape.
    let plan = FaultPlan::new(1).with_site("executor.cur", 1.0, 1);
    assert!(plan.trip("executor.cur"));
    for _ in 0..10 {
        assert!(!plan.trip("executor.cur"), "cap of 1 must block further injections");
    }
    assert_eq!(plan.injected_at("executor.cur"), 1);
    assert_eq!(plan.occurrences("executor.cur"), 11);
}

#[test]
fn disabled_ambient_path_allocates_nothing() {
    install(None);
    // Warm the thread-local slot so lazy TLS setup is not charged to
    // the measured region.
    let _ = trip_ambient(site::STREAM_READ);
    let before = allocs_now();
    for _ in 0..1000 {
        assert!(!trip_ambient(site::STREAM_READ));
        assert!(!enabled());
    }
    let after = allocs_now();
    assert_eq!(after - before, 0, "disabled fault path must not allocate");
}

#[test]
fn install_is_per_thread_and_current_returns_the_plan() {
    let plan =
        std::sync::Arc::new(FaultPlan::new(9).with_site(site::QUEUE_ADMISSION, 1.0, u64::MAX));
    install(Some(plan.clone()));
    assert!(enabled());
    assert!(trip_ambient(site::QUEUE_ADMISSION));
    assert_eq!(current().unwrap().seed(), 9);
    // A fresh thread sees no plan.
    std::thread::spawn(|| assert!(!enabled())).join().unwrap();
    install(None);
    assert!(!enabled());
}

#[test]
fn breaker_walks_closed_open_half_open_closed() {
    let br = CircuitBreaker::new(3, Duration::from_millis(5));
    assert_eq!(br.state_name(), "closed");
    assert!(!br.on_failure());
    assert!(!br.on_failure());
    assert!(br.admit(), "closed breaker admits while under threshold");
    // A success resets the consecutive-failure count.
    br.on_success();
    assert!(!br.on_failure());
    assert!(!br.on_failure());
    assert!(br.on_failure(), "third consecutive failure opens");
    assert_eq!(br.state_name(), "open");
    assert!(!br.admit(), "open breaker fails fast during cooldown");
    std::thread::sleep(Duration::from_millis(8));
    assert!(br.admit(), "cooldown elapsed: half-open probe admitted");
    assert_eq!(br.state_name(), "half-open");
    // A failed probe re-opens immediately...
    assert!(br.on_failure());
    assert_eq!(br.state_name(), "open");
    std::thread::sleep(Duration::from_millis(8));
    assert!(br.admit());
    // ...and a successful probe closes.
    br.on_success();
    assert_eq!(br.state_name(), "closed");
    assert!(br.admit());
}

#[test]
fn retry_backoff_doubles_and_caps() {
    let p = RetryPolicy {
        max_attempts: 6,
        base_backoff: Duration::from_millis(10),
        cap: Duration::from_millis(35),
    };
    assert_eq!(p.backoff(1), Duration::from_millis(10));
    assert_eq!(p.backoff(2), Duration::from_millis(20));
    assert_eq!(p.backoff(3), Duration::from_millis(35), "third retry hits the cap");
    assert_eq!(p.backoff(4), Duration::from_millis(35));
    assert_eq!(RetryPolicy::none().max_attempts, 1);
}

/// A faulted-then-retried stream hands out exactly the blocks the clean
/// stream would: same col_starts, bitwise-identical data — the property
/// that lets retries hide under single-pass consumers.
#[test]
fn retried_faulty_stream_is_bitwise_identical_to_clean_stream() {
    use crate::svdstream::source::DenseColumnStream;

    let mut r = rng(42);
    let a = Mat::randn(30, 57, &mut r);
    let drain = |s: &mut dyn ColumnStream| {
        let mut out = Vec::new();
        while let Some(b) = s.next_block().unwrap() {
            out.push((b.col_start, b.data));
        }
        out
    };
    let clean = drain(&mut DenseColumnStream::new(&a, 8));

    let plan =
        std::sync::Arc::new(FaultPlan::new(0xfa11).with_site(site::STREAM_READ, 0.5, u64::MAX));
    let policy =
        RetryPolicy { max_attempts: 64, base_backoff: Duration::ZERO, cap: Duration::ZERO };
    let faulty = FaultyStream::new(DenseColumnStream::new(&a, 8), plan.clone());
    let mut retried = RetryStream::new(faulty, policy);
    let got = drain(&mut retried);

    assert!(plan.injected() > 0, "rate 0.5 over 8 blocks should inject at least once");
    assert_eq!(got.len(), clean.len());
    for ((gs, gd), (cs, cd)) in got.iter().zip(clean.iter()) {
        assert_eq!(gs, cs);
        assert_eq!(gd.data(), cd.data(), "retried block must be bitwise identical");
    }
}

/// A permanent error is not retried: it surfaces on the first attempt.
#[test]
fn retry_stream_propagates_permanent_errors_immediately() {
    struct Broken;
    impl ColumnStream for Broken {
        fn rows(&self) -> usize {
            1
        }
        fn cols(&self) -> usize {
            1
        }
        fn next_block(&mut self) -> crate::error::Result<Option<ColumnBlock>> {
            Err(crate::error::FgError::StreamRead {
                context: "disk gone".into(),
                transient: false,
            })
        }
        fn reset(&mut self) {}
    }
    let mut s = RetryStream::new(Broken, RetryPolicy::default());
    match s.next_block() {
        Err(crate::error::FgError::StreamRead { transient: false, .. }) => {}
        Err(e) => panic!("expected permanent StreamRead, got {e}"),
        Ok(_) => panic!("expected an error"),
    }
}
