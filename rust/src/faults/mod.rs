//! Deterministic fault injection — chaos testing for the serving stack.
//!
//! A seeded [`FaultPlan`] decides, per named injection *site* and per
//! occurrence number, whether to inject a fault. The decision is a pure
//! function of `(seed, site, occurrence)`, so a chaos run is
//! reproducible bit-for-bit from its seed alone: the same job stream
//! against the same plan injects the same faults at the same points, no
//! matter how the run is timed or scheduled (occurrence counters are the
//! only shared state, and each site counts independently).
//!
//! The harness follows the `obs` model: a plan is installed per thread
//! with [`install`] (the router installs its configured plan on every
//! executor thread, exactly like its trace collector), and ambient
//! checks via [`trip_ambient`] are **zero-cost when disabled** — no
//! allocation, one thread-local read — which is pinned by an
//! allocation-counting test like the tracing layer's.
//!
//! Injection sites:
//!
//! | site                  | effect when tripped                         |
//! |-----------------------|---------------------------------------------|
//! | `stream.read`         | a transient [`FgError::StreamRead`]          |
//! | `executor.<kind>`     | a panic inside the executor body             |
//! | `cache.persist`       | an I/O error while persisting the cache      |
//! | `cache.warm_start`    | an I/O error while warm-starting the cache   |
//! | `queue.admission`     | a simulated queue-full at admission          |
//! | `net.accept`          | the listener sheds the accept with `BUSY`    |
//! | `net.read`            | a transient I/O error on a socket read       |
//! | `net.write`           | a transient I/O error on a socket write      |
//!
//! [`FgError::StreamRead`]: crate::error::FgError::StreamRead

pub mod breaker;
pub mod retry;
#[cfg(test)]
mod tests;

pub use breaker::CircuitBreaker;
pub use retry::{RetryPolicy, RetryStream};

use crate::error::{FgError, Result};
use crate::svdstream::source::{ColumnBlock, ColumnStream};
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Canonical injection-site names.
pub mod site {
    /// A column-block read from a [`ColumnStream`](super::ColumnStream).
    pub const STREAM_READ: &str = "stream.read";
    /// Writing the artifact cache to disk.
    pub const CACHE_PERSIST: &str = "cache.persist";
    /// Reading the artifact cache back from disk.
    pub const CACHE_WARM_START: &str = "cache.warm_start";
    /// Submit-queue admission (a trip simulates queue-full pressure).
    pub const QUEUE_ADMISSION: &str = "queue.admission";
    /// Accepting a TCP connection (a trip sheds the accept with `BUSY`).
    pub const NET_ACCEPT: &str = "net.accept";
    /// Reading a line from a wire connection (transient, retried).
    pub const NET_READ: &str = "net.read";
    /// Writing a response to a wire connection (transient, retried).
    pub const NET_WRITE: &str = "net.write";

    /// Executor-body site for one job kind: `executor.<kind>`.
    pub fn executor(kind: &str) -> String {
        format!("executor.{kind}")
    }
}

/// One site's injection schedule: inject with probability `rate` per
/// occurrence, at most `max` times total.
#[derive(Clone, Debug)]
pub struct SiteSpec {
    pub site: String,
    pub rate: f64,
    pub max: u64,
}

/// A seeded, process-shareable fault schedule. Immutable after
/// construction apart from its occurrence counters; share via `Arc`.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    specs: Vec<SiteSpec>,
    /// Per-spec `[occurrences_seen, faults_injected]`.
    counters: Vec<[AtomicU64; 2]>,
    injected_total: AtomicU64,
}

/// FNV-1a over the site name — stable site identity across runs.
fn fnv64(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// splitmix64 finalizer — decorrelates `(seed, site, occurrence)`.
fn mix(v: u64) -> u64 {
    let mut z = v.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// An empty plan (no sites — never injects) with the given seed.
    pub fn new(seed: u64) -> Self {
        Self { seed, specs: Vec::new(), counters: Vec::new(), injected_total: AtomicU64::new(0) }
    }

    /// Builder: add an injection site with a per-occurrence probability
    /// and a cap on total injections (`u64::MAX` for unlimited).
    pub fn with_site(mut self, site: impl Into<String>, rate: f64, max: u64) -> Self {
        self.specs.push(SiteSpec { site: site.into(), rate, max });
        self.counters.push([AtomicU64::new(0), AtomicU64::new(0)]);
        self
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    fn spec_index(&self, site: &str) -> Option<usize> {
        self.specs.iter().position(|s| s.site == site)
    }

    /// Pure injection decision for occurrence `n` at `site` — no state
    /// read or written, so the full schedule is enumerable in tests.
    pub fn decide(&self, site: &str, occurrence: u64) -> bool {
        let Some(idx) = self.spec_index(site) else { return false };
        let rate = self.specs[idx].rate;
        if rate <= 0.0 {
            return false;
        }
        if rate >= 1.0 {
            return true;
        }
        let h = mix(self.seed ^ fnv64(site) ^ occurrence.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        (h as f64) < rate * (u64::MAX as f64)
    }

    /// Count one occurrence at `site` and return whether to inject,
    /// honoring the site's injection cap.
    pub fn trip(&self, site: &str) -> bool {
        let Some(idx) = self.spec_index(site) else { return false };
        let n = self.counters[idx][0].fetch_add(1, Ordering::Relaxed);
        if !self.decide(site, n) {
            return false;
        }
        // Reserve an injection slot; back off if the cap is exhausted.
        let prev = self.counters[idx][1].fetch_add(1, Ordering::Relaxed);
        if prev >= self.specs[idx].max {
            self.counters[idx][1].fetch_sub(1, Ordering::Relaxed);
            return false;
        }
        self.injected_total.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Total faults injected so far across all sites.
    pub fn injected(&self) -> u64 {
        self.injected_total.load(Ordering::Relaxed)
    }

    /// Faults injected at one site.
    pub fn injected_at(&self, site: &str) -> u64 {
        self.spec_index(site).map_or(0, |i| self.counters[i][1].load(Ordering::Relaxed))
    }

    /// Occurrences counted at one site (injected or not).
    pub fn occurrences(&self, site: &str) -> u64 {
        self.spec_index(site).map_or(0, |i| self.counters[i][0].load(Ordering::Relaxed))
    }
}

thread_local! {
    static PLAN: RefCell<Option<Arc<FaultPlan>>> = const { RefCell::new(None) };
}

/// Install a fault plan on the current thread (like `obs::install`).
/// Threads are installed independently; the router installs its
/// configured plan on each executor thread so one plan covers the whole
/// serving process.
pub fn install(plan: Option<Arc<FaultPlan>>) {
    PLAN.with(|p| *p.borrow_mut() = plan);
}

/// The plan installed on this thread, if any.
pub fn current() -> Option<Arc<FaultPlan>> {
    PLAN.with(|p| p.borrow().clone())
}

/// Whether a plan is installed on this thread.
pub fn enabled() -> bool {
    PLAN.with(|p| p.borrow().is_some())
}

/// Ambient trip: count an occurrence at `site` against the installed
/// plan. Returns `false` (without allocating) when no plan is installed
/// — the disabled path is pinned to zero allocations by test.
pub fn trip_ambient(site: &str) -> bool {
    PLAN.with(|p| match &*p.borrow() {
        Some(plan) => plan.trip(site),
        None => false,
    })
}

/// Stream wrapper that injects transient read faults per the plan.
///
/// The trip is consulted **before** the inner stream advances, so a
/// faulted read leaves the source untouched and a retry re-yields the
/// exact block the failed attempt would have — the single-pass contract
/// survives injection + retry.
pub struct FaultyStream<S: ColumnStream> {
    inner: S,
    plan: Arc<FaultPlan>,
}

impl<S: ColumnStream> FaultyStream<S> {
    pub fn new(inner: S, plan: Arc<FaultPlan>) -> Self {
        Self { inner, plan }
    }
}

impl<S: ColumnStream> ColumnStream for FaultyStream<S> {
    fn rows(&self) -> usize {
        self.inner.rows()
    }

    fn cols(&self) -> usize {
        self.inner.cols()
    }

    fn next_block(&mut self) -> Result<Option<ColumnBlock>> {
        if self.plan.trip(site::STREAM_READ) {
            return Err(FgError::StreamRead {
                context: format!("injected fault (seed {:#x})", self.plan.seed),
                transient: true,
            });
        }
        self.inner.next_block()
    }

    fn reset(&mut self) {
        self.inner.reset();
    }
}
