//! CUR subsystem tests: the ISSUE acceptance bars (rank-k relative
//! error, identity-sized agreement, subspace-vs-full leverage on
//! square-ish inputs, streaming-vs-in-memory agreement), stabilized-core
//! behaviour on ill-conditioned selections, sparse/dense path agreement,
//! and the SPSD cross-check against the Nyström baseline.

use super::*;
use crate::data::{rbf_kernel, synth_clustered, synth_dense, synth_sparse, SpectrumKind};
use crate::linalg::{fro_norm_diff, qr_thin};
use crate::rng::rng;
use crate::sketch::{column_leverage_scores, subspace_column_leverage_scores};
use crate::sparse::Csr;
use crate::svdstream::{DenseColumnStream, OnePassStream};
use crate::testing::assert_close;

fn rank_k_matrix(m: usize, n: usize, k: usize, noise: f64, seed: u64) -> Mat {
    let mut r = rng(seed);
    synth_dense(m, n, k, SpectrumKind::Exponential { base: 0.75 }, noise, &mut r)
}

/// Acceptance bar: leverage-selection CUR with the Fast-GMR core lands
/// within 1.5× of the best rank-k error on a rank-k + noise matrix.
#[test]
fn leverage_fast_cur_within_rank_k_error() {
    let k = 6;
    let a = rank_k_matrix(220, 180, k, 0.02, 71);
    let input = Input::Dense(&a);
    let cfg = CurConfig::fast(4 * k, 4 * k, 3);
    let mut r = rng(72);
    let d = decompose(input, &cfg, &mut r);
    assert_eq!(d.c.shape(), (220, 4 * k));
    assert_eq!(d.u.shape(), (4 * k, 4 * k));
    assert_eq!(d.r.shape(), (4 * k, 180));
    let mut re = rng(73);
    let report = relative_error(input, &d, k, None, &mut re);
    assert!(report.residual > 0.0 && report.ak_error > 0.0);
    assert!(
        report.ratio() <= 1.5,
        "leverage+fast CUR ratio {} exceeds the 1.5 acceptance bar",
        report.ratio()
    );
}

/// Identity-sized sketches must reproduce the exact core to ≤ 1e-8
/// relative — the sketched code path degenerates to `C† A R†`.
#[test]
fn identity_sized_fast_core_matches_exact() {
    let a = rank_k_matrix(60, 50, 8, 0.05, 11);
    let input = Input::Dense(&a);
    let mut r = rng(12);
    let (_, c) = select_columns(input, &SelectionStrategy::Leverage, 12, &mut r);
    let (_, rr) = select_rows(input, &SelectionStrategy::Leverage, 12, &mut r);
    let u_exact = core_exact(input, &c, &rr);
    let mut rf = rng(13); // unused by the identity path, required by the API
    let u_fast = core_fast(input, &c, &rr, SketchKind::Gaussian, 60, 50, &mut rf);
    let rel = fro_norm_diff(&u_fast, &u_exact) / u_exact.fro_norm();
    assert!(rel <= 1e-8, "identity-sized fast core off by {rel} relative");
}

/// The sketched core approaches the exact core as sketches grow, and is
/// already a usable approximation at moderate sizes.
#[test]
fn fast_core_converges_with_sketch_size() {
    let a = rank_k_matrix(150, 120, 5, 0.02, 21);
    let input = Input::Dense(&a);
    let mut r = rng(22);
    let (_, c) = select_columns(input, &SelectionStrategy::Leverage, 15, &mut r);
    let (_, rr) = select_rows(input, &SelectionStrategy::Leverage, 15, &mut r);
    let exact_res = gmr::residual(input, &c, &core_exact(input, &c, &rr), &rr);
    let mut res_small = 0.0;
    let mut res_big = 0.0;
    for t in 0..3u64 {
        let mut rs = rng(100 + t);
        let u = core_fast(input, &c, &rr, SketchKind::Gaussian, 30, 30, &mut rs);
        res_small += gmr::residual(input, &c, &u, &rr);
        let mut rb = rng(200 + t);
        let u = core_fast(input, &c, &rr, SketchKind::Gaussian, 120, 100, &mut rb);
        res_big += gmr::residual(input, &c, &u, &rr);
    }
    res_small /= 3.0;
    res_big /= 3.0;
    assert!(res_big >= exact_res * (1.0 - 1e-9), "residual below the optimum is impossible");
    assert!(res_big <= exact_res * 1.1, "near-full sketches should sit at the optimum");
    assert!(res_small <= exact_res * 1.6, "even small sketches stay near the optimum");
}

/// Stabilized-QR core: agrees with the exact core on well-conditioned
/// selections and survives a rank-deficient C (duplicate column) by
/// falling back to the pinv route.
#[test]
fn stabilized_core_matches_exact_and_survives_duplicates() {
    let a = rank_k_matrix(80, 70, 6, 0.05, 31);
    let input = Input::Dense(&a);
    let mut r = rng(32);
    let (_, c) = select_columns(input, &SelectionStrategy::Leverage, 10, &mut r);
    let (_, rr) = select_rows(input, &SelectionStrategy::Leverage, 10, &mut r);
    let u_exact = core_exact(input, &c, &rr);
    let u_qr = core_stabilized(input, &c, &rr);
    assert_close(&u_qr, &u_exact, 1e-7, "stabilized vs exact core");

    // Duplicate a column of C: the triangular guard must trip and the
    // fallback must still produce a finite core with a sane residual.
    let dup = c.select_cols(&[0, 0, 1, 2, 3, 4, 5, 6, 7, 8]);
    let u_dup = core_stabilized(input, &dup, &rr);
    assert!(u_dup.data().iter().all(|v| v.is_finite()), "fallback core has non-finite entries");
    let res = gmr::residual(input, &dup, &u_dup, &rr);
    assert!(res.is_finite() && res <= a.fro_norm(), "fallback residual {res} not sane");
}

/// Leverage selection concentrates on the rows that carry the mass: a
/// tall matrix whose first four rows are the only independent directions
/// must have exactly those rows selected.
#[test]
fn leverage_selection_finds_dominant_rows() {
    let mut a = Mat::zeros(40, 4);
    for j in 0..4 {
        a[(j, j)] = 10.0;
    }
    let mut r = rng(41);
    for i in 4..40 {
        for j in 0..4 {
            a[(i, j)] = 1e-7 * r.next_normal();
        }
    }
    let (idx, rows) = select_rows(Input::Dense(&a), &SelectionStrategy::Leverage, 4, &mut r);
    assert_eq!(idx, vec![0, 1, 2, 3]);
    assert_eq!(rows.shape(), (4, 4));
}

/// Sparse and dense inputs must agree end-to-end: same seed, same
/// selected indices, and the same core to floating-point slack.
#[test]
fn sparse_and_dense_paths_agree() {
    let mut r = rng(51);
    let sp = synth_sparse(120, 90, 0.08, 6, &mut r);
    let dense = sp.to_dense();
    let cfg = CurConfig {
        c: 14,
        r: 14,
        selection: SelectionStrategy::SketchedLeverage { kind: SketchKind::Count, size: 24 },
        core: CoreMethod::FastGmr,
        sketch: SketchKind::Count,
        s_c: 42,
        s_r: 42,
    };
    let mut r1 = rng(52);
    let d_sparse = decompose(Input::Sparse(&sp), &cfg, &mut r1);
    let mut r2 = rng(52);
    let d_dense = decompose(Input::Dense(&dense), &cfg, &mut r2);
    assert_eq!(d_sparse.col_idx, d_dense.col_idx, "column selection diverged");
    assert_eq!(d_sparse.row_idx, d_dense.row_idx, "row selection diverged");
    assert_close(&d_sparse.c, &d_dense.c, 1e-12, "gathered C");
    assert_close(&d_sparse.r, &d_dense.r, 1e-12, "gathered R");
    assert_close(&d_sparse.u, &d_dense.u, 1e-9, "core U");
    let res = d_sparse.residual(Input::Sparse(&sp));
    assert!(res.is_finite() && res < sp.fro_norm(), "sparse residual {res} not sane");
}

/// The sketched residual estimator tracks the exact residual (the §6.1
/// evaluation trick, re-used by the CUR error report).
#[test]
fn residual_estimate_tracks_exact_residual() {
    let a = rank_k_matrix(140, 110, 5, 0.05, 61);
    let input = Input::Dense(&a);
    let cfg = CurConfig::fast(15, 15, 3);
    let mut r = rng(62);
    let d = decompose(input, &cfg, &mut r);
    let exact = d.residual(input);
    let mut acc = 0.0;
    let trials = 8;
    for t in 0..trials {
        let mut re = rng(900 + t);
        acc += d.residual_estimate(input, 80, &mut re);
    }
    let est = acc / trials as f64;
    assert!(
        (est - exact).abs() <= 0.35 * exact,
        "sketched residual {est} far from exact {exact}"
    );
}

/// SPSD cross-check: symmetric CUR on an RBF kernel with the same index
/// set on both sides solves `min_X ‖K − C X Cᵀ‖` exactly — so its
/// residual can only beat the classical Nyström `W†` core, and the
/// Fast-GMR core must stay close to that optimum.
#[test]
fn cur_on_rbf_kernel_cross_checks_nystrom() {
    let mut r = rng(81);
    let x = synth_clustered(160, 6, 5, 0.3, &mut r);
    let k = rbf_kernel(&x, 0.5);
    let input = Input::Dense(&k);
    let (idx, c) = select_columns(input, &SelectionStrategy::Leverage, 12, &mut r);
    let rmat = c.transpose(); // K symmetric ⇒ K[idx, :] = Cᵀ

    let u_exact = core_exact(input, &c, &rmat);
    let cur_err = crate::spsd::error_ratio(&k, &c, &u_exact);
    let w_pinv = crate::spsd::nystrom_core(&c, &idx);
    let ny_err = crate::spsd::error_ratio(&k, &c, &w_pinv);
    assert!(
        cur_err <= ny_err * 1.05 + 1e-9,
        "exact-core CUR ({cur_err}) lost to Nyström ({ny_err}) — impossible for the optimal core"
    );

    let mut rf = rng(82);
    let u_fast = core_fast(input, &c, &rmat, SketchKind::Gaussian, 60, 60, &mut rf);
    let fast_err = crate::spsd::error_ratio(&k, &c, &u_fast);
    assert!(
        fast_err <= cur_err * 1.5 + 1e-12,
        "fast-core CUR ({fast_err}) strayed from the exact core ({cur_err})"
    );
}

/// Degenerate configurations must not panic: over-selection (more
/// columns than A has rows) falls back to the exact core, and a
/// `Leverage` *scoring* sketch degrades to uniform sampling instead of
/// demanding the scores it is supposed to be estimating.
#[test]
fn degenerate_configs_fall_back_gracefully() {
    let a = rank_k_matrix(20, 60, 4, 0.05, 95);
    let input = Input::Dense(&a);
    let mut r = rng(96);
    // c = 30 > m = 20: no valid left sketch size exists.
    let (_, c) = select_columns(input, &SelectionStrategy::Uniform, 30, &mut r);
    let (_, rr) = select_rows(input, &SelectionStrategy::Uniform, 8, &mut r);
    let u = core_fast(input, &c, &rr, SketchKind::Gaussian, 90, 24, &mut r);
    assert_eq!(u.shape(), (30, 8));
    assert!(u.data().iter().all(|v| v.is_finite()), "over-selection core not finite");

    let strat = SelectionStrategy::SketchedLeverage { kind: SketchKind::Leverage, size: 10 };
    let (idx, cmat) = select_columns(input, &strat, 12, &mut r);
    assert_eq!(cmat.shape(), (20, 12));
    assert_eq!(idx.len(), 12);
}

/// A square invertible matrix with `k` planted heavy columns: `Aᵀ`'s
/// thin-QR `Q` is orthogonal, so every full-rank column leverage score
/// is *exactly* 1 (provably uniform — selection is blind), while the
/// rank-`k` subspace scores concentrate on the planted columns.
fn planted_square(n: usize, k: usize, seed: u64) -> Mat {
    let mut r = rng(seed);
    let u = qr_thin(&Mat::randn(n, k, &mut r)).q;
    let mut a = Mat::zeros(n, n);
    for t in 0..k {
        let w = 10.0 * (1.0 - 0.1 * t as f64);
        for i in 0..n {
            a[(i, t)] = w * u[(i, t)];
        }
    }
    for i in 0..n {
        for j in 0..n {
            a[(i, j)] += 1e-3 * r.next_normal();
        }
    }
    a
}

/// ISSUE acceptance bar: on the planted square-ish matrix the full-QR
/// scores are uniform to fp noise, the rank-k subspace scores separate
/// the planted heavy columns, and `SubspaceLeverage { k }` CUR beats
/// full-QR `Leverage` CUR by a wide residual margin.
#[test]
fn subspace_leverage_beats_uniform_full_qr_scores_on_square_input() {
    let (n, k) = (48, 5);
    let a = planted_square(n, k, 0xAB);
    let input = Input::Dense(&a);

    let full = column_leverage_scores(&a);
    for (j, &s) in full.iter().enumerate() {
        assert!((s - 1.0).abs() < 1e-6, "full-rank score {s} at column {j} not uniform");
    }
    let sub = subspace_column_leverage_scores(&a, k);
    let heavy: f64 = sub[..k].iter().sum();
    assert!(heavy >= 0.9 * k as f64, "subspace scores miss the planted columns (sum {heavy})");
    for (j, &s) in sub.iter().enumerate().skip(k) {
        assert!(s < 1e-2, "light column {j} got subspace score {s}");
    }

    let mut rs = rng(0xAC);
    let (idx, _) = select_columns(input, &SelectionStrategy::SubspaceLeverage { k }, k, &mut rs);
    let hits = idx.iter().filter(|&&j| j < k).count();
    assert!(hits + 1 >= k, "subspace selection found only {hits}/{k} planted columns: {idx:?}");

    let exact = |sel: SelectionStrategy, seed: u64| {
        let cfg = CurConfig { selection: sel, ..CurConfig::exact(k, k) };
        let mut r = rng(seed);
        decompose(input, &cfg, &mut r).residual(input)
    };
    let res_sub = exact(SelectionStrategy::SubspaceLeverage { k }, 0xAD);
    let res_full = exact(SelectionStrategy::Leverage, 0xAD);
    assert!(
        res_sub < 0.25 * res_full,
        "subspace CUR ({res_sub}) must beat uniform-score full-QR CUR ({res_full})"
    );
}

/// At full sketch sizes both streaming sketches degenerate to the
/// identity, so the single-pass driver must reproduce the in-memory
/// Fast-GMR CUR exactly: actual columns in C, actual rows resolved in
/// R̂, and the identity-degenerate core — all ≤ 1e-10.
#[test]
fn streaming_full_sketches_match_in_memory_fast_core() {
    let a = rank_k_matrix(60, 50, 6, 0.05, 101);
    let input = Input::Dense(&a);
    let cfg = StreamingCurConfig {
        c: 10,
        r: 10,
        k: 6,
        kind: SketchKind::Gaussian,
        s_c: 60,
        s_r: 50,
        oversample: 5,
    };
    let mut stream = DenseColumnStream::new(&a, 16);
    let mut r = rng(102);
    let res = streaming_cur(&mut stream, &cfg, &mut r).unwrap();
    assert_eq!(res.blocks, 4);
    assert_eq!(res.candidates, 50, "full-capacity reservoir must retain every column");
    assert_eq!(res.cur.col_idx.len(), 10);
    assert_eq!(res.cur.row_idx.len(), 10);

    let c_ref = gather_columns(input, &res.cur.col_idx);
    let r_ref = gather_rows(input, &res.cur.row_idx);
    assert_eq!(res.cur.c.data(), c_ref.data(), "reservoir columns differ from A's columns");
    assert_close(&res.cur.r, &r_ref, 1e-10, "sketch-resolved rows at full sizes");

    let mut rf = rng(0); // the identity-degenerate path consumes no rng
    let u_ref = core_fast(input, &c_ref, &r_ref, SketchKind::Gaussian, 60, 50, &mut rf);
    assert_close(&res.cur.u, &u_ref, 1e-10, "streaming core vs in-memory fast core");
}

/// Streaming CUR reads the stream exactly once (OnePassStream panics on
/// any replay) and lands within a small constant of the best rank-k
/// error with sketch-sized state.
#[test]
fn streaming_cur_single_pass_close_to_best_rank_k() {
    let k = 6;
    let a = rank_k_matrix(260, 220, k, 0.02, 55);
    let input = Input::Dense(&a);
    let cfg = StreamingCurConfig::fast(4 * k, 4 * k, k, 3);
    let mut stream = OnePassStream::new(DenseColumnStream::new(&a, 40));
    let mut r = rng(56);
    let res = streaming_cur(&mut stream, &cfg, &mut r).unwrap();
    assert_eq!(res.blocks, stream.blocks());
    assert_eq!(res.blocks, 6);
    assert!(res.cur.col_idx.windows(2).all(|w| w[0] < w[1]), "column indices not sorted-unique");
    assert!(res.cur.row_idx.windows(2).all(|w| w[0] < w[1]), "row indices not sorted-unique");
    for (o, &j) in res.cur.col_idx.iter().enumerate() {
        assert_eq!(res.cur.c.col(o), a.col(j), "C column {o} is not A[:, {j}]");
    }
    let mut re = rng(57);
    let report = relative_error(input, &res.cur, k, None, &mut re);
    assert!(report.ratio() <= 2.5, "streaming CUR ratio {} above the bar", report.ratio());
}

/// ISSUE 9 acceptance: ε-planned CUR achieves `(1+ε)` relative error
/// against the exact core *for its own selected factors* in ≥90% of
/// fixed-seed trials. At this scale the planner's check saturates to an
/// exact certificate and the schedule's last entry reaches the
/// dimension, so certified ⟹ true and the loop must terminate attained.
#[test]
fn planner_acceptance_cur() {
    let eps = 0.25;
    crate::testing::assert_attains_epsilon("cur planned", eps, 10, 9, |seed| {
        let a = rank_k_matrix(100, 80, 6, 0.05, seed);
        let input = Input::Dense(&a);
        let cfg = CurConfig::fast(10, 10, 3);
        let plan = crate::plan::EpsilonPlan::new(eps).with_seed(seed);
        let mut r = rng(seed ^ 0x1);
        let (d, out) = decompose_planned(input, &cfg, &plan, &mut r);
        let achieved = d.residual(input);
        let optimum = gmr::residual(input, &d.c, &core_exact(input, &d.c, &d.r), &d.r);
        (achieved, optimum, out.attained)
    });
}

/// ISSUE 9 acceptance, streaming flavour: the planned single-pass CUR
/// re-opens the stream per attempt, escalates sketch sizes, and must
/// land within `(1+ε)` of the best core for the factors it streamed out
/// — again in ≥90% of fixed-seed trials (here: all, the check is
/// saturated-exact at 120×100).
#[test]
fn planner_acceptance_streaming_cur() {
    let eps = 0.5;
    crate::testing::assert_attains_epsilon("streaming cur planned", eps, 10, 9, |seed| {
        let a = rank_k_matrix(120, 100, 5, 0.05, seed);
        let input = Input::Dense(&a);
        let cfg = StreamingCurConfig::fast(5, 5, 4, 2);
        let plan = crate::plan::EpsilonPlan::new(eps).with_seed(seed);
        let open = || {
            Ok(Box::new(DenseColumnStream::new(&a, 32))
                as Box<dyn crate::svdstream::ColumnStream + '_>)
        };
        let (res, out) = streaming_cur_planned(open, &cfg, &plan).unwrap();
        let achieved = res.cur.residual(input);
        let optimum =
            gmr::residual(input, &res.cur.c, &core_exact(input, &res.cur.c, &res.cur.r), &res.cur.r);
        (achieved, optimum, out.attained)
    });
}

/// Unknown strategy tokens must be a hard config error listing the
/// accepted values — never a silent fallback.
#[test]
fn selection_parse_rejects_unknown_strategies() {
    for ok in ["uniform", "Leverage", "subspace", "lev-k", "sketched", "approx"] {
        assert!(
            SelectionStrategy::parse(ok, SketchKind::Gaussian, 8, 4).is_ok(),
            "token `{ok}` must parse"
        );
    }
    let err = match SelectionStrategy::parse("bogus", SketchKind::Gaussian, 8, 4) {
        Err(e) => format!("{e}"),
        Ok(_) => panic!("bogus strategy must be rejected"),
    };
    assert!(
        err.contains("bogus") && err.contains("subspace") && err.contains("uniform"),
        "error must name the offender and list accepted values: {err}"
    );
}

/// Uniform selection and the Csr gather helpers behave on a plain
/// sparse input (shape bookkeeping + index bounds).
#[test]
fn uniform_selection_on_sparse_input() {
    let mut r = rng(91);
    let mut trips = Vec::new();
    for i in 0..30 {
        trips.push(crate::sparse::Triplet { row: i, col: (i * 7) % 25, val: 1.0 + i as f64 });
    }
    let sp = Csr::from_triplets(30, 25, trips);
    let (cidx, c) = select_columns(Input::Sparse(&sp), &SelectionStrategy::Uniform, 10, &mut r);
    let (ridx, rr) = select_rows(Input::Sparse(&sp), &SelectionStrategy::Uniform, 8, &mut r);
    assert_eq!(c.shape(), (30, 10));
    assert_eq!(rr.shape(), (8, 25));
    assert!(cidx.windows(2).all(|w| w[0] < w[1]), "column indices not sorted-unique");
    assert!(ridx.windows(2).all(|w| w[0] < w[1]), "row indices not sorted-unique");
    assert!(cidx.iter().all(|&j| j < 25) && ridx.iter().all(|&i| i < 30));
}
