//! CUR decomposition built on Fast GMR — the paper's flagship
//! application of the `min_X ‖A − C X R‖_F` problem (abstract; Wang &
//! Zhang 2015 / Wang 2015 give the column/row selection recipes).
//!
//! A CUR decomposition approximates `A ∈ R^{m×n}` by
//!
//! ```text
//! A ≈ C · U · R,   C = A[:, col_idx] (m×c),  R = A[row_idx, :] (r×n)
//! ```
//!
//! so the factors are *actual rows and columns* of `A` — interpretable
//! and sparsity-preserving, unlike SVD factors. The in-memory pipeline is
//!
//! 1. **select** ([`select_columns`]/[`select_rows`]) — uniform, exact
//!    leverage-score, rank-k subspace leverage
//!    ([`SelectionStrategy::SubspaceLeverage`] — the right tool on
//!    square-ish inputs where full-rank scores are provably uniform), or
//!    sketched approximate-leverage column/row sampling;
//! 2. **core** ([`CoreMethod`]) — `U ≈ C† A R†` computed exactly (pinv
//!    baseline), by the Fast-GMR sketched solve (Algorithm 1 — the
//!    whole point: `U` costs sketch-sized work instead of a full pass),
//!    or through a thin-QR-stabilized solve for ill-conditioned
//!    selections;
//! 3. **evaluate** ([`relative_error`]) — `‖A − C U R‖_F / ‖A − A_k‖_F`
//!    with the residual either exact (blockwise, never materialized) or
//!    count-sketch estimated via [`gmr::estimate_residual`].
//!
//! The single-pass form lives in [`streaming`]: one read of a
//! [`crate::svdstream::ColumnStream`], sketch-sized state, and the same
//! scoring module — see [`streaming::streaming_cur`].
//!
//! Selection scoring and the gathers shard over the [`crate::parallel`]
//! pool with the usual contract: `threads = 1` is bitwise serial, and
//! the selected index sets are identical for every thread count (index
//! draws consume only the seeded rng).

mod core;
mod select;
pub mod streaming;
#[cfg(test)]
mod tests;

pub use self::core::{core_exact, core_fast, core_stabilized, CoreMethod};
pub use select::{
    column_scores, gather_columns, gather_rows, row_scores, select_columns, select_rows,
    SelectionStrategy,
};
pub use streaming::{
    streaming_cur, streaming_cur_planned, streaming_cur_with, StreamingCurConfig,
    StreamingCurSketches,
};

use crate::gmr::{self, Input};
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::sketch::SketchKind;

/// Configuration for [`decompose`].
#[derive(Clone, Debug)]
pub struct CurConfig {
    /// Number of columns to select (`C` is m×c).
    pub c: usize,
    /// Number of rows to select (`R` is r×n).
    pub r: usize,
    /// Column/row selection strategy.
    pub selection: SelectionStrategy,
    /// Core solver.
    pub core: CoreMethod,
    /// Sketch family for the Fast-GMR core (ignored by the other cores).
    pub sketch: SketchKind,
    /// Fast-GMR sketch sizes, clamped to `[c, m]` / `[r, n]`.
    pub s_c: usize,
    /// See [`CurConfig::s_c`].
    pub s_r: usize,
}

impl CurConfig {
    /// The paper-flavoured default: leverage selection and the Fast-GMR
    /// core with Gaussian sketches sized `mult ×` the selection.
    pub fn fast(c: usize, r: usize, mult: usize) -> Self {
        Self {
            c,
            r,
            selection: SelectionStrategy::Leverage,
            core: CoreMethod::FastGmr,
            sketch: SketchKind::Gaussian,
            s_c: mult * c,
            s_r: mult * r,
        }
    }

    /// Exact-core baseline with leverage selection.
    pub fn exact(c: usize, r: usize) -> Self {
        Self {
            c,
            r,
            selection: SelectionStrategy::Leverage,
            core: CoreMethod::Exact,
            sketch: SketchKind::Gaussian,
            s_c: 0,
            s_r: 0,
        }
    }
}

/// A computed CUR decomposition `A ≈ C U R` (clonable so the serving
/// layer's artifact cache can hand copies to repeated queries).
#[derive(Clone)]
pub struct CurDecomposition {
    /// Selected column indices (sorted ascending).
    pub col_idx: Vec<usize>,
    /// Selected row indices (sorted ascending).
    pub row_idx: Vec<usize>,
    /// The gathered columns `A[:, col_idx]` (m×c).
    pub c: Mat,
    /// The core matrix (c×r).
    pub u: Mat,
    /// The gathered rows `A[row_idx, :]` (r×n).
    pub r: Mat,
}

impl CurDecomposition {
    /// `‖A − C U R‖_F`, computed blockwise (the m×n approximation is
    /// never materialized).
    pub fn residual(&self, a: Input<'_>) -> f64 {
        gmr::residual(a, &self.c, &self.u, &self.r)
    }

    /// `(1±ε)`-estimate of the residual from two count sketches of size
    /// `s = O(ε⁻²)` (see [`gmr::estimate_residual`]) — for inputs too
    /// large to afford the exact blockwise pass.
    pub fn residual_estimate(&self, a: Input<'_>, s: usize, rng: &mut Pcg64) -> f64 {
        gmr::estimate_residual(a, &self.c, &self.u, &self.r, s, rng)
    }
}

/// Compute a CUR decomposition: select columns and rows, then solve the
/// core with the configured method.
///
/// ```
/// use fastgmr::cur::{decompose, CurConfig};
/// use fastgmr::linalg::Mat;
/// use fastgmr::rng::rng;
///
/// let mut r = rng(1);
/// let a = Mat::randn(60, 40, &mut r);
/// let d = decompose((&a).into(), &CurConfig::fast(8, 8, 3), &mut r);
/// assert_eq!((d.c.shape(), d.u.shape(), d.r.shape()), ((60, 8), (8, 8), (8, 40)));
/// assert!(d.residual((&a).into()).is_finite());
/// ```
pub fn decompose(a: Input<'_>, cfg: &CurConfig, rng: &mut Pcg64) -> CurDecomposition {
    let (col_idx, c) = {
        let mut sp = crate::obs::span("cur.select.columns", crate::obs::cat::GATHER);
        sp.meta("c", cfg.c);
        select::select_columns(a, &cfg.selection, cfg.c, rng)
    };
    let (row_idx, r) = {
        let mut sp = crate::obs::span("cur.select.rows", crate::obs::cat::GATHER);
        sp.meta("r", cfg.r);
        select::select_rows(a, &cfg.selection, cfg.r, rng)
    };
    let u = {
        let mut sp = crate::obs::span("cur.core", crate::obs::cat::SOLVE);
        sp.meta("method", cfg.core.name());
        match cfg.core {
            CoreMethod::Exact => core::core_exact(a, &c, &r),
            CoreMethod::StabilizedQr => core::core_stabilized(a, &c, &r),
            CoreMethod::FastGmr => core::core_fast(a, &c, &r, cfg.sketch, cfg.s_c, cfg.s_r, rng),
        }
    };
    CurDecomposition { col_idx, row_idx, c, u, r }
}

/// ε-planned CUR: the same column/row selection as [`decompose`]
/// (consuming `rng` identically), but the core is solved by
/// [`crate::plan::solve_gmr_planned`] — sketch sizes come from the
/// plan's `O(ε^{-1/2})` seeding and escalate geometrically (reusing
/// each sketch as a bitwise prefix) until the a-posteriori check
/// certifies `(1+ε)` relative error *for the selected factors*.
/// `cfg.s_c`/`cfg.s_r` are ignored; `cfg.core` is ignored (the planned
/// core is always Fast GMR — an exact core needs no plan).
pub fn decompose_planned(
    a: Input<'_>,
    cfg: &CurConfig,
    plan: &crate::plan::EpsilonPlan,
    rng: &mut Pcg64,
) -> (CurDecomposition, crate::plan::PlanOutcome) {
    let (col_idx, c) = {
        let mut sp = crate::obs::span("cur.select.columns", crate::obs::cat::GATHER);
        sp.meta("c", cfg.c);
        select::select_columns(a, &cfg.selection, cfg.c, rng)
    };
    let (row_idx, r) = {
        let mut sp = crate::obs::span("cur.select.rows", crate::obs::cat::GATHER);
        sp.meta("r", cfg.r);
        select::select_rows(a, &cfg.selection, cfg.r, rng)
    };
    let (sol, outcome) = {
        let mut sp = crate::obs::span("cur.core", crate::obs::cat::SOLVE);
        sp.meta("method", "planned");
        crate::plan::solve_gmr_planned(a, &c, &r, cfg.sketch, cfg.sketch, plan)
    };
    (CurDecomposition { col_idx, row_idx, c, u: sol.x, r }, outcome)
}

/// Rank-`k` relative-error report for a CUR decomposition.
pub struct CurErrorReport {
    /// `‖A − C U R‖_F` (exact or count-sketch estimated).
    pub residual: f64,
    /// `‖A − A_k‖_F` (randomized subspace iteration).
    pub ak_error: f64,
}

impl CurErrorReport {
    /// `‖A − C U R‖_F / ‖A − A_k‖_F` — 1.0 is the best any rank-k
    /// factorization can do; leverage CUR lands within a small constant.
    pub fn ratio(&self) -> f64 {
        self.residual / self.ak_error
    }
}

/// Evaluate `d` against the best rank-`k` error. `sketch_s = Some(s)`
/// estimates the numerator with count sketches of size `s` (never
/// materializing the residual — the §6.1 evaluation trick); `None`
/// computes it exactly blockwise.
pub fn relative_error(
    a: Input<'_>,
    d: &CurDecomposition,
    k: usize,
    sketch_s: Option<usize>,
    rng: &mut Pcg64,
) -> CurErrorReport {
    let residual = match sketch_s {
        Some(s) => d.residual_estimate(a, s, rng),
        None => d.residual(a),
    };
    let ak_error = crate::svdstream::ak_error(a, k, 6, rng);
    CurErrorReport { residual, ak_error }
}
