//! Column/row selection for CUR decomposition.
//!
//! Three strategies, all returning a sorted index set plus the gathered
//! submatrix (`C = A[:, idx]` for columns, `R = A[idx, :]` for rows):
//!
//! * **uniform** — indices without replacement, the cheapest baseline;
//! * **leverage** — exact leverage-score sampling: column scores are
//!   `sketch::leverage::column_leverage_scores` (thin-QR of `Aᵀ`), row
//!   scores `row_leverage_scores` (thin-QR of `A`) — `O(mn·min(m,n))`;
//! * **sketched leverage** — approximate scores from a small sketch of
//!   the *opposite* side (Drineas et al. 2012 flavour): column scores
//!   come from `S·A` with `S ∈ R^{s×m}`, so scoring is sublinear in `m`
//!   (and `O(nnz)` for CSR inputs with CountSketch); row scores from
//!   `A·Sᵀ`. The scores are the rank-`s` leverage proxy — exactly what
//!   CUR wants when the full-rank scores degenerate to uniform.
//!
//! Leverage draws are *without replacement* (weights are zeroed as
//! indices are taken), so the gathered factors are full-rank generically
//! instead of carrying duplicate columns into the core solve.

use crate::gmr::Input;
use crate::linalg::Mat;
use crate::parallel::{self, Pool};
use crate::rng::Pcg64;
use crate::sketch::{column_leverage_scores, row_leverage_scores, Sketch, SketchKind};

/// How CUR picks its column/row index sets.
#[derive(Clone, Debug)]
pub enum SelectionStrategy {
    /// Uniform sampling without replacement.
    Uniform,
    /// Exact leverage-score sampling (thin-QR of `A`/`Aᵀ`; densifies CSR
    /// inputs — prefer [`SelectionStrategy::SketchedLeverage`] there).
    Leverage,
    /// Leverage scores estimated from a `size`-row sketch of the
    /// opposite dimension; sublinear in the big dimension.
    SketchedLeverage { kind: SketchKind, size: usize },
}

impl SelectionStrategy {
    /// CLI/config token → strategy (`size` scales with the selection).
    pub fn parse(s: &str, sketch: SketchKind, size: usize) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "uniform" => Self::Uniform,
            "leverage" | "lev" => Self::Leverage,
            "sketched" | "sketched-leverage" | "approx" => {
                Self::SketchedLeverage { kind: sketch, size }
            }
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Uniform => "uniform",
            Self::Leverage => "leverage",
            Self::SketchedLeverage { .. } => "sketched-leverage",
        }
    }
}

/// Column sampling weights for the strategy (`None` = uniform).
pub fn column_scores(
    a: Input<'_>,
    strategy: &SelectionStrategy,
    rng: &mut Pcg64,
) -> Option<Vec<f64>> {
    match strategy {
        SelectionStrategy::Uniform => None,
        SelectionStrategy::Leverage => Some(match a {
            Input::Dense(m) => column_leverage_scores(m),
            Input::Sparse(m) => column_leverage_scores(&m.to_dense()),
        }),
        SelectionStrategy::SketchedLeverage { kind, size } => {
            let s = (*size).clamp(1, a.rows().max(1));
            let sk = Sketch::draw(oblivious(*kind), s, a.rows(), None, rng);
            // Column scores of S·A ≈ rank-s column leverage of A.
            Some(column_leverage_scores(&a.sketch_left(&sk)))
        }
    }
}

/// Row sampling weights for the strategy (`None` = uniform).
pub fn row_scores(a: Input<'_>, strategy: &SelectionStrategy, rng: &mut Pcg64) -> Option<Vec<f64>> {
    match strategy {
        SelectionStrategy::Uniform => None,
        SelectionStrategy::Leverage => Some(match a {
            Input::Dense(m) => row_leverage_scores(m),
            Input::Sparse(m) => row_leverage_scores(&m.to_dense()),
        }),
        SelectionStrategy::SketchedLeverage { kind, size } => {
            let s = (*size).clamp(1, a.cols().max(1));
            let sk = Sketch::draw(oblivious(*kind), s, a.cols(), None, rng);
            // Row scores of A·Sᵀ ≈ rank-s row leverage of A.
            Some(row_leverage_scores(&a.sketch_right(&sk)))
        }
    }
}

/// Select `count` column indices of `A` and gather `C = A[:, idx]`.
pub fn select_columns(
    a: Input<'_>,
    strategy: &SelectionStrategy,
    count: usize,
    rng: &mut Pcg64,
) -> (Vec<usize>, Mat) {
    let n = a.cols();
    let idx = match column_scores(a, strategy, rng) {
        None => uniform_indices(n, count, rng),
        Some(w) => weighted_indices_without_replacement(&w, count, rng),
    };
    let c = gather_columns(a, &idx);
    (idx, c)
}

/// Select `count` row indices of `A` and gather `R = A[idx, :]`.
pub fn select_rows(
    a: Input<'_>,
    strategy: &SelectionStrategy,
    count: usize,
    rng: &mut Pcg64,
) -> (Vec<usize>, Mat) {
    let m = a.rows();
    let idx = match row_scores(a, strategy, rng) {
        None => uniform_indices(m, count, rng),
        Some(w) => weighted_indices_without_replacement(&w, count, rng),
    };
    let r = gather_rows(a, &idx);
    (idx, r)
}

/// The scoring sketch must be data-oblivious: `SketchKind::Leverage`
/// would need the very scores we are estimating, so it degrades to
/// uniform sampling instead of panicking in `Sketch::draw`.
fn oblivious(kind: SketchKind) -> SketchKind {
    match kind {
        SketchKind::Leverage => SketchKind::Uniform,
        k => k,
    }
}

fn uniform_indices(n: usize, count: usize, rng: &mut Pcg64) -> Vec<usize> {
    let mut idx = rng.sample_without_replacement(n, count.min(n));
    idx.sort_unstable();
    idx
}

/// Draw `count` distinct indices with probability proportional to the
/// (nonnegative) weights, zeroing each taken weight. A tiny uniform
/// floor (the same 1e-12 convention as `sketch::leverage`) keeps
/// degenerate score vectors able to fill every slot.
fn weighted_indices_without_replacement(
    weights: &[f64],
    count: usize,
    rng: &mut Pcg64,
) -> Vec<usize> {
    let n = weights.len();
    let count = count.min(n);
    let mut w: Vec<f64> = weights.iter().map(|&x| x.max(0.0)).collect();
    let total: f64 = w.iter().sum();
    assert!(total.is_finite(), "cur selection: non-finite leverage scores");
    let floor = (total.max(1.0)) * 1e-12 / n as f64;
    for v in &mut w {
        *v += floor;
    }
    let mut idx = Vec::with_capacity(count);
    for _ in 0..count {
        let i = rng.sample_weighted(&w);
        idx.push(i);
        w[i] = 0.0;
    }
    idx.sort_unstable();
    idx
}

/// Gather `C = A[:, idx]` — dense inputs shard the row-wise gather over
/// the calling thread's pool (bitwise: pure gather, no reductions); CSR
/// inputs use the `O(nnz)` column gather.
pub fn gather_columns(a: Input<'_>, idx: &[usize]) -> Mat {
    match a {
        Input::Dense(am) => {
            let (rows, w) = (am.rows(), idx.len());
            let mut out = Mat::zeros(rows, w);
            let pool = if parallel::threads() > 1 && rows * w >= parallel::PAR_MIN_WORK {
                Pool::current()
            } else {
                Pool::new(1)
            };
            pool.run_row_panels(rows, w, out.data_mut(), |r0, r1, panel| {
                for i in r0..r1 {
                    let src = am.row(i);
                    let dst = &mut panel[(i - r0) * w..(i - r0 + 1) * w];
                    for (o, &j) in idx.iter().enumerate() {
                        dst[o] = src[j];
                    }
                }
            });
            out
        }
        Input::Sparse(am) => am.select_cols_dense(idx),
    }
}

/// Gather `R = A[idx, :]` (row copies — memcpy-bound, not worth sharding).
pub fn gather_rows(a: Input<'_>, idx: &[usize]) -> Mat {
    match a {
        Input::Dense(am) => am.select_rows(idx),
        Input::Sparse(am) => {
            let ones = vec![1.0; idx.len()];
            am.select_rows_scaled_dense(idx, &ones)
        }
    }
}
