//! Column/row selection for CUR decomposition.
//!
//! Four strategies, all returning a sorted index set plus the gathered
//! submatrix (`C = A[:, idx]` for columns, `R = A[idx, :]` for rows):
//!
//! * **uniform** — indices without replacement, the cheapest baseline;
//! * **leverage** — exact full-rank leverage-score sampling: column
//!   scores are `sketch::leverage::column_leverage_scores` (thin-QR of
//!   `Aᵀ`), row scores `row_leverage_scores` (thin-QR of `A`) —
//!   `O(mn·min(m,n))`;
//! * **subspace leverage** — rank-`k` restricted scores
//!   `‖U_k(i,:)‖²`/`‖V_k(j,:)‖²` from the top-`k` singular subspaces
//!   (Wang & Zhang's near-optimal CUR sampling). On square-ish full-rank
//!   inputs the full-rank scores above are *exactly* uniform (the thin-QR
//!   `Q` is orthogonal), so only the subspace restriction can see which
//!   columns carry the spectral mass;
//! * **sketched leverage** — approximate scores from a small sketch of
//!   the *opposite* side (Drineas et al. 2012 flavour): column scores
//!   come from `S·A` with `S ∈ R^{s×m}`, so scoring is sublinear in `m`
//!   (and `O(nnz)` for CSR inputs with CountSketch); row scores from
//!   `A·Sᵀ`. The scores are the rank-`s` leverage proxy.
//!
//! Leverage draws are *without replacement* (weights are zeroed as
//! indices are taken), so the gathered factors are full-rank generically
//! instead of carrying duplicate columns into the core solve.
//!
//! The streaming CUR driver ([`crate::cur::streaming`]) shares this
//! module's scoring (`sketch::leverage`) and the weighted
//! without-replacement draw, applied to its co-range accumulator instead
//! of to `A` directly.

use crate::error::{FgError, Result};
use crate::gmr::Input;
use crate::linalg::Mat;
use crate::parallel::{self, Pool};
use crate::rng::Pcg64;
use crate::sketch::{
    column_leverage_scores, row_leverage_scores, subspace_column_leverage_scores,
    subspace_row_leverage_scores, Sketch, SketchKind,
};

/// How CUR picks its column/row index sets.
#[derive(Clone, Debug)]
pub enum SelectionStrategy {
    /// Uniform sampling without replacement.
    Uniform,
    /// Exact full-rank leverage-score sampling (thin-QR of `A`/`Aᵀ`;
    /// densifies CSR inputs — prefer
    /// [`SelectionStrategy::SketchedLeverage`] there). Degenerates to
    /// uniform scores on square-ish full-rank inputs — use
    /// [`SelectionStrategy::SubspaceLeverage`] then.
    Leverage,
    /// Rank-`k` subspace leverage scores `‖U_k(i,:)‖²` / `‖V_k(j,:)‖²`
    /// from the top-`k` singular subspaces of `A` (densifies CSR inputs).
    SubspaceLeverage { k: usize },
    /// Leverage scores estimated from a `size`-row sketch of the
    /// opposite dimension; sublinear in the big dimension.
    SketchedLeverage { kind: SketchKind, size: usize },
}

/// The accepted CLI/config tokens, kept next to [`SelectionStrategy::parse`]
/// so `--help` text and error messages cannot drift apart.
pub const SELECTION_TOKENS: &str =
    "uniform | leverage|lev | subspace|subspace-leverage|lev-k | sketched|sketched-leverage|approx";

impl SelectionStrategy {
    /// CLI/config token → strategy. `size` scales the sketched-leverage
    /// sketch with the selection; `k` is the subspace rank. Unknown
    /// tokens are a hard [`FgError::Config`] listing the accepted values
    /// — a silent fallback would benchmark a strategy the user did not
    /// ask for.
    pub fn parse(s: &str, sketch: SketchKind, size: usize, k: usize) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "uniform" => Self::Uniform,
            "leverage" | "lev" => Self::Leverage,
            "subspace" | "subspace-leverage" | "lev-k" => Self::SubspaceLeverage { k: k.max(1) },
            "sketched" | "sketched-leverage" | "approx" => {
                Self::SketchedLeverage { kind: sketch, size }
            }
            other => {
                return Err(FgError::Config(format!(
                    "unknown selection strategy `{other}` (accepted: {SELECTION_TOKENS})"
                )))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Uniform => "uniform",
            Self::Leverage => "leverage",
            Self::SubspaceLeverage { .. } => "subspace-leverage",
            Self::SketchedLeverage { .. } => "sketched-leverage",
        }
    }
}

/// Column sampling weights for the strategy (`None` = uniform).
pub fn column_scores(
    a: Input<'_>,
    strategy: &SelectionStrategy,
    rng: &mut Pcg64,
) -> Option<Vec<f64>> {
    match strategy {
        SelectionStrategy::Uniform => None,
        SelectionStrategy::Leverage => Some(match a {
            Input::Dense(m) => column_leverage_scores(m),
            Input::Sparse(m) => column_leverage_scores(&m.to_dense()),
        }),
        SelectionStrategy::SubspaceLeverage { k } => Some(match a {
            Input::Dense(m) => subspace_column_leverage_scores(m, *k),
            Input::Sparse(m) => subspace_column_leverage_scores(&m.to_dense(), *k),
        }),
        SelectionStrategy::SketchedLeverage { kind, size } => {
            let s = (*size).clamp(1, a.rows().max(1));
            let sk = Sketch::draw(oblivious(*kind), s, a.rows(), None, rng);
            // Column scores of S·A ≈ rank-s column leverage of A.
            Some(column_leverage_scores(&a.sketch_left(&sk)))
        }
    }
}

/// Row sampling weights for the strategy (`None` = uniform).
pub fn row_scores(a: Input<'_>, strategy: &SelectionStrategy, rng: &mut Pcg64) -> Option<Vec<f64>> {
    match strategy {
        SelectionStrategy::Uniform => None,
        SelectionStrategy::Leverage => Some(match a {
            Input::Dense(m) => row_leverage_scores(m),
            Input::Sparse(m) => row_leverage_scores(&m.to_dense()),
        }),
        SelectionStrategy::SubspaceLeverage { k } => Some(match a {
            Input::Dense(m) => subspace_row_leverage_scores(m, *k),
            Input::Sparse(m) => subspace_row_leverage_scores(&m.to_dense(), *k),
        }),
        SelectionStrategy::SketchedLeverage { kind, size } => {
            let s = (*size).clamp(1, a.cols().max(1));
            let sk = Sketch::draw(oblivious(*kind), s, a.cols(), None, rng);
            // Row scores of A·Sᵀ ≈ rank-s row leverage of A.
            Some(row_leverage_scores(&a.sketch_right(&sk)))
        }
    }
}

/// Select `count` column indices of `A` and gather `C = A[:, idx]`.
pub fn select_columns(
    a: Input<'_>,
    strategy: &SelectionStrategy,
    count: usize,
    rng: &mut Pcg64,
) -> (Vec<usize>, Mat) {
    let n = a.cols();
    let idx = match column_scores(a, strategy, rng) {
        None => uniform_indices(n, count, rng),
        Some(w) => weighted_indices_without_replacement(&w, count, rng),
    };
    let c = gather_columns(a, &idx);
    (idx, c)
}

/// Select `count` row indices of `A` and gather `R = A[idx, :]`.
pub fn select_rows(
    a: Input<'_>,
    strategy: &SelectionStrategy,
    count: usize,
    rng: &mut Pcg64,
) -> (Vec<usize>, Mat) {
    let m = a.rows();
    let idx = match row_scores(a, strategy, rng) {
        None => uniform_indices(m, count, rng),
        Some(w) => weighted_indices_without_replacement(&w, count, rng),
    };
    let r = gather_rows(a, &idx);
    (idx, r)
}

/// The scoring sketch must be data-oblivious: `SketchKind::Leverage`
/// would need the very scores we are estimating, so it degrades to
/// uniform sampling instead of panicking in `Sketch::draw`.
fn oblivious(kind: SketchKind) -> SketchKind {
    match kind {
        SketchKind::Leverage => SketchKind::Uniform,
        k => k,
    }
}

fn uniform_indices(n: usize, count: usize, rng: &mut Pcg64) -> Vec<usize> {
    let mut idx = rng.sample_without_replacement(n, count.min(n));
    idx.sort_unstable();
    idx
}

/// Draw `count` distinct indices with probability proportional to the
/// (nonnegative) weights, zeroing each taken weight; returns them sorted
/// ascending. A tiny uniform floor (the same 1e-12 convention as
/// `sketch::leverage`) keeps degenerate score vectors able to fill every
/// slot. Shared with the streaming driver's end-of-pass draws.
pub(crate) fn weighted_indices_without_replacement(
    weights: &[f64],
    count: usize,
    rng: &mut Pcg64,
) -> Vec<usize> {
    let n = weights.len();
    let count = count.min(n);
    let mut w: Vec<f64> = weights.iter().map(|&x| x.max(0.0)).collect();
    let total: f64 = w.iter().sum();
    assert!(total.is_finite(), "cur selection: non-finite leverage scores");
    let floor = (total.max(1.0)) * 1e-12 / n as f64;
    for v in &mut w {
        *v += floor;
    }
    let mut idx = Vec::with_capacity(count);
    for _ in 0..count {
        let i = rng.sample_weighted(&w);
        idx.push(i);
        w[i] = 0.0;
    }
    idx.sort_unstable();
    idx
}

/// Gather `C = A[:, idx]` — dense inputs shard the row-wise gather over
/// the calling thread's pool (bitwise: pure gather, no reductions); CSR
/// inputs use the `O(nnz)` column gather.
pub fn gather_columns(a: Input<'_>, idx: &[usize]) -> Mat {
    match a {
        Input::Dense(am) => {
            let (rows, w) = (am.rows(), idx.len());
            let mut out = Mat::zeros(rows, w);
            let pool = if parallel::threads() > 1 && rows * w >= parallel::PAR_MIN_WORK {
                Pool::current()
            } else {
                Pool::new(1)
            };
            pool.run_row_panels(rows, w, out.data_mut(), |r0, r1, panel| {
                for i in r0..r1 {
                    let src = am.row(i);
                    let dst = &mut panel[(i - r0) * w..(i - r0 + 1) * w];
                    for (o, &j) in idx.iter().enumerate() {
                        dst[o] = src[j];
                    }
                }
            });
            out
        }
        Input::Sparse(am) => am.select_cols_dense(idx),
    }
}

/// Gather `R = A[idx, :]` (row copies — memcpy-bound, not worth sharding).
pub fn gather_rows(a: Input<'_>, idx: &[usize]) -> Mat {
    match a {
        Input::Dense(am) => am.select_rows(idx),
        Input::Sparse(am) => {
            let ones = vec![1.0; idx.len()];
            am.select_rows_scaled_dense(idx, &ones)
        }
    }
}
