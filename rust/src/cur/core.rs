//! Core-matrix solvers: the three ways CUR computes `U ≈ C† A R†`.

use crate::gmr::{self, Input};
use crate::linalg::{matmul_at_b, qr_thin, solve_upper, Mat};
use crate::rng::Pcg64;
use crate::sketch::{Sketch, SketchKind};

/// How the core `U` is computed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoreMethod {
    /// `U = C† A R†` via the normal-equation pinv-applies (the baseline
    /// Fast GMR accelerates; one full pass over `A`).
    Exact,
    /// Fast-GMR sketched core (Algorithm 1, the paper's route): solve
    /// the sketched problem `(S_C C)† (S_C A S_Rᵀ) (R S_Rᵀ)†`.
    FastGmr,
    /// Exact core solved through thin-QR of `C` and `Rᵀ` (the blocked
    /// compact-WY kernel, so the tall factors ride the pool) — avoids
    /// squaring the condition number for ill-conditioned selections,
    /// falling back to [`CoreMethod::Exact`] when a triangular factor is
    /// numerically rank-deficient (e.g. near-duplicate sampled columns).
    StabilizedQr,
}

impl CoreMethod {
    /// Parse from a CLI/config token.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "exact" => Self::Exact,
            "fast" | "gmr" | "fast-gmr" => Self::FastGmr,
            "qr" | "stabilized" | "stabilized-qr" => Self::StabilizedQr,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Exact => "exact",
            Self::FastGmr => "fast-gmr",
            Self::StabilizedQr => "stabilized-qr",
        }
    }
}

/// `U = C† A R†` (delegates to [`gmr::solve_exact`]).
pub fn core_exact(a: Input<'_>, c: &Mat, r: &Mat) -> Mat {
    gmr::solve_exact(a, c, r).x
}

/// Fast-GMR core with `kind` sketches of size `s_c × s_r` (clamped to
/// `[cols(C), m] × [rows(R), n]`). When both sketch sizes reach the full
/// dimensions the sketches degenerate to [`Sketch::identity`], so the
/// sketched code path reproduces the exact `C† A R†` solve — the
/// identity-sized agreement the tests pin at ≤ 1e-8. Degenerate
/// selections that no sketch size can serve (more columns than rows of
/// A, or vice versa) and sparse identity-sized inputs (where an identity
/// sampling sketch would densify A) solve through [`core_exact`].
pub fn core_fast(
    a: Input<'_>,
    c: &Mat,
    r: &Mat,
    kind: SketchKind,
    s_c: usize,
    s_r: usize,
    rng: &mut Pcg64,
) -> Mat {
    let (m, n) = (a.rows(), a.cols());
    // Lower-bound by the factor width (solve_fast's requirement), then
    // cap at the full dimension where sketching stops making sense.
    let s_c = s_c.max(c.cols());
    let s_c = s_c.min(m);
    let s_r = s_r.max(r.rows());
    let s_r = s_r.min(n);
    if s_c < c.cols() || s_r < r.rows() {
        // Over-selection (c > m or r > n): no valid sketch size exists.
        return core_exact(a, c, r);
    }
    if s_c >= m && s_r >= n {
        return match a {
            Input::Dense(_) => {
                gmr::solve_fast_with(a, c, r, &Sketch::identity(m), &Sketch::identity(n)).x
            }
            // Identity sampling would materialize the sparse A densely
            // (twice); the exact core computes the same thing in O(nnz).
            Input::Sparse(_) => core_exact(a, c, r),
        };
    }
    let cfg = gmr::FastGmrConfig { kind_c: kind, kind_r: kind, s_c, s_r };
    gmr::solve_fast(a, c, r, &cfg, rng).x
}

/// Stabilized exact core: with thin factorizations `C = Q_c R_c` and
/// `Rᵀ = Q_r R_r`, the minimizer is
///
/// ```text
/// U = C† A R† = R_c⁻¹ (Q_cᵀ A Q_r) R_r⁻ᵀ
/// ```
///
/// computed by two triangular solves — conditioning κ(C) instead of the
/// normal equations' κ(C)². Falls back to [`core_exact`]'s pinv route
/// when either triangular factor is numerically singular.
pub fn core_stabilized(a: Input<'_>, c: &Mat, r: &Mat) -> Mat {
    let qc = qr_thin(c);
    let qr_fac = qr_thin(&r.transpose());
    if !diag_well_conditioned(&qc.r) || !diag_well_conditioned(&qr_fac.r) {
        return core_exact(a, c, r);
    }
    let aq = a.a_b(&qr_fac.q); // m × r
    let mid = matmul_at_b(&qc.q, &aq); // c × r = Q_cᵀ A Q_r
    let y = solve_upper(&qc.r, &mid); // R_c Y = Q_cᵀ A Q_r
    // U R_rᵀ = Y  ⇔  R_r Uᵀ = Yᵀ.
    solve_upper(&qr_fac.r, &y.transpose()).transpose()
}

/// Diagonal-ratio conditioning guard for a triangular QR factor: the
/// smallest |diagonal| must not be more than ~10 decades below the
/// largest (duplicate sampled columns put an exact zero here).
fn diag_well_conditioned(r: &Mat) -> bool {
    let k = r.rows().min(r.cols());
    if k == 0 {
        return false;
    }
    let mut maxd = 0.0f64;
    let mut mind = f64::INFINITY;
    for i in 0..k {
        let d = r[(i, i)].abs();
        maxd = maxd.max(d);
        mind = mind.min(d);
    }
    maxd > 0.0 && mind >= maxd * 1e-10
}
