//! Single-pass streaming CUR over a [`ColumnStream`] — CUR joins the
//! §5 single-pass family next to `svdstream`.
//!
//! The in-memory path ([`crate::cur::decompose`]) reads `A` several
//! times: once to score, once to gather, once per core sketch. This
//! driver consumes the stream **exactly once** and keeps only
//! sketch-sized state, following Tropp et al.'s *practical sketching*
//! range/co-range recipe and Wang & Zhang's leverage-based CUR:
//!
//! * `Y = S_C·A` (s_c × n) — the **co-range accumulator**: block `A_L`
//!   contributes the column slice `Y[:, c0..c1] = S_C A_L` (disjoint
//!   writes, so the accumulation is exact and order-free). `Y` yields
//!   the rank-`k` subspace column leverage scores `‖V_k(j,:)‖²`
//!   ([`crate::sketch::subspace_column_leverage_scores`]) *and* the
//!   Fast-GMR products `S_C C = Y[:, col_idx]`,
//!   `Ã = S_C A S_Rᵀ = Y·S_Rᵀ`.
//! * `Z = A·S_Rᵀ` (m × s_r) — the **range accumulator**:
//!   `Z += A_L·(S_R[:, c0..c1])ᵀ`, folded in stream order. `Z` yields
//!   the rank-`k` subspace row scores and `R S_Rᵀ = Z[row_idx, :]`.
//! * a **weighted column reservoir** (Efraimidis–Spirakis keys
//!   `u^{1/w}`, capacity `oversample·c`) retains *actual columns* of
//!   `A` as they stream past, keyed on the provisional sketched column
//!   norms `‖S_C a_j‖²`; the final `c` columns are drawn from the
//!   retained candidates under the end-of-pass rank-`k` scores.
//!
//! After the pass everything resolves from the retained state — no
//! second read: `C` from the reservoir, the Fast-GMR core
//! `U = (S_C C)† Ã (R S_Rᵀ)†` ([`crate::gmr::solve_core`]) entirely
//! from sketch products, and the row factor by the single-pass
//! reconstruction `R̂ = (R S_Rᵀ)·Ã†·Y ≈ A[row_idx, :]` (Tropp et al.;
//! needs `s_c` comfortably above `s_r`, see
//! [`StreamingCurConfig::fast`]). With full-dimension sketch sizes both
//! sketches degenerate to [`Sketch::identity`], and every resolved
//! quantity reproduces the in-memory Fast-GMR CUR exactly.
//!
//! Determinism: the reservoir and the final draws consume the seeded
//! rng on the driver thread in stream order, and the Gaussian/SRHT
//! applies are bitwise thread-invariant — so the selected indices are
//! bitwise identical across thread counts (the global threads-knob test
//! pins this). The concurrent production form of the per-block work
//! lives in [`crate::coordinator::pipeline`] (`run_cur`), which
//! double-buffers batches exactly like the SVD pipeline.

use super::select::weighted_indices_without_replacement;
use super::CurDecomposition;
use crate::error::Result;
use crate::gmr;
use crate::linalg::{matmul, pinv, Mat};
use crate::parallel::Pool;
use crate::rng::Pcg64;
use crate::sketch::{
    subspace_column_leverage_scores, subspace_row_leverage_scores, Sketch, SketchKind,
};
use crate::svdstream::source::ColumnStream;

/// Configuration for [`streaming_cur`].
#[derive(Clone, Debug)]
pub struct StreamingCurConfig {
    /// Number of columns to select (`C` is m×c).
    pub c: usize,
    /// Number of rows to select (`R̂` is r×n).
    pub r: usize,
    /// Subspace rank for the rank-`k` leverage scores.
    pub k: usize,
    /// Sketch family. `S_C` uses it directly (Gaussian/SRHT are bitwise
    /// thread-invariant); `S_R` must be input-sliceable per block, so
    /// SRHT falls back to Gaussian there (and the data-dependent
    /// Leverage family to uniform sampling on both sides).
    pub kind: SketchKind,
    /// Co-range sketch size (rows of `Y = S_C A`), clamped to `[c, m]`;
    /// at `m` the sketch degenerates to the identity.
    pub s_c: usize,
    /// Range sketch size (columns of `Z = A S_Rᵀ`), clamped to `[r, n]`.
    pub s_r: usize,
    /// Column reservoir capacity multiplier: `oversample·c` candidate
    /// columns are retained during the pass (clamped to `[c, n]`).
    pub oversample: usize,
}

impl StreamingCurConfig {
    /// The paper-flavoured default: Gaussian sketches with
    /// `s_r = mult·r` and `s_c = 2·mult·c`. The co-range sketch is twice
    /// the range sketch because the single-pass row reconstruction
    /// `R̂ = (R S_Rᵀ)Ã†Y` is only stable when `s_c` dominates `s_r`
    /// (Tropp et al. recommend a factor ≈ 2; at `s_c = s_r` its variance
    /// blows up).
    pub fn fast(c: usize, r: usize, k: usize, mult: usize) -> Self {
        Self {
            c,
            r,
            k,
            kind: SketchKind::Gaussian,
            s_c: 2 * mult * c,
            s_r: mult * r,
            oversample: 4,
        }
    }
}

/// The realized sketch pair, drawn before the pass (shared between the
/// reference driver and the coordinator pipeline so both are
/// bit-identical given the same rng seed).
pub struct StreamingCurSketches {
    /// `S_C` — s_c × m (co-range / leverage sketch).
    pub s_c: Sketch,
    /// `S_R` — s_r × n (range / core sketch; sliced per column block).
    pub s_r: Sketch,
}

impl StreamingCurSketches {
    /// Draw both sketches for an m×n stream. Sizes are clamped to
    /// `[c, m]` / `[r, n]` (the core solve needs `s_c ≥ c`, `s_r ≥ r`);
    /// a full-dimension size degenerates to [`Sketch::identity`], which
    /// makes the whole driver reproduce the in-memory Fast-GMR CUR.
    pub fn draw(cfg: &StreamingCurConfig, m: usize, n: usize, rng: &mut Pcg64) -> Self {
        let sc_size = cfg.s_c.max(cfg.c).min(m);
        let s_c = if sc_size >= m {
            Sketch::identity(m)
        } else {
            Sketch::draw(oblivious(cfg.kind), sc_size, m, None, rng)
        };
        let sr_size = cfg.s_r.max(cfg.r).min(n);
        let s_r = if sr_size >= n {
            Sketch::identity(n)
        } else {
            Sketch::draw(sliceable(cfg.kind), sr_size, n, None, rng)
        };
        Self { s_c, s_r }
    }
}

/// `S_C` must be data-oblivious (no scores exist yet mid-stream).
fn oblivious(kind: SketchKind) -> SketchKind {
    match kind {
        SketchKind::Leverage => SketchKind::Uniform,
        k => k,
    }
}

/// `S_R` is additionally sliced per block, which SRHT's global mixing
/// cannot support.
fn sliceable(kind: SketchKind) -> SketchKind {
    match kind {
        SketchKind::Srht => SketchKind::Gaussian,
        k => oblivious(k),
    }
}

/// Weighted reservoir of actual columns (Efraimidis–Spirakis A-ES):
/// a column with provisional weight `w` gets key `u^{1/w}` for a fresh
/// uniform `u`, and the `cap` largest keys survive. One uniform is
/// consumed per offered column whether or not it is admitted, so the
/// rng stream — and with it the retained set — depends only on stream
/// order, never on thread count.
pub(crate) struct ColumnReservoir {
    cap: usize,
    entries: Vec<ReservoirEntry>,
}

struct ReservoirEntry {
    key: f64,
    idx: usize,
    col: Vec<f64>,
}

impl ColumnReservoir {
    fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), entries: Vec::new() }
    }

    /// Offer column `idx` with provisional weight `weight`; `col` is
    /// called only when the column is admitted (so unretained columns
    /// are never copied).
    fn offer(&mut self, idx: usize, weight: f64, col: impl FnOnce() -> Vec<f64>, rng: &mut Pcg64) {
        let u = rng.next_f64();
        let key = u.powf(1.0 / weight.max(1e-300));
        if self.entries.len() < self.cap {
            self.entries.push(ReservoirEntry { key, idx, col: col() });
            return;
        }
        let mut min_at = 0;
        for (t, e) in self.entries.iter().enumerate() {
            if e.key < self.entries[min_at].key {
                min_at = t;
            }
        }
        if key > self.entries[min_at].key {
            self.entries[min_at] = ReservoirEntry { key, idx, col: col() };
        }
    }

    fn len(&self) -> usize {
        self.entries.len()
    }
}

/// The per-block sketch products, computed pool-parallel and folded
/// serially in stream order (the split that lets the coordinator
/// pipeline overlap block sketching with the stream read).
pub struct BlockSketch {
    pub(crate) col_start: usize,
    pub(crate) data: Mat,
    pub(crate) y_blk: Mat,
    pub(crate) z_blk: Mat,
    pub(crate) norms: Vec<f64>,
}

/// Sketch one column block: `Y` slice, `Z` contribution, and the
/// provisional column weights `‖S_C a_j‖²`. Pure function of the block —
/// safe to run concurrently for different blocks on any pool.
pub fn sketch_block(
    col_start: usize,
    data: Mat,
    sk: &StreamingCurSketches,
    pool: &Pool,
) -> BlockSketch {
    let c1 = col_start + data.cols();
    let y_blk = sk.s_c.apply_left_with(&data, pool);
    let z_blk = sk.s_r.slice_input(col_start, c1).apply_right_with(&data, pool);
    let mut norms = vec![0.0; y_blk.cols()];
    for i in 0..y_blk.rows() {
        for (o, &v) in norms.iter_mut().zip(y_blk.row(i)) {
            *o += v * v;
        }
    }
    BlockSketch { col_start, data, y_blk, z_blk, norms }
}

/// Accumulated single-pass state: the two sketch accumulators plus the
/// column reservoir. Folding is driver-side and strictly in stream
/// order, so the result is independent of how blocks were sketched.
pub struct StreamState {
    y: Mat,
    z: Mat,
    reservoir: ColumnReservoir,
    blocks: usize,
}

impl StreamState {
    /// Fresh state for an m×n stream.
    pub fn new(cfg: &StreamingCurConfig, sk: &StreamingCurSketches, m: usize, n: usize) -> Self {
        let cap = (cfg.oversample.max(1) * cfg.c.max(1)).min(n.max(1));
        Self {
            y: Mat::zeros(sk.s_c.out_dim(), n),
            z: Mat::zeros(m, sk.s_r.out_dim()),
            reservoir: ColumnReservoir::new(cap),
            blocks: 0,
        }
    }

    /// Fold one sketched block (must be called in stream order): write
    /// the `Y` slice, add the `Z` contribution, and offer every column
    /// to the reservoir. Consumes the block — the raw data is dropped
    /// here unless the reservoir retained a column.
    pub fn fold(&mut self, bs: BlockSketch, rng: &mut Pcg64) {
        self.y.set_block(0, bs.col_start, &bs.y_blk);
        self.z += &bs.z_blk;
        for j in 0..bs.data.cols() {
            self.reservoir.offer(bs.col_start + j, bs.norms[j], || bs.data.col(j), rng);
        }
        self.blocks += 1;
    }

    /// Blocks folded so far.
    pub fn blocks(&self) -> usize {
        self.blocks
    }

    /// Current reservoir occupancy (diagnostics/metrics).
    pub fn candidates(&self) -> usize {
        self.reservoir.len()
    }
}

/// A completed streaming CUR run.
pub struct StreamingCurResult {
    /// The decomposition. `c` holds *actual columns* of `A` (retained by
    /// the reservoir); `r` is the sketch-resolved `R̂ ≈ A[row_idx, :]`
    /// (exact at full sketch sizes).
    pub cur: CurDecomposition,
    /// Column blocks consumed (diagnostics).
    pub blocks: usize,
    /// Candidate columns retained by the reservoir at finalize time.
    pub candidates: usize,
}

/// End-of-pass resolution: rank-`k` scores from the accumulators, final
/// column draw from the reservoir, row draw, and the core + row factor
/// from the retained sketches alone.
pub fn finalize(
    cfg: &StreamingCurConfig,
    sk: &StreamingCurSketches,
    mut state: StreamState,
    rng: &mut Pcg64,
) -> StreamingCurResult {
    let m = state.z.rows();
    let blocks = state.blocks;

    // Columns: rank-k subspace scores over all n columns from Y, then a
    // weighted draw restricted to the retained candidates.
    let mut select_span = crate::obs::span("curstream.select", crate::obs::cat::GATHER);
    select_span.meta("candidates", state.reservoir.len());
    let col_scores = subspace_column_leverage_scores(&state.y, cfg.k);
    state.reservoir.entries.sort_by_key(|e| e.idx);
    let cand_weights: Vec<f64> =
        state.reservoir.entries.iter().map(|e| col_scores[e.idx]).collect();
    let candidates = state.reservoir.len();
    let picks = weighted_indices_without_replacement(&cand_weights, cfg.c, rng);
    let col_idx: Vec<usize> = picks.iter().map(|&p| state.reservoir.entries[p].idx).collect();
    let mut c_mat = Mat::zeros(m, col_idx.len());
    for (o, &p) in picks.iter().enumerate() {
        for (i, &v) in state.reservoir.entries[p].col.iter().enumerate() {
            c_mat[(i, o)] = v;
        }
    }

    // Rows: rank-k subspace scores from the range accumulator Z.
    let row_scores = subspace_row_leverage_scores(&state.z, cfg.k);
    let row_idx = weighted_indices_without_replacement(&row_scores, cfg.r, rng);
    drop(select_span);

    // Fast-GMR core from sketch products only: S_C C = Y[:, col_idx],
    // R S_Rᵀ = Z[row_idx, :], Ã = Y S_Rᵀ.
    let mut core_span = crate::obs::span("curstream.core", crate::obs::cat::SOLVE);
    core_span.meta("s_c", sk.s_c.out_dim());
    core_span.meta("s_r", sk.s_r.out_dim());
    let sc_c = state.y.select_cols(&col_idx);
    let r_sr = state.z.select_rows(&row_idx);
    let a_tilde = sk.s_r.apply_right(&state.y);
    let u = gmr::solve_core(&sc_c, &a_tilde, &r_sr);
    drop(core_span);

    // Row factor: single-pass reconstruction R̂ = (R S_Rᵀ)·Ã†·Y. Ã is
    // *tall* (s_c ≈ 2·s_r by design), so `pinv_apply_right` — whose
    // Cholesky path builds the rows×rows Gram, singular here — is the
    // wrong tool; the SVD pseudoinverse handles the tall rank-s_r shape.
    let rows_span = crate::obs::span("curstream.rows", crate::obs::cat::SOLVE);
    let r_hat = matmul(&matmul(&r_sr, &pinv(&a_tilde)), &state.y);
    drop(rows_span);

    StreamingCurResult {
        cur: CurDecomposition { col_idx, row_idx, c: c_mat, u, r: r_hat },
        blocks,
        candidates,
    }
}

/// Single-pass streaming CUR (reference driver): draw the sketches,
/// fold every block in stream order on the calling thread, resolve. The
/// concurrent production form is
/// [`crate::coordinator::StreamPipeline::run_cur`].
///
/// ```
/// use fastgmr::cur::streaming::{streaming_cur, StreamingCurConfig};
/// use fastgmr::linalg::Mat;
/// use fastgmr::rng::rng;
/// use fastgmr::svdstream::DenseColumnStream;
///
/// let mut r = rng(3);
/// let a = Mat::randn(50, 64, &mut r);
/// let cfg = StreamingCurConfig::fast(6, 6, 4, 2);
/// let mut stream = DenseColumnStream::new(&a, 16);
/// let res = streaming_cur(&mut stream, &cfg, &mut r).unwrap();
/// assert_eq!(res.blocks, 4);
/// assert_eq!(res.cur.c.shape(), (50, 6));
/// assert_eq!(res.cur.r.shape(), (6, 64));
/// ```
pub fn streaming_cur(
    stream: &mut dyn ColumnStream,
    cfg: &StreamingCurConfig,
    rng: &mut Pcg64,
) -> Result<StreamingCurResult> {
    let (m, n) = (stream.rows(), stream.cols());
    let sk = {
        let mut sp = crate::obs::span("curstream.sketch.draw", crate::obs::cat::SKETCH);
        sp.meta("s_c", cfg.s_c);
        sp.meta("s_r", cfg.s_r);
        StreamingCurSketches::draw(cfg, m, n, rng)
    };
    streaming_cur_with(stream, cfg, &sk, rng)
}

/// [`streaming_cur`] with pre-drawn sketches (shared with the
/// coordinator pipeline and with tests that pin reference agreement).
pub fn streaming_cur_with(
    stream: &mut dyn ColumnStream,
    cfg: &StreamingCurConfig,
    sk: &StreamingCurSketches,
    rng: &mut Pcg64,
) -> Result<StreamingCurResult> {
    let (m, n) = (stream.rows(), stream.cols());
    let mut state = StreamState::new(cfg, sk, m, n);
    let pool = Pool::current();
    while let Some(block) = stream.next_block()? {
        let mut sp = crate::obs::span("curstream.block", crate::obs::cat::STREAM);
        sp.meta("col_start", block.col_start);
        sp.meta("cols", block.data.cols());
        let bs = sketch_block(block.col_start, block.data, sk, &pool);
        state.fold(bs, rng);
    }
    Ok(finalize(cfg, sk, state, rng))
}

/// ε-planned streaming CUR. A [`ColumnStream`] is single-pass, so the
/// caller hands over a *factory*: each escalation attempt opens a fresh
/// stream over the same data (one full pass per attempt — the honest
/// cost model for out-of-core data; what *is* reused across attempts is
/// the sketch randomness, via [`Sketch::draw_extension`] each attempt's
/// sketches extend the previous attempt's bitwise, and the a-posteriori
/// check products, accumulated once on the first pass).
///
/// Sizing keeps the driver's `s_c ≈ 2·s_r` stability ratio (see
/// [`StreamingCurConfig::fast`]) by planning the co-range side at width
/// `2·c`; `cfg.s_c`/`cfg.s_r` are ignored. The attainment check scores
/// each attempt's *own* factors (reselection can change them), so the
/// certified ε is relative to the best core for the returned `C`/`R̂`.
pub fn streaming_cur_planned<'a, F>(
    mut open_stream: F,
    cfg: &StreamingCurConfig,
    plan: &crate::plan::EpsilonPlan,
) -> Result<(StreamingCurResult, crate::plan::PlanOutcome)>
where
    F: FnMut() -> Result<Box<dyn ColumnStream + 'a>>,
{
    use crate::plan::CheckOracle;
    use crate::rng::rng;

    let mut next_stream = Some(open_stream()?);
    let (m, n) = {
        let s = next_stream.as_ref().expect("stream");
        (s.rows(), s.cols())
    };
    let sched_c = plan.schedule(2 * cfg.c.max(1), m);
    let sched_r = plan.schedule(cfg.r.max(1), n);
    let attempts = sched_c.len().max(sched_r.len());

    let (chk1, chk2) =
        CheckOracle::sketch_pair(m, n, plan.check_size(cfg.c.max(cfg.r)), plan.seed ^ 0x5cc5_c4ec);
    let mut oracle: Option<CheckOracle> = None;

    let mut result = None;
    for attempt in 0..attempts {
        let t_c = sched_c[attempt.min(sched_c.len() - 1)];
        let t_r = sched_r[attempt.min(sched_r.len() - 1)];
        let mut sp = crate::obs::span("plan.attempt", crate::obs::cat::DISPATCH);
        sp.meta("attempt", attempt + 1);
        sp.meta("s_c", t_c);
        sp.meta("s_r", t_r);

        // Each attempt's sketches replay the same seeded stream, so the
        // previous attempt's sketch is a bitwise prefix of this one.
        let sk = StreamingCurSketches {
            s_c: if t_c >= m {
                Sketch::identity(m)
            } else {
                Sketch::draw_extension(
                    oblivious(cfg.kind),
                    sched_c[0],
                    t_c,
                    m,
                    None,
                    &mut rng(plan.seed ^ 0x5cc5_00c0),
                )
            },
            s_r: if t_r >= n {
                Sketch::identity(n)
            } else {
                Sketch::draw_extension(
                    sliceable(cfg.kind),
                    sched_r[0],
                    t_r,
                    n,
                    None,
                    &mut rng(plan.seed ^ 0x5cc5_00f0),
                )
            },
        };
        let mut sel_rng = rng(plan.seed ^ 0x5cc5_5e1e);
        let mut stream = match next_stream.take() {
            Some(s) => s,
            None => open_stream()?,
        };
        assert_eq!(
            (stream.rows(), stream.cols()),
            (m, n),
            "streaming_cur_planned: reopened stream changed shape"
        );
        let mut state = StreamState::new(cfg, &sk, m, n);
        let pool = Pool::current();
        // The check's S₁A product is accumulated alongside the first
        // pass (the data never resides in memory to sketch later).
        let mut y1 = if oracle.is_none() {
            Some(Mat::zeros(chk1.out_dim(), n))
        } else {
            None
        };
        while let Some(block) = stream.next_block()? {
            let bs = sketch_block(block.col_start, block.data, &sk, &pool);
            if let Some(y1m) = y1.as_mut() {
                y1m.set_block(0, bs.col_start, &chk1.apply_left_with(&bs.data, &pool));
            }
            state.fold(bs, &mut sel_rng);
        }
        let res = finalize(cfg, &sk, state, &mut sel_rng);
        if let Some(y1m) = y1.take() {
            let sa = chk2.apply_right(&y1m);
            oracle = Some(CheckOracle::from_sketched(chk1.clone(), chk2.clone(), sa));
        }
        let fc = oracle.as_ref().expect("oracle built on first attempt").for_factors(
            &res.cur.c,
            &res.cur.r,
        );
        let achieved = fc.residual_of(&res.cur.u);
        let attained = fc.attained(plan.epsilon, achieved);
        sp.meta("achieved", achieved);
        sp.meta("attained", if attained { "yes" } else { "no" });
        drop(sp);

        if attained || attempt + 1 == attempts {
            let outcome = crate::plan::PlanOutcome {
                epsilon: plan.epsilon,
                attempts: attempt + 1,
                s_c: sk.s_c.out_dim(),
                s_r: sk.s_r.out_dim(),
                achieved,
                optimum: fc.optimum(),
                attained,
            };
            result = Some((res, outcome));
            break;
        }
    }
    Ok(result.expect("planner runs at least one attempt"))
}
