//! CSR sparse matrix.

use crate::linalg::Mat;

/// Coordinate-format entry used to assemble CSR matrices.
#[derive(Clone, Copy, Debug)]
pub struct Triplet {
    pub row: usize,
    pub col: usize,
    pub val: f64,
}

/// Compressed sparse row matrix over `f64`.
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    /// Row pointer array, length rows+1.
    indptr: Vec<usize>,
    /// Column indices, length nnz, sorted within each row.
    indices: Vec<usize>,
    /// Values, parallel to `indices`.
    values: Vec<f64>,
}

impl Csr {
    /// Assemble from (row, col, val) triplets; duplicates are summed.
    pub fn from_triplets(rows: usize, cols: usize, mut trips: Vec<Triplet>) -> Self {
        trips.sort_by(|a, b| (a.row, a.col).cmp(&(b.row, b.col)));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices: Vec<usize> = Vec::with_capacity(trips.len());
        let mut values: Vec<f64> = Vec::with_capacity(trips.len());
        let mut last: Option<(usize, usize)> = None;
        for t in trips {
            assert!(t.row < rows && t.col < cols, "triplet out of bounds");
            if last == Some((t.row, t.col)) {
                // Duplicate coordinate: accumulate.
                *values.last_mut().unwrap() += t.val;
                continue;
            }
            indices.push(t.col);
            values.push(t.val);
            indptr[t.row + 1] += 1;
            last = Some((t.row, t.col));
        }
        for r in 0..rows {
            indptr[r + 1] += indptr[r];
        }
        Self { rows, cols, indptr, indices, values }
    }

    /// Build from raw CSR arrays (trusted input, validated cheaply).
    pub fn from_raw(rows: usize, cols: usize, indptr: Vec<usize>, indices: Vec<usize>, values: Vec<f64>) -> Self {
        assert_eq!(indptr.len(), rows + 1);
        assert_eq!(indices.len(), values.len());
        assert_eq!(*indptr.last().unwrap(), indices.len());
        debug_assert!(indices.iter().all(|&c| c < cols));
        Self { rows, cols, indptr, indices, values }
    }

    /// Densify-then-sparsify constructor (entries with |v| <= tol dropped).
    pub fn from_dense(a: &Mat, tol: f64) -> Self {
        let mut trips = Vec::new();
        for i in 0..a.rows() {
            for (j, &v) in a.row(i).iter().enumerate() {
                if v.abs() > tol {
                    trips.push(Triplet { row: i, col: j, val: v });
                }
            }
        }
        Self::from_triplets(a.rows(), a.cols(), trips)
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Sparsity as nnz / (rows*cols).
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Borrow row `i` as (column indices, values).
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    /// Densify.
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let dst = out.row_mut(i);
            for (&j, &v) in cols.iter().zip(vals) {
                dst[j] = v;
            }
        }
        out
    }

    /// `self * B` with dense B — O(nnz(self) * B.cols).
    pub fn spmm(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows(), "spmm: dim mismatch");
        let n = b.cols();
        let mut out = Mat::zeros(self.rows, n);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let orow = out.row_mut(i);
            for (&k, &v) in cols.iter().zip(vals) {
                let brow = b.row(k);
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += v * bv;
                }
            }
        }
        out
    }

    /// `selfᵀ * B` with dense B (B has self.rows rows) — O(nnz * B.cols).
    pub fn spmm_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows(), "spmm_t: dim mismatch");
        let n = b.cols();
        let mut out = Mat::zeros(self.cols, n);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let brow = b.row(i);
            for (&k, &v) in cols.iter().zip(vals) {
                let orow = out.row_mut(k);
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += v * bv;
                }
            }
        }
        out
    }

    /// `S * self` with dense S (S.cols == self.rows) — iterates the sparse
    /// rows once: O(nnz * S.rows).
    pub fn left_mul_dense(&self, s: &Mat) -> Mat {
        assert_eq!(s.cols(), self.rows, "left_mul_dense: dim mismatch");
        let m = s.rows();
        let mut out = Mat::zeros(m, self.cols);
        for k in 0..self.rows {
            let (cols, vals) = self.row(k);
            if cols.is_empty() {
                continue;
            }
            for i in 0..m {
                let sik = s[(i, k)];
                if sik == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                for (&j, &v) in cols.iter().zip(vals) {
                    orow[j] += sik * v;
                }
            }
        }
        out
    }

    /// Transpose (O(nnz)).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.cols + 1];
        for &j in &self.indices {
            counts[j + 1] += 1;
        }
        for j in 0..self.cols {
            counts[j + 1] += counts[j];
        }
        let indptr = counts.clone();
        let mut indices = vec![0usize; self.nnz()];
        let mut values = vec![0.0f64; self.nnz()];
        let mut next = counts;
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                let pos = next[j];
                indices[pos] = i;
                values[pos] = v;
                next[j] += 1;
            }
        }
        Csr { rows: self.cols, cols: self.rows, indptr, indices, values }
    }

    /// Gather a column subset into a dense matrix (used to extract the
    /// sampled columns C of a sparse A).
    pub fn select_cols_dense(&self, idx: &[usize]) -> Mat {
        let mut pos_of: std::collections::HashMap<usize, Vec<usize>> = std::collections::HashMap::new();
        for (o, &j) in idx.iter().enumerate() {
            pos_of.entry(j).or_default().push(o);
        }
        let mut out = Mat::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let orow = out.row_mut(i);
            for (&j, &v) in cols.iter().zip(vals) {
                if let Some(outs) = pos_of.get(&j) {
                    for &o in outs {
                        orow[o] = v;
                    }
                }
            }
        }
        out
    }

    /// Gather a row subset into a dense matrix, scaling row `idx[t]` by
    /// `scale[t]` (sampling-sketch application).
    pub fn select_rows_scaled_dense(&self, idx: &[usize], scale: &[f64]) -> Mat {
        assert_eq!(idx.len(), scale.len());
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (t, (&i, &s)) in idx.iter().zip(scale).enumerate() {
            let (cols, vals) = self.row(i);
            let orow = out.row_mut(t);
            for (&j, &v) in cols.iter().zip(vals) {
                orow[j] = s * v;
            }
        }
        out
    }

    /// Squared Frobenius norm.
    pub fn fro_norm_sq(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.fro_norm_sq().sqrt()
    }

    /// Column slice (contiguous range) as a new CSR — used by the
    /// streaming reader to hand out column blocks.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Csr {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut trips = Vec::new();
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                if j >= c0 && j < c1 {
                    trips.push(Triplet { row: i, col: j - c0, val: v });
                }
            }
        }
        Csr::from_triplets(self.rows, c1 - c0, trips)
    }
}
