//! CSR sparse matrix.
//!
//! The dense products [`Csr::spmm`] / [`Csr::spmm_t`] shard over
//! contiguous output-row panels on the process-wide `crate::parallel`
//! pool when `nnz · B.cols()` clears the flop floor — bitwise identical
//! to serial at any thread count (same contract as the dense drivers).

use crate::linalg::Mat;

/// Coordinate-format entry used to assemble CSR matrices.
#[derive(Clone, Copy, Debug)]
pub struct Triplet {
    pub row: usize,
    pub col: usize,
    pub val: f64,
}

/// Compressed sparse row matrix over `f64`.
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    /// Row pointer array, length rows+1.
    indptr: Vec<usize>,
    /// Column indices, length nnz, sorted within each row.
    indices: Vec<usize>,
    /// Values, parallel to `indices`.
    values: Vec<f64>,
}

impl Csr {
    /// Assemble from (row, col, val) triplets; duplicates are summed.
    pub fn from_triplets(rows: usize, cols: usize, mut trips: Vec<Triplet>) -> Self {
        trips.sort_by(|a, b| (a.row, a.col).cmp(&(b.row, b.col)));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices: Vec<usize> = Vec::with_capacity(trips.len());
        let mut values: Vec<f64> = Vec::with_capacity(trips.len());
        let mut last: Option<(usize, usize)> = None;
        for t in trips {
            assert!(t.row < rows && t.col < cols, "triplet out of bounds");
            if last == Some((t.row, t.col)) {
                // Duplicate coordinate: accumulate.
                *values.last_mut().unwrap() += t.val;
                continue;
            }
            indices.push(t.col);
            values.push(t.val);
            indptr[t.row + 1] += 1;
            last = Some((t.row, t.col));
        }
        for r in 0..rows {
            indptr[r + 1] += indptr[r];
        }
        Self { rows, cols, indptr, indices, values }
    }

    /// Build from raw CSR arrays (trusted input, validated cheaply).
    pub fn from_raw(rows: usize, cols: usize, indptr: Vec<usize>, indices: Vec<usize>, values: Vec<f64>) -> Self {
        assert_eq!(indptr.len(), rows + 1);
        assert_eq!(indices.len(), values.len());
        assert_eq!(*indptr.last().unwrap(), indices.len());
        debug_assert!(indices.iter().all(|&c| c < cols));
        Self { rows, cols, indptr, indices, values }
    }

    /// Densify-then-sparsify constructor (entries with |v| <= tol dropped).
    pub fn from_dense(a: &Mat, tol: f64) -> Self {
        let mut trips = Vec::new();
        for i in 0..a.rows() {
            for (j, &v) in a.row(i).iter().enumerate() {
                if v.abs() > tol {
                    trips.push(Triplet { row: i, col: j, val: v });
                }
            }
        }
        Self::from_triplets(a.rows(), a.cols(), trips)
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Sparsity as nnz / (rows*cols).
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Borrow row `i` as (column indices, values).
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    /// Densify.
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let dst = out.row_mut(i);
            for (&j, &v) in cols.iter().zip(vals) {
                dst[j] = v;
            }
        }
        out
    }

    /// True when an `O(nnz · n)` sparse product is big enough to shard
    /// over the pool (same flop floor as the dense drivers).
    fn spmm_should_shard(&self, n: usize, out_rows: usize) -> bool {
        crate::parallel::threads() > 1
            && out_rows >= 2
            && self.nnz().saturating_mul(n) >= crate::parallel::PAR_FLOP_MIN
    }

    /// `self * B` with dense B — O(nnz(self) * B.cols).
    ///
    /// Above the sharding floor the output rows split into contiguous
    /// panels on the process-wide pool; each output row is a gather over
    /// its own sparse row in the serial order, so the sharded product is
    /// **bitwise identical** to the serial one at any thread count
    /// (pinned by the threads-knob suite in `crate::parallel::tests`).
    pub fn spmm(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows(), "spmm: dim mismatch");
        let n = b.cols();
        let mut out = Mat::zeros(self.rows, n);
        if self.spmm_should_shard(n, self.rows) {
            let pool = crate::parallel::Pool::current();
            pool.run_row_panels(self.rows, n, out.data_mut(), |r0, r1, panel| {
                self.spmm_panel(b, r0, r1, panel);
            });
        } else {
            self.spmm_panel(b, 0, self.rows, out.data_mut());
        }
        out
    }

    /// Serial `self · B` kernel over the sparse-row panel `r0..r1`,
    /// writing the panel-local `(r1-r0)×b.cols()` slice.
    fn spmm_panel(&self, b: &Mat, r0: usize, r1: usize, panel: &mut [f64]) {
        let n = b.cols();
        for i in r0..r1 {
            let (cols, vals) = self.row(i);
            let orow = &mut panel[(i - r0) * n..(i - r0 + 1) * n];
            for (&k, &v) in cols.iter().zip(vals) {
                let brow = b.row(k);
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += v * bv;
                }
            }
        }
    }

    /// `selfᵀ * B` with dense B (B has self.rows rows) — O(nnz * B.cols).
    ///
    /// The scatter shards over *output*-row panels (columns of `self`):
    /// every worker streams the sparse rows in the same ascending order
    /// and keeps only the entries that land in its panel, so each output
    /// row accumulates in exactly the serial order — bitwise identical
    /// at any thread count. Workers re-scan the index array (`O(nnz)`
    /// each), which the `nnz·n` flop floor keeps amortized.
    pub fn spmm_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows(), "spmm_t: dim mismatch");
        let n = b.cols();
        let mut out = Mat::zeros(self.cols, n);
        // The n >= 16 floor keeps each worker's O(nnz) index re-scan
        // small next to its O(nnz·n / workers) useful flops.
        if n >= 16 && self.spmm_should_shard(n, self.cols) {
            let pool = crate::parallel::Pool::current();
            pool.run_row_panels(self.cols, n, out.data_mut(), |k0, k1, panel| {
                self.spmm_t_panel(b, k0, k1, panel);
            });
        } else {
            self.spmm_t_panel(b, 0, self.cols, out.data_mut());
        }
        out
    }

    /// Serial `selfᵀ · B` scatter restricted to output rows `k0..k1`.
    fn spmm_t_panel(&self, b: &Mat, k0: usize, k1: usize, panel: &mut [f64]) {
        let n = b.cols();
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let brow = b.row(i);
            for (&k, &v) in cols.iter().zip(vals) {
                if k < k0 || k >= k1 {
                    continue;
                }
                let orow = &mut panel[(k - k0) * n..(k - k0 + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += v * bv;
                }
            }
        }
    }

    /// `S * self` with dense S (S.cols == self.rows) — iterates the sparse
    /// rows once: O(nnz * S.rows).
    pub fn left_mul_dense(&self, s: &Mat) -> Mat {
        assert_eq!(s.cols(), self.rows, "left_mul_dense: dim mismatch");
        let m = s.rows();
        let mut out = Mat::zeros(m, self.cols);
        for k in 0..self.rows {
            let (cols, vals) = self.row(k);
            if cols.is_empty() {
                continue;
            }
            for i in 0..m {
                let sik = s[(i, k)];
                if sik == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                for (&j, &v) in cols.iter().zip(vals) {
                    orow[j] += sik * v;
                }
            }
        }
        out
    }

    /// Transpose (O(nnz)).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.cols + 1];
        for &j in &self.indices {
            counts[j + 1] += 1;
        }
        for j in 0..self.cols {
            counts[j + 1] += counts[j];
        }
        let indptr = counts.clone();
        let mut indices = vec![0usize; self.nnz()];
        let mut values = vec![0.0f64; self.nnz()];
        let mut next = counts;
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                let pos = next[j];
                indices[pos] = i;
                values[pos] = v;
                next[j] += 1;
            }
        }
        Csr { rows: self.cols, cols: self.rows, indptr, indices, values }
    }

    /// Gather a column subset into a dense matrix (used to extract the
    /// sampled columns C of a sparse A).
    pub fn select_cols_dense(&self, idx: &[usize]) -> Mat {
        let mut pos_of: std::collections::HashMap<usize, Vec<usize>> = std::collections::HashMap::new();
        for (o, &j) in idx.iter().enumerate() {
            pos_of.entry(j).or_default().push(o);
        }
        let mut out = Mat::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let orow = out.row_mut(i);
            for (&j, &v) in cols.iter().zip(vals) {
                if let Some(outs) = pos_of.get(&j) {
                    for &o in outs {
                        orow[o] = v;
                    }
                }
            }
        }
        out
    }

    /// Gather a row subset into a dense matrix, scaling row `idx[t]` by
    /// `scale[t]` (sampling-sketch application).
    pub fn select_rows_scaled_dense(&self, idx: &[usize], scale: &[f64]) -> Mat {
        assert_eq!(idx.len(), scale.len());
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (t, (&i, &s)) in idx.iter().zip(scale).enumerate() {
            let (cols, vals) = self.row(i);
            let orow = out.row_mut(t);
            for (&j, &v) in cols.iter().zip(vals) {
                orow[j] = s * v;
            }
        }
        out
    }

    /// Squared Frobenius norm.
    pub fn fro_norm_sq(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.fro_norm_sq().sqrt()
    }

    /// Column slice (contiguous range) as a new CSR — used by the
    /// streaming reader to hand out column blocks.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Csr {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut trips = Vec::new();
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                if j >= c0 && j < c1 {
                    trips.push(Triplet { row: i, col: j - c0, val: v });
                }
            }
        }
        Csr::from_triplets(self.rows, c1 - c0, trips)
    }
}
