//! Compressed sparse row matrices and the operations the input-sparsity
//! code paths need (`O(nnz)` sketch application, spmm, norms).

mod csr;

pub use csr::{Csr, Triplet};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, Mat};
    use crate::rng::rng;
    use crate::testing::assert_close;

    fn random_sparse(m: usize, n: usize, density: f64, seed: u64) -> Csr {
        let mut r = rng(seed);
        let mut trips = Vec::new();
        for i in 0..m {
            for j in 0..n {
                if r.next_f64() < density {
                    trips.push(Triplet { row: i, col: j, val: r.next_normal() });
                }
            }
        }
        Csr::from_triplets(m, n, trips)
    }

    #[test]
    fn dense_roundtrip() {
        let a = random_sparse(13, 9, 0.3, 1);
        let d = a.to_dense();
        let a2 = Csr::from_dense(&d, 0.0);
        assert_close(&a2.to_dense(), &d, 1e-15, "csr roundtrip");
        assert_eq!(a.nnz(), a2.nnz());
    }

    #[test]
    fn spmm_matches_dense() {
        let a = random_sparse(20, 15, 0.2, 2);
        let mut r = rng(3);
        let b = Mat::randn(15, 7, &mut r);
        let got = a.spmm(&b);
        let want = matmul(&a.to_dense(), &b);
        assert_close(&got, &want, 1e-12, "spmm");
    }

    #[test]
    fn spmm_t_matches_dense() {
        let a = random_sparse(20, 15, 0.2, 4);
        let mut r = rng(5);
        let b = Mat::randn(20, 6, &mut r);
        let got = a.spmm_t(&b);
        let want = matmul(&a.to_dense().transpose(), &b);
        assert_close(&got, &want, 1e-12, "spmm_t");
    }

    #[test]
    fn left_dense_product() {
        let a = random_sparse(12, 18, 0.25, 6);
        let mut r = rng(7);
        let s = Mat::randn(5, 12, &mut r);
        let got = a.left_mul_dense(&s);
        let want = matmul(&s, &a.to_dense());
        assert_close(&got, &want, 1e-12, "left_mul_dense");
    }

    #[test]
    fn norms_and_cols() {
        let a = random_sparse(10, 10, 0.3, 8);
        let d = a.to_dense();
        assert!((a.fro_norm() - d.fro_norm()).abs() < 1e-12);
        let cols = a.select_cols_dense(&[0, 3, 7]);
        let want = d.select_cols(&[0, 3, 7]);
        assert_close(&cols, &want, 1e-15, "select_cols_dense");
    }

    #[test]
    fn transpose_involution() {
        let a = random_sparse(9, 14, 0.2, 9);
        let att = a.transpose().transpose();
        assert_close(&att.to_dense(), &a.to_dense(), 1e-15, "transpose twice");
    }

    #[test]
    fn row_slice_view() {
        let a = random_sparse(8, 8, 0.4, 10);
        let d = a.to_dense();
        for i in 0..8 {
            let (cols, vals) = a.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                assert_eq!(d[(i, j)], v);
            }
            let nnz_row = (0..8).filter(|&j| d[(i, j)] != 0.0).count();
            assert_eq!(cols.len(), nnz_row);
        }
    }

    #[test]
    fn empty_matrix_ok() {
        let a = Csr::from_triplets(5, 5, vec![]);
        assert_eq!(a.nnz(), 0);
        let b = Mat::eye(5);
        let c = a.spmm(&b);
        assert_eq!(c.fro_norm(), 0.0);
    }
}
