//! Generalized matrix regression: the paper's core problem
//! `min_X ‖A − C X R‖_F` (Eqn. 1.1), its exact solution, and the Fast GMR
//! sketched solver (Algorithm 1) with the symmetric/SPSD extensions of
//! Section 3.2.

mod error_est;
mod exact;
mod fast;
mod rho;
mod sym;

pub(crate) use error_est::residual_sketch_pair;
pub use error_est::{estimate_residual, sketched_fro_norm};
pub use exact::{solve_exact, solve_exact_robust, ExactGmrSolution};
pub use fast::{approximate, solve_core, solve_fast, solve_fast_with, FastGmrConfig, FastGmrSolution};
pub use rho::{compute_rho, compute_rho_symmetric, rho_upper_bound_inverse, RhoParts};
pub use sym::{solve_fast_psd, solve_fast_symmetric, SymGmrConfig};

use crate::linalg::{fro_norm_diff, matmul, Mat};
use crate::sparse::Csr;

/// Dense-or-sparse input matrix `A`.
#[derive(Clone, Copy)]
pub enum Input<'a> {
    Dense(&'a Mat),
    Sparse(&'a Csr),
}

impl<'a> From<&'a Mat> for Input<'a> {
    fn from(a: &'a Mat) -> Self {
        Input::Dense(a)
    }
}

impl<'a> From<&'a Csr> for Input<'a> {
    fn from(a: &'a Csr) -> Self {
        Input::Sparse(a)
    }
}

impl<'a> Input<'a> {
    pub fn rows(&self) -> usize {
        match self {
            Input::Dense(a) => a.rows(),
            Input::Sparse(a) => a.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Input::Dense(a) => a.cols(),
            Input::Sparse(a) => a.cols(),
        }
    }

    pub fn fro_norm(&self) -> f64 {
        match self {
            Input::Dense(a) => a.fro_norm(),
            Input::Sparse(a) => a.fro_norm(),
        }
    }

    /// `S · A`.
    pub fn sketch_left(&self, s: &crate::sketch::Sketch) -> Mat {
        match self {
            Input::Dense(a) => s.apply_left(a),
            Input::Sparse(a) => s.apply_left_csr(a),
        }
    }

    /// `A · Sᵀ`.
    pub fn sketch_right(&self, s: &crate::sketch::Sketch) -> Mat {
        match self {
            Input::Dense(a) => s.apply_right(a),
            Input::Sparse(a) => s.apply_right_csr(a),
        }
    }

    /// `Aᵀ B` (tall-thin B).
    pub fn at_b(&self, b: &Mat) -> Mat {
        match self {
            Input::Dense(a) => crate::linalg::matmul_at_b(a, b),
            Input::Sparse(a) => a.spmm_t(b),
        }
    }

    /// `A B`.
    pub fn a_b(&self, b: &Mat) -> Mat {
        match self {
            Input::Dense(a) => matmul(a, b),
            Input::Sparse(a) => a.spmm(b),
        }
    }
}

/// Residual `‖A − C X R‖_F`, computed blockwise (dense) or via the
/// inner-product expansion (sparse) so the m×n approximation is never
/// materialized.
pub fn residual(a: Input<'_>, c: &Mat, x: &Mat, r: &Mat) -> f64 {
    assert_eq!(c.cols(), x.rows(), "residual: C/X mismatch");
    assert_eq!(x.cols(), r.rows(), "residual: X/R mismatch");
    let cx = matmul(c, x); // m x r_dim — thin
    match a {
        Input::Dense(am) => {
            let mut acc = 0.0f64;
            const B: usize = 512;
            let m = am.rows();
            for i0 in (0..m).step_by(B) {
                let i1 = (i0 + B).min(m);
                let cx_blk = cx.slice(i0, i1, 0, cx.cols());
                let approx = matmul(&cx_blk, r);
                let a_blk = am.slice(i0, i1, 0, am.cols());
                let d = fro_norm_diff(&a_blk, &approx);
                acc += d * d;
            }
            acc.sqrt()
        }
        Input::Sparse(am) => {
            // ‖A − CXR‖² = ‖A‖² − 2·tr(Rᵀ(CX)ᵀA) + tr(Rᵀ(CX)ᵀ(CX)R).
            let at_cx = am.spmm_t(&cx); // n x rdim  (Aᵀ·CX)
            let mut cross = 0.0;
            for j in 0..at_cx.rows() {
                let row = at_cx.row(j);
                for (t, &v) in row.iter().enumerate() {
                    cross += v * r[(t, j)];
                }
            }
            let gram = crate::linalg::matmul_at_b(&cx, &cx); // rdim x rdim
            let gr = matmul(&gram, r); // rdim x n
            let mut norm_cxr_sq = 0.0;
            for t in 0..r.rows() {
                for (a_, b_) in r.row(t).iter().zip(gr.row(t)) {
                    norm_cxr_sq += a_ * b_;
                }
            }
            (am.fro_norm_sq() - 2.0 * cross + norm_cxr_sq).max(0.0).sqrt()
        }
    }
}

/// Paper §6.1 error ratio: `‖A − C X̃ R‖ / ‖A − C X* R‖ − 1`.
pub fn relative_regret(a: Input<'_>, c: &Mat, r: &Mat, x_tilde: &Mat, x_star: &Mat) -> f64 {
    let num = residual(a, c, x_tilde, r);
    let den = residual(a, c, x_star, r);
    num / den - 1.0
}

#[cfg(test)]
mod tests;
