//! Tests for the GMR solvers.

use super::*;
use crate::linalg::{eigh, matmul, matmul_a_bt, Mat};
use crate::rng::rng;
use crate::sketch::SketchKind;
use crate::sparse::Csr;
use crate::testing::{assert_close, assert_scalar_close};

/// Low-rank-plus-noise test matrix with controllable residual level.
fn test_problem(m: usize, n: usize, c_dim: usize, r_dim: usize, seed: u64) -> (Mat, Mat, Mat) {
    let mut r = rng(seed);
    let base = {
        let u = Mat::randn(m, 10, &mut r);
        let v = Mat::randn(10, n, &mut r);
        let mut b = matmul(&u, &v);
        let noise = Mat::randn(m, n, &mut r);
        b.axpy(0.05, &noise);
        b
    };
    let g_c = Mat::randn(n, c_dim, &mut r);
    let c = matmul(&base, &g_c); // C = A G_C, as in §6.1
    let g_r = Mat::randn(r_dim, m, &mut r);
    let rr = matmul(&g_r, &base); // R = G_R A
    (base, c, rr)
}

#[test]
fn exact_solution_is_optimal() {
    let (a, c, r) = test_problem(60, 50, 8, 6, 1);
    let sol = solve_exact(Input::Dense(&a), &c, &r);
    // Matches the robust SVD-based computation.
    let want = exact::solve_exact_robust(&a, &c, &r);
    assert_close(&sol.x, &want, 1e-7, "exact vs robust");
    // First-order optimality: perturbing X in any direction cannot reduce
    // the residual.
    let base_res = residual(Input::Dense(&a), &c, &sol.x, &r);
    let mut rr = rng(2);
    for _ in 0..5 {
        let dx = Mat::randn(sol.x.rows(), sol.x.cols(), &mut rr);
        let mut xp = sol.x.clone();
        xp.axpy(1e-4, &dx);
        let res = residual(Input::Dense(&a), &c, &xp, &r);
        assert!(res >= base_res - 1e-9, "perturbation reduced residual");
    }
}

#[test]
fn exact_csr_matches_dense() {
    let (a, c, r) = test_problem(40, 35, 5, 4, 3);
    let a_sp = Csr::from_dense(&a, 0.0);
    let dense = solve_exact(Input::Dense(&a), &c, &r).x;
    let sparse = solve_exact(Input::Sparse(&a_sp), &c, &r).x;
    assert_close(&sparse, &dense, 1e-9, "exact csr vs dense");
}

#[test]
fn residual_sparse_matches_dense() {
    let (a, c, r) = test_problem(30, 25, 5, 4, 4);
    let x = solve_exact(Input::Dense(&a), &c, &r).x;
    let a_sp = Csr::from_dense(&a, 0.0);
    let rd = residual(Input::Dense(&a), &c, &x, &r);
    let rs = residual(Input::Sparse(&a_sp), &c, &x, &r);
    assert_scalar_close(rs, rd, 1e-9, "residual sparse vs dense");
}

#[test]
fn fast_gmr_converges_with_sketch_size() {
    let (a, c, r) = test_problem(300, 250, 10, 10, 5);
    let exact = solve_exact(Input::Dense(&a), &c, &r);
    let mut rr = rng(6);
    let mut prev = f64::INFINITY;
    for &mult in &[2usize, 8, 24] {
        // Average regret over draws (regret is a random variable).
        let mut acc = 0.0;
        let trials = 5;
        for _ in 0..trials {
            let cfg = FastGmrConfig::gaussian(mult * 10, mult * 10);
            let sol = solve_fast(Input::Dense(&a), &c, &r, &cfg, &mut rr);
            acc += relative_regret(Input::Dense(&a), &c, &r, &sol.x, &exact.x);
        }
        let regret = acc / trials as f64;
        assert!(regret >= -1e-9, "regret cannot be negative, got {regret}");
        assert!(regret < prev.max(1e-3) * 1.5, "regret not improving: {regret} after {prev}");
        prev = regret;
    }
    // At 24x the base dims the sketched solve is essentially exact.
    assert!(prev < 0.05, "regret at largest sketch {prev}");
}

#[test]
fn fast_gmr_all_families_give_small_regret() {
    let (a, c, r) = test_problem(400, 300, 8, 8, 7);
    let exact = solve_exact(Input::Dense(&a), &c, &r);
    for kind in SketchKind::all() {
        let mut rr = rng(8);
        let cfg = FastGmrConfig::uniform_kind(kind, 160, 160);
        let mut acc = 0.0;
        let trials = 3;
        for _ in 0..trials {
            let sol = solve_fast(Input::Dense(&a), &c, &r, &cfg, &mut rr);
            acc += relative_regret(Input::Dense(&a), &c, &r, &sol.x, &exact.x);
        }
        let regret = acc / trials as f64;
        assert!(regret < 0.6, "{}: regret {regret}", kind.name());
    }
}

#[test]
fn fast_gmr_sparse_input() {
    let mut r = rng(9);
    let mut trips = Vec::new();
    let (m, n) = (200, 150);
    for i in 0..m {
        for j in 0..n {
            if r.next_f64() < 0.05 {
                trips.push(crate::sparse::Triplet { row: i, col: j, val: r.next_normal() });
            }
        }
    }
    let a_sp = Csr::from_triplets(m, n, trips);
    let a_d = a_sp.to_dense();
    let g_c = Mat::randn(n, 6, &mut r);
    let c = a_sp.spmm(&g_c);
    let g_r = Mat::randn(5, m, &mut r);
    let rr_mat = g_r.data().to_vec();
    let rr = {
        let g = Mat::from_vec(5, m, rr_mat);
        matmul(&g, &a_d)
    };
    let exact = solve_exact(Input::Sparse(&a_sp), &c, &rr);
    let cfg = FastGmrConfig::count(90, 90);
    let sol = solve_fast(Input::Sparse(&a_sp), &c, &rr, &cfg, &mut r);
    let regret = relative_regret(Input::Sparse(&a_sp), &c, &rr, &sol.x, &exact.x);
    assert!(regret >= -1e-9 && regret < 0.5, "sparse fast gmr regret {regret}");
}

#[test]
fn lemma2_pythagoras() {
    // ‖A − CX̃R‖² = ‖A − CX*R‖² + ‖C(X*−X̃)R‖² for any X̃ (Lemma 2).
    let (a, c, r) = test_problem(50, 40, 6, 5, 10);
    let star = solve_exact(Input::Dense(&a), &c, &r).x;
    let mut rr = rng(11);
    let xt = Mat::randn(6, 5, &mut rr);
    let lhs = residual(Input::Dense(&a), &c, &xt, &r).powi(2);
    let opt = residual(Input::Dense(&a), &c, &star, &r).powi(2);
    let diff = &star - &xt;
    let cross = matmul(&matmul(&c, &diff), &r).fro_norm_sq();
    assert_scalar_close(lhs, opt + cross, 1e-9, "Lemma 2");
}

#[test]
fn symmetric_solver_outputs_symmetric() {
    let mut r = rng(12);
    let b = Mat::randn(80, 80, &mut r);
    let a = &b + &b.transpose(); // symmetric, indefinite
    let g = Mat::randn(80, 8, &mut r);
    let c = matmul(&a, &g);
    let cfg = SymGmrConfig { kind: SketchKind::Gaussian, s: 64 };
    let x = solve_fast_symmetric(Input::Dense(&a), &c, &cfg, &mut r);
    assert_close(&x, &x.transpose(), 1e-12, "symmetric output");
}

#[test]
fn psd_solver_outputs_psd_and_close() {
    let mut r = rng(13);
    let b = Mat::randn(100, 20, &mut r);
    let a = matmul_a_bt(&b, &b); // SPSD rank 20
    let idx: Vec<usize> = (0..10).map(|i| i * 9).collect();
    let c = a.select_cols(&idx);
    let cfg = SymGmrConfig { kind: SketchKind::Leverage, s: 80 };
    let x = solve_fast_psd(Input::Dense(&a), &c, &cfg, &mut r);
    // PSD check.
    let e = eigh(&x);
    assert!(e.values.iter().all(|&w| w >= -1e-9), "core not PSD");
    // Error close to the optimal core's error.
    let opt = solve_exact(Input::Dense(&a), &c, &c.transpose()).x;
    let err_fast = residual(Input::Dense(&a), &c, &x, &c.transpose());
    let err_opt = residual(Input::Dense(&a), &c, &opt, &c.transpose());
    assert!(
        err_fast <= err_opt * 1.8 + 1e-9,
        "psd solve error {err_fast} vs optimal {err_opt}"
    );
}

#[test]
fn rho_definition_matches_direct_computation() {
    let (a, c, r) = test_problem(40, 30, 5, 4, 14);
    let parts = compute_rho(Input::Dense(&a), &c, &r);
    // Direct: build the projectors densely.
    let cp = crate::linalg::pinv(&c);
    let rp = crate::linalg::pinv(&r);
    let pc = matmul(&c, &cp); // m x m
    let pr = matmul(&rp, &r); // n x n
    let pa = matmul(&matmul(&pc, &a), &pr);
    let residual_direct = crate::linalg::fro_norm_diff(&a, &pa);
    let left = {
        let t = &matmul(&a, &pr) - &pa;
        t.fro_norm()
    };
    let right = {
        let t = &matmul(&pc, &a) - &pa;
        t.fro_norm()
    };
    assert_scalar_close(parts.residual, residual_direct, 1e-8, "rho residual");
    assert_scalar_close(parts.left_defect, left, 1e-8, "rho left defect");
    assert_scalar_close(parts.right_defect, right, 1e-8, "rho right defect");
    assert!(parts.rho().is_finite() && parts.rho() > 0.0);
}

/// ISSUE 9 acceptance: the ε-planner must hit `(1+ε)` *true* relative
/// error (vs the exactly-computed optimum) in ≥90% of fixed-seed trials.
/// At this scale the a-posteriori check saturates to the identity, so a
/// certificate is a proof — every certified trial must also pass the
/// independent recomputation here.
#[test]
fn planner_acceptance_gmr() {
    let eps = 0.25;
    crate::testing::assert_attains_epsilon("gmr planned", eps, 10, 9, |seed| {
        let (a, c, r) = test_problem(70, 55, 6, 5, seed);
        let plan = crate::plan::EpsilonPlan::new(eps).with_seed(seed);
        let (sol, out) = crate::plan::solve_gmr_planned(
            Input::Dense(&a),
            &c,
            &r,
            SketchKind::Gaussian,
            SketchKind::Gaussian,
            &plan,
        );
        let achieved = residual(Input::Dense(&a), &c, &sol.x, &r);
        let optimum = residual(Input::Dense(&a), &c, &solve_exact(Input::Dense(&a), &c, &r).x, &r);
        (achieved, optimum, out.attained)
    });
}

/// The a-posteriori estimator concentrates: at the `s = 32/ε²` rate the
/// plan uses for its check sketch, the estimate lands in the `(1±ε)`
/// band in ≥90% of fixed-seed trials — on the dense path *and* the CSR
/// path (which shares the sketch pair, not the arithmetic).
#[test]
fn error_estimator_concentrates_at_quadratic_size() {
    let (a, c, rr) = test_problem(150, 120, 6, 5, 17);
    let x = solve_exact(Input::Dense(&a), &c, &rr).x;
    let truth = residual(Input::Dense(&a), &c, &x, &rr);
    let a_sp = Csr::from_dense(&a, 0.0);
    let eps = 0.5;
    let s = (32.0 / (eps * eps)).ceil() as usize; // 128 — the plan's check rate
    for (name, input) in [("dense", Input::Dense(&a)), ("csr", Input::Sparse(&a_sp))] {
        let trials = 10;
        let mut hits = 0;
        for t in 0..trials {
            let est = estimate_residual(input, &c, &x, &rr, s, &mut rng(0xc0c0 + t));
            if (est / truth - 1.0).abs() <= eps {
                hits += 1;
            }
        }
        assert!(hits >= 9, "{name}: only {hits}/{trials} estimates within (1±{eps})");
    }
}

/// At `s ≥ max(m, n)` the estimator's sketch pair degenerates to the
/// identity and the estimate *is* the exact residual / norm. Pins the
/// historical bug where `s` was passed to the count-sketch draw
/// unclamped (a 10⁴ sketch of a 40-row matrix allocated 10⁴ rows and
/// destroyed the estimate's scale).
#[test]
fn error_estimator_saturates_to_exact() {
    let (a, c, rr) = test_problem(40, 30, 5, 4, 18);
    let x = solve_exact(Input::Dense(&a), &c, &rr).x;
    let truth = residual(Input::Dense(&a), &c, &x, &rr);
    let a_sp = Csr::from_dense(&a, 0.0);
    for s in [40, 64, 10_000] {
        let est = estimate_residual(Input::Dense(&a), &c, &x, &rr, s, &mut rng(19));
        assert_scalar_close(est, truth, 1e-10, "saturated dense estimate");
        let est_sp = estimate_residual(Input::Sparse(&a_sp), &c, &x, &rr, s, &mut rng(19));
        assert_scalar_close(est_sp, truth, 1e-10, "saturated csr estimate");
    }
    let nrm = sketched_fro_norm(Input::Dense(&a), 10_000, &mut rng(20));
    assert_scalar_close(nrm, a.fro_norm(), 1e-10, "saturated dense norm");
    let nrm_sp = sketched_fro_norm(Input::Sparse(&a_sp), 10_000, &mut rng(20));
    assert_scalar_close(nrm_sp, a.fro_norm(), 1e-10, "saturated csr norm");
}

#[test]
fn sketched_norm_estimates() {
    let mut r = rng(15);
    let a = Mat::randn(300, 200, &mut r);
    let est = sketched_fro_norm(Input::Dense(&a), 600, &mut r);
    let exact = a.fro_norm();
    assert!((est / exact - 1.0).abs() < 0.15, "norm estimate ratio {}", est / exact);

    let (a2, c, rr) = test_problem(150, 120, 6, 5, 16);
    let x = solve_exact(Input::Dense(&a2), &c, &rr).x;
    let est_res = estimate_residual(Input::Dense(&a2), &c, &x, &rr, 500, &mut r);
    let true_res = residual(Input::Dense(&a2), &c, &x, &rr);
    assert!(
        (est_res / true_res - 1.0).abs() < 0.2,
        "residual estimate ratio {}",
        est_res / true_res
    );
}
