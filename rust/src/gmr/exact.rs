//! Exact GMR: `X* = C† A R†` (the baseline Algorithm 1 accelerates).
//!
//! Cost `O(nnz(A)·min(c,r) + mc² + nr²)` exactly as stated in the paper's
//! introduction: we form `Cᵀ A` in one pass over A and solve the two
//! small Gram systems; the pseudoinverses are never materialized.

use super::Input;
use crate::linalg::{matmul, pinv, pinv_apply_right, Mat};

/// Result of the exact GMR solve.
pub struct ExactGmrSolution {
    /// `X* = C† A R†`, c×r.
    pub x: Mat,
}

/// Solve `min_X ‖A − C X R‖_F` exactly.
pub fn solve_exact(a: Input<'_>, c: &Mat, r: &Mat) -> ExactGmrSolution {
    assert_eq!(a.rows(), c.rows(), "solve_exact: A/C row mismatch");
    assert_eq!(a.cols(), r.cols(), "solve_exact: A/R col mismatch");
    // C†A = (CᵀC)⁻¹ CᵀA; CᵀA = (AᵀC)ᵀ is one pass over A (O(nnz·c)).
    let ct_a = a.at_b(c).transpose(); // c×n
    let gram_c = crate::linalg::matmul_at_b(c, c);
    let ca = match crate::linalg::cholesky_solve(&gram_c, &ct_a) {
        Ok(x) => x,
        // Rank-deficient C: fall back to the SVD pseudoinverse. Only hit
        // on degenerate inputs; cost is fine at c ≪ m.
        Err(_) => {
            let cp = pinv(c); // c×m
            match a {
                Input::Dense(am) => matmul(&cp, am),
                Input::Sparse(am) => am.left_mul_dense(&cp),
            }
        }
    };
    // X* = (C†A) R†.
    let x = pinv_apply_right(&ca, r);
    ExactGmrSolution { x }
}

/// Fully SVD-based exact solve — slow but maximally robust; the gold
/// reference for unit tests.
pub fn solve_exact_robust(a: &Mat, c: &Mat, r: &Mat) -> Mat {
    let cp = pinv(c);
    let rp = pinv(r);
    matmul(&matmul(&cp, a), &rp)
}
