//! Fast GMR — Algorithm 1 of the paper.
//!
//! Draw sketches `S_C ∈ R^{s_c×m}`, `S_R ∈ R^{s_r×n}`, form the three
//! small products `S_C C`, `R S_Rᵀ`, `Ã = S_C A S_Rᵀ`, and solve the
//! sketched problem in closed form:
//!
//! ```text
//! X̃ = (S_C C)† Ã (R S_Rᵀ)†          (Eqn. 3.3)
//! ```
//!
//! Theorem 1: with sketch sizes from Table 2 this is a `(1+ε)`-relative-
//! error solution with probability ≥ 0.95, and the solve itself costs
//! `O(s_r r² + s_c c² + s_c s_r min(c,r)) + T_sketch` — independent of
//! `A`'s dimensions beyond the sketch applications.

use super::Input;
use crate::linalg::{matmul, pinv_apply_left, pinv_apply_right, Mat};
use crate::rng::Pcg64;
use crate::sketch::{row_leverage_scores, Sketch, SketchKind};

/// Configuration for Algorithm 1.
#[derive(Clone, Debug)]
pub struct FastGmrConfig {
    /// Family for the left sketch S_C (row space of C).
    pub kind_c: SketchKind,
    /// Family for the right sketch S_R (column space of R).
    pub kind_r: SketchKind,
    /// Left sketch size s_c.
    pub s_c: usize,
    /// Right sketch size s_r.
    pub s_r: usize,
}

impl FastGmrConfig {
    /// Gaussian sketches on both sides (the paper's dense-data choice).
    pub fn gaussian(s_c: usize, s_r: usize) -> Self {
        Self { kind_c: SketchKind::Gaussian, kind_r: SketchKind::Gaussian, s_c, s_r }
    }

    /// CountSketch on both sides (the paper's sparse-data choice, §6.1).
    pub fn count(s_c: usize, s_r: usize) -> Self {
        Self { kind_c: SketchKind::Count, kind_r: SketchKind::Count, s_c, s_r }
    }

    /// Leverage-score sampling on both sides (Remark 1's recommendation:
    /// the whole A need not be observed).
    pub fn leverage(s_c: usize, s_r: usize) -> Self {
        Self { kind_c: SketchKind::Leverage, kind_r: SketchKind::Leverage, s_c, s_r }
    }

    /// Same family both sides.
    pub fn uniform_kind(kind: SketchKind, s_c: usize, s_r: usize) -> Self {
        Self { kind_c: kind, kind_r: kind, s_c, s_r }
    }
}

/// Result of Algorithm 1, including the realized sketch products for
/// callers that reuse them (the benches and the SPSD/SVD applications).
pub struct FastGmrSolution {
    /// `X̃` — the (1+ε)-approximate core matrix, c×r.
    pub x: Mat,
    /// `S_C C` (s_c × c).
    pub sc_c: Mat,
    /// `R S_Rᵀ` (r × s_r).
    pub r_sr: Mat,
    /// `Ã = S_C A S_Rᵀ` (s_c × s_r).
    pub a_tilde: Mat,
}

/// Algorithm 1 (Fast GMR).
///
/// When a sampling family is selected, leverage scores are computed from
/// the appropriate factor exactly as Table 2 prescribes: `S_C` w.r.t. the
/// (column-space) leverage scores of `C`, `S_R` w.r.t. the (row-space)
/// leverage scores of `R`.
///
/// ```
/// use fastgmr::gmr::{residual, solve_fast, FastGmrConfig, Input};
/// use fastgmr::linalg::Mat;
/// use fastgmr::rng::rng;
///
/// let mut rand = rng(7);
/// let a = Mat::randn(40, 30, &mut rand);
/// let c = a.slice(0, 40, 0, 5); // any m×c / r×n factors work
/// let r = a.slice(0, 5, 0, 30);
/// let sol = solve_fast(Input::Dense(&a), &c, &r, &FastGmrConfig::gaussian(20, 20), &mut rand);
/// assert_eq!(sol.x.shape(), (5, 5));
/// assert!(residual(Input::Dense(&a), &c, &sol.x, &r).is_finite());
/// ```
pub fn solve_fast(a: Input<'_>, c: &Mat, r: &Mat, cfg: &FastGmrConfig, rng: &mut Pcg64) -> FastGmrSolution {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(c.rows(), m, "solve_fast: A/C row mismatch");
    assert_eq!(r.cols(), n, "solve_fast: A/R col mismatch");
    assert!(cfg.s_c >= c.cols(), "s_c must be >= c (got {} < {})", cfg.s_c, c.cols());
    assert!(cfg.s_r >= r.rows(), "s_r must be >= r (got {} < {})", cfg.s_r, r.rows());

    let mut draw_span = crate::obs::span("gmr.sketch.draw", crate::obs::cat::SKETCH);
    draw_span.meta("s_c", cfg.s_c);
    draw_span.meta("s_r", cfg.s_r);
    let scores_c;
    let s_c = match cfg.kind_c {
        SketchKind::Leverage => {
            scores_c = row_leverage_scores(c);
            Sketch::draw(SketchKind::Leverage, cfg.s_c, m, Some(&scores_c), rng)
        }
        kind => Sketch::draw(kind, cfg.s_c, m, None, rng),
    };
    let scores_r;
    let s_r = match cfg.kind_r {
        SketchKind::Leverage => {
            scores_r = row_leverage_scores(&r.transpose());
            Sketch::draw(SketchKind::Leverage, cfg.s_r, n, Some(&scores_r), rng)
        }
        kind => Sketch::draw(kind, cfg.s_r, n, None, rng),
    };
    drop(draw_span);

    solve_fast_with(a, c, r, &s_c, &s_r)
}

/// Algorithm 1 with caller-supplied sketches (used when the coordinator
/// has already streamed `Ã` or when sketches must be shared across calls).
pub fn solve_fast_with(a: Input<'_>, c: &Mat, r: &Mat, s_c: &Sketch, s_r: &Sketch) -> FastGmrSolution {
    let (m, n) = (a.rows(), a.cols());
    let mut apply_span = crate::obs::span("gmr.sketch.apply", crate::obs::cat::SKETCH);
    if apply_span.active() {
        // Dense-equivalent multiply cost of the four products below —
        // the basis for the span's derived GFLOP/s.
        let flops = 2.0
            * (s_c.out_dim() * m * c.cols()
                + r.rows() * n * s_r.out_dim()
                + s_c.out_dim() * m * n
                + s_c.out_dim() * n * s_r.out_dim()) as f64;
        apply_span.meta("m", m);
        apply_span.meta("n", n);
        apply_span.meta("flops", flops);
    }
    // Step 3: the three sketched products.
    let sc_c = s_c.apply_left(c); // s_c x c
    let r_sr = s_r.apply_right(r); // r x s_r  (R S_Rᵀ)
    let sc_a = a.sketch_left(s_c); // s_c x n
    let a_tilde = s_r.apply_right(&sc_a); // s_c x s_r
    drop(apply_span);

    // Step 4: X̃ = (S_C C)† Ã (R S_Rᵀ)†.
    let x = solve_core(&sc_c, &a_tilde, &r_sr);
    FastGmrSolution { x, sc_c, r_sr, a_tilde }
}

/// The sketched closed-form solve given the three small matrices
/// (shared by the CPU backend and the PJRT-artifact path, which computes
/// the same quantity inside the AOT graph).
pub fn solve_core(sc_c: &Mat, a_tilde: &Mat, r_sr: &Mat) -> Mat {
    let _sp = crate::obs::span("gmr.core.solve", crate::obs::cat::SOLVE);
    let left = pinv_apply_left(sc_c, a_tilde); // c x s_r
    pinv_apply_right(&left, r_sr) // c x r
}

/// Convenience wrapper returning only the residual-relevant product
/// `C X̃ R`'s factors: (C·X̃, R). Kept for examples. The right factor is
/// returned by reference — the caller already owns `r` and cloning a
/// potentially r×n matrix here would be pure overhead.
pub fn approximate<'r>(
    a: Input<'_>,
    c: &Mat,
    r: &'r Mat,
    cfg: &FastGmrConfig,
    rng: &mut Pcg64,
) -> (Mat, &'r Mat) {
    let sol = solve_fast(a, c, r, cfg, rng);
    (matmul(c, &sol.x), r)
}
