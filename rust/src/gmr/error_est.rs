//! Sketched residual estimation — the §6.1 evaluation trick:
//! `‖S_1 (A − C X̃ R) S_2‖_F = (1±ε) ‖A − C X̃ R‖_F` for count-sketch
//! `S_1, S_2` with `s = O(ε⁻²)`, so large sparse residuals can be
//! estimated without densifying `A − C X̃ R`.

use super::Input;
use crate::linalg::{matmul, Mat};
use crate::rng::Pcg64;
use crate::sketch::{Sketch, SketchKind};

/// `(1±ε)`-estimate of `‖A‖_F` via two count sketches of size `s`.
pub fn sketched_fro_norm(a: Input<'_>, s: usize, rng: &mut Pcg64) -> f64 {
    let s1 = Sketch::draw(SketchKind::Count, s, a.rows(), None, rng);
    let s2 = Sketch::draw(SketchKind::Count, s, a.cols(), None, rng);
    let left = a.sketch_left(&s1);
    s2.apply_right(&left).fro_norm()
}

/// `(1±ε)`-estimate of the GMR residual `‖A − C X R‖_F` using count
/// sketches on both sides; never materializes `C X R` at full size.
pub fn estimate_residual(a: Input<'_>, c: &Mat, x: &Mat, r: &Mat, s: usize, rng: &mut Pcg64) -> f64 {
    let s1 = Sketch::draw(SketchKind::Count, s, a.rows(), None, rng);
    let s2 = Sketch::draw(SketchKind::Count, s, a.cols(), None, rng);
    // S1 A S2ᵀ   (s×s)
    let sa = s2.apply_right(&a.sketch_left(&s1));
    // S1 C X R S2ᵀ = (S1 C) X (R S2ᵀ)
    let s1c = s1.apply_left(c);
    let rs2 = s2.apply_right(r);
    let approx = matmul(&matmul(&s1c, x), &rs2);
    crate::linalg::fro_norm_diff(&sa, &approx)
}
