//! Sketched residual estimation — the §6.1 evaluation trick:
//! `‖S_1 (A − C X̃ R) S_2‖_F = (1±ε) ‖A − C X̃ R‖_F` for count-sketch
//! `S_1, S_2` with `s = O(ε⁻²)`, so large sparse residuals can be
//! estimated without densifying `A − C X̃ R`.

use super::Input;
use crate::linalg::{matmul, Mat};
use crate::rng::Pcg64;
use crate::sketch::{Sketch, SketchKind};

/// Draw the two-sided count-sketch pair used by every residual
/// estimator in this module (and mirrored bitwise by
/// [`crate::plan::CheckOracle`]).
///
/// `s` saturates per side: a count sketch with `s ≥ dim` buckets cannot
/// beat computing the exact norm on that side (extra buckets beyond
/// `dim` buy nothing, while collisions among `dim` coordinates in `dim`
/// buckets still add noise), so the side degenerates to
/// [`Sketch::identity`] — the estimate becomes exact there at the same
/// `O(dim²)` downstream cost. The identity branch consumes no `rng`
/// draws; callers relying on bitwise reproducibility must pass the same
/// `(rows, cols, s)` triple.
pub(crate) fn residual_sketch_pair(
    rows: usize,
    cols: usize,
    s: usize,
    rng: &mut Pcg64,
) -> (Sketch, Sketch) {
    let s1 = if s >= rows {
        Sketch::identity(rows)
    } else {
        Sketch::draw(SketchKind::Count, s, rows, None, rng)
    };
    let s2 = if s >= cols {
        Sketch::identity(cols)
    } else {
        Sketch::draw(SketchKind::Count, s, cols, None, rng)
    };
    (s1, s2)
}

/// `(1±ε)`-estimate of `‖A‖_F` via two count sketches of size `s`.
///
/// `s` saturates at the matching dimension of `A` on each side (the
/// side degenerates to the identity — see [`residual_sketch_pair`]), so
/// oversketching never inflates the work past the exact computation.
pub fn sketched_fro_norm(a: Input<'_>, s: usize, rng: &mut Pcg64) -> f64 {
    let (s1, s2) = residual_sketch_pair(a.rows(), a.cols(), s, rng);
    let left = a.sketch_left(&s1);
    s2.apply_right(&left).fro_norm()
}

/// `(1±ε)`-estimate of the GMR residual `‖A − C X R‖_F` using count
/// sketches on both sides; never materializes `C X R` at full size.
/// `s` saturates at `A`'s dimensions per side (see
/// [`residual_sketch_pair`]) — at `s ≥ max(m, n)` the estimate is the
/// exact residual.
pub fn estimate_residual(a: Input<'_>, c: &Mat, x: &Mat, r: &Mat, s: usize, rng: &mut Pcg64) -> f64 {
    let (s1, s2) = residual_sketch_pair(a.rows(), a.cols(), s, rng);
    // S1 A S2ᵀ   (s×s)
    let sa = s2.apply_right(&a.sketch_left(&s1));
    // S1 C X R S2ᵀ = (S1 C) X (R S2ᵀ)
    let s1c = s1.apply_left(c);
    let rs2 = s2.apply_right(r);
    let approx = matmul(&matmul(&s1c, x), &rs2);
    crate::linalg::fro_norm_diff(&sa, &approx)
}
