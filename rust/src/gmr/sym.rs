//! Symmetric / SPSD Fast GMR — Section 3.2 (Theorem 2).
//!
//! For symmetric `A` and `C = Rᵀ`, draw two *independent* sketches
//! `S_1, S_2 ∈ R^{s×n}`, solve
//! `X̃ = (S_1 C)† (S_1 A S_2ᵀ) (Cᵀ S_2ᵀ)†`, then project onto the
//! symmetric matrices (Eqn. 3.5) or the PSD cone (Eqn. 3.6). By
//! Proposition 1 the projection cannot increase the error, so the
//! (1+ε) bound of Theorem 1 carries over.

use super::{fast::solve_core, Input};
use crate::linalg::{project_psd, project_symmetric, Mat};
use crate::rng::Pcg64;
use crate::sketch::{row_leverage_scores, Sketch, SketchKind};

/// Configuration for the symmetric solver (one size, one family — the two
/// sketches are always drawn independently as Theorem 2 requires).
#[derive(Clone, Debug)]
pub struct SymGmrConfig {
    pub kind: SketchKind,
    pub s: usize,
}

/// Draw the two independent sketches for the symmetric solve.
fn draw_pair(a: Input<'_>, c: &Mat, cfg: &SymGmrConfig, rng: &mut Pcg64) -> (Sketch, Sketch) {
    let n = a.rows();
    match cfg.kind {
        SketchKind::Leverage => {
            // Table 3: leverage scores w.r.t. the column leverage scores
            // of C (i.e. row leverage scores of the n×c factor).
            let scores = row_leverage_scores(c);
            let s1 = Sketch::draw(SketchKind::Leverage, cfg.s, n, Some(&scores), rng);
            let s2 = Sketch::draw(SketchKind::Leverage, cfg.s, n, Some(&scores), rng);
            (s1, s2)
        }
        kind => {
            let s1 = Sketch::draw(kind, cfg.s, n, None, rng);
            let s2 = Sketch::draw(kind, cfg.s, n, None, rng);
            (s1, s2)
        }
    }
}

/// Theorem 2, symmetric case: returns `Π_H(X̃)` — symmetric, and within
/// (1+ε) of the optimal symmetric core.
pub fn solve_fast_symmetric(a: Input<'_>, c: &Mat, cfg: &SymGmrConfig, rng: &mut Pcg64) -> Mat {
    let x = solve_raw(a, c, cfg, rng);
    project_symmetric(&x)
}

/// Theorem 2, SPSD case: returns `Π_{H+}(X̃)` — PSD, and within (1+ε) of
/// the optimal core for SPSD `A`. This is the core step of Algorithm 2.
pub fn solve_fast_psd(a: Input<'_>, c: &Mat, cfg: &SymGmrConfig, rng: &mut Pcg64) -> Mat {
    let x = solve_raw(a, c, cfg, rng);
    project_psd(&x)
}

/// The unprojected X̃ of Eqn. (3.7).
pub fn solve_raw(a: Input<'_>, c: &Mat, cfg: &SymGmrConfig, rng: &mut Pcg64) -> Mat {
    let n = a.rows();
    assert_eq!(a.cols(), n, "symmetric solve expects square A");
    assert_eq!(c.rows(), n, "C must have n rows");
    assert!(cfg.s >= c.cols(), "sketch size must be >= c");
    let (s1, s2) = draw_pair(a, c, cfg, rng);

    let s1_c = s1.apply_left(c); // s x c
    let ct_s2 = s2.apply_right(&c.transpose()); // c x s   (Cᵀ S_2ᵀ)
    let s1_a = a.sketch_left(&s1); // s x n
    let a_tilde = s2.apply_right(&s1_a); // s x s

    solve_core(&s1_c, &a_tilde, &ct_s2)
}
