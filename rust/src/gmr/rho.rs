//! The spectral ratio ρ (Eqn. 3.2) that governs whether the ε^{-1/2}
//! sketch-size regime applies (Remark 2): when 1/ρ² ≤ √ε the sketch
//! sizes are O(ε^{-1/2}); otherwise the ε^{-1} term dominates.

use super::Input;
use crate::linalg::{matmul_at_b, qr_thin, Mat};

/// The three Frobenius norms that make up ρ.
#[derive(Debug, Clone, Copy)]
pub struct RhoParts {
    /// ‖A − CC†A RR†‖_F (the optimal GMR residual).
    pub residual: f64,
    /// ‖(I − CC†) A RR†‖_F.
    pub left_defect: f64,
    /// ‖CC†A (I − RR†)‖_F.
    pub right_defect: f64,
}

impl RhoParts {
    /// ρ = residual / (left_defect + right_defect).
    pub fn rho(&self) -> f64 {
        let den = self.left_defect + self.right_defect;
        if den == 0.0 {
            f64::INFINITY
        } else {
            self.residual / den
        }
    }
}

/// Compute ρ (Eqn. 3.2) from `A`, `C`, `R`.
///
/// Implementation identities (U = orthobasis(C), V = orthobasis(Rᵀ)):
/// with `P = UᵀAV` (c×r), `B = AV` (m×r), `D = UᵀA` (c×n):
/// * residual²      = ‖A‖² − ‖P‖²   (‖A − UUᵀAVVᵀ‖², cross-term = ‖P‖²)
/// * left_defect²   = ‖B‖² − ‖P‖²   (‖(I−UUᵀ)AVVᵀ‖²)
/// * right_defect²  = ‖D‖² − ‖P‖²   (‖UUᵀA(I−VVᵀ)‖²)
///
/// Only thin products against A are formed — O(nnz·(c+r)) total; the
/// two orthobasis QRs are the blocked compact-WY kernel.
pub fn compute_rho(a: Input<'_>, c: &Mat, r: &Mat) -> RhoParts {
    let u = qr_thin(c).q; // m x c'
    let v = qr_thin(&r.transpose()).q; // n x r'
    let b = a.a_b(&v); // m x r'   (A V)
    let d_t = a.at_b(&u); // n x c'  (Aᵀ U) = Dᵀ
    let p = matmul_at_b(&u, &b); // c' x r'  (Uᵀ A V)

    let a2 = {
        let f = a.fro_norm();
        f * f
    };
    let b2 = b.fro_norm_sq();
    let d2 = d_t.fro_norm_sq();
    let p2 = p.fro_norm_sq();

    RhoParts {
        residual: (a2 - p2).max(0.0).sqrt(),
        left_defect: (b2 - p2).max(0.0).sqrt(),
        right_defect: (d2 - p2).max(0.0).sqrt(),
    }
}

/// Symmetric-case ρ (Table 3 / Eqn. 4.3):
/// `ρ = ½ ‖K − CC†KCC†‖_F / ‖(I − CC†)KCC†‖_F`.
pub fn compute_rho_symmetric(k: Input<'_>, c: &Mat) -> f64 {
    let parts = compute_rho(k, c, &c.transpose());
    // For symmetric K and R = Cᵀ the two defects are equal, so
    // residual / (2 * left_defect) = parts.rho()… keep the explicit form:
    let den = parts.left_defect.max(parts.right_defect);
    if den == 0.0 {
        f64::INFINITY
    } else {
        0.5 * parts.residual / den
    }
}

/// Remark 2's upper bound check helper: given singular values of A,
/// 1/ρ ≤ 2‖A_max{c,r}‖_F / ‖A − A_min{c,r}‖_F … exposed for the table
/// benches that report both the exact ρ and the bound.
pub fn rho_upper_bound_inverse(singular_values: &[f64], c: usize, r: usize) -> f64 {
    let hi = c.max(r).min(singular_values.len());
    let lo = c.min(r).min(singular_values.len());
    let head: f64 = singular_values[..hi].iter().map(|s| s * s).sum::<f64>().sqrt();
    let tail: f64 = singular_values[lo..].iter().map(|s| s * s).sum::<f64>().sqrt();
    if tail == 0.0 {
        f64::INFINITY
    } else {
        2.0 * head / tail
    }
}
