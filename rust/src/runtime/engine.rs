//! PJRT execution engine.
//!
//! Wraps the `xla` crate: one CPU client per process, one compiled
//! executable per artifact (compiled lazily, cached). All artifacts are
//! lowered by `aot.py` with `return_tuple=True`, so outputs arrive as a
//! tuple literal; inputs/outputs are f32 (the PJRT boundary — the Rust
//! side computes in f64 and converts here).

use super::artifacts::{Manifest, ManifestEntry};
use crate::error::{FgError, Result};
use crate::linalg::Mat;
use std::collections::HashMap;
use std::sync::Mutex;

/// A compiled, ready-to-run artifact.
pub struct LoadedGraph {
    pub entry: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedGraph {
    /// Execute with `Mat` inputs (converted to f32 literals); returns the
    /// tuple elements as `Mat`s in declaration order.
    pub fn run(&self, inputs: &[&Mat]) -> Result<Vec<Mat>> {
        if inputs.len() != self.entry.input_shapes.len() {
            return Err(FgError::ShapeMismatch {
                context: format!("{} inputs", self.entry.name),
                expected: format!("{}", self.entry.input_shapes.len()),
                got: format!("{}", inputs.len()),
            });
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (mat, &(r, c)) in inputs.iter().zip(&self.entry.input_shapes) {
            if mat.shape() != (r, c) {
                return Err(FgError::ShapeMismatch {
                    context: format!("{} input", self.entry.name),
                    expected: format!("{r}x{c}"),
                    got: format!("{}x{}", mat.rows(), mat.cols()),
                });
            }
            let lit = xla::Literal::vec1(&mat.to_f32()).reshape(&[r as i64, c as i64])?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        let mut out = Vec::with_capacity(tuple.len());
        for (lit, &(r, c)) in tuple.iter().zip(&self.entry.output_shapes) {
            let vals = lit.to_vec::<f32>()?;
            if vals.len() != r * c {
                return Err(FgError::ShapeMismatch {
                    context: format!("{} output", self.entry.name),
                    expected: format!("{r}x{c}"),
                    got: format!("{} elements", vals.len()),
                });
            }
            out.push(Mat::from_f32(r, c, &vals));
        }
        Ok(out)
    }
}

/// The process-wide engine: PJRT client + executable cache.
///
/// Single-threaded (the `xla` crate's client handle is `Rc`-based); the
/// coordinator keeps the engine on its executor thread.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedGraph>>>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.client.platform_name())
            .field("artifacts", &self.manifest.len())
            .finish()
    }
}

impl Engine {
    /// Create the CPU PJRT client and load the manifest from `dir`
    /// (default `artifacts/`).
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        let manifest = Manifest::load(dir)?;
        Ok(Self { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<LoadedGraph>> {
        if let Some(g) = self.cache.lock().unwrap().get(name) {
            return Ok(g.clone());
        }
        let entry = self.manifest.get(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(
            entry.hlo_path.to_str().ok_or_else(|| FgError::Runtime("bad path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let graph = std::sync::Arc::new(LoadedGraph { entry, exe });
        self.cache.lock().unwrap().insert(name.to_string(), graph.clone());
        Ok(graph)
    }

    /// Run every artifact that ships a golden file against it; returns
    /// (name, max |err|) per graph. Startup self-check.
    pub fn verify_goldens(&self) -> Result<Vec<(String, f64)>> {
        let names: Vec<String> = self.manifest.names().map(str::to_string).collect();
        let mut results = Vec::new();
        for name in names {
            let entry = self.manifest.get(&name)?.clone();
            let Some(golden) = entry.golden_path.clone() else { continue };
            let graph = self.load(&name)?;
            let bytes = std::fs::read(&golden)?;
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            // Layout: concatenated inputs then outputs, row-major f32.
            let mut pos = 0usize;
            let mut inputs = Vec::new();
            for &(r, c) in &entry.input_shapes {
                inputs.push(Mat::from_f32(r, c, &floats[pos..pos + r * c]));
                pos += r * c;
            }
            let mut expected = Vec::new();
            for &(r, c) in &entry.output_shapes {
                expected.push(Mat::from_f32(r, c, &floats[pos..pos + r * c]));
                pos += r * c;
            }
            let input_refs: Vec<&Mat> = inputs.iter().collect();
            let outputs = graph.run(&input_refs)?;
            let mut max_err = 0.0f64;
            for (got, want) in outputs.iter().zip(&expected) {
                let scale = want.max_abs().max(1.0);
                for (g, w) in got.data().iter().zip(want.data()) {
                    max_err = max_err.max((g - w).abs() / scale);
                }
            }
            results.push((name, max_err));
        }
        Ok(results)
    }
}
