//! Artifact manifest: `artifacts/manifest.txt` maps graph names to HLO
//! files, I/O shapes, and golden-check files. Written by `aot.py` in a
//! line format the Rust side parses without a JSON dependency:
//!
//! ```text
//! graph rbf_block_256 file=rbf_block_256.hlo.txt inputs=256x8,256x8 outputs=256x256 golden=rbf_block_256.golden
//! ```

use crate::error::{FgError, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One AOT graph.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub hlo_path: PathBuf,
    /// Input shapes, row-major (rows, cols) per argument.
    pub input_shapes: Vec<(usize, usize)>,
    /// Output shapes per result.
    pub output_shapes: Vec<(usize, usize)>,
    /// Optional golden check file (f32 binary: inputs then outputs).
    pub golden_path: Option<PathBuf>,
}

impl ManifestEntry {
    /// Render back to the one-line `manifest.txt` format that
    /// [`Manifest::load`] parses (paths reduce to their file names,
    /// which `load` re-joins onto the manifest directory). The serving
    /// layer's artifact cache reuses this shape for its inventory
    /// listing, so cached factorizations and AOT graphs read the same.
    pub fn to_line(&self) -> String {
        fn fname(p: &Path) -> String {
            match p.file_name() {
                Some(s) => s.to_string_lossy().into_owned(),
                None => p.display().to_string(),
            }
        }
        fn shapes(s: &[(usize, usize)]) -> String {
            s.iter().map(|(r, c)| format!("{r}x{c}")).collect::<Vec<_>>().join(",")
        }
        let mut line = format!("graph {} file={}", self.name, fname(&self.hlo_path));
        if !self.input_shapes.is_empty() {
            line.push_str(&format!(" inputs={}", shapes(&self.input_shapes)));
        }
        if !self.output_shapes.is_empty() {
            line.push_str(&format!(" outputs={}", shapes(&self.output_shapes)));
        }
        if let Some(g) = &self.golden_path {
            line.push_str(&format!(" golden={}", fname(g)));
        }
        line
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    entries: BTreeMap<String, ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|_| FgError::ArtifactMissing {
            name: "manifest.txt".into(),
            dir: dir.display().to_string(),
        })?;
        let mut entries = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let entry = Self::parse_line(&dir, line)
                .ok_or_else(|| FgError::Config(format!("manifest line {}: malformed", lineno + 1)))?;
            entries.insert(entry.name.clone(), entry);
        }
        Ok(Self { dir, entries })
    }

    /// Parse one `graph NAME file=… [inputs=…] [outputs=…] [golden=…]`
    /// manifest line relative to `dir`. Public because the artifact
    /// cache's on-disk inventory reuses this exact line grammar for its
    /// warm-start header records.
    pub fn parse_line(dir: &Path, line: &str) -> Option<ManifestEntry> {
        let mut parts = line.split_whitespace();
        if parts.next()? != "graph" {
            return None;
        }
        let name = parts.next()?.to_string();
        let mut hlo_path = None;
        let mut input_shapes = Vec::new();
        let mut output_shapes = Vec::new();
        let mut golden_path = None;
        for kv in parts {
            let (k, v) = kv.split_once('=')?;
            match k {
                "file" => hlo_path = Some(dir.join(v)),
                "inputs" => input_shapes = parse_shapes(v)?,
                "outputs" => output_shapes = parse_shapes(v)?,
                "golden" => golden_path = Some(dir.join(v)),
                _ => return None,
            }
        }
        Some(ManifestEntry { name, hlo_path: hlo_path?, input_shapes, output_shapes, golden_path })
    }

    pub fn get(&self, name: &str) -> Result<&ManifestEntry> {
        self.entries.get(name).ok_or_else(|| FgError::ArtifactMissing {
            name: name.to_string(),
            dir: self.dir.display().to_string(),
        })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn parse_shapes(spec: &str) -> Option<Vec<(usize, usize)>> {
    spec.split(',')
        .map(|s| {
            let (r, c) = s.split_once('x')?;
            Some((r.parse().ok()?, c.parse().ok()?))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_lines() {
        let dir = std::path::Path::new("/tmp/fastgmr_manifest_test");
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "# comment\n\
             graph g1 file=g1.hlo.txt inputs=4x3,3x2 outputs=4x2 golden=g1.golden\n\
             graph g2 file=g2.hlo.txt inputs=8x8 outputs=8x8\n",
        )
        .unwrap();
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.len(), 2);
        let g1 = m.get("g1").unwrap();
        assert_eq!(g1.input_shapes, vec![(4, 3), (3, 2)]);
        assert_eq!(g1.output_shapes, vec![(4, 2)]);
        assert!(g1.golden_path.is_some());
        let g2 = m.get("g2").unwrap();
        assert!(g2.golden_path.is_none());
        assert!(m.get("missing").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn to_line_roundtrips_through_parse() {
        let dir = Path::new("/tmp/fastgmr_manifest_roundtrip");
        let line = "graph g1 file=g1.hlo.txt inputs=4x3,3x2 outputs=4x2 golden=g1.golden";
        let entry = Manifest::parse_line(dir, line).unwrap();
        assert_eq!(entry.to_line(), line);
        let bare = Manifest::parse_line(dir, "graph g2 file=g2.hlo.txt outputs=8x8").unwrap();
        assert_eq!(bare.to_line(), "graph g2 file=g2.hlo.txt outputs=8x8");
        let again = Manifest::parse_line(dir, &bare.to_line()).unwrap();
        assert_eq!(again.output_shapes, bare.output_shapes);
        assert!(again.golden_path.is_none());
    }

    #[test]
    fn missing_manifest_is_artifact_error() {
        let err = Manifest::load("/tmp/definitely_missing_dir_fastgmr").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
