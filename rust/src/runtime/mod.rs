//! PJRT runtime — loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs at request time: `make artifacts` lowers the L2 JAX
//! graphs (which call the L1 Pallas kernels) to HLO *text* once; this
//! module compiles them on the PJRT CPU client at startup and caches the
//! executables.

mod artifacts;
mod engine;

pub use artifacts::{Manifest, ManifestEntry};
pub use engine::{Engine, LoadedGraph};

#[cfg(test)]
mod tests;
