//! Runtime tests that don't require artifacts (the artifact integration
//! test lives in `rust/tests/artifacts.rs` and is skipped when
//! `artifacts/` hasn't been built).

use super::*;

#[test]
fn engine_errors_cleanly_without_artifacts() {
    let err = Engine::new("/tmp/no_such_artifacts_dir").unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("make artifacts"), "unexpected error: {msg}");
}

#[test]
fn manifest_entry_is_cloneable() {
    let e = ManifestEntry {
        name: "g".into(),
        hlo_path: "/tmp/g.hlo.txt".into(),
        input_shapes: vec![(2, 2)],
        output_shapes: vec![(2, 2)],
        golden_path: None,
    };
    let e2 = e.clone();
    assert_eq!(e2.name, "g");
}
