//! Thin (economy) QR via *blocked* Householder reflections with
//! compact-WY accumulation.
//!
//! The factorization proceeds over column panels of width [`PANEL`].
//! Each panel is copied into a column-major scratch buffer so the
//! unblocked reflector construction walks contiguous slices (the seed
//! kernel's strided `r_work[(i, col)]` access was the dominant cost on
//! tall inputs), then the panel's reflectors are aggregated into the
//! compact-WY form `Q_panel = I − V T Vᵀ` (Schreiber–Van Loan). The
//! trailing-matrix update and the thin-Q formation are then two dense
//! products per panel — `W = Vᵀ·A_trail` and `A_trail −= V·(Tᵀ·W)` —
//! routed through [`matmul_at_b`] / [`matmul_acc`], so the O(mn²) bulk
//! of the factorization rides the blocked, register-tiled matmul kernel
//! and shards over the `crate::parallel` pool exactly like a plain
//! product (bitwise identical for every thread count — see the
//! determinism notes in `crate::parallel`).
//!
//! Callers throughout the crate — Algorithm 1's sketched solve
//! (`gmr`), the CUR stabilized core (`cur::core`), leverage-score
//! selection (`sketch::leverage`), `svd_randomized`'s three thin QRs
//! per power iteration, and the streaming finalizers (`svdstream`) —
//! all go through this one entry point.

use super::{matmul_acc, matmul_at_b, Mat};

/// Panel width: wide enough that the trailing update is matmul-bound,
/// narrow enough that the panel fits in L1/L2 alongside a C panel.
pub(crate) const PANEL: usize = 32;

/// Thin QR factorization `A = Q R`, `Q` m×k with orthonormal columns,
/// `R` k×n upper trapezoidal (k×k triangular when n ≤ m), `k = min(m, n)`.
pub struct QrThin {
    pub q: Mat,
    pub r: Mat,
}

/// One factored panel in compact-WY form: `Q_p = I − V T Vᵀ` acting on
/// rows `j0..m`. `v` is (m−j0)×nb column-major (column `i` zero above
/// its pivot row `i`), `t` is nb×nb upper triangular row-major.
struct WyPanel {
    j0: usize,
    nb: usize,
    /// (m − j0) × nb, as a row-major [`Mat`] for the update products.
    v: Mat,
    /// nb × nb upper triangular.
    t: Mat,
}

/// Blocked Householder thin QR. Numerically stable (reflector-based,
/// column pivot-free); `A` is m×n with m ≥ n typical for our use
/// (orthonormal bases of sketch outputs, Algorithm 3 step 10).
pub fn qr_thin(a: &Mat) -> QrThin {
    let (m, n) = a.shape();
    let k = m.min(n);
    if k == 0 {
        return QrThin { q: Mat::zeros(m, 0), r: Mat::zeros(0, n) };
    }
    let mut r_work = a.clone(); // reduced to R in its top k rows
    let mut panels: Vec<WyPanel> = Vec::with_capacity(k.div_ceil(PANEL));

    let mut j0 = 0;
    while j0 < k {
        let nb = PANEL.min(k - j0);
        let panel = factor_panel(&mut r_work, j0, nb);
        // Trailing update: A[j0.., j0+nb..] ← (I − V Tᵀ Vᵀ)·A  (= Qᵀ_p A).
        // The trailing block is packed out to a contiguous Mat and
        // written back — O(mn) traffic per panel against the update's
        // O(mn·nb) flops (the same pack cost every blocked kernel pays;
        // updating in place would need leading-dimension strides the
        // matmul drivers don't carry).
        let jt = j0 + nb;
        if jt < n {
            let mut trail = r_work.slice(j0, m, jt, n); // (m−j0) × (n−jt)
            apply_wy_transpose(&panel, &mut trail);
            r_work.set_block(j0, jt, &trail);
        }
        panels.push(panel);
        j0 += nb;
    }

    // Extract R (k×n, upper trapezoidal).
    let mut r = Mat::zeros(k, n);
    for i in 0..k {
        let src = &r_work.row(i)[i..n];
        r.row_mut(i)[i..n].copy_from_slice(src);
    }

    // Form thin Q by applying the panel reflectors to E_k in reverse
    // panel order: Q[j0.., j0..] ← (I − V T Vᵀ)·Q[j0.., j0..]. Columns
    // 0..j0 are untouched unit vectors at this point (their support lies
    // above row j0), so each application is restricted to the trailing
    // column block — the standard O(mnk) formation.
    let mut q = Mat::zeros(m, k);
    for i in 0..k {
        q[(i, i)] = 1.0;
    }
    for panel in panels.iter().rev() {
        let j0 = panel.j0;
        let mut qsub = q.slice(j0, m, j0, k);
        apply_wy(panel, &mut qsub);
        q.set_block(j0, j0, &qsub);
    }

    QrThin { q, r }
}

/// Unblocked Householder factorization of the panel `rows j0..m, cols
/// j0..j0+nb` of `r_work`, on a column-major scratch copy so every
/// reflector builds and applies over contiguous slices. Writes the
/// reduced panel (R values on/above the diagonal, zeros below) back into
/// `r_work` and returns the compact-WY pair (V, T).
fn factor_panel(r_work: &mut Mat, j0: usize, nb: usize) -> WyPanel {
    let m = r_work.rows();
    let rows = m - j0;

    // Column-major copy of the panel: pan[c*rows + r] = A[j0+r, j0+c].
    let mut pan = vec![0.0f64; rows * nb];
    for r in 0..rows {
        let src = &r_work.row(j0 + r)[j0..j0 + nb];
        for (c, &x) in src.iter().enumerate() {
            pan[c * rows + r] = x;
        }
    }

    // vbuf: column-major like pan; column i holds the (unnormalized)
    // reflector v_i in rows i.., zeros above.
    let mut vbuf = vec![0.0f64; rows * nb];
    let mut betas = vec![0.0f64; nb];

    for i in 0..nb {
        // Build reflector i from pan column i, rows i..
        let (head, tail) = pan.split_at_mut((i + 1) * rows);
        let col = &mut head[i * rows + i..];
        let norm = col.iter().map(|x| x * x).sum::<f64>().sqrt();
        let alpha = if col[0] >= 0.0 { -norm } else { norm };
        if alpha == 0.0 {
            // Column already zero at and below the pivot: identity
            // reflector (beta = 0, zero V column keeps WY consistent).
            continue;
        }
        let v = &mut vbuf[i * rows + i..(i + 1) * rows];
        v.copy_from_slice(col);
        v[0] -= alpha;
        let vnorm_sq: f64 = v.iter().map(|x| x * x).sum();
        let beta = if vnorm_sq == 0.0 { 0.0 } else { 2.0 / vnorm_sq };
        betas[i] = beta;
        // Reduced column i: alpha on the diagonal, zeros below.
        col[0] = alpha;
        for x in col.iter_mut().skip(1) {
            *x = 0.0;
        }
        if beta == 0.0 {
            continue;
        }
        // Apply (I − beta v vᵀ) to the remaining panel columns — all
        // contiguous column slices.
        for c in i + 1..nb {
            let colc = &mut tail[(c - i - 1) * rows + i..(c - i) * rows];
            let v = &vbuf[i * rows + i..(i + 1) * rows];
            let dot: f64 = v.iter().zip(colc.iter()).map(|(a, b)| a * b).sum();
            let s = beta * dot;
            if s != 0.0 {
                for (x, &vv) in colc.iter_mut().zip(v) {
                    *x -= s * vv;
                }
            }
        }
    }

    // Write the reduced panel back (row-major r_work).
    for r in 0..rows {
        let dst = &mut r_work.row_mut(j0 + r)[j0..j0 + nb];
        for (c, x) in dst.iter_mut().enumerate() {
            *x = pan[c * rows + r];
        }
    }

    // Build T (upper triangular): T[i][i] = beta_i and
    // T[0..i, i] = −beta_i · T_{0..i,0..i} · (V_{:,0..i}ᵀ v_i).
    // (The recurrence holds for unnormalized v — T absorbs the scaling.)
    let mut t = Mat::zeros(nb, nb);
    for i in 0..nb {
        let beta = betas[i];
        t[(i, i)] = beta;
        if beta == 0.0 || i == 0 {
            continue;
        }
        // w = Vᵀ_{cols 0..i} · v_i; column j of V is zero above row j and
        // v_i is zero above row i, so the dot runs over rows i..rows.
        let vi = &vbuf[i * rows + i..(i + 1) * rows];
        let mut w = vec![0.0f64; i];
        for (j, wj) in w.iter_mut().enumerate() {
            let vj = &vbuf[j * rows + i..(j + 1) * rows];
            *wj = vj.iter().zip(vi.iter()).map(|(a, b)| a * b).sum();
        }
        // t_col = −beta · T_{0..i,0..i} · w (upper-triangular matvec).
        for r in 0..i {
            let mut acc = 0.0;
            for (c, &wc) in w.iter().enumerate().skip(r) {
                acc += t[(r, c)] * wc;
            }
            t[(r, i)] = -beta * acc;
        }
    }

    // Convert V to a row-major Mat for the matmul-driven updates.
    let mut v = Mat::zeros(rows, nb);
    for r in 0..rows {
        let dst = v.row_mut(r);
        for (c, x) in dst.iter_mut().enumerate() {
            *x = vbuf[c * rows + r];
        }
    }

    WyPanel { j0, nb, v, t }
}

/// `X ← (I − V Tᵀ Vᵀ)·X` — the Qᵀ-side block application used for the
/// trailing update. Two dense products (`Vᵀ X` then `V·(Tᵀ W)`), both
/// routed through the blocked/parallel matmul drivers.
fn apply_wy_transpose(panel: &WyPanel, x: &mut Mat) {
    debug_assert_eq!(x.rows(), panel.v.rows());
    let w = matmul_at_b(&panel.v, x); // nb × nc
    let mut tw = matmul_at_b(&panel.t, &w); // Tᵀ·W, nb × nc
    tw.scale(-1.0);
    matmul_acc(&panel.v, &tw, x); // X −= V·(Tᵀ W)
}

/// `X ← (I − V T Vᵀ)·X` — the Q-side block application used when
/// forming the thin Q factor.
fn apply_wy(panel: &WyPanel, x: &mut Mat) {
    debug_assert_eq!(x.rows(), panel.v.rows());
    let w = matmul_at_b(&panel.v, x); // nb × nc
    let mut tw = Mat::zeros(panel.nb, w.cols());
    matmul_acc(&panel.t, &w, &mut tw); // T·W
    tw.scale(-1.0);
    matmul_acc(&panel.v, &tw, x); // X −= V·(T W)
}
