//! Thin (economy) QR via blocked Householder reflections.

use super::Mat;

/// Thin QR factorization `A = Q R`, `Q` m×k with orthonormal columns,
/// `R` k×k upper triangular, `k = min(m, n)`.
pub struct QrThin {
    pub q: Mat,
    pub r: Mat,
}

/// Householder thin QR. Numerically stable (reflector-based, column
/// pivot-free); `A` is m×n with m >= n typical for our use (orthonormal
/// bases of sketch outputs, Algorithm 3 step 10).
pub fn qr_thin(a: &Mat) -> QrThin {
    let (m, n) = a.shape();
    let k = m.min(n);
    let mut r_work = a.clone(); // will be reduced to R in its top k rows
    // Householder vectors stored in the strictly-lower part + diag scale.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);
    let mut betas = Vec::with_capacity(k);

    for j in 0..k {
        // Build the reflector for column j from rows j..m.
        let mut v: Vec<f64> = (j..m).map(|i| r_work[(i, j)]).collect();
        let alpha = {
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if v[0] >= 0.0 {
                -norm
            } else {
                norm
            }
        };
        if alpha == 0.0 {
            // Column already zero below the diagonal; identity reflector.
            vs.push(v);
            betas.push(0.0);
            continue;
        }
        v[0] -= alpha;
        let vnorm_sq: f64 = v.iter().map(|x| x * x).sum();
        let beta = if vnorm_sq == 0.0 { 0.0 } else { 2.0 / vnorm_sq };

        // Apply (I - beta v vᵀ) to the trailing submatrix of r_work.
        for col in j..n {
            let mut dot = 0.0;
            for (t, i) in (j..m).enumerate() {
                dot += v[t] * r_work[(i, col)];
            }
            let s = beta * dot;
            if s != 0.0 {
                for (t, i) in (j..m).enumerate() {
                    r_work[(i, col)] -= s * v[t];
                }
            }
        }
        vs.push(v);
        betas.push(beta);
    }

    // Extract R (k x n upper-triangular in its first k columns; thin R is k x k
    // when n <= m, otherwise k x n).
    let rc = n;
    let mut r = Mat::zeros(k, rc);
    for i in 0..k {
        for j in i..rc {
            r[(i, j)] = r_work[(i, j)];
        }
    }

    // Form thin Q by applying reflectors to the first k columns of I.
    let mut q = Mat::zeros(m, k);
    for i in 0..k {
        q[(i, i)] = 1.0;
    }
    for j in (0..k).rev() {
        let (v, beta) = (&vs[j], betas[j]);
        if beta == 0.0 {
            continue;
        }
        for col in 0..k {
            let mut dot = 0.0;
            for (t, i) in (j..m).enumerate() {
                dot += v[t] * q[(i, col)];
            }
            let s = beta * dot;
            if s != 0.0 {
                for (t, i) in (j..m).enumerate() {
                    q[(i, col)] -= s * v[t];
                }
            }
        }
    }

    QrThin { q, r }
}
