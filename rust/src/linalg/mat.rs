//! Row-major dense matrix type.

use crate::rng::Pcg64;
use std::fmt;
use std::ops::{Add, AddAssign, Mul, Sub, SubAssign};

/// Dense row-major matrix over `f64`.
///
/// Storage is a single contiguous `Vec<f64>` of length `rows * cols`;
/// element `(i, j)` lives at `data[i * cols + j]`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of order `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length != rows*cols");
        Self { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// i.i.d. standard normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| rng.next_normal()).collect();
        Self { rows, cols, data }
    }

    /// i.i.d. N(0, 1/rows) entries — a Gaussian sketching matrix with the
    /// scaling from Section 2.3 of the paper.
    pub fn randn_sketch(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let sigma = 1.0 / (rows as f64).sqrt();
        let data = (0..rows * cols).map(|_| rng.next_normal() * sigma).collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f64> {
        self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow row `i` mutably.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy column `j` out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transpose (materializing).
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Block the transpose for cache friendliness on large inputs.
        const B: usize = 64;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Sub-matrix copy: rows `r0..r1`, cols `c0..c1`.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows && c0 <= c1 && c1 <= self.cols);
        let mut out = Mat::zeros(r1 - r0, c1 - c0);
        for (oi, i) in (r0..r1).enumerate() {
            out.row_mut(oi).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Gather a row subset (used by sampling sketches).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (oi, &i) in idx.iter().enumerate() {
            out.row_mut(oi).copy_from_slice(self.row(i));
        }
        out
    }

    /// Gather a column subset.
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (oj, &j) in idx.iter().enumerate() {
                dst[oj] = src[j];
            }
        }
        out
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "hcat: row mismatch");
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Vertical concatenation.
    pub fn vcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "vcat: col mismatch");
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Mat::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Write `block` into `self` starting at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, block: &Mat) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for i in 0..block.rows {
            let dst = &mut self.row_mut(r0 + i)[c0..c0 + block.cols];
            dst.copy_from_slice(block.row(i));
        }
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// `self += s * other` (axpy).
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape(), "axpy: shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += s * b;
        }
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
    }

    /// Squared Frobenius norm (f64 accumulation).
    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.fro_norm_sq().sqrt()
    }

    /// Trace (square matrices).
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Matrix–vector product `self * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Transposed matrix–vector product `selfᵀ * x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += xi * a;
            }
        }
        out
    }

    /// Row squared norms (leverage-score helper).
    pub fn row_norms_sq(&self) -> Vec<f64> {
        (0..self.rows).map(|i| self.row(i).iter().map(|v| v * v).sum()).collect()
    }

    /// Convert to f32 (PJRT boundary).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Build from an f32 buffer (PJRT boundary).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data: data.iter().map(|&v| v as f64).collect() }
    }
}

/// 64-byte-aligned, growable `f64` scratch buffer.
///
/// Backing store for the packed-GEMM workspaces in `linalg::matmul`:
/// panel packing wants cache-line/vector-register alignment so the
/// microkernel's loads never straddle a cache line, and `Vec<f64>` only
/// guarantees the allocator's 8/16-byte minimum. The buffer grows
/// monotonically and never shrinks — thread-local workspaces reuse it
/// across calls, which is the whole point (no per-call allocation on the
/// hot path). Contents after [`AlignedBuf::ensure`] are whatever the last
/// use left there (zeroed on first allocation); callers overwrite the
/// prefix they asked for.
pub(crate) struct AlignedBuf {
    ptr: std::ptr::NonNull<f64>,
    cap: usize,
}

impl AlignedBuf {
    /// Cache-line (and AVX-512 register) alignment.
    const ALIGN: usize = 64;

    /// Empty buffer; allocates nothing until the first [`AlignedBuf::ensure`].
    pub(crate) const fn new() -> Self {
        Self { ptr: std::ptr::NonNull::dangling(), cap: 0 }
    }

    /// Borrow at least `len` elements, reallocating (aligned, zero-filled)
    /// if the current capacity is smaller.
    pub(crate) fn ensure(&mut self, len: usize) -> &mut [f64] {
        if len > self.cap {
            self.grow(len);
        }
        // SAFETY: `ptr` points to an allocation of `cap >= len` f64s that
        // was zero-initialized at allocation time (or `len == 0`, for
        // which the dangling-but-aligned pointer is valid), and `self` is
        // mutably borrowed for the slice's lifetime.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), len) }
    }

    fn grow(&mut self, len: usize) {
        let layout = Self::layout(len);
        // SAFETY: `len > cap >= 0` so the layout is non-zero-sized.
        let raw = unsafe { std::alloc::alloc_zeroed(layout) };
        let Some(ptr) = std::ptr::NonNull::new(raw.cast::<f64>()) else {
            std::alloc::handle_alloc_error(layout)
        };
        self.release();
        self.ptr = ptr;
        self.cap = len;
    }

    fn release(&mut self) {
        if self.cap > 0 {
            // SAFETY: `ptr`/`cap` describe a live allocation made by
            // `grow` with this exact layout.
            unsafe { std::alloc::dealloc(self.ptr.as_ptr().cast(), Self::layout(self.cap)) };
            self.cap = 0;
            self.ptr = std::ptr::NonNull::dangling();
        }
    }

    fn layout(len: usize) -> std::alloc::Layout {
        std::alloc::Layout::from_size_align(len * std::mem::size_of::<f64>(), Self::ALIGN)
            .expect("AlignedBuf: layout overflow")
    }
}

impl Default for AlignedBuf {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        self.release();
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Add for &Mat {
    type Output = Mat;
    fn add(self, rhs: &Mat) -> Mat {
        assert_eq!(self.shape(), rhs.shape());
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a + b).collect();
        Mat::from_vec(self.rows, self.cols, data)
    }
}

impl Sub for &Mat {
    type Output = Mat;
    fn sub(self, rhs: &Mat) -> Mat {
        assert_eq!(self.shape(), rhs.shape());
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a - b).collect();
        Mat::from_vec(self.rows, self.cols, data)
    }
}

impl AddAssign<&Mat> for Mat {
    fn add_assign(&mut self, rhs: &Mat) {
        assert_eq!(self.shape(), rhs.shape());
        for (a, b) in self.data.iter_mut().zip(&rhs.data) {
            *a += b;
        }
    }
}

impl SubAssign<&Mat> for Mat {
    fn sub_assign(&mut self, rhs: &Mat) {
        assert_eq!(self.shape(), rhs.shape());
        for (a, b) in self.data.iter_mut().zip(&rhs.data) {
            *a -= b;
        }
    }
}

impl Mul for &Mat {
    type Output = Mat;
    fn mul(self, rhs: &Mat) -> Mat {
        super::matmul(self, rhs)
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}
