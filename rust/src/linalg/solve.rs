//! Triangular solves (forward/backward substitution), matrix right-hand
//! sides.

use super::Mat;

/// Solve `L X = B` with `L` lower triangular (forward substitution).
pub fn solve_lower(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows();
    assert_eq!(n, l.cols(), "solve_lower: L must be square");
    assert_eq!(n, b.rows(), "solve_lower: dim mismatch");
    let m = b.cols();
    let mut x = b.clone();
    for i in 0..n {
        let lii = l[(i, i)];
        debug_assert!(lii != 0.0, "singular triangular factor");
        // x[i, :] = (b[i, :] - sum_{k<i} l[i,k] x[k, :]) / l[i,i]
        for k in 0..i {
            let lik = l[(i, k)];
            if lik == 0.0 {
                continue;
            }
            let (head, tail) = x.data_mut().split_at_mut(i * m);
            let xk = &head[k * m..(k + 1) * m];
            let xi = &mut tail[..m];
            for (xi_v, xk_v) in xi.iter_mut().zip(xk) {
                *xi_v -= lik * xk_v;
            }
        }
        for v in x.row_mut(i) {
            *v /= lii;
        }
    }
    x
}

/// Solve `Lᵀ X = B` with `L` lower triangular (backward substitution on
/// the transpose, without materializing it).
pub fn solve_lower_transpose(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows();
    assert_eq!(n, l.cols());
    assert_eq!(n, b.rows());
    let m = b.cols();
    let mut x = b.clone();
    for i in (0..n).rev() {
        let lii = l[(i, i)];
        debug_assert!(lii != 0.0, "singular triangular factor");
        for k in (i + 1)..n {
            let lki = l[(k, i)]; // (Lᵀ)[i,k]
            if lki == 0.0 {
                continue;
            }
            let (head, tail) = x.data_mut().split_at_mut(k * m);
            let xi = &mut head[i * m..(i + 1) * m];
            let xk = &tail[..m];
            for (xi_v, xk_v) in xi.iter_mut().zip(xk) {
                *xi_v -= lki * xk_v;
            }
        }
        for v in x.row_mut(i) {
            *v /= lii;
        }
    }
    x
}

/// Solve `U X = B` with `U` upper triangular.
pub fn solve_upper(u: &Mat, b: &Mat) -> Mat {
    let n = u.rows();
    assert_eq!(n, u.cols());
    assert_eq!(n, b.rows());
    let m = b.cols();
    let mut x = b.clone();
    for i in (0..n).rev() {
        let uii = u[(i, i)];
        debug_assert!(uii != 0.0, "singular triangular factor");
        for k in (i + 1)..n {
            let uik = u[(i, k)];
            if uik == 0.0 {
                continue;
            }
            let (head, tail) = x.data_mut().split_at_mut(k * m);
            let xi = &mut head[i * m..(i + 1) * m];
            let xk = &tail[..m];
            for (xi_v, xk_v) in xi.iter_mut().zip(xk) {
                *xi_v -= uik * xk_v;
            }
        }
        for v in x.row_mut(i) {
            *v /= uii;
        }
    }
    x
}
