//! Moore–Penrose pseudoinverse and pinv-apply helpers.
//!
//! The Fast GMR solve (Eqn. 3.3) is `(S_C C)† Ã (R S_Rᵀ)†`. We never
//! materialize a pseudoinverse on the hot path — `pinv_apply_left/right`
//! solve the associated least-squares problems via Cholesky on the Gram
//! matrix when well-conditioned, falling back to an SVD cutoff when not.
//! The SVD fallback runs on the round-robin parallel [`svd_jacobi`], so
//! even the ill-conditioned path shards over the pool.

use super::{cholesky_solve, matmul, matmul_a_bt, matmul_at_b, svd_jacobi, Mat, Svd};

/// Relative singular-value cutoff for the SVD fallback (LAPACK-style).
fn default_rcond(shape: (usize, usize)) -> f64 {
    let (m, n) = shape;
    m.max(n) as f64 * f64::EPSILON
}

/// Full pseudoinverse via SVD (baseline / test use; O(mn·min) + O(min³)).
pub fn pinv(a: &Mat) -> Mat {
    let Svd { u, s, v } = svd_jacobi(a);
    let cutoff = s.first().copied().unwrap_or(0.0) * default_rcond(a.shape());
    // A† = V diag(1/s) Uᵀ
    let k = s.len();
    let mut vs = v.clone(); // n x k scaled columns
    for j in 0..k {
        let inv = if s[j] > cutoff { 1.0 / s[j] } else { 0.0 };
        for i in 0..vs.rows() {
            vs[(i, j)] *= inv;
        }
    }
    matmul_a_bt(&vs, &u)
}

/// `C† B` for a tall full-column-rank-ish `C` (m×c, m ≥ c): solves the
/// normal equations `(CᵀC) X = Cᵀ B` by Cholesky; falls back to the SVD
/// pseudoinverse if the Gram matrix is numerically singular.
pub fn pinv_apply_left(c: &Mat, b: &Mat) -> Mat {
    assert_eq!(c.rows(), b.rows(), "pinv_apply_left: dim mismatch");
    let gram = matmul_at_b(c, c);
    let rhs = matmul_at_b(c, b);
    match cholesky_solve(&gram, &rhs) {
        Ok(x) => x,
        Err(_) => matmul(&pinv(c), b),
    }
}

/// `B R†` for a wide full-row-rank-ish `R` (r×n, n ≥ r): solves
/// `X (R Rᵀ) = B Rᵀ`, i.e. `(R Rᵀ) Xᵀ = R Bᵀ`, by Cholesky; SVD fallback.
pub fn pinv_apply_right(b: &Mat, r: &Mat) -> Mat {
    assert_eq!(b.cols(), r.cols(), "pinv_apply_right: dim mismatch");
    let gram = matmul_a_bt(r, r); // r x r
    let rhs = matmul_a_bt(b, r); // b.rows x r
    match cholesky_solve(&gram, &rhs.transpose()) {
        Ok(xt) => xt.transpose(),
        Err(_) => matmul(b, &pinv(r)),
    }
}
