//! Dense linear-algebra substrate, written from scratch (no BLAS/LAPACK in
//! the offline image). Everything the paper's algorithms need:
//!
//! * [`Mat`] — row-major dense matrix over `f64`.
//! * BLIS-style packed GEMM ([`matmul`] and the `Aᵀ·B` / `A·Bᵀ`
//!   variants): MR×NR register microkernel over panels packed into
//!   aligned thread-local scratch — see `matmul`'s module docs for the
//!   determinism contract,
//! * blocked compact-WY Householder QR ([`qr_thin`]) whose panel
//!   updates ride the matmul kernel and the `crate::parallel` pool,
//! * Cholesky + triangular solves ([`cholesky`], [`solve_upper`]),
//! * symmetric eigendecomposition via round-robin parallel Jacobi
//!   ([`eigh`]),
//! * full SVD via pool-parallel one-sided Jacobi ([`svd_jacobi`])
//!   and randomized top-k SVD via subspace iteration
//!   ([`svd_randomized`]),
//! * Moore–Penrose pseudoinverse ([`pinv`]),
//! * norms and projections ([`fro_norm`], [`project_psd`]).
//!
//! Conventions: all factorizations are "thin"/economy size; matrices are
//! row-major; row/column indices are zero-based.

mod chol;
mod eig;
mod jacobi;
mod mat;
mod matmul;
mod norms;
mod pinv;
mod qr;
mod solve;
mod svd;

pub use chol::{cholesky, cholesky_solve};
pub use eig::{eigh, project_psd, project_symmetric, EigH};
pub use mat::Mat;
pub use matmul::{matmul, matmul_acc, matmul_at_b, matmul_a_bt};
pub(crate) use matmul::{matmul_a_bt_panel, matmul_acc_panel, matmul_at_b_panel, matmul_serial};
pub use norms::{fro_norm, fro_norm_diff, spectral_norm_est};
pub use pinv::{pinv, pinv_apply_left, pinv_apply_right};
pub use qr::{qr_thin, QrThin};
pub use solve::{solve_lower, solve_lower_transpose, solve_upper};
pub use svd::{svd_jacobi, svd_randomized, Svd};

#[cfg(test)]
mod tests;
