//! Blocked matrix multiplication.
//!
//! Cache-blocked and written so LLVM auto-vectorizes the inner loops
//! (AVX-512 via `-C target-cpu=native` in `.cargo/config.toml`). Layout
//! is row-major throughout; the serial kernel packs nothing but iterates
//! i-k-j with 4-row A-blocking so each streamed B row is reused 4x.
//! Measured ~8.7–10.9 GFLOP/s f64 single-core on the dev container's
//! Xeon (vs ~3.5 before the perf pass); the optimization log lives in
//! EXPERIMENTS.md §Perf.
//!
//! Above `parallel::PAR_FLOP_MIN` the public entry points dispatch to
//! `crate::parallel`'s row-panel drivers, which run this same kernel on
//! disjoint row panels — one worker per panel, bitwise identical to the
//! serial path (row iterations are independent; per-row accumulation
//! order is unchanged).

use super::Mat;

/// Cache block sizes (L1-ish for the k panel, L2-ish for the i panel).
const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 512;

/// `C = A * B`.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul: inner dims mismatch {}x{} * {}x{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// `C = A * B` on the serial kernel regardless of the `threads` knob
/// (hot-loop callers that manage their own sharding).
pub(crate) fn matmul_serial(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul_serial: inner dims mismatch");
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_acc_panel(a.data(), b.data(), c.data_mut(), a.rows(), a.cols(), b.cols());
    c
}

/// `C += A * B` into a preallocated output (hot-path form, no alloc).
pub fn matmul_acc(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols(), b.rows(), "matmul_acc: inner dims mismatch");
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    if crate::parallel::matmul_should_shard(m, k, n) {
        crate::parallel::par_matmul_acc(&crate::parallel::Pool::current(), a, b, c);
        return;
    }
    matmul_acc_panel(a.data(), b.data(), c.data_mut(), m, k, n);
}

/// The serial blocked kernel on raw row-major slices: `C += A * B` for
/// an `m×k` panel of A and matching `m×n` panel of C. Callers (serial
/// dispatch above, row-panel workers in `crate::parallel`) pass panel
/// slices; the kernel itself never sees global row indices.
pub(crate) fn matmul_acc_panel(ad: &[f64], bd: &[f64], cd: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(ad.len(), m * k);
    debug_assert_eq!(bd.len(), k * n);
    debug_assert_eq!(cd.len(), m * n);
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                // Macro kernel on the (mb x kb) * (kb x nb) panel.
                // Rows of A are processed four at a time so each streamed
                // B row is reused 4x from registers/L1 (≈1.6x measured).
                let mut i = ic;
                while i + 4 <= ic + mb {
                    let (a0, a1, a2, a3) = (
                        &ad[i * k + pc..i * k + pc + kb],
                        &ad[(i + 1) * k + pc..(i + 1) * k + pc + kb],
                        &ad[(i + 2) * k + pc..(i + 2) * k + pc + kb],
                        &ad[(i + 3) * k + pc..(i + 3) * k + pc + kb],
                    );
                    for p in 0..kb {
                        let (v0, v1, v2, v3) = (a0[p], a1[p], a2[p], a3[p]);
                        if v0 == 0.0 && v1 == 0.0 && v2 == 0.0 && v3 == 0.0 {
                            continue;
                        }
                        let brow = &bd[(pc + p) * n + jc..(pc + p) * n + jc + nb];
                        // Split borrows: four disjoint C rows.
                        let (c01, c23) = cd[i * n..].split_at_mut(2 * n);
                        let (c0, c1) = c01.split_at_mut(n);
                        let (c2, c3) = c23.split_at_mut(n);
                        let c0 = &mut c0[jc..jc + nb];
                        let c1 = &mut c1[jc..jc + nb];
                        let c2 = &mut c2[jc..jc + nb];
                        let c3 = &mut c3[jc..jc + nb];
                        for t in 0..nb {
                            let bv = brow[t];
                            c0[t] += v0 * bv;
                            c1[t] += v1 * bv;
                            c2[t] += v2 * bv;
                            c3[t] += v3 * bv;
                        }
                    }
                    i += 4;
                }
                for i in i..ic + mb {
                    let arow = &ad[i * k + pc..i * k + pc + kb];
                    let crow = &mut cd[i * n + jc..i * n + jc + nb];
                    for (p, &aval) in arow.iter().enumerate() {
                        if aval == 0.0 {
                            continue;
                        }
                        let brow = &bd[(pc + p) * n + jc..(pc + p) * n + jc + nb];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += aval * bv;
                        }
                    }
                }
            }
        }
    }
}

/// Overwriting variant used by `matmul`.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    c.data_mut().fill(0.0);
    matmul_acc(a, b, c);
}

/// `C = Aᵀ * B` without materializing the transpose.
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b: dims mismatch");
    let (m, k, n) = (a.cols(), a.rows(), b.cols());
    if crate::parallel::matmul_should_shard(m, k, n) {
        return crate::parallel::par_matmul_at_b(a, b);
    }
    let mut c = Mat::zeros(m, n);
    matmul_at_b_panel(a, b, 0, m, c.data_mut());
    c
}

/// Serial `Aᵀ · B` scatter kernel over the output-row panel `c0..c1`
/// (columns `c0..c1` of A), writing the panel-local `(c1-c0)×b.cols()`
/// slice. Row `p` of A contributes in ascending `p` order regardless of
/// the panel bounds, so a sharded run accumulates every output row in
/// exactly the serial order (bitwise equal for any shard count).
pub(crate) fn matmul_at_b_panel(a: &Mat, b: &Mat, c0: usize, c1: usize, cd: &mut [f64]) {
    let (m, k, n) = (a.cols(), a.rows(), b.cols());
    debug_assert_eq!(cd.len(), (c1 - c0) * n);
    let (ad, bd) = (a.data(), b.data());
    // aᵀ(i, p) = a(p, i): iterate p (rows of A/B), scatter into C rows.
    for p in 0..k {
        let arow = &ad[p * m + c0..p * m + c1];
        let brow = &bd[p * n..(p + 1) * n];
        for (i, &aval) in arow.iter().enumerate() {
            if aval == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aval * bv;
            }
        }
    }
}

/// `C = A * Bᵀ` without materializing the transpose.
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt: dims mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    if crate::parallel::matmul_should_shard(m, k, n) {
        return crate::parallel::par_matmul_a_bt(a, b);
    }
    let mut c = Mat::zeros(m, n);
    matmul_a_bt_panel(a, b, 0, m, c.data_mut());
    c
}

/// Serial `A · Bᵀ` kernel over the row panel `r0..r1` of A, writing the
/// matching panel of C into `cd` (panel-local, `(r1-r0)×b.rows()`).
pub(crate) fn matmul_a_bt_panel(a: &Mat, b: &Mat, r0: usize, r1: usize, cd: &mut [f64]) {
    let n = b.rows();
    debug_assert_eq!(cd.len(), (r1 - r0) * n);
    for i in r0..r1 {
        let arow = a.row(i);
        let crow = &mut cd[(i - r0) * n..(i - r0 + 1) * n];
        // Four B rows per pass: the A row streams from L1 once per four
        // dot products, and the four accumulators break the reduction
        // dependency chain so the loop vectorizes with multiple FMAs.
        let mut j = 0;
        while j + 4 <= n {
            let (b0, b1, b2, b3) = (b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3));
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
            for t in 0..arow.len() {
                let x = arow[t];
                s0 += x * b0[t];
                s1 += x * b1[t];
                s2 += x * b2[t];
                s3 += x * b3[t];
            }
            crow[j] = s0;
            crow[j + 1] = s1;
            crow[j + 2] = s2;
            crow[j + 3] = s3;
            j += 4;
        }
        for j in j..n {
            let brow = b.row(j);
            let mut acc = 0.0;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            crow[j] = acc;
        }
    }
}
