//! BLIS-style packed GEMM — the dense multiply layer under every hot
//! path in the reproduction (sketch products `S_C·A` / `A·S_Rᵀ`,
//! compact-WY trailing updates, CUR cores, SPSD approximation, streaming
//! SVD folds).
//!
//! Layout is row-major throughout. The classic five-loop BLIS structure
//! (Van Zee & van de Geijn) drives everything:
//!
//! * **packing** — per `KC`-deep panel, A blocks are repacked into
//!   `MR`-row strips (strip-major, `MR` consecutive values per k step)
//!   and B blocks into `NR`-column strips, both into 64-byte-aligned
//!   thread-local scratch (`mat::AlignedBuf`, reused across
//!   calls — no per-call allocation) so the microkernel streams both
//!   operands contiguously with zero index arithmetic;
//! * **microkernel** — an `MR×NR` register tile of f64 accumulators
//!   (fixed-size arrays; 8×8 when the build has AVX-512, 4×8 otherwise
//!   so the tile fits the 16 ymm registers of `x86-64-v2`) that LLVM
//!   keeps entirely in vector registers under the `-C target-cpu` flags
//!   from `.cargo/config.toml`; edge tiles are zero-padded at pack time
//!   so the one microkernel serves every geometry;
//! * **cache blocking** — `MC×KC` A blocks (~L2) and `KC×NC` B blocks
//!   (~L3), C written once per `KC` panel instead of once per k step.
//!
//! Determinism contract (what the threads=1-vs-N bitwise suite in
//! `crate::parallel::tests` pins): each output element accumulates its
//! `k` products in **ascending k order** — a register-tile partial sum
//! per `KC` block, blocks added to C in ascending block order — and that
//! per-element chain depends only on `k`, never on which row panel,
//! strip, or worker computed it. Row-sharded runs are therefore bitwise
//! identical to serial ones at any thread count (validated against a
//! transliterated reference during development, enforced by tests).
//! Products are deliberately *not* fused (`mul_add`): FMA contraction
//! would change results between hosts with and without the instruction,
//! and the win here is packing + register tiling, not fusion.
//!
//! For small single-`KC`-block products (`k ≤ KC`) the per-element chain
//! is *exactly* the naive ascending-k triple loop, which
//! `linalg::tests` asserts bitwise. Measured numbers live in
//! EXPERIMENTS.md §Perf; `bench fig_gemm` tracks packed-vs-seed GFLOP/s
//! per PR with the pre-pack kernels frozen bench-local.
//!
//! Above `parallel::PAR_FLOP_MIN` the public entry points dispatch to
//! `crate::parallel`'s row-panel drivers, which run this same packed
//! macro-kernel on disjoint row panels — one worker per panel, each
//! packing its own strips into its own thread-local workspace.

use super::mat::AlignedBuf;
use super::Mat;
use std::cell::RefCell;

/// Microkernel rows: 8 keeps the accumulator tile in 8 zmm registers on
/// AVX-512 builds; 4 keeps it in 8 ymm registers (of 16) on `x86-64-v2`
/// CI builds, leaving room for the B row and broadcasts.
pub(crate) const MR: usize = if cfg!(target_feature = "avx512f") { 8 } else { 4 };
/// Microkernel columns: one 8-wide f64 AVX-512 vector (two ymm on AVX2).
pub(crate) const NR: usize = 8;
/// Cache blocks: `MC×KC` f64 A panel ≈ 256 KB (L2-resident),
/// `KC×NC` B panel ≈ 1 MB (L3-resident). `MC % MR == 0`, `NC % NR == 0`
/// so only the final strip of a block ever pads.
const MC: usize = 128;
pub(crate) const KC: usize = 256;
const NC: usize = 512;

/// Per-thread packing workspace. Long-lived threads (the main thread,
/// router executors, pipeline workers) pay the two scratch allocations
/// once and reuse them for every subsequent product; scoped pool workers
/// allocate once per parallel region and amortize over their panels.
struct Workspace {
    a: AlignedBuf,
    b: AlignedBuf,
}

thread_local! {
    static WORKSPACE: RefCell<Workspace> =
        const { RefCell::new(Workspace { a: AlignedBuf::new(), b: AlignedBuf::new() }) };
}

/// `C = A * B`.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul: inner dims mismatch {}x{} * {}x{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// `C = A * B` on the serial kernel regardless of the `threads` knob
/// (hot-loop callers that manage their own sharding).
pub(crate) fn matmul_serial(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul_serial: inner dims mismatch");
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_acc_panel(a.data(), b.data(), c.data_mut(), a.rows(), a.cols(), b.cols());
    c
}

/// `C += A * B` into a preallocated output (hot-path form, no alloc).
pub fn matmul_acc(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols(), b.rows(), "matmul_acc: inner dims mismatch");
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    if crate::parallel::matmul_should_shard(m, k, n) {
        crate::parallel::par_matmul_acc(&crate::parallel::Pool::current(), a, b, c);
        return;
    }
    matmul_acc_panel(a.data(), b.data(), c.data_mut(), m, k, n);
}

/// The serial packed kernel on raw row-major slices: `C += A * B` for an
/// `m×k` panel of A and matching `m×n` panel of C. Callers (serial
/// dispatch above, row-panel workers in `crate::parallel`) pass panel
/// slices; the kernel itself never sees global row indices.
pub(crate) fn matmul_acc_panel(
    ad: &[f64],
    bd: &[f64],
    cd: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(ad.len(), m * k);
    debug_assert_eq!(bd.len(), k * n);
    debug_assert_eq!(cd.len(), m * n);
    gemm_packed(
        m,
        n,
        k,
        cd,
        |i0, mb, p0, kb, buf| pack_a_rows(ad, k, i0, mb, p0, kb, buf),
        |p0, kb, j0, nb, buf| pack_b_rows(bd, n, p0, kb, j0, nb, buf),
    );
}

/// Overwriting variant used by `matmul`.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    c.data_mut().fill(0.0);
    matmul_acc(a, b, c);
}

/// `C = Aᵀ * B` without materializing the transpose.
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b: dims mismatch");
    let (m, k, n) = (a.cols(), a.rows(), b.cols());
    if crate::parallel::matmul_should_shard(m, k, n) {
        return crate::parallel::par_matmul_at_b(a, b);
    }
    let mut c = Mat::zeros(m, n);
    matmul_at_b_panel(a, b, 0, m, c.data_mut());
    c
}

/// Packed `Aᵀ · B` kernel over the output-row panel `c0..c1` (columns
/// `c0..c1` of A), accumulating into the panel-local `(c1-c0)×b.cols()`
/// slice (callers pass zeroed panels). The A-pack reads `A(p, c0+i)` —
/// contiguous per k step in row-major A — and every output element's
/// k-chain is independent of the panel bounds, so a sharded run is
/// bitwise equal to the serial one for any shard count.
pub(crate) fn matmul_at_b_panel(a: &Mat, b: &Mat, c0: usize, c1: usize, cd: &mut [f64]) {
    let (k, n) = (a.rows(), b.cols());
    debug_assert_eq!(cd.len(), (c1 - c0) * n);
    let (ad, bd, lda) = (a.data(), b.data(), a.cols());
    gemm_packed(
        c1 - c0,
        n,
        k,
        cd,
        |i0, mb, p0, kb, buf| pack_a_cols(ad, lda, c0 + i0, mb, p0, kb, buf),
        |p0, kb, j0, nb, buf| pack_b_rows(bd, n, p0, kb, j0, nb, buf),
    );
}

/// `C = A * Bᵀ` without materializing the transpose.
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt: dims mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    if crate::parallel::matmul_should_shard(m, k, n) {
        return crate::parallel::par_matmul_a_bt(a, b);
    }
    let mut c = Mat::zeros(m, n);
    matmul_a_bt_panel(a, b, 0, m, c.data_mut());
    c
}

/// Packed `A · Bᵀ` kernel over the row panel `r0..r1` of A, accumulating
/// into the matching panel of C (panel-local, `(r1-r0)×b.rows()`;
/// callers pass zeroed panels). The B-pack reads `B(j, p)` column walks —
/// the per-element k-chain again never depends on the panel bounds.
pub(crate) fn matmul_a_bt_panel(a: &Mat, b: &Mat, r0: usize, r1: usize, cd: &mut [f64]) {
    let (k, n) = (a.cols(), b.rows());
    debug_assert_eq!(cd.len(), (r1 - r0) * n);
    let (ad, bd) = (&a.data()[r0 * k..], b.data());
    gemm_packed(
        r1 - r0,
        n,
        k,
        cd,
        |i0, mb, p0, kb, buf| pack_a_rows(ad, k, i0, mb, p0, kb, buf),
        |p0, kb, j0, nb, buf| pack_b_cols(bd, k, p0, kb, j0, nb, buf),
    );
}

/// The five-loop packed driver: `C += op_A · op_B` where the operand
/// views are defined entirely by the two packing closures.
///
/// `pack_a(i0, mb, p0, kb, buf)` must fill `buf` with the `mb×kb` block
/// of the (possibly transposed) A view at row `i0`, k offset `p0`, as
/// `MR`-row strips (strip-major; within a strip, `MR` consecutive values
/// per k step, zero-padded rows past `mb`). `pack_b(p0, kb, j0, nb,
/// buf)` likewise packs the `kb×nb` B block as `NR`-column strips.
///
/// Loop order is `jc → pc → ic` (B panel reused across the ic loop), so
/// for every output element the `pc` blocks arrive in ascending order —
/// the determinism contract in the module header.
fn gemm_packed(
    m: usize,
    n: usize,
    k: usize,
    cd: &mut [f64],
    pack_a: impl Fn(usize, usize, usize, usize, &mut [f64]),
    pack_b: impl Fn(usize, usize, usize, usize, &mut [f64]),
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    debug_assert_eq!(cd.len(), m * n);
    WORKSPACE.with(|ws| {
        let mut ws = ws.borrow_mut();
        let Workspace { a, b } = &mut *ws;
        let kc = KC.min(k);
        let abuf = a.ensure(MC.min(m).div_ceil(MR) * MR * kc);
        let bbuf = b.ensure(NC.min(n).div_ceil(NR) * NR * kc);
        for jc in (0..n).step_by(NC) {
            let nb = NC.min(n - jc);
            for pc in (0..k).step_by(KC) {
                let kb = KC.min(k - pc);
                pack_b(pc, kb, jc, nb, &mut *bbuf);
                for ic in (0..m).step_by(MC) {
                    let mb = MC.min(m - ic);
                    pack_a(ic, mb, pc, kb, &mut *abuf);
                    macro_kernel(abuf, bbuf, &mut cd[ic * n + jc..], n, mb, nb, kb);
                }
            }
        }
    });
}

/// Sweep the packed block with the register microkernel: `NR` strips of
/// B against `MR` strips of A, each tile's partial sum added to C once.
/// `cd` is the output slice starting at the block's top-left element,
/// with row stride `ldc`.
fn macro_kernel(
    abuf: &[f64],
    bbuf: &[f64],
    cd: &mut [f64],
    ldc: usize,
    mb: usize,
    nb: usize,
    kb: usize,
) {
    let mut j0 = 0;
    while j0 < nb {
        let nr = NR.min(nb - j0);
        let bp = &bbuf[(j0 / NR) * kb * NR..];
        let mut i0 = 0;
        while i0 < mb {
            let mr = MR.min(mb - i0);
            let ap = &abuf[(i0 / MR) * kb * MR..];
            let acc = micro_tile(kb, ap, bp);
            for (i, arow) in acc.iter().enumerate().take(mr) {
                let off = (i0 + i) * ldc + j0;
                for (cx, &v) in cd[off..off + nr].iter_mut().zip(&arow[..nr]) {
                    *cx += v;
                }
            }
            i0 += MR;
        }
        j0 += NR;
    }
}

/// The `MR×NR` register tile: `acc[i][j] = Σ_p ap[p][i] · bp[p][j]` in
/// ascending `p` order. Both operands stream contiguously from their
/// packed strips; the fixed-size accumulator array is what lets LLVM
/// keep the whole tile in vector registers.
#[inline(always)]
fn micro_tile(kb: usize, ap: &[f64], bp: &[f64]) -> [[f64; NR]; MR] {
    let mut acc = [[0.0f64; NR]; MR];
    for (av, bv) in ap[..kb * MR].chunks_exact(MR).zip(bp[..kb * NR].chunks_exact(NR)) {
        for (arow, &a) in acc.iter_mut().zip(av) {
            for (cx, &b) in arow.iter_mut().zip(bv) {
                *cx += a * b;
            }
        }
    }
    acc
}

/// Pack the `mb×kb` block of a row-major `lda`-stride matrix view
/// (rows `i0..`, k offset `p0..`) into `MR`-row strips. Each source row
/// is read once, contiguously; lanes past `mb` in the final strip are
/// zeroed so the microkernel needs no edge cases.
fn pack_a_rows(
    ad: &[f64],
    lda: usize,
    i0: usize,
    mb: usize,
    p0: usize,
    kb: usize,
    buf: &mut [f64],
) {
    let mut off = 0;
    let mut s = 0;
    while s < mb {
        let mr = MR.min(mb - s);
        for ii in 0..mr {
            let base = (i0 + s + ii) * lda + p0;
            for (p, &x) in ad[base..base + kb].iter().enumerate() {
                buf[off + p * MR + ii] = x;
            }
        }
        for ii in mr..MR {
            for p in 0..kb {
                buf[off + p * MR + ii] = 0.0;
            }
        }
        off += kb * MR;
        s += MR;
    }
}

/// Pack the transposed view `A'(i, p) = A(p, c0+i)` of a row-major
/// `lda`-stride matrix into `MR`-row strips — the `Aᵀ·B` operand. Both
/// the read (a row segment of A per k step) and the write are
/// contiguous.
fn pack_a_cols(
    ad: &[f64],
    lda: usize,
    c0: usize,
    mb: usize,
    p0: usize,
    kb: usize,
    buf: &mut [f64],
) {
    let mut off = 0;
    let mut s = 0;
    while s < mb {
        let mr = MR.min(mb - s);
        for p in 0..kb {
            let base = (p0 + p) * lda + c0 + s;
            let dst = &mut buf[off + p * MR..off + (p + 1) * MR];
            dst[..mr].copy_from_slice(&ad[base..base + mr]);
            dst[mr..].fill(0.0);
        }
        off += kb * MR;
        s += MR;
    }
}

/// Pack the `kb×nb` block of row-major B (k offset `p0..`, columns
/// `j0..`) into `NR`-column strips; row segments copy contiguously.
fn pack_b_rows(
    bd: &[f64],
    ldb: usize,
    p0: usize,
    kb: usize,
    j0: usize,
    nb: usize,
    buf: &mut [f64],
) {
    let mut off = 0;
    let mut s = 0;
    while s < nb {
        let nr = NR.min(nb - s);
        for p in 0..kb {
            let base = (p0 + p) * ldb + j0 + s;
            let dst = &mut buf[off + p * NR..off + (p + 1) * NR];
            dst[..nr].copy_from_slice(&bd[base..base + nr]);
            dst[nr..].fill(0.0);
        }
        off += kb * NR;
        s += NR;
    }
}

/// Pack the transposed view `B'(p, j) = B(j0+j, p)` of row-major B
/// (shape `n×k`, stride `ldb = k`) into `NR`-column strips — the `A·Bᵀ`
/// operand. Each source row (a column of the view) is read once,
/// contiguously.
fn pack_b_cols(
    bd: &[f64],
    ldb: usize,
    p0: usize,
    kb: usize,
    j0: usize,
    nb: usize,
    buf: &mut [f64],
) {
    let mut off = 0;
    let mut s = 0;
    while s < nb {
        let nr = NR.min(nb - s);
        for jj in 0..nr {
            let base = (j0 + s + jj) * ldb + p0;
            for (p, &x) in bd[base..base + kb].iter().enumerate() {
                buf[off + p * NR + jj] = x;
            }
        }
        for jj in nr..NR {
            for p in 0..kb {
                buf[off + p * NR + jj] = 0.0;
            }
        }
        off += kb * NR;
        s += NR;
    }
}
