//! Unit and property tests for the linalg substrate.

use super::*;
use crate::rng::rng;
use crate::testing::{assert_close, prop_mats, MAT_DIM_SMALL};

fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut s = 0.0;
            for k in 0..a.cols() {
                s += a[(i, k)] * b[(k, j)];
            }
            c[(i, j)] = s;
        }
    }
    c
}

#[test]
fn matmul_matches_naive() {
    let mut r = rng(1);
    for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 31, 13), (64, 64, 64), (65, 129, 67)] {
        let a = Mat::randn(m, k, &mut r);
        let b = Mat::randn(k, n, &mut r);
        let got = matmul(&a, &b);
        let want = naive_matmul(&a, &b);
        assert_close(&got, &want, 1e-10, &format!("matmul {m}x{k}x{n}"));
    }
}

/// Single-`KC`-block products (`k ≤ KC`): the packed kernel's
/// per-element chain — one register-tile partial sum, added to a zeroed
/// C — is exactly the naive ascending-k triple loop, so the outputs must
/// be **bitwise** identical. Sizes cover m/n/k below the MR×NR register
/// tile, 1×1, primes straddling the pack-panel boundaries (33/65/127),
/// an MC straddle (129 rows), an NC straddle (513 cols), and k exactly
/// at the KC boundary.
#[test]
fn packed_gemm_bitwise_matches_naive_single_block() {
    let mut r = rng(21);
    for &(m, k, n) in &[
        (1, 1, 1),
        (3, 2, 5),
        (7, 7, 7),
        (8, 8, 8),
        (9, 9, 9),
        (33, 65, 127),
        (65, 33, 64),
        (127, 127, 33),
        (129, 16, 9),
        (8, 40, 513),
        (130, 256, 130),
    ] {
        assert!(k <= super::matmul::KC, "exact-equality sizes must stay single-KC-block");
        let a = Mat::randn(m, k, &mut r);
        let b = Mat::randn(k, n, &mut r);
        let got = matmul(&a, &b);
        let want = naive_matmul(&a, &b);
        assert_eq!(got.data(), want.data(), "packed vs naive not bitwise at {m}x{k}x{n}");
    }
}

/// The transposed-operand entry points share the exact-chain property on
/// single-block sizes: `Aᵀ·B` and `A·Bᵀ` must be bitwise equal to the
/// naive triple loop over the materialized transpose.
#[test]
fn packed_at_b_and_a_bt_bitwise_match_naive_single_block() {
    let mut r = rng(22);
    for &(k, m, n) in &[(1, 1, 1), (9, 5, 7), (83, 53, 31), (129, 33, 65)] {
        let a = Mat::randn(k, m, &mut r);
        let b = Mat::randn(k, n, &mut r);
        let got = matmul_at_b(&a, &b);
        let want = naive_matmul(&a.transpose(), &b);
        assert_eq!(got.data(), want.data(), "at_b vs naive not bitwise at k={k} {m}x{n}");
    }
    for &(m, k, n) in &[(1, 1, 1), (9, 5, 7), (61, 40, 29), (65, 127, 33)] {
        let a = Mat::randn(m, k, &mut r);
        let b = Mat::randn(n, k, &mut r);
        let got = matmul_a_bt(&a, &b);
        let want = naive_matmul(&a, &b.transpose());
        assert_eq!(got.data(), want.data(), "a_bt vs naive not bitwise at {m}x{k}x{n}");
    }
}

/// Above `KC` each element's chain groups into per-block partial sums —
/// no longer the naive chain bitwise, but within 1e-12 relative. (The
/// bitwise properties that *are* promised across k blocks — serial vs
/// sharded, repeat runs — live in `crate::parallel::tests`.)
#[test]
fn packed_gemm_multi_block_close_to_naive() {
    let mut r = rng(23);
    let (m, k, n) = (7, 2 * super::matmul::KC + 37, 9);
    let a = Mat::randn(m, k, &mut r);
    let b = Mat::randn(k, n, &mut r);
    assert_close(&matmul(&a, &b), &naive_matmul(&a, &b), 1e-12, "multi-KC-block gemm");
}

/// Degenerate shapes: empty inner or outer dimensions produce the
/// correctly shaped all-zero output without touching the workspace.
#[test]
fn packed_gemm_degenerate_dims() {
    let c = matmul(&Mat::zeros(4, 0), &Mat::zeros(0, 3));
    assert_eq!(c.shape(), (4, 3));
    assert!(c.data().iter().all(|&v| v == 0.0), "k=0 product must be zero");
    assert_eq!(matmul(&Mat::zeros(0, 5), &Mat::zeros(5, 0)).shape(), (0, 0));
    let c2 = matmul(&Mat::zeros(5, 0), &Mat::zeros(0, 5));
    assert_eq!(c2.shape(), (5, 5));
    assert!(c2.data().iter().all(|&v| v == 0.0));
}

#[test]
fn matmul_at_b_matches_transpose() {
    let mut r = rng(2);
    let a = Mat::randn(23, 11, &mut r);
    let b = Mat::randn(23, 17, &mut r);
    let got = matmul_at_b(&a, &b);
    let want = matmul(&a.transpose(), &b);
    assert_close(&got, &want, 1e-10, "matmul_at_b");
}

#[test]
fn matmul_a_bt_matches_transpose() {
    let mut r = rng(3);
    let a = Mat::randn(9, 21, &mut r);
    let b = Mat::randn(14, 21, &mut r);
    let got = matmul_a_bt(&a, &b);
    let want = matmul(&a, &b.transpose());
    assert_close(&got, &want, 1e-10, "matmul_a_bt");
}

#[test]
fn prop_matmul_associates_with_identity() {
    prop_mats(10, MAT_DIM_SMALL, |a, r| {
        let i = Mat::eye(a.cols());
        assert_close(&matmul(a, &i), a, 1e-12, "A*I = A");
        let i2 = Mat::eye(a.rows());
        assert_close(&matmul(&i2, a), a, 1e-12, "I*A = A");
        let _ = r;
    });
}

#[test]
fn qr_reconstructs_and_is_orthonormal() {
    let mut r = rng(4);
    // Includes the compact-WY panel boundaries (PANEL = 32): one column
    // short of a panel, exactly one/two panels, one column past.
    for &(m, n) in &[
        (5, 3),
        (30, 7),
        (12, 12),
        (64, 20),
        (33, 31),
        (100, 32),
        (97, 33),
        (64, 64),
        (130, 65),
        (65, 65),
    ] {
        let a = Mat::randn(m, n, &mut r);
        let QrThin { q, r: rr } = qr_thin(&a);
        assert_eq!(q.shape(), (m, n.min(m)));
        // QᵀQ = I
        let qtq = matmul_at_b(&q, &q);
        assert_close(&qtq, &Mat::eye(n.min(m)), 1e-10, "QᵀQ = I");
        // A = QR
        let qr = matmul(&q, &rr);
        assert_close(&qr, &a, 1e-9, "A = QR");
        // R upper triangular
        for i in 0..rr.rows() {
            for j in 0..i.min(rr.cols()) {
                assert!(rr[(i, j)].abs() < 1e-12, "R not upper triangular");
            }
        }
    }
}

#[test]
fn qr_wide_matrix() {
    let mut r = rng(5);
    // Wide shapes, again straddling the panel width (k = m here).
    for &(m, n) in &[(4, 9), (32, 65), (33, 100), (65, 129)] {
        let a = Mat::randn(m, n, &mut r);
        let QrThin { q, r: rr } = qr_thin(&a);
        assert_eq!(q.shape(), (m, m));
        assert_eq!(rr.shape(), (m, n));
        assert_close(&matmul(&q, &rr), &a, 1e-9, &format!("wide {m}x{n} A = QR"));
        let qtq = matmul_at_b(&q, &q);
        assert_close(&qtq, &Mat::eye(m), 1e-10, &format!("wide {m}x{n} QᵀQ = I"));
    }
}

/// Rank-deficient input: duplicate and zero columns exercise the
/// zero-reflector (beta = 0) path inside a panel.
#[test]
fn qr_rank_deficient_columns() {
    let mut r = rng(45);
    let base = Mat::randn(40, 3, &mut r);
    let mut a = Mat::zeros(40, 7);
    for i in 0..40 {
        a[(i, 0)] = base[(i, 0)];
        a[(i, 1)] = base[(i, 1)];
        a[(i, 2)] = base[(i, 0)]; // duplicate of col 0
        // col 3 stays zero
        a[(i, 4)] = base[(i, 2)];
        a[(i, 5)] = 2.0 * base[(i, 1)]; // multiple of col 1
        a[(i, 6)] = base[(i, 0)] + base[(i, 2)];
    }
    let QrThin { q, r: rr } = qr_thin(&a);
    assert_close(&matmul(&q, &rr), &a, 1e-9, "rank-deficient A = QR");
    for i in 0..rr.rows() {
        for j in 0..i.min(rr.cols()) {
            assert!(rr[(i, j)].abs() < 1e-9, "R not upper triangular");
        }
    }
}

/// The ring (round-robin) schedule behind the parallel Jacobi kernels:
/// rounds partition each sweep into disjoint pairs, and together they
/// cover every unordered pair exactly once.
#[test]
fn ring_rounds_cover_all_pairs_disjointly() {
    for n in [0usize, 1, 2, 3, 4, 5, 8, 13, 33, 64] {
        let rounds = super::jacobi::ring_rounds(n);
        let mut seen = std::collections::HashSet::new();
        for round in &rounds {
            let mut used = std::collections::HashSet::new();
            for &(p, q) in round {
                assert!(p < q && q < n, "bad pair ({p},{q}) for n={n}");
                assert!(used.insert(p) && used.insert(q), "round reuses an index (n={n})");
                assert!(seen.insert((p, q)), "pair ({p},{q}) repeated (n={n})");
            }
        }
        assert_eq!(seen.len(), n * n.saturating_sub(1) / 2, "pair coverage for n={n}");
    }
}

#[test]
fn cholesky_roundtrip() {
    let mut r = rng(6);
    let b = Mat::randn(20, 12, &mut r);
    let a = matmul_at_b(&b, &b); // SPD (almost surely)
    let l = cholesky(&a).expect("SPD");
    let llt = matmul_a_bt(&l, &l);
    assert_close(&llt, &a, 1e-9, "A = LLᵀ");
}

#[test]
fn cholesky_rejects_indefinite() {
    let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
    assert!(cholesky(&a).is_err());
}

#[test]
fn cholesky_solve_solves() {
    let mut r = rng(7);
    let b = Mat::randn(15, 15, &mut r);
    let a = {
        let mut g = matmul_at_b(&b, &b);
        for i in 0..15 {
            g[(i, i)] += 1.0;
        }
        g
    };
    let x_true = Mat::randn(15, 4, &mut r);
    let rhs = matmul(&a, &x_true);
    let x = cholesky_solve(&a, &rhs).unwrap();
    assert_close(&x, &x_true, 1e-8, "cholesky_solve");
}

#[test]
fn triangular_solves() {
    let mut r = rng(8);
    let mut l = Mat::randn(10, 10, &mut r);
    for i in 0..10 {
        for j in (i + 1)..10 {
            l[(i, j)] = 0.0;
        }
        l[(i, i)] = l[(i, i)].abs() + 1.0;
    }
    let x_true = Mat::randn(10, 3, &mut r);
    let b = matmul(&l, &x_true);
    assert_close(&solve_lower(&l, &b), &x_true, 1e-10, "solve_lower");

    let bt = matmul(&l.transpose(), &x_true);
    assert_close(&solve_lower_transpose(&l, &bt), &x_true, 1e-10, "solve_lower_transpose");

    let u = l.transpose();
    let bu = matmul(&u, &x_true);
    assert_close(&solve_upper(&u, &bu), &x_true, 1e-10, "solve_upper");
}

#[test]
fn eigh_reconstructs() {
    let mut r = rng(9);
    // Odd sizes exercise the ring schedule's bye index; 33/65 straddle
    // the pool-sharding chunk boundaries.
    for &n in &[1usize, 2, 5, 18, 33, 65] {
        let b = Mat::randn(n, n, &mut r);
        let a = &b + &b.transpose();
        let EigH { values, vectors } = eigh(&a);
        // Descending order.
        for w in values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        // V diag(w) Vᵀ = A
        let mut vd = vectors.clone();
        for j in 0..n {
            for i in 0..n {
                vd[(i, j)] *= values[j];
            }
        }
        let rec = matmul_a_bt(&vd, &vectors);
        assert_close(&rec, &a, 1e-7, &format!("eigh reconstruction n={n}"));
        // VᵀV = I
        assert_close(
            &matmul_at_b(&vectors, &vectors),
            &Mat::eye(n),
            1e-9,
            &format!("VᵀV = I n={n}"),
        );
    }
}

#[test]
fn project_psd_properties() {
    let mut r = rng(10);
    let x = Mat::randn(12, 12, &mut r);
    let p = project_psd(&x);
    // Symmetric.
    assert_close(&p, &p.transpose(), 1e-12, "PSD projection symmetric");
    // PSD: all eigenvalues >= -tol.
    let e = eigh(&p);
    assert!(e.values.iter().all(|&w| w > -1e-9), "projection not PSD: {:?}", e.values);
    // Idempotent.
    let p2 = project_psd(&p);
    assert_close(&p2, &p, 1e-8, "PSD projection idempotent");
    // Proposition 1: projecting an SPD matrix is a no-op.
    let b = Mat::randn(12, 12, &mut r);
    let spd = matmul_a_bt(&b, &b);
    assert_close(&project_psd(&spd), &spd, 1e-8, "PSD fixed point");
}

#[test]
fn svd_jacobi_reconstructs() {
    let mut r = rng(11);
    // Tall, wide, square, and ring-schedule boundary sizes (odd n, and
    // 33/64/65 around the panel/chunk widths).
    for &(m, n) in &[(10, 6), (6, 10), (15, 15), (65, 33), (33, 65), (64, 64), (40, 1)] {
        let a = Mat::randn(m, n, &mut r);
        let Svd { u, s, v } = svd_jacobi(&a);
        // Descending singular values, nonnegative.
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(s.iter().all(|&x| x >= 0.0));
        // U diag(s) Vᵀ = A
        let mut us = u.clone();
        for j in 0..s.len().min(us.cols()) {
            for i in 0..us.rows() {
                us[(i, j)] *= s[j];
            }
        }
        let rec = matmul_a_bt(&us, &v);
        assert_close(&rec, &a, 1e-8, &format!("svd reconstruction {m}x{n}"));
        // Orthonormal factors on the thin side.
        let k = m.min(n);
        let ut_u = matmul_at_b(&u, &u);
        let vt_v = matmul_at_b(&v, &v);
        if m >= n {
            assert_close(&ut_u, &Mat::eye(k), 1e-9, &format!("UᵀU = I {m}x{n}"));
            assert_close(&vt_v, &Mat::eye(n), 1e-9, &format!("VᵀV = I {m}x{n}"));
        } else {
            assert_close(&vt_v.slice(0, k, 0, k), &Mat::eye(k), 1e-9, &format!("VᵀV {m}x{n}"));
        }
    }
}

#[test]
fn svd_randomized_captures_top_k() {
    let mut r = rng(12);
    // Construct a matrix with known spectrum.
    let m = 80;
    let n = 60;
    let k = 5;
    let u = qr_thin(&Mat::randn(m, n, &mut r)).q;
    let v = qr_thin(&Mat::randn(n, n, &mut r)).q;
    let s_true: Vec<f64> = (0..n).map(|i| 100.0 * 0.5f64.powi(i as i32)).collect();
    let mut us = u.clone();
    for j in 0..n {
        for i in 0..m {
            us[(i, j)] *= s_true[j];
        }
    }
    let a = matmul_a_bt(&us, &v);
    let svd = svd_randomized(&a, k, 10, 4, &mut r);
    for i in 0..k {
        let rel = (svd.s[i] - s_true[i]).abs() / s_true[i];
        assert!(rel < 1e-6, "sigma_{i}: got {} want {}", svd.s[i], s_true[i]);
    }
}

#[test]
fn pinv_moore_penrose_axioms() {
    let mut r = rng(13);
    for &(m, n) in &[(12, 5), (5, 12), (8, 8)] {
        let a = Mat::randn(m, n, &mut r);
        let p = pinv(&a);
        assert_eq!(p.shape(), (n, m));
        let apa = matmul(&matmul(&a, &p), &a);
        assert_close(&apa, &a, 1e-8, "A A† A = A");
        let pap = matmul(&matmul(&p, &a), &p);
        assert_close(&pap, &p, 1e-8, "A† A A† = A†");
        let ap = matmul(&a, &p);
        assert_close(&ap, &ap.transpose(), 1e-8, "(A A†)ᵀ = A A†");
        let pa = matmul(&p, &a);
        assert_close(&pa, &pa.transpose(), 1e-8, "(A† A)ᵀ = A† A");
    }
}

#[test]
fn pinv_apply_matches_pinv() {
    let mut r = rng(14);
    let c = Mat::randn(40, 7, &mut r); // tall
    let b = Mat::randn(40, 9, &mut r);
    let got = pinv_apply_left(&c, &b);
    let want = matmul(&pinv(&c), &b);
    assert_close(&got, &want, 1e-8, "pinv_apply_left");

    let rr = Mat::randn(6, 30, &mut r); // wide
    let b2 = Mat::randn(9, 30, &mut r);
    let got2 = pinv_apply_right(&b2, &rr);
    let want2 = matmul(&b2, &pinv(&rr));
    assert_close(&got2, &want2, 1e-8, "pinv_apply_right");
}

#[test]
fn norms_basic() {
    let a = Mat::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
    assert!((fro_norm(&a) - 5.0).abs() < 1e-12);
    let b = Mat::zeros(2, 2);
    assert!((fro_norm_diff(&a, &b) - 5.0).abs() < 1e-12);
    let mut r = rng(15);
    let sigma = spectral_norm_est(&a, 50, &mut r);
    assert!((sigma - 4.0).abs() < 1e-6, "spectral est {sigma}");
}

#[test]
fn mat_block_ops() {
    let a = Mat::from_fn(4, 5, |i, j| (i * 5 + j) as f64);
    let s = a.slice(1, 3, 2, 5);
    assert_eq!(s.shape(), (2, 3));
    assert_eq!(s[(0, 0)], 7.0);
    let rows = a.select_rows(&[3, 0]);
    assert_eq!(rows[(0, 0)], 15.0);
    assert_eq!(rows[(1, 4)], 4.0);
    let cols = a.select_cols(&[4, 1]);
    assert_eq!(cols[(2, 0)], 14.0);
    let cat = a.hcat(&a);
    assert_eq!(cat.shape(), (4, 10));
    assert_eq!(cat[(1, 7)], a[(1, 2)]);
    let vc = a.vcat(&a);
    assert_eq!(vc.shape(), (8, 5));
    assert_eq!(vc[(5, 2)], a[(1, 2)]);
    let t = a.transpose();
    assert_eq!(t.shape(), (5, 4));
    assert_eq!(t[(2, 3)], a[(3, 2)]);
}
