//! Symmetric eigendecomposition (round-robin parallel Jacobi) and the
//! convex-cone projections from Section 3.2 of the paper (Eqns. 3.5 / 3.6).

use super::{jacobi, matmul, Mat};

/// Symmetric eigendecomposition `A = V diag(w) Vᵀ`.
pub struct EigH {
    /// Eigenvalues in descending order.
    pub values: Vec<f64>,
    /// Eigenvectors as columns, matching `values` order.
    pub vectors: Mat,
}

/// One row pair for the Jacobi row-rotation phase, moved out of the row
/// table so the pool can rotate the round's pairs concurrently (header
/// swaps only, no element copies).
struct RowPair {
    rp: Vec<f64>,
    rq: Vec<f64>,
    c: f64,
    s: f64,
}

/// Round-robin parallel Jacobi eigensolver for symmetric matrices.
///
/// Quadratically convergent sweeps; intended for the small `s×s` / `c×c`
/// core matrices of Algorithms 2–3 (c ≲ few hundred), exactly the regime
/// Remark 3 of the paper argues is cheap (`O(c³)`).
///
/// Each sweep is `n−1` rounds of disjoint pivot pairs
/// ([`jacobi::ring_rounds`]). A round applies its similarity rotations
/// `A ← Jᵀ A J` in two structurally fixed phases — column rotations
/// `A·J` (every row updated independently, sharded over row chunks),
/// then row rotations `Jᵀ·A` (each pair owns its two contiguous rows) —
/// so the result is **bitwise identical** for every thread count: each
/// element is written by exactly one worker, in a schedule-independent
/// expression. Rotation angles come from the round-start matrix; a
/// pair's defining entries `(p,p), (q,q), (p,q)` are untouched by the
/// round's other (disjoint) pairs, so the angles equal the sequential
/// ones.
pub fn eigh(a: &Mat) -> EigH {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigh: matrix must be square");
    // Rows as contiguous Vecs (the two phases shard over rows / row
    // pairs), symmetrized defensively (callers pass (X + Xᵀ)/2 already).
    let mut arows: Vec<Vec<f64>> = (0..n).map(|i| a.row(i).to_vec()).collect();
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (arows[i][j] + arows[j][i]);
            arows[i][j] = avg;
            arows[j][i] = avg;
        }
    }
    let mut vrows: Vec<Vec<f64>> = (0..n)
        .map(|i| {
            let mut e = vec![0.0; n];
            e[i] = 1.0;
            e
        })
        .collect();
    let max_sweeps = 64;
    let fro = arows.iter().flatten().map(|x| x * x).sum::<f64>().sqrt();
    let tol = 1e-14 * fro.max(1e-300);
    let rounds = jacobi::ring_rounds(n);
    let pool = jacobi::jacobi_pool(n * n);

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for (i, row) in arows.iter().enumerate() {
            for &x in &row[i + 1..] {
                off += x * x;
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for round in &rounds {
            // Rotation angles from the round-start state.
            let rots: Vec<(usize, usize, f64, f64)> = round
                .iter()
                .filter_map(|&(p, q)| {
                    let apq = arows[p][q];
                    if apq.abs() <= tol * 1e-2 {
                        return None;
                    }
                    let (c, s) = jacobi::jacobi_cs(arows[p][p], arows[q][q], apq);
                    Some((p, q, c, s))
                })
                .collect();
            if rots.is_empty() {
                continue;
            }
            // Phase A — column rotations `A ← A·J`: every row applies the
            // round's rotations to its own entries, rows sharded in
            // chunks over the pool.
            pool.for_each_mut(&mut arows, |_, row| apply_col_rotations(row, &rots));
            // Phase B — row rotations `A ← Jᵀ·A`: each pair rotates its
            // two (contiguous) rows, pairs sharded over the pool.
            let mut units: Vec<RowPair> = rots
                .iter()
                .map(|&(p, q, c, s)| RowPair {
                    rp: std::mem::take(&mut arows[p]),
                    rq: std::mem::take(&mut arows[q]),
                    c,
                    s,
                })
                .collect();
            pool.for_each_mut(&mut units, |_, u| {
                jacobi::rotate_pair(&mut u.rp, &mut u.rq, u.c, u.s);
            });
            for (&(p, q, _, _), u) in rots.iter().zip(units) {
                arows[p] = u.rp;
                arows[q] = u.rq;
            }
            // Accumulate `V ← V·J` — the same per-row column rotations.
            pool.for_each_mut(&mut vrows, |_, row| apply_col_rotations(row, &rots));
        }
    }

    // Sort eigenpairs by descending eigenvalue (NaN-safe ordering).
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| arows[i][i]).collect();
    order.sort_by(|&a, &b| diag[b].total_cmp(&diag[a]));
    let values: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let mut vectors = Mat::zeros(n, n);
    for i in 0..n {
        let dst = vectors.row_mut(i);
        for (oj, &j) in order.iter().enumerate() {
            dst[oj] = vrows[i][j];
        }
    }
    EigH { values, vectors }
}

/// Apply a round's plane rotations to one row's column entries:
/// `(row[p], row[q]) ← (c·row[p] − s·row[q], s·row[p] + c·row[q])`.
/// Pairs are disjoint, so the per-row result is order-independent.
#[inline]
fn apply_col_rotations(row: &mut [f64], rots: &[(usize, usize, f64, f64)]) {
    for &(p, q, c, s) in rots {
        let (x, y) = (row[p], row[q]);
        row[p] = c * x - s * y;
        row[q] = s * x + c * y;
    }
}

/// Projection onto the symmetric matrices `H^n` (Eqn. 3.5):
/// `Π(X) = (X + Xᵀ)/2`.
pub fn project_symmetric(x: &Mat) -> Mat {
    assert_eq!(x.rows(), x.cols(), "project_symmetric: square input required");
    let mut out = x.clone();
    let n = x.rows();
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (x[(i, j)] + x[(j, i)]);
            out[(i, j)] = avg;
            out[(j, i)] = avg;
        }
    }
    out
}

/// Projection onto the PSD cone `H^n_+` (Eqn. 3.6): symmetrize, eigen-
/// decompose, zero out negative eigenvalues, reassemble.
pub fn project_psd(x: &Mat) -> Mat {
    let sym = project_symmetric(x);
    let EigH { values, vectors } = eigh(&sym);
    let n = sym.rows();
    // V * diag(max(w, 0)) * Vᵀ
    let mut vd = vectors.clone();
    for j in 0..n {
        let w = values[j].max(0.0);
        for i in 0..n {
            vd[(i, j)] *= w;
        }
    }
    matmul(&vd, &vectors.transpose())
}
