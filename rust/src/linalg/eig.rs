//! Symmetric eigendecomposition (cyclic Jacobi) and the convex-cone
//! projections from Section 3.2 of the paper (Eqns. 3.5 / 3.6).

use super::{matmul, Mat};

/// Symmetric eigendecomposition `A = V diag(w) Vᵀ`.
pub struct EigH {
    /// Eigenvalues in descending order.
    pub values: Vec<f64>,
    /// Eigenvectors as columns, matching `values` order.
    pub vectors: Mat,
}

/// Cyclic Jacobi eigensolver for symmetric matrices.
///
/// Quadratically convergent sweeps; intended for the small `s×s` / `c×c`
/// core matrices of Algorithms 2–3 (c ≲ few hundred), exactly the regime
/// Remark 3 of the paper argues is cheap (`O(c³)`).
pub fn eigh(a: &Mat) -> EigH {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigh: matrix must be square");
    let mut m = a.clone();
    // Symmetrize defensively (callers pass (X + Xᵀ)/2 already).
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    let tol = 1e-14 * m.fro_norm().max(1e-300);

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= tol * 1e-2 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = {
                    let s = if theta >= 0.0 { 1.0 } else { -1.0 };
                    s / (theta.abs() + (theta * theta + 1.0).sqrt())
                };
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // Rotate rows/cols p and q of m.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // Accumulate rotations into V.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Sort eigenpairs by descending eigenvalue.
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&a, &b| diag[b].partial_cmp(&diag[a]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let vectors = v.select_cols(&order);
    EigH { values, vectors }
}

/// Projection onto the symmetric matrices `H^n` (Eqn. 3.5):
/// `Π(X) = (X + Xᵀ)/2`.
pub fn project_symmetric(x: &Mat) -> Mat {
    assert_eq!(x.rows(), x.cols(), "project_symmetric: square input required");
    let mut out = x.clone();
    let n = x.rows();
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (x[(i, j)] + x[(j, i)]);
            out[(i, j)] = avg;
            out[(j, i)] = avg;
        }
    }
    out
}

/// Projection onto the PSD cone `H^n_+` (Eqn. 3.6): symmetrize, eigen-
/// decompose, zero out negative eigenvalues, reassemble.
pub fn project_psd(x: &Mat) -> Mat {
    let sym = project_symmetric(x);
    let EigH { values, vectors } = eigh(&sym);
    let n = sym.rows();
    // V * diag(max(w, 0)) * Vᵀ
    let mut vd = vectors.clone();
    for j in 0..n {
        let w = values[j].max(0.0);
        for i in 0..n {
            vd[(i, j)] *= w;
        }
    }
    matmul(&vd, &vectors.transpose())
}
