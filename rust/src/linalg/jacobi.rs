//! Shared machinery for the *parallel* Jacobi kernels ([`super::svd_jacobi`],
//! [`super::eigh`]): the round-robin (ring) pair ordering that partitions
//! each sweep into rounds of disjoint rotations, the 2×2 rotation solve,
//! and the pool-gating helper.
//!
//! Determinism contract: a round's pairs touch disjoint columns (one-sided
//! SVD) or are applied in two structurally fixed phases (two-sided eigh),
//! so executing a round's pairs concurrently produces *bitwise* the same
//! result as executing them one after another — `threads = 1` and
//! `threads = N` agree exactly, and the pool gate below is a pure
//! performance switch.

use crate::parallel::{self, Pool};

/// Round-robin tournament schedule over `0..n`: `ñ − 1` rounds
/// (`ñ = n` rounded up to even), each a maximal set of disjoint index
/// pairs, together covering every unordered pair exactly once per sweep.
/// This is the classic "circle method": index `ñ−1` sits still while the
/// rest rotate one seat per round.
pub(crate) fn ring_rounds(n: usize) -> Vec<Vec<(usize, usize)>> {
    if n < 2 {
        return Vec::new();
    }
    let e = n + (n & 1); // round up to even; index n is a bye when n is odd
    let mut rounds = Vec::with_capacity(e - 1);
    for r in 0..e - 1 {
        let mut pairs = Vec::with_capacity(e / 2);
        // Seat 0: the fixed player (index e−1 — the bye when n is odd)
        // against the rotating one.
        if r < n && e - 1 < n {
            pairs.push((r.min(e - 1), r.max(e - 1)));
        }
        for i in 1..e / 2 {
            let a = (r + i) % (e - 1);
            let b = (r + e - 1 - i) % (e - 1);
            if a < n && b < n {
                pairs.push((a.min(b), a.max(b)));
            }
        }
        pairs.sort_unstable(); // fixed, schedule-independent round order
        rounds.push(pairs);
    }
    rounds
}

/// Solve the 2×2 symmetric Jacobi rotation: the (c, s) that diagonalizes
/// `[[app, apq], [apq, aqq]]` (inner-rotation convention, |t| ≤ 1).
#[inline]
pub(crate) fn jacobi_cs(app: f64, aqq: f64, apq: f64) -> (f64, f64) {
    let theta = (aqq - app) / (2.0 * apq);
    let t = {
        let sgn = if theta >= 0.0 { 1.0 } else { -1.0 };
        sgn / (theta.abs() + (theta * theta + 1.0).sqrt())
    };
    let c = 1.0 / (t * t + 1.0).sqrt();
    (c, t * c)
}

/// Apply the plane rotation to a pair of equal-length contiguous slices:
/// `(x, y) ← (c·x − s·y, s·x + c·y)` elementwise. Contiguous access is
/// what lets LLVM vectorize this — the seed kernels' strided `(i, p)`
/// walks could not.
#[inline]
pub(crate) fn rotate_pair(x: &mut [f64], y: &mut [f64], c: f64, s: f64) {
    debug_assert_eq!(x.len(), y.len());
    for (a, b) in x.iter_mut().zip(y.iter_mut()) {
        let (xa, yb) = (*a, *b);
        *a = c * xa - s * yb;
        *b = s * xa + c * yb;
    }
}

/// Pool for a Jacobi solve over `work` elements of state: the configured
/// pool when the knob allows sharding and the matrix is big enough to
/// amortize spawn cost, else the inline serial pool. Either choice gives
/// bitwise-identical results (see module docs), so this gate is
/// perf-only.
pub(crate) fn jacobi_pool(work: usize) -> Pool {
    if parallel::threads() > 1 && work >= parallel::PAR_MIN_WORK {
        Pool::current()
    } else {
        Pool::new(1)
    }
}
