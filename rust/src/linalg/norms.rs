//! Norms and norm estimates.

use super::Mat;
use crate::rng::Pcg64;

/// Frobenius norm.
pub fn fro_norm(a: &Mat) -> f64 {
    a.fro_norm()
}

/// `‖A − B‖_F` without materializing the difference.
pub fn fro_norm_diff(a: &Mat, b: &Mat) -> f64 {
    assert_eq!(a.shape(), b.shape(), "fro_norm_diff: shape mismatch");
    a.data()
        .iter()
        .zip(b.data())
        .map(|(x, y)| {
            let d = x - y;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Spectral-norm estimate by power iteration on `AᵀA`.
pub fn spectral_norm_est(a: &Mat, iters: usize, rng: &mut Pcg64) -> f64 {
    let n = a.cols();
    if n == 0 || a.rows() == 0 {
        return 0.0;
    }
    let mut x: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
    let mut sigma = 0.0;
    for _ in 0..iters.max(1) {
        let y = a.matvec(&x); // m
        let z = a.matvec_t(&y); // n = AᵀA x
        let nz = z.iter().map(|v| v * v).sum::<f64>().sqrt();
        if nz == 0.0 {
            return 0.0;
        }
        sigma = nz.sqrt(); // ‖AᵀA x‖ ≈ σ² ⇒ σ ≈ sqrt
        x = z.iter().map(|v| v / nz).collect();
    }
    sigma
}
