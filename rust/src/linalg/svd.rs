//! Singular value decompositions:
//!
//! * [`svd_jacobi`] — full thin SVD via one-sided Jacobi (small/medium
//!   matrices, high accuracy; used for the core-matrix SVDs of
//!   Algorithms 3–4 and for exact baselines on test-sized inputs).
//! * [`svd_randomized`] — randomized subspace-iteration top-k SVD
//!   (Halko–Martinsson–Tropp) for the `‖A − A_k‖_F` denominators on
//!   dataset-sized matrices.

use super::{matmul, matmul_at_b, qr_thin, Mat};
use crate::rng::Pcg64;

/// Thin SVD `A = U diag(s) Vᵀ`.
pub struct Svd {
    /// m×k left singular vectors.
    pub u: Mat,
    /// Singular values, descending.
    pub s: Vec<f64>,
    /// n×k right singular vectors (columns).
    pub v: Mat,
}

/// One-sided Jacobi SVD (Hestenes). Works on `A` with m >= n by
/// orthogonalizing columns; for m < n we factor the transpose and swap.
pub fn svd_jacobi(a: &Mat) -> Svd {
    let (m, n) = a.shape();
    if m < n {
        let Svd { u, s, v } = svd_jacobi(&a.transpose());
        return Svd { u: v, s, v: u };
    }
    let mut u = a.clone(); // columns get orthogonalized in place
    let mut v = Mat::eye(n);
    let tol = 1e-15;
    let max_sweeps = 64;

    for _sweep in 0..max_sweeps {
        let mut rotated = false;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram block of columns p, q.
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= tol * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                rotated = true;
                let theta = (aqq - app) / (2.0 * apq);
                let t = {
                    let sgn = if theta >= 0.0 { 1.0 } else { -1.0 };
                    sgn / (theta.abs() + (theta * theta + 1.0).sqrt())
                };
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    u[(i, p)] = c * up - s * uq;
                    u[(i, q)] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if !rotated {
            break;
        }
    }

    // Column norms are the singular values; normalize U's columns.
    let mut sv: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm: f64 = (0..m).map(|i| u[(i, j)] * u[(i, j)]).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    sv.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u_out = Mat::zeros(m, n);
    let mut v_out = Mat::zeros(n, n);
    let mut s_out = Vec::with_capacity(n);
    for (oj, &(norm, j)) in sv.iter().enumerate() {
        s_out.push(norm);
        if norm > 0.0 {
            for i in 0..m {
                u_out[(i, oj)] = u[(i, j)] / norm;
            }
        }
        for i in 0..n {
            v_out[(i, oj)] = v[(i, j)];
        }
    }
    Svd { u: u_out, s: s_out, v: v_out }
}

/// Randomized top-k SVD via subspace iteration with oversampling.
///
/// `n_iter` power iterations sharpen the spectrum (default callers use 4–8
/// which is plenty for the exponential/power-law decays in our datasets).
pub fn svd_randomized(a: &Mat, k: usize, oversample: usize, n_iter: usize, rng: &mut Pcg64) -> Svd {
    let (m, n) = a.shape();
    let l = (k + oversample).min(m.min(n));
    // Range finder on the side with fewer rows for efficiency.
    let omega = Mat::randn(n, l, rng);
    let mut y = matmul(a, &omega); // m x l
    let mut q = qr_thin(&y).q;
    for _ in 0..n_iter {
        let z = matmul_at_b(a, &q); // n x l  (Aᵀ Q)
        let qz = qr_thin(&z).q;
        y = matmul(a, &qz);
        q = qr_thin(&y).q;
    }
    // B = Qᵀ A (l x n), small SVD of B.
    let b = matmul_at_b(&q, a);
    let Svd { u: ub, s, v } = svd_jacobi(&b);
    let u = matmul(&q, &ub);
    // Truncate to k.
    let kk = k.min(s.len());
    let mut u_k = Mat::zeros(m, kk);
    let mut v_k = Mat::zeros(n, kk);
    for j in 0..kk {
        for i in 0..m {
            u_k[(i, j)] = u[(i, j)];
        }
        for i in 0..n {
            v_k[(i, j)] = v[(i, j)];
        }
    }
    Svd { u: u_k, s: s[..kk].to_vec(), v: v_k }
}
