//! Singular value decompositions:
//!
//! * [`svd_jacobi`] — full thin SVD via *round-robin parallel* one-sided
//!   Jacobi (small/medium matrices, high accuracy; used for the
//!   core-matrix SVDs of Algorithms 3–4 and for exact baselines on
//!   test-sized inputs). Each sweep's n(n−1)/2 column pairs are
//!   partitioned into n−1 rounds of disjoint pairs
//!   ([`jacobi::ring_rounds`]); a round's rotations touch disjoint
//!   column pairs, so they shard over the `crate::parallel` pool and are
//!   **bitwise identical** between `threads = 1` and `threads = N`.
//! * [`svd_randomized`] — randomized subspace-iteration top-k SVD
//!   (Halko–Martinsson–Tropp) for the `‖A − A_k‖_F` denominators on
//!   dataset-sized matrices. Its three thin QRs per power iteration and
//!   the small final SVD ride the blocked [`qr_thin`] and the parallel
//!   Jacobi above.

use super::{jacobi, matmul, matmul_at_b, qr_thin, Mat};
use crate::rng::Pcg64;

/// Thin SVD `A = U diag(s) Vᵀ`.
pub struct Svd {
    /// m×k left singular vectors.
    pub u: Mat,
    /// Singular values, descending.
    pub s: Vec<f64>,
    /// n×k right singular vectors (columns).
    pub v: Mat,
}

/// One working pair for a Jacobi round: the two U columns and two V
/// columns it may rotate, moved out of the column table so the pool can
/// process the round's pairs concurrently without aliasing. The moves
/// are `Vec` header swaps — no element copies.
struct PairUnit {
    up: Vec<f64>,
    uq: Vec<f64>,
    vp: Vec<f64>,
    vq: Vec<f64>,
    rotated: bool,
}

impl PairUnit {
    /// Orthogonalize the pair: 2×2 Gram from the U columns, rotate U and
    /// V columns when the off-diagonal coupling is above `tol`. Reads and
    /// writes only this unit's own data — the independence that makes a
    /// round's pairs bitwise schedule-invariant.
    fn rotate(&mut self, tol: f64) {
        let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
        for (x, y) in self.up.iter().zip(self.uq.iter()) {
            app += x * x;
            aqq += y * y;
            apq += x * y;
        }
        if apq == 0.0 || apq.abs() <= tol * (app * aqq).sqrt() {
            return;
        }
        self.rotated = true;
        let (c, s) = jacobi::jacobi_cs(app, aqq, apq);
        jacobi::rotate_pair(&mut self.up, &mut self.uq, c, s);
        jacobi::rotate_pair(&mut self.vp, &mut self.vq, c, s);
    }
}

/// One-sided Jacobi SVD (Hestenes), round-robin ordered and
/// pool-parallel. Works on `A` with m >= n by orthogonalizing columns;
/// for m < n we factor the transpose and swap.
pub fn svd_jacobi(a: &Mat) -> Svd {
    let (m, n) = a.shape();
    if m < n {
        let Svd { u, s, v } = svd_jacobi(&a.transpose());
        return Svd { u: v, s, v: u };
    }
    if n == 0 {
        return Svd { u: Mat::zeros(m, 0), s: Vec::new(), v: Mat::zeros(0, 0) };
    }
    // Columns as contiguous Vecs: rotations walk whole columns (the seed
    // kernel's `(i, p)` walks were strided across every row), and a
    // round's disjoint pairs move their columns into per-pair units for
    // the pool.
    let mut ucols: Vec<Vec<f64>> = (0..n).map(|j| a.col(j)).collect();
    let mut vcols: Vec<Vec<f64>> = (0..n)
        .map(|j| {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            e
        })
        .collect();
    let tol = 1e-15;
    let max_sweeps = 64;
    let rounds = jacobi::ring_rounds(n);
    let pool = jacobi::jacobi_pool(m * n);

    for _sweep in 0..max_sweeps {
        let mut rotated = false;
        for round in &rounds {
            let mut units: Vec<PairUnit> = round
                .iter()
                .map(|&(p, q)| PairUnit {
                    up: std::mem::take(&mut ucols[p]),
                    uq: std::mem::take(&mut ucols[q]),
                    vp: std::mem::take(&mut vcols[p]),
                    vq: std::mem::take(&mut vcols[q]),
                    rotated: false,
                })
                .collect();
            pool.for_each_mut(&mut units, |_, u| u.rotate(tol));
            for (&(p, q), u) in round.iter().zip(units) {
                ucols[p] = u.up;
                ucols[q] = u.uq;
                vcols[p] = u.vp;
                vcols[q] = u.vq;
                rotated |= u.rotated;
            }
        }
        if !rotated {
            break;
        }
    }

    // Column norms are the singular values; normalize U's columns.
    let mut sv: Vec<(f64, usize)> = ucols
        .iter()
        .enumerate()
        .map(|(j, col)| (col.iter().map(|x| x * x).sum::<f64>().sqrt(), j))
        .collect();
    sv.sort_by(|a, b| b.0.total_cmp(&a.0)); // NaN-safe descending order

    let mut u_out = Mat::zeros(m, n);
    let mut v_out = Mat::zeros(n, n);
    let mut s_out = Vec::with_capacity(n);
    for (oj, &(norm, j)) in sv.iter().enumerate() {
        s_out.push(norm);
        if norm > 0.0 {
            for (i, &x) in ucols[j].iter().enumerate() {
                u_out[(i, oj)] = x / norm;
            }
        }
        for (i, &x) in vcols[j].iter().enumerate() {
            v_out[(i, oj)] = x;
        }
    }
    Svd { u: u_out, s: s_out, v: v_out }
}

/// Randomized top-k SVD via subspace iteration with oversampling.
///
/// `n_iter` power iterations sharpen the spectrum (default callers use 4–8
/// which is plenty for the exponential/power-law decays in our datasets).
pub fn svd_randomized(a: &Mat, k: usize, oversample: usize, n_iter: usize, rng: &mut Pcg64) -> Svd {
    let (m, n) = a.shape();
    let l = (k + oversample).min(m.min(n));
    // Range finder on the side with fewer rows for efficiency.
    let omega = Mat::randn(n, l, rng);
    let mut y = matmul(a, &omega); // m x l
    let mut q = qr_thin(&y).q;
    for _ in 0..n_iter {
        let z = matmul_at_b(a, &q); // n x l  (Aᵀ Q)
        let qz = qr_thin(&z).q;
        y = matmul(a, &qz);
        q = qr_thin(&y).q;
    }
    // B = Qᵀ A (l x n), small SVD of B.
    let b = matmul_at_b(&q, a);
    let Svd { u: ub, s, v } = svd_jacobi(&b);
    let u = matmul(&q, &ub);
    // Truncate to k.
    let kk = k.min(s.len());
    let mut u_k = Mat::zeros(m, kk);
    let mut v_k = Mat::zeros(n, kk);
    for j in 0..kk {
        for i in 0..m {
            u_k[(i, j)] = u[(i, j)];
        }
        for i in 0..n {
            v_k[(i, j)] = v[(i, j)];
        }
    }
    Svd { u: u_k, s: s[..kk].to_vec(), v: v_k }
}
