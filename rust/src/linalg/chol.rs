//! Cholesky factorization and SPD solves.

use super::{solve_lower, solve_lower_transpose, Mat};
use crate::error::FgError;

/// Lower Cholesky factor of an SPD matrix: `A = L Lᵀ`.
///
/// Returns `Err` if a non-positive pivot is hit (matrix not numerically
/// positive definite); callers that work with Gram matrices of possibly
/// rank-deficient factors should add a ridge first (see `pinv`).
pub fn cholesky(a: &Mat) -> Result<Mat, FgError> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky: matrix must be square");
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            // s -= sum_k l[i,k] * l[j,k]
            let (li, lj) = (l.row(i), l.row(j));
            for k in 0..j {
                s -= li[k] * lj[k];
            }
            if i == j {
                if s <= 0.0 {
                    return Err(FgError::NotPositiveDefinite { pivot: i, value: s });
                }
                l[(i, i)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve `A X = B` for SPD `A` via Cholesky (two triangular solves).
pub fn cholesky_solve(a: &Mat, b: &Mat) -> Result<Mat, FgError> {
    let l = cholesky(a)?;
    let y = solve_lower(&l, b);
    Ok(solve_lower_transpose(&l, &y))
}
