//! Command-line interface for the `fastgmr` launcher.
//!
//! Hand-rolled argument parsing (no clap in the offline vendor set).
//!
//! ```text
//! fastgmr info                         # platform + artifact inventory
//! fastgmr verify                       # run artifact golden self-checks
//! fastgmr bench <target> [--full|--smoke] [--threads N]
//! fastgmr pipeline [--config f.toml] [--threads N]
//! fastgmr serve [--jobs N] [--workers W] [--queue-depth D] [--cache-mb M]
//!               [--batch-window MS] [--deadline MS] [--threads N]
//! fastgmr cur [--size MxN] [--rank K] [--selection S] [--sketch KIND]
//! fastgmr cur --stream [--block B] …      # single-pass streaming CUR
//! ```
//!
//! `--threads N` sets the process-wide worker count for the parallel
//! sketch/matmul layer (`crate::parallel`); `0` auto-detects, `1`
//! reproduces single-threaded results bitwise. Config files can set the
//! same knob as `[parallel] threads`.
//!
//! `--epsilon E` sets an accuracy target accepted by *every* subcommand:
//! sketch sizes are planned from the paper's `O(ε^{-1/2})` bounds and
//! escalated until the a-posteriori check certifies `(1+ε)` relative
//! error (see [`crate::plan`]); `serve` enforces it as a per-job SLO.
//!
//! `serve`, `pipeline`, and `cur` additionally accept the observability
//! flags `--trace-out FILE` (span trace: Chrome trace-event JSON, or
//! JSONL when `FILE` ends in `.jsonl` — see [`crate::obs`]) and
//! `--metrics-out FILE` (Prometheus text exposition of the run's
//! metrics registry).

use crate::config::Config;
use crate::coordinator::{
    jobs::MatrixPayload, ApproxJob, PipelineConfig, Router, ServeConfig, StreamPipeline,
};
use crate::cur::{self, CurConfig, SelectionStrategy, StreamingCurConfig};
use crate::data::{synth_dense, SpectrumKind};
use crate::error::{FgError, Result};
use crate::linalg::Mat;
use crate::metrics::Metrics;
use crate::obs::TraceCollector;
use crate::rng::rng;
use crate::sketch::SketchKind;
use crate::svdstream::fast::FastSpSvdSketches;
use crate::svdstream::source::DenseColumnStream;
use crate::svdstream::FastSpSvdConfig;
use std::sync::Arc;

const USAGE: &str = "\
fastgmr — Fast Generalized Matrix Regression (paper reproduction)

USAGE:
  fastgmr info                       platform + artifact inventory
  fastgmr verify                     artifact golden self-checks
  fastgmr bench <target|all> [--full|--smoke] [--threads N]
                                     regenerate paper tables/figures
  fastgmr pipeline [--config FILE] [--threads N]
                                     run the streaming SP-SVD pipeline
  fastgmr serve [--jobs N] [--workers W] [--queue-depth D] [--cache-mb M]
                [--batch-window MS] [--deadline MS] [--threads N]
                [--retry-max R] [--degrade] [--cache-dir DIR]
                [--cache-ttl T] [--listen ADDR] [--max-conns C]
                [--net-timeout MS]
                                     demo the serving daemon: mixed jobs
                                     through admission control (D=0
                                     unbounded), the coalescing batcher
                                     (MS=0 off), and the fingerprint-
                                     keyed artifact cache (M=0 off);
                                     prints the serve.* metrics report
                                     and the cache inventory.
                                     --retry-max R retries transient
                                     failures and executor panics up to
                                     R attempts (1 = fail fast);
                                     --degrade re-plans jobs at a
                                     smaller sketch tier under admission
                                     pressure instead of shedding;
                                     --cache-dir DIR persists the
                                     artifact cache crash-safely on
                                     shutdown and warm-starts from it;
                                     --cache-ttl T expires cached
                                     artifacts older than T cache
                                     operations (logical ticks; 0 =
                                     never expire);
                                     --listen ADDR serves the v1 line
                                     protocol over TCP at ADDR (e.g.
                                     127.0.0.1:7463) and round-trips
                                     the demo stream through a loopback
                                     wire client; with --jobs 0 it
                                     serves until stdin closes (daemon
                                     mode), then drains gracefully
                                     (finishes in-flight requests and
                                     persists the cache).
                                     --max-conns C sheds connects
                                     beyond C with BUSY (0=unlimited);
                                     --net-timeout MS sets the per-
                                     connection socket read/write
                                     deadlines (default 5000, 0=none)
  fastgmr cur [--size MxN] [--rank K] [--c C] [--r R] [--selection S]
              [--sketch KIND] [--mult A] [--seed N] [--threads N]
                                     CUR decomposition demo: compare the
                                     exact, Fast-GMR, and stabilized-QR
                                     cores on a synthetic rank-K matrix
  fastgmr cur --stream [--block B] [--workers W] …
                                     single-pass streaming CUR over a
                                     column stream (rank-K subspace
                                     leverage scores, reservoir column
                                     retention), compared against the
                                     in-memory path
  fastgmr help                       this message

  --epsilon E    accuracy target: plan sketch sizes from the paper's
                 O(ε^{-1/2}) bounds and escalate (reusing each sketch as
                 a bitwise prefix) until the a-posteriori check
                 certifies (1+ε) relative error. Accepted by every
                 subcommand: info prints the ε → size schedule, verify
                 runs a planned self-check, bench restricts the
                 fig_epsilon sweep to E, pipeline/cur/cur --stream run
                 the ε-planned solvers and report attempts, serve
                 enforces E as a per-job accuracy SLO (escalations in
                 serve.plan.*; degraded jobs report their estimated ε
                 instead)
  --selection S  one of: uniform | leverage (exact full-rank scores;
                 provably uniform on square full-rank inputs) |
                 subspace (rank-K restricted scores, a.k.a.
                 subspace-leverage / lev-k) | sketched (approximate
                 scores from a small sketch, a.k.a. sketched-leverage /
                 approx); anything else is an error
  --sketch KIND  sketch family for the Fast-GMR core / SVD pipeline:
                 gaussian | uniform | leverage | srht | count | osnap |
                 osnap-gaussian; anything else is an error listing the
                 accepted tokens (also `[svd] sketch` in config files)
  --threads N    worker threads for the parallel layer (0 = auto-detect,
                 1 = bitwise single-threaded reproduction)
  --trace-out F  (serve | pipeline | cur) write the run's span trace to F
                 on exit: Chrome trace-event JSON for chrome://tracing /
                 Perfetto, or line-oriented JSONL events when F ends in
                 .jsonl; tracing is off (zero cost) without this flag
  --metrics-out F  (serve | pipeline | cur) write the run's metrics
                 registry to F as Prometheus text exposition (counters,
                 gauges, and latency histograms with cumulative buckets).
                 For serve, both exports are flushed by the router
                 itself during graceful drain (before shutdown returns),
                 so daemon and netted runs persist them too

Bench targets: table1..table7, fig1, fig2, fig3, fig_cur, fig_curstream,
fig_epsilon, fig_gemm, fig_linalg, fig_serve, perf (see DESIGN.md §5).
`bench --smoke` runs a reduced CI subset and writes
results/bench_smoke.json.";

/// Main dispatch (called from `rust/src/main.rs`).
pub fn main_entry() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let tail = args.get(1..).unwrap_or(&[]);
    let (rest, threads) = take_flag_value(tail, "--threads");
    apply_threads(threads.as_deref())?;
    let (rest, eps_spec) = take_flag_value(&rest, "--epsilon");
    let epsilon = parse_epsilon(eps_spec.as_deref())?;
    match cmd {
        "info" => info(epsilon),
        "verify" => verify(epsilon),
        "bench" => {
            if let Some(eps) = epsilon {
                crate::bench::fig_epsilon::set_cli_epsilon(eps);
            }
            let targets: Vec<String> = rest
                .iter()
                .map(|a| if a == "all" { String::new() } else { a.clone() })
                .filter(|a| !a.is_empty())
                .collect();
            crate::bench::bench_main(&targets);
            Ok(())
        }
        "pipeline" => pipeline(&rest, threads.is_some(), epsilon),
        "serve" => serve(&rest, epsilon),
        "cur" => cur_cmd(&rest, epsilon),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn info(epsilon: Option<f64>) -> Result<()> {
    match crate::runtime::Engine::new("artifacts") {
        Ok(engine) => {
            println!("platform: {}", engine.platform());
            println!("threads: {}", crate::parallel::threads());
            println!("artifacts ({}):", engine.manifest().len());
            for name in engine.manifest().names() {
                let e = engine.manifest().get(name)?;
                let ins: Vec<String> =
                    e.input_shapes.iter().map(|(r, c)| format!("{r}x{c}")).collect();
                println!("  {name}: inputs [{}]", ins.join(", "));
            }
        }
        Err(e) => println!("no artifacts: {e}"),
    }
    if let Some(eps) = epsilon {
        let plan = crate::plan::EpsilonPlan::new(eps);
        println!("\nepsilon plan (ε = {eps}, max {} attempts):", plan.max_attempts);
        println!("  check sketch: {} (saturates to an exact check at the matrix dims)", plan.check_size(1));
        println!("  {:>6}  {:>7}  schedule at dim 4096", "width", "s_init");
        for w in [4usize, 8, 16, 32, 64] {
            let sched: Vec<String> =
                plan.schedule(w, 4096).iter().map(usize::to_string).collect();
            println!("  {:>6}  {:>7}  {}", w, plan.initial_size(w, 4096), sched.join(" -> "));
        }
    }
    Ok(())
}

fn verify(epsilon: Option<f64>) -> Result<()> {
    let engine = crate::runtime::Engine::new("artifacts")?;
    let results = engine.verify_goldens()?;
    let mut worst = 0.0f64;
    for (name, err) in &results {
        println!("{name}: max rel err {err:.2e}");
        worst = worst.max(*err);
    }
    if worst > 2e-3 {
        return Err(FgError::Runtime(format!("golden verification failed (worst {worst:.2e})")));
    }
    println!("all {} artifacts verified", results.len());
    if let Some(eps) = epsilon {
        // Planned self-check: the ε-planner must certify its own target
        // on a fixed synthetic problem (the check saturates to exact at
        // this scale, so "attained" really means (1+ε)).
        let mut r = rng(7);
        let a = synth_dense(120, 90, 8, SpectrumKind::Exponential { base: 0.85 }, 0.02, &mut r);
        let idx: Vec<usize> = (0..24).collect();
        let c = a.select_cols(&idx);
        let rm = a.select_rows(&idx);
        let plan = crate::plan::EpsilonPlan::new(eps);
        let (_, out) = crate::plan::solve_gmr_planned(
            crate::gmr::Input::Dense(&a),
            &c,
            &rm,
            SketchKind::Gaussian,
            SketchKind::Gaussian,
            &plan,
        );
        println!(
            "epsilon self-check (ε = {eps}): attempts {}, s_c={} s_r={}, estimated ε̂ = {:.4}",
            out.attempts,
            out.s_c,
            out.s_r,
            out.estimated_epsilon()
        );
        if !out.attained {
            return Err(FgError::Runtime(format!(
                "epsilon self-check failed: ε = {eps} not attained in {} attempts (ε̂ = {:.4})",
                out.attempts,
                out.estimated_epsilon()
            )));
        }
    }
    Ok(())
}

fn flag_value<'a>(args: &'a [String], flag: &str) -> Option<&'a str> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1)).map(String::as_str)
}

/// Remove `flag VALUE` / `flag=VALUE` from an argument list, returning
/// the remaining arguments and the (last) value, so subcommands never
/// mistake the value for a positional argument. A trailing `flag` with
/// no value yields `Some("")` so the caller reports a usage error
/// instead of silently ignoring the flag.
fn take_flag_value(args: &[String], flag: &str) -> (Vec<String>, Option<String>) {
    let mut rest = Vec::with_capacity(args.len());
    let mut value = None;
    let mut i = 0;
    while i < args.len() {
        if args[i] == flag {
            if i + 1 < args.len() {
                value = Some(args[i + 1].clone());
                i += 2;
            } else {
                value = Some(String::new());
                i += 1;
            }
        } else if let Some(v) = args[i].strip_prefix(flag).and_then(|r| r.strip_prefix('=')) {
            value = Some(v.to_string());
            i += 1;
        } else {
            rest.push(args[i].clone());
            i += 1;
        }
    }
    (rest, value)
}

/// Observability flags shared by `serve`, `pipeline`, and `cur`:
/// `--trace-out FILE` (span trace export) and `--metrics-out FILE`
/// (Prometheus text exposition). Parsed and stripped up front so the
/// subcommands' positional parsing never sees the file paths.
struct ObsFlags {
    trace_out: Option<String>,
    metrics_out: Option<String>,
    collector: Option<Arc<TraceCollector>>,
}

fn take_obs_flags(args: &[String]) -> Result<(Vec<String>, ObsFlags)> {
    let (rest, trace_out) = take_flag_value(args, "--trace-out");
    let (rest, metrics_out) = take_flag_value(&rest, "--metrics-out");
    for (flag, v) in [("--trace-out", &trace_out), ("--metrics-out", &metrics_out)] {
        if v.as_deref() == Some("") {
            return Err(FgError::Config(format!("{flag}: expected a file path")));
        }
    }
    // The collector only exists when tracing was requested — `None`
    // keeps every span site on its zero-cost disabled path.
    let collector = trace_out.as_ref().map(|_| Arc::new(TraceCollector::new()));
    Ok((rest, ObsFlags { trace_out, metrics_out, collector }))
}

impl ObsFlags {
    /// Collector handle for `ServeConfig::trace` / `obs::install`.
    fn collector(&self) -> Option<Arc<TraceCollector>> {
        self.collector.clone()
    }

    /// Write the requested export files. Called after the traced work
    /// has completed (for `serve`, after `shutdown()` joined the
    /// executors), so every span has been recorded.
    fn write_outputs(&self, metrics: &Metrics) -> Result<()> {
        if let (Some(path), Some(c)) = (&self.trace_out, &self.collector) {
            let data = if path.ends_with(".jsonl") { c.to_jsonl() } else { c.to_chrome_json() };
            std::fs::write(path, data)
                .map_err(|e| FgError::Runtime(format!("--trace-out {path}: {e}")))?;
            println!("wrote {path} ({} spans)", c.len());
        }
        if let Some(path) = &self.metrics_out {
            std::fs::write(path, metrics.prometheus())
                .map_err(|e| FgError::Runtime(format!("--metrics-out {path}: {e}")))?;
            println!("wrote {path}");
        }
        Ok(())
    }

    /// Confirm the export files the router flushed during its drain.
    /// `serve` hands the paths to `ServeConfig` so the flush happens
    /// *inside* `Router::drain()` — before shutdown returns, on every
    /// exit path (demo, loopback, daemon) — rather than here.
    fn announce_router_outputs(&self) {
        for path in [&self.trace_out, &self.metrics_out].into_iter().flatten() {
            if std::path::Path::new(path).exists() {
                println!("wrote {path} (flushed at router drain)");
            } else {
                eprintln!("warning: {path} was not written (see drain errors above)");
            }
        }
    }
}

/// Parse a `--epsilon E` accuracy target; malformed or non-positive
/// values are a hard error (a silently dropped accuracy target would be
/// an SLO violation by the launcher itself).
fn parse_epsilon(spec: Option<&str>) -> Result<Option<f64>> {
    match spec {
        None => Ok(None),
        Some(s) => {
            let eps: f64 = s.parse().map_err(|_| {
                FgError::Config(format!("--epsilon: expected a number, got `{s}`"))
            })?;
            if !eps.is_finite() || eps <= 0.0 {
                return Err(FgError::Config(format!(
                    "--epsilon: expected a positive finite target, got `{s}`"
                )));
            }
            Ok(Some(eps))
        }
    }
}

/// Apply a `--threads N` override to the process-wide pool knob.
fn apply_threads(spec: Option<&str>) -> Result<()> {
    if let Some(s) = spec {
        let n: usize = s
            .parse()
            .map_err(|_| FgError::Config(format!("--threads: expected a number, got `{s}`")))?;
        crate::parallel::set_threads(n);
    }
    Ok(())
}

fn pipeline(args: &[String], cli_threads: bool, epsilon: Option<f64>) -> Result<()> {
    let (args, obs_flags) = take_obs_flags(args)?;
    let args = &args[..];
    let cfg = match flag_value(args, "--config") {
        Some(path) => Config::load(path)?,
        None => Config::default(),
    };
    // Config-file threads knob (a CLI --threads, applied earlier, wins).
    if !cli_threads {
        if let Some(t) = cfg.parallel_threads() {
            crate::parallel::set_threads(t);
        }
    }
    let m = cfg.int_or("pipeline", "rows", 2048) as usize;
    let n = cfg.int_or("pipeline", "cols", 4096) as usize;
    let block = cfg.int_or("pipeline", "block", 512) as usize;
    let workers = cfg.int_or("pipeline", "workers", 0) as usize;
    let depth = cfg.int_or("pipeline", "queue_depth", 4) as usize;
    let k = cfg.int_or("svd", "k", 10) as usize;
    let mult = cfg.int_or("svd", "mult", 4) as usize;
    // Unknown sketch families are a hard error listing the accepted
    // tokens (`[svd] sketch` in the config file), never a fallback.
    let kind = SketchKind::parse(cfg.str_or("svd", "sketch", "gaussian"))?;
    let seed = cfg.int_or("pipeline", "seed", 0) as u64;

    println!(
        "pipeline: {m}x{n}, block={block}, workers={workers} (0=auto), depth={depth}, \
         threads={}, k={k}, mult={mult}",
        crate::parallel::threads()
    );
    let mut r = rng(seed);
    let a = synth_dense(m, n, 3 * k, SpectrumKind::Exponential { base: 0.85 }, 0.02, &mut r);
    let svd_cfg = FastSpSvdConfig::paper(k, mult, kind);
    let sketches = FastSpSvdSketches::draw(&svd_cfg, m, n, &mut r);
    let pipeline = StreamPipeline::new(PipelineConfig {
        workers,
        queue_depth: depth,
        ..PipelineConfig::default()
    });
    let start = std::time::Instant::now();
    let mut stream = DenseColumnStream::new(&a, block);
    // Install on this thread: the pipeline's stream/finalize spans are
    // recorded on the driver thread (compute workers stay span-free so
    // the trace structure is independent of the worker count).
    crate::obs::install(obs_flags.collector());
    let run = pipeline.run(&mut stream, &svd_cfg, &sketches);
    crate::obs::install(None);
    let res = run?;
    let secs = start.elapsed().as_secs_f64();

    let mut r2 = rng(seed + 1);
    let ak = crate::svdstream::ak_error(crate::gmr::Input::Dense(&a), k, 6, &mut r2);
    let ratio = crate::svdstream::error_ratio(&a, &res, ak);
    println!("blocks={} time={secs:.2}s throughput={:.1} cols/s", res.blocks, n as f64 / secs);
    println!("error ratio vs ‖A−A_k‖: {ratio:.4}");
    if let Some(eps) = epsilon {
        // ε-planned reference driver: re-streams the matrix per
        // escalation attempt (honest single-pass cost model) until the
        // a-posteriori check certifies the target for the SVD factors.
        let plan = crate::plan::EpsilonPlan::new(eps).with_seed(seed);
        let t0 = std::time::Instant::now();
        let (pres, out) = crate::svdstream::fast_sp_svd_planned(
            || {
                Ok(Box::new(DenseColumnStream::new(&a, block))
                    as Box<dyn crate::svdstream::ColumnStream + '_>)
            },
            &svd_cfg,
            &plan,
        )?;
        let psecs = t0.elapsed().as_secs_f64();
        let pratio = crate::svdstream::error_ratio(&a, &pres, ak);
        println!(
            "planned (ε={eps}): attempts {} (s_c={} s_r={}), attained {}, ε̂ {:.4}, \
             error ratio {pratio:.4}, {psecs:.2}s",
            out.attempts,
            out.s_c,
            out.s_r,
            out.attained,
            out.estimated_epsilon()
        );
    }
    println!("{}", pipeline.metrics.report());
    obs_flags.write_outputs(&pipeline.metrics)?;
    Ok(())
}

/// Parse an optional numeric flag, erroring loudly on malformed values
/// (a silent default would benchmark a configuration the user did not
/// ask for).
fn parse_flag<T: std::str::FromStr>(args: &[String], flag: &str, default: T) -> Result<T> {
    match flag_value(args, flag) {
        None => Ok(default),
        Some(v) => {
            v.parse().map_err(|_| FgError::Config(format!("{flag}: expected a number, got `{v}`")))
        }
    }
}

/// `fastgmr cur` — decompose a synthetic rank-`k` + noise matrix and
/// compare the three core solvers against `‖A − A_k‖_F`.
fn cur_cmd(args: &[String], epsilon: Option<f64>) -> Result<()> {
    let (args, obs_flags) = take_obs_flags(args)?;
    let args = &args[..];
    let (m, n) = match flag_value(args, "--size").unwrap_or("1200x900").split_once('x') {
        Some((ms, ns)) => {
            let m = ms.parse().map_err(|_| FgError::Config(format!("--size: bad rows `{ms}`")))?;
            let n = ns.parse().map_err(|_| FgError::Config(format!("--size: bad cols `{ns}`")))?;
            (m, n)
        }
        None => return Err(FgError::Config("--size: expected MxN (e.g. 1200x900)".into())),
    };
    let k: usize = parse_flag(args, "--rank", 10)?;
    let c: usize = parse_flag(args, "--c", 3 * k)?;
    let r: usize = parse_flag(args, "--r", 3 * k)?;
    let mult: usize = parse_flag(args, "--mult", 4)?;
    let seed: u64 = parse_flag(args, "--seed", 0)?;
    // Unknown sketch families are a hard error listing the accepted
    // tokens, never a silent fallback — same contract as `--selection`.
    let sketch = SketchKind::parse(flag_value(args, "--sketch").unwrap_or("gaussian"))?;
    let sel_tok = flag_value(args, "--selection").unwrap_or("leverage");
    // Unknown strategy names are a hard error (listing the accepted
    // tokens), never a silent fallback.
    let selection = SelectionStrategy::parse(sel_tok, sketch, 4 * k, k)?;
    if args.iter().any(|a| a == "--stream") {
        if flag_value(args, "--selection").is_some() {
            println!("note: --selection is ignored with --stream (always subspace leverage)");
        }
        return cur_stream_cmd(args, &obs_flags, m, n, k, c, r, mult, seed, sketch, epsilon);
    }

    println!(
        "cur: A {m}x{n} rank-{k}+noise, c={c} r={r}, selection={}, sketch={} (mult {mult}), \
         threads={}",
        selection.name(),
        sketch.name(),
        crate::parallel::threads()
    );
    let mut rs = rng(seed);
    let a = synth_dense(m, n, k, SpectrumKind::Exponential { base: 0.85 }, 0.02, &mut rs);
    let input = crate::gmr::Input::Dense(&a);
    let metrics = Metrics::new();
    crate::obs::install(obs_flags.collector());

    let start = std::time::Instant::now();
    let (col_idx, cmat) = cur::select_columns(input, &selection, c, &mut rs);
    let (row_idx, rmat) = cur::select_rows(input, &selection, r, &mut rs);
    metrics.observe("cur.select", start.elapsed().as_secs_f64());
    println!(
        "selected {} columns / {} rows in {:.3}s",
        col_idx.len(),
        row_idx.len(),
        start.elapsed().as_secs_f64()
    );

    let mut rak = rng(seed + 1);
    let ak = crate::svdstream::ak_error(input, k, 6, &mut rak);
    println!("‖A − A_k‖_F = {ak:.5}");

    println!("{:>14}  {:>10}  {:>10}  {:>8}", "core", "residual", "vs ‖A−A_k‖", "t_core");
    let report = |name: &str, u: Mat, secs: f64| {
        metrics.observe(&format!("cur.core.{name}"), secs);
        let res = crate::gmr::residual(input, &cmat, &u, &rmat);
        println!("{:>14}  {:>10.5}  {:>10.4}  {:>7.3}s", name, res, res / ak, secs);
    };
    let t0 = std::time::Instant::now();
    let u = cur::core_exact(input, &cmat, &rmat);
    report("exact", u, t0.elapsed().as_secs_f64());
    let mut rc = rng(seed + 2);
    let t0 = std::time::Instant::now();
    let u = cur::core_fast(input, &cmat, &rmat, sketch, mult * c, mult * r, &mut rc);
    report("fast-gmr", u, t0.elapsed().as_secs_f64());
    let t0 = std::time::Instant::now();
    let u = cur::core_stabilized(input, &cmat, &rmat);
    report("stabilized-qr", u, t0.elapsed().as_secs_f64());
    if let Some(eps) = epsilon {
        // ε-planned core on the same factors: sizes come from the plan,
        // escalating until the check certifies (1+ε) for this C/R pair.
        let plan = crate::plan::EpsilonPlan::new(eps).with_seed(seed);
        let t0 = std::time::Instant::now();
        let (sol, out) =
            crate::plan::solve_gmr_planned(input, &cmat, &rmat, sketch, sketch, &plan);
        report("planned", sol.x, t0.elapsed().as_secs_f64());
        println!(
            "planned: ε={eps}, attempts {} (s_c={} s_r={}), attained {}, estimated ε̂ = {:.4}",
            out.attempts,
            out.s_c,
            out.s_r,
            out.attained,
            out.estimated_epsilon()
        );
    }
    crate::obs::install(None);
    obs_flags.write_outputs(&metrics)?;
    Ok(())
}

/// `fastgmr cur --stream` — single-pass streaming CUR through the
/// double-buffered pipeline, compared against the in-memory
/// subspace-leverage path on the same synthetic matrix.
fn cur_stream_cmd(
    args: &[String],
    obs_flags: &ObsFlags,
    m: usize,
    n: usize,
    k: usize,
    c: usize,
    r: usize,
    mult: usize,
    seed: u64,
    sketch: SketchKind,
    epsilon: Option<f64>,
) -> Result<()> {
    let block: usize = parse_flag(args, "--block", 256)?;
    let workers: usize = parse_flag(args, "--workers", 0)?;
    // Traces both the in-memory reference (cur.select.*/cur.core) and
    // the streaming pass (pipeline.stream, curstream.*) on this thread.
    crate::obs::install(obs_flags.collector());
    println!(
        "cur --stream: A {m}x{n} rank-{k}+noise, c={c} r={r}, sketch={} (mult {mult}), \
         block={block}, workers={workers} (0=auto), threads={}",
        sketch.name(),
        crate::parallel::threads()
    );
    let mut rs = rng(seed);
    let a = synth_dense(m, n, k, SpectrumKind::Exponential { base: 0.85 }, 0.02, &mut rs);
    let input = crate::gmr::Input::Dense(&a);
    let mut rak = rng(seed + 1);
    let ak = crate::svdstream::ak_error(input, k, 6, &mut rak);
    println!("‖A − A_k‖_F = {ak:.5}");

    // In-memory reference: subspace-leverage selection + Fast-GMR core.
    let mem_cfg = CurConfig {
        c,
        r,
        selection: SelectionStrategy::SubspaceLeverage { k },
        core: crate::cur::CoreMethod::FastGmr,
        sketch,
        s_c: mult * c,
        s_r: mult * r,
    };
    let mut rm = rng(seed + 2);
    let t0 = std::time::Instant::now();
    let mem = cur::decompose(input, &mem_cfg, &mut rm);
    let t_mem = t0.elapsed().as_secs_f64();
    let res_mem = mem.residual(input);
    println!("in-memory:  {:.3}s  residual {res_mem:.5}  ratio {:.4}", t_mem, res_mem / ak);

    // Streaming: one pass over the column stream (enforced by the
    // OnePassStream wrapper) through the concurrent pipeline. Only the
    // sketch family differs from the library default — the sizing rule
    // (s_c = 2·s_r) stays in one place, StreamingCurConfig::fast.
    let stream_cfg = StreamingCurConfig { kind: sketch, ..StreamingCurConfig::fast(c, r, k, mult) };
    let mut rdraw = rng(seed + 3);
    let sketches = crate::cur::StreamingCurSketches::draw(&stream_cfg, m, n, &mut rdraw);
    let pipeline = StreamPipeline::new(PipelineConfig {
        workers,
        queue_depth: 4,
        ..PipelineConfig::default()
    });
    let mut stream = crate::svdstream::OnePassStream::new(DenseColumnStream::new(&a, block.max(1)));
    let t0 = std::time::Instant::now();
    let run = pipeline.run_cur(&mut stream, &stream_cfg, &sketches, &mut rdraw);
    crate::obs::install(None);
    let res = run?;
    let t_stream = t0.elapsed().as_secs_f64();
    let res_stream = res.cur.residual(input);
    println!(
        "streaming:  {:.3}s  residual {res_stream:.5}  ratio {:.4}  ({} blocks, {} candidates, \
         {:.0} cols/s)",
        t_stream,
        res_stream / ak,
        res.blocks,
        res.candidates,
        n as f64 / t_stream
    );
    if let Some(eps) = epsilon {
        // ε-planned streaming CUR: one full pass per escalation attempt
        // (the stream factory reopens the data), sketch randomness and
        // the check products reused across attempts.
        let plan = crate::plan::EpsilonPlan::new(eps).with_seed(seed);
        let t0 = std::time::Instant::now();
        let (pres, out) = cur::streaming_cur_planned(
            || {
                Ok(Box::new(DenseColumnStream::new(&a, block.max(1)))
                    as Box<dyn crate::svdstream::ColumnStream + '_>)
            },
            &stream_cfg,
            &plan,
        )?;
        let t_plan = t0.elapsed().as_secs_f64();
        let res_plan = pres.cur.residual(input);
        println!(
            "planned:    {t_plan:.3}s  residual {res_plan:.5}  ratio {:.4}  (ε={eps}, \
             attempts {}, s_c={} s_r={}, attained {}, ε̂ {:.4})",
            res_plan / ak,
            out.attempts,
            out.s_c,
            out.s_r,
            out.attained,
            out.estimated_epsilon()
        );
    }
    println!("\n{}", pipeline.metrics.report());
    obs_flags.write_outputs(&pipeline.metrics)?;
    Ok(())
}

/// `fastgmr serve` — demo the serving daemon on a mixed job stream with
/// a repeating (kind, dataset, seed) period of 12, so every request
/// beyond the first period repeats an earlier cache key and a warm
/// artifact cache answers it without recomputing (the paper's
/// one-sketch-many-queries amortization, served across requests).
///
/// With `--listen ADDR` the router is fronted by the TCP wire server
/// (`net::Server`) and the same demo stream round-trips through a
/// loopback `net::Client`; `--jobs 0 --listen ADDR` instead serves
/// external clients until stdin closes, then drains gracefully.
fn serve(args: &[String], epsilon: Option<f64>) -> Result<()> {
    let (args, obs_flags) = take_obs_flags(args)?;
    let args = &args[..];
    let jobs: usize = parse_flag(args, "--jobs", 24)?;
    let workers: usize = parse_flag(args, "--workers", 2)?;
    let queue_depth: usize = parse_flag(args, "--queue-depth", 0)?;
    let cache_mb: usize = parse_flag(args, "--cache-mb", 64)?;
    let batch_ms: u64 = parse_flag(args, "--batch-window", 0)?;
    let deadline_ms: u64 = parse_flag(args, "--deadline", 0)?;
    let retry_max: u32 = parse_flag(args, "--retry-max", 1)?;
    let cache_ttl: u64 = parse_flag(args, "--cache-ttl", 0)?;
    let max_conns: usize = parse_flag(args, "--max-conns", 64)?;
    let net_timeout_ms: u64 = parse_flag(args, "--net-timeout", 5000)?;
    let listen = flag_value(args, "--listen").map(str::to_string);
    let degrade = args.iter().any(|a| a == "--degrade");
    let cache_dir = flag_value(args, "--cache-dir").map(str::to_string);
    if let Some(d) = &cache_dir {
        std::fs::create_dir_all(d)
            .map_err(|e| FgError::Config(format!("--cache-dir {d}: {e}")))?;
    }
    let cache_path = cache_dir
        .as_ref()
        .map(|d| std::path::Path::new(d).join("artifact_cache.txt"));
    let retry = if retry_max > 1 {
        crate::faults::RetryPolicy {
            max_attempts: retry_max,
            base_backoff: std::time::Duration::from_millis(10),
            cap: std::time::Duration::from_millis(200),
        }
    } else {
        crate::faults::RetryPolicy::none()
    };
    let cfg = ServeConfig {
        workers,
        queue_depth,
        cache_bytes: cache_mb << 20,
        cache_ttl,
        batch_window: std::time::Duration::from_millis(batch_ms),
        default_deadline: (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms)),
        trace: obs_flags.collector(),
        retry,
        degrade,
        cache_path,
        // The router flushes these exports during its own drain, so
        // every exit path (demo, loopback, daemon EOF) persists them.
        trace_path: obs_flags.trace_out.clone().map(std::path::PathBuf::from),
        metrics_path: obs_flags.metrics_out.clone().map(std::path::PathBuf::from),
        epsilon,
        ..ServeConfig::service(workers)
    };
    let router = Router::with_config(&cfg);
    println!(
        "serve: {jobs} jobs, workers={workers}, queue-depth={queue_depth} (0=unbounded), \
         cache={cache_mb} MB, cache-ttl={cache_ttl} (0=never), batch-window={batch_ms} ms, \
         deadline={deadline_ms} ms (0=none), retry-max={retry_max}, degrade={degrade}, \
         epsilon={}, cache-dir={}, threads={}",
        epsilon.map(|e| e.to_string()).unwrap_or_else(|| "-".into()),
        cache_dir.as_deref().unwrap_or("-"),
        crate::parallel::threads()
    );

    if let Some(addr) = listen {
        return serve_net(router, &addr, jobs, max_conns, net_timeout_ms, &obs_flags);
    }

    let mut handles = Vec::new();
    let mut shed = 0usize;
    for (j, job) in demo_job_stream(jobs).into_iter().enumerate() {
        match router.submit(job) {
            Ok(h) => handles.push((j, h)),
            // Shedding at a bounded queue is the design working, not a
            // launcher failure.
            Err(FgError::Overloaded { .. }) => shed += 1,
            Err(e) => return Err(e),
        }
    }
    for (j, h) in handles {
        match h.wait() {
            Ok(res) if res.is_degraded() => {
                println!("job {j}: {} done (degraded tier)", res.kind())
            }
            Ok(res) => println!("job {j}: {} done", res.kind()),
            Err(e) => println!("job {j}: failed ({e})"),
        }
    }
    if shed > 0 {
        println!("{shed} requests shed at admission (queue depth {queue_depth})");
    }
    println!("\n{}", router.metrics.report());
    if let Some(manifest) = router.cache_manifest() {
        println!("{manifest}");
    }
    // shutdown() joins the executors, persists the cache, and flushes
    // the trace/metrics exports before returning.
    router.shutdown();
    obs_flags.announce_router_outputs();
    Ok(())
}

/// The demo request stream shared by the in-process and wire paths: a
/// repeating (kind, dataset, seed) period of 12 over two synthetic
/// datasets, so requests beyond the first period hit the artifact cache.
fn demo_job_stream(jobs: usize) -> Vec<ApproxJob> {
    let mut r = rng(42);
    let datasets: Vec<Mat> = (0..2)
        .map(|_| synth_dense(300, 240, 20, SpectrumKind::Exponential { base: 0.9 }, 0.02, &mut r))
        .collect();
    let points: Vec<Mat> = (0..2).map(|_| Mat::randn(400, 8, &mut r)).collect();
    (0..jobs)
        .map(|j| {
            let dataset = (j / 3) % 2;
            let a = &datasets[dataset];
            let seed = (j / 6) as u64 % 2;
            match j % 3 {
                0 => ApproxJob::SpsdKernel {
                    x: points[dataset].clone(),
                    sigma: 0.4,
                    c: 12,
                    s: 60,
                    seed,
                },
                1 => ApproxJob::StreamSvd {
                    a: MatrixPayload::Dense(a.clone()),
                    cfg: FastSpSvdConfig::paper(5, 4, SketchKind::Gaussian),
                    block: 64,
                    seed,
                },
                _ => ApproxJob::Cur {
                    a: MatrixPayload::Dense(a.clone()),
                    cfg: CurConfig::fast(12, 12, 3),
                    seed,
                },
            }
        })
        .collect()
}

/// `serve --listen`: front the router with the TCP wire server. With
/// `jobs > 0` the demo stream round-trips through a loopback wire
/// client (every result decoded from the v1 line protocol); with
/// `--jobs 0` the process serves external clients until stdin closes.
/// Either way the exit path is a graceful drain: stop accepting, finish
/// in-flight requests, persist the cache, flush the exports.
fn serve_net(
    router: Router,
    addr: &str,
    jobs: usize,
    max_conns: usize,
    net_timeout_ms: u64,
    obs_flags: &ObsFlags,
) -> Result<()> {
    use crate::net::{Client, NetConfig, Server};
    let timeout = (net_timeout_ms > 0).then(|| std::time::Duration::from_millis(net_timeout_ms));
    let ncfg = NetConfig {
        max_conns,
        read_timeout: timeout,
        write_timeout: timeout,
        ..NetConfig::default()
    };
    let router = Arc::new(router);
    let server = Server::bind(addr, Arc::clone(&router), ncfg.clone())?;
    let bound = server.addr();
    println!(
        "serve: listening on {bound} (max-conns={max_conns}, net-timeout={net_timeout_ms} ms)"
    );

    if jobs == 0 {
        println!("daemon mode: serving until stdin closes (send EOF to drain)");
        let mut sink = Vec::new();
        let _ = std::io::Read::read_to_end(&mut std::io::stdin().lock(), &mut sink);
    } else {
        let mut client = Client::connect(bound, &ncfg)?;
        for (j, job) in demo_job_stream(jobs).into_iter().enumerate() {
            match client.submit(&job) {
                Ok((res, trace)) if res.is_degraded() => println!(
                    "job {j}: {} done over the wire (degraded tier, trace {trace:016x})",
                    res.kind()
                ),
                Ok((res, trace)) => {
                    println!("job {j}: {} done over the wire (trace {trace:016x})", res.kind())
                }
                Err(e) => println!("job {j}: failed ({e})"),
            }
        }
        client.quit()?;
    }

    println!("\n{}", router.metrics.report());
    if let Some(manifest) = router.cache_manifest() {
        println!("{manifest}");
    }
    // Graceful drain: stop accepting, finish in-flight requests, then
    // the router persists the cache and flushes the exports.
    server.drain();
    obs_flags.announce_router_outputs();
    Ok(())
}
