//! Minimal TOML-subset configuration parser (the offline image vendors no
//! TOML crate). Supports what the launcher needs: `[section]` headers,
//! `key = value` with string/int/float/bool values, `#` comments.
//!
//! ```toml
//! [pipeline]
//! block = 1024
//! workers = 1
//!
//! [parallel]
//! threads = 4        # worker pool size; 0 = auto, 1 = bitwise serial
//!
//! [svd]
//! k = 10
//! sketch = "gaussian"
//! ```

use crate::error::{FgError, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed configuration: section → key → value. Keys outside any section
/// land in the "" section.
#[derive(Clone, Debug, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') || line.len() < 3 {
                    return Err(FgError::Config(format!("line {}: malformed section header", lineno + 1)));
                }
                section = line[1..line.len() - 1].trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| {
                FgError::Config(format!("line {}: expected `key = value`", lineno + 1))
            })?;
            let key = line[..eq].trim().to_string();
            let val = parse_value(line[eq + 1..].trim())
                .ok_or_else(|| FgError::Config(format!("line {}: bad value", lineno + 1)))?;
            cfg.sections.entry(section.clone()).or_default().insert(key, val);
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    /// Look up `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// Integer with default.
    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_int).unwrap_or(default)
    }

    /// Float with default.
    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_float).unwrap_or(default)
    }

    /// String with default.
    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }

    /// Bool with default.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Section names.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }

    /// Set a value programmatically (CLI overrides).
    pub fn set(&mut self, section: &str, key: &str, value: Value) {
        self.sections.entry(section.to_string()).or_default().insert(key.to_string(), value);
    }

    /// The `[parallel] threads` knob for `crate::parallel::set_threads`,
    /// if present: `0` means auto-detect, `1` means bitwise serial.
    /// Negative values are treated as absent.
    pub fn parallel_threads(&self) -> Option<usize> {
        match self.get("parallel", "threads").and_then(Value::as_int) {
            Some(n) if n >= 0 => Some(n as usize),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(tok: &str) -> Option<Value> {
    if tok.starts_with('"') && tok.ends_with('"') && tok.len() >= 2 {
        return Some(Value::Str(tok[1..tok.len() - 1].to_string()));
    }
    match tok {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = tok.parse::<i64>() {
        return Some(Value::Int(i));
    }
    if let Ok(f) = tok.parse::<f64>() {
        return Some(Value::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let cfg = Config::parse(
            r#"
# top comment
global_key = 7
[pipeline]
block = 1024           # inline comment
workers = 2
ratio = 0.5
name = "fast # gmr"
enabled = true
"#,
        )
        .unwrap();
        assert_eq!(cfg.int_or("", "global_key", 0), 7);
        assert_eq!(cfg.int_or("pipeline", "block", 0), 1024);
        assert_eq!(cfg.int_or("pipeline", "workers", 0), 2);
        assert_eq!(cfg.float_or("pipeline", "ratio", 0.0), 0.5);
        assert_eq!(cfg.str_or("pipeline", "name", ""), "fast # gmr");
        assert!(cfg.bool_or("pipeline", "enabled", false));
        // Defaults.
        assert_eq!(cfg.int_or("pipeline", "missing", 9), 9);
        assert_eq!(cfg.str_or("nosec", "x", "d"), "d");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("k = @@@").is_err());
    }

    #[test]
    fn parallel_threads_knob() {
        let cfg = Config::parse("[parallel]\nthreads = 3\n").unwrap();
        assert_eq!(cfg.parallel_threads(), Some(3));
        assert_eq!(Config::parse("[parallel]\nthreads = -1\n").unwrap().parallel_threads(), None);
        assert_eq!(Config::default().parallel_threads(), None);
    }

    #[test]
    fn set_overrides() {
        let mut cfg = Config::parse("[a]\nx = 1\n").unwrap();
        cfg.set("a", "x", Value::Int(5));
        assert_eq!(cfg.int_or("a", "x", 0), 5);
    }
}
