//! Sketch-library tests: correctness of every apply path against the
//! densified operator, plus empirical checks of Lemma 1's two properties.

use super::*;
use crate::linalg::{matmul, matmul_a_bt, qr_thin, Mat};
use crate::rng::rng;
use crate::sparse::{Csr, Triplet};
use crate::testing::assert_close;

fn random_csr(m: usize, n: usize, density: f64, seed: u64) -> Csr {
    let mut r = rng(seed);
    let mut t = Vec::new();
    for i in 0..m {
        for j in 0..n {
            if r.next_f64() < density {
                t.push(Triplet { row: i, col: j, val: r.next_normal() });
            }
        }
    }
    Csr::from_triplets(m, n, t)
}

/// Every family: apply_left(A) must equal to_dense(S) * A, and the CSR and
/// right-apply paths must agree with the dense operator too.
#[test]
fn all_families_consistent_with_dense_operator() {
    let (s, m, n) = (16, 37, 9);
    for kind in SketchKind::all() {
        let mut r = rng(100 + kind.name().len() as u64);
        let scores: Vec<f64> = (0..m).map(|i| 1.0 + (i % 5) as f64).collect();
        let sk = Sketch::draw(kind, s, m, Some(&scores), &mut r);
        assert_eq!(sk.out_dim(), s);
        assert_eq!(sk.in_dim(), m);
        let sd = sk.to_dense();
        assert_eq!(sd.shape(), (s, m));

        let a = Mat::randn(m, n, &mut r);
        let got = sk.apply_left(&a);
        let want = matmul(&sd, &a);
        assert_close(&got, &want, 1e-10, &format!("{} apply_left", kind.name()));

        let ac = Csr::from_dense(&a, 0.0);
        let got_csr = sk.apply_left_csr(&ac);
        assert_close(&got_csr, &want, 1e-10, &format!("{} apply_left_csr", kind.name()));

        let b = Mat::randn(n, m, &mut r);
        let got_r = sk.apply_right(&b);
        let want_r = matmul_a_bt(&b, &sd);
        assert_close(&got_r, &want_r, 1e-10, &format!("{} apply_right", kind.name()));

        let bc = Csr::from_dense(&b, 0.0);
        let got_rc = sk.apply_right_csr(&bc);
        assert_close(&got_rc, &want_r, 1e-10, &format!("{} apply_right_csr", kind.name()));
    }
}

#[test]
fn csr_paths_on_truly_sparse_input() {
    let a = random_csr(50, 31, 0.1, 7);
    for kind in [SketchKind::Count, SketchKind::Osnap, SketchKind::Gaussian] {
        let mut r = rng(3);
        let sk = Sketch::draw(kind, 12, 50, None, &mut r);
        let want = matmul(&sk.to_dense(), &a.to_dense());
        assert_close(&sk.apply_left_csr(&a), &want, 1e-10, kind.name());
    }
}

/// Lemma 1 property 1 (subspace embedding): for an orthonormal U (m×k),
/// all singular values of SU should lie in [1-η, 1+η].
#[test]
fn subspace_embedding_property() {
    let m = 512;
    let k = 8;
    let mut r = rng(42);
    let u = qr_thin(&Mat::randn(m, k, &mut r)).q;
    let scores = u.row_norms_sq();
    // Generous sizes appropriate for each family at this (m, k).
    let cases = [
        (SketchKind::Gaussian, 160),
        (SketchKind::Srht, 200),
        (SketchKind::Count, 400),
        (SketchKind::Osnap, 300),
        (SketchKind::Leverage, 300),
        (SketchKind::OsnapGaussian, 200),
    ];
    for (kind, s) in cases {
        let sk = Sketch::draw(kind, s, m, Some(&scores), &mut r);
        let su = sk.apply_left(&u);
        let gram = crate::linalg::matmul_at_b(&su, &su);
        // Eigenvalues of (SU)ᵀSU must be within [1-η, 1+η].
        let e = crate::linalg::eigh(&gram);
        let (lo, hi) = (e.values[k - 1], e.values[0]);
        assert!(
            lo > 0.25 && hi < 2.5,
            "{}: singular value bounds violated: [{lo}, {hi}]",
            kind.name()
        );
    }
}

/// Lemma 1 property 2 (approximate matrix multiplication): averaged over
/// draws, ‖BᵀSᵀSA − BᵀA‖_F should shrink like 1/sqrt(s).
#[test]
fn matrix_multiplication_property_scales() {
    let m = 256;
    let mut r = rng(9);
    let a = Mat::randn(m, 6, &mut r);
    let b = Mat::randn(m, 5, &mut r);
    let exact = crate::linalg::matmul_at_b(&b, &a);
    let denom = a.fro_norm() * b.fro_norm();
    for kind in [SketchKind::Gaussian, SketchKind::Count, SketchKind::Osnap] {
        let mut err_small = 0.0;
        let mut err_big = 0.0;
        let trials = 12;
        for t in 0..trials {
            let mut rr = rng(1000 + t);
            let sk_small = Sketch::draw(kind, 32, m, None, &mut rr);
            let sk_big = Sketch::draw(kind, 512, m, None, &mut rr);
            for (sk, acc) in [(&sk_small, &mut err_small), (&sk_big, &mut err_big)] {
                let sa = sk.apply_left(&a);
                let sb = sk.apply_left(&b);
                let approx = crate::linalg::matmul_at_b(&sb, &sa);
                *acc += crate::linalg::fro_norm_diff(&approx, &exact) / denom;
            }
        }
        // s grows 16x => error should shrink ~4x; accept 2x as the pass bar.
        assert!(
            err_big < err_small / 2.0,
            "{}: error did not shrink with s: small={err_small} big={err_big}",
            kind.name()
        );
    }
}

/// Unbiasedness: E[SᵀS] = I — empirical mean over draws approaches I.
#[test]
fn expectation_identity() {
    let m = 24;
    for kind in [SketchKind::Gaussian, SketchKind::Count, SketchKind::Osnap, SketchKind::Uniform, SketchKind::Srht] {
        let mut acc = Mat::zeros(m, m);
        let trials = 300;
        for t in 0..trials {
            let mut r = rng(5000 + t);
            let sk = Sketch::draw(kind, 48, m, None, &mut r);
            let sd = sk.to_dense();
            acc += &crate::linalg::matmul_at_b(&sd, &sd);
        }
        acc.scale(1.0 / trials as f64);
        let err = crate::linalg::fro_norm_diff(&acc, &Mat::eye(m)) / (m as f64).sqrt();
        assert!(err < 0.25, "{}: E[SᵀS] far from I (err {err})", kind.name());
    }
}

/// `draw_sampling` with a weight vector that is zero everywhere but one
/// coordinate: the 1e-12 uniform floor must keep every probability
/// finite, and every draw lands on the single massive coordinate with
/// the unbiased `1/sqrt(s·p)` scale (p ≈ 1 ⇒ entries ≈ 1/sqrt(s)).
#[test]
fn draw_sampling_single_nonzero_weight_floor_path() {
    let (s, m, hot) = (8usize, 16usize, 11usize);
    let mut w = vec![0.0; m];
    w[hot] = 2.5;
    let mut r = rng(91);
    let sk = super::leverage::draw_sampling(s, m, &w, &mut r);
    let sd = sk.to_dense();
    assert_eq!(sd.shape(), (s, m));
    let expect = 1.0 / (s as f64).sqrt();
    for t in 0..s {
        for j in 0..m {
            if j == hot {
                assert!(
                    (sd[(t, j)] - expect).abs() < 1e-6,
                    "row {t}: scale {} != 1/sqrt(s) {expect}",
                    sd[(t, j)]
                );
            } else {
                assert_eq!(sd[(t, j)], 0.0, "row {t} sampled a zero-weight coordinate {j}");
            }
        }
    }
}

/// Oversampling `s > m` is legal for sampling-with-replacement sketches:
/// shapes stay `s×m` and the realized operator agrees with its densified
/// form on both apply paths.
#[test]
fn draw_sampling_oversamples_beyond_input_dim() {
    let (s, m) = (50usize, 10usize);
    let mut r = rng(92);
    let w = vec![1.0; m];
    let sk = super::leverage::draw_sampling(s, m, &w, &mut r);
    assert_eq!(sk.out_dim(), s);
    assert_eq!(sk.in_dim(), m);
    let sd = sk.to_dense();
    let a = Mat::randn(m, 7, &mut r);
    assert_close(&sk.apply_left(&a), &matmul(&sd, &a), 1e-12, "oversampled apply_left");
    let b = Mat::randn(6, m, &mut r);
    assert_close(&sk.apply_right(&b), &matmul_a_bt(&b, &sd), 1e-12, "oversampled apply_right");
}

/// The `1/sqrt(s·p_i)` scaling keeps `E[SᵀS] ≈ I` for *non-uniform*
/// weights too (the existing expectation test only covers the uniform
/// family) — averaged over draws, the weighted sampling operator is
/// unbiased.
#[test]
fn draw_sampling_weighted_expectation_identity() {
    let m = 20;
    let weights: Vec<f64> = (0..m).map(|i| 1.0 + i as f64).collect();
    let mut acc = Mat::zeros(m, m);
    let trials = 400;
    for t in 0..trials {
        let mut r = rng(9000 + t);
        let sk = super::leverage::draw_sampling(32, m, &weights, &mut r);
        let sd = sk.to_dense();
        acc += &crate::linalg::matmul_at_b(&sd, &sd);
    }
    acc.scale(1.0 / trials as f64);
    let err = crate::linalg::fro_norm_diff(&acc, &Mat::eye(m)) / (m as f64).sqrt();
    assert!(err < 0.25, "weighted sampling E[SᵀS] far from I (err {err})");
}

#[test]
fn leverage_scores_sum_to_rank() {
    let mut r = rng(17);
    let a = Mat::randn(40, 6, &mut r);
    let scores = row_leverage_scores(&a);
    let total: f64 = scores.iter().sum();
    assert!((total - 6.0).abs() < 1e-8, "sum of leverage scores = rank, got {total}");
    assert!(scores.iter().all(|&s| s >= -1e-12 && s <= 1.0 + 1e-12));

    let col_scores = column_leverage_scores(&a);
    assert_eq!(col_scores.len(), 6);
    let ct: f64 = col_scores.iter().sum();
    assert!((ct - 6.0).abs() < 1e-8);
}

#[test]
fn fwht_is_orthogonal_involution() {
    let mut r = rng(23);
    let n = 64;
    let orig: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
    let mut buf = orig.clone();
    super::srht::fwht(&mut buf);
    super::srht::fwht(&mut buf);
    // H_unnorm^2 = n * I
    for (a, b) in buf.iter().zip(&orig) {
        assert!((a / n as f64 - b).abs() < 1e-12);
    }
}

#[test]
fn srht_preserves_norms_in_expectation() {
    let m = 100;
    let mut r = rng(29);
    let x = Mat::randn(m, 1, &mut r);
    let norm_sq = x.fro_norm_sq();
    let mut acc = 0.0;
    let trials = 200;
    for t in 0..trials {
        let mut rr = rng(7000 + t);
        let sk = Sketch::draw(SketchKind::Srht, 40, m, None, &mut rr);
        acc += sk.apply_left(&x).fro_norm_sq();
    }
    let ratio = acc / trials as f64 / norm_sq;
    assert!((ratio - 1.0).abs() < 0.1, "SRHT norm ratio {ratio}");
}

#[test]
fn compose_matches_sequential() {
    let mut r = rng(31);
    let first = Sketch::draw(SketchKind::Count, 64, 128, None, &mut r);
    let second = Sketch::draw(SketchKind::Gaussian, 16, 64, None, &mut r);
    let a = Mat::randn(128, 5, &mut r);
    let seq = second.apply_left(&first.apply_left(&a));
    let composed = super::combined::compose(first, second);
    assert_close(&composed.apply_left(&a), &seq, 1e-12, "compose");
    assert_eq!(composed.out_dim(), 16);
    assert_eq!(composed.in_dim(), 128);
}

#[test]
#[should_panic(expected = "apply_left")]
fn dimension_mismatch_panics() {
    let mut r = rng(37);
    let sk = Sketch::draw(SketchKind::Gaussian, 4, 10, None, &mut r);
    let a = Mat::zeros(11, 3);
    let _ = sk.apply_left(&a);
}

/// The ε-planner's escalation contract: for *every* family,
/// `draw_extension(kind, s, t, …)` run on a fresh rng seeded like
/// `draw(kind, s, …)` has its first `s` rows bitwise identical to that
/// base draw — re-sketching larger never redraws the prefix. The
/// degenerate `t == s` call must be bitwise the plain draw.
#[test]
fn extension_prefix_is_bitwise_the_base_draw() {
    let m = 40;
    let scores: Vec<f64> = (0..m).map(|i| 1.0 + (i % 7) as f64).collect();
    for kind in SketchKind::all() {
        let sc = if kind == SketchKind::Leverage { Some(&scores[..]) } else { None };
        let base = Sketch::draw(kind, 8, m, sc, &mut rng(0x77));
        let ext = Sketch::draw_extension(kind, 8, 20, m, sc, &mut rng(0x77));
        assert_eq!((ext.out_dim(), ext.in_dim()), (20, m), "{}", kind.name());
        let bd = base.to_dense();
        let ed = ext.to_dense();
        for i in 0..8 {
            for j in 0..m {
                assert!(
                    bd[(i, j)] == ed[(i, j)],
                    "{}: prefix row {i} col {j}: base {} vs extension {}",
                    kind.name(),
                    bd[(i, j)],
                    ed[(i, j)]
                );
            }
        }
        let plain = Sketch::draw_extension(kind, 8, 8, m, sc, &mut rng(0x77)).to_dense();
        for i in 0..8 {
            for j in 0..m {
                assert!(plain[(i, j)] == bd[(i, j)], "{}: t==s must be the plain draw", kind.name());
            }
        }
    }
}

/// Two extensions of the same base along the doubling path agree
/// bitwise on their common prefix — the multi-escalation invariant the
/// planner relies on across attempts 1 → 2 → 3.
#[test]
fn extension_chain_shares_prefixes_bitwise() {
    let m = 33;
    let scores: Vec<f64> = (0..m).map(|i| 1.0 + (i % 4) as f64).collect();
    for kind in SketchKind::all() {
        let sc = if kind == SketchKind::Leverage { Some(&scores[..]) } else { None };
        let mid = Sketch::draw_extension(kind, 7, 14, m, sc, &mut rng(0x99)).to_dense();
        let big = Sketch::draw_extension(kind, 7, 28, m, sc, &mut rng(0x99)).to_dense();
        for i in 0..14 {
            for j in 0..m {
                assert!(
                    mid[(i, j)] == big[(i, j)],
                    "{}: chained prefix diverged at ({i},{j})",
                    kind.name()
                );
            }
        }
    }
}

/// A stacked (multi-block) extension sketch must behave like one flat
/// operator on all four apply paths — left/right × dense/CSR — exactly
/// like the single-block families do.
#[test]
fn stacked_apply_paths_consistent_with_dense_operator() {
    let (m, n) = (37, 9);
    for kind in SketchKind::all() {
        let mut r = rng(400 + kind.name().len() as u64);
        let scores: Vec<f64> = (0..m).map(|i| 1.0 + (i % 5) as f64).collect();
        let sc = if kind == SketchKind::Leverage { Some(&scores[..]) } else { None };
        let sk = Sketch::draw_extension(kind, 6, 21, m, sc, &mut r);
        assert!(sk.stacked_blocks().is_some(), "{}: 6→21 must stack blocks", kind.name());
        let sd = sk.to_dense();
        assert_eq!(sd.shape(), (21, m));

        let a = Mat::randn(m, n, &mut r);
        let want = matmul(&sd, &a);
        assert_close(&sk.apply_left(&a), &want, 1e-10, &format!("{} stacked apply_left", kind.name()));
        let ac = Csr::from_dense(&a, 0.0);
        assert_close(
            &sk.apply_left_csr(&ac),
            &want,
            1e-10,
            &format!("{} stacked apply_left_csr", kind.name()),
        );

        let b = Mat::randn(n, m, &mut r);
        let want_r = matmul_a_bt(&b, &sd);
        assert_close(&sk.apply_right(&b), &want_r, 1e-10, &format!("{} stacked apply_right", kind.name()));
        let bc = Csr::from_dense(&b, 0.0);
        assert_close(
            &sk.apply_right_csr(&bc),
            &want_r,
            1e-10,
            &format!("{} stacked apply_right_csr", kind.name()),
        );
    }
}

/// Every accepted token round-trips through `parse`, and unknown tokens
/// are a hard `FgError::Config` that lists the accepted values (so the
/// CLI error is self-documenting, same contract as `--selection`).
#[test]
fn sketch_kind_parse_accepts_tokens_and_rejects_unknown() {
    for (tok, want) in [
        ("gaussian", SketchKind::Gaussian),
        ("GAUSS", SketchKind::Gaussian),
        ("uniform", SketchKind::Uniform),
        ("lev", SketchKind::Leverage),
        ("srht", SketchKind::Srht),
        ("hadamard", SketchKind::Srht),
        ("countsketch", SketchKind::Count),
        ("osnap", SketchKind::Osnap),
        ("osnap-gaussian", SketchKind::OsnapGaussian),
        ("combined", SketchKind::OsnapGaussian),
    ] {
        assert_eq!(SketchKind::parse(tok).unwrap(), want, "token `{tok}`");
    }
    for kind in SketchKind::all() {
        assert_eq!(SketchKind::parse(kind.name()).unwrap(), kind, "name() must round-trip");
    }
    let err = SketchKind::parse("bogus").unwrap_err().to_string();
    assert!(err.contains("bogus"), "error names the bad token: {err}");
    assert!(err.contains("accepted:"), "error lists accepted tokens: {err}");
    assert!(err.contains("osnap-gaussian"), "error lists the full token set: {err}");
}
