//! Composed sketches. Remark 1 of the paper: "Gaussian projection matrix
//! is commonly not used independently but combined with count sketch or
//! OSNAP, where after sketching by OSNAP, Gaussian projection is used to
//! obtain a more compact sketched form." The composition
//! `S = G · S_osnap` keeps `O(nnz)` application cost while reaching the
//! smaller Gaussian sketch sizes of Table 2.

use super::{osnap, Op, Sketch};
use crate::linalg::Mat;
use crate::rng::Pcg64;

/// Draw `G ∘ OSNAP`: OSNAP to an intermediate dimension `s_mid = 4s`
/// (a (1+γ)-style inflation), then dense Gaussian down to `s`.
pub(crate) fn draw_osnap_gaussian(s: usize, m: usize, rng: &mut Pcg64) -> Sketch {
    let s_mid = (4 * s).min(m.max(s));
    let first = osnap::draw(s_mid, m, 2, rng);
    let g = Mat::randn_sketch(s, s_mid, rng);
    let second = Sketch::from_op(s, s_mid, Op::Gaussian(g));
    Sketch::from_op(s, m, Op::Composed { first: Box::new(first), second: Box::new(second) })
}

/// General composition helper (exposed for Algorithm 3's Ω̃ = Ωᵀ G_Cᵀ and
/// Ψ̃ = G_R Ψ constructions, where the caller picks both stages).
pub fn compose(first: Sketch, second: Sketch) -> Sketch {
    assert_eq!(second.in_dim(), first.out_dim(), "compose: inner dims mismatch");
    let (s, m) = (second.out_dim(), first.in_dim());
    Sketch::from_op(s, m, Op::Composed { first: Box::new(first), second: Box::new(second) })
}
