//! Matrix sketching library — all five families from Section 2.3 of the
//! paper plus the OSNAP∘Gaussian composition recommended by Remark 1.
//!
//! A [`Sketch`] is a realized random linear map `S ∈ R^{s×m}`. The two
//! operations the algorithms need are
//!
//! * `apply_left(A)`  → `S · A`   (sketching the row space / rows of A),
//! * `apply_right(A)` → `A · Sᵀ`  (sketching the column space),
//!
//! with `O(nnz)`-time specializations for CSR inputs where the family
//! admits them (sampling, CountSketch, OSNAP), an `O(mn log s)`-style
//! fast Walsh–Hadamard path for SRHT, and dense matmul for Gaussian.
//!
//! Scalings follow Lemma 1's conventions: every family satisfies
//! `E[SᵀS] = I`, so singular values are preserved in expectation and the
//! subspace-embedding property (property 1) holds with the sketch sizes
//! of Table 1 — which `tests::subspace_embedding_*` verify empirically.

mod combined;
mod count;
mod gaussian;
mod leverage;
mod osnap;
mod srht;

pub use combined::compose as compose_sketches;
pub use leverage::{
    column_leverage_scores, row_leverage_scores, subspace_column_leverage_scores,
    subspace_row_leverage_scores,
};

use crate::error::{FgError, Result};
use crate::linalg::Mat;
use crate::parallel::Pool;
use crate::rng::Pcg64;
use crate::sparse::Csr;

/// Which sketching family to use (bench/config-facing descriptor).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SketchKind {
    /// Dense i.i.d. N(0, 1/s) projection.
    Gaussian,
    /// Uniform row sampling with replacement, scaled 1/sqrt(s p_i).
    Uniform,
    /// Leverage-score row sampling (scores must be supplied).
    Leverage,
    /// Subsampled randomized Hadamard transform.
    Srht,
    /// CountSketch: one ±1 per column of S.
    Count,
    /// OSNAP with `p` nonzeros per column (we default p = 2).
    Osnap,
    /// Gaussian ∘ OSNAP composition (Remark 1): OSNAP to an intermediate
    /// dimension, then a dense Gaussian to the final size.
    OsnapGaussian,
}

/// The accepted CLI/config tokens, kept next to [`SketchKind::parse`] so
/// `--help` text and error messages cannot drift apart (the same pattern
/// as `cur::SELECTION_TOKENS`).
pub const SKETCH_TOKENS: &str = "gaussian|gauss | uniform | leverage|lev | srht|hadamard | \
                                 count|countsketch | osnap | osnap-gaussian|osnapgaussian|combined";

impl SketchKind {
    /// CLI/config token → sketch family. Unknown tokens are a hard
    /// [`FgError::Config`] listing the accepted values — a silent
    /// fallback would benchmark a family the user did not ask for.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "gaussian" | "gauss" => Self::Gaussian,
            "uniform" => Self::Uniform,
            "leverage" | "lev" => Self::Leverage,
            "srht" | "hadamard" => Self::Srht,
            "count" | "countsketch" => Self::Count,
            "osnap" => Self::Osnap,
            "osnap-gaussian" | "osnapgaussian" | "combined" => Self::OsnapGaussian,
            other => {
                return Err(FgError::Config(format!(
                    "unknown sketch kind `{other}` (accepted: {SKETCH_TOKENS})"
                )))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Gaussian => "gaussian",
            Self::Uniform => "uniform",
            Self::Leverage => "leverage",
            Self::Srht => "srht",
            Self::Count => "count",
            Self::Osnap => "osnap",
            Self::OsnapGaussian => "osnap-gaussian",
        }
    }

    /// All kinds, for table sweeps.
    pub fn all() -> [SketchKind; 7] {
        [
            Self::Gaussian,
            Self::Uniform,
            Self::Leverage,
            Self::Srht,
            Self::Count,
            Self::Osnap,
            Self::OsnapGaussian,
        ]
    }
}

/// Internal realized operator.
pub(crate) enum Op {
    Gaussian(Mat),
    /// Row sampling: out row t = `scale[t] * A[idx[t], :]`.
    Sampling { idx: Vec<usize>, scale: Vec<f64> },
    /// SRHT: signs (±1, length m), sampled indices into the padded
    /// Hadamard domain, padded = next power of two >= m.
    Srht { signs: Vec<f64>, sample: Vec<usize>, padded: usize, scale: f64 },
    /// CountSketch: for input coordinate i, add `sign[i]*row_i` to `bucket[i]`.
    Count { bucket: Vec<usize>, sign: Vec<f64> },
    /// OSNAP: p entries per input coordinate; flattened (m*p) arrays.
    Osnap { buckets: Vec<usize>, signs: Vec<f64>, p: usize },
    /// Composition second ∘ first (first applied to the data first).
    Composed { first: Box<Sketch>, second: Box<Sketch> },
    /// Vertical stack of independently drawn blocks: row block `b` of `S`
    /// is `blocks[b]` (all sharing the input dimension `m`). Produced by
    /// [`Sketch::draw_extension`] so an escalating caller can grow `s`
    /// while keeping the already-drawn rows bitwise intact. Each block is
    /// normalized to `E[S_bᵀS_b] = I`, so the stack satisfies
    /// `E[SᵀS] = (#blocks)·I` — a global scalar that every pseudo-inverse
    /// solve in the crate is invariant to.
    Stacked(Vec<Sketch>),
}

/// A realized sketching matrix `S ∈ R^{s×m}`.
pub struct Sketch {
    s: usize,
    m: usize,
    pub(crate) op: Op,
}

impl Sketch {
    /// Draw a sketch of the given family. `scores` is required for
    /// [`SketchKind::Leverage`] (row leverage scores of the matrix whose
    /// row space must be preserved) and ignored otherwise.
    pub fn draw(kind: SketchKind, s: usize, m: usize, scores: Option<&[f64]>, rng: &mut Pcg64) -> Self {
        match kind {
            SketchKind::Gaussian => gaussian::draw(s, m, rng),
            SketchKind::Uniform => {
                let w = vec![1.0; m];
                leverage::draw_sampling(s, m, &w, rng)
            }
            SketchKind::Leverage => {
                let scores = scores.expect("leverage sketch requires scores");
                assert_eq!(scores.len(), m, "leverage scores length != m");
                leverage::draw_sampling(s, m, scores, rng)
            }
            SketchKind::Srht => srht::draw(s, m, rng),
            SketchKind::Count => count::draw(s, m, rng),
            SketchKind::Osnap => osnap::draw(s, m, 2, rng),
            SketchKind::OsnapGaussian => combined::draw_osnap_gaussian(s, m, rng),
        }
    }

    /// Draw a sketch of `s_total` rows whose first `s_base` rows are
    /// **bitwise identical** to `Sketch::draw(kind, s_base, m, …)` run on
    /// the same freshly seeded `rng` — the escalation primitive of the
    /// ε-planner ([`crate::plan`]).
    ///
    /// The extension replays a deterministic *block schedule* from the
    /// original seed: the first block is exactly the base draw, and each
    /// further block doubles the running total (`min(total, s_total −
    /// total)` rows), consuming the rng in the same order every time. Two
    /// calls with the same `(kind, s_base, m)` and totals on the same
    /// doubling path therefore agree bitwise on their common prefix —
    /// re-sketching larger never discards completed rows. `s_total ==
    /// s_base` degenerates to a plain [`Sketch::draw`].
    pub fn draw_extension(
        kind: SketchKind,
        s_base: usize,
        s_total: usize,
        m: usize,
        scores: Option<&[f64]>,
        rng: &mut Pcg64,
    ) -> Self {
        assert!(s_base > 0, "draw_extension: s_base must be positive");
        assert!(s_total >= s_base, "draw_extension: s_total {s_total} < s_base {s_base}");
        let mut blocks = vec![Self::draw(kind, s_base, m, scores, rng)];
        let mut total = s_base;
        while total < s_total {
            let b = total.min(s_total - total);
            blocks.push(Self::draw(kind, b, m, scores, rng));
            total += b;
        }
        if blocks.len() == 1 {
            return blocks.pop().expect("one block");
        }
        Self::from_op(total, m, Op::Stacked(blocks))
    }

    /// The row blocks if this sketch came from [`Sketch::draw_extension`]
    /// (`None` for single-block sketches). Lets the planner apply only
    /// the blocks beyond an already-computed prefix.
    pub(crate) fn stacked_blocks(&self) -> Option<&[Sketch]> {
        match &self.op {
            Op::Stacked(blocks) => Some(blocks),
            _ => None,
        }
    }

    pub(crate) fn from_op(s: usize, m: usize, op: Op) -> Self {
        Self { s, m, op }
    }

    /// The identity operator `S = I_m` as a (degenerate) sampling sketch:
    /// `apply_left`/`apply_right` return the input unchanged up to a
    /// copy. Lets sketched code paths degenerate *exactly* to their
    /// unsketched solves — `cur` uses it when a requested sketch size
    /// reaches the full dimension.
    pub fn identity(m: usize) -> Self {
        Self::from_op(m, m, Op::Sampling { idx: (0..m).collect(), scale: vec![1.0; m] })
    }

    /// Output dimension `s`.
    #[inline]
    pub fn out_dim(&self) -> usize {
        self.s
    }

    /// Input dimension `m`.
    #[inline]
    pub fn in_dim(&self) -> usize {
        self.m
    }

    /// `S · A` for dense `A` (m×n) → (s×n), sharded on the process-wide
    /// pool when the apply is big enough (see [`Sketch::apply_left_with`]).
    pub fn apply_left(&self, a: &Mat) -> Mat {
        self.apply_left_with(a, &Pool::current())
    }

    /// `S · A` with the sketch application sharded over row panels on
    /// `pool`:
    ///
    /// * Gaussian — parallel matmul (bitwise equal to serial: row panels
    ///   partition independent output rows),
    /// * SRHT — FWHT column strips sharded across workers (bitwise equal:
    ///   each output column is computed exactly as in the serial path),
    /// * CountSketch/OSNAP — input-row shards scatter into private
    ///   buckets, reduced in fixed shard order (deterministic for a given
    ///   thread count; agrees with serial to ~1e-15/element),
    /// * sampling — a gather, too cheap to shard.
    ///
    /// A pool with 1 thread reproduces the serial results bitwise.
    pub fn apply_left_with(&self, a: &Mat, pool: &Pool) -> Mat {
        assert_eq!(a.rows(), self.m, "apply_left: A has {} rows, sketch wants {}", a.rows(), self.m);
        let sharded = pool.threads() > 1 && self.m * a.cols() >= crate::parallel::PAR_MIN_WORK;
        match &self.op {
            Op::Gaussian(g) => {
                if pool.threads() > 1 && crate::parallel::worth_sharding(g.rows(), g.cols(), a.cols())
                {
                    crate::parallel::par_matmul_with(pool, g, a)
                } else {
                    crate::linalg::matmul_serial(g, a)
                }
            }
            Op::Sampling { idx, scale } => {
                let mut out = a.select_rows(idx);
                for (t, &sc) in scale.iter().enumerate() {
                    for v in out.row_mut(t) {
                        *v *= sc;
                    }
                }
                out
            }
            Op::Srht { signs, sample, padded, scale } => {
                srht::apply_left(a, signs, sample, *padded, *scale, pool)
            }
            Op::Count { bucket, sign } => {
                scatter_sharded(pool, sharded, self.m, self.s, a.cols(), |i0, i1, out| {
                    for i in i0..i1 {
                        let (b, sg) = (bucket[i], sign[i]);
                        let src = a.row(i);
                        let dst = out.row_mut(b);
                        for (d, &v) in dst.iter_mut().zip(src) {
                            *d += sg * v;
                        }
                    }
                })
            }
            Op::Osnap { buckets, signs, p } => {
                let p = *p;
                scatter_sharded(pool, sharded, self.m, self.s, a.cols(), |i0, i1, out| {
                    for i in i0..i1 {
                        let src = a.row(i);
                        for t in 0..p {
                            let (b, sg) = (buckets[i * p + t], signs[i * p + t]);
                            let dst = out.row_mut(b);
                            for (d, &v) in dst.iter_mut().zip(src) {
                                *d += sg * v;
                            }
                        }
                    }
                })
            }
            Op::Composed { first, second } => {
                second.apply_left_with(&first.apply_left_with(a, pool), pool)
            }
            Op::Stacked(blocks) => {
                stack_left(self.s, a.cols(), blocks, |b| b.apply_left_with(a, pool))
            }
        }
    }

    /// `S · A` for CSR `A` — `O(nnz)` for sampling/count/OSNAP families.
    pub fn apply_left_csr(&self, a: &Csr) -> Mat {
        assert_eq!(a.rows(), self.m, "apply_left_csr: dim mismatch");
        match &self.op {
            Op::Gaussian(g) => a.left_mul_dense(g),
            Op::Sampling { idx, scale } => a.select_rows_scaled_dense(idx, scale),
            Op::Srht { .. } => self.apply_left(&a.to_dense()),
            Op::Count { bucket, sign } => {
                let mut out = Mat::zeros(self.s, a.cols());
                for i in 0..self.m {
                    let (cols, vals) = a.row(i);
                    if cols.is_empty() {
                        continue;
                    }
                    let (b, sg) = (bucket[i], sign[i]);
                    let dst = out.row_mut(b);
                    for (&j, &v) in cols.iter().zip(vals) {
                        dst[j] += sg * v;
                    }
                }
                out
            }
            Op::Osnap { buckets, signs, p } => {
                let mut out = Mat::zeros(self.s, a.cols());
                for i in 0..self.m {
                    let (cols, vals) = a.row(i);
                    if cols.is_empty() {
                        continue;
                    }
                    for t in 0..*p {
                        let (b, sg) = (buckets[i * p + t], signs[i * p + t]);
                        let dst = out.row_mut(b);
                        for (&j, &v) in cols.iter().zip(vals) {
                            dst[j] += sg * v;
                        }
                    }
                }
                out
            }
            Op::Composed { first, second } => second.apply_left(&first.apply_left_csr(a)),
            Op::Stacked(blocks) => stack_left(self.s, a.cols(), blocks, |b| b.apply_left_csr(a)),
        }
    }

    /// `A · Sᵀ` for dense `A` (n×m) → (n×s), sharded on the process-wide
    /// pool when the apply is big enough.
    pub fn apply_right(&self, a: &Mat) -> Mat {
        self.apply_right_with(a, &Pool::current())
    }

    /// `A · Sᵀ` sharded over row panels of `A` on `pool`. Every family's
    /// output rows depend only on the matching input row, so the sharded
    /// result is bitwise equal to the serial one for any thread count.
    pub fn apply_right_with(&self, a: &Mat, pool: &Pool) -> Mat {
        assert_eq!(a.cols(), self.m, "apply_right: A has {} cols, sketch wants {}", a.cols(), self.m);
        let sharded = pool.threads() > 1 && a.rows() * self.m >= crate::parallel::PAR_MIN_WORK;
        match &self.op {
            Op::Gaussian(g) => {
                if pool.threads() > 1 && crate::parallel::worth_sharding(a.rows(), a.cols(), g.rows())
                {
                    crate::parallel::par_matmul_a_bt_with(pool, a, g)
                } else {
                    let mut out = Mat::zeros(a.rows(), g.rows());
                    crate::linalg::matmul_a_bt_panel(a, g, 0, a.rows(), out.data_mut());
                    out
                }
            }
            Op::Sampling { idx, scale } => {
                let mut out = a.select_cols(idx);
                for i in 0..out.rows() {
                    let row = out.row_mut(i);
                    for (t, &sc) in scale.iter().enumerate() {
                        row[t] *= sc;
                    }
                }
                out
            }
            Op::Srht { signs, sample, padded, scale } => {
                srht::apply_right(a, signs, sample, *padded, *scale, pool)
            }
            Op::Count { bucket, sign } => {
                let (rows, s, m) = (a.rows(), self.s, self.m);
                let mut out = Mat::zeros(rows, s);
                let shard_pool = if sharded { *pool } else { Pool::new(1) };
                shard_pool.run_row_panels(rows, s, out.data_mut(), |r0, r1, panel| {
                    for i in r0..r1 {
                        let src = a.row(i);
                        let dst = &mut panel[(i - r0) * s..(i - r0 + 1) * s];
                        for j in 0..m {
                            dst[bucket[j]] += sign[j] * src[j];
                        }
                    }
                });
                out
            }
            Op::Osnap { buckets, signs, p } => {
                let (rows, s, m, p) = (a.rows(), self.s, self.m, *p);
                let mut out = Mat::zeros(rows, s);
                let shard_pool = if sharded { *pool } else { Pool::new(1) };
                shard_pool.run_row_panels(rows, s, out.data_mut(), |r0, r1, panel| {
                    for i in r0..r1 {
                        let src = a.row(i);
                        let dst = &mut panel[(i - r0) * s..(i - r0 + 1) * s];
                        for j in 0..m {
                            for t in 0..p {
                                dst[buckets[j * p + t]] += signs[j * p + t] * src[j];
                            }
                        }
                    }
                });
                out
            }
            Op::Composed { first, second } => {
                second.apply_right_with(&first.apply_right_with(a, pool), pool)
            }
            Op::Stacked(blocks) => {
                stack_right(a.rows(), self.s, blocks, |b| b.apply_right_with(a, pool))
            }
        }
    }

    /// `A · Sᵀ` for CSR `A`.
    pub fn apply_right_csr(&self, a: &Csr) -> Mat {
        assert_eq!(a.cols(), self.m, "apply_right_csr: dim mismatch");
        match &self.op {
            Op::Gaussian(g) => {
                let mut out = Mat::zeros(a.rows(), self.s);
                for i in 0..a.rows() {
                    let (cols, vals) = a.row(i);
                    let dst = out.row_mut(i);
                    for (t, d) in dst.iter_mut().enumerate() {
                        let grow = g.row(t);
                        let mut acc = 0.0;
                        for (&j, &v) in cols.iter().zip(vals) {
                            acc += grow[j] * v;
                        }
                        *d = acc;
                    }
                }
                out
            }
            Op::Srht { .. } => self.apply_right(&a.to_dense()),
            Op::Sampling { idx, scale } => {
                let mut pos_of: std::collections::HashMap<usize, Vec<usize>> = Default::default();
                for (t, &j) in idx.iter().enumerate() {
                    pos_of.entry(j).or_default().push(t);
                }
                let mut out = Mat::zeros(a.rows(), self.s);
                for i in 0..a.rows() {
                    let (cols, vals) = a.row(i);
                    let dst = out.row_mut(i);
                    for (&j, &v) in cols.iter().zip(vals) {
                        if let Some(ts) = pos_of.get(&j) {
                            for &t in ts {
                                dst[t] = scale[t] * v;
                            }
                        }
                    }
                }
                out
            }
            Op::Count { bucket, sign } => {
                let mut out = Mat::zeros(a.rows(), self.s);
                for i in 0..a.rows() {
                    let (cols, vals) = a.row(i);
                    let dst = out.row_mut(i);
                    for (&j, &v) in cols.iter().zip(vals) {
                        dst[bucket[j]] += sign[j] * v;
                    }
                }
                out
            }
            Op::Osnap { buckets, signs, p } => {
                let mut out = Mat::zeros(a.rows(), self.s);
                for i in 0..a.rows() {
                    let (cols, vals) = a.row(i);
                    let dst = out.row_mut(i);
                    for (&j, &v) in cols.iter().zip(vals) {
                        for t in 0..*p {
                            dst[buckets[j * p + t]] += signs[j * p + t] * v;
                        }
                    }
                }
                out
            }
            Op::Composed { first, second } => second.apply_right(&first.apply_right_csr(a)),
            Op::Stacked(blocks) => {
                stack_right(a.rows(), self.s, blocks, |b| b.apply_right_csr(a))
            }
        }
    }

    /// Materialize `S` as a dense matrix (tests, artifact generation).
    pub fn to_dense(&self) -> Mat {
        let id = Mat::eye(self.m);
        self.apply_left(&id)
    }

    /// Restrict the sketch to the input coordinates `c0..c1` — i.e. the
    /// column slice `S[:, c0..c1]` as a new sketch on `c1 - c0` inputs.
    ///
    /// This is what makes sketches *streamable*: for a column block
    /// `A_L = A[:, c0..c1]`, `A · Sᵀ = Σ_blocks A_L · (S[:, c0..c1])ᵀ`,
    /// so the coordinator can consume blocks with a sliced sketch and
    /// accumulate. Supported for Gaussian, sampling, CountSketch, OSNAP,
    /// and compositions whose first stage is sliceable; SRHT mixes all
    /// coordinates globally and cannot be sliced (panics).
    pub fn slice_input(&self, c0: usize, c1: usize) -> Sketch {
        assert!(c0 <= c1 && c1 <= self.m, "slice_input out of bounds");
        let w = c1 - c0;
        let op = match &self.op {
            Op::Gaussian(g) => Op::Gaussian(g.slice(0, g.rows(), c0, c1)),
            Op::Sampling { idx, scale } => {
                // Rows sampling a coordinate outside the slice become zero
                // rows (index 0, scale 0 — exact).
                let mut nidx = Vec::with_capacity(idx.len());
                let mut nscale = Vec::with_capacity(scale.len());
                for (&i, &sc) in idx.iter().zip(scale) {
                    if i >= c0 && i < c1 {
                        nidx.push(i - c0);
                        nscale.push(sc);
                    } else {
                        nidx.push(0);
                        nscale.push(0.0);
                    }
                }
                Op::Sampling { idx: nidx, scale: nscale }
            }
            Op::Count { bucket, sign } => {
                Op::Count { bucket: bucket[c0..c1].to_vec(), sign: sign[c0..c1].to_vec() }
            }
            Op::Osnap { buckets, signs, p } => Op::Osnap {
                buckets: buckets[c0 * p..c1 * p].to_vec(),
                signs: signs[c0 * p..c1 * p].to_vec(),
                p: *p,
            },
            Op::Composed { first, second } => {
                let sliced = first.slice_input(c0, c1);
                return Sketch::from_op(
                    self.s,
                    w,
                    Op::Composed {
                        first: Box::new(sliced),
                        second: Box::new(Sketch::from_op(second.s, second.m, clone_op(&second.op))),
                    },
                );
            }
            Op::Stacked(blocks) => {
                Op::Stacked(blocks.iter().map(|b| b.slice_input(c0, c1)).collect())
            }
            Op::Srht { .. } => panic!("SRHT sketches cannot be input-sliced (global mixing)"),
        };
        Sketch::from_op(self.s, w, op)
    }
}

/// Vertically stack per-block `apply_left` results into `s_total×n`:
/// block `b`'s rows land at the offset of the blocks before it.
fn stack_left(s_total: usize, n: usize, blocks: &[Sketch], apply: impl Fn(&Sketch) -> Mat) -> Mat {
    let mut out = Mat::zeros(s_total, n);
    let mut r0 = 0;
    for blk in blocks {
        let part = apply(blk);
        for i in 0..part.rows() {
            out.row_mut(r0 + i).copy_from_slice(part.row(i));
        }
        r0 += blk.out_dim();
    }
    out
}

/// Horizontally stack per-block `apply_right` results into `rows×s_total`.
fn stack_right(rows: usize, s_total: usize, blocks: &[Sketch], apply: impl Fn(&Sketch) -> Mat) -> Mat {
    let mut out = Mat::zeros(rows, s_total);
    let mut c0 = 0;
    for blk in blocks {
        let part = apply(blk);
        let w = blk.out_dim();
        for i in 0..rows {
            out.row_mut(i)[c0..c0 + w].copy_from_slice(part.row(i));
        }
        c0 += w;
    }
    out
}

/// Shard a row-scatter `out = Σ_i contribution(i)` over contiguous
/// input-row panels: each shard accumulates into a private `s×n` buffer
/// (`body(i0, i1, buf)` adds rows `i0..i1`), and partials are reduced in
/// ascending shard order — deterministic for a fixed thread count, and
/// exactly the serial path when `sharded` is false or the pool has one
/// thread.
fn scatter_sharded(
    pool: &Pool,
    sharded: bool,
    m: usize,
    s: usize,
    n: usize,
    body: impl Fn(usize, usize, &mut Mat) + Sync,
) -> Mat {
    let mut shards = if sharded { pool.threads().min(m).max(1) } else { 1 };
    // Each shard zero-inits and later folds an s×n partial; unless the
    // per-shard scatter work (m/shards rows) dominates that buffer
    // traffic (s rows), the "parallel" path would cost more than the
    // serial scatter it replaces.
    if m < 2 * shards * s {
        shards = 1;
    }
    if shards <= 1 {
        let mut out = Mat::zeros(s, n);
        body(0, m, &mut out);
        return out;
    }
    let bounds = Pool::shard_bounds(m, shards);
    let mut partials: Vec<Mat> = (0..shards).map(|_| Mat::zeros(s, n)).collect();
    {
        let bounds = &bounds;
        let body = &body;
        pool.for_each_mut(&mut partials, |w, buf| body(bounds[w], bounds[w + 1], buf));
    }
    let mut it = partials.into_iter();
    let mut out = it.next().expect("at least one shard");
    for p in it {
        out += &p;
    }
    out
}

/// Deep-clone an op (sketches are cheap to clone except Gaussian).
fn clone_op(op: &Op) -> Op {
    match op {
        Op::Gaussian(g) => Op::Gaussian(g.clone()),
        Op::Sampling { idx, scale } => Op::Sampling { idx: idx.clone(), scale: scale.clone() },
        Op::Srht { signs, sample, padded, scale } => {
            Op::Srht { signs: signs.clone(), sample: sample.clone(), padded: *padded, scale: *scale }
        }
        Op::Count { bucket, sign } => Op::Count { bucket: bucket.clone(), sign: sign.clone() },
        Op::Osnap { buckets, signs, p } => {
            Op::Osnap { buckets: buckets.clone(), signs: signs.clone(), p: *p }
        }
        Op::Composed { first, second } => Op::Composed {
            first: Box::new(Sketch::from_op(first.s, first.m, clone_op(&first.op))),
            second: Box::new(Sketch::from_op(second.s, second.m, clone_op(&second.op))),
        },
        Op::Stacked(blocks) => Op::Stacked(blocks.to_vec()),
    }
}

impl Clone for Sketch {
    fn clone(&self) -> Self {
        Sketch::from_op(self.s, self.m, clone_op(&self.op))
    }
}

#[cfg(test)]
mod tests;
