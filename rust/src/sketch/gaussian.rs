//! Gaussian projection: dense `S` with i.i.d. N(0, 1/s) entries
//! (Section 2.3). Classic Johnson–Lindenstrauss; `E[SᵀS] = I`.

use super::{Op, Sketch};
use crate::linalg::Mat;
use crate::rng::Pcg64;

pub(crate) fn draw(s: usize, m: usize, rng: &mut Pcg64) -> Sketch {
    let g = Mat::randn_sketch(s, m, rng);
    Sketch::from_op(s, m, Op::Gaussian(g))
}
