//! Row-sampling sketches (uniform and leverage-score), plus leverage
//! score computation — Section 2.1: `ℓ_i = ‖Q_{i,:}‖²` for an orthonormal
//! basis Q of the column space — and the rank-k *subspace* restriction
//! `ℓ_i^{(k)} = ‖U_k(i,:)‖²` (Wang & Zhang 2013 flavour) that CUR
//! selection uses when the full-rank scores degenerate to uniform.

use super::{Op, Sketch};
use crate::linalg::{matmul, qr_thin, svd_jacobi, Mat};
use crate::rng::Pcg64;

/// Row leverage scores of `A` (m×n, m ≥ n typical): squared row norms of
/// the thin-QR `Q` factor. Sums to rank(A). The QR is the blocked
/// compact-WY kernel, so score computation on tall inputs rides the
/// pool-parallel matmul drivers.
pub fn row_leverage_scores(a: &Mat) -> Vec<f64> {
    let mut sp = crate::obs::span("leverage.scores", crate::obs::cat::FACTORIZE);
    sp.meta("rows", a.rows());
    sp.meta("cols", a.cols());
    let q = qr_thin(a).q;
    q.row_norms_sq()
}

/// Column leverage scores of `A` = row leverage scores of `Aᵀ`.
pub fn column_leverage_scores(a: &Mat) -> Vec<f64> {
    row_leverage_scores(&a.transpose())
}

/// Rank-`k` (subspace-restricted) row leverage scores:
/// `ℓ_i^{(k)} = ‖U_k(i,:)‖²` where `U_k` holds the top-`k` left singular
/// vectors of `A`. Sums to ≈ k.
///
/// Full-rank scores are useless on square-ish full-rank inputs — the
/// thin-QR `Q` is then orthogonal, so every score is exactly 1 — while
/// the rank-`k` restriction still separates the directions that carry
/// the spectral mass (the selection signal CUR needs). Computed as
/// thin-QR of `A` followed by an SVD of the small triangular factor
/// (`U_k = Q · Ū[:, :k]`), so the `O(mn²)` bulk rides the blocked
/// compact-WY kernel. `k` is clamped to `[1, min(m, n)]`.
pub fn subspace_row_leverage_scores(a: &Mat, k: usize) -> Vec<f64> {
    let mut sp = crate::obs::span("leverage.subspace_scores", crate::obs::cat::FACTORIZE);
    sp.meta("rows", a.rows());
    sp.meta("cols", a.cols());
    sp.meta("k", k);
    let k = k.max(1).min(a.rows().min(a.cols()).max(1));
    let fac = qr_thin(a);
    let svd = svd_jacobi(&fac.r);
    let uk = matmul(&fac.q, &svd.u.slice(0, svd.u.rows(), 0, k));
    uk.row_norms_sq()
}

/// Rank-`k` column leverage scores of `A` = rank-`k` row scores of `Aᵀ`
/// (`‖V_k(j,:)‖²` for the top-`k` right singular vectors).
pub fn subspace_column_leverage_scores(a: &Mat, k: usize) -> Vec<f64> {
    subspace_row_leverage_scores(&a.transpose(), k)
}

/// Sampling sketch with probabilities proportional to `weights`
/// (uniform sampling = all-ones weights). Row `t` of `S A` is
/// `A[idx_t, :] / sqrt(s * p_{idx_t})`, the standard unbiased scaling.
pub(crate) fn draw_sampling(s: usize, m: usize, weights: &[f64], rng: &mut Pcg64) -> Sketch {
    assert_eq!(weights.len(), m);
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "sampling sketch: weights sum to zero");
    // Guard against exactly-zero probabilities producing infinite scales:
    // mix in a tiny uniform floor (standard practice; changes p_i by <1e-9).
    let floor = total * 1e-12 / m as f64;
    let probs: Vec<f64> = weights.iter().map(|&w| (w + floor) / (total + floor * m as f64)).collect();
    let idx = rng.sample_weighted_many(&probs, s);
    let scale: Vec<f64> = idx.iter().map(|&i| 1.0 / ((s as f64) * probs[i]).sqrt()).collect();
    Sketch::from_op(s, m, Op::Sampling { idx, scale })
}
