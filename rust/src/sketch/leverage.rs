//! Row-sampling sketches (uniform and leverage-score), plus leverage
//! score computation (Section 2.1: `ℓ_i = ‖Q_{i,:}‖²` for an orthonormal
//! basis Q of the column space).

use super::{Op, Sketch};
use crate::linalg::{qr_thin, Mat};
use crate::rng::Pcg64;

/// Row leverage scores of `A` (m×n, m ≥ n typical): squared row norms of
/// the thin-QR `Q` factor. Sums to rank(A). The QR is the blocked
/// compact-WY kernel, so score computation on tall inputs rides the
/// pool-parallel matmul drivers.
pub fn row_leverage_scores(a: &Mat) -> Vec<f64> {
    let q = qr_thin(a).q;
    q.row_norms_sq()
}

/// Column leverage scores of `A` = row leverage scores of `Aᵀ`.
pub fn column_leverage_scores(a: &Mat) -> Vec<f64> {
    row_leverage_scores(&a.transpose())
}

/// Sampling sketch with probabilities proportional to `weights`
/// (uniform sampling = all-ones weights). Row `t` of `S A` is
/// `A[idx_t, :] / sqrt(s * p_{idx_t})`, the standard unbiased scaling.
pub(crate) fn draw_sampling(s: usize, m: usize, weights: &[f64], rng: &mut Pcg64) -> Sketch {
    assert_eq!(weights.len(), m);
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "sampling sketch: weights sum to zero");
    // Guard against exactly-zero probabilities producing infinite scales:
    // mix in a tiny uniform floor (standard practice; changes p_i by <1e-9).
    let floor = total * 1e-12 / m as f64;
    let probs: Vec<f64> = weights.iter().map(|&w| (w + floor) / (total + floor * m as f64)).collect();
    let idx = rng.sample_weighted_many(&probs, s);
    let scale: Vec<f64> = idx.iter().map(|&i| 1.0 / ((s as f64) * probs[i]).sqrt()).collect();
    Sketch::from_op(s, m, Op::Sampling { idx, scale })
}
