//! CountSketch (Clarkson–Woodruff 2013): each column of `S` has exactly
//! one nonzero, a random sign at a uniformly random row. Applying S to a
//! matrix costs `O(nnz)`.

use super::{Op, Sketch};
use crate::rng::Pcg64;

pub(crate) fn draw(s: usize, m: usize, rng: &mut Pcg64) -> Sketch {
    assert!(s > 0);
    let mut bucket = Vec::with_capacity(m);
    let mut sign = Vec::with_capacity(m);
    for _ in 0..m {
        bucket.push(rng.next_range(s));
        sign.push(rng.next_sign() as f64);
    }
    Sketch::from_op(s, m, Op::Count { bucket, sign })
}
