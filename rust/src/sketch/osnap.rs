//! OSNAP (Nelson–Nguyễn 2013): `p` nonzeros per column, each a random
//! sign scaled by `1/sqrt(p)`, at distinct uniformly random rows.
//! `p = O(1)` suffices per the paper (Algorithm 3 step 3 uses O(1)
//! nonzeros per column); we default to p = 2.

use super::{Op, Sketch};
use crate::rng::Pcg64;

pub(crate) fn draw(s: usize, m: usize, p: usize, rng: &mut Pcg64) -> Sketch {
    assert!(p >= 1 && p <= s, "osnap: need 1 <= p <= s");
    let inv = 1.0 / (p as f64).sqrt();
    let mut buckets = Vec::with_capacity(m * p);
    let mut signs = Vec::with_capacity(m * p);
    for _ in 0..m {
        let rows = rng.sample_without_replacement(s, p);
        for t in 0..p {
            buckets.push(rows[t]);
            signs.push(rng.next_sign() as f64 * inv);
        }
    }
    Sketch::from_op(s, m, Op::Osnap { buckets, signs, p })
}
