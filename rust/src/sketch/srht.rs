//! Subsampled Randomized Hadamard Transform:
//! `S = sqrt(m̃/s) · P · H · D` with `H` the orthonormal Walsh–Hadamard
//! matrix on the zero-padded dimension `m̃ = 2^⌈log2 m⌉`, `D` random ±1
//! diagonal, `P` a uniform row sampler. Applying to an m×n matrix costs
//! `O(m̃ n log m̃)` via the in-place fast Walsh–Hadamard transform.
//!
//! Parallelism: the FWHT mixes *within* a column (`apply_left`) or row
//! (`apply_right`), never across them, so columns/rows shard perfectly —
//! each worker transforms a disjoint strip with a private padded buffer
//! and the sharded result is bitwise equal to the serial one.

use super::{Op, Sketch};
use crate::linalg::Mat;
use crate::parallel::Pool;
use crate::rng::Pcg64;

pub(crate) fn draw(s: usize, m: usize, rng: &mut Pcg64) -> Sketch {
    let padded = m.next_power_of_two();
    let signs: Vec<f64> = (0..m).map(|_| rng.next_sign() as f64).collect();
    let sample: Vec<usize> = (0..s).map(|_| rng.next_range(padded)).collect();
    // H is orthonormal (entries ±1/sqrt(padded)); uniform sampling of s of
    // padded rows needs sqrt(padded/s) to keep E[SᵀS] = I.
    let scale = ((padded as f64) / (s as f64)).sqrt();
    Sketch::from_op(s, m, Op::Srht { signs, sample, padded, scale })
}

/// In-place fast Walsh–Hadamard transform of a buffer whose length is a
/// power of two (unnormalized butterflies; caller divides by sqrt(len)).
pub(crate) fn fwht(buf: &mut [f64]) {
    let n = buf.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        for block in (0..n).step_by(h * 2) {
            for i in block..block + h {
                let (x, y) = (buf[i], buf[i + h]);
                buf[i] = x + y;
                buf[i + h] = x - y;
            }
        }
        h *= 2;
    }
}

/// `S · A`: sign-flip rows, FWHT each column over the padded domain,
/// select sampled rows with scaling. Column strips are sharded across
/// `pool`'s workers when the apply is big enough.
pub(crate) fn apply_left(
    a: &Mat,
    signs: &[f64],
    sample: &[usize],
    padded: usize,
    scale: f64,
    pool: &Pool,
) -> Mat {
    let (m, n) = a.shape();
    let s = sample.len();
    let shardable = pool.threads() > 1 && n >= 2 && m * n >= crate::parallel::PAR_MIN_WORK;
    if !shardable {
        return apply_left_cols(a, signs, sample, padded, scale, 0, n);
    }
    let shards = pool.threads().min(n);
    let bounds = Pool::shard_bounds(n, shards);
    // Each shard transforms its own column range into a private s×w
    // piece; pieces land in disjoint column blocks of the output (no
    // reduction, hence bitwise equality with the serial path).
    let mut pieces: Vec<(usize, Mat)> =
        bounds.windows(2).map(|w| (w[0], Mat::zeros(0, 0))).collect();
    {
        let bounds = &bounds;
        pool.for_each_mut(&mut pieces, |w, piece| {
            piece.1 = apply_left_cols(a, signs, sample, padded, scale, bounds[w], bounds[w + 1]);
        });
    }
    let mut out = Mat::zeros(s, n);
    for (j0, piece) in &pieces {
        out.set_block(0, *j0, piece);
    }
    out
}

/// Serial worker for [`apply_left`]: transform columns `j0..j1` of A,
/// returning the `s × (j1-j0)` output block.
fn apply_left_cols(
    a: &Mat,
    signs: &[f64],
    sample: &[usize],
    padded: usize,
    scale: f64,
    j0: usize,
    j1: usize,
) -> Mat {
    let m = a.rows();
    let s = sample.len();
    let width = j1 - j0;
    let norm = 1.0 / (padded as f64).sqrt();
    let mut out = Mat::zeros(s, width);
    // Process columns in strips to stay cache-friendly: transform a strip
    // of `W` columns at once, walking the FWHT over rows.
    const W: usize = 32;
    let mut strip = vec![0.0f64; padded * W.min(width.max(1))];
    for c0 in (0..width).step_by(W) {
        let w = W.min(width - c0);
        // Load strip (row-major a → column-strip buffer, padded with 0).
        strip[..padded * w].iter_mut().for_each(|v| *v = 0.0);
        for i in 0..m {
            let arow = &a.row(i)[j0 + c0..j0 + c0 + w];
            let sg = signs[i];
            for (jj, &v) in arow.iter().enumerate() {
                strip[jj * padded + i] = sg * v;
            }
        }
        for jj in 0..w {
            let col = &mut strip[jj * padded..(jj + 1) * padded];
            fwht(col);
            for (t, &src) in sample.iter().enumerate() {
                out[(t, c0 + jj)] = col[src] * norm * scale;
            }
        }
    }
    out
}

/// `A · Sᵀ` where S sketches the column dimension of A: sign-flip
/// columns, FWHT each row, select sampled coordinates. Rows shard
/// perfectly (each worker keeps a private padded buffer), bitwise equal
/// to the serial path.
pub(crate) fn apply_right(
    a: &Mat,
    signs: &[f64],
    sample: &[usize],
    padded: usize,
    scale: f64,
    pool: &Pool,
) -> Mat {
    let (m, n) = a.shape();
    let s = sample.len();
    let norm = 1.0 / (padded as f64).sqrt();
    let mut out = Mat::zeros(m, s);
    let shardable = pool.threads() > 1 && m >= 2 && m * n >= crate::parallel::PAR_MIN_WORK;
    let shard_pool = if shardable { *pool } else { Pool::new(1) };
    shard_pool.run_row_panels(m, s, out.data_mut(), |r0, r1, panel| {
        let mut buf = vec![0.0f64; padded];
        for i in r0..r1 {
            buf.fill(0.0);
            for (j, &v) in a.row(i).iter().enumerate() {
                buf[j] = signs[j] * v;
            }
            fwht(&mut buf);
            let orow = &mut panel[(i - r0) * s..(i - r0 + 1) * s];
            for (t, &src) in sample.iter().enumerate() {
                orow[t] = buf[src] * norm * scale;
            }
        }
    });
    out
}
