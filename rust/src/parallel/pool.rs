//! The worker pool: deterministic contiguous sharding on scoped
//! `std::thread` workers.
//!
//! A [`Pool`] is a *shard plan*, not a set of live threads: each parallel
//! region spawns its workers inside a `std::thread::scope`, which keeps
//! every borrow safe without `unsafe` lifetime laundering and joins all
//! workers (propagating panics) before the region returns. Spawn cost is
//! tens of microseconds per region — noise next to the panel products the
//! regions guard, which are threshold-gated in `parallel::mod`.
//!
//! Determinism contract: shards are *contiguous ascending* ranges fixed
//! by `(len, threads)` alone — never by scheduling — so any reduction
//! performed in shard order is reproducible run-to-run for a given
//! thread count, and `threads = 1` executes the exact serial code path.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Process-wide thread-count override; 0 means "auto-detect".
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread cap on the worker budget; 0 means "no cap". Installed
    /// by coordinator layers that own several executor threads (the
    /// router) so each executor's nested pool regions use only its share
    /// of the process-wide knob instead of all of it.
    static THREAD_BUDGET: Cell<usize> = const { Cell::new(0) };
}

fn detected_parallelism() -> usize {
    static DETECTED: OnceLock<usize> = OnceLock::new();
    *DETECTED.get_or_init(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// The calling thread's effective worker count: the value set by
/// [`set_threads`] (or the machine's available parallelism when unset),
/// capped by any per-thread budget installed with [`set_thread_budget`].
/// `threads = 1` still reproduces single-threaded results bitwise —
/// a budget can only shrink the count, never raise it.
pub fn threads() -> usize {
    let base = match THREAD_OVERRIDE.load(Ordering::Relaxed) {
        0 => detected_parallelism(),
        n => n,
    };
    match THREAD_BUDGET.with(|b| b.get()) {
        0 => base,
        cap => base.min(cap),
    }
}

/// Set the process-wide worker count (the `threads` knob: CLI
/// `--threads N`, config `[parallel] threads`). 0 restores auto-detect;
/// 1 reproduces the single-threaded code paths bitwise.
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Cap the *calling thread's* worker budget (0 clears the cap). An
/// executor thread that runs pool-hungry jobs concurrently with its
/// siblings installs its share of the knob here once at startup; every
/// `Pool::current()` region it opens afterwards — directly or deep
/// inside `linalg`/`sketch` dispatch — is then bounded by that share, so
/// `N_workers × threads` never oversubscribes the machine. The cap is
/// thread-local and does not propagate to threads the pool spawns (panel
/// workers run serial kernels and open no nested regions).
pub fn set_thread_budget(n: usize) {
    THREAD_BUDGET.with(|b| b.set(n));
}

/// The calling thread's budget cap (0 = none). See [`set_thread_budget`].
pub fn thread_budget() -> usize {
    THREAD_BUDGET.with(|b| b.get())
}

/// Executor `w`'s share when a `total`-thread budget is split across
/// `shares` sibling executors: remainder-aware (the first `total %
/// shares` executors get one extra) and floored at 1 so every executor
/// can always make progress. Mirrors the pipeline's per-slot split.
pub fn share_budget(total: usize, shares: usize, w: usize) -> usize {
    let shares = shares.max(1);
    (total / shares + usize::from(w % shares < total % shares)).max(1)
}

/// A shard plan over a fixed number of workers.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// A pool with an explicit worker count (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// A pool sized by the process-wide `threads` knob.
    pub fn current() -> Self {
        Self::new(threads())
    }

    /// Worker count of this pool.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Deterministic contiguous shard boundaries: `shards + 1` ascending
    /// cut points over `0..len`, the first `len % shards` shards one
    /// element longer (so remainders never starve a trailing panel).
    pub fn shard_bounds(len: usize, shards: usize) -> Vec<usize> {
        let shards = shards.max(1);
        let (base, rem) = (len / shards, len % shards);
        let mut bounds = Vec::with_capacity(shards + 1);
        let mut at = 0usize;
        bounds.push(at);
        for s in 0..shards {
            at += base + usize::from(s < rem);
            bounds.push(at);
        }
        bounds
    }

    /// Run `f(r0, r1, panel)` over disjoint contiguous row panels of
    /// `out` (a `rows × row_len` row-major buffer), one scoped worker per
    /// panel. With one shard (or one row) this degenerates to a plain
    /// inline call — the exact serial path.
    pub fn run_row_panels<F>(&self, rows: usize, row_len: usize, out: &mut [f64], f: F)
    where
        F: Fn(usize, usize, &mut [f64]) + Sync,
    {
        assert_eq!(out.len(), rows * row_len, "run_row_panels: buffer is not rows*row_len");
        let shards = self.threads.min(rows);
        if shards <= 1 {
            f(0, rows, out);
            return;
        }
        let bounds = Self::shard_bounds(rows, shards);
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest = out;
            for w in bounds.windows(2) {
                let (r0, r1) = (w[0], w[1]);
                let (panel, tail) = rest.split_at_mut((r1 - r0) * row_len);
                rest = tail;
                scope.spawn(move || f(r0, r1, panel));
            }
        });
    }

    /// Run `f(i, &mut items[i])` for every item, items partitioned into
    /// contiguous chunks across workers. Chunk boundaries come from
    /// [`Pool::shard_bounds`], so the item→worker mapping is
    /// deterministic.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        let shards = self.threads.min(n);
        if shards <= 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let bounds = Self::shard_bounds(n, shards);
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest = items;
            for w in bounds.windows(2) {
                let (i0, i1) = (w[0], w[1]);
                let (chunk, tail) = rest.split_at_mut(i1 - i0);
                rest = tail;
                scope.spawn(move || {
                    for (off, item) in chunk.iter_mut().enumerate() {
                        f(i0 + off, item);
                    }
                });
            }
        });
    }
}
