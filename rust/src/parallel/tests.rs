//! Determinism tests for the parallel layer: `threads = 1` and
//! `threads = N` must agree — bitwise for the row-panel matmul drivers,
//! ≤ 1e-12 for the ordered-reduction scatter paths — including odd sizes
//! where rows don't divide the shard count (remainder panels).

use super::*;
use crate::compute::{Backend, CpuBackend};
use crate::gmr::{solve_fast, FastGmrConfig, Input};
use crate::linalg::matmul;
use crate::rng::rng;
use crate::sketch::{Sketch, SketchKind};
use crate::testing::assert_close;

#[test]
fn shard_bounds_cover_and_balance() {
    for (len, shards) in [(10usize, 3usize), (97, 4), (5, 8), (16, 1), (0, 3), (7, 7)] {
        let b = Pool::shard_bounds(len, shards);
        assert_eq!(b.len(), shards.max(1) + 1);
        assert_eq!(b[0], 0);
        assert_eq!(*b.last().unwrap(), len);
        for w in b.windows(2) {
            assert!(w[0] <= w[1]);
            // Balanced to within one element.
            assert!(w[1] - w[0] <= len / shards.max(1) + 1);
        }
    }
}

/// Row panels partition independent output rows, so the parallel matmul
/// must be *bitwise* identical to the serial kernel for any thread
/// count — including 97 rows over 4/7 shards (remainder panels).
#[test]
fn par_matmul_bitwise_matches_serial_all_thread_counts() {
    let mut r = rng(1);
    let a = crate::linalg::Mat::randn(97, 64, &mut r);
    let b = crate::linalg::Mat::randn(64, 53, &mut r);
    let serial = par_matmul_with(&Pool::new(1), &a, &b);
    assert_close(&serial, &matmul(&a, &b), 1e-12, "serial driver vs matmul");
    for t in [2usize, 3, 4, 7] {
        let par = par_matmul_with(&Pool::new(t), &a, &b);
        assert_eq!(serial.data(), par.data(), "par_matmul not bitwise equal at threads={t}");
    }
}

/// The `Aᵀ·B` scatter kernel shards over output rows (columns of A);
/// each worker streams A's rows in the same ascending order over its
/// private column strip, so the result is bitwise equal to serial for
/// any thread count — including 53 output rows over 3/5/7 shards.
#[test]
fn par_matmul_at_b_bitwise_matches_serial_all_thread_counts() {
    let mut r = rng(6);
    let a = crate::linalg::Mat::randn(83, 53, &mut r);
    let b = crate::linalg::Mat::randn(83, 31, &mut r);
    let serial = par_matmul_at_b_with(&Pool::new(1), &a, &b);
    assert_close(&serial, &matmul(&a.transpose(), &b), 1e-12, "serial driver vs reference");
    for t in [2usize, 3, 5, 7] {
        let par = par_matmul_at_b_with(&Pool::new(t), &a, &b);
        assert_eq!(serial.data(), par.data(), "par_matmul_at_b not bitwise equal at threads={t}");
    }
}

/// The per-thread budget caps `threads()` on the installing thread only;
/// other threads (including this one) are unaffected, and clearing the
/// budget restores the process-wide knob.
#[test]
fn thread_budget_caps_calling_thread_only() {
    let handle = std::thread::spawn(|| {
        set_thread_budget(1);
        let capped = threads();
        set_thread_budget(0);
        (capped, thread_budget())
    });
    let (capped, cleared) = handle.join().unwrap();
    assert_eq!(capped, 1, "budget of 1 must cap threads() to 1");
    assert_eq!(cleared, 0, "set_thread_budget(0) must clear the cap");
    assert_eq!(thread_budget(), 0, "budget must not leak across threads");
}

#[test]
fn share_budget_splits_remainder_and_floors_at_one() {
    assert_eq!((0..3).map(|w| share_budget(8, 3, w)).sum::<usize>(), 8);
    assert_eq!((0..3).map(|w| share_budget(8, 3, w)).collect::<Vec<_>>(), vec![3, 3, 2]);
    assert_eq!((0..4).map(|w| share_budget(2, 4, w)).collect::<Vec<_>>(), vec![1, 1, 1, 1]);
    assert_eq!(share_budget(0, 4, 2), 1, "budget floors at one");
    assert_eq!(share_budget(5, 0, 0), 5, "zero shares clamps to one executor");
}

#[test]
fn par_matmul_a_bt_bitwise_matches_serial_all_thread_counts() {
    let mut r = rng(2);
    let a = crate::linalg::Mat::randn(61, 40, &mut r);
    let b = crate::linalg::Mat::randn(29, 40, &mut r);
    let serial = par_matmul_a_bt_with(&Pool::new(1), &a, &b);
    for t in [2usize, 3, 5] {
        let par = par_matmul_a_bt_with(&Pool::new(t), &a, &b);
        assert_eq!(serial.data(), par.data(), "par_matmul_a_bt not bitwise equal at threads={t}");
    }
}

/// Accumulating drivers must preserve pre-existing output contents.
#[test]
fn par_matmul_acc_accumulates() {
    let mut r = rng(3);
    let a = crate::linalg::Mat::randn(33, 17, &mut r);
    let b = crate::linalg::Mat::randn(17, 21, &mut r);
    let mut c1 = crate::linalg::Mat::randn(33, 21, &mut r);
    let mut c4 = c1.clone();
    par_matmul_acc(&Pool::new(1), &a, &b, &mut c1);
    par_matmul_acc(&Pool::new(4), &a, &b, &mut c4);
    assert_eq!(c1.data(), c4.data(), "accumulation not bitwise equal");
}

/// Sharded sketch application: Gaussian/SRHT are bitwise, CountSketch/
/// OSNAP reduce per-shard partials in fixed order (≤ 1e-12). Sizes are
/// above the sharding thresholds so threads > 1 actually shards, and 601
/// rows over 4 shards pins the remainder path.
#[test]
fn sketch_apply_threads_agree() {
    let mut r = rng(4);
    let a = crate::linalg::Mat::randn(601, 120, &mut r);
    let at = a.transpose(); // 120 x 601, for apply_right
    for kind in
        [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Count, SketchKind::Osnap, SketchKind::OsnapGaussian]
    {
        let mut rs = rng(40 + kind.name().len() as u64);
        let s = Sketch::draw(kind, 48, 601, None, &mut rs);
        let serial = sketch_apply(&Pool::new(1), &s, &a);
        let serial_r = s.apply_right_with(&at, &Pool::new(1));
        for t in [2usize, 4] {
            let par = sketch_apply(&Pool::new(t), &s, &a);
            assert_close(&par, &serial, 1e-12, &format!("apply_left {} threads={t}", kind.name()));
            let par_r = s.apply_right_with(&at, &Pool::new(t));
            assert_close(
                &par_r,
                &serial_r,
                1e-12,
                &format!("apply_right {} threads={t}", kind.name()),
            );
        }
    }
}

/// The process-wide knob end-to-end: matmul dispatch, the factorization
/// kernels (blocked QR, round-robin Jacobi SVD/eigh), the CPU backend's
/// rbf_block/twoside/stream_update, the sharded sparse products
/// (`Csr::spmm`/`spmm_t`), and a full `solve_fast` call must
/// agree between threads=1 and threads=4. Everything global-knob-touching
/// lives in this one test so concurrent tests never observe a knob value
/// they didn't set.
#[test]
fn global_threads_knob_end_to_end() {
    let be = CpuBackend;
    let run_all = || {
        let mut r = rng(5);
        let a = crate::linalg::Mat::randn(300, 240, &mut r);
        let x = crate::linalg::Mat::randn(220, 9, &mut r);
        let m = matmul(&a, &a.transpose().slice(0, 240, 0, 200));
        let k = be.rbf_block(&x, &x, 0.35).unwrap();
        let sc = crate::linalg::Mat::randn(40, 300, &mut r);
        let sr = crate::linalg::Mat::randn(44, 240, &mut r);
        let two = be.twoside_sketch(&sc, &a, &sr).unwrap();
        // Factorization layer: sizes above the pool gates (the blocked
        // QR's panel updates shard through the matmul drivers; the
        // Jacobi rounds shard their disjoint pairs / row chunks).
        let qr = crate::linalg::qr_thin(&a);
        let svd = crate::linalg::svd_jacobi(&a.slice(0, 300, 0, 80));
        let gram = {
            let s = a.slice(0, 300, 0, 150);
            crate::linalg::matmul_at_b(&s, &s)
        };
        let eig = crate::linalg::eigh(&gram);
        let mut rg = rng(6);
        let g_c = crate::linalg::Mat::randn(240, 12, &mut rg);
        let c = matmul(&a, &g_c);
        let g_r = crate::linalg::Mat::randn(10, 300, &mut rg);
        let rr = matmul(&g_r, &a);
        let mut rs = rng(7);
        let sol =
            solve_fast(Input::Dense(&a), &c, &rr, &FastGmrConfig::gaussian(60, 60), &mut rs);
        let mut rs2 = rng(7);
        let sol_count =
            solve_fast(Input::Dense(&a), &c, &rr, &FastGmrConfig::count(60, 60), &mut rs2);
        // ε-planner contract: escalation decisions compare sketched
        // residuals, so the certified outcome (attempt count, final
        // sizes, achieved residual) and the planned solution itself
        // must be bitwise invariant to the thread count.
        let eplan = crate::plan::EpsilonPlan::new(0.25).with_seed(0xE5);
        let (psol, pout) = crate::plan::solve_gmr_planned(
            Input::Dense(&a),
            &c,
            &rr,
            crate::sketch::SketchKind::Gaussian,
            crate::sketch::SketchKind::Gaussian,
            &eplan,
        );
        let pout_path =
            (pout.attempts, pout.s_c, pout.s_r, pout.attained, pout.achieved.to_bits());
        let mut rc = rng(8);
        let cur_cfg = crate::cur::CurConfig::fast(10, 10, 3);
        let cur = crate::cur::decompose(Input::Dense(&a), &cur_cfg, &mut rc);
        let mut rsc = rng(9);
        let mut stream = crate::svdstream::DenseColumnStream::new(&a, 64);
        let scur = crate::cur::streaming_cur(
            &mut stream,
            &crate::cur::StreamingCurConfig::fast(10, 10, 6, 3),
            &mut rsc,
        )
        .unwrap();
        // Retried stream contract: transient injected read faults plus
        // retry must be *bitwise* invisible — the fault trips before the
        // source advances, so each retry re-reads the block the failed
        // attempt would have yielded.
        let mut rsc_f = rng(9);
        let plan = std::sync::Arc::new(
            crate::faults::FaultPlan::new(0xFA17)
                .with_site(crate::faults::site::STREAM_READ, 0.5, 64),
        );
        let faulted = crate::faults::FaultyStream::new(
            crate::svdstream::DenseColumnStream::new(&a, 64),
            plan.clone(),
        );
        let mut retried = crate::faults::RetryStream::new(
            faulted,
            crate::faults::RetryPolicy {
                max_attempts: 8,
                base_backoff: std::time::Duration::from_micros(10),
                cap: std::time::Duration::from_micros(50),
            },
        );
        let scur_faulted = crate::cur::streaming_cur(
            &mut retried,
            &crate::cur::StreamingCurConfig::fast(10, 10, 6, 3),
            &mut rsc_f,
        )
        .unwrap();
        assert!(plan.injected() > 0, "the 50% stream-read plan must actually inject");
        assert_eq!(scur.cur.col_idx, scur_faulted.cur.col_idx, "retried stream drifted");
        assert_eq!(scur.cur.c.data(), scur_faulted.cur.c.data(), "retried stream drifted");
        assert_eq!(scur.cur.u.data(), scur_faulted.cur.u.data(), "retried stream drifted");
        assert_eq!(scur.cur.r.data(), scur_faulted.cur.r.data(), "retried stream drifted");
        // Sparse products above the nnz·n sharding floor (~10k nnz × 40
        // cols ≥ 2^18), so threads=4 actually shards the row panels.
        let mut rsp = rng(10);
        let sp = crate::data::synth_sparse(500, 400, 0.05, 12, &mut rsp);
        let bs = crate::linalg::Mat::randn(400, 40, &mut rsp);
        let bst = crate::linalg::Mat::randn(500, 40, &mut rsp);
        let spmm = sp.spmm(&bs);
        let spmm_t = sp.spmm_t(&bst);
        // Served CUR through the caching router: executors install
        // budget shares of the knob, and the artifact-cache hit must be
        // a bitwise clone of the cold compute it amortizes. A trace
        // collector rides along — the span structure the job records is
        // part of the thread-count-invariance contract below.
        let trace = std::sync::Arc::new(crate::obs::TraceCollector::new());
        let router = crate::coordinator::Router::with_config(&crate::coordinator::ServeConfig {
            workers: 2,
            cache_bytes: 64 << 20,
            trace: Some(trace.clone()),
            ..crate::coordinator::ServeConfig::service(2)
        });
        let serve_job = || crate::coordinator::ApproxJob::Cur {
            a: crate::coordinator::MatrixPayload::Dense(a.clone()),
            cfg: cur_cfg.clone(),
            seed: 21,
        };
        let crate::coordinator::JobResult::Cur { cur: served_cold } =
            router.submit(serve_job()).unwrap().wait().unwrap()
        else {
            panic!("wrong result kind")
        };
        let crate::coordinator::JobResult::Cur { cur: served } =
            router.submit(serve_job()).unwrap().wait().unwrap()
        else {
            panic!("wrong result kind")
        };
        assert_eq!(router.metrics.get("serve.cache.hits"), 1, "second submit must hit the cache");
        assert_eq!(served_cold.col_idx, served.col_idx, "cache hit not bitwise vs cold compute");
        assert_eq!(served_cold.c.data(), served.c.data(), "cache hit not bitwise vs cold compute");
        assert_eq!(served_cold.u.data(), served.u.data(), "cache hit not bitwise vs cold compute");
        assert_eq!(served_cold.r.data(), served.r.data(), "cache hit not bitwise vs cold compute");
        // Canonical structure strings of the recorded span forest: one
        // root (the second submit is a cache hit and never dispatches),
        // with the CUR phases nested under it. Spans live only on the
        // sequential executor thread, so the rendering must be identical
        // at any worker/thread count.
        let ts = trace.root_structures().join(";");
        assert!(ts.contains("cur.core"), "served CUR trace missing the core-solve span: {ts}");
        (
            m, k, two, qr, svd, eig, sol.x, sol_count.x, cur, scur, spmm, spmm_t, served, ts,
            psol.x, pout_path,
        )
    };

    set_threads(1);
    let (m1, k1, two1, qr1, svd1, eig1, x1, xc1, cur1, scur1, sp1, spt1, served1, ts1, px1, pp1) =
        run_all();
    set_threads(4);
    let (m4, k4, two4, qr4, svd4, eig4, x4, xc4, cur4, scur4, sp4, spt4, served4, ts4, px4, pp4) =
        run_all();
    set_threads(0); // restore auto-detect

    assert_eq!(m1.data(), m4.data(), "matmul dispatch not bitwise across thread counts");
    // Sparse contract: spmm rows are independent gathers; spmm_t workers
    // scan sparse rows in the serial ascending order over their private
    // output panels — both bitwise across thread counts.
    assert_eq!(sp1.data(), sp4.data(), "Csr::spmm not bitwise across thread counts");
    assert_eq!(spt1.data(), spt4.data(), "Csr::spmm_t not bitwise across thread counts");
    assert_eq!(k1.data(), k4.data(), "rbf_block not bitwise across thread counts");
    assert_eq!(two1.data(), two4.data(), "twoside_sketch not bitwise across thread counts");
    // Factorization contract: the blocked QR's bulk rides the bitwise
    // matmul drivers, and the Jacobi rounds apply disjoint-pair
    // rotations in fixed order — all three are bitwise across counts.
    assert_eq!(qr1.q.data(), qr4.q.data(), "qr_thin Q not bitwise across thread counts");
    assert_eq!(qr1.r.data(), qr4.r.data(), "qr_thin R not bitwise across thread counts");
    assert_eq!(svd1.u.data(), svd4.u.data(), "svd_jacobi U not bitwise across thread counts");
    assert_eq!(svd1.s, svd4.s, "svd_jacobi σ not bitwise across thread counts");
    assert_eq!(svd1.v.data(), svd4.v.data(), "svd_jacobi V not bitwise across thread counts");
    assert_eq!(eig1.values, eig4.values, "eigh values not bitwise across thread counts");
    assert_eq!(
        eig1.vectors.data(),
        eig4.vectors.data(),
        "eigh vectors not bitwise across thread counts"
    );
    assert_close(&x4, &x1, 1e-12, "solve_fast (gaussian) threads=1 vs 4");
    assert_close(&xc4, &xc1, 1e-12, "solve_fast (count) threads=1 vs 4");
    // Planner contract: the whole escalation path — attempts taken,
    // final sketch sizes, certification, and the achieved residual down
    // to its bits — plus the planned solution must not move with the
    // thread count.
    assert_eq!(pp1, pp4, "ε-planner escalation path not invariant across thread counts");
    assert_eq!(px1.data(), px4.data(), "planned GMR solution not bitwise across thread counts");
    // CUR contract: selection indices bitwise, core ≤ 1e-12 across counts.
    assert_eq!(cur1.col_idx, cur4.col_idx, "CUR column selection not bitwise across thread counts");
    assert_eq!(cur1.row_idx, cur4.row_idx, "CUR row selection not bitwise across thread counts");
    assert_eq!(cur1.c.data(), cur4.c.data(), "CUR column gather not bitwise across thread counts");
    assert_eq!(cur1.r.data(), cur4.r.data(), "CUR row gather not bitwise across thread counts");
    assert_close(&cur4.u, &cur1.u, 1e-12, "CUR core threads=1 vs 4");
    // Streaming CUR contract: the reservoir and all score draws consume
    // the rng in stream order on the driver thread, and the Gaussian
    // applies are bitwise — indices and retained columns must be bitwise
    // across thread counts, core and resolved rows ≤ 1e-12.
    assert_eq!(
        scur1.cur.col_idx,
        scur4.cur.col_idx,
        "streaming CUR column selection not bitwise across thread counts"
    );
    assert_eq!(
        scur1.cur.row_idx,
        scur4.cur.row_idx,
        "streaming CUR row selection not bitwise across thread counts"
    );
    assert_eq!(
        scur1.cur.c.data(),
        scur4.cur.c.data(),
        "streaming CUR retained columns not bitwise across thread counts"
    );
    assert_close(&scur4.cur.u, &scur1.cur.u, 1e-12, "streaming CUR core threads=1 vs 4");
    assert_close(&scur4.cur.r, &scur1.cur.r, 1e-12, "streaming CUR rows threads=1 vs 4");
    // Served CUR contract across thread counts mirrors the direct one:
    // the routed job runs under per-executor budget shares of the knob,
    // so its selection/gathers stay bitwise and the core stays ≤ 1e-12.
    assert_eq!(
        served1.col_idx,
        served4.col_idx,
        "served CUR column selection not bitwise across thread counts"
    );
    assert_eq!(
        served1.row_idx,
        served4.row_idx,
        "served CUR row selection not bitwise across thread counts"
    );
    assert_eq!(
        served1.c.data(),
        served4.c.data(),
        "served CUR column gather not bitwise across thread counts"
    );
    assert_eq!(
        served1.r.data(),
        served4.r.data(),
        "served CUR row gather not bitwise across thread counts"
    );
    assert_close(&served4.u, &served1.u, 1e-12, "served CUR core threads=1 vs 4");
    assert_eq!(ts1, ts4, "served CUR span structure not identical across thread counts");
}
