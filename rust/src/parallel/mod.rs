//! Parallel execution layer for the sketch hot paths.
//!
//! The paper's promise is that the sketched solve is cheap once the
//! sketch applications (`S_C A`, `A S_Rᵀ`, RBF blocks) are fast; this
//! module makes those applications use every core. It provides
//!
//! * [`Pool`] — a `std::thread`-based worker pool with deterministic
//!   contiguous row-panel sharding (no new dependencies),
//! * parallel drivers [`par_matmul`], [`par_matmul_a_bt`],
//!   [`par_matmul_at_b`], and a panel-sharded [`sketch_apply`],
//! * the process-wide `threads` knob ([`threads`]/[`set_threads`]) that
//!   `linalg::matmul`, the sketch library, [`crate::compute::CpuBackend`]
//!   and the streaming pipeline all consult. Default is the machine's
//!   available parallelism; `threads = 1` reproduces the single-threaded
//!   results bitwise.
//!
//! Determinism: matmul row panels partition an `i`-loop whose iterations
//! are independent, and the packed GEMM underneath (`linalg::matmul`)
//! accumulates every output element in an ascending-k chain that never
//! depends on panel bounds — so sharded products are **bitwise
//! identical** to the serial kernel for every thread count. The sparse
//! `Csr::spmm`/`spmm_t` products shard the same way (disjoint output-row
//! panels, fixed scan order). Scatter-style sketch applies
//! (CountSketch/OSNAP) accumulate per-shard partials and reduce them in
//! fixed shard order — deterministic for a given thread count and within
//! ~1e-15/element of the serial order (the `tests` module pins ≤ 1e-12).

mod pool;
#[cfg(test)]
mod tests;

pub use pool::{set_thread_budget, set_threads, share_budget, thread_budget, threads, Pool};

use crate::linalg::{matmul_a_bt_panel, matmul_acc_panel, matmul_at_b_panel, Mat};

/// Minimum fused-multiply-add count (`m·k·n`) before a matmul is worth
/// sharding — below this, thread spawn overhead dominates.
pub(crate) const PAR_FLOP_MIN: usize = 1 << 18;

/// Minimum output/input element count (`m·n`) before an elementwise or
/// scatter pass is worth sharding.
pub(crate) const PAR_MIN_WORK: usize = 1 << 14;

/// True when a `m×k · k×n` product is big enough to shard at all.
pub(crate) fn worth_sharding(m: usize, k: usize, n: usize) -> bool {
    m >= 2 && m.saturating_mul(k).saturating_mul(n) >= PAR_FLOP_MIN
}

/// Minimum C rows per sharded matmul worker. Each worker re-packs the
/// shared B panels into its own thread-local workspace — the packed
/// kernel's one duplicated cost, `O(k·n)` against the worker's
/// `O(rows·k·n)` compute — so a panel must hold enough rows to amortize
/// it: 16 rows (≥ 2 microkernel strips) keeps the duplicate pack under
/// ~7% of a worker's flops. Short-m products simply use fewer workers
/// (down to the serial inline path), which changes nothing numerically:
/// sharded runs are bitwise equal to serial at every worker count.
const MIN_PANEL_ROWS: usize = 16;

/// Worker count for an `m`-output-row sharded product on `pool`: the
/// pool's threads, capped so no panel falls below [`MIN_PANEL_ROWS`]
/// (1 = run the serial kernel inline).
fn panel_workers(pool: &Pool, m: usize) -> usize {
    pool.threads().min((m / MIN_PANEL_ROWS).max(1))
}

/// Dispatch predicate used by `linalg::matmul`/`matmul_a_bt`: shard when
/// the knob allows more than one thread and the product is big enough.
pub(crate) fn matmul_should_shard(m: usize, k: usize, n: usize) -> bool {
    threads() > 1 && worth_sharding(m, k, n)
}

/// `C = A · B` on the configured pool (row panels of A/C). Bitwise equal
/// to the serial kernel for every thread count.
pub fn par_matmul(a: &Mat, b: &Mat) -> Mat {
    par_matmul_with(&Pool::current(), a, b)
}

/// [`par_matmul`] on an explicit pool.
pub fn par_matmul_with(pool: &Pool, a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols(),
        b.rows(),
        "par_matmul: inner dims mismatch {}x{} * {}x{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
    let mut c = Mat::zeros(a.rows(), b.cols());
    par_matmul_acc(pool, a, b, &mut c);
    c
}

/// `C += A · B` with deterministic row-panel sharding: worker `s` owns
/// rows `bounds[s]..bounds[s+1]` of C and runs the serial packed kernel
/// on them (each worker packs its disjoint A strips — and its own copy
/// of the shared B panels — into its own thread-local workspace), so
/// every output row accumulates in exactly the serial k-order. Worker
/// count is capped so each panel keeps at least `MIN_PANEL_ROWS` rows
/// (amortizing the duplicated B pack); the cap never changes results,
/// only how many workers produce them.
pub fn par_matmul_acc(pool: &Pool, a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols(), b.rows(), "par_matmul_acc: inner dims mismatch");
    assert_eq!(c.rows(), a.rows(), "par_matmul_acc: output rows mismatch");
    assert_eq!(c.cols(), b.cols(), "par_matmul_acc: output cols mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let workers = panel_workers(pool, m);
    if workers <= 1 {
        matmul_acc_panel(a.data(), b.data(), c.data_mut(), m, k, n);
        return;
    }
    let (ad, bd) = (a.data(), b.data());
    Pool::new(workers).run_row_panels(m, n, c.data_mut(), |r0, r1, cpanel| {
        matmul_acc_panel(&ad[r0 * k..r1 * k], bd, cpanel, r1 - r0, k, n);
    });
}

/// `C = A · Bᵀ` on the configured pool (row panels of A/C; bitwise equal
/// to the serial kernel — C rows are independent dot-product sweeps).
pub fn par_matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    par_matmul_a_bt_with(&Pool::current(), a, b)
}

/// [`par_matmul_a_bt`] on an explicit pool.
pub fn par_matmul_a_bt_with(pool: &Pool, a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "par_matmul_a_bt: dims mismatch");
    let (m, n) = (a.rows(), b.rows());
    let mut c = Mat::zeros(m, n);
    let workers = panel_workers(pool, m);
    if workers <= 1 {
        matmul_a_bt_panel(a, b, 0, m, c.data_mut());
        return c;
    }
    Pool::new(workers).run_row_panels(m, n, c.data_mut(), |r0, r1, cpanel| {
        matmul_a_bt_panel(a, b, r0, r1, cpanel);
    });
    c
}

/// `C = Aᵀ · B` on the configured pool. Output-row panels are column
/// strips of A; each worker streams the rows of A in the same ascending
/// order over its private strip, so every output row accumulates in
/// exactly the serial order — bitwise equal for any thread count.
pub fn par_matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    par_matmul_at_b_with(&Pool::current(), a, b)
}

/// [`par_matmul_at_b`] on an explicit pool.
pub fn par_matmul_at_b_with(pool: &Pool, a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "par_matmul_at_b: dims mismatch");
    let (m, n) = (a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    let workers = panel_workers(pool, m);
    if workers <= 1 {
        matmul_at_b_panel(a, b, 0, m, c.data_mut());
        return c;
    }
    Pool::new(workers).run_row_panels(m, n, c.data_mut(), |r0, r1, panel| {
        matmul_at_b_panel(a, b, r0, r1, panel);
    });
    c
}

/// Panel-sharded sketch application `S · A` on an explicit pool —
/// Gaussian goes through [`par_matmul_with`], SRHT shards its FWHT
/// column strips, CountSketch/OSNAP scatter over input-row shards with
/// an ordered reduction.
pub fn sketch_apply(pool: &Pool, s: &crate::sketch::Sketch, a: &Mat) -> Mat {
    s.apply_left_with(a, pool)
}
