//! PCG-64 (XSL-RR 128/64) generator with the distribution helpers the
//! sketch library needs. Reference: O'Neill, "PCG: A Family of Simple
//! Fast Space-Efficient Statistically Good Algorithms for Random Number
//! Generation" (2014).

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;
const PCG_INC: u128 = 0x5851_f42d_4c95_7f2d_1405_7b7e_f767_814f;

/// 128-bit-state PCG generator producing 64-bit outputs.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second normal from the Box–Muller pair.
    spare_normal: Option<f64>,
}

impl Pcg64 {
    /// Seed from a single u64 via SplitMix64 expansion.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Self { state, inc, spare_normal: None };
        // Burn a few outputs so poor seeds decorrelate.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent stream (used to hand each worker its own rng).
    pub fn split(&mut self) -> Self {
        Self::seed_from_u64(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc | PCG_INC);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in [0, bound) (Lemire rejection).
    #[inline]
    pub fn next_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Rademacher sign: ±1 with equal probability.
    #[inline]
    pub fn next_sign(&mut self) -> i8 {
        if self.next_u64() & 1 == 0 {
            1
        } else {
            -1
        }
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Standard normal as f32.
    #[inline]
    pub fn next_normal_f32(&mut self) -> f32 {
        self.next_normal() as f32
    }

    /// Fill a slice with i.i.d. N(0, sigma^2) values.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.next_normal() as f32 * sigma;
        }
    }

    /// Fisher–Yates permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.next_range(i + 1);
            p.swap(i, j);
        }
        p
    }

    /// Sample `k` distinct indices from 0..n (k <= n), in random order.
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n} without replacement");
        if k * 4 >= n {
            let mut p = self.permutation(n);
            p.truncate(k);
            return p;
        }
        // Floyd's algorithm for k << n.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_range(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Sample one index proportional to the (nonnegative) weights.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must have positive sum");
        let target = self.next_f64() * total;
        let mut acc = 0.0;
        for (i, &w) in weights.iter().enumerate() {
            acc += w;
            if target < acc {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Alias-free batched weighted sampling with replacement: returns `k`
    /// indices drawn proportional to `weights`, using a cumulative table
    /// and binary search (O(n + k log n)).
    pub fn sample_weighted_many(&mut self, weights: &[f64], k: usize) -> Vec<usize> {
        let mut cum = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            debug_assert!(w >= 0.0);
            acc += w;
            cum.push(acc);
        }
        assert!(acc > 0.0, "weights must have positive sum");
        (0..k)
            .map(|_| {
                let t = self.next_f64() * acc;
                match cum.binary_search_by(|c| c.partial_cmp(&t).unwrap()) {
                    Ok(i) => (i + 1).min(weights.len() - 1),
                    Err(i) => i.min(weights.len() - 1),
                }
            })
            .collect()
    }
}
