//! Deterministic, dependency-free random number generation.
//!
//! The offline build vendors no `rand` crate, so the library ships its own
//! PCG-64 generator plus the distributions the sketching library needs
//! (uniform, normal, Rademacher signs, permutations, weighted index
//! sampling). Everything is seedable and reproducible across runs, which
//! the property-test harness and the benchmark sweeps rely on.

mod pcg;

pub use pcg::Pcg64;

/// Convenience constructor used across tests and benches.
pub fn rng(seed: u64) -> Pcg64 {
    Pcg64::seed_from_u64(seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = rng(42);
        let mut b = rng(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = rng(1);
        let mut b = rng(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = rng(7);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = rng(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 1e-2, "mean={mean}");
        assert!((var - 1.0).abs() < 2e-2, "var={var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = rng(3);
        for _ in 0..10_000 {
            let v = r.next_range(17);
            assert!(v < 17);
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = rng(5);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_sample_respects_weights() {
        let mut r = rng(9);
        let w = vec![0.0, 1.0, 3.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[3], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn rademacher_is_balanced() {
        let mut r = rng(13);
        let n = 100_000;
        let sum: i64 = (0..n).map(|_| r.next_sign() as i64).sum();
        assert!(sum.abs() < 2_000, "sum={sum}");
    }
}
