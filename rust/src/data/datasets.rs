//! Dataset registries mirroring the paper's Table 5 (GMR/SVD matrices)
//! and Table 6 (kernel datasets), with per-dataset generation.
//!
//! If a real LIBSVM file is present under `data/<name>` it is loaded
//! instead of the synthetic generator (shape-truncated to the spec), so
//! the benches run on real data when available and on matched synthetic
//! data otherwise. `scaled` shrinks the biggest datasets to single-core-
//! friendly sizes while preserving aspect ratio, sparsity and spectrum —
//! the substitution table in DESIGN.md records the exact mapping.

use super::synth::{synth_clustered, synth_dense, synth_sparse, SpectrumKind};
use super::{load_libsvm, rbf::calibrate_sigma};
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::sparse::Csr;

/// A Table 5 dataset: either dense or sparse.
pub struct DatasetSpec {
    pub name: &'static str,
    /// Paper's (m, n).
    pub paper_shape: (usize, usize),
    /// Shape actually used here (scaled for the 1-core container).
    pub run_shape: (usize, usize),
    /// None for dense, Some(density) for sparse.
    pub density: Option<f64>,
    pub spectrum: SpectrumKind,
}

/// A loaded dataset.
pub enum Dataset {
    Dense(Mat),
    Sparse(Csr),
}

impl DatasetSpec {
    /// Generate (or load, if `data/<name>.libsvm` exists).
    pub fn load(&self, rng: &mut Pcg64) -> Dataset {
        let path = format!("data/{}.libsvm", self.name);
        if std::path::Path::new(&path).exists() {
            if let Ok(d) = load_libsvm(&path) {
                let (m, n) = self.run_shape;
                return match self.density {
                    None => Dataset::Dense(d.features.to_dense_truncated(m, n)),
                    Some(_) => Dataset::Sparse(d.features.truncated(m, n)),
                };
            }
        }
        let (m, n) = self.run_shape;
        match self.density {
            None => Dataset::Dense(synth_dense(m, n, 60.min(m.min(n)), self.spectrum, 0.02, rng)),
            Some(d) => Dataset::Sparse(synth_sparse(m, n, d, 40, rng)),
        }
    }
}

/// Table 5 registry. svhn/real-sim are row-scaled (documented in
/// DESIGN.md §4); all other shapes match the paper exactly.
pub fn matrix_registry() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec {
            name: "gisette",
            paper_shape: (5_000, 6_000),
            run_shape: (5_000, 6_000),
            density: None,
            spectrum: SpectrumKind::Exponential { base: 0.93 },
        },
        DatasetSpec {
            name: "mnist",
            paper_shape: (60_000, 780),
            run_shape: (20_000, 780),
            density: None,
            spectrum: SpectrumKind::Exponential { base: 0.90 },
        },
        DatasetSpec {
            name: "svhn",
            paper_shape: (19_082, 3_072),
            run_shape: (8_000, 3_072),
            density: None,
            spectrum: SpectrumKind::Exponential { base: 0.94 },
        },
        DatasetSpec {
            name: "rcv1",
            paper_shape: (20_242, 50_236),
            run_shape: (20_242, 50_236),
            density: Some(0.0016),
            spectrum: SpectrumKind::PowerLaw { alpha: 0.9 },
        },
        DatasetSpec {
            name: "real-sim",
            paper_shape: (72_309, 20_958),
            run_shape: (36_000, 20_958),
            density: Some(0.0024),
            spectrum: SpectrumKind::PowerLaw { alpha: 0.9 },
        },
        DatasetSpec {
            name: "news20",
            paper_shape: (15_935, 62_061),
            run_shape: (15_935, 62_061),
            density: Some(0.0013),
            spectrum: SpectrumKind::PowerLaw { alpha: 1.0 },
        },
    ]
}

/// A Table 6 kernel dataset: feature matrix + the paper's η target.
pub struct KernelSpec {
    pub name: &'static str,
    /// Paper's (#instances, #attributes).
    pub paper_shape: (usize, usize),
    /// Shape used here.
    pub run_shape: (usize, usize),
    /// Paper's η = ‖K_k‖²_F/‖K‖²_F at k = 15.
    pub eta: f64,
    /// Cluster spread driving the synthetic kernel spectrum.
    pub spread: f64,
}

impl KernelSpec {
    /// Generate the feature matrix and calibrate σ to hit `eta` at k=15
    /// (the paper's procedure: "We choose σ such that η is above 0.6").
    pub fn load(&self, rng: &mut Pcg64) -> (Mat, f64) {
        let (n, d) = self.run_shape;
        let x = synth_clustered(n, d, 12, self.spread, rng);
        let sigma = calibrate_sigma(&x, 15, self.eta, rng);
        (x, sigma)
    }
}

/// Table 6 registry. gisette-kernel is dimension-scaled (5000-dim RBF
/// distances are dominated by noise; 800 dims give the same spectrum
/// after σ calibration). mushrooms/a5a row-scaled for the 1-core budget.
pub fn kernel_registry() -> Vec<KernelSpec> {
    vec![
        KernelSpec { name: "dna", paper_shape: (2_000, 180), run_shape: (2_000, 180), eta: 0.89, spread: 0.45 },
        KernelSpec { name: "gisette", paper_shape: (6_000, 5_000), run_shape: (3_000, 800), eta: 0.85, spread: 0.55 },
        KernelSpec { name: "madelon", paper_shape: (2_000, 500), run_shape: (2_000, 500), eta: 0.87, spread: 0.5 },
        KernelSpec { name: "mushrooms", paper_shape: (8_142, 112), run_shape: (4_000, 112), eta: 0.95, spread: 0.3 },
        KernelSpec { name: "splice", paper_shape: (1_000, 60), run_shape: (1_000, 60), eta: 0.83, spread: 0.6 },
        KernelSpec { name: "a5a", paper_shape: (6_414, 123), run_shape: (3_200, 123), eta: 0.63, spread: 0.95 },
    ]
}
