//! Synthetic matrix generators.
//!
//! The paper's experiments use six LIBSVM datasets (Table 5) purely as
//! sources of realistically-spectrum'd matrices; all reported quantities
//! (error ratio vs sketch size, ρ, η) depend only on shape, sparsity and
//! spectrum. These generators match those three properties (substitution
//! documented in DESIGN.md §4).

use crate::linalg::{qr_thin, Mat};
use crate::rng::Pcg64;
use crate::sparse::{Csr, Triplet};

/// Singular-value decay profile.
#[derive(Clone, Copy, Debug)]
pub enum SpectrumKind {
    /// σ_i = base^i (geometric decay — clean low-rank structure, like
    /// image/pixel datasets such as mnist/svhn).
    Exponential { base: f64 },
    /// σ_i = 1 / (1+i)^alpha (power-law — heavy tail, like text/tf-idf
    /// datasets such as rcv1/news20).
    PowerLaw { alpha: f64 },
}

impl SpectrumKind {
    pub fn value(&self, i: usize) -> f64 {
        match self {
            SpectrumKind::Exponential { base } => base.powi(i as i32),
            SpectrumKind::PowerLaw { alpha } => 1.0 / ((1 + i) as f64).powf(*alpha),
        }
    }
}

/// Dense m×n matrix with the given singular-value profile over an
/// `inner`-dimensional core plus white noise at `noise` relative scale.
///
/// Construction: `A = U diag(σ) Vᵀ + noise·‖σ‖/√(mn) · E` with Haar U, V
/// on an `inner`-dim subspace — O(mn·inner) to build.
pub fn synth_dense(
    m: usize,
    n: usize,
    inner: usize,
    spectrum: SpectrumKind,
    noise: f64,
    rng: &mut Pcg64,
) -> Mat {
    let inner = inner.min(m.min(n));
    let u = qr_thin(&Mat::randn(m, inner, rng)).q;
    let v = qr_thin(&Mat::randn(n, inner, rng)).q;
    let sigmas: Vec<f64> = (0..inner).map(|i| spectrum.value(i)).collect();
    let mut us = u;
    for j in 0..inner {
        for i in 0..m {
            us[(i, j)] *= sigmas[j];
        }
    }
    let mut a = crate::linalg::matmul_a_bt(&us, &v);
    if noise > 0.0 {
        let sig_norm: f64 = sigmas.iter().map(|s| s * s).sum::<f64>().sqrt();
        let scale = noise * sig_norm / ((m * n) as f64).sqrt();
        for v in a.data_mut() {
            *v += scale * rng.next_normal();
        }
    }
    a
}

/// Sparse m×n matrix with target `density` and a latent low-rank +
/// power-law structure: nonzero positions follow per-column popularity
/// (Zipf-like, mimicking bag-of-words), values from a low-rank latent
/// model plus noise so the spectrum has a decaying head.
pub fn synth_sparse(m: usize, n: usize, density: f64, inner: usize, rng: &mut Pcg64) -> Csr {
    let target_nnz = ((m as f64) * (n as f64) * density).round() as usize;
    // Column popularity ~ 1/(rank)^0.8 (word-frequency-like).
    let col_w: Vec<f64> = (0..n).map(|j| 1.0 / ((1 + j) as f64).powf(0.8)).collect();
    // Latent factors for the values.
    let uf = Mat::randn(m, inner, rng);
    let vf = Mat::randn(n, inner, rng);
    let decay: Vec<f64> = (0..inner).map(|t| 0.75f64.powi(t as i32)).collect();

    let mut seen = std::collections::HashSet::with_capacity(target_nnz * 2);
    let mut trips = Vec::with_capacity(target_nnz);
    let col_cum: Vec<f64> = {
        let mut acc = 0.0;
        col_w
            .iter()
            .map(|w| {
                acc += w;
                acc
            })
            .collect()
    };
    let total_w = *col_cum.last().unwrap();
    let mut attempts = 0usize;
    while trips.len() < target_nnz && attempts < target_nnz * 20 {
        attempts += 1;
        let i = rng.next_range(m);
        let t = rng.next_f64() * total_w;
        let j = match col_cum.binary_search_by(|c| c.partial_cmp(&t).unwrap()) {
            Ok(p) => (p + 1).min(n - 1),
            Err(p) => p.min(n - 1),
        };
        if !seen.insert((i, j)) {
            continue;
        }
        let mut val = 0.0;
        for (t, &d) in decay.iter().enumerate() {
            val += d * uf[(i, t)] * vf[(j, t)];
        }
        val += 0.3 * rng.next_normal();
        trips.push(Triplet { row: i, col: j, val });
    }
    Csr::from_triplets(m, n, trips)
}

/// Gaussian-mixture feature matrix (n points × d dims) with `centers`
/// clusters at `spread` within-cluster std — the kernel datasets of
/// Table 6 (clustered data → near-low-rank RBF kernel, which is what the
/// paper's η ≥ 0.6 calibration expresses).
pub fn synth_clustered(n: usize, d: usize, centers: usize, spread: f64, rng: &mut Pcg64) -> Mat {
    let c = Mat::randn(centers, d, rng);
    let mut x = Mat::zeros(n, d);
    for i in 0..n {
        let ci = i % centers;
        for j in 0..d {
            x[(i, j)] = c[(ci, j)] + spread * rng.next_normal();
        }
    }
    x
}
