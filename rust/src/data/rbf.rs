//! RBF kernels and the paper's σ calibration.
//!
//! §6.2: `K_ij = exp(−σ‖x_i − x_j‖²)`; σ is chosen so that
//! `η = ‖K_k‖²_F / ‖K‖²_F = Σ_{i≤k} λ_i² / Σ_i λ_i²` (k = 15) matches the
//! per-dataset values of Table 6.

use crate::linalg::{matmul_a_bt, svd_randomized, Mat};
use crate::rng::Pcg64;

/// Materialize the full RBF kernel (benches/tests; O(n²d)).
pub fn rbf_kernel(x: &Mat, sigma: f64) -> Mat {
    let n = x.rows();
    let norms = x.row_norms_sq();
    let mut k = Mat::zeros(n, n);
    const B: usize = 256;
    for i0 in (0..n).step_by(B) {
        let i1 = (i0 + B).min(n);
        let xi = x.slice(i0, i1, 0, x.cols());
        let cross = matmul_a_bt(&xi, x); // (i1-i0) x n
        for (oi, i) in (i0..i1).enumerate() {
            let crow = cross.row(oi);
            let krow = k.row_mut(i);
            for j in 0..n {
                let d2 = (norms[i] + norms[j] - 2.0 * crow[j]).max(0.0);
                krow[j] = (-sigma * d2).exp();
            }
        }
    }
    k
}

/// Estimate η(σ) = ‖K_k‖²_F/‖K‖²_F on a row subsample (kernels of
/// subsampled point sets have near-identical spectral mass fractions).
pub fn eta_for_sigma(x: &Mat, sigma: f64, k: usize, rng: &mut Pcg64) -> f64 {
    let n_sub = x.rows().min(600);
    let idx = rng.sample_without_replacement(x.rows(), n_sub);
    let xs = x.select_rows(&idx);
    let kmat = rbf_kernel(&xs, sigma);
    let svd = svd_randomized(&kmat, k, 10, 4, rng);
    let top: f64 = svd.s.iter().map(|s| s * s).sum();
    top / kmat.fro_norm_sq()
}

/// Bisection on log σ to hit the target η at rank k (the paper's Table 6
/// calibration). Monotone: larger σ → more local kernel → flatter
/// spectrum → smaller η.
pub fn calibrate_sigma(x: &Mat, k: usize, eta_target: f64, rng: &mut Pcg64) -> f64 {
    // Normalize by the mean pairwise distance scale first.
    let scale = {
        let n_sub = x.rows().min(200);
        let idx = rng.sample_without_replacement(x.rows(), n_sub);
        let xs = x.select_rows(&idx);
        let norms = xs.row_norms_sq();
        let cross = matmul_a_bt(&xs, &xs);
        let mut acc = 0.0;
        let mut cnt = 0.0;
        for i in 0..n_sub {
            for j in 0..n_sub {
                if i != j {
                    acc += (norms[i] + norms[j] - 2.0 * cross[(i, j)]).max(0.0);
                    cnt += 1.0;
                }
            }
        }
        (acc / cnt).max(1e-12)
    };
    let mut lo = 0.01 / scale; // very global → η ~ 1
    let mut hi = 100.0 / scale; // very local → η ~ k/n
    for _ in 0..24 {
        let mid = (lo * hi).sqrt();
        let eta = eta_for_sigma(x, mid, k, rng);
        if eta > eta_target {
            lo = mid;
        } else {
            hi = mid;
        }
        if (hi / lo) < 1.02 {
            break;
        }
    }
    (lo * hi).sqrt()
}
