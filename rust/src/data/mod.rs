//! Data substrate: synthetic generators matched to the paper's datasets
//! (Tables 5–6), a LIBSVM-format reader for real files when present, and
//! RBF-kernel construction with the paper's η-based σ calibration.

pub mod datasets;
mod libsvm;
mod rbf;
pub mod synth;

pub use datasets::{kernel_registry, matrix_registry, Dataset, DatasetSpec, KernelSpec};
pub use libsvm::{load_libsvm, LibsvmData};
pub use rbf::{calibrate_sigma, eta_for_sigma, rbf_kernel};
pub use synth::{synth_clustered, synth_dense, synth_sparse, SpectrumKind};

#[cfg(test)]
mod tests;
