//! Data-substrate tests.

use super::*;
use crate::linalg::{svd_randomized, Mat};
use crate::rng::rng;

#[test]
fn synth_dense_has_target_spectrum() {
    let mut r = rng(1);
    let a = synth_dense(200, 150, 30, SpectrumKind::Exponential { base: 0.8 }, 0.0, &mut r);
    let svd = svd_randomized(&a, 10, 10, 6, &mut r);
    for i in 0..10 {
        let want = 0.8f64.powi(i as i32);
        let rel = (svd.s[i] - want).abs() / want;
        assert!(rel < 0.05, "sigma_{i}: got {} want {want}", svd.s[i]);
    }
}

#[test]
fn synth_dense_noise_raises_tail() {
    let mut r = rng(2);
    let clean = synth_dense(100, 80, 10, SpectrumKind::PowerLaw { alpha: 1.0 }, 0.0, &mut r);
    let mut r2 = rng(2);
    let noisy = synth_dense(100, 80, 10, SpectrumKind::PowerLaw { alpha: 1.0 }, 0.5, &mut r2);
    assert!(noisy.fro_norm() > clean.fro_norm());
}

#[test]
fn synth_sparse_hits_density() {
    let mut r = rng(3);
    let a = synth_sparse(500, 400, 0.01, 10, &mut r);
    let d = a.density();
    assert!((d - 0.01).abs() < 0.002, "density {d}");
    assert_eq!(a.shape(), (500, 400));
}

#[test]
fn registries_are_complete() {
    let mats = matrix_registry();
    assert_eq!(mats.len(), 6);
    let names: Vec<&str> = mats.iter().map(|d| d.name).collect();
    assert_eq!(names, ["gisette", "mnist", "svhn", "rcv1", "real-sim", "news20"]);
    // Dense trio then sparse trio, as in Table 5.
    assert!(mats[..3].iter().all(|d| d.density.is_none()));
    assert!(mats[3..].iter().all(|d| d.density.is_some()));

    let kernels = kernel_registry();
    assert_eq!(kernels.len(), 6);
    assert!(kernels.iter().all(|k| k.eta > 0.6 && k.eta < 1.0));
}

#[test]
fn small_dataset_loads() {
    let mut r = rng(4);
    // Shrink a spec for test speed.
    let spec = DatasetSpec {
        name: "test-dense",
        paper_shape: (100, 80),
        run_shape: (100, 80),
        density: None,
        spectrum: SpectrumKind::Exponential { base: 0.9 },
    };
    match spec.load(&mut r) {
        super::datasets::Dataset::Dense(a) => assert_eq!(a.shape(), (100, 80)),
        _ => panic!("expected dense"),
    }
    let spec_sp = DatasetSpec {
        name: "test-sparse",
        paper_shape: (100, 80),
        run_shape: (100, 80),
        density: Some(0.05),
        spectrum: SpectrumKind::PowerLaw { alpha: 1.0 },
    };
    match spec_sp.load(&mut r) {
        super::datasets::Dataset::Sparse(a) => {
            assert_eq!(a.shape(), (100, 80));
            assert!(a.nnz() > 0);
        }
        _ => panic!("expected sparse"),
    }
}

#[test]
fn rbf_kernel_is_valid() {
    let mut r = rng(5);
    let x = Mat::randn(50, 6, &mut r);
    let k = rbf_kernel(&x, 0.3);
    assert_eq!(k.shape(), (50, 50));
    for i in 0..50 {
        assert!((k[(i, i)] - 1.0).abs() < 1e-12, "diagonal must be 1");
        for j in 0..50 {
            assert!(k[(i, j)] > 0.0 && k[(i, j)] <= 1.0 + 1e-12);
            assert!((k[(i, j)] - k[(j, i)]).abs() < 1e-12, "symmetry");
        }
    }
    // PSD check via eigenvalues.
    let e = crate::linalg::eigh(&k);
    assert!(e.values.iter().all(|&w| w > -1e-8), "RBF kernel must be PSD");
}

#[test]
fn sigma_calibration_hits_eta() {
    let mut r = rng(6);
    let x = super::synth::synth_clustered(400, 20, 8, 0.4, &mut r);
    let target = 0.85;
    let sigma = calibrate_sigma(&x, 15, target, &mut r);
    let eta = eta_for_sigma(&x, sigma, 15, &mut r);
    assert!((eta - target).abs() < 0.08, "eta {eta} target {target} (sigma {sigma})");
    // Monotonicity: bigger σ → smaller η.
    let eta_hi = eta_for_sigma(&x, sigma * 8.0, 15, &mut r);
    assert!(eta_hi < eta, "eta not monotone: {eta_hi} !< {eta}");
}

#[test]
fn libsvm_roundtrip() {
    let path = "/tmp/fastgmr_test.libsvm";
    std::fs::write(path, "1 1:0.5 3:2.0\n-1 2:1.5\n1 1:1.0 4:-0.25\n").unwrap();
    let d = load_libsvm(path).unwrap();
    assert_eq!(d.labels, vec![1.0, -1.0, 1.0]);
    assert_eq!(d.features.rows, 3);
    assert_eq!(d.features.cols, 4);
    let dense = d.features.to_dense_truncated(3, 4);
    assert_eq!(dense[(0, 0)], 0.5);
    assert_eq!(dense[(0, 2)], 2.0);
    assert_eq!(dense[(1, 1)], 1.5);
    assert_eq!(dense[(2, 3)], -0.25);
    // Truncation.
    let small = d.features.truncated(2, 2);
    assert_eq!(small.shape(), (2, 2));
    assert_eq!(small.nnz(), 2);
    std::fs::remove_file(path).ok();
}

#[test]
fn libsvm_rejects_zero_index() {
    let path = "/tmp/fastgmr_test_bad.libsvm";
    std::fs::write(path, "1 0:0.5\n").unwrap();
    assert!(load_libsvm(path).is_err());
    std::fs::remove_file(path).ok();
}
