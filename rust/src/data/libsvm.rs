//! LIBSVM sparse-format reader (`label idx:val idx:val ...`, 1-based
//! indices). Used when real dataset files are dropped into `data/`;
//! otherwise the synthetic generators stand in.

use crate::error::{FgError, Result};
use crate::linalg::Mat;
use crate::sparse::{Csr, Triplet};
use std::io::BufRead;

/// Parsed LIBSVM file.
pub struct LibsvmData {
    pub labels: Vec<f64>,
    pub features: SparseFeatures,
}

/// Row-major sparse feature holder with truncation helpers.
pub struct SparseFeatures {
    pub rows: usize,
    pub cols: usize,
    pub trips: Vec<Triplet>,
}

impl SparseFeatures {
    /// First `m` rows / `n` cols as CSR.
    pub fn truncated(&self, m: usize, n: usize) -> Csr {
        let trips: Vec<Triplet> = self
            .trips
            .iter()
            .filter(|t| t.row < m && t.col < n)
            .copied()
            .collect();
        Csr::from_triplets(m.min(self.rows), n.min(self.cols), trips)
    }

    /// Dense truncation.
    pub fn to_dense_truncated(&self, m: usize, n: usize) -> Mat {
        self.truncated(m, n).to_dense()
    }
}

/// Parse a LIBSVM file.
pub fn load_libsvm(path: &str) -> Result<LibsvmData> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let mut labels = Vec::new();
    let mut trips = Vec::new();
    let mut max_col = 0usize;
    for (row, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f64 = parts
            .next()
            .ok_or_else(|| FgError::Data(format!("{path}:{}: empty line", row + 1)))?
            .parse()
            .map_err(|_| FgError::Data(format!("{path}:{}: bad label", row + 1)))?;
        labels.push(label);
        for tok in parts {
            let colon = tok
                .find(':')
                .ok_or_else(|| FgError::Data(format!("{path}:{}: expected idx:val", row + 1)))?;
            let idx: usize = tok[..colon]
                .parse()
                .map_err(|_| FgError::Data(format!("{path}:{}: bad index", row + 1)))?;
            let val: f64 = tok[colon + 1..]
                .parse()
                .map_err(|_| FgError::Data(format!("{path}:{}: bad value", row + 1)))?;
            if idx == 0 {
                return Err(FgError::Data(format!("{path}:{}: LIBSVM indices are 1-based", row + 1)));
            }
            max_col = max_col.max(idx);
            trips.push(Triplet { row: labels.len() - 1, col: idx - 1, val });
        }
    }
    let rows = labels.len();
    Ok(LibsvmData { labels, features: SparseFeatures { rows, cols: max_col, trips } })
}
