//! Tiled kernel-entry oracle — the production form of Algorithm 2's
//! "observe O(c²/ε) entries" step.
//!
//! The SPSD algorithms request arbitrary `K[rows, cols]` blocks; this
//! oracle tiles each request into fixed-shape [`Backend::rbf_block`]
//! executions (padding the ragged edges), so on the PJRT backend every
//! kernel-entry computation runs through the AOT Pallas artifact. Entry
//! accounting matches [`crate::spsd::CountingOracle`] semantics: we count
//! *requested* entries (padding is overhead the §Perf bench measures, not
//! observation).

use crate::compute::Backend;
use crate::linalg::Mat;
use crate::spsd::KernelOracle;
use std::cell::Cell;

/// Kernel oracle that computes RBF entries through a compute backend in
/// fixed-size tiles.
pub struct TiledKernelOracle<'a> {
    /// Data points (n×d).
    pub x: &'a Mat,
    pub sigma: f64,
    backend: &'a dyn Backend,
    /// Tile edge (rows/cols per backend call).
    pub tile: usize,
    requested: Cell<u64>,
    tiles_executed: Cell<u64>,
}

impl<'a> TiledKernelOracle<'a> {
    pub fn new(x: &'a Mat, sigma: f64, backend: &'a dyn Backend, tile: usize) -> Self {
        assert!(tile > 0);
        Self { x, sigma, backend, tile, requested: Cell::new(0), tiles_executed: Cell::new(0) }
    }

    /// Entries requested by the algorithms (the Theorem 3 quantity).
    pub fn entries_requested(&self) -> u64 {
        self.requested.get()
    }

    /// Backend tile executions issued (padding overhead diagnostics).
    pub fn tiles_executed(&self) -> u64 {
        self.tiles_executed.get()
    }
}

impl<'a> KernelOracle for TiledKernelOracle<'a> {
    fn n(&self) -> usize {
        self.x.rows()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Mat {
        self.requested.set(self.requested.get() + (rows.len() * cols.len()) as u64);
        let mut out = Mat::zeros(rows.len(), cols.len());
        for r0 in (0..rows.len()).step_by(self.tile) {
            let r1 = (r0 + self.tile).min(rows.len());
            let xi = self.x.select_rows(&rows[r0..r1]);
            for c0 in (0..cols.len()).step_by(self.tile) {
                let c1 = (c0 + self.tile).min(cols.len());
                let xj = self.x.select_rows(&cols[c0..c1]);
                let blk = self
                    .backend
                    .rbf_block(&xi, &xj, self.sigma)
                    .expect("backend rbf_block failed");
                self.tiles_executed.set(self.tiles_executed.get() + 1);
                out.set_block(r0, c0, &blk);
            }
        }
        out
    }
}
