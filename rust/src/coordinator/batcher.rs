//! Batching: coalescing identical work into one execution.
//!
//! Two batching roles live here, both instances of the paper's
//! amortization story (one sketch serves many consumers):
//!
//! * [`Batcher`] — *cross-request* coalescing for the serving layer.
//!   Jobs submitted within a configurable window that share a
//!   [`CacheKey`] (same dataset fingerprint, same config, same seed) are
//!   collapsed onto one in-flight execution: the first submitter leads
//!   and computes, later identical submitters attach as waiters and
//!   receive clones of the leader's result. The sketch/factorization is
//!   computed once per burst instead of once per request.
//! * [`TiledKernelOracle`] — *intra-request* batching of kernel-entry
//!   observations into fixed-shape backend tiles (Algorithm 2's
//!   "observe O(c²/ε) entries" step through the AOT Pallas artifact).

use crate::compute::Backend;
use crate::coordinator::cache::CacheKey;
use crate::coordinator::jobs::JobResult;
use crate::error::{FgError, Result};
use crate::linalg::Mat;
use crate::spsd::KernelOracle;
use std::cell::Cell;
use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// What [`Batcher::join`] decided about a submission.
pub enum Admission {
    /// First in-flight submission for this key within the window: the
    /// caller must enqueue the job and, on completion, fan the result
    /// out via [`Batcher::complete`] (or release waiters with
    /// [`Batcher::abort`] if the job is shed before enqueueing).
    Lead,
    /// An identical job is already in flight and the window is open: the
    /// caller's reply sender has been attached to it, nothing to enqueue.
    Coalesced,
    /// An identical job is in flight but its window has closed: run this
    /// one independently (it is *not* registered, so its completion must
    /// not call [`Batcher::complete`]).
    Solo,
}

struct Pending {
    opened: Instant,
    waiters: Vec<(Sender<Result<JobResult>>, Instant)>,
}

/// Cross-request coalescer: identical in-flight jobs within a time
/// window share one execution.
///
/// Invariants (what makes the accounting race-free): an entry is
/// registered only by a `Lead` admission and removed only by that
/// leader's [`Batcher::complete`]/[`Batcher::abort`]; duplicates that
/// arrive after the window closes run `Solo` without touching the entry.
pub struct Batcher {
    window: Duration,
    inflight: Mutex<HashMap<CacheKey, Pending>>,
}

impl Batcher {
    /// A coalescer with the given window. `Duration::ZERO` disables
    /// coalescing: every join answers [`Admission::Lead`] or
    /// [`Admission::Solo`], never attaches waiters.
    pub fn new(window: Duration) -> Self {
        Self { window, inflight: Mutex::new(HashMap::new()) }
    }

    /// Admit a submission: lead, attach to an in-flight leader, or run
    /// solo. `submitted` is the waiter's arrival time (its end-to-end
    /// latency clock, returned by [`Batcher::complete`]).
    pub fn join(
        &self,
        key: CacheKey,
        reply: &Sender<Result<JobResult>>,
        submitted: Instant,
    ) -> Admission {
        let mut map = self.inflight.lock().unwrap();
        match map.get_mut(&key) {
            Some(p) if self.window > Duration::ZERO && p.opened.elapsed() < self.window => {
                p.waiters.push((reply.clone(), submitted));
                Admission::Coalesced
            }
            Some(_) => Admission::Solo,
            None => {
                map.insert(key, Pending { opened: Instant::now(), waiters: Vec::new() });
                Admission::Lead
            }
        }
    }

    /// Release a leader's entry without a result (the job was shed at
    /// admission): waiters coalesced in the meantime are failed with
    /// [`FgError::Overloaded`] at the given queue depth.
    pub fn abort(&self, key: &CacheKey, depth: usize) {
        if let Some(p) = self.inflight.lock().unwrap().remove(key) {
            for (tx, _) in p.waiters {
                let _ = tx.send(Err(FgError::Overloaded { depth }));
            }
        }
    }

    /// Fan a leader's result out to every coalesced waiter (clones on
    /// success, a variant-preserving [`FgError::echo`] on failure — a
    /// follower of a panicked leader sees the same `Runtime` error the
    /// leader's submitter does, not a generic coordinator failure) and
    /// return the waiters' submission instants so the caller can record
    /// their end-to-end latencies.
    pub fn complete(&self, key: &CacheKey, result: &Result<JobResult>) -> Vec<Instant> {
        let Some(p) = self.inflight.lock().unwrap().remove(key) else { return Vec::new() };
        let mut submitted = Vec::with_capacity(p.waiters.len());
        for (tx, t0) in p.waiters {
            let echo = match result {
                Ok(r) => Ok(r.clone()),
                Err(e) => Err(e.echo()),
            };
            let _ = tx.send(echo);
            submitted.push(t0);
        }
        submitted
    }
}

/// Kernel oracle that computes RBF entries through a compute backend in
/// fixed-size tiles.
pub struct TiledKernelOracle<'a> {
    /// Data points (n×d).
    pub x: &'a Mat,
    pub sigma: f64,
    backend: &'a dyn Backend,
    /// Tile edge (rows/cols per backend call).
    pub tile: usize,
    requested: Cell<u64>,
    tiles_executed: Cell<u64>,
}

impl<'a> TiledKernelOracle<'a> {
    pub fn new(x: &'a Mat, sigma: f64, backend: &'a dyn Backend, tile: usize) -> Self {
        assert!(tile > 0);
        Self { x, sigma, backend, tile, requested: Cell::new(0), tiles_executed: Cell::new(0) }
    }

    /// Entries requested by the algorithms (the Theorem 3 quantity).
    pub fn entries_requested(&self) -> u64 {
        self.requested.get()
    }

    /// Backend tile executions issued (padding overhead diagnostics).
    pub fn tiles_executed(&self) -> u64 {
        self.tiles_executed.get()
    }
}

impl<'a> KernelOracle for TiledKernelOracle<'a> {
    fn n(&self) -> usize {
        self.x.rows()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Mat {
        self.requested.set(self.requested.get() + (rows.len() * cols.len()) as u64);
        let mut out = Mat::zeros(rows.len(), cols.len());
        for r0 in (0..rows.len()).step_by(self.tile) {
            let r1 = (r0 + self.tile).min(rows.len());
            let xi = self.x.select_rows(&rows[r0..r1]);
            for c0 in (0..cols.len()).step_by(self.tile) {
                let c1 = (c0 + self.tile).min(cols.len());
                let xj = self.x.select_rows(&cols[c0..c1]);
                let blk = self
                    .backend
                    .rbf_block(&xi, &xj, self.sigma)
                    .expect("backend rbf_block failed");
                self.tiles_executed.set(self.tiles_executed.get() + 1);
                out.set_block(r0, c0, &blk);
            }
        }
        out
    }
}
