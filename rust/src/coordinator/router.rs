//! Job router and serving layer: a long-lived multi-worker service
//! executing [`ApproxJob`]s behind admission control, cross-request
//! batching, and a fingerprint-keyed artifact cache.
//!
//! The paper's algorithms are built to be *amortized*: one pair of
//! sketches answers many downstream queries (CUR, SPSD, streaming SVD).
//! A daemon serving approximation requests should therefore never
//! recompute what an earlier request already paid for. The submit path
//! enforces that, in order:
//!
//! ```text
//! submit ──► artifact cache ──► batcher ──► admission ──► queue ──► executor
//!             (hit: done)     (coalesce)    (or shed)
//! ```
//!
//! * **Cache** — completed factorizations keyed by
//!   [`CacheKey`] = dataset fingerprint × config digest
//!   ([`super::cache`]); a hit returns a bitwise-identical clone without
//!   touching the queue.
//! * **Batcher** — identical jobs in flight within the batch window
//!   share one execution ([`super::batcher::Batcher`]).
//! * **Admission** — a bounded submit queue sheds excess load with
//!   [`FgError::Overloaded`] instead of letting latency grow without
//!   bound; per-job deadlines fail stale work with
//!   [`FgError::DeadlineExceeded`] before it wastes an executor.
//!
//! Workers pull from a shared queue (single consumer lock on the
//! receiver), run the algorithm under `catch_unwind` (a panicking job
//! fails that job, not the daemon), and report per-kind latency into
//! [`Metrics`] — `router.<kind>.*` for executor-side counts and compute
//! latency, `serve.*` for the serving layer (hits, misses, evictions,
//! shed, coalesced, queue depth, end-to-end latency; naming convention
//! in the README §Serving).
//!
//! Each executor thread installs its share of the process-wide `threads`
//! knob as a per-thread pool budget
//! ([`crate::parallel::set_thread_budget`]) at startup, so the pool
//! regions its jobs open — matmul dispatch, sketch applies, CUR
//! selection — use `threads / workers` lanes each instead of all of
//! them. Without the cap, N workers running pool-hungry jobs would
//! oversubscribe the machine N×.

use super::batcher::{Admission, Batcher};
use super::cache::{job_key, ArtifactCache, CacheKey, Lookup};
use super::jobs::{ApproxJob, JobResult, MatrixPayload};
use crate::error::{panic_message, FgError, Result};
use crate::faults::{self, site, CircuitBreaker, FaultPlan, FaultyStream, RetryPolicy, RetryStream};
use crate::linalg::Mat;
use crate::metrics::Metrics;
use crate::obs::{self, TraceCollector};
use crate::rng::rng;
use crate::spsd::{CountingOracle, RbfOracle};
use crate::svdstream::source::{ColumnStream, CsrColumnStream, DenseColumnStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Handle to a submitted job.
pub struct JobHandle {
    rx: mpsc::Receiver<Result<JobResult>>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobResult> {
        self.rx
            .recv()
            .map_err(|_| FgError::Coordinator("router shut down before job completed".into()))?
    }

    /// Block until the job completes or `timeout` elapses, whichever
    /// comes first (elapsing maps to [`FgError::DeadlineExceeded`]; the
    /// job itself keeps running to completion on its executor).
    pub fn wait_timeout(self, timeout: Duration) -> Result<JobResult> {
        match self.rx.recv_timeout(timeout) {
            Ok(result) => result,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(FgError::DeadlineExceeded { waited_ms: timeout.as_millis() as u64 })
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(FgError::Coordinator("router shut down before job completed".into()))
            }
        }
    }
}

/// Serving-layer configuration for [`Router::with_config`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Executor threads (≥ 1).
    pub workers: usize,
    /// Submit-queue bound; `0` = unbounded (no load shedding).
    pub queue_depth: usize,
    /// Artifact-cache byte budget; `0` disables the cache.
    pub cache_bytes: usize,
    /// Artifact time-to-live in *logical cache ticks* (one per cache
    /// operation — deterministic, no wall clock); `0` = entries never
    /// expire. An expired entry counts as a miss, bumps
    /// `serve.cache.expired`, and is recomputed; the persisted
    /// inventory records insertion ticks so a warm start honors the
    /// TTL across restarts.
    pub cache_ttl: u64,
    /// Coalescing window for identical in-flight jobs;
    /// `Duration::ZERO` disables batching.
    pub batch_window: Duration,
    /// Deadline applied to every [`Router::submit`]; `None` = jobs
    /// never expire in the queue.
    pub default_deadline: Option<Duration>,
    /// Trace collector installed on every executor thread; `None`
    /// (the default) disables tracing at zero cost on the span path.
    pub trace: Option<Arc<TraceCollector>>,
    /// Retry policy for transient failures: stream-read errors inside
    /// streaming executors and panicking executor bodies (job-level
    /// re-execution). [`RetryPolicy::none`] (the [`ServeConfig::service`]
    /// default) fails on the first error, preserving plain-router
    /// semantics.
    pub retry: RetryPolicy,
    /// Graceful degradation: when admission would shed a job
    /// ([`FgError::Overloaded`]), re-plan it at a smaller sketch-size
    /// tier instead and tag the result [`JobResult::Degraded`] with its
    /// sketched relative residual. Jobs that cannot degrade (the exact
    /// baseline, or already at minimum) are still shed.
    pub degrade: bool,
    /// On-disk artifact-cache inventory: warm-started from this path at
    /// construction and persisted (crash-safely, temp file + rename) on
    /// shutdown/drop. `None` keeps the cache memory-only.
    pub cache_path: Option<PathBuf>,
    /// Write the configured trace collector's spans here when the
    /// router drains (Chrome trace-event JSON, or JSONL when the path
    /// ends in `.jsonl`). Flushed *before* [`Router::shutdown`]
    /// returns, so a caller that shuts down and aborts still has the
    /// trace.
    pub trace_path: Option<PathBuf>,
    /// Write the metrics registry here (Prometheus text exposition)
    /// when the router drains — same before-return guarantee as
    /// [`ServeConfig::trace_path`].
    pub metrics_path: Option<PathBuf>,
    /// Consecutive job-level failures (post-retry panics) of one kind
    /// that open that kind's circuit breaker; `0` disables breakers.
    pub breaker_threshold: u32,
    /// How long an open breaker rejects before admitting a half-open
    /// probe.
    pub breaker_cooldown: Duration,
    /// Deterministic fault-injection plan (chaos testing): installed on
    /// every executor thread via [`faults::install`] and consulted at the
    /// admission/persistence sites. `None` (the default) injects nothing
    /// at zero cost.
    pub faults: Option<Arc<FaultPlan>>,
    /// Accuracy SLO: target relative error ε enforced per job by the
    /// ε-planner ([`crate::plan::EpsilonPlan`]). When set, every planned
    /// job kind (fast GMR, CUR, streaming CUR, streaming SVD, SPSD
    /// kernel) sizes its sketches from ε and escalates geometrically
    /// until the a-posteriori check certifies `(1+ε)`; attempts show up
    /// in `serve.plan.*` counters and as `plan.attempt` spans in the
    /// trace. Degraded-tier jobs deliberately skip the planner —
    /// degradation trades accuracy for admission, and the
    /// [`JobResult::Degraded`] tag reports the estimated residual so the
    /// SLO is missed loudly, not silently. `None` (the default) keeps
    /// the config-sized execution paths.
    pub epsilon: Option<f64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self::service(2)
    }
}

impl ServeConfig {
    /// Plain job-router behavior (what [`Router::new`] uses): no cache,
    /// no batching, unbounded queue, no deadlines, no retries, no
    /// degradation, no breakers, no fault injection.
    pub fn service(workers: usize) -> Self {
        Self {
            workers,
            queue_depth: 0,
            cache_bytes: 0,
            cache_ttl: 0,
            batch_window: Duration::ZERO,
            default_deadline: None,
            trace: None,
            retry: RetryPolicy::none(),
            degrade: false,
            cache_path: None,
            trace_path: None,
            metrics_path: None,
            breaker_threshold: 0,
            breaker_cooldown: Duration::from_millis(100),
            faults: None,
            epsilon: None,
        }
    }
}

/// Pre-resolved `Arc<AtomicU64>` handles for every serving-layer
/// counter and gauge the submit/executor hot paths touch.
/// [`Metrics::add`] takes the registry map lock per increment; these
/// handles are the same atomics fetched once at router construction, so
/// per-job accounting is a lock-free `fetch_add`/`store`.
struct ServeCounters {
    cache_hits: Arc<AtomicU64>,
    cache_misses: Arc<AtomicU64>,
    /// Lookups that found a resident entry older than the TTL (also
    /// counted as misses — the request goes on to recompute).
    cache_expired: Arc<AtomicU64>,
    cache_evictions: Arc<AtomicU64>,
    cache_bytes: Arc<AtomicU64>,
    cache_entries: Arc<AtomicU64>,
    coalesced: Arc<AtomicU64>,
    shed: Arc<AtomicU64>,
    deadline_expired: Arc<AtomicU64>,
    queue_depth: Arc<AtomicU64>,
    queue_peak: Arc<AtomicU64>,
    /// Retries performed: stream-level (transient read errors absorbed
    /// by [`RetryStream`]) plus job-level (panicked executors re-run).
    retries: Arc<AtomicU64>,
    /// Jobs completed at a degraded sketch tier instead of being shed.
    degraded: Arc<AtomicU64>,
    /// Circuit-breaker open transitions (closed/half-open → open).
    breaker_open: Arc<AtomicU64>,
    /// Gauge mirroring [`FaultPlan::injected`] — total faults the
    /// configured plan has injected, across every site.
    faults_injected: Arc<AtomicU64>,
    /// ε-planner attempts across all planned jobs (equals jobs executed
    /// under the SLO when every first attempt attains).
    plan_attempts: Arc<AtomicU64>,
    /// Escalations — attempts beyond each job's first.
    plan_escalations: Arc<AtomicU64>,
    /// Jobs whose final attempt still missed the ε target (escalation
    /// budget exhausted; the result ships with its achieved error).
    plan_misses: Arc<AtomicU64>,
}

impl ServeCounters {
    fn new(metrics: &Metrics) -> Self {
        Self {
            cache_hits: metrics.counter("serve.cache.hits"),
            cache_misses: metrics.counter("serve.cache.misses"),
            cache_expired: metrics.counter("serve.cache.expired"),
            cache_evictions: metrics.counter("serve.cache.evictions"),
            cache_bytes: metrics.counter("serve.cache.bytes"),
            cache_entries: metrics.counter("serve.cache.entries"),
            coalesced: metrics.counter("serve.batch.coalesced"),
            shed: metrics.counter("serve.shed"),
            deadline_expired: metrics.counter("serve.deadline_expired"),
            queue_depth: metrics.counter("serve.queue.depth"),
            queue_peak: metrics.counter("serve.queue.peak"),
            retries: metrics.counter("serve.retries"),
            degraded: metrics.counter("serve.degraded"),
            breaker_open: metrics.counter("serve.breaker_open"),
            faults_injected: metrics.counter("faults.injected"),
            plan_attempts: metrics.counter("serve.plan.attempts"),
            plan_escalations: metrics.counter("serve.plan.escalations"),
            plan_misses: metrics.counter("serve.plan.misses"),
        }
    }
}

/// Per-kind counter handles plus pre-formatted histogram names (the
/// histogram path locks anyway, but the `format!` per job does not need
/// to happen on it).
struct KindCounters {
    kind: &'static str,
    submitted: Arc<AtomicU64>,
    completed: Arc<AtomicU64>,
    router_latency: String,
    serve_latency: String,
}

/// State shared between the submit path and the executor threads.
struct Shared {
    metrics: Arc<Metrics>,
    cache: Option<Mutex<ArtifactCache>>,
    batcher: Batcher,
    batching: bool,
    queue_depth: usize,
    queued: AtomicUsize,
    peak: AtomicUsize,
    default_deadline: Option<Duration>,
    serve: ServeCounters,
    kinds: Vec<KindCounters>,
    trace: Option<Arc<TraceCollector>>,
    retry: RetryPolicy,
    degrade: bool,
    cache_path: Option<PathBuf>,
    trace_path: Option<PathBuf>,
    metrics_path: Option<PathBuf>,
    /// Per-kind breakers, aligned with `kinds` (`None` = disabled).
    breakers: Option<Vec<CircuitBreaker>>,
    faults: Option<Arc<FaultPlan>>,
    /// Accuracy SLO (see [`ServeConfig::epsilon`]).
    epsilon: Option<f64>,
}

impl Shared {
    /// Mirror the plan's injected-fault total into the `faults.injected`
    /// gauge (no-op without a plan).
    fn sync_faults_gauge(&self) {
        if let Some(plan) = &self.faults {
            self.serve.faults_injected.store(plan.injected(), Ordering::Relaxed);
        }
    }

    /// Whether submissions need a [`CacheKey`] at all (fingerprinting
    /// costs a pass over the payload — skip it for the plain router).
    fn keyed(&self) -> bool {
        self.cache.is_some() || self.batching
    }

    /// The pre-resolved counter handles for a job kind.
    fn kind_counters(&self, kind: &str) -> &KindCounters {
        self.kinds
            .iter()
            .find(|k| k.kind == kind)
            .expect("job kind missing from ApproxJob::KINDS")
    }

    /// Record one end-to-end serve latency (submit → result in hand).
    fn observe_latency(&self, kc: &KindCounters, submitted: Instant) {
        let secs = submitted.elapsed().as_secs_f64();
        self.metrics.observe("serve.latency", secs);
        self.metrics.observe(&kc.serve_latency, secs);
    }
}

struct QueueItem {
    job: ApproxJob,
    key: Option<CacheKey>,
    /// Whether this submission leads a batch (must fan out on completion).
    lead: bool,
    /// Whether admission re-planned this job at a degraded sketch tier
    /// (the result must be verified, tagged, and never cached).
    degraded: bool,
    /// Caller-supplied request trace id (the wire front-end's per-request
    /// id), attached to the job's `router.dispatch` root span.
    trace_id: Option<u64>,
    reply: mpsc::Sender<Result<JobResult>>,
    submitted: Instant,
    deadline: Option<Instant>,
}

/// The router service.
///
/// Shareable across threads behind an `Arc`: submission takes `&self`,
/// and [`Router::drain`] shuts the service down by shared reference —
/// which is how the wire front-end (`crate::net`) drains the daemon
/// while connection handlers still hold their clone.
pub struct Router {
    tx: Mutex<Option<mpsc::Sender<QueueItem>>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Set by the first drain to run its side effects (cache persist +
    /// export flush) exactly once, no matter how many of
    /// `drain`/`shutdown`/`Drop` execute.
    finalized: AtomicBool,
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
}

impl Router {
    /// Spawn `workers` executor threads with plain-router behavior
    /// (no cache, no batching, no admission bound) — see
    /// [`Router::with_config`] for the serving layer.
    pub fn new(workers: usize) -> Self {
        Self::with_config(&ServeConfig::service(workers))
    }

    /// Spawn the serving layer described by `cfg`.
    pub fn with_config(cfg: &ServeConfig) -> Self {
        assert!(cfg.workers >= 1);
        let (tx, rx) = mpsc::channel::<QueueItem>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let kinds = ApproxJob::KINDS
            .iter()
            .map(|&kind| KindCounters {
                kind,
                submitted: metrics.counter(&format!("router.{kind}.submitted")),
                completed: metrics.counter(&format!("router.{kind}.completed")),
                router_latency: format!("router.{kind}.latency"),
                serve_latency: format!("serve.{kind}.latency"),
            })
            .collect();
        let shared = Arc::new(Shared {
            metrics: metrics.clone(),
            cache: (cfg.cache_bytes > 0)
                .then(|| Mutex::new(ArtifactCache::new(cfg.cache_bytes).with_ttl(cfg.cache_ttl))),
            batcher: Batcher::new(cfg.batch_window),
            batching: cfg.batch_window > Duration::ZERO,
            queue_depth: cfg.queue_depth,
            queued: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            default_deadline: cfg.default_deadline,
            serve: ServeCounters::new(&metrics),
            kinds,
            trace: cfg.trace.clone(),
            retry: cfg.retry,
            degrade: cfg.degrade,
            cache_path: cfg.cache_path.clone(),
            trace_path: cfg.trace_path.clone(),
            metrics_path: cfg.metrics_path.clone(),
            breakers: (cfg.breaker_threshold > 0).then(|| {
                ApproxJob::KINDS
                    .iter()
                    .map(|_| CircuitBreaker::new(cfg.breaker_threshold, cfg.breaker_cooldown))
                    .collect()
            }),
            faults: cfg.faults.clone(),
            epsilon: cfg.epsilon,
        });
        warm_start(&shared);
        let mut handles = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let rx = rx.clone();
            let shared = shared.clone();
            let workers = cfg.workers;
            handles.push(std::thread::spawn(move || {
                // This executor's share of the `threads` knob: nested
                // pool regions opened by its jobs stay within it, so
                // `workers × threads` never oversubscribes the machine.
                let budget = crate::parallel::share_budget(crate::parallel::threads(), workers, w);
                crate::parallel::set_thread_budget(budget);
                obs::install(shared.trace.clone());
                faults::install(shared.faults.clone());
                loop {
                    let item = rx.lock().unwrap().recv();
                    let Ok(item) = item else { break };
                    run_item(&shared, item);
                }
            }));
        }
        Self {
            tx: Mutex::new(Some(tx)),
            workers: Mutex::new(handles),
            finalized: AtomicBool::new(false),
            shared,
            metrics,
        }
    }

    /// Submit a job through the serving path (cache → batcher →
    /// admission → queue); returns immediately with a [`JobHandle`]
    /// unless the submit queue is full, in which case the request is
    /// shed with [`FgError::Overloaded`].
    ///
    /// ```
    /// use fastgmr::coordinator::{ApproxJob, JobResult, MatrixPayload, Router};
    /// use fastgmr::cur::CurConfig;
    /// use fastgmr::linalg::Mat;
    ///
    /// let router = Router::new(2);
    /// let a = Mat::from_fn(24, 18, |i, j| ((i * 7 + j * 3) % 11) as f64);
    /// let job =
    ///     ApproxJob::Cur { a: MatrixPayload::Dense(a), cfg: CurConfig::fast(4, 4, 2), seed: 7 };
    /// let JobResult::Cur { cur } = router.submit(job)?.wait()? else { unreachable!() };
    /// assert_eq!((cur.c.shape(), cur.u.shape(), cur.r.shape()), ((24, 4), (4, 4), (4, 18)));
    /// # Ok::<(), fastgmr::FgError>(())
    /// ```
    pub fn submit(&self, job: ApproxJob) -> Result<JobHandle> {
        self.submit_with_deadline(job, self.shared.default_deadline)
    }

    /// [`Router::submit`] with an explicit per-job deadline override
    /// (`None` = never expires). A job whose deadline passes while it is
    /// still queued is failed with [`FgError::DeadlineExceeded`] at
    /// dequeue, without occupying an executor.
    pub fn submit_with_deadline(
        &self,
        job: ApproxJob,
        deadline: Option<Duration>,
    ) -> Result<JobHandle> {
        self.submit_traced(job, deadline, None)
    }

    /// [`Router::submit_with_deadline`] with a caller-supplied request
    /// trace id: the wire front-end (`crate::net`) tags every request it
    /// parses, and the id rides to the job's `router.dispatch` root span
    /// so one request is traceable from socket to executor.
    pub fn submit_traced(
        &self,
        mut job: ApproxJob,
        deadline: Option<Duration>,
        trace_id: Option<u64>,
    ) -> Result<JobHandle> {
        let shared = &self.shared;
        let submitted = Instant::now();
        let kc = shared.kind_counters(job.kind());
        let (reply_tx, reply_rx) = mpsc::channel();
        let handle = JobHandle { rx: reply_rx };

        let key = shared.keyed().then(|| job_key(&job));

        // 1. Artifact cache: a fresh hit is the whole request. A
        //    TTL-expired resident is dropped and recomputed — counted
        //    both as `expired` (staleness visibility) and as a miss
        //    (hit-rate accounting stays truthful).
        if let (Some(key), Some(cache)) = (&key, &shared.cache) {
            let looked = {
                let mut guard = cache.lock().unwrap();
                let looked = guard.lookup(key);
                if matches!(looked, Lookup::Expired) {
                    shared.serve.cache_bytes.store(guard.bytes() as u64, Ordering::Relaxed);
                    shared.serve.cache_entries.store(guard.len() as u64, Ordering::Relaxed);
                }
                looked
            };
            match looked {
                Lookup::Hit(result) => {
                    shared.serve.cache_hits.fetch_add(1, Ordering::Relaxed);
                    shared.observe_latency(kc, submitted);
                    let _ = reply_tx.send(Ok(result));
                    return Ok(handle);
                }
                Lookup::Expired => {
                    shared.serve.cache_expired.fetch_add(1, Ordering::Relaxed);
                    shared.serve.cache_misses.fetch_add(1, Ordering::Relaxed);
                }
                Lookup::Miss => {
                    shared.serve.cache_misses.fetch_add(1, Ordering::Relaxed);
                }
            }
        }

        // 2. Batcher: attach to an identical in-flight job if one opened
        //    a window; otherwise lead (and fan out on completion).
        let mut lead = false;
        if let (Some(key), true) = (&key, shared.batching) {
            match shared.batcher.join(*key, &reply_tx, submitted) {
                Admission::Coalesced => {
                    shared.serve.coalesced.fetch_add(1, Ordering::Relaxed);
                    return Ok(handle);
                }
                Admission::Lead => lead = true,
                Admission::Solo => {}
            }
        }

        // 3. Admission: bound the queue. Under pressure (a full queue, or
        //    an injected `queue.admission` fault simulating one) either
        //    shed the job, or — with degradation on — re-plan it at a
        //    smaller sketch tier and admit the cheaper job instead.
        let depth = shared.queued.fetch_add(1, Ordering::SeqCst) + 1;
        let over = shared.queue_depth > 0 && depth > shared.queue_depth;
        let injected = shared.faults.as_ref().is_some_and(|p| p.trip(site::QUEUE_ADMISSION));
        if injected {
            shared.sync_faults_gauge();
        }
        let mut degraded = false;
        if over || injected {
            if shared.degrade && job.degrade_in_place() {
                degraded = true;
            } else {
                shared.queued.fetch_sub(1, Ordering::SeqCst);
                shared.serve.shed.fetch_add(1, Ordering::Relaxed);
                if let (Some(key), true) = (&key, lead) {
                    shared.batcher.abort(key, shared.queue_depth);
                }
                return Err(FgError::Overloaded { depth: shared.queue_depth });
            }
        }
        shared.peak.fetch_max(depth, Ordering::SeqCst);
        shared.serve.queue_depth.store(depth as u64, Ordering::Relaxed);
        shared.serve.queue_peak.store(shared.peak.load(Ordering::SeqCst) as u64, Ordering::Relaxed);
        kc.submitted.fetch_add(1, Ordering::Relaxed);

        let deadline = deadline.map(|d| submitted + d);
        let item =
            QueueItem { job, key, lead, degraded, trace_id, reply: reply_tx, submitted, deadline };
        let sent = match self.tx.lock().unwrap().as_ref() {
            // A drained router refuses new work with a typed error
            // instead of panicking — the wire front-end keeps accepting
            // (and cleanly refusing) requests while the drain completes.
            None => Err(FgError::Coordinator("router already shut down".into())),
            Some(tx) => tx.send(item).map_err(|_| {
                FgError::Coordinator("router workers exited before job could be queued".into())
            }),
        };
        if let Err(e) = sent {
            self.shared.queued.fetch_sub(1, Ordering::SeqCst);
            return Err(e);
        }
        Ok(handle)
    }

    /// Inventory of cached artifacts in the `manifest.txt` line format
    /// (see [`ArtifactCache::manifest`]); `None` when the cache is
    /// disabled.
    pub fn cache_manifest(&self) -> Option<String> {
        self.shared.cache.as_ref().map(|c| c.lock().unwrap().manifest())
    }

    /// The trace collector configured via [`ServeConfig::trace`], shared
    /// with the wire front-end so connection threads record their
    /// `net.request` spans into the same trace as the executors.
    pub(crate) fn trace_collector(&self) -> Option<Arc<TraceCollector>> {
        self.shared.trace.clone()
    }

    /// The default per-job deadline configured via
    /// [`ServeConfig::default_deadline`].
    pub fn default_deadline(&self) -> Option<Duration> {
        self.shared.default_deadline
    }

    /// Graceful drain by shared reference: stop admitting new work
    /// (subsequent submits fail with a typed
    /// [`FgError::Coordinator`]), let in-flight jobs finish, join the
    /// executors, then — exactly once across any combination of
    /// `drain`/[`Router::shutdown`]/`Drop` — persist the artifact cache
    /// and flush the configured trace/metrics exports. All side effects
    /// complete **before this returns**: a caller that drains and then
    /// aborts the process still has the inventory and the exports on
    /// disk.
    pub fn drain(&self) {
        drop(self.tx.lock().unwrap().take());
        let workers: Vec<_> = std::mem::take(&mut *self.workers.lock().unwrap());
        for h in workers {
            let _ = h.join();
        }
        if !self.finalized.swap(true, Ordering::SeqCst) {
            persist(&self.shared);
            flush_exports(&self.shared);
        }
    }

    /// Consuming [`Router::drain`]: drain, join, persist, and flush
    /// before returning.
    pub fn shutdown(self) {
        self.drain();
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Flush the configured observability exports (trace + metrics files)
/// as the final step of a drain. Errors are reported, not fatal — a
/// full disk must not turn a clean shutdown into a crash.
fn flush_exports(shared: &Shared) {
    if let (Some(path), Some(c)) = (&shared.trace_path, &shared.trace) {
        let data = if path.extension().is_some_and(|e| e == "jsonl") {
            c.to_jsonl()
        } else {
            c.to_chrome_json()
        };
        if let Err(e) = std::fs::write(path, data) {
            eprintln!("trace export {}: {e}", path.display());
        }
    }
    if let Some(path) = &shared.metrics_path {
        if let Err(e) = std::fs::write(path, shared.metrics.prometheus()) {
            eprintln!("metrics export {}: {e}", path.display());
        }
    }
}

/// Warm-start the artifact cache from its on-disk inventory at router
/// construction (no-op without both a cache and a path). An injected
/// `cache.warm_start` fault degrades to a cold start — the daemon comes
/// up empty rather than not at all. The constructing thread installs the
/// configured trace collector first so the `cache.warm_start` span is
/// captured alongside executor spans.
fn warm_start(shared: &Shared) {
    let (Some(cache), Some(path)) = (&shared.cache, &shared.cache_path) else { return };
    if shared.trace.is_some() {
        obs::install(shared.trace.clone());
    }
    if shared.faults.as_ref().is_some_and(|p| p.trip(site::CACHE_WARM_START)) {
        shared.sync_faults_gauge();
        eprintln!("cache.warm_start: injected fault — starting cold");
        return;
    }
    let mut sp = obs::span("cache.warm_start", obs::cat::CACHE);
    let mut guard = cache.lock().unwrap();
    match guard.warm_start_from(path) {
        Ok(stats) => {
            if sp.active() {
                sp.meta("loaded", stats.loaded as u64);
                sp.meta("skipped_corrupt", stats.skipped_corrupt as u64);
            }
            shared.metrics.add("serve.warm_start.loaded", stats.loaded as u64);
            shared.metrics.add("serve.warm_start.skipped_corrupt", stats.skipped_corrupt as u64);
            shared.serve.cache_bytes.store(guard.bytes() as u64, Ordering::Relaxed);
            shared.serve.cache_entries.store(guard.len() as u64, Ordering::Relaxed);
        }
        Err(e) => eprintln!("cache.warm_start: {e} — starting cold"),
    }
}

/// Persist the artifact cache on router drop (no-op without both a cache
/// and a path). An injected `cache.persist` fault skips the write — the
/// simulated crash between compute and persist; the previous on-disk
/// inventory, if any, stays intact thanks to the temp-file + rename
/// protocol.
fn persist(shared: &Shared) {
    let (Some(cache), Some(path)) = (&shared.cache, &shared.cache_path) else { return };
    if shared.faults.as_ref().is_some_and(|p| p.trip(site::CACHE_PERSIST)) {
        shared.sync_faults_gauge();
        eprintln!("cache.persist: injected fault — skipping persist (simulated crash)");
        return;
    }
    let mut sp = obs::span("cache.persist", obs::cat::CACHE);
    let guard = cache.lock().unwrap();
    if sp.active() {
        sp.meta("entries", guard.len() as u64);
        sp.meta("bytes", guard.bytes() as u64);
    }
    if let Err(e) = guard.persist_to(path) {
        eprintln!("cache.persist: {e}");
    }
}

/// Executor body for one dequeued item: deadline check, circuit-breaker
/// admission, guarded (retried) execution, degraded-tier verification,
/// cache fill, batch fan-out, latency accounting.
fn run_item(shared: &Shared, item: QueueItem) {
    let QueueItem { job, key, lead, degraded, trace_id, reply, submitted, deadline } = item;
    let depth = shared.queued.fetch_sub(1, Ordering::SeqCst).saturating_sub(1);
    shared.serve.queue_depth.store(depth as u64, Ordering::Relaxed);
    let kind = job.kind();
    let kc = shared.kind_counters(kind);

    if let Some(d) = deadline {
        if Instant::now() >= d {
            shared.serve.deadline_expired.fetch_add(1, Ordering::Relaxed);
            let waited_ms = submitted.elapsed().as_millis() as u64;
            if let (Some(key), true) = (&key, lead) {
                shared.batcher.complete(key, &Err(FgError::DeadlineExceeded { waited_ms }));
            }
            let _ = reply.send(Err(FgError::DeadlineExceeded { waited_ms }));
            return;
        }
    }

    // Job-scoped root span: every phase the algorithm opens below nests
    // under it, so one job is one tree in the exported trace.
    let mut root = obs::span("router.dispatch", obs::cat::DISPATCH);
    if root.active() {
        let (rows, cols) = job.dims();
        root.meta("kind", kind);
        root.meta("rows", rows);
        root.meta("cols", cols);
        root.meta("weight", job.weight());
        if let Some(id) = trace_id {
            root.meta("trace_id", id);
        }
    }

    // A panicking job must fail that job, not take down the executor:
    // the daemon serves many independent requests. Panics are retried at
    // the job level up to the policy (an injected or otherwise transient
    // panic heals); a kind that keeps failing trips its circuit breaker
    // so later jobs fail fast instead of burning executor time.
    let breaker = shared
        .breakers
        .as_ref()
        .and_then(|bs| shared.kinds.iter().position(|k| k.kind == kind).map(|i| &bs[i]));
    let mut panicked = false;
    let result = if breaker.is_some_and(|b| !b.admit()) {
        Err(FgError::CircuitOpen { kind: kind.to_string() })
    } else {
        let mut attempt = 1u32;
        loop {
            let guarded = || {
                catch_unwind(AssertUnwindSafe(|| {
                    if let Some(plan) = faults::current() {
                        if plan.trip(&site::executor(kind)) {
                            panic!("injected executor fault (site executor.{kind})");
                        }
                    }
                    execute(&job, shared, degraded)
                }))
            };
            match shared.metrics.time(&kc.router_latency, guarded) {
                Ok(res) => break res,
                Err(payload) => {
                    let msg = panic_message(payload);
                    if attempt < shared.retry.max_attempts {
                        shared.serve.retries.fetch_add(1, Ordering::Relaxed);
                        let mut sp = obs::span("router.retry", obs::cat::DISPATCH);
                        if sp.active() {
                            sp.meta("kind", kind);
                            sp.meta("attempt", attempt as u64);
                        }
                        std::thread::sleep(shared.retry.backoff(attempt));
                        attempt += 1;
                    } else {
                        panicked = true;
                        break Err(FgError::Runtime(format!(
                            "{kind} job panicked in executor: {msg}"
                        )));
                    }
                }
            }
        }
    };
    shared.sync_faults_gauge();
    if let Some(b) = breaker {
        match &result {
            Ok(_) => b.on_success(),
            Err(_) if panicked => {
                if b.on_failure() {
                    shared.serve.breaker_open.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(_) => {}
        }
    }
    // Verify and tag a degraded-tier result: the client learns both that
    // it got the cheaper answer and how far off the estimator thinks it
    // is. Degraded results never enter the cache (the `is_degraded`
    // guard below), so a later uncontended request recomputes at full
    // fidelity.
    let result = match (result, degraded) {
        (Ok(res), true) => {
            let mut sp = obs::span("router.degrade.verify", obs::cat::DISPATCH);
            let est = degraded_residual(&job, &res);
            if sp.active() {
                sp.meta("kind", kind);
                sp.meta("est_rel_residual", est);
            }
            drop(sp);
            shared.serve.degraded.fetch_add(1, Ordering::Relaxed);
            Ok(JobResult::Degraded { est_rel_residual: est, inner: Box::new(res) })
        }
        (result, _) => result,
    };
    kc.completed.fetch_add(1, Ordering::Relaxed);

    if let (Some(key), Some(cache), Ok(res)) = (&key, &shared.cache, &result) {
        // A degraded artifact must not be cached under its full-fidelity
        // key.
        if !res.is_degraded() {
            let mut cache = cache.lock().unwrap();
            let evicted = cache.insert(*key, res);
            if evicted > 0 {
                shared.serve.cache_evictions.fetch_add(evicted as u64, Ordering::Relaxed);
            }
            shared.serve.cache_bytes.store(cache.bytes() as u64, Ordering::Relaxed);
            shared.serve.cache_entries.store(cache.len() as u64, Ordering::Relaxed);
        }
    }
    // Close the job's span tree before the reply is observable: a test
    // that waits on the handle must find the full tree recorded.
    drop(root);

    if let (Some(key), true) = (&key, lead) {
        for waiter_submitted in shared.batcher.complete(key, &result) {
            shared.observe_latency(kc, waiter_submitted);
        }
    }
    shared.observe_latency(kc, submitted);
    let _ = reply.send(result);
}

/// Run a streaming-job body over its column stream, wired for fault
/// tolerance: under an installed [`FaultPlan`] the raw stream is wrapped
/// in a [`FaultyStream`] (so `stream.read` trips surface as transient
/// errors), and either way in a [`RetryStream`] so transient read errors
/// are retried in place up to the policy — the fault layer errors
/// *before* its source advances, so each retry re-yields the same block
/// and the single-pass reservoir/sketch state never sees a gap.
fn with_stream<S: ColumnStream, T>(
    stream: S,
    retry: &RetryPolicy,
    retries: &Arc<AtomicU64>,
    f: impl FnOnce(&mut dyn ColumnStream) -> Result<T>,
) -> Result<T> {
    match faults::current() {
        Some(plan) => {
            let faulty = FaultyStream::new(stream, plan);
            let mut retried = RetryStream::new(faulty, *retry).with_counter(retries.clone());
            f(&mut retried)
        }
        None => {
            let mut retried = RetryStream::new(stream, *retry).with_counter(retries.clone());
            f(&mut retried)
        }
    }
}

/// Wrap a raw column stream in the fault-tolerance layers (the same
/// wiring as [`with_stream`]) and box it: the ε-planned streaming
/// drivers take a stream *factory* — one fresh wrapped pass per
/// escalation attempt.
fn wrap_stream<'a, S: ColumnStream + 'a>(
    stream: S,
    retry: &RetryPolicy,
    retries: &Arc<AtomicU64>,
) -> Box<dyn ColumnStream + 'a> {
    match faults::current() {
        Some(plan) => Box::new(
            RetryStream::new(FaultyStream::new(stream, plan), *retry)
                .with_counter(retries.clone()),
        ),
        None => Box::new(RetryStream::new(stream, *retry).with_counter(retries.clone())),
    }
}

/// Fold one planner outcome into the `serve.plan.*` counters.
fn record_plan(shared: &Shared, outcome: &crate::plan::PlanOutcome) {
    shared.serve.plan_attempts.fetch_add(outcome.attempts as u64, Ordering::Relaxed);
    shared
        .serve
        .plan_escalations
        .fetch_add(outcome.attempts.saturating_sub(1) as u64, Ordering::Relaxed);
    if !outcome.attained {
        shared.serve.plan_misses.fetch_add(1, Ordering::Relaxed);
    }
}

/// Execute one job (the worker body). Borrows the job so the caller can
/// retry a panicked execution and verify a degraded result against the
/// original input.
///
/// With [`ServeConfig::epsilon`] set, every plannable kind routes
/// through its ε-planned variant — sketch sizes seeded from the paper's
/// `O(ε^{-1/2})` bounds, escalated until the a-posteriori check
/// certifies `(1+ε)` — except degraded-tier jobs, which deliberately
/// trade accuracy for admission and run config-sized (their
/// [`JobResult::Degraded`] tag reports the estimated residual, so an
/// SLO miss is visible, never silent).
fn execute(job: &ApproxJob, shared: &Shared, degraded: bool) -> Result<JobResult> {
    let retry = &shared.retry;
    let retries = &shared.serve.retries;
    let plan_eps = if degraded { None } else { shared.epsilon };
    match job {
        ApproxJob::Gmr { a, c, r, cfg, seed } => {
            if let Some(eps) = plan_eps {
                let plan = crate::plan::EpsilonPlan::new(eps).with_seed(*seed);
                let (sol, outcome) = crate::plan::solve_gmr_planned(
                    a.as_input(),
                    c,
                    r,
                    cfg.kind_c,
                    cfg.kind_r,
                    &plan,
                );
                record_plan(shared, &outcome);
                return Ok(JobResult::Gmr { x: sol.x });
            }
            let mut rr = rng(*seed);
            let sol = crate::gmr::solve_fast(a.as_input(), c, r, cfg, &mut rr);
            Ok(JobResult::Gmr { x: sol.x })
        }
        ApproxJob::GmrExact { a, c, r } => {
            let sol = crate::gmr::solve_exact(a.as_input(), c, r);
            Ok(JobResult::Gmr { x: sol.x })
        }
        ApproxJob::SpsdKernel { x, sigma, c, s, seed } => {
            let mut rr = rng(*seed);
            let oracle = RbfOracle::new(x, *sigma);
            let counting = CountingOracle::new(&oracle);
            let cfg = crate::spsd::FasterSpsdConfig { c: *c, s: *s };
            let sol = if let Some(eps) = plan_eps {
                let plan = crate::plan::EpsilonPlan::new(eps).with_seed(*seed);
                let (sol, outcome) =
                    crate::spsd::faster_spsd_planned(&counting, &cfg, &plan, &mut rr);
                record_plan(shared, &outcome);
                sol
            } else {
                crate::spsd::faster_spsd(&counting, &cfg, &mut rr)
            };
            Ok(JobResult::Spsd {
                idx: sol.idx,
                c: sol.c,
                x: sol.x,
                entries_observed: counting.observed(),
            })
        }
        ApproxJob::Cur { a, cfg, seed } => {
            let mut rr = rng(*seed);
            if let Some(eps) = plan_eps {
                let plan = crate::plan::EpsilonPlan::new(eps).with_seed(*seed);
                let (cur, outcome) = crate::cur::decompose_planned(a.as_input(), cfg, &plan, &mut rr);
                record_plan(shared, &outcome);
                return Ok(JobResult::Cur { cur });
            }
            let cur = crate::cur::decompose(a.as_input(), cfg, &mut rr);
            Ok(JobResult::Cur { cur })
        }
        ApproxJob::StreamingCur { a, cfg, block, seed } => {
            // Single pass over the payload; the sketch applies inside
            // run on this executor's budgeted pool share.
            if let Some(eps) = plan_eps {
                let plan = crate::plan::EpsilonPlan::new(eps).with_seed(*seed);
                let open = || {
                    Ok(match a {
                        MatrixPayload::Dense(m) => {
                            wrap_stream(DenseColumnStream::new(m, *block), retry, retries)
                        }
                        MatrixPayload::Sparse(m) => {
                            wrap_stream(CsrColumnStream::new(m, *block), retry, retries)
                        }
                    })
                };
                let (res, outcome) = crate::cur::streaming_cur_planned(open, cfg, &plan)?;
                record_plan(shared, &outcome);
                return Ok(JobResult::Cur { cur: res.cur });
            }
            let mut rr = rng(*seed);
            let res = match a {
                MatrixPayload::Dense(m) => {
                    with_stream(DenseColumnStream::new(m, *block), retry, retries, |s| {
                        crate::cur::streaming_cur(s, cfg, &mut rr)
                    })?
                }
                MatrixPayload::Sparse(m) => {
                    with_stream(CsrColumnStream::new(m, *block), retry, retries, |s| {
                        crate::cur::streaming_cur(s, cfg, &mut rr)
                    })?
                }
            };
            Ok(JobResult::Cur { cur: res.cur })
        }
        ApproxJob::StreamSvd { a, cfg, block, seed } => {
            if let Some(eps) = plan_eps {
                let plan = crate::plan::EpsilonPlan::new(eps).with_seed(*seed);
                let open = || {
                    Ok(match a {
                        MatrixPayload::Dense(m) => {
                            wrap_stream(DenseColumnStream::new(m, *block), retry, retries)
                        }
                        MatrixPayload::Sparse(m) => {
                            wrap_stream(CsrColumnStream::new(m, *block), retry, retries)
                        }
                    })
                };
                let (res, outcome) = crate::svdstream::fast_sp_svd_planned(open, cfg, &plan)?;
                record_plan(shared, &outcome);
                return Ok(JobResult::Svd { u: res.u, sigma: res.sigma, v: res.v });
            }
            let mut rr = rng(*seed);
            let res = match a {
                MatrixPayload::Dense(m) => {
                    with_stream(DenseColumnStream::new(m, *block), retry, retries, |s| {
                        crate::svdstream::fast_sp_svd(s, cfg, &mut rr)
                    })?
                }
                MatrixPayload::Sparse(m) => {
                    with_stream(CsrColumnStream::new(m, *block), retry, retries, |s| {
                        crate::svdstream::fast_sp_svd(s, cfg, &mut rr)
                    })?
                }
            };
            Ok(JobResult::Svd { u: res.u, sigma: res.sigma, v: res.v })
        }
    }
}

/// Sketched relative residual `‖A − C X R‖_F / ‖A‖_F` of a degraded
/// result against its job's input, via the paper's §2 count-sketch
/// estimators ([`crate::gmr::estimate_residual`] /
/// [`crate::gmr::sketched_fro_norm`]). The sketch seeds derive from the
/// job seed, so verification is deterministic. Kernel jobs have no
/// materialized input matrix — they report `NaN` (tagged but unverified).
fn degraded_residual(job: &ApproxJob, res: &JobResult) -> f64 {
    const S: usize = 64;
    let rel = |a: crate::gmr::Input<'_>, c: &Mat, x: &Mat, r: &Mat, seed: u64| {
        let est = crate::gmr::estimate_residual(a, c, x, r, S, &mut rng(seed ^ 0x5eed_0001));
        let norm = crate::gmr::sketched_fro_norm(a, S, &mut rng(seed ^ 0x5eed_0002));
        if norm > 0.0 {
            est / norm
        } else {
            0.0
        }
    };
    match (job, res) {
        (ApproxJob::Gmr { a, c, r, seed, .. }, JobResult::Gmr { x }) => {
            rel(a.as_input(), c, x, r, *seed)
        }
        (ApproxJob::Cur { a, seed, .. }, JobResult::Cur { cur })
        | (ApproxJob::StreamingCur { a, seed, .. }, JobResult::Cur { cur }) => {
            rel(a.as_input(), &cur.c, &cur.u, &cur.r, *seed)
        }
        (ApproxJob::StreamSvd { a, seed, .. }, JobResult::Svd { u, sigma, v }) => {
            let k = sigma.len();
            let d = Mat::from_fn(k, k, |i, j| if i == j { sigma[i] } else { 0.0 });
            rel(a.as_input(), u, &d, &v.transpose(), *seed)
        }
        _ => f64::NAN,
    }
}
