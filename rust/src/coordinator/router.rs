//! Job router and serving layer: a long-lived multi-worker service
//! executing [`ApproxJob`]s behind admission control, cross-request
//! batching, and a fingerprint-keyed artifact cache.
//!
//! The paper's algorithms are built to be *amortized*: one pair of
//! sketches answers many downstream queries (CUR, SPSD, streaming SVD).
//! A daemon serving approximation requests should therefore never
//! recompute what an earlier request already paid for. The submit path
//! enforces that, in order:
//!
//! ```text
//! submit ──► artifact cache ──► batcher ──► admission ──► queue ──► executor
//!             (hit: done)     (coalesce)    (or shed)
//! ```
//!
//! * **Cache** — completed factorizations keyed by
//!   [`CacheKey`] = dataset fingerprint × config digest
//!   ([`super::cache`]); a hit returns a bitwise-identical clone without
//!   touching the queue.
//! * **Batcher** — identical jobs in flight within the batch window
//!   share one execution ([`super::batcher::Batcher`]).
//! * **Admission** — a bounded submit queue sheds excess load with
//!   [`FgError::Overloaded`] instead of letting latency grow without
//!   bound; per-job deadlines fail stale work with
//!   [`FgError::DeadlineExceeded`] before it wastes an executor.
//!
//! Workers pull from a shared queue (single consumer lock on the
//! receiver), run the algorithm under `catch_unwind` (a panicking job
//! fails that job, not the daemon), and report per-kind latency into
//! [`Metrics`] — `router.<kind>.*` for executor-side counts and compute
//! latency, `serve.*` for the serving layer (hits, misses, evictions,
//! shed, coalesced, queue depth, end-to-end latency; naming convention
//! in the README §Serving).
//!
//! Each executor thread installs its share of the process-wide `threads`
//! knob as a per-thread pool budget
//! ([`crate::parallel::set_thread_budget`]) at startup, so the pool
//! regions its jobs open — matmul dispatch, sketch applies, CUR
//! selection — use `threads / workers` lanes each instead of all of
//! them. Without the cap, N workers running pool-hungry jobs would
//! oversubscribe the machine N×.

use super::batcher::{Admission, Batcher};
use super::cache::{job_key, ArtifactCache, CacheKey};
use super::jobs::{ApproxJob, JobResult, MatrixPayload};
use crate::error::{FgError, Result};
use crate::metrics::Metrics;
use crate::obs::{self, TraceCollector};
use crate::rng::rng;
use crate::spsd::{CountingOracle, RbfOracle};
use crate::svdstream::source::{CsrColumnStream, DenseColumnStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Handle to a submitted job.
pub struct JobHandle {
    rx: mpsc::Receiver<Result<JobResult>>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobResult> {
        self.rx
            .recv()
            .map_err(|_| FgError::Coordinator("router shut down before job completed".into()))?
    }

    /// Block until the job completes or `timeout` elapses, whichever
    /// comes first (elapsing maps to [`FgError::DeadlineExceeded`]; the
    /// job itself keeps running to completion on its executor).
    pub fn wait_timeout(self, timeout: Duration) -> Result<JobResult> {
        match self.rx.recv_timeout(timeout) {
            Ok(result) => result,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(FgError::DeadlineExceeded { waited_ms: timeout.as_millis() as u64 })
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(FgError::Coordinator("router shut down before job completed".into()))
            }
        }
    }
}

/// Serving-layer configuration for [`Router::with_config`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Executor threads (≥ 1).
    pub workers: usize,
    /// Submit-queue bound; `0` = unbounded (no load shedding).
    pub queue_depth: usize,
    /// Artifact-cache byte budget; `0` disables the cache.
    pub cache_bytes: usize,
    /// Coalescing window for identical in-flight jobs;
    /// `Duration::ZERO` disables batching.
    pub batch_window: Duration,
    /// Deadline applied to every [`Router::submit`]; `None` = jobs
    /// never expire in the queue.
    pub default_deadline: Option<Duration>,
    /// Trace collector installed on every executor thread; `None`
    /// (the default) disables tracing at zero cost on the span path.
    pub trace: Option<Arc<TraceCollector>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self::service(2)
    }
}

impl ServeConfig {
    /// Plain job-router behavior (what [`Router::new`] uses): no cache,
    /// no batching, unbounded queue, no deadlines.
    pub fn service(workers: usize) -> Self {
        Self {
            workers,
            queue_depth: 0,
            cache_bytes: 0,
            batch_window: Duration::ZERO,
            default_deadline: None,
            trace: None,
        }
    }
}

/// Pre-resolved `Arc<AtomicU64>` handles for every serving-layer
/// counter and gauge the submit/executor hot paths touch.
/// [`Metrics::add`] takes the registry map lock per increment; these
/// handles are the same atomics fetched once at router construction, so
/// per-job accounting is a lock-free `fetch_add`/`store`.
struct ServeCounters {
    cache_hits: Arc<AtomicU64>,
    cache_misses: Arc<AtomicU64>,
    cache_evictions: Arc<AtomicU64>,
    cache_bytes: Arc<AtomicU64>,
    cache_entries: Arc<AtomicU64>,
    coalesced: Arc<AtomicU64>,
    shed: Arc<AtomicU64>,
    deadline_expired: Arc<AtomicU64>,
    queue_depth: Arc<AtomicU64>,
    queue_peak: Arc<AtomicU64>,
}

impl ServeCounters {
    fn new(metrics: &Metrics) -> Self {
        Self {
            cache_hits: metrics.counter("serve.cache.hits"),
            cache_misses: metrics.counter("serve.cache.misses"),
            cache_evictions: metrics.counter("serve.cache.evictions"),
            cache_bytes: metrics.counter("serve.cache.bytes"),
            cache_entries: metrics.counter("serve.cache.entries"),
            coalesced: metrics.counter("serve.batch.coalesced"),
            shed: metrics.counter("serve.shed"),
            deadline_expired: metrics.counter("serve.deadline_expired"),
            queue_depth: metrics.counter("serve.queue.depth"),
            queue_peak: metrics.counter("serve.queue.peak"),
        }
    }
}

/// Per-kind counter handles plus pre-formatted histogram names (the
/// histogram path locks anyway, but the `format!` per job does not need
/// to happen on it).
struct KindCounters {
    kind: &'static str,
    submitted: Arc<AtomicU64>,
    completed: Arc<AtomicU64>,
    router_latency: String,
    serve_latency: String,
}

/// State shared between the submit path and the executor threads.
struct Shared {
    metrics: Arc<Metrics>,
    cache: Option<Mutex<ArtifactCache>>,
    batcher: Batcher,
    batching: bool,
    queue_depth: usize,
    queued: AtomicUsize,
    peak: AtomicUsize,
    default_deadline: Option<Duration>,
    serve: ServeCounters,
    kinds: Vec<KindCounters>,
    trace: Option<Arc<TraceCollector>>,
}

impl Shared {
    /// Whether submissions need a [`CacheKey`] at all (fingerprinting
    /// costs a pass over the payload — skip it for the plain router).
    fn keyed(&self) -> bool {
        self.cache.is_some() || self.batching
    }

    /// The pre-resolved counter handles for a job kind.
    fn kind_counters(&self, kind: &str) -> &KindCounters {
        self.kinds
            .iter()
            .find(|k| k.kind == kind)
            .expect("job kind missing from ApproxJob::KINDS")
    }

    /// Record one end-to-end serve latency (submit → result in hand).
    fn observe_latency(&self, kc: &KindCounters, submitted: Instant) {
        let secs = submitted.elapsed().as_secs_f64();
        self.metrics.observe("serve.latency", secs);
        self.metrics.observe(&kc.serve_latency, secs);
    }
}

struct QueueItem {
    job: ApproxJob,
    key: Option<CacheKey>,
    /// Whether this submission leads a batch (must fan out on completion).
    lead: bool,
    reply: mpsc::Sender<Result<JobResult>>,
    submitted: Instant,
    deadline: Option<Instant>,
}

/// The router service.
pub struct Router {
    tx: Option<mpsc::Sender<QueueItem>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
}

impl Router {
    /// Spawn `workers` executor threads with plain-router behavior
    /// (no cache, no batching, no admission bound) — see
    /// [`Router::with_config`] for the serving layer.
    pub fn new(workers: usize) -> Self {
        Self::with_config(&ServeConfig::service(workers))
    }

    /// Spawn the serving layer described by `cfg`.
    pub fn with_config(cfg: &ServeConfig) -> Self {
        assert!(cfg.workers >= 1);
        let (tx, rx) = mpsc::channel::<QueueItem>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let kinds = ApproxJob::KINDS
            .iter()
            .map(|&kind| KindCounters {
                kind,
                submitted: metrics.counter(&format!("router.{kind}.submitted")),
                completed: metrics.counter(&format!("router.{kind}.completed")),
                router_latency: format!("router.{kind}.latency"),
                serve_latency: format!("serve.{kind}.latency"),
            })
            .collect();
        let shared = Arc::new(Shared {
            metrics: metrics.clone(),
            cache: (cfg.cache_bytes > 0).then(|| Mutex::new(ArtifactCache::new(cfg.cache_bytes))),
            batcher: Batcher::new(cfg.batch_window),
            batching: cfg.batch_window > Duration::ZERO,
            queue_depth: cfg.queue_depth,
            queued: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            default_deadline: cfg.default_deadline,
            serve: ServeCounters::new(&metrics),
            kinds,
            trace: cfg.trace.clone(),
        });
        let mut handles = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let rx = rx.clone();
            let shared = shared.clone();
            let workers = cfg.workers;
            handles.push(std::thread::spawn(move || {
                // This executor's share of the `threads` knob: nested
                // pool regions opened by its jobs stay within it, so
                // `workers × threads` never oversubscribes the machine.
                let budget = crate::parallel::share_budget(crate::parallel::threads(), workers, w);
                crate::parallel::set_thread_budget(budget);
                obs::install(shared.trace.clone());
                loop {
                    let item = rx.lock().unwrap().recv();
                    let Ok(item) = item else { break };
                    run_item(&shared, item);
                }
            }));
        }
        Self { tx: Some(tx), workers: handles, shared, metrics }
    }

    /// Submit a job through the serving path (cache → batcher →
    /// admission → queue); returns immediately with a [`JobHandle`]
    /// unless the submit queue is full, in which case the request is
    /// shed with [`FgError::Overloaded`].
    ///
    /// ```
    /// use fastgmr::coordinator::{ApproxJob, JobResult, MatrixPayload, Router};
    /// use fastgmr::cur::CurConfig;
    /// use fastgmr::linalg::Mat;
    ///
    /// let router = Router::new(2);
    /// let a = Mat::from_fn(24, 18, |i, j| ((i * 7 + j * 3) % 11) as f64);
    /// let job =
    ///     ApproxJob::Cur { a: MatrixPayload::Dense(a), cfg: CurConfig::fast(4, 4, 2), seed: 7 };
    /// let JobResult::Cur { cur } = router.submit(job)?.wait()? else { unreachable!() };
    /// assert_eq!((cur.c.shape(), cur.u.shape(), cur.r.shape()), ((24, 4), (4, 4), (4, 18)));
    /// # Ok::<(), fastgmr::FgError>(())
    /// ```
    pub fn submit(&self, job: ApproxJob) -> Result<JobHandle> {
        self.submit_with_deadline(job, self.shared.default_deadline)
    }

    /// [`Router::submit`] with an explicit per-job deadline override
    /// (`None` = never expires). A job whose deadline passes while it is
    /// still queued is failed with [`FgError::DeadlineExceeded`] at
    /// dequeue, without occupying an executor.
    pub fn submit_with_deadline(
        &self,
        job: ApproxJob,
        deadline: Option<Duration>,
    ) -> Result<JobHandle> {
        let shared = &self.shared;
        let submitted = Instant::now();
        let kc = shared.kind_counters(job.kind());
        let (reply_tx, reply_rx) = mpsc::channel();
        let handle = JobHandle { rx: reply_rx };

        let key = shared.keyed().then(|| job_key(&job));

        // 1. Artifact cache: a hit is the whole request.
        if let (Some(key), Some(cache)) = (&key, &shared.cache) {
            let hit = cache.lock().unwrap().get(key);
            if let Some(result) = hit {
                shared.serve.cache_hits.fetch_add(1, Ordering::Relaxed);
                shared.observe_latency(kc, submitted);
                let _ = reply_tx.send(Ok(result));
                return Ok(handle);
            }
            shared.serve.cache_misses.fetch_add(1, Ordering::Relaxed);
        }

        // 2. Batcher: attach to an identical in-flight job if one opened
        //    a window; otherwise lead (and fan out on completion).
        let mut lead = false;
        if let (Some(key), true) = (&key, shared.batching) {
            match shared.batcher.join(*key, &reply_tx, submitted) {
                Admission::Coalesced => {
                    shared.serve.coalesced.fetch_add(1, Ordering::Relaxed);
                    return Ok(handle);
                }
                Admission::Lead => lead = true,
                Admission::Solo => {}
            }
        }

        // 3. Admission: bound the queue, shedding excess load.
        let depth = shared.queued.fetch_add(1, Ordering::SeqCst) + 1;
        if shared.queue_depth > 0 && depth > shared.queue_depth {
            shared.queued.fetch_sub(1, Ordering::SeqCst);
            shared.serve.shed.fetch_add(1, Ordering::Relaxed);
            if let (Some(key), true) = (&key, lead) {
                shared.batcher.abort(key, shared.queue_depth);
            }
            return Err(FgError::Overloaded { depth: shared.queue_depth });
        }
        shared.peak.fetch_max(depth, Ordering::SeqCst);
        shared.serve.queue_depth.store(depth as u64, Ordering::Relaxed);
        shared.serve.queue_peak.store(shared.peak.load(Ordering::SeqCst) as u64, Ordering::Relaxed);
        kc.submitted.fetch_add(1, Ordering::Relaxed);

        let deadline = deadline.map(|d| submitted + d);
        let item = QueueItem { job, key, lead, reply: reply_tx, submitted, deadline };
        self.tx.as_ref().expect("router already shut down").send(item).map_err(|_| {
            FgError::Coordinator("router workers exited before job could be queued".into())
        })?;
        Ok(handle)
    }

    /// Inventory of cached artifacts in the `manifest.txt` line format
    /// (see [`ArtifactCache::manifest`]); `None` when the cache is
    /// disabled.
    pub fn cache_manifest(&self) -> Option<String> {
        self.shared.cache.as_ref().map(|c| c.lock().unwrap().manifest())
    }

    /// Drain and join workers.
    pub fn shutdown(mut self) {
        self.tx.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Executor body for one dequeued item: deadline check, guarded
/// execution, cache fill, batch fan-out, latency accounting.
fn run_item(shared: &Shared, item: QueueItem) {
    let QueueItem { job, key, lead, reply, submitted, deadline } = item;
    let depth = shared.queued.fetch_sub(1, Ordering::SeqCst).saturating_sub(1);
    shared.serve.queue_depth.store(depth as u64, Ordering::Relaxed);
    let kind = job.kind();
    let kc = shared.kind_counters(kind);

    if let Some(d) = deadline {
        if Instant::now() >= d {
            shared.serve.deadline_expired.fetch_add(1, Ordering::Relaxed);
            let waited_ms = submitted.elapsed().as_millis() as u64;
            if let (Some(key), true) = (&key, lead) {
                shared.batcher.complete(key, &Err(FgError::DeadlineExceeded { waited_ms }));
            }
            let _ = reply.send(Err(FgError::DeadlineExceeded { waited_ms }));
            return;
        }
    }

    // Job-scoped root span: every phase the algorithm opens below nests
    // under it, so one job is one tree in the exported trace.
    let mut root = obs::span("router.dispatch", obs::cat::DISPATCH);
    if root.active() {
        let (rows, cols) = job.dims();
        root.meta("kind", kind);
        root.meta("rows", rows);
        root.meta("cols", cols);
        root.meta("weight", job.weight());
    }

    // A panicking job must fail that job, not take down the executor:
    // the daemon serves many independent requests.
    let guarded = || catch_unwind(AssertUnwindSafe(|| execute(job)));
    let result = shared
        .metrics
        .time(&kc.router_latency, guarded)
        .unwrap_or_else(|_| Err(FgError::Runtime(format!("{kind} job panicked in executor"))));
    kc.completed.fetch_add(1, Ordering::Relaxed);

    if let (Some(key), Some(cache), Ok(res)) = (&key, &shared.cache, &result) {
        let mut cache = cache.lock().unwrap();
        let evicted = cache.insert(*key, res);
        if evicted > 0 {
            shared.serve.cache_evictions.fetch_add(evicted as u64, Ordering::Relaxed);
        }
        shared.serve.cache_bytes.store(cache.bytes() as u64, Ordering::Relaxed);
        shared.serve.cache_entries.store(cache.len() as u64, Ordering::Relaxed);
    }
    // Close the job's span tree before the reply is observable: a test
    // that waits on the handle must find the full tree recorded.
    drop(root);

    if let (Some(key), true) = (&key, lead) {
        for waiter_submitted in shared.batcher.complete(key, &result) {
            shared.observe_latency(kc, waiter_submitted);
        }
    }
    shared.observe_latency(kc, submitted);
    let _ = reply.send(result);
}

/// Execute one job (the worker body).
fn execute(job: ApproxJob) -> Result<JobResult> {
    match job {
        ApproxJob::Gmr { a, c, r, cfg, seed } => {
            let mut rr = rng(seed);
            let sol = crate::gmr::solve_fast(a.as_input(), &c, &r, &cfg, &mut rr);
            Ok(JobResult::Gmr { x: sol.x })
        }
        ApproxJob::GmrExact { a, c, r } => {
            let sol = crate::gmr::solve_exact(a.as_input(), &c, &r);
            Ok(JobResult::Gmr { x: sol.x })
        }
        ApproxJob::SpsdKernel { x, sigma, c, s, seed } => {
            let mut rr = rng(seed);
            let oracle = RbfOracle::new(&x, sigma);
            let counting = CountingOracle::new(&oracle);
            let sol = crate::spsd::faster_spsd(
                &counting,
                &crate::spsd::FasterSpsdConfig { c, s },
                &mut rr,
            );
            Ok(JobResult::Spsd {
                idx: sol.idx,
                c: sol.c,
                x: sol.x,
                entries_observed: counting.observed(),
            })
        }
        ApproxJob::Cur { a, cfg, seed } => {
            let mut rr = rng(seed);
            let cur = crate::cur::decompose(a.as_input(), &cfg, &mut rr);
            Ok(JobResult::Cur { cur })
        }
        ApproxJob::StreamingCur { a, cfg, block, seed } => {
            // Single pass over the payload; the sketch applies inside
            // run on this executor's budgeted pool share.
            let mut rr = rng(seed);
            let res = match &a {
                MatrixPayload::Dense(m) => {
                    let mut stream = DenseColumnStream::new(m, block);
                    crate::cur::streaming_cur(&mut stream, &cfg, &mut rr)
                }
                MatrixPayload::Sparse(m) => {
                    let mut stream = CsrColumnStream::new(m, block);
                    crate::cur::streaming_cur(&mut stream, &cfg, &mut rr)
                }
            };
            Ok(JobResult::Cur { cur: res.cur })
        }
        ApproxJob::StreamSvd { a, cfg, block, seed } => {
            let mut rr = rng(seed);
            let res = match &a {
                MatrixPayload::Dense(m) => {
                    let mut stream = DenseColumnStream::new(m, block);
                    crate::svdstream::fast_sp_svd(&mut stream, &cfg, &mut rr)
                }
                MatrixPayload::Sparse(m) => {
                    let mut stream = CsrColumnStream::new(m, block);
                    crate::svdstream::fast_sp_svd(&mut stream, &cfg, &mut rr)
                }
            };
            Ok(JobResult::Svd { u: res.u, sigma: res.sigma, v: res.v })
        }
    }
}
