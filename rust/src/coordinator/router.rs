//! Job router: a small multi-worker service executing [`ApproxJob`]s.
//!
//! Jobs are submitted from any thread; each returns a [`JobHandle`] whose
//! `wait()` blocks for the result. Workers pull from a shared queue
//! (work-stealing by contention — single consumer lock on the receiver),
//! run the algorithm, and report per-kind latency into [`Metrics`].
//!
//! Each executor thread installs its share of the process-wide `threads`
//! knob as a per-thread pool budget
//! ([`crate::parallel::set_thread_budget`]) at startup, so the pool
//! regions its jobs open — matmul dispatch, sketch applies, CUR
//! selection — use `threads / workers` lanes each instead of all of
//! them. Without the cap, N workers running pool-hungry jobs would
//! oversubscribe the machine N×.

use super::jobs::{ApproxJob, JobResult, MatrixPayload};
use crate::error::{FgError, Result};
use crate::metrics::Metrics;
use crate::rng::rng;
use crate::spsd::{CountingOracle, RbfOracle};
use crate::svdstream::source::{CsrColumnStream, DenseColumnStream};
use std::sync::{mpsc, Arc, Mutex};

/// Handle to a submitted job.
pub struct JobHandle {
    rx: mpsc::Receiver<Result<JobResult>>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobResult> {
        self.rx
            .recv()
            .map_err(|_| FgError::Coordinator("router shut down before job completed".into()))?
    }
}

type QueueItem = (ApproxJob, mpsc::Sender<Result<JobResult>>);

/// The router service.
pub struct Router {
    tx: Option<mpsc::Sender<QueueItem>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Router {
    /// Spawn `workers` executor threads.
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1);
        let (tx, rx) = mpsc::channel::<QueueItem>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = rx.clone();
            let metrics = metrics.clone();
            handles.push(std::thread::spawn(move || {
                // This executor's share of the `threads` knob: nested
                // pool regions opened by its jobs stay within it, so
                // `workers × threads` never oversubscribes the machine.
                let budget = crate::parallel::share_budget(crate::parallel::threads(), workers, w);
                crate::parallel::set_thread_budget(budget);
                loop {
                    let item = rx.lock().unwrap().recv();
                    let Ok((job, reply)) = item else { break };
                    let kind = job.kind();
                    metrics.add(&format!("router.{kind}.submitted"), 1);
                    let result = metrics.time(&format!("router.{kind}.latency"), || execute(job));
                    metrics.add(&format!("router.{kind}.completed"), 1);
                    let _ = reply.send(result);
                }
            }));
        }
        Self { tx: Some(tx), workers: handles, metrics }
    }

    /// Submit a job; returns immediately.
    pub fn submit(&self, job: ApproxJob) -> JobHandle {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("router already shut down")
            .send((job, reply_tx))
            .expect("router workers exited");
        JobHandle { rx: reply_rx }
    }

    /// Drain and join workers.
    pub fn shutdown(mut self) {
        self.tx.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Execute one job (the worker body).
fn execute(job: ApproxJob) -> Result<JobResult> {
    match job {
        ApproxJob::Gmr { a, c, r, cfg, seed } => {
            let mut rr = rng(seed);
            let sol = crate::gmr::solve_fast(a.as_input(), &c, &r, &cfg, &mut rr);
            Ok(JobResult::Gmr { x: sol.x })
        }
        ApproxJob::GmrExact { a, c, r } => {
            let sol = crate::gmr::solve_exact(a.as_input(), &c, &r);
            Ok(JobResult::Gmr { x: sol.x })
        }
        ApproxJob::SpsdKernel { x, sigma, c, s, seed } => {
            let mut rr = rng(seed);
            let oracle = RbfOracle::new(&x, sigma);
            let counting = CountingOracle::new(&oracle);
            let sol = crate::spsd::faster_spsd(
                &counting,
                &crate::spsd::FasterSpsdConfig { c, s },
                &mut rr,
            );
            Ok(JobResult::Spsd {
                idx: sol.idx,
                c: sol.c,
                x: sol.x,
                entries_observed: counting.observed(),
            })
        }
        ApproxJob::Cur { a, cfg, seed } => {
            let mut rr = rng(seed);
            let cur = crate::cur::decompose(a.as_input(), &cfg, &mut rr);
            Ok(JobResult::Cur { cur })
        }
        ApproxJob::StreamingCur { a, cfg, block, seed } => {
            // Single pass over the payload; the sketch applies inside
            // run on this executor's budgeted pool share.
            let mut rr = rng(seed);
            let res = match &a {
                MatrixPayload::Dense(m) => {
                    let mut stream = DenseColumnStream::new(m, block);
                    crate::cur::streaming_cur(&mut stream, &cfg, &mut rr)
                }
                MatrixPayload::Sparse(m) => {
                    let mut stream = CsrColumnStream::new(m, block);
                    crate::cur::streaming_cur(&mut stream, &cfg, &mut rr)
                }
            };
            Ok(JobResult::Cur { cur: res.cur })
        }
        ApproxJob::StreamSvd { a, cfg, block, seed } => {
            let mut rr = rng(seed);
            let res = match &a {
                MatrixPayload::Dense(m) => {
                    let mut stream = DenseColumnStream::new(m, block);
                    crate::svdstream::fast_sp_svd(&mut stream, &cfg, &mut rr)
                }
                MatrixPayload::Sparse(m) => {
                    let mut stream = CsrColumnStream::new(m, block);
                    crate::svdstream::fast_sp_svd(&mut stream, &cfg, &mut rr)
                }
            };
            Ok(JobResult::Svd { u: res.u, sigma: res.sigma, v: res.v })
        }
    }
}
