//! Fingerprint-keyed artifact cache — the amortization layer of the
//! serving daemon.
//!
//! The paper's economics are amortization: one pair of sketches serves
//! many downstream approximations — the same sketched factors back CUR
//! (§3), SPSD (§4), and single-pass SVD (§5) queries over the same
//! dataset. In a serving setting that sharing happens *across requests*:
//! repeated queries against a dataset the daemon has already factorized
//! should hit a cached artifact instead of recomputing it. This module
//! provides the key — a 64-bit fingerprint of the dataset bytes paired
//! with a digest of the job configuration (sketch family, sizes, seed) —
//! and an LRU store with a byte budget holding completed [`JobResult`]s.
//!
//! Because every job is deterministic given its seed, a cache hit is
//! *bitwise identical* to a cold compute (pinned in `coordinator::tests`),
//! so caching is transparent to callers. The inventory listing reuses the
//! [`crate::runtime::artifacts::ManifestEntry`] line shape, so cached
//! factorizations and AOT-compiled graphs read the same way.

use crate::coordinator::jobs::{ApproxJob, JobResult, MatrixPayload};
use crate::cur::{CoreMethod, SelectionStrategy};
use crate::error::Result;
use crate::linalg::Mat;
use crate::runtime::artifacts::{Manifest, ManifestEntry};
use crate::sparse::Csr;
use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

/// Word-folded FNV-1a: the classic byte-wise FNV-1a constants applied
/// per 64-bit word (one xor + multiply per `f64`/`usize`), which keeps
/// fingerprinting a large matrix cheap relative to any factorization of
/// it while still mixing every bit of every entry into the digest.
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn write_u64(&mut self, word: u64) {
        self.0 ^= word;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    pub fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }

    /// Fold in an `f64` by bit pattern (so `-0.0` and `0.0` differ —
    /// the cache contract is bitwise, not numeric).
    pub fn write_f64(&mut self, x: f64) {
        self.write_u64(x.to_bits());
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        for b in s.as_bytes() {
            self.write_u64(u64::from(*b));
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Fingerprint of a dense matrix: dimensions plus every entry's bit
/// pattern, in storage order.
pub fn fingerprint_dense(a: &Mat) -> u64 {
    let mut h = Fnv64::new();
    h.write_str("dense");
    h.write_usize(a.rows());
    h.write_usize(a.cols());
    for &x in a.data() {
        h.write_f64(x);
    }
    h.finish()
}

/// Fingerprint of a CSR matrix: dimensions plus the full sparsity
/// structure and values (`O(nnz)`, never densified).
pub fn fingerprint_sparse(a: &Csr) -> u64 {
    let mut h = Fnv64::new();
    h.write_str("csr");
    h.write_usize(a.rows());
    h.write_usize(a.cols());
    for i in 0..a.rows() {
        let (idx, vals) = a.row(i);
        h.write_usize(idx.len());
        for &j in idx {
            h.write_usize(j);
        }
        for &v in vals {
            h.write_f64(v);
        }
    }
    h.finish()
}

/// Fingerprint of a job payload (the dataset half of a [`CacheKey`]).
pub fn fingerprint_payload(p: &MatrixPayload) -> u64 {
    match p {
        MatrixPayload::Dense(a) => fingerprint_dense(a),
        MatrixPayload::Sparse(a) => fingerprint_sparse(a),
    }
}

/// Key of one cached artifact: dataset fingerprint × config digest.
///
/// Two jobs share a key exactly when they would compute the same factor:
/// same input bytes, same algorithm, same sketch configuration, same
/// seed. [`job_key`] derives both halves from an [`ApproxJob`].
///
/// ```
/// use fastgmr::coordinator::CacheKey;
/// let key = CacheKey::new(0x5eed_da7a, 0xc0ffee);
/// assert_eq!(key, CacheKey::new(0x5eed_da7a, 0xc0ffee));
/// assert_ne!(key, CacheKey::new(0x5eed_da7a, 0xc0ffef));   // config differs
/// assert_ne!(key, CacheKey::new(0x5eed_da7b, 0xc0ffee));   // dataset differs
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Fingerprint of the dataset bytes (payload matrices).
    pub dataset: u64,
    /// Digest of the job kind + configuration + seed.
    pub config: u64,
}

impl CacheKey {
    pub fn new(dataset: u64, config: u64) -> Self {
        Self { dataset, config }
    }
}

fn sketch_tag(h: &mut Fnv64, kind: crate::sketch::SketchKind) {
    h.write_str(kind.name());
}

fn selection_tag(h: &mut Fnv64, s: &SelectionStrategy) {
    match s {
        SelectionStrategy::Uniform => h.write_str("uniform"),
        SelectionStrategy::Leverage => h.write_str("leverage"),
        SelectionStrategy::SubspaceLeverage { k } => {
            h.write_str("subspace");
            h.write_usize(*k);
        }
        SelectionStrategy::SketchedLeverage { kind, size } => {
            h.write_str("sketched");
            sketch_tag(h, *kind);
            h.write_usize(*size);
        }
    }
}

fn core_tag(h: &mut Fnv64, c: &CoreMethod) {
    match c {
        CoreMethod::Exact => h.write_str("exact"),
        CoreMethod::FastGmr => h.write_str("fast_gmr"),
        CoreMethod::StabilizedQr => h.write_str("stabilized_qr"),
    }
}

/// Derive the cache key of a job: the dataset fingerprint over every
/// input matrix, and a config digest over the job kind, every
/// algorithmic parameter, and the seed (jobs are deterministic given
/// their seed, so equal keys imply bitwise-equal results).
pub fn job_key(job: &ApproxJob) -> CacheKey {
    let mut cfg = Fnv64::new();
    cfg.write_str(job.kind());
    let dataset = match job {
        ApproxJob::Gmr { a, c, r, cfg: f, seed } => {
            sketch_tag(&mut cfg, f.kind_c);
            sketch_tag(&mut cfg, f.kind_r);
            cfg.write_usize(f.s_c);
            cfg.write_usize(f.s_r);
            cfg.write_u64(*seed);
            let mut d = Fnv64::new();
            d.write_u64(fingerprint_payload(a));
            d.write_u64(fingerprint_dense(c));
            d.write_u64(fingerprint_dense(r));
            d.finish()
        }
        ApproxJob::GmrExact { a, c, r } => {
            let mut d = Fnv64::new();
            d.write_u64(fingerprint_payload(a));
            d.write_u64(fingerprint_dense(c));
            d.write_u64(fingerprint_dense(r));
            d.finish()
        }
        ApproxJob::SpsdKernel { x, sigma, c, s, seed } => {
            cfg.write_f64(*sigma);
            cfg.write_usize(*c);
            cfg.write_usize(*s);
            cfg.write_u64(*seed);
            fingerprint_dense(x)
        }
        ApproxJob::StreamSvd { a, cfg: f, block, seed } => {
            cfg.write_usize(f.k);
            cfg.write_usize(f.c);
            cfg.write_usize(f.r);
            cfg.write_usize(f.s_c);
            cfg.write_usize(f.s_r);
            cfg.write_usize(f.osnap_mult);
            sketch_tag(&mut cfg, f.core_kind);
            cfg.write_usize(*block);
            cfg.write_u64(*seed);
            fingerprint_payload(a)
        }
        ApproxJob::Cur { a, cfg: f, seed } => {
            cfg.write_usize(f.c);
            cfg.write_usize(f.r);
            selection_tag(&mut cfg, &f.selection);
            core_tag(&mut cfg, &f.core);
            sketch_tag(&mut cfg, f.sketch);
            cfg.write_usize(f.s_c);
            cfg.write_usize(f.s_r);
            cfg.write_u64(*seed);
            fingerprint_payload(a)
        }
        ApproxJob::StreamingCur { a, cfg: f, block, seed } => {
            cfg.write_usize(f.c);
            cfg.write_usize(f.r);
            cfg.write_usize(f.k);
            sketch_tag(&mut cfg, f.kind);
            cfg.write_usize(f.s_c);
            cfg.write_usize(f.s_r);
            cfg.write_usize(f.oversample);
            cfg.write_usize(*block);
            cfg.write_u64(*seed);
            fingerprint_payload(a)
        }
    };
    CacheKey::new(dataset, cfg.finish())
}

struct Entry {
    result: JobResult,
    bytes: usize,
    /// Last-touched logical time (monotone per cache op) — the LRU order.
    tick: u64,
    kind: &'static str,
}

/// First line of the on-disk cache inventory (format version gate).
const PERSIST_HEADER: &str = "# fastgmr artifact cache v1";

/// Outcome of [`ArtifactCache::warm_start_from`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WarmStartStats {
    /// Entries restored into the cache.
    pub loaded: usize,
    /// Records skipped because they failed to parse, their checksum did
    /// not match their payload, or their payload disagreed with the
    /// recorded shapes — logged to stderr, never fatal.
    pub skipped_corrupt: usize,
}

/// Split a persisted entry name (`{kind}_{dataset:016x}_{config:016x}`)
/// back into its kind tag and [`CacheKey`]. Kind tags may themselves
/// contain underscores (`gmr_exact`, `cur_stream`), so the two 16-digit
/// hex halves are peeled off the *end*.
fn parse_cache_name(name: &str) -> Option<(&str, CacheKey)> {
    let (rest, config) = name.rsplit_once('_')?;
    let (kind, dataset) = rest.rsplit_once('_')?;
    if dataset.len() != 16 || config.len() != 16 {
        return None;
    }
    let dataset = u64::from_str_radix(dataset, 16).ok()?;
    let config = u64::from_str_radix(config, 16).ok()?;
    Some((kind, CacheKey::new(dataset, config)))
}

/// LRU artifact store with a byte budget.
///
/// Holds completed [`JobResult`]s keyed by [`CacheKey`]; `get` refreshes
/// recency, `insert` evicts least-recently-used entries until the new
/// artifact fits. A result larger than the whole budget is not admitted
/// (churning every resident artifact for one oversized one is never a
/// win). Purely a data structure — the [`crate::coordinator::Router`]
/// owns the locking and translates hits/misses/evictions into `serve.*`
/// metrics.
pub struct ArtifactCache {
    budget: usize,
    bytes: usize,
    tick: u64,
    map: HashMap<CacheKey, Entry>,
}

impl ArtifactCache {
    /// An empty cache with the given byte budget.
    pub fn new(budget_bytes: usize) -> Self {
        Self { budget: budget_bytes, bytes: 0, tick: 0, map: HashMap::new() }
    }

    /// Look up an artifact, refreshing its recency on hit.
    pub fn get(&mut self, key: &CacheKey) -> Option<JobResult> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(key).map(|e| {
            e.tick = tick;
            e.result.clone()
        })
    }

    /// Store an artifact, evicting LRU entries until it fits; returns
    /// how many residents were evicted (0 if the artifact was oversized
    /// and not admitted, or simply fit).
    pub fn insert(&mut self, key: CacheKey, result: &JobResult) -> usize {
        let bytes = result.approx_bytes();
        if bytes > self.budget {
            return 0;
        }
        if let Some(old) = self.map.remove(&key) {
            self.bytes -= old.bytes;
        }
        let mut evicted = 0;
        while self.bytes + bytes > self.budget {
            let Some((&victim, _)) = self.map.iter().min_by_key(|(_, e)| e.tick) else { break };
            let gone = self.map.remove(&victim).expect("victim key just observed");
            self.bytes -= gone.bytes;
            evicted += 1;
        }
        self.tick += 1;
        self.bytes += bytes;
        let entry = Entry { result: result.clone(), bytes, tick: self.tick, kind: result.kind() };
        self.map.insert(key, entry);
        evicted
    }

    /// Resident artifact count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Resident bytes (always ≤ the budget).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// The configured byte budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Render the resident artifacts in the `manifest.txt` line format
    /// of [`ManifestEntry::to_line`], LRU first — the serving inventory
    /// the `fastgmr serve` subcommand prints.
    pub fn manifest(&self) -> String {
        let mut rows: Vec<(u64, String)> =
            self.map.iter().map(|(key, e)| (e.tick, manifest_entry(key, e).to_line())).collect();
        rows.sort();
        let mut out = format!(
            "# artifact cache: {} entries, {} / {} bytes (LRU first)\n",
            self.map.len(),
            self.bytes,
            self.budget
        );
        for (_, line) in rows {
            out.push_str(&line);
            out.push('\n');
        }
        out
    }

    /// Write the resident artifacts to disk, crash-safely: the full
    /// inventory is rendered to `<path>.tmp` and atomically renamed over
    /// `path`, so a crash mid-write leaves the previous inventory (or no
    /// file) intact — never a torn one.
    ///
    /// Each record is three lines: the [`ManifestEntry::to_line`] header
    /// (name `{kind}_{dataset}_{config}`, outputs = factor shapes), a
    /// `words <count> <fnv64>` checksum line, and the
    /// [`JobResult::to_words`] payload as one line of hex words. Records
    /// are written LRU first so a warm start replays them in recency
    /// order and reproduces the eviction order. Degraded results are
    /// never resident (the router does not cache them), so every record
    /// is a full-fidelity artifact.
    pub fn persist_to(&self, path: &Path) -> Result<()> {
        let mut rows: Vec<(u64, &CacheKey, &Entry)> =
            self.map.iter().map(|(key, e)| (e.tick, key, e)).collect();
        rows.sort_by_key(|(tick, ..)| *tick);
        let mut out = String::with_capacity(64 + self.bytes * 3);
        out.push_str(PERSIST_HEADER);
        out.push('\n');
        for (_, key, e) in rows {
            let words = e.result.to_words();
            let mut h = Fnv64::new();
            for &w in &words {
                h.write_u64(w);
            }
            out.push_str(&manifest_entry(key, e).to_line());
            out.push('\n');
            out.push_str(&format!("words {} {:016x}\n", words.len(), h.finish()));
            for (i, w) in words.iter().enumerate() {
                if i > 0 {
                    out.push(' ');
                }
                out.push_str(&format!("{w:016x}"));
            }
            out.push('\n');
        }
        let tmp = path.with_extension("tmp");
        fs::write(&tmp, &out)?;
        fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Restore artifacts persisted by [`ArtifactCache::persist_to`],
    /// inserting each record through the normal LRU/byte-budget path.
    /// A missing file is a cold start (zero stats, no error); a file
    /// whose first line is not the expected format header is refused
    /// with a config error. Individual records that fail to parse,
    /// fail their checksum, or decode to the wrong word count are
    /// skipped and counted (and logged to stderr) — one corrupt record
    /// never poisons the rest of the inventory.
    pub fn warm_start_from(&mut self, path: &Path) -> Result<WarmStartStats> {
        let text = match fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(WarmStartStats::default())
            }
            Err(e) => return Err(e.into()),
        };
        let mut lines = text.lines();
        if lines.next().map(str::trim) != Some(PERSIST_HEADER) {
            return Err(crate::error::FgError::Config(format!(
                "{} is not a fastgmr artifact cache inventory (missing `{PERSIST_HEADER}`)",
                path.display()
            )));
        }
        let mut stats = WarmStartStats::default();
        let mut lines = lines.peekable();
        while let Some(line) = lines.next() {
            if !line.starts_with("graph ") {
                continue; // resync: records always open with a manifest line
            }
            match Self::parse_record(line, &mut lines) {
                Some((key, result)) => {
                    self.insert(key, &result);
                    // A record oversized for this budget is valid but not
                    // admitted — neither loaded nor corrupt.
                    if self.map.contains_key(&key) {
                        stats.loaded += 1;
                    }
                }
                None => {
                    stats.skipped_corrupt += 1;
                    eprintln!(
                        "warm-start: skipping corrupt cache record at `{}`",
                        line.split_whitespace().nth(1).unwrap_or("?")
                    );
                }
            }
        }
        Ok(stats)
    }

    /// Parse one persisted record (manifest line + checksum line + hex
    /// payload line). Consumes the two follow-up lines only when they
    /// are structurally plausible, so a truncated record cannot swallow
    /// the next record's header.
    fn parse_record(
        header: &str,
        lines: &mut std::iter::Peekable<std::str::Lines<'_>>,
    ) -> Option<(CacheKey, JobResult)> {
        let entry = Manifest::parse_line(Path::new(""), header)?;
        let (kind, key) = parse_cache_name(&entry.name)?;
        let meta = lines.peek().copied()?;
        if !meta.starts_with("words ") {
            return None;
        }
        lines.next();
        let mut parts = meta.split_whitespace().skip(1);
        let count: usize = parts.next()?.parse().ok()?;
        let checksum = u64::from_str_radix(parts.next()?, 16).ok()?;
        let data = lines.peek().copied()?;
        if data.starts_with("graph ") {
            return None;
        }
        lines.next();
        let words: Vec<u64> = data
            .split_whitespace()
            .map(|w| u64::from_str_radix(w, 16).ok())
            .collect::<Option<_>>()?;
        if words.len() != count {
            return None;
        }
        let mut h = Fnv64::new();
        for &w in &words {
            h.write_u64(w);
        }
        if h.finish() != checksum {
            return None;
        }
        JobResult::from_words(kind, &entry.output_shapes, &words).map(|r| (key, r))
    }
}

/// Render one resident entry as the shared manifest-line shape.
fn manifest_entry(key: &CacheKey, e: &Entry) -> ManifestEntry {
    ManifestEntry {
        name: format!("{}_{:016x}_{:016x}", e.kind, key.dataset, key.config),
        hlo_path: PathBuf::from("cache"),
        input_shapes: Vec::new(),
        output_shapes: e.result.output_shapes(),
        golden_path: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result_of(rows: usize, cols: usize) -> JobResult {
        JobResult::Gmr { x: Mat::zeros(rows, cols) }
    }

    #[test]
    fn fingerprint_is_content_sensitive() {
        let a = Mat::from_fn(5, 4, |i, j| (i * 4 + j) as f64);
        let mut b = a.clone();
        assert_eq!(fingerprint_dense(&a), fingerprint_dense(&b));
        b.data_mut()[7] += 1e-12;
        assert_ne!(fingerprint_dense(&a), fingerprint_dense(&b));
        // Same bytes, different shape ⇒ different fingerprint.
        let c = Mat::from_vec(4, 5, a.data().to_vec());
        assert_ne!(fingerprint_dense(&a), fingerprint_dense(&c));
    }

    #[test]
    fn sparse_and_dense_fingerprints_are_tagged_apart() {
        let d = Mat::zeros(3, 3);
        let s = Csr::from_dense(&d, 0.0);
        assert_ne!(
            fingerprint_payload(&MatrixPayload::Dense(d)),
            fingerprint_payload(&MatrixPayload::Sparse(s))
        );
    }

    #[test]
    fn job_key_separates_seed_config_and_data() {
        let a = Mat::from_fn(10, 8, |i, j| ((i * 31 + j * 7) % 13) as f64);
        let job = |seed, c| ApproxJob::Cur {
            a: MatrixPayload::Dense(a.clone()),
            cfg: crate::cur::CurConfig::fast(c, 4, 2),
            seed,
        };
        let base = job_key(&job(1, 4));
        assert_eq!(base, job_key(&job(1, 4)), "key must be a pure function of the job");
        assert_ne!(base, job_key(&job(2, 4)), "seed must enter the config digest");
        assert_ne!(base, job_key(&job(1, 5)), "config must enter the digest");
        assert_eq!(base.dataset, job_key(&job(2, 4)).dataset, "dataset half ignores config");
        let mut b = a.clone();
        b.data_mut()[0] += 1.0;
        let other = job_key(&ApproxJob::Cur {
            a: MatrixPayload::Dense(b),
            cfg: crate::cur::CurConfig::fast(4, 4, 2),
            seed: 1,
        });
        assert_ne!(base.dataset, other.dataset, "data bytes must enter the dataset half");
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        // 3 entries of 800 bytes each against a 2000-byte budget.
        let mut cache = ArtifactCache::new(2000);
        let (k1, k2, k3) = (CacheKey::new(1, 1), CacheKey::new(2, 2), CacheKey::new(3, 3));
        let r = result_of(10, 10); // 800 bytes
        assert_eq!(r.approx_bytes(), 800);
        assert_eq!(cache.insert(k1, &r), 0);
        assert_eq!(cache.insert(k2, &r), 0);
        assert_eq!(cache.bytes(), 1600);
        // Touch k1 so k2 is the LRU victim.
        assert!(cache.get(&k1).is_some());
        assert_eq!(cache.insert(k3, &r), 1, "one eviction to fit the third entry");
        assert_eq!(cache.len(), 2);
        assert!(cache.bytes() <= cache.budget());
        assert!(cache.get(&k2).is_none(), "LRU entry k2 must be the victim");
        assert!(cache.get(&k1).is_some() && cache.get(&k3).is_some());
    }

    #[test]
    fn oversized_artifacts_are_not_admitted() {
        let mut cache = ArtifactCache::new(100);
        let key = CacheKey::new(7, 7);
        assert_eq!(cache.insert(key, &result_of(10, 10)), 0);
        assert!(cache.is_empty(), "an artifact larger than the budget must not evict residents");
        assert!(cache.get(&key).is_none());
    }

    #[test]
    fn reinsert_replaces_in_place() {
        let mut cache = ArtifactCache::new(2000);
        let key = CacheKey::new(9, 9);
        cache.insert(key, &result_of(10, 10));
        cache.insert(key, &result_of(5, 10));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.bytes(), 400);
    }

    #[test]
    fn manifest_lists_entries_in_manifest_line_format() {
        let mut cache = ArtifactCache::new(10_000);
        cache.insert(CacheKey::new(0xAB, 0xCD), &result_of(4, 3));
        let listing = cache.manifest();
        assert!(listing.starts_with("# artifact cache: 1 entries"));
        assert!(listing.contains("file=cache"), "reuses the manifest line shape: {listing}");
        assert!(listing.contains("outputs=4x3"), "{listing}");
    }

    /// One result of every kind, with distinctive (irrational) entries so
    /// a bitwise round-trip failure cannot hide behind round numbers.
    fn one_of_each() -> Vec<(CacheKey, JobResult)> {
        let m = |r, c, salt: f64| Mat::from_fn(r, c, |i, j| ((i * 7 + j) as f64 + salt).sin());
        vec![
            (CacheKey::new(0x11, 0xA1), JobResult::Gmr { x: m(4, 3, 0.1) }),
            (
                CacheKey::new(0x22, 0xA2),
                JobResult::Spsd {
                    idx: vec![3, 1, 4, 1, 5],
                    c: m(6, 5, 0.2),
                    x: m(5, 5, 0.3),
                    entries_observed: 271828,
                },
            ),
            (
                CacheKey::new(0x33, 0xA3),
                JobResult::Svd { u: m(6, 2, 0.4), sigma: vec![2.5, 0.125], v: m(5, 2, 0.5) },
            ),
            (
                CacheKey::new(0x44, 0xA4),
                JobResult::Cur {
                    cur: crate::cur::CurDecomposition {
                        col_idx: vec![0, 2, 3],
                        row_idx: vec![1, 4],
                        c: m(5, 3, 0.6),
                        u: m(3, 2, 0.7),
                        r: m(2, 6, 0.8),
                    },
                },
            ),
        ]
    }

    #[test]
    fn persist_and_warm_start_round_trip_every_kind_bitwise() {
        let path = Path::new("/tmp/fastgmr_cache_roundtrip_test.txt");
        let mut cache = ArtifactCache::new(1 << 20);
        for (key, result) in &one_of_each() {
            cache.insert(*key, result);
        }
        cache.persist_to(path).unwrap();
        let mut warmed = ArtifactCache::new(1 << 20);
        let stats = warmed.warm_start_from(path).unwrap();
        assert_eq!(stats, WarmStartStats { loaded: 4, skipped_corrupt: 0 });
        for (key, expected) in &one_of_each() {
            let got = warmed.get(key).expect("entry survives the round trip");
            assert_eq!(got.kind(), expected.kind());
            assert_eq!(got.output_shapes(), expected.output_shapes());
            let label = format!("bitwise round trip for {}", got.kind());
            assert_eq!(got.to_words(), expected.to_words(), "{label}");
        }
        let _ = fs::remove_file(path);
    }

    #[test]
    fn warm_start_skips_corrupt_records_and_keeps_the_rest() {
        let path = Path::new("/tmp/fastgmr_cache_corrupt_test.txt");
        let mut cache = ArtifactCache::new(1 << 20);
        for (key, result) in &one_of_each() {
            cache.insert(*key, result);
        }
        cache.persist_to(path).unwrap();
        // Mangle the checksum of the second record only.
        let text = fs::read_to_string(path).unwrap();
        let mut seen = 0;
        let mangled: Vec<String> = text
            .lines()
            .map(|l| {
                if l.starts_with("words ") {
                    seen += 1;
                    if seen == 2 {
                        let mut parts: Vec<&str> = l.split_whitespace().collect();
                        parts[2] = "0000000000000000";
                        return parts.join(" ");
                    }
                }
                l.to_string()
            })
            .collect();
        fs::write(path, mangled.join("\n")).unwrap();
        let mut warmed = ArtifactCache::new(1 << 20);
        let stats = warmed.warm_start_from(path).unwrap();
        assert_eq!(stats.loaded, 3, "the three intact records load");
        assert_eq!(stats.skipped_corrupt, 1, "the mangled record is skipped, not fatal");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn warm_start_from_missing_file_is_a_cold_start() {
        let mut cache = ArtifactCache::new(1000);
        let stats =
            cache.warm_start_from(Path::new("/tmp/fastgmr_no_such_cache_file.txt")).unwrap();
        assert_eq!(stats, WarmStartStats::default());
        assert!(cache.is_empty());
    }

    #[test]
    fn warm_start_refuses_a_file_without_the_format_header() {
        let path = Path::new("/tmp/fastgmr_cache_bad_header_test.txt");
        fs::write(path, "not a cache inventory\n").unwrap();
        let err = ArtifactCache::new(1000).warm_start_from(path).unwrap_err();
        assert!(err.to_string().contains("artifact cache"), "{err}");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn cache_names_parse_back_including_underscored_kinds() {
        for kind in ApproxJob::KINDS {
            let name = format!("{}_{:016x}_{:016x}", kind, 0xdead_beefu64, 7u64);
            let (parsed, key) = parse_cache_name(&name).expect("name round-trips");
            assert_eq!(parsed, kind);
            assert_eq!(key, CacheKey::new(0xdead_beef, 7));
        }
        assert!(parse_cache_name("gmr_0123_0456").is_none(), "short hex halves are rejected");
    }
}
