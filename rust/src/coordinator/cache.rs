//! Fingerprint-keyed artifact cache — the amortization layer of the
//! serving daemon.
//!
//! The paper's economics are amortization: one pair of sketches serves
//! many downstream approximations — the same sketched factors back CUR
//! (§3), SPSD (§4), and single-pass SVD (§5) queries over the same
//! dataset. In a serving setting that sharing happens *across requests*:
//! repeated queries against a dataset the daemon has already factorized
//! should hit a cached artifact instead of recomputing it. This module
//! provides the key — a 64-bit fingerprint of the dataset bytes paired
//! with a digest of the job configuration (sketch family, sizes, seed) —
//! and an LRU store with a byte budget holding completed [`JobResult`]s.
//!
//! Because every job is deterministic given its seed, a cache hit is
//! *bitwise identical* to a cold compute (pinned in `coordinator::tests`),
//! so caching is transparent to callers. The inventory listing reuses the
//! [`crate::runtime::artifacts::ManifestEntry`] line shape, so cached
//! factorizations and AOT-compiled graphs read the same way.

use crate::coordinator::jobs::{ApproxJob, JobResult, MatrixPayload};
use crate::cur::{CoreMethod, SelectionStrategy};
use crate::error::Result;
use crate::linalg::Mat;
use crate::runtime::artifacts::{Manifest, ManifestEntry};
use crate::sparse::Csr;
use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

/// Word-folded FNV-1a: the classic byte-wise FNV-1a constants applied
/// per 64-bit word (one xor + multiply per `f64`/`usize`), which keeps
/// fingerprinting a large matrix cheap relative to any factorization of
/// it while still mixing every bit of every entry into the digest.
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn write_u64(&mut self, word: u64) {
        self.0 ^= word;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    pub fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }

    /// Fold in an `f64` by bit pattern (so `-0.0` and `0.0` differ —
    /// the cache contract is bitwise, not numeric).
    pub fn write_f64(&mut self, x: f64) {
        self.write_u64(x.to_bits());
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        for b in s.as_bytes() {
            self.write_u64(u64::from(*b));
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Fingerprint of a dense matrix: dimensions plus every entry's bit
/// pattern, in storage order.
pub fn fingerprint_dense(a: &Mat) -> u64 {
    let mut h = Fnv64::new();
    h.write_str("dense");
    h.write_usize(a.rows());
    h.write_usize(a.cols());
    for &x in a.data() {
        h.write_f64(x);
    }
    h.finish()
}

/// Fingerprint of a CSR matrix: dimensions plus the full sparsity
/// structure and values (`O(nnz)`, never densified).
pub fn fingerprint_sparse(a: &Csr) -> u64 {
    let mut h = Fnv64::new();
    h.write_str("csr");
    h.write_usize(a.rows());
    h.write_usize(a.cols());
    for i in 0..a.rows() {
        let (idx, vals) = a.row(i);
        h.write_usize(idx.len());
        for &j in idx {
            h.write_usize(j);
        }
        for &v in vals {
            h.write_f64(v);
        }
    }
    h.finish()
}

/// Fingerprint of a job payload (the dataset half of a [`CacheKey`]).
pub fn fingerprint_payload(p: &MatrixPayload) -> u64 {
    match p {
        MatrixPayload::Dense(a) => fingerprint_dense(a),
        MatrixPayload::Sparse(a) => fingerprint_sparse(a),
    }
}

/// Key of one cached artifact: dataset fingerprint × config digest.
///
/// Two jobs share a key exactly when they would compute the same factor:
/// same input bytes, same algorithm, same sketch configuration, same
/// seed. [`job_key`] derives both halves from an [`ApproxJob`].
///
/// ```
/// use fastgmr::coordinator::CacheKey;
/// let key = CacheKey::new(0x5eed_da7a, 0xc0ffee);
/// assert_eq!(key, CacheKey::new(0x5eed_da7a, 0xc0ffee));
/// assert_ne!(key, CacheKey::new(0x5eed_da7a, 0xc0ffef));   // config differs
/// assert_ne!(key, CacheKey::new(0x5eed_da7b, 0xc0ffee));   // dataset differs
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Fingerprint of the dataset bytes (payload matrices).
    pub dataset: u64,
    /// Digest of the job kind + configuration + seed.
    pub config: u64,
}

impl CacheKey {
    pub fn new(dataset: u64, config: u64) -> Self {
        Self { dataset, config }
    }
}

fn sketch_tag(h: &mut Fnv64, kind: crate::sketch::SketchKind) {
    h.write_str(kind.name());
}

fn selection_tag(h: &mut Fnv64, s: &SelectionStrategy) {
    match s {
        SelectionStrategy::Uniform => h.write_str("uniform"),
        SelectionStrategy::Leverage => h.write_str("leverage"),
        SelectionStrategy::SubspaceLeverage { k } => {
            h.write_str("subspace");
            h.write_usize(*k);
        }
        SelectionStrategy::SketchedLeverage { kind, size } => {
            h.write_str("sketched");
            sketch_tag(h, *kind);
            h.write_usize(*size);
        }
    }
}

fn core_tag(h: &mut Fnv64, c: &CoreMethod) {
    match c {
        CoreMethod::Exact => h.write_str("exact"),
        CoreMethod::FastGmr => h.write_str("fast_gmr"),
        CoreMethod::StabilizedQr => h.write_str("stabilized_qr"),
    }
}

/// Derive the cache key of a job: the dataset fingerprint over every
/// input matrix, and a config digest over the job kind, every
/// algorithmic parameter, and the seed (jobs are deterministic given
/// their seed, so equal keys imply bitwise-equal results).
pub fn job_key(job: &ApproxJob) -> CacheKey {
    let mut cfg = Fnv64::new();
    cfg.write_str(job.kind());
    let dataset = match job {
        ApproxJob::Gmr { a, c, r, cfg: f, seed } => {
            sketch_tag(&mut cfg, f.kind_c);
            sketch_tag(&mut cfg, f.kind_r);
            cfg.write_usize(f.s_c);
            cfg.write_usize(f.s_r);
            cfg.write_u64(*seed);
            let mut d = Fnv64::new();
            d.write_u64(fingerprint_payload(a));
            d.write_u64(fingerprint_dense(c));
            d.write_u64(fingerprint_dense(r));
            d.finish()
        }
        ApproxJob::GmrExact { a, c, r } => {
            let mut d = Fnv64::new();
            d.write_u64(fingerprint_payload(a));
            d.write_u64(fingerprint_dense(c));
            d.write_u64(fingerprint_dense(r));
            d.finish()
        }
        ApproxJob::SpsdKernel { x, sigma, c, s, seed } => {
            cfg.write_f64(*sigma);
            cfg.write_usize(*c);
            cfg.write_usize(*s);
            cfg.write_u64(*seed);
            fingerprint_dense(x)
        }
        ApproxJob::StreamSvd { a, cfg: f, block, seed } => {
            cfg.write_usize(f.k);
            cfg.write_usize(f.c);
            cfg.write_usize(f.r);
            cfg.write_usize(f.s_c);
            cfg.write_usize(f.s_r);
            cfg.write_usize(f.osnap_mult);
            sketch_tag(&mut cfg, f.core_kind);
            cfg.write_usize(*block);
            cfg.write_u64(*seed);
            fingerprint_payload(a)
        }
        ApproxJob::Cur { a, cfg: f, seed } => {
            cfg.write_usize(f.c);
            cfg.write_usize(f.r);
            selection_tag(&mut cfg, &f.selection);
            core_tag(&mut cfg, &f.core);
            sketch_tag(&mut cfg, f.sketch);
            cfg.write_usize(f.s_c);
            cfg.write_usize(f.s_r);
            cfg.write_u64(*seed);
            fingerprint_payload(a)
        }
        ApproxJob::StreamingCur { a, cfg: f, block, seed } => {
            cfg.write_usize(f.c);
            cfg.write_usize(f.r);
            cfg.write_usize(f.k);
            sketch_tag(&mut cfg, f.kind);
            cfg.write_usize(f.s_c);
            cfg.write_usize(f.s_r);
            cfg.write_usize(f.oversample);
            cfg.write_usize(*block);
            cfg.write_u64(*seed);
            fingerprint_payload(a)
        }
    };
    CacheKey::new(dataset, cfg.finish())
}

struct Entry {
    result: JobResult,
    bytes: usize,
    /// Last-touched logical time (monotone per cache op) — the LRU order.
    tick: u64,
    /// Logical time of insertion — the TTL clock. Never refreshed by
    /// hits: TTL bounds an artifact's *age*, not its idleness (idleness
    /// is LRU's job).
    inserted: u64,
    kind: &'static str,
}

/// Outcome of a TTL-aware [`ArtifactCache::lookup`].
///
/// `Expired` is distinct from `Miss` so the router can count staleness
/// separately (`serve.cache.expired`) while still treating both as "go
/// compute" — an expired artifact was bitwise-correct but older than
/// the configured freshness bound, so it is dropped, not returned.
#[derive(Debug)]
pub enum Lookup {
    /// Resident and fresh — a clone of the stored artifact.
    Hit(JobResult),
    /// Resident but older than the TTL; the entry has been dropped.
    Expired,
    /// Not resident.
    Miss,
}

/// First line of the on-disk cache inventory (format version gate).
const PERSIST_HEADER: &str = "# fastgmr artifact cache v1";

/// Outcome of [`ArtifactCache::warm_start_from`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WarmStartStats {
    /// Entries restored into the cache.
    pub loaded: usize,
    /// Records skipped because they failed to parse, their checksum did
    /// not match their payload, or their payload disagreed with the
    /// recorded shapes — logged to stderr, never fatal.
    pub skipped_corrupt: usize,
    /// Valid records not restored because their persisted age already
    /// exceeded this cache's TTL — a restart must not resurrect
    /// artifacts the running daemon would have refused to serve.
    pub expired: usize,
}

/// Split a persisted entry name (`{kind}_{dataset:016x}_{config:016x}`)
/// back into its kind tag and [`CacheKey`]. Kind tags may themselves
/// contain underscores (`gmr_exact`, `cur_stream`), so the two 16-digit
/// hex halves are peeled off the *end*.
fn parse_cache_name(name: &str) -> Option<(&str, CacheKey)> {
    let (rest, config) = name.rsplit_once('_')?;
    let (kind, dataset) = rest.rsplit_once('_')?;
    if dataset.len() != 16 || config.len() != 16 {
        return None;
    }
    let dataset = u64::from_str_radix(dataset, 16).ok()?;
    let config = u64::from_str_radix(config, 16).ok()?;
    Some((kind, CacheKey::new(dataset, config)))
}

/// LRU artifact store with a byte budget and an optional logical TTL.
///
/// Holds completed [`JobResult`]s keyed by [`CacheKey`]; `get` refreshes
/// recency, `insert` evicts least-recently-used entries until the new
/// artifact fits. A result larger than the whole budget is not admitted
/// (churning every resident artifact for one oversized one is never a
/// win). The TTL is measured in *logical ticks* (one per cache
/// operation), not wall time, so expiry is deterministic and replayable
/// — the same operation sequence expires the same entries. Purely a
/// data structure — the [`crate::coordinator::Router`] owns the locking
/// and translates hits/misses/expiries/evictions into `serve.*`
/// metrics.
pub struct ArtifactCache {
    budget: usize,
    bytes: usize,
    tick: u64,
    /// Maximum entry age in ticks (`0` = never expires).
    ttl: u64,
    map: HashMap<CacheKey, Entry>,
}

impl ArtifactCache {
    /// An empty cache with the given byte budget (and no TTL).
    pub fn new(budget_bytes: usize) -> Self {
        Self { budget: budget_bytes, bytes: 0, tick: 0, ttl: 0, map: HashMap::new() }
    }

    /// Builder: expire entries older than `ttl` cache operations
    /// (`0` = never).
    pub fn with_ttl(mut self, ttl: u64) -> Self {
        self.ttl = ttl;
        self
    }

    /// Look up an artifact, refreshing its recency on hit. TTL-expired
    /// entries read as `None` (see [`ArtifactCache::lookup`]).
    pub fn get(&mut self, key: &CacheKey) -> Option<JobResult> {
        match self.lookup(key) {
            Lookup::Hit(r) => Some(r),
            Lookup::Expired | Lookup::Miss => None,
        }
    }

    /// TTL-aware lookup distinguishing a fresh hit from an expired
    /// resident and a plain miss. An expired entry is removed on
    /// observation (lazy expiry — no background sweeper to schedule),
    /// so its bytes are immediately available to the next insert.
    pub fn lookup(&mut self, key: &CacheKey) -> Lookup {
        self.tick += 1;
        let tick = self.tick;
        let ttl = self.ttl;
        let Some(e) = self.map.get_mut(key) else { return Lookup::Miss };
        if ttl > 0 && tick.saturating_sub(e.inserted) > ttl {
            let gone = self.map.remove(key).expect("entry just observed");
            self.bytes -= gone.bytes;
            return Lookup::Expired;
        }
        e.tick = tick;
        Lookup::Hit(e.result.clone())
    }

    /// Store an artifact, evicting LRU entries until it fits; returns
    /// how many residents were evicted (0 if the artifact was oversized
    /// and not admitted, or simply fit).
    pub fn insert(&mut self, key: CacheKey, result: &JobResult) -> usize {
        let bytes = result.approx_bytes();
        if bytes > self.budget {
            return 0;
        }
        if let Some(old) = self.map.remove(&key) {
            self.bytes -= old.bytes;
        }
        let mut evicted = 0;
        while self.bytes + bytes > self.budget {
            let Some((&victim, _)) = self.map.iter().min_by_key(|(_, e)| e.tick) else { break };
            let gone = self.map.remove(&victim).expect("victim key just observed");
            self.bytes -= gone.bytes;
            evicted += 1;
        }
        self.tick += 1;
        self.bytes += bytes;
        let entry = Entry {
            result: result.clone(),
            bytes,
            tick: self.tick,
            inserted: self.tick,
            kind: result.kind(),
        };
        self.map.insert(key, entry);
        evicted
    }

    /// Resident artifact count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Resident bytes (always ≤ the budget).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// The configured byte budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Render the resident artifacts in the `manifest.txt` line format
    /// of [`ManifestEntry::to_line`], LRU first — the serving inventory
    /// the `fastgmr serve` subcommand prints.
    pub fn manifest(&self) -> String {
        let mut rows: Vec<(u64, String)> =
            self.map.iter().map(|(key, e)| (e.tick, manifest_entry(key, e).to_line())).collect();
        rows.sort();
        let mut out = format!(
            "# artifact cache: {} entries, {} / {} bytes (LRU first)\n",
            self.map.len(),
            self.bytes,
            self.budget
        );
        for (_, line) in rows {
            out.push_str(&line);
            out.push('\n');
        }
        out
    }

    /// Write the resident artifacts to disk, crash-safely: the full
    /// inventory is rendered to `<path>.tmp` and atomically renamed over
    /// `path`, so a crash mid-write leaves the previous inventory (or no
    /// file) intact — never a torn one.
    ///
    /// Each record is three lines: the [`ManifestEntry::to_line`] header
    /// (name `{kind}_{dataset}_{config}`, outputs = factor shapes), a
    /// `words <count> <fnv64> <inserted-tick>` checksum line, and the
    /// [`JobResult::to_words`] payload as one line of hex words. A
    /// `tick <now>` line after the format header records the logical
    /// clock at persist time, so a warm start can reconstruct each
    /// entry's *age* and honor the TTL across restarts (both additions
    /// are ignored by pre-TTL readers: the resync loop skips unknown
    /// lines and the checksum parser ignores trailing tokens). Records
    /// are written LRU first so a warm start replays them in recency
    /// order and reproduces the eviction order. Degraded results are
    /// never resident (the router does not cache them), so every record
    /// is a full-fidelity artifact.
    pub fn persist_to(&self, path: &Path) -> Result<()> {
        let mut rows: Vec<(u64, &CacheKey, &Entry)> =
            self.map.iter().map(|(key, e)| (e.tick, key, e)).collect();
        rows.sort_by_key(|(tick, ..)| *tick);
        let mut out = String::with_capacity(64 + self.bytes * 3);
        out.push_str(PERSIST_HEADER);
        out.push('\n');
        out.push_str(&format!("tick {}\n", self.tick));
        for (_, key, e) in rows {
            let words = e.result.to_words();
            let mut h = Fnv64::new();
            for &w in &words {
                h.write_u64(w);
            }
            out.push_str(&manifest_entry(key, e).to_line());
            out.push('\n');
            out.push_str(&format!("words {} {:016x} {}\n", words.len(), h.finish(), e.inserted));
            for (i, w) in words.iter().enumerate() {
                if i > 0 {
                    out.push(' ');
                }
                out.push_str(&format!("{w:016x}"));
            }
            out.push('\n');
        }
        let tmp = path.with_extension("tmp");
        fs::write(&tmp, &out)?;
        fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Restore artifacts persisted by [`ArtifactCache::persist_to`],
    /// inserting each record through the normal LRU/byte-budget path.
    /// A missing file is a cold start (zero stats, no error); a file
    /// whose first line is not the expected format header is refused
    /// with a config error. Individual records that fail to parse,
    /// fail their checksum, or decode to the wrong word count are
    /// skipped and counted (and logged to stderr) — one corrupt record
    /// never poisons the rest of the inventory.
    pub fn warm_start_from(&mut self, path: &Path) -> Result<WarmStartStats> {
        let text = match fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(WarmStartStats::default())
            }
            Err(e) => return Err(e.into()),
        };
        let mut lines = text.lines();
        if lines.next().map(str::trim) != Some(PERSIST_HEADER) {
            return Err(crate::error::FgError::Config(format!(
                "{} is not a fastgmr artifact cache inventory (missing `{PERSIST_HEADER}`)",
                path.display()
            )));
        }
        let mut stats = WarmStartStats::default();
        let mut lines = lines.peekable();
        // Logical clock at persist time (absent in pre-TTL inventories:
        // every record then reads as age 0, i.e. fresh).
        let mut persist_tick = 0u64;
        if let Some(line) = lines.peek() {
            if let Some(t) = line.strip_prefix("tick ").and_then(|t| t.trim().parse().ok()) {
                persist_tick = t;
                lines.next();
            }
        }
        while let Some(line) = lines.next() {
            if !line.starts_with("graph ") {
                continue; // resync: records always open with a manifest line
            }
            match Self::parse_record(line, &mut lines) {
                Some((key, result, inserted)) => {
                    let age = persist_tick.saturating_sub(inserted);
                    if self.ttl > 0 && age > self.ttl {
                        // Already stale on disk — restoring it would
                        // serve an artifact the daemon that persisted it
                        // had committed to expiring.
                        stats.expired += 1;
                        continue;
                    }
                    self.insert(key, &result);
                    // A record oversized for this budget is valid but not
                    // admitted — neither loaded nor corrupt.
                    if let Some(e) = self.map.get_mut(&key) {
                        // Back-date the entry so its remaining TTL
                        // matches what it had at persist time.
                        e.inserted = self.tick.saturating_sub(age);
                        stats.loaded += 1;
                    }
                }
                None => {
                    stats.skipped_corrupt += 1;
                    eprintln!(
                        "warm-start: skipping corrupt cache record at `{}`",
                        line.split_whitespace().nth(1).unwrap_or("?")
                    );
                }
            }
        }
        Ok(stats)
    }

    /// Parse one persisted record (manifest line + checksum line + hex
    /// payload line). Consumes the two follow-up lines only when they
    /// are structurally plausible, so a truncated record cannot swallow
    /// the next record's header. The third value is the entry's
    /// insertion tick (0 for pre-TTL inventories without the token).
    fn parse_record(
        header: &str,
        lines: &mut std::iter::Peekable<std::str::Lines<'_>>,
    ) -> Option<(CacheKey, JobResult, u64)> {
        let entry = Manifest::parse_line(Path::new(""), header)?;
        let (kind, key) = parse_cache_name(&entry.name)?;
        let meta = lines.peek().copied()?;
        if !meta.starts_with("words ") {
            return None;
        }
        lines.next();
        let mut parts = meta.split_whitespace().skip(1);
        let count: usize = parts.next()?.parse().ok()?;
        let checksum = u64::from_str_radix(parts.next()?, 16).ok()?;
        let inserted: u64 = parts.next().and_then(|t| t.parse().ok()).unwrap_or(0);
        let data = lines.peek().copied()?;
        if data.starts_with("graph ") {
            return None;
        }
        lines.next();
        let words: Vec<u64> = data
            .split_whitespace()
            .map(|w| u64::from_str_radix(w, 16).ok())
            .collect::<Option<_>>()?;
        if words.len() != count {
            return None;
        }
        let mut h = Fnv64::new();
        for &w in &words {
            h.write_u64(w);
        }
        if h.finish() != checksum {
            return None;
        }
        JobResult::from_words(kind, &entry.output_shapes, &words).map(|r| (key, r, inserted))
    }
}

/// Render one resident entry as the shared manifest-line shape.
fn manifest_entry(key: &CacheKey, e: &Entry) -> ManifestEntry {
    ManifestEntry {
        name: format!("{}_{:016x}_{:016x}", e.kind, key.dataset, key.config),
        hlo_path: PathBuf::from("cache"),
        input_shapes: Vec::new(),
        output_shapes: e.result.output_shapes(),
        golden_path: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result_of(rows: usize, cols: usize) -> JobResult {
        JobResult::Gmr { x: Mat::zeros(rows, cols) }
    }

    #[test]
    fn fingerprint_is_content_sensitive() {
        let a = Mat::from_fn(5, 4, |i, j| (i * 4 + j) as f64);
        let mut b = a.clone();
        assert_eq!(fingerprint_dense(&a), fingerprint_dense(&b));
        b.data_mut()[7] += 1e-12;
        assert_ne!(fingerprint_dense(&a), fingerprint_dense(&b));
        // Same bytes, different shape ⇒ different fingerprint.
        let c = Mat::from_vec(4, 5, a.data().to_vec());
        assert_ne!(fingerprint_dense(&a), fingerprint_dense(&c));
    }

    #[test]
    fn sparse_and_dense_fingerprints_are_tagged_apart() {
        let d = Mat::zeros(3, 3);
        let s = Csr::from_dense(&d, 0.0);
        assert_ne!(
            fingerprint_payload(&MatrixPayload::Dense(d)),
            fingerprint_payload(&MatrixPayload::Sparse(s))
        );
    }

    #[test]
    fn job_key_separates_seed_config_and_data() {
        let a = Mat::from_fn(10, 8, |i, j| ((i * 31 + j * 7) % 13) as f64);
        let job = |seed, c| ApproxJob::Cur {
            a: MatrixPayload::Dense(a.clone()),
            cfg: crate::cur::CurConfig::fast(c, 4, 2),
            seed,
        };
        let base = job_key(&job(1, 4));
        assert_eq!(base, job_key(&job(1, 4)), "key must be a pure function of the job");
        assert_ne!(base, job_key(&job(2, 4)), "seed must enter the config digest");
        assert_ne!(base, job_key(&job(1, 5)), "config must enter the digest");
        assert_eq!(base.dataset, job_key(&job(2, 4)).dataset, "dataset half ignores config");
        let mut b = a.clone();
        b.data_mut()[0] += 1.0;
        let other = job_key(&ApproxJob::Cur {
            a: MatrixPayload::Dense(b),
            cfg: crate::cur::CurConfig::fast(4, 4, 2),
            seed: 1,
        });
        assert_ne!(base.dataset, other.dataset, "data bytes must enter the dataset half");
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        // 3 entries of 800 bytes each against a 2000-byte budget.
        let mut cache = ArtifactCache::new(2000);
        let (k1, k2, k3) = (CacheKey::new(1, 1), CacheKey::new(2, 2), CacheKey::new(3, 3));
        let r = result_of(10, 10); // 800 bytes
        assert_eq!(r.approx_bytes(), 800);
        assert_eq!(cache.insert(k1, &r), 0);
        assert_eq!(cache.insert(k2, &r), 0);
        assert_eq!(cache.bytes(), 1600);
        // Touch k1 so k2 is the LRU victim.
        assert!(cache.get(&k1).is_some());
        assert_eq!(cache.insert(k3, &r), 1, "one eviction to fit the third entry");
        assert_eq!(cache.len(), 2);
        assert!(cache.bytes() <= cache.budget());
        assert!(cache.get(&k2).is_none(), "LRU entry k2 must be the victim");
        assert!(cache.get(&k1).is_some() && cache.get(&k3).is_some());
    }

    #[test]
    fn oversized_artifacts_are_not_admitted() {
        let mut cache = ArtifactCache::new(100);
        let key = CacheKey::new(7, 7);
        assert_eq!(cache.insert(key, &result_of(10, 10)), 0);
        assert!(cache.is_empty(), "an artifact larger than the budget must not evict residents");
        assert!(cache.get(&key).is_none());
    }

    #[test]
    fn reinsert_replaces_in_place() {
        let mut cache = ArtifactCache::new(2000);
        let key = CacheKey::new(9, 9);
        cache.insert(key, &result_of(10, 10));
        cache.insert(key, &result_of(5, 10));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.bytes(), 400);
    }

    #[test]
    fn manifest_lists_entries_in_manifest_line_format() {
        let mut cache = ArtifactCache::new(10_000);
        cache.insert(CacheKey::new(0xAB, 0xCD), &result_of(4, 3));
        let listing = cache.manifest();
        assert!(listing.starts_with("# artifact cache: 1 entries"));
        assert!(listing.contains("file=cache"), "reuses the manifest line shape: {listing}");
        assert!(listing.contains("outputs=4x3"), "{listing}");
    }

    /// One result of every kind, with distinctive (irrational) entries so
    /// a bitwise round-trip failure cannot hide behind round numbers.
    fn one_of_each() -> Vec<(CacheKey, JobResult)> {
        let m = |r, c, salt: f64| Mat::from_fn(r, c, |i, j| ((i * 7 + j) as f64 + salt).sin());
        vec![
            (CacheKey::new(0x11, 0xA1), JobResult::Gmr { x: m(4, 3, 0.1) }),
            (
                CacheKey::new(0x22, 0xA2),
                JobResult::Spsd {
                    idx: vec![3, 1, 4, 1, 5],
                    c: m(6, 5, 0.2),
                    x: m(5, 5, 0.3),
                    entries_observed: 271828,
                },
            ),
            (
                CacheKey::new(0x33, 0xA3),
                JobResult::Svd { u: m(6, 2, 0.4), sigma: vec![2.5, 0.125], v: m(5, 2, 0.5) },
            ),
            (
                CacheKey::new(0x44, 0xA4),
                JobResult::Cur {
                    cur: crate::cur::CurDecomposition {
                        col_idx: vec![0, 2, 3],
                        row_idx: vec![1, 4],
                        c: m(5, 3, 0.6),
                        u: m(3, 2, 0.7),
                        r: m(2, 6, 0.8),
                    },
                },
            ),
        ]
    }

    #[test]
    fn persist_and_warm_start_round_trip_every_kind_bitwise() {
        let path = Path::new("/tmp/fastgmr_cache_roundtrip_test.txt");
        let mut cache = ArtifactCache::new(1 << 20);
        for (key, result) in &one_of_each() {
            cache.insert(*key, result);
        }
        cache.persist_to(path).unwrap();
        let mut warmed = ArtifactCache::new(1 << 20);
        let stats = warmed.warm_start_from(path).unwrap();
        assert_eq!(stats, WarmStartStats { loaded: 4, skipped_corrupt: 0, expired: 0 });
        for (key, expected) in &one_of_each() {
            let got = warmed.get(key).expect("entry survives the round trip");
            assert_eq!(got.kind(), expected.kind());
            assert_eq!(got.output_shapes(), expected.output_shapes());
            let label = format!("bitwise round trip for {}", got.kind());
            assert_eq!(got.to_words(), expected.to_words(), "{label}");
        }
        let _ = fs::remove_file(path);
    }

    #[test]
    fn warm_start_skips_corrupt_records_and_keeps_the_rest() {
        let path = Path::new("/tmp/fastgmr_cache_corrupt_test.txt");
        let mut cache = ArtifactCache::new(1 << 20);
        for (key, result) in &one_of_each() {
            cache.insert(*key, result);
        }
        cache.persist_to(path).unwrap();
        // Mangle the checksum of the second record only.
        let text = fs::read_to_string(path).unwrap();
        let mut seen = 0;
        let mangled: Vec<String> = text
            .lines()
            .map(|l| {
                if l.starts_with("words ") {
                    seen += 1;
                    if seen == 2 {
                        let mut parts: Vec<&str> = l.split_whitespace().collect();
                        parts[2] = "0000000000000000";
                        return parts.join(" ");
                    }
                }
                l.to_string()
            })
            .collect();
        fs::write(path, mangled.join("\n")).unwrap();
        let mut warmed = ArtifactCache::new(1 << 20);
        let stats = warmed.warm_start_from(path).unwrap();
        assert_eq!(stats.loaded, 3, "the three intact records load");
        assert_eq!(stats.skipped_corrupt, 1, "the mangled record is skipped, not fatal");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn warm_start_from_missing_file_is_a_cold_start() {
        let mut cache = ArtifactCache::new(1000);
        let stats =
            cache.warm_start_from(Path::new("/tmp/fastgmr_no_such_cache_file.txt")).unwrap();
        assert_eq!(stats, WarmStartStats::default());
        assert!(cache.is_empty());
    }

    #[test]
    fn warm_start_refuses_a_file_without_the_format_header() {
        let path = Path::new("/tmp/fastgmr_cache_bad_header_test.txt");
        fs::write(path, "not a cache inventory\n").unwrap();
        let err = ArtifactCache::new(1000).warm_start_from(path).unwrap_err();
        assert!(err.to_string().contains("artifact cache"), "{err}");
        let _ = fs::remove_file(path);
    }

    #[test]
    fn ttl_expires_entries_by_logical_age() {
        let mut cache = ArtifactCache::new(10_000).with_ttl(2);
        let (k1, k2) = (CacheKey::new(1, 1), CacheKey::new(2, 2));
        cache.insert(k1, &result_of(4, 3)); // tick 1
        assert!(matches!(cache.lookup(&k1), Lookup::Hit(_)), "age 1 is within ttl 2"); // tick 2
        assert!(matches!(cache.lookup(&k2), Lookup::Miss)); // tick 3
        // Tick 4: age 3 > ttl 2 — the entry expires and frees its bytes.
        assert!(matches!(cache.lookup(&k1), Lookup::Expired));
        assert_eq!(cache.bytes(), 0);
        assert_eq!(cache.len(), 0);
        assert!(matches!(cache.lookup(&k1), Lookup::Miss), "expired entries are gone, not stale");
    }

    #[test]
    fn hits_do_not_extend_ttl() {
        // LRU recency refresh must not reset the age clock: an entry hit
        // on every tick still expires once it outlives the TTL.
        let mut cache = ArtifactCache::new(10_000).with_ttl(3);
        let k = CacheKey::new(5, 5);
        cache.insert(k, &result_of(4, 3)); // tick 1
        for _ in 0..3 {
            assert!(matches!(cache.lookup(&k), Lookup::Hit(_))); // ticks 2..=4
        }
        assert!(matches!(cache.lookup(&k), Lookup::Expired)); // tick 5: age 4 > 3
    }

    #[test]
    fn zero_ttl_never_expires() {
        let mut cache = ArtifactCache::new(10_000);
        let k = CacheKey::new(6, 6);
        cache.insert(k, &result_of(4, 3));
        for _ in 0..100 {
            assert!(cache.get(&k).is_some());
        }
    }

    #[test]
    fn warm_start_honors_ttl_from_persisted_insertion_ticks() {
        let path = Path::new("/tmp/fastgmr_cache_ttl_warm_test.txt");
        let mut cache = ArtifactCache::new(1 << 20).with_ttl(4);
        let (old, fresh) = (CacheKey::new(0xAA, 1), CacheKey::new(0xBB, 2));
        cache.insert(old, &result_of(4, 3)); // tick 1
        for _ in 0..5 {
            let _ = cache.lookup(&CacheKey::new(0xFF, 0xFF)); // burn ticks 2..=6
        }
        cache.insert(fresh, &result_of(5, 3)); // tick 7
        cache.persist_to(path).unwrap(); // persist tick 7: old is age 6, fresh age 0
        let mut warmed = ArtifactCache::new(1 << 20).with_ttl(4);
        let stats = warmed.warm_start_from(path).unwrap();
        assert_eq!(stats.loaded, 1, "only the fresh entry is restored");
        assert_eq!(stats.expired, 1, "the stale entry is dropped at load, not resurrected");
        assert!(warmed.get(&fresh).is_some());
        assert!(warmed.get(&old).is_none());
        let _ = fs::remove_file(path);
    }

    #[test]
    fn warm_start_restores_remaining_ttl_not_a_fresh_one() {
        let path = Path::new("/tmp/fastgmr_cache_ttl_age_test.txt");
        let mut cache = ArtifactCache::new(1 << 20).with_ttl(4);
        let k = CacheKey::new(0xCC, 3);
        cache.insert(k, &result_of(4, 3)); // tick 1
        let _ = cache.lookup(&CacheKey::new(0xFF, 0xFF)); // tick 2
        let _ = cache.lookup(&CacheKey::new(0xFF, 0xFF)); // tick 3
        cache.persist_to(path).unwrap(); // persisted at age 2 of ttl 4
        let mut warmed = ArtifactCache::new(1 << 20).with_ttl(4);
        assert_eq!(warmed.warm_start_from(path).unwrap().loaded, 1);
        assert!(matches!(warmed.lookup(&k), Lookup::Hit(_)), "remaining ttl still serves"); // tick 2
        let _ = warmed.lookup(&CacheKey::new(0xFF, 0xFF)); // tick 3
        let _ = warmed.lookup(&CacheKey::new(0xFF, 0xFF)); // tick 4
        // Tick 5: a freshly-inserted entry would still be alive (age 4),
        // but the restored age bounds the total lifetime across the
        // restart.
        assert!(matches!(warmed.lookup(&k), Lookup::Expired));
        let _ = fs::remove_file(path);
    }

    #[test]
    fn cache_names_parse_back_including_underscored_kinds() {
        for kind in ApproxJob::KINDS {
            let name = format!("{}_{:016x}_{:016x}", kind, 0xdead_beefu64, 7u64);
            let (parsed, key) = parse_cache_name(&name).expect("name round-trips");
            assert_eq!(parsed, kind);
            assert_eq!(key, CacheKey::new(0xdead_beef, 7));
        }
        assert!(parse_cache_name("gmr_0123_0456").is_none(), "short hex halves are rejected");
    }
}
