//! Fingerprint-keyed artifact cache — the amortization layer of the
//! serving daemon.
//!
//! The paper's economics are amortization: one pair of sketches serves
//! many downstream approximations — the same sketched factors back CUR
//! (§3), SPSD (§4), and single-pass SVD (§5) queries over the same
//! dataset. In a serving setting that sharing happens *across requests*:
//! repeated queries against a dataset the daemon has already factorized
//! should hit a cached artifact instead of recomputing it. This module
//! provides the key — a 64-bit fingerprint of the dataset bytes paired
//! with a digest of the job configuration (sketch family, sizes, seed) —
//! and an LRU store with a byte budget holding completed [`JobResult`]s.
//!
//! Because every job is deterministic given its seed, a cache hit is
//! *bitwise identical* to a cold compute (pinned in `coordinator::tests`),
//! so caching is transparent to callers. The inventory listing reuses the
//! [`crate::runtime::artifacts::ManifestEntry`] line shape, so cached
//! factorizations and AOT-compiled graphs read the same way.

use crate::coordinator::jobs::{ApproxJob, JobResult, MatrixPayload};
use crate::cur::{CoreMethod, SelectionStrategy};
use crate::linalg::Mat;
use crate::runtime::artifacts::ManifestEntry;
use crate::sparse::Csr;
use std::collections::HashMap;
use std::path::PathBuf;

/// Word-folded FNV-1a: the classic byte-wise FNV-1a constants applied
/// per 64-bit word (one xor + multiply per `f64`/`usize`), which keeps
/// fingerprinting a large matrix cheap relative to any factorization of
/// it while still mixing every bit of every entry into the digest.
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn write_u64(&mut self, word: u64) {
        self.0 ^= word;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    pub fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }

    /// Fold in an `f64` by bit pattern (so `-0.0` and `0.0` differ —
    /// the cache contract is bitwise, not numeric).
    pub fn write_f64(&mut self, x: f64) {
        self.write_u64(x.to_bits());
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        for b in s.as_bytes() {
            self.write_u64(u64::from(*b));
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Fingerprint of a dense matrix: dimensions plus every entry's bit
/// pattern, in storage order.
pub fn fingerprint_dense(a: &Mat) -> u64 {
    let mut h = Fnv64::new();
    h.write_str("dense");
    h.write_usize(a.rows());
    h.write_usize(a.cols());
    for &x in a.data() {
        h.write_f64(x);
    }
    h.finish()
}

/// Fingerprint of a CSR matrix: dimensions plus the full sparsity
/// structure and values (`O(nnz)`, never densified).
pub fn fingerprint_sparse(a: &Csr) -> u64 {
    let mut h = Fnv64::new();
    h.write_str("csr");
    h.write_usize(a.rows());
    h.write_usize(a.cols());
    for i in 0..a.rows() {
        let (idx, vals) = a.row(i);
        h.write_usize(idx.len());
        for &j in idx {
            h.write_usize(j);
        }
        for &v in vals {
            h.write_f64(v);
        }
    }
    h.finish()
}

/// Fingerprint of a job payload (the dataset half of a [`CacheKey`]).
pub fn fingerprint_payload(p: &MatrixPayload) -> u64 {
    match p {
        MatrixPayload::Dense(a) => fingerprint_dense(a),
        MatrixPayload::Sparse(a) => fingerprint_sparse(a),
    }
}

/// Key of one cached artifact: dataset fingerprint × config digest.
///
/// Two jobs share a key exactly when they would compute the same factor:
/// same input bytes, same algorithm, same sketch configuration, same
/// seed. [`job_key`] derives both halves from an [`ApproxJob`].
///
/// ```
/// use fastgmr::coordinator::CacheKey;
/// let key = CacheKey::new(0x5eed_da7a, 0xc0ffee);
/// assert_eq!(key, CacheKey::new(0x5eed_da7a, 0xc0ffee));
/// assert_ne!(key, CacheKey::new(0x5eed_da7a, 0xc0ffef));   // config differs
/// assert_ne!(key, CacheKey::new(0x5eed_da7b, 0xc0ffee));   // dataset differs
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Fingerprint of the dataset bytes (payload matrices).
    pub dataset: u64,
    /// Digest of the job kind + configuration + seed.
    pub config: u64,
}

impl CacheKey {
    pub fn new(dataset: u64, config: u64) -> Self {
        Self { dataset, config }
    }
}

fn sketch_tag(h: &mut Fnv64, kind: crate::sketch::SketchKind) {
    h.write_str(kind.name());
}

fn selection_tag(h: &mut Fnv64, s: &SelectionStrategy) {
    match s {
        SelectionStrategy::Uniform => h.write_str("uniform"),
        SelectionStrategy::Leverage => h.write_str("leverage"),
        SelectionStrategy::SubspaceLeverage { k } => {
            h.write_str("subspace");
            h.write_usize(*k);
        }
        SelectionStrategy::SketchedLeverage { kind, size } => {
            h.write_str("sketched");
            sketch_tag(h, *kind);
            h.write_usize(*size);
        }
    }
}

fn core_tag(h: &mut Fnv64, c: &CoreMethod) {
    match c {
        CoreMethod::Exact => h.write_str("exact"),
        CoreMethod::FastGmr => h.write_str("fast_gmr"),
        CoreMethod::StabilizedQr => h.write_str("stabilized_qr"),
    }
}

/// Derive the cache key of a job: the dataset fingerprint over every
/// input matrix, and a config digest over the job kind, every
/// algorithmic parameter, and the seed (jobs are deterministic given
/// their seed, so equal keys imply bitwise-equal results).
pub fn job_key(job: &ApproxJob) -> CacheKey {
    let mut cfg = Fnv64::new();
    cfg.write_str(job.kind());
    let dataset = match job {
        ApproxJob::Gmr { a, c, r, cfg: f, seed } => {
            sketch_tag(&mut cfg, f.kind_c);
            sketch_tag(&mut cfg, f.kind_r);
            cfg.write_usize(f.s_c);
            cfg.write_usize(f.s_r);
            cfg.write_u64(*seed);
            let mut d = Fnv64::new();
            d.write_u64(fingerprint_payload(a));
            d.write_u64(fingerprint_dense(c));
            d.write_u64(fingerprint_dense(r));
            d.finish()
        }
        ApproxJob::GmrExact { a, c, r } => {
            let mut d = Fnv64::new();
            d.write_u64(fingerprint_payload(a));
            d.write_u64(fingerprint_dense(c));
            d.write_u64(fingerprint_dense(r));
            d.finish()
        }
        ApproxJob::SpsdKernel { x, sigma, c, s, seed } => {
            cfg.write_f64(*sigma);
            cfg.write_usize(*c);
            cfg.write_usize(*s);
            cfg.write_u64(*seed);
            fingerprint_dense(x)
        }
        ApproxJob::StreamSvd { a, cfg: f, block, seed } => {
            cfg.write_usize(f.k);
            cfg.write_usize(f.c);
            cfg.write_usize(f.r);
            cfg.write_usize(f.s_c);
            cfg.write_usize(f.s_r);
            cfg.write_usize(f.osnap_mult);
            sketch_tag(&mut cfg, f.core_kind);
            cfg.write_usize(*block);
            cfg.write_u64(*seed);
            fingerprint_payload(a)
        }
        ApproxJob::Cur { a, cfg: f, seed } => {
            cfg.write_usize(f.c);
            cfg.write_usize(f.r);
            selection_tag(&mut cfg, &f.selection);
            core_tag(&mut cfg, &f.core);
            sketch_tag(&mut cfg, f.sketch);
            cfg.write_usize(f.s_c);
            cfg.write_usize(f.s_r);
            cfg.write_u64(*seed);
            fingerprint_payload(a)
        }
        ApproxJob::StreamingCur { a, cfg: f, block, seed } => {
            cfg.write_usize(f.c);
            cfg.write_usize(f.r);
            cfg.write_usize(f.k);
            sketch_tag(&mut cfg, f.kind);
            cfg.write_usize(f.s_c);
            cfg.write_usize(f.s_r);
            cfg.write_usize(f.oversample);
            cfg.write_usize(*block);
            cfg.write_u64(*seed);
            fingerprint_payload(a)
        }
    };
    CacheKey::new(dataset, cfg.finish())
}

struct Entry {
    result: JobResult,
    bytes: usize,
    /// Last-touched logical time (monotone per cache op) — the LRU order.
    tick: u64,
    kind: &'static str,
}

/// LRU artifact store with a byte budget.
///
/// Holds completed [`JobResult`]s keyed by [`CacheKey`]; `get` refreshes
/// recency, `insert` evicts least-recently-used entries until the new
/// artifact fits. A result larger than the whole budget is not admitted
/// (churning every resident artifact for one oversized one is never a
/// win). Purely a data structure — the [`crate::coordinator::Router`]
/// owns the locking and translates hits/misses/evictions into `serve.*`
/// metrics.
pub struct ArtifactCache {
    budget: usize,
    bytes: usize,
    tick: u64,
    map: HashMap<CacheKey, Entry>,
}

impl ArtifactCache {
    /// An empty cache with the given byte budget.
    pub fn new(budget_bytes: usize) -> Self {
        Self { budget: budget_bytes, bytes: 0, tick: 0, map: HashMap::new() }
    }

    /// Look up an artifact, refreshing its recency on hit.
    pub fn get(&mut self, key: &CacheKey) -> Option<JobResult> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(key).map(|e| {
            e.tick = tick;
            e.result.clone()
        })
    }

    /// Store an artifact, evicting LRU entries until it fits; returns
    /// how many residents were evicted (0 if the artifact was oversized
    /// and not admitted, or simply fit).
    pub fn insert(&mut self, key: CacheKey, result: &JobResult) -> usize {
        let bytes = result.approx_bytes();
        if bytes > self.budget {
            return 0;
        }
        if let Some(old) = self.map.remove(&key) {
            self.bytes -= old.bytes;
        }
        let mut evicted = 0;
        while self.bytes + bytes > self.budget {
            let Some((&victim, _)) = self.map.iter().min_by_key(|(_, e)| e.tick) else { break };
            let gone = self.map.remove(&victim).expect("victim key just observed");
            self.bytes -= gone.bytes;
            evicted += 1;
        }
        self.tick += 1;
        self.bytes += bytes;
        let entry = Entry { result: result.clone(), bytes, tick: self.tick, kind: result.kind() };
        self.map.insert(key, entry);
        evicted
    }

    /// Resident artifact count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Resident bytes (always ≤ the budget).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// The configured byte budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Render the resident artifacts in the `manifest.txt` line format
    /// of [`ManifestEntry::to_line`], LRU first — the serving inventory
    /// the `fastgmr serve` subcommand prints.
    pub fn manifest(&self) -> String {
        let mut rows: Vec<(u64, String)> = self
            .map
            .iter()
            .map(|(key, e)| {
                let entry = ManifestEntry {
                    name: format!("{}_{:016x}_{:016x}", e.kind, key.dataset, key.config),
                    hlo_path: PathBuf::from("cache"),
                    input_shapes: Vec::new(),
                    output_shapes: e.result.output_shapes(),
                    golden_path: None,
                };
                (e.tick, entry.to_line())
            })
            .collect();
        rows.sort();
        let mut out = format!(
            "# artifact cache: {} entries, {} / {} bytes (LRU first)\n",
            self.map.len(),
            self.bytes,
            self.budget
        );
        for (_, line) in rows {
            out.push_str(&line);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result_of(rows: usize, cols: usize) -> JobResult {
        JobResult::Gmr { x: Mat::zeros(rows, cols) }
    }

    #[test]
    fn fingerprint_is_content_sensitive() {
        let a = Mat::from_fn(5, 4, |i, j| (i * 4 + j) as f64);
        let mut b = a.clone();
        assert_eq!(fingerprint_dense(&a), fingerprint_dense(&b));
        b.data_mut()[7] += 1e-12;
        assert_ne!(fingerprint_dense(&a), fingerprint_dense(&b));
        // Same bytes, different shape ⇒ different fingerprint.
        let c = Mat::from_vec(4, 5, a.data().to_vec());
        assert_ne!(fingerprint_dense(&a), fingerprint_dense(&c));
    }

    #[test]
    fn sparse_and_dense_fingerprints_are_tagged_apart() {
        let d = Mat::zeros(3, 3);
        let s = Csr::from_dense(&d, 0.0);
        assert_ne!(
            fingerprint_payload(&MatrixPayload::Dense(d)),
            fingerprint_payload(&MatrixPayload::Sparse(s))
        );
    }

    #[test]
    fn job_key_separates_seed_config_and_data() {
        let a = Mat::from_fn(10, 8, |i, j| ((i * 31 + j * 7) % 13) as f64);
        let job = |seed, c| ApproxJob::Cur {
            a: MatrixPayload::Dense(a.clone()),
            cfg: crate::cur::CurConfig::fast(c, 4, 2),
            seed,
        };
        let base = job_key(&job(1, 4));
        assert_eq!(base, job_key(&job(1, 4)), "key must be a pure function of the job");
        assert_ne!(base, job_key(&job(2, 4)), "seed must enter the config digest");
        assert_ne!(base, job_key(&job(1, 5)), "config must enter the digest");
        assert_eq!(base.dataset, job_key(&job(2, 4)).dataset, "dataset half ignores config");
        let mut b = a.clone();
        b.data_mut()[0] += 1.0;
        let other = job_key(&ApproxJob::Cur {
            a: MatrixPayload::Dense(b),
            cfg: crate::cur::CurConfig::fast(4, 4, 2),
            seed: 1,
        });
        assert_ne!(base.dataset, other.dataset, "data bytes must enter the dataset half");
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        // 3 entries of 800 bytes each against a 2000-byte budget.
        let mut cache = ArtifactCache::new(2000);
        let (k1, k2, k3) = (CacheKey::new(1, 1), CacheKey::new(2, 2), CacheKey::new(3, 3));
        let r = result_of(10, 10); // 800 bytes
        assert_eq!(r.approx_bytes(), 800);
        assert_eq!(cache.insert(k1, &r), 0);
        assert_eq!(cache.insert(k2, &r), 0);
        assert_eq!(cache.bytes(), 1600);
        // Touch k1 so k2 is the LRU victim.
        assert!(cache.get(&k1).is_some());
        assert_eq!(cache.insert(k3, &r), 1, "one eviction to fit the third entry");
        assert_eq!(cache.len(), 2);
        assert!(cache.bytes() <= cache.budget());
        assert!(cache.get(&k2).is_none(), "LRU entry k2 must be the victim");
        assert!(cache.get(&k1).is_some() && cache.get(&k3).is_some());
    }

    #[test]
    fn oversized_artifacts_are_not_admitted() {
        let mut cache = ArtifactCache::new(100);
        let key = CacheKey::new(7, 7);
        assert_eq!(cache.insert(key, &result_of(10, 10)), 0);
        assert!(cache.is_empty(), "an artifact larger than the budget must not evict residents");
        assert!(cache.get(&key).is_none());
    }

    #[test]
    fn reinsert_replaces_in_place() {
        let mut cache = ArtifactCache::new(2000);
        let key = CacheKey::new(9, 9);
        cache.insert(key, &result_of(10, 10));
        cache.insert(key, &result_of(5, 10));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.bytes(), 400);
    }

    #[test]
    fn manifest_lists_entries_in_manifest_line_format() {
        let mut cache = ArtifactCache::new(10_000);
        cache.insert(CacheKey::new(0xAB, 0xCD), &result_of(4, 3));
        let listing = cache.manifest();
        assert!(listing.starts_with("# artifact cache: 1 entries"));
        assert!(listing.contains("file=cache"), "reuses the manifest line shape: {listing}");
        assert!(listing.contains("outputs=4x3"), "{listing}");
    }
}
